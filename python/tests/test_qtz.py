"""QTZ container: python round-trip + header invariants that the Rust
reader relies on (magic, alignment, dtype tags)."""

import json
import struct

import numpy as np
import pytest

from compile import qtz


def test_roundtrip(tmp_path):
    path = str(tmp_path / "t.qtz")
    tensors = {
        "w": np.arange(12, dtype=np.float32).reshape(3, 4),
        "codes": np.array([[-8, 7], [0, 1]], dtype=np.int8),
        "bias": np.array([1.5, -2.5], dtype=np.float32),
    }
    qtz.save(path, tensors, {"name": "unit", "dim": 4})
    meta, back = qtz.load(path)
    assert meta == {"name": "unit", "dim": 4}
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype


def test_header_layout(tmp_path):
    path = str(tmp_path / "t.qtz")
    qtz.save(path, {"x": np.zeros(3, dtype=np.float32)}, {})
    raw = open(path, "rb").read()
    assert raw[:4] == b"QTZ1"
    (hlen,) = struct.unpack("<Q", raw[4:12])
    header = json.loads(raw[12 : 12 + hlen])
    entry = header["tensors"]["x"]
    assert entry["dtype"] == "f32"
    assert entry["shape"] == [3]
    assert entry["offset"] % 64 == 0


def test_rejects_unsupported_dtype(tmp_path):
    with pytest.raises(TypeError):
        qtz.save(str(tmp_path / "bad.qtz"), {"x": np.zeros(2, dtype=np.float64)})


def test_rust_compatible_meta_types(tmp_path):
    # Rust parses meta ints via as_usize on JSON numbers.
    path = str(tmp_path / "t.qtz")
    qtz.save(path, {"x": np.zeros(1, dtype=np.float32)},
             {"dim": 64, "n_layers": 4})
    meta, _ = qtz.load(path)
    assert isinstance(meta["dim"], int)
