"""L1 kernel correctness: Pallas (interpret) vs the pure-jnp oracle.
Hypothesis sweeps shapes and block configurations; this is the CORE
correctness signal for the quantized serving path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hessian_accum, quant_matmul
from compile.kernels.ref import dequantize_ref, hessian_ref, quant_matmul_ref


def rand_quant_problem(rng, m, n, k, group, bits=4):
    x = rng.standard_normal((m, k), dtype=np.float32)
    codes = rng.integers(0, 2**bits, size=(n, k)).astype(np.float32)
    g = k // group
    scales = (0.01 + rng.random((n, g))).astype(np.float32)
    zeros = rng.integers(0, 2**bits, size=(n, g)).astype(np.float32)
    return x, codes, scales, zeros


class TestQuantMatmul:
    @settings(max_examples=12, deadline=None)
    @given(
        m=st.sampled_from([8, 32, 128]),
        n=st.sampled_from([32, 64, 128]),
        kg=st.sampled_from([(32, 32), (64, 32), (128, 64), (256, 32)]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_across_shapes(self, m, n, kg, seed):
        k, group = kg
        rng = np.random.default_rng(seed)
        x, codes, scales, zeros = rand_quant_problem(rng, m, n, k, group)
        got = quant_matmul(x, codes, scales, zeros, group=group,
                           block_m=min(32, m), block_n=min(32, n))
        want = quant_matmul_ref(x, codes, scales, zeros, group)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)

    def test_blocking_is_invisible(self):
        rng = np.random.default_rng(0)
        x, codes, scales, zeros = rand_quant_problem(rng, 128, 128, 64, 32)
        a = quant_matmul(x, codes, scales, zeros, group=32, block_m=128, block_n=128)
        b = quant_matmul(x, codes, scales, zeros, group=32, block_m=32, block_n=64)
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-5)

    def test_zero_codes_give_negative_zero_point_rows(self):
        # All-zero codes dequantize to (0 - zero) * scale exactly.
        rng = np.random.default_rng(1)
        x = np.eye(4, 32, dtype=np.float32)
        codes = np.zeros((8, 32), dtype=np.float32)
        scales = np.full((8, 1), 2.0, dtype=np.float32)
        zeros = np.full((8, 1), 3.0, dtype=np.float32)
        got = quant_matmul(x, codes, scales, zeros, group=32, block_m=4, block_n=8)
        np.testing.assert_allclose(got, np.full((4, 8), -6.0), rtol=1e-6)

    def test_group_structure_respected(self):
        # Different scales per group must produce different columns.
        x = np.ones((4, 64), dtype=np.float32)
        codes = np.ones((4, 64), dtype=np.float32)
        scales = np.array([[1.0, 10.0]] * 4, dtype=np.float32)
        zeros = np.zeros((4, 2), dtype=np.float32)
        got = quant_matmul(x, codes, scales, zeros, group=32, block_m=4, block_n=4)
        want = np.full((4, 4), 32 * 1.0 + 32 * 10.0, dtype=np.float32)
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_rejects_bad_group(self):
        rng = np.random.default_rng(2)
        x, codes, scales, zeros = rand_quant_problem(rng, 8, 8, 32, 32)
        with pytest.raises(AssertionError):
            quant_matmul(x, codes, scales, zeros, group=33)


class TestDequantRef:
    def test_roundtrip_against_manual(self):
        codes = np.array([[0.0, 1.0, 2.0, 3.0]], dtype=np.float32)
        scales = np.array([[0.5, 2.0]], dtype=np.float32)
        zeros = np.array([[1.0, 2.0]], dtype=np.float32)
        w = dequantize_ref(codes, scales, zeros, group=2)
        np.testing.assert_allclose(w, [[-0.5, 0.0, 0.0, 2.0]])


class TestHessian:
    @settings(max_examples=10, deadline=None)
    @given(
        m=st.sampled_from([64, 128, 256, 512]),
        d=st.sampled_from([16, 64, 128]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, m, d, seed):
        rng = np.random.default_rng(seed)
        x = rng.standard_normal((m, d), dtype=np.float32)
        got = hessian_accum(x, block_m=64)
        np.testing.assert_allclose(got, hessian_ref(x), rtol=1e-4, atol=1e-3)

    def test_accumulation_across_tiles(self):
        # Splitting the token axis must not change the result.
        rng = np.random.default_rng(3)
        x = rng.standard_normal((256, 32), dtype=np.float32)
        a = hessian_accum(x, block_m=256)
        b = hessian_accum(x, block_m=32)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-4)

    def test_result_is_symmetric_psd(self):
        rng = np.random.default_rng(4)
        x = rng.standard_normal((128, 24), dtype=np.float32)
        h = np.asarray(hessian_accum(x))
        np.testing.assert_allclose(h, h.T, rtol=1e-5, atol=1e-4)
        eig = np.linalg.eigvalsh(h.astype(np.float64))
        assert eig.min() > -1e-3
