"""L2 model tests: shapes, op semantics matching the Rust forward spec,
and a smoke training step (loss must drop)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def tiny():
    cfg = model.Config("unit", 32, 2, 2, 64, seq_len=16)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_param_names_cover_init(tiny):
    cfg, params = tiny
    names = model.param_names(cfg)
    assert set(names) == set(params.keys())
    assert names[0] == "embed" and names[-1] == "final_norm"


def test_forward_shapes(tiny):
    cfg, params = tiny
    tokens = jnp.arange(cfg.seq_len, dtype=jnp.int32) % 200
    logits = model.forward_segment(cfg, params, tokens)
    assert logits.shape == (cfg.seq_len, cfg.vocab)
    batch = jnp.stack([tokens, tokens + 1])
    blogits = model.forward_batch(cfg, params, batch)
    assert blogits.shape == (2, cfg.seq_len, cfg.vocab)


def test_rmsnorm_matches_manual(tiny):
    cfg, _ = tiny
    x = jax.random.normal(jax.random.PRNGKey(1), (5, cfg.dim))
    g = jnp.ones((cfg.dim,)) * 2.0
    y = model.rmsnorm(x, g)
    ms = np.mean(np.asarray(x) ** 2, axis=-1, keepdims=True)
    want = np.asarray(x) / np.sqrt(ms + model.NORM_EPS) * 2.0
    np.testing.assert_allclose(y, want, rtol=1e-5)


def test_attention_is_causal(tiny):
    cfg, params = tiny
    t1 = jnp.zeros((cfg.seq_len,), jnp.int32)
    t2 = t1.at[-1].set(77)  # change only the last token
    l1 = model.forward_segment(cfg, params, t1)
    l2 = model.forward_segment(cfg, params, t2)
    np.testing.assert_allclose(l1[:-1], l2[:-1], rtol=1e-5, atol=1e-6)
    assert not np.allclose(l1[-1], l2[-1])


def test_untrained_ppl_near_uniform(tiny):
    cfg, params = tiny
    tokens = jax.random.randint(jax.random.PRNGKey(2), (4, cfg.seq_len), 0, 256)
    ppl = float(model.perplexity(cfg, params, tokens))
    assert 0.5 * cfg.vocab < ppl < 2.0 * cfg.vocab


def test_one_train_step_reduces_loss(tiny):
    cfg, params = tiny
    tokens = jax.random.randint(jax.random.PRNGKey(3), (8, cfg.seq_len), 90, 110)
    loss_fn = lambda p: model.next_token_loss(cfg, p, tokens)
    l0, grads = jax.value_and_grad(loss_fn)(params)
    params2 = jax.tree_util.tree_map(lambda p, g: p - 0.5 * g, params, grads)
    l1 = loss_fn(params2)
    assert float(l1) < float(l0)


def test_block_captures_present(tiny):
    cfg, params = tiny
    x = jax.random.normal(jax.random.PRNGKey(4), (cfg.seq_len, cfg.dim))
    out, cap = model.block(cfg, params, 0, x)
    assert out.shape == x.shape
    assert set(cap) == {"attn_in", "attn_ctx", "mlp_in", "mlp_act"}
    assert cap["mlp_act"].shape == (cfg.seq_len, cfg.ffn)
