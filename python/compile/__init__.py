"""Build-time Python: JAX model (L2), Pallas kernels (L1), trainer, and AOT
export to HLO-text artifacts. Never imported at runtime — the Rust binary
only reads the files this package writes."""
