"""Python side of the QTZ tensor container (mirrors rust/src/io/qtz.rs).

Layout: b"QTZ1" | u64 LE header_len | JSON header | 64-byte-aligned blob.
"""

import json
import struct

import numpy as np

MAGIC = b"QTZ1"
ALIGN = 64

_DTYPES = {"f32": (np.float32, 4), "i8": (np.int8, 1)}


def save(path: str, tensors: dict, meta: dict | None = None):
    """tensors: name → np.ndarray (float32 or int8)."""
    blob = bytearray()
    entries = {}
    for name, arr in tensors.items():
        arr = np.ascontiguousarray(arr)
        if arr.dtype == np.float32:
            dt = "f32"
        elif arr.dtype == np.int8:
            dt = "i8"
        else:
            raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
        while len(blob) % ALIGN != 0:
            blob.append(0)
        offset = len(blob)
        raw = arr.tobytes()  # little-endian on all supported hosts
        blob.extend(raw)
        entries[name] = {
            "dtype": dt,
            "shape": list(arr.shape),
            "offset": offset,
            "nbytes": len(raw),
        }
    header = json.dumps({"meta": meta or {}, "tensors": entries}).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<Q", len(header)))
        f.write(header)
        pos = 12 + len(header)
        f.write(b" " * (-pos % ALIGN))
        f.write(bytes(blob))


def load(path: str):
    """Returns (meta, {name: np.ndarray})."""
    with open(path, "rb") as f:
        data = f.read()
    assert data[:4] == MAGIC, "not a QTZ1 file"
    (hlen,) = struct.unpack("<Q", data[4:12])
    header = json.loads(data[12 : 12 + hlen])
    blob_start = -(-(12 + hlen) // ALIGN) * ALIGN
    blob = data[blob_start:]
    tensors = {}
    for name, e in header["tensors"].items():
        np_dt, _ = _DTYPES[e["dtype"]]
        raw = blob[e["offset"] : e["offset"] + e["nbytes"]]
        tensors[name] = np.frombuffer(raw, dtype=np_dt).reshape(e["shape"]).copy()
    return header.get("meta", {}), tensors
