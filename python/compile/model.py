"""L2: the JAX transformer, mirroring `rust/src/model/` op-for-op
(RMSNorm eps, tied head, learned positions, SwiGLU, per-segment causal
attention). Weights trained here load into the Rust forward and must agree
numerically — `rust/tests/pjrt_crosscheck.rs` enforces it.

Parameter pytree: dict with keys matching the Rust QTZ tensor names
(`embed`, `pos`, `blocks.{i}.attn.wq`, ... `final_norm`). Canonical flat
order is defined by `param_names` and mirrored by
`rust/src/runtime/artifacts.rs::param_order`.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

NORM_EPS = 1e-5
VOCAB = 259


@dataclasses.dataclass(frozen=True)
class Config:
    name: str
    dim: int
    n_layers: int
    n_heads: int
    ffn: int
    vocab: int = VOCAB
    seq_len: int = 128

    @property
    def head_dim(self):
        return self.dim // self.n_heads


SIZES = {
    "tiny-s": Config("tiny-s", 64, 4, 4, 128),
    "tiny-m": Config("tiny-m", 128, 6, 4, 256),
    "tiny-l": Config("tiny-l", 256, 8, 8, 512),
}


def param_names(cfg: Config):
    """Canonical flat parameter order (matches the Rust runtime)."""
    names = ["embed", "pos"]
    for i in range(cfg.n_layers):
        p = f"blocks.{i}"
        names += [
            f"{p}.attn_norm", f"{p}.attn.wq", f"{p}.attn.wk", f"{p}.attn.wv",
            f"{p}.attn.wo", f"{p}.mlp_norm", f"{p}.mlp.gate", f"{p}.mlp.up",
            f"{p}.mlp.down",
        ]
    names.append("final_norm")
    return names


def init_params(cfg: Config, key):
    """Init matching Rust `Model::random`: N(0, 0.02), residual projections
    down-scaled by sqrt(2·L)."""
    std = 0.02
    resid = std / (2.0 * cfg.n_layers) ** 0.5
    params = {}
    key, k1, k2 = jax.random.split(key, 3)
    params["embed"] = std * jax.random.normal(k1, (cfg.vocab, cfg.dim), jnp.float32)
    params["pos"] = std * jax.random.normal(k2, (cfg.seq_len, cfg.dim), jnp.float32)
    for i in range(cfg.n_layers):
        p = f"blocks.{i}"
        key, kq, kk, kv, ko, kg, ku, kd = jax.random.split(key, 8)
        params[f"{p}.attn_norm"] = jnp.ones((cfg.dim,), jnp.float32)
        params[f"{p}.attn.wq"] = std * jax.random.normal(kq, (cfg.dim, cfg.dim))
        params[f"{p}.attn.wk"] = std * jax.random.normal(kk, (cfg.dim, cfg.dim))
        params[f"{p}.attn.wv"] = std * jax.random.normal(kv, (cfg.dim, cfg.dim))
        params[f"{p}.attn.wo"] = resid * jax.random.normal(ko, (cfg.dim, cfg.dim))
        params[f"{p}.mlp_norm"] = jnp.ones((cfg.dim,), jnp.float32)
        params[f"{p}.mlp.gate"] = std * jax.random.normal(kg, (cfg.ffn, cfg.dim))
        params[f"{p}.mlp.up"] = std * jax.random.normal(ku, (cfg.ffn, cfg.dim))
        params[f"{p}.mlp.down"] = resid * jax.random.normal(kd, (cfg.dim, cfg.ffn))
    params["final_norm"] = jnp.ones((cfg.dim,), jnp.float32)
    return params


def rmsnorm(x, gain):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + NORM_EPS) * gain


def linear(x, w):
    """y = x · Wᵀ for weight [out, in] — matches the Rust convention."""
    return x @ w.T


def causal_attention(q, k, v, n_heads: int):
    """Per-segment causal MHA. q/k/v: [S, d]."""
    s, d = q.shape
    hd = d // n_heads
    qh = q.reshape(s, n_heads, hd).transpose(1, 0, 2)  # [h, s, hd]
    kh = k.reshape(s, n_heads, hd).transpose(1, 0, 2)
    vh = v.reshape(s, n_heads, hd).transpose(1, 0, 2)
    scores = jnp.einsum("hqd,hkd->hqk", qh, kh) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("hqk,hkd->hqd", probs, vh)
    return ctx.transpose(1, 0, 2).reshape(s, d)


def block(cfg: Config, params, i: int, x):
    """One transformer block on a single segment x[S, d]; returns
    (out, captures) with the same capture points as the Rust pipeline."""
    p = f"blocks.{i}"
    attn_in = rmsnorm(x, params[f"{p}.attn_norm"])
    q = linear(attn_in, params[f"{p}.attn.wq"])
    k = linear(attn_in, params[f"{p}.attn.wk"])
    v = linear(attn_in, params[f"{p}.attn.wv"])
    attn_ctx = causal_attention(q, k, v, cfg.n_heads)
    x1 = x + linear(attn_ctx, params[f"{p}.attn.wo"])
    mlp_in = rmsnorm(x1, params[f"{p}.mlp_norm"])
    g = linear(mlp_in, params[f"{p}.mlp.gate"])
    u = linear(mlp_in, params[f"{p}.mlp.up"])
    mlp_act = jax.nn.silu(g) * u
    out = x1 + linear(mlp_act, params[f"{p}.mlp.down"])
    return out, dict(attn_in=attn_in, attn_ctx=attn_ctx, mlp_in=mlp_in, mlp_act=mlp_act)


def forward_segment(cfg: Config, params, tokens):
    """tokens[S] int32 → logits[S, vocab]."""
    x = params["embed"][tokens] + params["pos"]
    for i in range(cfg.n_layers):
        x, _ = block(cfg, params, i, x)
    h = rmsnorm(x, params["final_norm"])
    return linear(h, params["embed"])


def forward_batch(cfg: Config, params, tokens):
    """tokens[B, S] → logits[B, S, vocab] (training entrypoint)."""
    return jax.vmap(lambda t: forward_segment(cfg, params, t))(tokens)


def next_token_loss(cfg: Config, params, tokens):
    """Mean next-token cross-entropy (nats) over a [B, S] batch."""
    logits = forward_batch(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


@functools.partial(jax.jit, static_argnames=("cfg",))
def perplexity(cfg: Config, params, tokens):
    return jnp.exp(next_token_loss(cfg, params, tokens))
