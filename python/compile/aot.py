"""AOT export: lower the L2 JAX model and L1 Pallas kernels to HLO *text*
artifacts for the Rust PJRT runtime.

HLO text — NOT `lowered.compile()` / proto `.serialize()` — is the
interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction
ids that the image's xla_extension 0.5.1 rejects; the text parser reassigns
ids (see /opt/xla-example/README.md and aot_recipe).

Artifacts per model size (parameter order = model.param_names, mirrored by
rust/src/runtime/artifacts.rs):
  <name>.fwd.hlo.txt     tokens[S] i32 + weights → (logits[S,V],)
  <name>.block.hlo.txt   x[S,d] + block weights → (out, attn_in, attn_ctx,
                          mlp_in, mlp_act)
  <name>.hess.hlo.txt    Pallas: x[1024,d] → (XᵀX[d,d],)
  <name>.qmm.hlo.txt     Pallas fused dequant×matmul [S,d]·[d,d codes]
  <name>.qmm_up.hlo.txt  … [S,d]·[ffn,d codes]
  <name>.qmm_down.hlo.txt… [S,ffn]·[d,ffn codes]

Usage: python -m compile.aot [--sizes ...] [--out ../artifacts]
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import hessian_accum, quant_matmul

QMM_GROUP = 32
HESS_TOKENS = 1024


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write(path: str, text: str):
    with open(path, "w") as f:
        f.write(text)
    print(f"[aot] wrote {path} ({len(text)} chars)")


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def export_fwd(cfg: model.Config, out: str):
    names = model.param_names(cfg)
    shapes = param_shapes(cfg)

    def fn(tokens, *flat):
        params = dict(zip(names, flat))
        return (model.forward_segment(cfg, params, tokens),)

    specs = [jax.ShapeDtypeStruct((cfg.seq_len,), jnp.int32)]
    specs += [f32(shapes[n]) for n in names]
    write(os.path.join(out, f"{cfg.name}.fwd.hlo.txt"),
          to_hlo_text(jax.jit(fn).lower(*specs)))


def export_block(cfg: model.Config, out: str):
    def fn(x, attn_norm, wq, wk, wv, wo, mlp_norm, gate, up, down):
        params = {
            "blocks.0.attn_norm": attn_norm,
            "blocks.0.attn.wq": wq,
            "blocks.0.attn.wk": wk,
            "blocks.0.attn.wv": wv,
            "blocks.0.attn.wo": wo,
            "blocks.0.mlp_norm": mlp_norm,
            "blocks.0.mlp.gate": gate,
            "blocks.0.mlp.up": up,
            "blocks.0.mlp.down": down,
        }
        o, cap = model.block(cfg, params, 0, x)
        return (o, cap["attn_in"], cap["attn_ctx"], cap["mlp_in"], cap["mlp_act"])

    d, ffn, s = cfg.dim, cfg.ffn, cfg.seq_len
    specs = [
        f32((s, d)), f32((d,)), f32((d, d)), f32((d, d)), f32((d, d)),
        f32((d, d)), f32((d,)), f32((ffn, d)), f32((ffn, d)), f32((d, ffn)),
    ]
    write(os.path.join(out, f"{cfg.name}.block.hlo.txt"),
          to_hlo_text(jax.jit(fn).lower(*specs)))


def export_hessian(cfg: model.Config, out: str):
    def fn(x):
        return (hessian_accum(x),)

    write(os.path.join(out, f"{cfg.name}.hess.hlo.txt"),
          to_hlo_text(jax.jit(fn).lower(f32((HESS_TOKENS, cfg.dim)))))


def export_qmm(cfg: model.Config, out: str):
    def make(n, k, suffix):
        g = k // QMM_GROUP

        def fn(x, codes, scales, zeros):
            return (quant_matmul(x, codes, scales, zeros, group=QMM_GROUP),)

        specs = [f32((cfg.seq_len, k)), f32((n, k)), f32((n, g)), f32((n, g))]
        write(os.path.join(out, f"{cfg.name}.qmm{suffix}.hlo.txt"),
              to_hlo_text(jax.jit(fn).lower(*specs)))

    make(cfg.dim, cfg.dim, "")           # attention projections
    make(cfg.ffn, cfg.dim, "_up")        # gate/up
    make(cfg.dim, cfg.ffn, "_down")      # down


def param_shapes(cfg: model.Config):
    shapes = {"embed": (cfg.vocab, cfg.dim), "pos": (cfg.seq_len, cfg.dim),
              "final_norm": (cfg.dim,)}
    for i in range(cfg.n_layers):
        p = f"blocks.{i}"
        shapes[f"{p}.attn_norm"] = (cfg.dim,)
        shapes[f"{p}.mlp_norm"] = (cfg.dim,)
        for w in ("wq", "wk", "wv", "wo"):
            shapes[f"{p}.attn.{w}"] = (cfg.dim, cfg.dim)
        shapes[f"{p}.mlp.gate"] = (cfg.ffn, cfg.dim)
        shapes[f"{p}.mlp.up"] = (cfg.ffn, cfg.dim)
        shapes[f"{p}.mlp.down"] = (cfg.dim, cfg.ffn)
    return shapes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="tiny-s,tiny-m,tiny-l")
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    for name in args.sizes.split(","):
        cfg = model.SIZES[name]
        export_fwd(cfg, args.out)
        export_block(cfg, args.out)
        export_hessian(cfg, args.out)
        export_qmm(cfg, args.out)


if __name__ == "__main__":
    main()
