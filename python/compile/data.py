"""Build-time data access: reads the corpora written by `repro gen-data`
(the Rust generator is canonical — one implementation, no drift) and
produces byte-token training batches."""

import os

import numpy as np

FLAVORS = ("wiki", "ptb", "c4")


def corpus_path(flavor: str, root: str = "../artifacts/data"):
    return os.path.join(root, f"{flavor}.txt")


def load_tokens(flavor: str, root: str = "../artifacts/data") -> np.ndarray:
    path = corpus_path(flavor, root)
    if not os.path.exists(path):
        raise FileNotFoundError(
            f"{path} missing — run `cargo run --release -- gen-data` first"
        )
    with open(path, "rb") as f:
        data = f.read()
    return np.frombuffer(data, dtype=np.uint8).astype(np.int32)


def batches(tokens: np.ndarray, batch: int, seq: int, steps: int, seed: int = 0):
    """Yield `steps` random [batch, seq] windows."""
    rng = np.random.default_rng(seed)
    max_start = len(tokens) - seq - 1
    for _ in range(steps):
        starts = rng.integers(0, max_start, size=batch)
        yield np.stack([tokens[s : s + seq] for s in starts])
