"""Layer-1 Pallas kernels (interpret mode on CPU; see DESIGN.md
§Hardware-Adaptation for the TPU BlockSpec reasoning)."""

from .hessian import hessian_accum
from .quant_matmul import quant_matmul

__all__ = ["quant_matmul", "hessian_accum"]
