"""Fused dequantize × matmul Pallas kernel — the quantized serving
hot-spot (L1).

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper's baselines run
GPU dequant kernels; on TPU the natural shape is an MXU-fed tile loop.
BlockSpec tiles are (BM × K) activations and (BN × K) codes: the codes are
dequantized in-register (VPU elementwise) and fed to `jnp.dot` (MXU). With
BM = BN = 128 and K ≤ 1024, VMEM per instance is
  128·K·4 (x) + 128·K·4 (codes) + small scales ≈ ≤ 1 MiB « 16 MiB VMEM,
leaving room for double buffering; the dot is MXU-shaped (128×K·128).

CPU execution uses interpret=True (Mosaic custom-calls cannot run on the
CPU PJRT plugin); numerics are identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qmm_kernel(x_ref, codes_ref, scales_ref, zeros_ref, o_ref, *, group: int):
    x = x_ref[...]  # [bm, k]
    codes = codes_ref[...]  # [bn, k]
    scales = scales_ref[...]  # [bn, k // group]
    zeros = zeros_ref[...]
    bn, k = codes.shape
    g = k // group
    w = (codes.reshape(bn, g, group) - zeros[:, :, None]) * scales[:, :, None]
    w = w.reshape(bn, k)
    o_ref[...] = jnp.dot(x, w.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("group", "block_m", "block_n"))
def quant_matmul(x, codes, scales, zeros, *, group: int = 32,
                 block_m: int = 128, block_n: int = 128):
    """y[m,n] = x[m,k] @ dequant(codes[n,k], scales[n,k//group], zeros).T

    codes are float32 holding b-bit integer values (storage packing is the
    coordinator's concern; the kernel consumes the unpacked representation).
    """
    m, k = x.shape
    n, k2 = codes.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert k % group == 0, f"k={k} not a multiple of group={group}"
    bm = min(block_m, m)
    bn = min(block_n, n)
    assert m % bm == 0 and n % bn == 0, "grid must tile evenly"
    g = k // group
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_qmm_kernel, group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, g), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, g), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, codes, scales, zeros)
