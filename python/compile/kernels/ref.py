"""Pure-jnp oracles for the Pallas kernels. The pytest suite asserts the
kernels match these to float tolerance; these are also the semantics the
Rust runtime's pure-Rust fallback implements."""

import jax.numpy as jnp


def dequantize_ref(codes, scales, zeros, group: int):
    """Dequantize group-wise codes: w[n,k] = (codes - zeros_g) * scales_g.

    codes: [n, k] float32 holding integer values in [0, 2^b)
    scales/zeros: [n, k // group]
    """
    n, k = codes.shape
    g = k // group
    c = codes.reshape(n, g, group)
    w = (c - zeros[:, :, None]) * scales[:, :, None]
    return w.reshape(n, k)


def quant_matmul_ref(x, codes, scales, zeros, group: int):
    """y[m,n] = x[m,k] @ dequantize(codes,scales,zeros).T"""
    w = dequantize_ref(codes, scales, zeros, group)
    return x @ w.T


def hessian_ref(x):
    """H[d,d] = X^T X for tokens-major activations x[m,d]."""
    return x.T @ x
