"""Hessian accumulation Pallas kernel (L1): H = XᵀX over calibration
tokens — the dominant dense cost of layer-wise PTQ calibration.

TPU mapping: the token axis is the reduction; the grid walks token tiles
of BM = 128 rows while the (d × d) accumulator tile stays resident in
VMEM (d ≤ 512 ⇒ ≤ 1 MiB f32). Each step computes an MXU-shaped
(d × BM)·(BM × d) product and accumulates in f32 — the standard
"stationary output" schedule for tall-skinny XᵀX on a systolic array.

CPU execution uses interpret=True.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hess_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # [bm, d]
    o_ref[...] += jnp.dot(x.T, x, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_m",))
def hessian_accum(x, *, block_m: int = 128):
    """H[d,d] = x[m,d]ᵀ · x[m,d], token-tiled accumulation."""
    m, d = x.shape
    bm = min(block_m, m)
    assert m % bm == 0, f"m={m} not a multiple of block_m={bm}"
    return pl.pallas_call(
        _hess_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((d, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=True,
    )(x)
