"""Build-time trainer: fits the three tiny transformers on the synthetic
corpus mix so the quantization experiments operate on *trained* weights
(anisotropic Hessians, real perplexity structure). Runs once under
`make artifacts`; Adam is implemented inline (no optax in this image).

Usage: python -m compile.train [--sizes tiny-s,tiny-m,tiny-l] [--steps N]
"""

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data, model, qtz

# Training mixes all three flavors so every eval corpus is in-distribution
# (the paper's models likewise saw broad pretraining data; Table 4's shift
# is about the *calibration* set, not the training set).
DEFAULT_STEPS = {"tiny-s": 700, "tiny-m": 500, "tiny-l": 350}
BATCH = 8
LR = 3e-3
WARMUP = 30


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


@jax.jit
def adam_step(params, state, grads, lr):
    t = state["t"] + 1
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    params = jax.tree_util.tree_map(
        lambda p, m, v: p - lr * (m * mhat_scale) / (jnp.sqrt(v * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return params, {"m": m, "v": v, "t": t}


def lr_schedule(step, total):
    if step < WARMUP:
        return LR * (step + 1) / WARMUP
    frac = (step - WARMUP) / max(1, total - WARMUP)
    return LR * 0.5 * (1.0 + np.cos(np.pi * frac))


def train_size(name: str, steps: int, out_dir: str, data_root: str, seed: int = 0):
    cfg = model.SIZES[name]
    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)

    tokens = np.concatenate([data.load_tokens(f, data_root) for f in data.FLAVORS])
    loss_fn = lambda p, batch: model.next_token_loss(cfg, p, batch)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    t0 = time.time()
    losses = []
    for step, batch in enumerate(data.batches(tokens, BATCH, cfg.seq_len, steps, seed)):
        batch = jnp.asarray(batch)
        loss, grads = grad_fn(params, batch)
        params, opt = adam_step(params, opt, grads, lr_schedule(step, steps))
        losses.append(float(loss))
        if step % 50 == 0 or step == steps - 1:
            print(
                f"[train {name}] step {step:4d}/{steps} loss {loss:.4f} "
                f"({time.time() - t0:.0f}s)",
                flush=True,
            )

    tensors = {k: np.asarray(v, dtype=np.float32) for k, v in params.items()}
    meta = {
        "name": cfg.name,
        "dim": cfg.dim,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "ffn": cfg.ffn,
        "vocab": cfg.vocab,
        "seq_len": cfg.seq_len,
        "train_steps": steps,
        "final_loss": losses[-1],
    }
    path = os.path.join(out_dir, f"{name}.qtz")
    qtz.save(path, tensors, meta)
    print(f"[train {name}] saved {path} (final loss {losses[-1]:.4f})")
    # Append to the training log for EXPERIMENTS.md.
    with open(os.path.join(out_dir, "train_log.txt"), "a") as f:
        f.write(
            f"{name}: steps={steps} batch={BATCH} lr={LR} "
            f"loss_first={losses[0]:.4f} loss_last={losses[-1]:.4f} "
            f"wall={time.time() - t0:.0f}s\n"
        )
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="tiny-s,tiny-m,tiny-l")
    ap.add_argument("--steps", type=int, default=0, help="override per-size defaults")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--data", default="../artifacts/data")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    for name in args.sizes.split(","):
        if name not in model.SIZES:
            print(f"unknown size {name}", file=sys.stderr)
            sys.exit(1)
        steps = args.steps or DEFAULT_STEPS[name]
        train_size(name, steps, args.out, args.data)


if __name__ == "__main__":
    main()
