//! **What this example demonstrates:** the end-to-end happy path — every
//! layer of the stack composing in one run. It loads the trained tiny-s
//! model (JAX-trained at build time, QTZ format), quantizes it with GPTQ
//! at INT2 — once plain, once QEP-enhanced — on the persistent worker
//! pool, evaluates perplexity on the WikiText-analog corpus, reports
//! zero-shot accuracy, and prints the QEP improvement. With the `pjrt`
//! cargo feature it additionally runs the same quantized model through
//! the PJRT-compiled JAX artifact as a cross-check; the default build
//! notes that the runtime is off and stays pure Rust.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use qep::coordinator::{Pipeline, PipelineConfig};
use qep::eval::{perplexity, TaskFamily, TaskSet};
use qep::model::Size;
use qep::quant::{Method, QuantConfig};
use qep::runtime::ArtifactRegistry;
#[cfg(feature = "pjrt")]
use qep::runtime::{artifacts::PjrtModel, PjrtRuntime};
use qep::text::Flavor;

fn main() -> anyhow::Result<()> {
    let reg = ArtifactRegistry::default_root();
    let model = reg.load_model(Size::TinyS.name())?;
    println!(
        "loaded {} ({:.2}M params, stand-in for {})",
        model.cfg.name,
        model.cfg.n_params() as f64 / 1e6,
        Size::TinyS.paper_analog()
    );

    let calib = reg.load_corpus(Flavor::C4)?;
    let calib_tokens = &calib.tokens[..24 * model.cfg.seq_len];
    let eval = reg.load_corpus(Flavor::Wiki)?;
    let eval_tokens = &eval.tokens[eval.tokens.len() - 16 * 1024..];

    let fp_ppl = perplexity(&model, eval_tokens);
    println!("full-precision wiki ppl: {fp_ppl:.3}");

    let mut quantized = Vec::new();
    for (label, qep) in [("GPTQ INT2 (base)", None), ("GPTQ INT2 +QEP", Some(0.5))] {
        let t = qep::util::Stopwatch::start();
        let out = Pipeline::new(PipelineConfig {
            quant: QuantConfig::int(2),
            method: Method::Gptq,
            qep_alpha: qep,
            ..Default::default()
        })
        .run(&model, calib_tokens)?;
        let ppl = perplexity(&out.model, eval_tokens);
        println!(
            "{label:18} ppl {ppl:8.3}   (quantized in {}, correction {})",
            qep::util::fmt_duration(t.seconds()),
            qep::util::fmt_duration(out.report.correction_s()),
        );
        quantized.push((label, out.model, ppl));
    }

    // Zero-shot snapshot on the QEP model.
    let (_, qep_model, _) = &quantized[1];
    for fam in TaskFamily::all() {
        let ts = TaskSet::generate(fam, &eval, 40, 1234);
        println!(
            "zero-shot {:10} ({}): {:.3}",
            fam.name(),
            fam.paper_analog(),
            ts.accuracy(qep_model)
        );
    }

    // Same quantized model through the PJRT serving path (L1+L2
    // artifacts) when the `pjrt` feature is compiled in.
    #[cfg(feature = "pjrt")]
    match PjrtRuntime::cpu() {
        Ok(rt) => {
            let pjrt = PjrtModel::bind(&rt, &reg, qep_model)?;
            let ppl = pjrt.perplexity(&eval_tokens[..8 * model.cfg.seq_len])?;
            println!("PJRT ({}) wiki ppl on 8 segments: {ppl:.3}", rt.platform());
        }
        Err(e) => println!("PJRT unavailable ({e}); pure-Rust path only"),
    }
    #[cfg(not(feature = "pjrt"))]
    println!("PJRT disabled at build time (enable with --features pjrt); pure-Rust path only");

    let base_ppl = quantized[0].2;
    let qep_ppl = quantized[1].2;
    println!(
        "\nQEP improvement at INT2: {:.3} -> {:.3} ({:+.1}%)",
        base_ppl,
        qep_ppl,
        (qep_ppl / base_ppl - 1.0) * 100.0
    );
    Ok(())
}
