//! **What this example demonstrates:** the paper's core diagnosis (Fig. 2)
//! — quantization error *propagates*. It quantizes the first half of
//! tiny-m's blocks with RTN INT3 (`PipelineConfig::max_blocks`), measures
//! the per-block output error Δ_m (Eq. 2, `eval::delta_per_block`), and
//! plots an ASCII log-scale chart of the error accumulating through the
//! quantized prefix and *continuing to grow* through the untouched
//! full-precision suffix — then repeats with QEP enabled to show the
//! compensation damping it. Falls back to random weights when artifacts
//! are missing.
//!
//! Run: `cargo run --release --example error_propagation [-- --bits 2]`

use qep::coordinator::{Pipeline, PipelineConfig};
use qep::eval::delta_per_block;
use qep::model::Size;
use qep::quant::{Method, QuantConfig};
use qep::runtime::ArtifactRegistry;
use qep::text::{Corpus, Flavor};
use qep::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let bits = args.get_usize("bits", 3) as u32;
    let reg = ArtifactRegistry::default_root();
    let model = reg
        .load_model(Size::TinyM.name())
        .unwrap_or_else(|_| {
            eprintln!("artifacts missing; using random weights (structure only)");
            qep::model::Model::random(&Size::TinyM.config(), 0xBEEF)
        });

    let calib = reg
        .load_corpus(Flavor::C4)
        .unwrap_or_else(|_| Corpus::generate(Flavor::C4, 128 * 1024, 0));
    let probe = reg
        .load_corpus(Flavor::Wiki)
        .unwrap_or_else(|_| Corpus::generate(Flavor::Wiki, 64 * 1024, 1));
    let calib_tokens = &calib.tokens[..16 * model.cfg.seq_len];
    let probe_tokens = &probe.tokens[..8 * model.cfg.seq_len];

    let n = model.cfg.n_layers / 2;
    println!(
        "quantizing first {n} of {} blocks with RTN INT{bits} (Fig. 2 setup, paper: 10 of 32)\n",
        model.cfg.n_layers
    );

    let mut curves = Vec::new();
    for (label, qep) in [("BASE", None), ("+QEP", Some(0.5))] {
        let out = Pipeline::new(PipelineConfig {
            quant: QuantConfig::int(bits),
            method: Method::Rtn,
            qep_alpha: qep,
            max_blocks: Some(n),
            ..Default::default()
        })
        .run(&model, calib_tokens)?;
        curves.push((label, delta_per_block(&model, &out.model, probe_tokens)));
    }

    // ASCII log-scale bar chart.
    let max = curves
        .iter()
        .flat_map(|(_, c)| c.iter())
        .cloned()
        .fold(f64::MIN_POSITIVE, f64::max);
    let min = curves
        .iter()
        .flat_map(|(_, c)| c.iter())
        .cloned()
        .filter(|&v| v > 0.0)
        .fold(f64::MAX, f64::min);
    println!("Δ_m (squared Frobenius, Eq. 2); log-scaled bars; '|' marks end of quantized prefix\n");
    for (label, curve) in &curves {
        println!("{label}:");
        for (m, &d) in curve.iter().enumerate() {
            let frac = ((d.max(min).ln() - min.ln()) / (max.ln() - min.ln() + 1e-12)).max(0.02);
            let bar = "#".repeat((frac * 48.0) as usize);
            let marker = if m + 1 == n { " |<- last quantized" } else { "" };
            println!("  block {:2}  {d:10.4e}  {bar}{marker}", m + 1);
        }
        println!();
    }
    let (_, base) = &curves[0];
    let (_, qep) = &curves[1];
    println!(
        "final-block error ratio BASE/QEP = {:.2}x",
        base.last().unwrap() / qep.last().unwrap().max(1e-30)
    );
    Ok(())
}
