//! **What this example demonstrates:** the paper's Table 4 as a runnable
//! experiment — how sensitive GPTQ and QEP+RTN are to the *calibration*
//! distribution. It quantizes the same model against C4-, PTB-, and
//! WikiText-analog calibration sets (synthetic corpora with real
//! distribution shift, see `text::gen`) and prints each method's
//! perplexity delta vs a calibration-free RTN reference. The paper's
//! finding to look for: GPTQ helps on C4/WikiText calibration but
//! *hurts* under PTB shift, while QEP+RTN improves under every
//! calibration set. Falls back to random weights (structure-only run)
//! when `make artifacts` hasn't been executed.
//!
//! Run: `cargo run --release --example calibration_robustness`

use qep::coordinator::{Pipeline, PipelineConfig};
use qep::eval::perplexity;
use qep::model::Size;
use qep::quant::{Method, QuantConfig};
use qep::runtime::ArtifactRegistry;
use qep::text::{Corpus, Flavor};

fn main() -> anyhow::Result<()> {
    let reg = ArtifactRegistry::default_root();
    let model = reg.load_model(Size::TinyS.name()).unwrap_or_else(|_| {
        eprintln!("artifacts missing; using random weights (structure only)");
        qep::model::Model::random(&Size::TinyS.config(), 0xBEEF)
    });
    let load = |f: Flavor| {
        reg.load_corpus(f)
            .unwrap_or_else(|_| Corpus::generate(f, 128 * 1024, 0))
    };
    let eval_corpus = load(Flavor::Wiki);
    let eval = &eval_corpus.tokens[eval_corpus.tokens.len() - 16 * 1024..];

    // Reference: calibration-free RTN.
    let rtn_out = Pipeline::new(PipelineConfig {
        quant: QuantConfig::int(3),
        method: Method::Rtn,
        ..Default::default()
    })
    .run(&model, &load(Flavor::C4).tokens[..16 * model.cfg.seq_len])?;
    let rtn_ppl = perplexity(&rtn_out.model, eval);
    println!("RTN INT3 reference (calibration-free): wiki ppl {rtn_ppl:.3}\n");
    println!("{:12} {:>12} {:>12} {:>12}", "method", "calib=c4", "calib=ptb", "calib=wiki");

    for (label, method, qep) in [("GPTQ", Method::Gptq, None), ("QEP+RTN", Method::Rtn, Some(0.5))] {
        print!("{label:12}");
        for flavor in [Flavor::C4, Flavor::Ptb, Flavor::Wiki] {
            let calib_corpus = load(flavor);
            let calib = &calib_corpus.tokens[..16 * model.cfg.seq_len];
            let out = Pipeline::new(PipelineConfig {
                quant: QuantConfig::int(3),
                method,
                qep_alpha: qep,
                ..Default::default()
            })
            .run(&model, calib)?;
            let delta = perplexity(&out.model, eval) - rtn_ppl;
            print!(" {delta:>+11.3}");
        }
        println!();
    }
    println!("\n(negative = better than RTN; the paper's Table 4 shows GPTQ going positive under PTB shift while QEP+RTN stays negative everywhere)");
    Ok(())
}
