//! **What this example demonstrates:** the *serving* story — batched
//! greedy generation from a QEP-quantized tiny-s model, reported like a
//! serving-paper harness (per-request latency, aggregate throughput).
//! Block-0's attention projections are wrapped as quantized
//! codes+grids layers; with the `pjrt` cargo feature (and `make
//! artifacts`) every step additionally runs them through the **Pallas
//! fused dequant×matmul artifact on PJRT** and cross-checks it against
//! the pure-Rust dequant·matmul — Python nowhere in sight. The default
//! (feature-less) build serves through the pure-Rust path alone, so the
//! example builds and runs everywhere.
//!
//! The generation loop itself runs on the persistent worker pool
//! (GEMMs dispatch through `util::pool`), so this is also the latency
//! profile of the parallel engine end to end.
//!
//! Run: `cargo run --release --example serve_generate`
//! (PJRT path: `make artifacts && cargo run --release --features pjrt
//! --example serve_generate`.)

use anyhow::Result;
use qep::coordinator::{Pipeline, PipelineConfig};
use qep::linalg::Mat;
use qep::model::{Forward, Size};
use qep::quant::{Method, QuantConfig, QuantizedTensor};
use qep::runtime::ArtifactRegistry;
#[cfg(feature = "pjrt")]
use qep::runtime::executor::{literal_to_mat, mat_to_literal};
#[cfg(feature = "pjrt")]
use qep::runtime::{HloExecutable, PjrtRuntime};
use qep::text::{ByteTokenizer, Flavor};
use qep::util::{stats, Stopwatch};

/// One attention projection served from quantized codes + per-group
/// grids (the `.qtz`/Pallas storage layout).
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
struct QmmLayer {
    codes: Mat,
    scales: Mat,
    zeros: Mat,
    /// Dequantized reference weights (what the codes decode to) — the
    /// pure-Rust serving path and the PJRT cross-check target.
    dequant: Mat,
}

impl QmmLayer {
    fn new(w: &Mat, cfg: &QuantConfig) -> QmmLayer {
        let qt = QuantizedTensor::from_mat(w, cfg);
        let ng = qt.n_groups();
        QmmLayer {
            codes: Mat::from_vec(qt.rows, qt.cols, qt.codes.iter().map(|&c| c as f32).collect()),
            scales: Mat::from_vec(qt.rows, ng, qt.scales.clone()),
            zeros: Mat::from_vec(qt.rows, ng, qt.zeros.clone()),
            dequant: qt.dequantize(),
        }
    }

    /// Serve through the compiled Pallas fused dequant×matmul artifact.
    #[cfg(feature = "pjrt")]
    fn run(&self, exe: &HloExecutable, x: &Mat) -> Result<Mat> {
        let out = exe.run(&[
            mat_to_literal(x)?,
            mat_to_literal(&self.codes)?,
            mat_to_literal(&self.scales)?,
            mat_to_literal(&self.zeros)?,
        ])?;
        literal_to_mat(&out[0])
    }
}

fn main() -> Result<()> {
    let reg = ArtifactRegistry::default_root();
    let model = reg.load_model(Size::TinyS.name())?;
    let corpus = reg.load_corpus(Flavor::Wiki)?;

    // Quantize with QEP+GPTQ INT4g32 (the qmm artifact's group contract).
    let calib = &corpus.tokens[..16 * model.cfg.seq_len];
    let qcfg = QuantConfig::int_group(4, 32);
    let out = Pipeline::new(PipelineConfig {
        quant: qcfg,
        method: Method::Gptq,
        qep_alpha: Some(0.5),
        ..Default::default()
    })
    .run(&model, calib)?;
    let qmodel = out.model;

    // With the `pjrt` feature + artifacts, bind the Pallas qmm executable
    // for the per-step cross-check; the default build serves pure-Rust.
    #[cfg(feature = "pjrt")]
    let (_rt, qmm) = {
        let rt = PjrtRuntime::cpu()?;
        let exe = rt.load(reg.qmm_hlo(&model.cfg.name))?;
        println!("PJRT platform: {}; qmm artifact: {}", rt.platform(), exe.name);
        (rt, exe)
    };
    #[cfg(not(feature = "pjrt"))]
    println!("PJRT disabled at build time (enable with --features pjrt); pure-Rust serving only");

    // Wrap block-0's q/k/v/o projections as quantized served layers.
    let b0 = &qmodel.blocks[0];
    let layers = [
        ("wq", QmmLayer::new(&b0.wq, &qcfg)),
        ("wk", QmmLayer::new(&b0.wk, &qcfg)),
        ("wv", QmmLayer::new(&b0.wv, &qcfg)),
        ("wo", QmmLayer::new(&b0.wo, &qcfg)),
    ];

    // Batched "requests": prompts drawn from the corpus; generation is
    // greedy over the full quantized model (pure-Rust forward) while the
    // served path handles block-0 attention projections every step.
    let tok = ByteTokenizer;
    let prompts: Vec<String> = (0..8)
        .map(|i| corpus.text[i * 500..i * 500 + 64].to_string())
        .collect();
    let f = Forward::new(&qmodel.cfg);
    let gen_len = 32;
    let mut latencies = Vec::new();
    let total = Stopwatch::start();
    let mut generated_tokens = 0usize;

    for (ri, prompt) in prompts.iter().enumerate() {
        let t = Stopwatch::start();
        let mut ids = tok.encode(prompt);
        for _ in 0..gen_len {
            // Build one full segment (pad with PAD after current ids).
            let real = ids.len().min(qmodel.cfg.seq_len);
            let mut seg = ids[ids.len() - real..].to_vec();
            seg.resize(qmodel.cfg.seq_len, qep::text::PAD);

            // Serve block-0's q-projection from the quantized layer (and,
            // with `pjrt`, cross-check it against the Pallas artifact).
            let x = f.embed(&qmodel, &seg);
            let attn_in = qep::model::ops::rmsnorm(&x, &qmodel.blocks[0].attn_norm);
            let q_rust = qep::model::ops::linear(&attn_in, &layers[0].1.dequant);
            #[cfg(feature = "pjrt")]
            {
                let q_pjrt = layers[0].1.run(&qmm, &attn_in)?;
                let rel = q_pjrt.sub(&q_rust).frob() / q_rust.frob().max(1e-12);
                assert!(rel < 1e-4, "Pallas/Rust divergence: {rel}");
            }
            qep::util::bench::black_box(&q_rust);

            // Greedy next token from the full forward.
            let logits = f.forward(&qmodel, &seg);
            let row = logits.row(real - 1);
            let next = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as u32)
                .unwrap();
            if next == qep::text::EOS {
                break;
            }
            ids.push(next.min(255));
            generated_tokens += 1;
        }
        let ms = t.millis();
        latencies.push(ms);
        let text = tok.decode(&ids[prompt.len()..]);
        println!(
            "req {ri}: {:5.0}ms  …{}",
            ms,
            text.chars().take(48).collect::<String>().replace('\n', "¶")
        );
    }

    let wall = total.seconds();
    println!("\n— serving report ————————————————————————");
    println!("requests:        {}", prompts.len());
    println!("generated:       {generated_tokens} tokens");
    println!("throughput:      {:.1} tok/s", generated_tokens as f64 / wall);
    println!(
        "latency:         mean {:.0}ms  p50 {:.0}ms  p90 {:.0}ms",
        stats::mean(&latencies),
        stats::percentile(&latencies, 50.0),
        stats::percentile(&latencies, 90.0)
    );
    #[cfg(feature = "pjrt")]
    println!(
        "(every step cross-checked Pallas qmm vs pure-Rust dequant·matmul, {} layers bound)",
        layers.len()
    );
    #[cfg(not(feature = "pjrt"))]
    println!(
        "(served via pure-Rust dequant·matmul, {} layers bound; `--features pjrt` adds the Pallas cross-check)",
        layers.len()
    );
    Ok(())
}
