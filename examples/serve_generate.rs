//! **What this example demonstrates:** the *serving* story end to end —
//! a QEP-quantized tiny-s model served by the batched KV-cache engine
//! (`qep::serve`): continuous-batching scheduler over per-session caches,
//! every block linear running the fused dequantize×GEMM micro-kernels
//! straight off the packed codes, and the bit-identity cross-check that
//! makes the speedup trustworthy — the same prompts are re-served through
//! the engine's *dense twin* (identical grid weights, materialized to
//! f32) and the generated tokens must match exactly. Quantization here
//! buys memory traffic, never bits.
//!
//! Greedy sampling uses the shared NaN-safe argmax (`qep::serve::argmax`)
//! and special tokens end a request explicitly ([`FinishReason`]) instead
//! of being clamped into byte range — both former footguns of this
//! example.
//!
//! Run: `cargo run --release --example serve_generate`
//! (the Pallas/PJRT cross-check of the same fused-qmm math lives in
//! `tests/pjrt_crosscheck.rs` behind `--features pjrt`).

use anyhow::Result;
use qep::coordinator::{Pipeline, PipelineConfig};
use qep::model::Size;
use qep::quant::{Method, QuantConfig};
use qep::runtime::ArtifactRegistry;
use qep::serve::{Completion, FinishReason, Scheduler, ServeConfig, ServeModel};
use qep::text::{ByteTokenizer, Flavor};
use qep::util::pool::Pool;
use qep::util::Stopwatch;

fn serve(model: ServeModel, prompts: &[Vec<u32>]) -> Result<(Vec<Completion>, f64)> {
    let mut sched = Scheduler::new(
        model,
        ServeConfig { max_batch: 4, max_new_tokens: 48 },
        Pool::new(0), // process-global default (all cores)
    );
    for p in prompts {
        sched.submit(p)?;
    }
    let t = Stopwatch::start();
    let done = sched.run();
    Ok((done, t.seconds()))
}

fn main() -> Result<()> {
    let reg = ArtifactRegistry::default_root();
    let model = reg.load_model(Size::TinyS.name())?;
    let corpus = reg.load_corpus(Flavor::Wiki)?;

    // Quantize with QEP+GPTQ INT4g32, then pack the result for serving.
    let calib = &corpus.tokens[..16 * model.cfg.seq_len];
    let qcfg = QuantConfig::int_group(4, 32);
    let out = Pipeline::new(PipelineConfig {
        quant: qcfg,
        method: Method::Gptq,
        qep_alpha: Some(0.5),
        ..Default::default()
    })
    .run(&model, calib)?;
    let packed = ServeModel::quantized(&out.model, &qcfg);
    let dense = packed.dequantized();

    // Batched "requests": prompts drawn from the corpus.
    let tok = ByteTokenizer;
    let prompts: Vec<Vec<u32>> = (0..8)
        .map(|i| tok.encode(&corpus.text[i * 500..i * 500 + 64]))
        .collect();

    let (quant_done, quant_s) = serve(packed, &prompts)?;
    let (dense_done, dense_s) = serve(dense, &prompts)?;

    // The cross-check: packed serving must generate EXACTLY the dense
    // twin's tokens (the fused kernel is bitwise dequantize-then-matmul).
    for (q, d) in quant_done.iter().zip(dense_done.iter()) {
        assert_eq!(q.tokens, d.tokens, "req {}: packed/dense divergence", q.id);
        assert_eq!(q.finish, d.finish, "req {}", q.id);
    }

    let generated: usize = quant_done.iter().map(|c| c.tokens.len()).sum();
    for c in &quant_done {
        let text = tok.decode(&c.tokens);
        let fin = match c.finish {
            FinishReason::Eos => "eos".to_string(),
            FinishReason::Special(id) => format!("special({id})"),
            FinishReason::Length => "length".to_string(),
        };
        println!(
            "req {}: {:2} tokens [{fin}]  …{}",
            c.id,
            c.tokens.len(),
            text.chars().take(48).collect::<String>().replace('\n', "¶")
        );
    }

    println!("\n— serving report ————————————————————————");
    println!("requests:        {}", prompts.len());
    println!("generated:       {generated} tokens (packed ≡ dense, cross-checked)");
    println!(
        "quantized INT4g32: {:6.1} tok/s  ({quant_s:.2}s wall)",
        generated as f64 / quant_s
    );
    println!(
        "dense f32 twin:    {:6.1} tok/s  ({dense_s:.2}s wall)",
        generated as f64 / dense_s
    );
    println!("speedup:           {:.2}×", dense_s / quant_s);
    qep::util::pool::shutdown();
    Ok(())
}
