#!/usr/bin/env python3
"""Validate one BENCH_*.json trajectory point.

Shared gate for the CI bench-smoke matrix. Every `harness = false` bench
binary self-validates its own JSON on write and exits nonzero on a schema
break; CI re-validates the file here, independently, so a silently-skipped
write still fails the job. The per-bench schema checks live in one place —
adding a bench means adding one check function and one matrix row.

Usage: validate_bench.py path/to/BENCH_<name>.json
"""

import json
import sys


def common(doc, bench, extra_keys=()):
    for key in ("schema_version", "bench", "smoke", "results") + tuple(extra_keys):
        assert key in doc, f"missing key: {key}"
    assert doc["schema_version"] == 1, doc["schema_version"]
    assert doc["bench"] == bench, (doc["bench"], bench)
    return doc["results"]


def check_serve(doc):
    rs = common(doc, "serve_throughput", ("model", "speedup_single_stream"))
    assert len(rs) >= 4, rs
    assert any(r["quantized"] for r in rs)
    assert any(not r["quantized"] for r in rs)
    for r in rs:
        assert r["tok_s"] > 0, r
    sp = doc["speedup_single_stream"]
    assert sp > 0, sp
    # The >=1.5x single-stream regression gate applies only to real
    # (non-smoke) trajectory points -- smoke numbers are meaningless.
    if not doc["smoke"]:
        assert sp >= 1.5, f"single-stream speedup regressed: {sp:.2f}x < 1.5x"
    return f"{len(rs)} points, speedup {sp:.2f}x"


def check_linalg(doc):
    rs = common(doc, "linalg_hotpath")
    assert len(rs) >= 4, rs
    engines = {r["engine"] for r in rs}
    assert {"jacobi", "randomized"} <= engines, engines
    for r in rs:
        assert r["mean_s"] > 0, r
        assert r["threads"] >= 1, r
    return f"{len(rs)} points, engines {sorted(engines)}"


def check_quantizers(doc):
    rs = common(doc, "quantizers")
    assert len(rs) >= 3, rs
    components = {r["component"] for r in rs}
    assert {"qep-correction", "hessian-build"} <= components, components
    for r in rs:
        assert r["mean_s"] > 0, r
        assert r["layer"], r
    return f"{len(rs)} points, components {sorted(components)}"


def check_pipeline(doc):
    rs = common(doc, "pipeline_e2e")
    assert len(rs) >= 2, rs
    assert any(r["qep"] for r in rs) and any(not r["qep"] for r in rs), rs
    for r in rs:
        assert r["mean_s"] > 0, r
        assert r["quantize_s"] > 0 and r["eval_s"] > 0, r
        assert r["ppl"] > 0, r
    return f"{len(rs)} cycles"


CHECKS = {
    "serve_throughput": check_serve,
    "linalg_hotpath": check_linalg,
    "quantizers": check_quantizers,
    "pipeline_e2e": check_pipeline,
}


def main():
    if len(sys.argv) != 2:
        sys.exit(f"usage: {sys.argv[0]} BENCH_<name>.json")
    path = sys.argv[1]
    with open(path) as f:
        doc = json.load(f)
    bench = doc.get("bench")
    assert bench in CHECKS, f"unknown bench {bench!r} in {path} (known: {sorted(CHECKS)})"
    detail = CHECKS[bench](doc)
    print(f"{path} ok: {detail} (smoke={doc['smoke']})")


if __name__ == "__main__":
    main()
