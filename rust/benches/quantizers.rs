//! Per-layer quantizer micro-benchmarks on realistic layer shapes
//! (tiny-l's 256×256 attention and 512×256/256×512 MLP projections), plus
//! the QEP correction itself. Breaks Table 3's totals down by component.
//!
//! Run: `cargo bench --bench quantizers`
//! (CI smoke-runs it via `BENCH_SMOKE=1 cargo test --benches` and
//! schema-gates the BENCH_quantizers.json it writes.)

use qep::linalg::Mat;
use qep::qep::corrected_weight;
use qep::quant::{quantizer_for, LayerCtx, Method, QuantConfig};
use qep::util::bench::{bench, fmt_time, smoke, BenchConfig};
use qep::util::json::Json;
use qep::util::rng::Rng;

/// One machine-readable component timing for `BENCH_quantizers.json`.
fn entry(name: &str, component: &str, layer: &str, mean_s: f64) -> Json {
    let mut r = Json::obj();
    r.set("name", Json::Str(name.to_string()));
    r.set("component", Json::Str(component.to_string()));
    r.set("layer", Json::Str(layer.to_string()));
    r.set("mean_s", Json::Num(mean_s));
    r
}

fn main() {
    let smoke = smoke();
    let cfg = if smoke {
        BenchConfig::from_env()
    } else {
        BenchConfig { measure_time: 2.0, ..Default::default() }
    };
    let mut rng = Rng::new(0);
    let m_tokens = 1024;
    let mut results = Vec::new();

    println!("# quantizer cost per layer (INT3, {m_tokens} calibration tokens)\n");

    let all_shapes: &[(usize, usize, &str)] = &[
        (256, 256, "attn 256x256"),
        (512, 256, "mlp.up 512x256"),
        (256, 512, "mlp.down 256x512"),
    ];
    // Smoke mode proves the harness + schema end to end on one shape;
    // the full matrix is for real bench sessions.
    let shapes = if smoke { &all_shapes[..1] } else { all_shapes };
    for &(n, d, label) in shapes {
        let x = Mat::randn(m_tokens, d, 1.0, &mut rng);
        let ctx = LayerCtx::from_activations(&x, 0, label);
        let w = Mat::randn(n, d, 0.05, &mut rng);
        let qc = QuantConfig::int(3);

        println!("## {label}");
        for method in Method::all() {
            let q = quantizer_for(method);
            let r = bench(&format!("{} {label}", method.name()), cfg, || {
                q.quantize(&w, &qc, &ctx).unwrap()
            });
            println!("  {:<8} {:>10}/layer", method.name(), fmt_time(r.mean_s));
            results.push(entry(&r.name, method.name(), label, r.mean_s));
        }

        // QEP correction on matching streams.
        let mut x_hat = x.clone();
        let mut nrng = Rng::new(1);
        for v in x_hat.data.iter_mut() {
            *v += 0.05 * nrng.normal_f32();
        }
        let r = bench(&format!("qep-correction {label}"), cfg, || {
            corrected_weight(&w, &x, &x_hat, 0.5, 1.0).unwrap()
        });
        println!("  {:<8} {:>10}/layer  (α=0.5 correction)", "QEP", fmt_time(r.mean_s));
        results.push(entry(&r.name, "qep-correction", label, r.mean_s));

        let r = bench(&format!("hessian-build {label}"), cfg, || {
            LayerCtx::from_activations(&x, 0, label)
        });
        println!("  {:<8} {:>10}/layer  (XᵀX + stats)", "Hessian", fmt_time(r.mean_s));
        results.push(entry(&r.name, "hessian-build", label, r.mean_s));
        println!();
    }

    // Trajectory point (same contract as BENCH_serve.json /
    // BENCH_linalg.json): CI gates on the schema, and smoke numbers are
    // flagged so downstream tooling never treats them as measurements.
    let mut doc = Json::obj();
    doc.set("schema_version", Json::Num(1.0));
    doc.set("bench", Json::Str("quantizers".into()));
    doc.set("smoke", Json::Bool(smoke));
    doc.set("results", Json::Arr(results));
    let text = doc.dump();
    std::fs::write("BENCH_quantizers.json", &text).expect("write BENCH_quantizers.json");

    // Self-validate: re-parse and check the keys CI's gate relies on, so
    // a schema break fails here first (exit code, not just a log line).
    let back = Json::parse(&text).expect("BENCH_quantizers.json must re-parse");
    for key in ["schema_version", "bench", "smoke", "results"] {
        assert!(back.get(key).is_some(), "BENCH_quantizers.json missing key '{key}'");
    }
    let entries = back.get("results").and_then(|r| r.as_arr()).expect("results must be an array");
    assert!(!entries.is_empty(), "results must be non-empty");
    for e in entries {
        let t = e.get("mean_s").and_then(Json::as_f64).expect("mean_s must be a number");
        assert!(t.is_finite() && t > 0.0, "mean_s must be positive, got {t}");
        assert!(e.get("component").and_then(Json::as_str).is_some(), "component must be a string");
    }
    println!("\nwrote BENCH_quantizers.json ({} bytes, schema ok)", text.len());
    qep::util::pool::shutdown();
}
