//! Per-layer quantizer micro-benchmarks on realistic layer shapes
//! (tiny-l's 256×256 attention and 512×256/256×512 MLP projections), plus
//! the QEP correction itself. Breaks Table 3's totals down by component.
//!
//! Run: `cargo bench --bench quantizers`

use qep::linalg::Mat;
use qep::qep::corrected_weight;
use qep::quant::{quantizer_for, LayerCtx, Method, QuantConfig};
use qep::util::bench::{bench, fmt_time, smoke, BenchConfig};
use qep::util::rng::Rng;

fn main() {
    let cfg = if smoke() {
        BenchConfig::from_env()
    } else {
        BenchConfig { measure_time: 2.0, ..Default::default() }
    };
    let mut rng = Rng::new(0);
    let m_tokens = 1024;

    println!("# quantizer cost per layer (INT3, {m_tokens} calibration tokens)\n");

    for (n, d, label) in [(256usize, 256usize, "attn 256x256"), (512, 256, "mlp.up 512x256"), (256, 512, "mlp.down 256x512")] {
        let x = Mat::randn(m_tokens, d, 1.0, &mut rng);
        let ctx = LayerCtx::from_activations(&x, 0, label);
        let w = Mat::randn(n, d, 0.05, &mut rng);
        let qc = QuantConfig::int(3);

        println!("## {label}");
        for method in Method::all() {
            let q = quantizer_for(method);
            let r = bench(&format!("{} {label}", method.name()), cfg, || {
                q.quantize(&w, &qc, &ctx).unwrap()
            });
            println!("  {:<8} {:>10}/layer", method.name(), fmt_time(r.mean_s));
        }

        // QEP correction on matching streams.
        let mut x_hat = x.clone();
        let mut nrng = Rng::new(1);
        for v in x_hat.data.iter_mut() {
            *v += 0.05 * nrng.normal_f32();
        }
        let r = bench(&format!("qep-correction {label}"), cfg, || {
            corrected_weight(&w, &x, &x_hat, 0.5, 1.0).unwrap()
        });
        println!("  {:<8} {:>10}/layer  (α=0.5 correction)", "QEP", fmt_time(r.mean_s));

        let r = bench(&format!("hessian-build {label}"), cfg, || {
            LayerCtx::from_activations(&x, 0, label)
        });
        println!("  {:<8} {:>10}/layer  (XᵀX + stats)", "Hessian", fmt_time(r.mean_s));
        println!();
    }
}
