//! L3 hot-path micro-benchmarks: GEMM variants, Cholesky/SPD solves, and
//! the fast Walsh–Hadamard transform. These are the kernels the §Perf pass
//! optimizes; the GFLOP/s numbers below are the before/after evidence in
//! EXPERIMENTS.md §Perf.
//!
//! Run: `cargo bench --bench linalg_hotpath`

use qep::linalg::{
    fwht_inplace, matmul, matmul_nt, matmul_tn, spd_inverse, upper_cholesky_of_inverse, Mat,
    Mat64,
};
use qep::util::bench::{bench, black_box, fmt_time, BenchConfig};
use qep::util::rng::Rng;

fn gflops(flops: f64, secs: f64) -> f64 {
    flops / secs / 1e9
}

fn main() {
    let cfg = BenchConfig::default();
    let mut rng = Rng::new(0);

    println!("# linalg hot path\n");

    for (m, k, n) in [(128, 256, 256), (256, 512, 512), (512, 512, 1024)] {
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let bt = b.transpose();
        let flops = 2.0 * m as f64 * k as f64 * n as f64;

        let r = bench(&format!("matmul    {m}x{k}x{n}"), cfg, || matmul(&a, &b));
        println!("{:<28} {:>10}  {:6.2} GFLOP/s", r.name, fmt_time(r.mean_s), gflops(flops, r.mean_s));

        let r = bench(&format!("matmul_nt {m}x{k}x{n}"), cfg, || matmul_nt(&a, &bt));
        println!("{:<28} {:>10}  {:6.2} GFLOP/s", r.name, fmt_time(r.mean_s), gflops(flops, r.mean_s));
    }

    for (m, d) in [(1024, 128), (3072, 256)] {
        let x = Mat::randn(m, d, 1.0, &mut rng);
        let flops = 2.0 * m as f64 * d as f64 * d as f64;
        let r = bench(&format!("hessian XᵀX {m}x{d}"), cfg, || matmul_tn(&x, &x));
        println!("{:<28} {:>10}  {:6.2} GFLOP/s", r.name, fmt_time(r.mean_s), gflops(flops, r.mean_s));
    }

    for d in [128usize, 256, 512] {
        // Well-conditioned SPD.
        let b = Mat::randn(d, d, 1.0, &mut rng);
        let h32 = matmul_tn(&b, &b);
        let mut h = Mat64::zeros(d, d);
        for (dst, src) in h.data.iter_mut().zip(h32.data.iter()) {
            *dst = *src as f64;
        }
        h.add_diag(d as f64);
        let r = bench(&format!("spd_inverse {d}"), cfg, || spd_inverse(&h).unwrap());
        println!("{:<28} {:>10}", r.name, fmt_time(r.mean_s));
        let r = bench(&format!("chol_of_inv {d}"), cfg, || {
            upper_cholesky_of_inverse(&h).unwrap()
        });
        println!("{:<28} {:>10}", r.name, fmt_time(r.mean_s));
    }

    for n in [256usize, 1024, 4096] {
        let mut x = rng.normal_vec(n, 1.0);
        let r = bench(&format!("fwht {n}"), cfg, || {
            fwht_inplace(black_box(&mut x));
            x[0]
        });
        println!("{:<28} {:>10}", r.name, fmt_time(r.mean_s));
    }
}
