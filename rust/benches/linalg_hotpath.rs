//! L3 hot-path micro-benchmarks: GEMM variants, Cholesky/SPD solves, the
//! fast Walsh–Hadamard transform, the dispatch-engine comparison
//! (persistent workers vs the scoped-spawn baseline), and the SYRK
//! micro-kernel vs its scalar reference. These are the kernels the §Perf
//! pass optimizes; the GFLOP/s numbers below are the before/after
//! evidence in docs/PERFORMANCE.md.
//!
//! Run: `cargo bench --bench linalg_hotpath`
//! (CI smoke-runs it via `BENCH_SMOKE=1 cargo test --benches`.)

use qep::linalg::micro::{dot1_sub_f64, syrk_row_sub_f64};
use qep::linalg::{
    cholesky_in_place_with, cholesky_unblocked, fwht_inplace, matmul, matmul_nt, matmul_nt_serial,
    matmul_nt_with, matmul_tn, matmul_tn_serial, matmul_tn_with, spd_inverse, spd_solve_with,
    svd_rank_with, svd_with, upper_cholesky_of_inverse, Mat, Mat64, CHOL_BLOCK,
};
use qep::util::bench::{bench, black_box, fmt_time, smoke, BenchConfig};
use qep::util::json::Json;
use qep::util::pool::{available_parallelism, chunk, Pool, SendPtr};
use qep::util::rng::Rng;

fn gflops(flops: f64, secs: f64) -> f64 {
    flops / secs / 1e9
}

/// One machine-readable SVD result for `BENCH_linalg.json`.
fn svd_entry(name: &str, engine: &str, threads: usize, mean_s: f64) -> Json {
    let mut r = Json::obj();
    r.set("name", Json::Str(name.to_string()));
    r.set("engine", Json::Str(engine.to_string()));
    r.set("threads", Json::Num(threads as f64));
    r.set("mean_s", Json::Num(mean_s));
    r
}

fn main() {
    let cfg = BenchConfig::from_env();
    let smoke = smoke();
    let mut rng = Rng::new(0);

    println!("# linalg hot path\n");

    for (m, k, n) in [(128, 256, 256), (256, 512, 512), (512, 512, 1024)] {
        let a = Mat::randn(m, k, 1.0, &mut rng);
        let b = Mat::randn(k, n, 1.0, &mut rng);
        let bt = b.transpose();
        let flops = 2.0 * m as f64 * k as f64 * n as f64;

        let r = bench(&format!("matmul    {m}x{k}x{n}"), cfg, || matmul(&a, &b));
        println!("{:<28} {:>10}  {:6.2} GFLOP/s", r.name, fmt_time(r.mean_s), gflops(flops, r.mean_s));

        let r = bench(&format!("matmul_nt {m}x{k}x{n}"), cfg, || matmul_nt(&a, &bt));
        println!("{:<28} {:>10}  {:6.2} GFLOP/s", r.name, fmt_time(r.mean_s), gflops(flops, r.mean_s));
    }

    for (m, d) in [(1024, 128), (3072, 256)] {
        let x = Mat::randn(m, d, 1.0, &mut rng);
        let flops = 2.0 * m as f64 * d as f64 * d as f64;
        let r = bench(&format!("hessian XᵀX {m}x{d}"), cfg, || matmul_tn(&x, &x));
        println!("{:<28} {:>10}  {:6.2} GFLOP/s", r.name, fmt_time(r.mean_s), gflops(flops, r.mean_s));
    }

    for d in [128usize, 256, 512] {
        // Well-conditioned SPD.
        let b = Mat::randn(d, d, 1.0, &mut rng);
        let h32 = matmul_tn(&b, &b);
        let mut h = Mat64::zeros(d, d);
        for (dst, src) in h.data.iter_mut().zip(h32.data.iter()) {
            *dst = *src as f64;
        }
        h.add_diag(d as f64);
        let r = bench(&format!("spd_inverse {d}"), cfg, || spd_inverse(&h).unwrap());
        println!("{:<28} {:>10}", r.name, fmt_time(r.mean_s));
        let r = bench(&format!("chol_of_inv {d}"), cfg, || {
            upper_cholesky_of_inverse(&h).unwrap()
        });
        println!("{:<28} {:>10}", r.name, fmt_time(r.mean_s));
    }

    for n in [256usize, 1024, 4096] {
        let mut x = rng.normal_vec(n, 1.0);
        let r = bench(&format!("fwht {n}"), cfg, || {
            fwht_inplace(black_box(&mut x));
            x[0]
        });
        println!("{:<28} {:>10}", r.name, fmt_time(r.mean_s));
    }

    // Parallel engine speedup: the acceptance bar is >= 2x for
    // matmul_nt 512x512x512 at 4 threads over the serial baseline
    // (on >= 4 hardware threads; results are bit-identical either way).
    println!(
        "\n# parallel engine (work-stealing pool, {} hardware threads)\n",
        available_parallelism()
    );
    let (m, k, n) = (512usize, 512usize, 512usize);
    let a = Mat::randn(m, k, 1.0, &mut rng);
    let b = Mat::randn(n, k, 1.0, &mut rng); // matmul_nt takes B as [n, k]
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    let base = bench("matmul_nt 512x512x512 serial", cfg, || matmul_nt_serial(&a, &b));
    println!(
        "{:<34} {:>10}  {:6.2} GFLOP/s",
        base.name,
        fmt_time(base.mean_s),
        gflops(flops, base.mean_s)
    );
    for threads in [2usize, 4, 8] {
        let pool = Pool::new(threads);
        let r = bench(&format!("matmul_nt 512x512x512 t={threads}"), cfg, || {
            matmul_nt_with(&a, &b, &pool)
        });
        println!(
            "{:<34} {:>10}  {:6.2} GFLOP/s  ({:.2}x vs serial)",
            r.name,
            fmt_time(r.mean_s),
            gflops(flops, r.mean_s),
            base.mean_s / r.mean_s
        );
    }

    // Blocked SPD engine: serial (unblocked reference) vs blocked-pool
    // Cholesky and multi-RHS spd_solve at the sizes where the QEP/GPTQ
    // compensation lives. Results are bit-identical across all variants;
    // only wall-clock differs.
    println!("\n# blocked SPD engine (Cholesky / spd_solve on the pool)\n");
    for n in [512usize, 1024] {
        let b = Mat::randn(n, n, 1.0, &mut rng);
        let h32 = matmul_tn(&b, &b);
        let mut h = Mat64::zeros(n, n);
        for (dst, src) in h.data.iter_mut().zip(h32.data.iter()) {
            *dst = *src as f64;
        }
        h.add_diag(n as f64);
        let rhs = Mat::randn(n, 64, 1.0, &mut rng).to_f64();

        let base = bench(&format!("cholesky {n} serial (unblocked)"), cfg, || {
            let mut c = h.clone();
            cholesky_unblocked(&mut c).unwrap();
            c
        });
        println!("{:<34} {:>10}", base.name, fmt_time(base.mean_s));
        for threads in [2usize, 4, 8] {
            let pool = Pool::new(threads);
            let r = bench(&format!("cholesky {n} blocked t={threads}"), cfg, || {
                let mut c = h.clone();
                cholesky_in_place_with(&mut c, CHOL_BLOCK, &pool).unwrap();
                c
            });
            println!(
                "{:<34} {:>10}  ({:.2}x vs serial)",
                r.name,
                fmt_time(r.mean_s),
                base.mean_s / r.mean_s
            );
        }

        let sbase = bench(&format!("spd_solve {n}x{n}·{n}x64 serial"), cfg, || {
            spd_solve_with(&h, &rhs, &Pool::serial()).unwrap()
        });
        println!("{:<34} {:>10}", sbase.name, fmt_time(sbase.mean_s));
        for threads in [2usize, 4, 8] {
            let pool = Pool::new(threads);
            let r = bench(&format!("spd_solve {n} blocked-pool t={threads}"), cfg, || {
                spd_solve_with(&h, &rhs, &pool).unwrap()
            });
            println!(
                "{:<34} {:>10}  ({:.2}x vs serial)",
                r.name,
                fmt_time(r.mean_s),
                sbase.mean_s / r.mean_s
            );
        }
    }

    // Dispatch engines: the persistent worker pool (parked threads,
    // mutex-lite injection) vs the scoped-spawn baseline it replaced.
    // The workload mimics the blocked Cholesky's per-panel row jobs —
    // many dispatches of n rows × one 64-long dot each — where the
    // per-dispatch overhead is the dominant cost being amortized.
    println!("\n# dispatch engines (persistent workers vs scoped spawn)\n");
    let dthreads = available_parallelism().min(4).max(2);
    let dpool = Pool::new(dthreads);
    for n in [512usize, 1024] {
        let xs = rng.normal_vec(n * 64, 1.0);
        let ys = rng.normal_vec(n * 64, 1.0);
        let mut out = vec![0.0f32; n];
        let base = SendPtr::new(out.as_mut_ptr());
        let body = |s: usize, e: usize| {
            for i in s..e {
                let d =
                    qep::linalg::gemm::dot(&xs[i * 64..(i + 1) * 64], &ys[i * 64..(i + 1) * 64]);
                // Sound: chunks are disjoint index ranges of `out`.
                unsafe { *base.0.add(i) = d };
            }
        };
        let grain = chunk(n, dpool.threads());
        let rs = bench(&format!("panel job {n} rows scoped-spawn"), cfg, || {
            dpool.run_scoped(n, grain, &body);
        });
        println!("{:<34} {:>10}  (per dispatch)", rs.name, fmt_time(rs.mean_s));
        let rp = bench(&format!("panel job {n} rows persistent"), cfg, || {
            dpool.run(n, grain, &body);
        });
        println!(
            "{:<34} {:>10}  (per dispatch, {:.2}x vs scoped, t={dthreads})",
            rp.name,
            fmt_time(rp.mean_s),
            rs.mean_s / rp.mean_s
        );
        black_box(&out);
    }

    // SYRK micro-kernel vs the scalar chain it replaces: a full trailing
    // update of an n×n lower triangle against a 64-wide panel — the exact
    // shape `cholesky_in_place_with` runs once per panel. Both variants
    // compute bit-identical results (gated in tests); only wall-clock
    // differs.
    println!("\n# SYRK micro-kernel vs scalar (trailing update, panel width 64)\n");
    let bw = 64usize;
    for n in [512usize, 1024] {
        let panel: Vec<f64> = (0..n * bw).map(|_| rng.normal()).collect();
        let trail0: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let syrk_flops = (n * n) as f64 * bw as f64; // ~2·(n²/2)·bw

        let rs = bench(&format!("syrk {n}x{bw} scalar"), cfg, || {
            let mut t = trail0.clone();
            for i in 0..n {
                let arow = &panel[i * bw..(i + 1) * bw];
                for j in 0..=i {
                    t[i * n + j] = dot1_sub_f64(arow, &panel[j * bw..(j + 1) * bw], t[i * n + j]);
                }
            }
            t
        });
        println!(
            "{:<34} {:>10}  {:6.2} GFLOP/s",
            rs.name,
            fmt_time(rs.mean_s),
            gflops(syrk_flops, rs.mean_s)
        );

        let rm = bench(&format!("syrk {n}x{bw} micro-kernel"), cfg, || {
            let mut t = trail0.clone();
            for i in 0..n {
                let arow = &panel[i * bw..(i + 1) * bw];
                // The exact production row kernel the blocked Cholesky's
                // trailing update dispatches (chol.rs::run_trail).
                // Sound: `t` (written) and `panel` (read) are disjoint
                // allocations; row i's output range is [0, i].
                unsafe {
                    syrk_row_sub_f64(arow, panel.as_ptr(), bw, t.as_mut_ptr().add(i * n), 0, i + 1);
                }
            }
            t
        });
        println!(
            "{:<34} {:>10}  {:6.2} GFLOP/s  ({:.2}x vs scalar)",
            rm.name,
            fmt_time(rm.mean_s),
            gflops(syrk_flops, rm.mean_s),
            rs.mean_s / rm.mean_s
        );
    }

    let x = Mat::randn(3072, 256, 1.0, &mut rng);
    let hflops = 2.0 * 3072.0 * 256.0 * 256.0;
    let hb = bench("hessian XᵀX 3072x256 serial", cfg, || matmul_tn_serial(&x, &x));
    println!(
        "{:<34} {:>10}  {:6.2} GFLOP/s",
        hb.name,
        fmt_time(hb.mean_s),
        gflops(hflops, hb.mean_s)
    );
    for threads in [2usize, 4] {
        let pool = Pool::new(threads);
        let r = bench(&format!("hessian XᵀX 3072x256 t={threads}"), cfg, || {
            matmul_tn_with(&x, &x, &pool)
        });
        println!(
            "{:<34} {:>10}  {:6.2} GFLOP/s  ({:.2}x vs serial)",
            r.name,
            fmt_time(r.mean_s),
            gflops(hflops, r.mean_s),
            hb.mean_s / r.mean_s
        );
    }

    // SVD engines behind the low-rank adjuncts: full one-sided Jacobi at
    // adjunct-sized layers, and the seeded randomized range-finder at the
    // large shapes where it takes over (min dim > 96, small rank). Both
    // are bit-identical across thread counts and block sizes (gated in
    // tests/svd_properties.rs) — the pool only moves the clock.
    println!("\n# SVD engines (one-sided Jacobi / seeded randomized range-finder)\n");
    let mut results = Vec::new();
    let jacobi_shapes: &[(usize, usize)] =
        if smoke { &[(48, 24)] } else { &[(96, 40), (128, 128)] };
    for &(m, n) in jacobi_shapes {
        let a = Mat::randn(m, n, 1.0, &mut rng);
        for threads in [1usize, 4] {
            let pool = Pool::new(threads);
            let r = bench(&format!("svd jacobi {m}x{n} t={threads}"), cfg, || svd_with(&a, &pool));
            println!("{:<34} {:>10}", r.name, fmt_time(r.mean_s));
            results.push(svd_entry(&r.name, "jacobi", threads, r.mean_s));
        }
    }
    // min(m, n) > 96 with a small rank routes to the randomized engine.
    let (rm, rn, rank) = if smoke { (128usize, 112usize, 4usize) } else { (512, 256, 8) };
    let a = Mat::randn(rm, rn, 1.0, &mut rng);
    for threads in [1usize, 4] {
        let pool = Pool::new(threads);
        let r = bench(&format!("svd randomized {rm}x{rn} r={rank} t={threads}"), cfg, || {
            svd_rank_with(&a, rank, 7, &pool)
        });
        println!("{:<34} {:>10}", r.name, fmt_time(r.mean_s));
        results.push(svd_entry(&r.name, "randomized", threads, r.mean_s));
    }

    // Trajectory point (same contract as BENCH_serve.json): CI gates on
    // the schema, and smoke numbers are flagged so downstream tooling
    // never treats them as measurements.
    let mut doc = Json::obj();
    doc.set("schema_version", Json::Num(1.0));
    doc.set("bench", Json::Str("linalg_hotpath".into()));
    doc.set("smoke", Json::Bool(smoke));
    doc.set("results", Json::Arr(results));
    let text = doc.dump();
    std::fs::write("BENCH_linalg.json", &text).expect("write BENCH_linalg.json");

    // Self-validate: re-parse and check the keys CI's gate relies on, so
    // a schema break fails here first (exit code, not just a log line).
    let back = Json::parse(&text).expect("BENCH_linalg.json must re-parse");
    for key in ["schema_version", "bench", "smoke", "results"] {
        assert!(back.get(key).is_some(), "BENCH_linalg.json missing key '{key}'");
    }
    let entries = back.get("results").and_then(|r| r.as_arr()).expect("results must be an array");
    assert!(!entries.is_empty(), "results must be non-empty");
    for e in entries {
        let t = e.get("mean_s").and_then(Json::as_f64).expect("mean_s must be a number");
        assert!(t.is_finite() && t > 0.0, "mean_s must be positive, got {t}");
        assert!(e.get("engine").and_then(Json::as_str).is_some(), "engine must be a string");
    }
    println!("\nwrote BENCH_linalg.json ({} bytes, schema ok)", text.len());
    qep::util::pool::shutdown();
}
