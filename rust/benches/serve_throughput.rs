//! Serving throughput: batched KV-cache decode, dense f32 vs packed
//! INT4g32 through the fused dequantize×GEMM kernels, at batch widths
//! N ∈ {1, 4, 16}. Reports single-stream and aggregate tokens/sec plus
//! the quantized-vs-f32 single-stream speedup, and persists the
//! machine-readable trajectory point to `BENCH_serve.json` (schema
//! self-validated by re-parsing before exit; CI runs this under
//! `BENCH_SMOKE=1` and gates on the file).
//!
//! Measurement is at the *engine* level — `decode_step_batch` in a loop
//! feeding fixed synthetic tokens, sampling bypassed — so the dense and
//! quantized engines do byte-for-byte the same amount of decoding work
//! regardless of what random-weight logits would sample. Weights come
//! from `Model::random` (tiny-l by default): serving throughput depends
//! on shapes and memory traffic, not on training.
//!
//! Run: `cargo bench --bench serve_throughput`

use qep::model::{Model, Size};
use qep::quant::QuantConfig;
use qep::serve::{KvCache, ServeModel};
use qep::util::bench::{black_box, smoke};
use qep::util::json::Json;
use qep::util::pool::Pool;
use qep::util::Stopwatch;

/// Decode-phase seconds for `gen` batched steps over `n` sessions
/// (prefill excluded from the timed region).
fn decode_secs(sm: &ServeModel, n: usize, prompt_len: usize, gen: usize, pool: &Pool) -> f64 {
    let prompt: Vec<u32> = (0..prompt_len).map(|i| (i % 200) as u32).collect();
    let mut caches: Vec<KvCache> = (0..n).map(|_| sm.new_cache()).collect();
    for c in caches.iter_mut() {
        sm.prefill(c, &prompt, pool);
    }
    let t = Stopwatch::start();
    for step in 0..gen {
        let toks = vec![(step % 200) as u32; n];
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        black_box(sm.decode_step_batch(&mut refs, &toks, pool));
    }
    t.seconds()
}

/// Best-of-`reps` tokens/sec (fresh caches each rep).
fn tok_s(sm: &ServeModel, n: usize, prompt_len: usize, gen: usize, reps: usize, pool: &Pool) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        best = best.min(decode_secs(sm, n, prompt_len, gen, pool));
    }
    (n * gen) as f64 / best.max(1e-9)
}

fn main() {
    let smoke = smoke();
    // Smoke shrinks everything to prove-it-runs size; real sessions use
    // tiny-l, whose f32 weights (~21 MB) actually spill cache so the
    // INT4 traffic saving shows up in the clock.
    let (size, prompt_len, gen, reps, widths): (Size, usize, usize, usize, &[usize]) = if smoke {
        (Size::TinyS, 4, 4, 1, &[1, 4])
    } else {
        (Size::TinyL, 16, 96, 3, &[1, 4, 16])
    };
    let model = Model::random(&size.config(), 1);
    let qcfg = QuantConfig::int_group(4, 32);
    let engines = [
        ("f32", ServeModel::from_model(&model)),
        ("int4g32", ServeModel::quantized(&model, &qcfg)),
    ];
    let pool = Pool::new(0);

    println!(
        "# serve_throughput: {} (dim={} layers={} seq={}), prefill {prompt_len} + {gen} decode steps, best of {reps}",
        model.cfg.name, model.cfg.dim, model.cfg.n_layers, model.cfg.seq_len
    );
    if smoke {
        println!("# BENCH_SMOKE: shrunk sizes — numbers are meaningless");
    }
    println!("{:<22} {:>10} {:>14} {:>14}", "config", "sessions", "agg tok/s", "tok/s/stream");

    let mut results = Vec::new();
    let mut single = [0.0f64; 2]; // [f32, quantized] @ n=1
    for (qi, (qname, sm)) in engines.iter().enumerate() {
        for &n in widths {
            let rate = tok_s(sm, n, prompt_len, gen, reps, &pool);
            println!("{:<22} {:>10} {:>14.1} {:>14.1}", *qname, n, rate, rate / n as f64);
            if n == 1 {
                single[qi] = rate;
            }
            let mut r = Json::obj();
            r.set("name", Json::Str(format!("{qname} n={n}")));
            r.set("sessions", Json::Num(n as f64));
            r.set("quantized", Json::Bool(qi == 1));
            r.set("tok_s", Json::Num(rate));
            results.push(r);
        }
    }
    let speedup = single[1] / single[0].max(1e-9);
    println!("\nsingle-stream speedup (int4g32 vs f32): {speedup:.2}×");

    // Trajectory point: schema gated by CI (smoke numbers are flagged so
    // downstream tooling never treats them as measurements).
    let mut doc = Json::obj();
    doc.set("schema_version", Json::Num(1.0));
    doc.set("bench", Json::Str("serve_throughput".into()));
    doc.set("model", Json::Str(model.cfg.name.clone()));
    doc.set("quant", Json::Str("int4g32".into()));
    doc.set("smoke", Json::Bool(smoke));
    doc.set("prompt_len", Json::Num(prompt_len as f64));
    doc.set("gen", Json::Num(gen as f64));
    doc.set("results", Json::Arr(results));
    doc.set("speedup_single_stream", Json::Num(speedup));
    let text = doc.dump();
    std::fs::write("BENCH_serve.json", &text).expect("write BENCH_serve.json");

    // Self-validate: re-parse and check the keys CI's gate relies on, so
    // a schema break fails here first (exit code, not just a log line).
    let back = Json::parse(&text).expect("BENCH_serve.json must re-parse");
    for key in [
        "schema_version",
        "bench",
        "model",
        "smoke",
        "results",
        "speedup_single_stream",
    ] {
        assert!(back.get(key).is_some(), "BENCH_serve.json missing key '{key}'");
    }
    let n_results = back.get("results").and_then(|r| r.as_arr()).map_or(0, |a| a.len());
    assert_eq!(n_results, 2 * widths.len(), "one result per engine × width");
    let sp = back
        .get("speedup_single_stream")
        .and_then(Json::as_f64)
        .expect("speedup must be a number");
    assert!(sp.is_finite() && sp > 0.0, "speedup must be positive, got {sp}");
    println!("wrote BENCH_serve.json ({} bytes, schema ok)", text.len());
    qep::util::pool::shutdown();
}
