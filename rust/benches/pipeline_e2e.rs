//! End-to-end pipeline bench: full quantize-and-evaluate cycles per
//! (method, ±QEP) on tiny-s — the number a user experiences, and the
//! denominator for the §Perf optimization log.
//!
//! Run: `cargo bench --bench pipeline_e2e`
//! (CI smoke-runs it via `BENCH_SMOKE=1 cargo test --benches` and
//! schema-gates the BENCH_pipeline.json it writes.)

use qep::coordinator::{Pipeline, PipelineConfig};
use qep::eval::perplexity;
use qep::exp::ExpEnv;
use qep::model::Size;
use qep::quant::{Method, QuantConfig};
use qep::text::Flavor;
use qep::util::bench::smoke;
use qep::util::json::Json;
use qep::util::{fmt_duration, Stopwatch};

/// One machine-readable cycle for `BENCH_pipeline.json`. `mean_s` is the
/// end-to-end wall time (the shared key every BENCH_*.json gate checks);
/// the quantize/eval split and the perplexity ride along.
fn entry(label: &str, method: &str, qep: bool, quant_s: f64, eval_s: f64, ppl: f64) -> Json {
    let mut r = Json::obj();
    r.set("name", Json::Str(label.to_string()));
    r.set("method", Json::Str(method.to_string()));
    r.set("qep", Json::Bool(qep));
    r.set("quantize_s", Json::Num(quant_s));
    r.set("eval_s", Json::Num(eval_s));
    r.set("mean_s", Json::Num(quant_s + eval_s));
    r.set("ppl", Json::Num(ppl));
    r
}

fn main() {
    let smoke = smoke();
    let mut env = ExpEnv::new("artifacts");
    let model = env.model(Size::TinyS);
    let calib = env.calib_tokens(Flavor::C4, model.cfg.seq_len, 0);
    let eval = env.eval_tokens(Flavor::Wiki);
    let mut results = Vec::new();

    println!("# end-to-end pipeline (tiny-s, INT3, 24 calib segments, 16k eval tokens)\n");
    println!(
        "{:<22} {:>12} {:>12} {:>12} {:>10}",
        "config", "quantize", "eval ppl", "total", "ppl"
    );
    // Smoke mode (CI's `cargo test --benches`): one method proves the
    // harness runs end to end; the full matrix is for real bench sessions.
    let all_methods = Method::all();
    let methods: &[Method] = if smoke { &all_methods[..1] } else { &all_methods };
    for method in methods.iter().copied() {
        for qep in [None, Some(0.5)] {
            let t_total = Stopwatch::start();
            let out = Pipeline::new(PipelineConfig {
                quant: QuantConfig::int(3),
                method,
                qep_alpha: qep,
                ..Default::default()
            })
            .run(&model, &calib)
            .unwrap();
            let t_q = t_total.seconds();
            let t_eval = Stopwatch::start();
            let ppl = perplexity(&out.model, &eval);
            let t_e = t_eval.seconds();
            let label = format!(
                "{} {}",
                method.name(),
                if qep.is_some() { "+QEP" } else { "base" }
            );
            println!(
                "{:<22} {:>12} {:>12} {:>12} {:>10.3}",
                label,
                fmt_duration(t_q),
                fmt_duration(t_e),
                fmt_duration(t_total.seconds()),
                ppl
            );
            results.push(entry(&label, method.name(), qep.is_some(), t_q, t_e, ppl));
        }
    }

    // Trajectory point (same contract as the other BENCH_*.json files):
    // CI gates on the schema, and smoke numbers are flagged so downstream
    // tooling never treats them as measurements.
    let mut doc = Json::obj();
    doc.set("schema_version", Json::Num(1.0));
    doc.set("bench", Json::Str("pipeline_e2e".into()));
    doc.set("smoke", Json::Bool(smoke));
    doc.set("results", Json::Arr(results));
    let text = doc.dump();
    std::fs::write("BENCH_pipeline.json", &text).expect("write BENCH_pipeline.json");

    // Self-validate: re-parse and check the keys CI's gate relies on, so
    // a schema break fails here first (exit code, not just a log line).
    let back = Json::parse(&text).expect("BENCH_pipeline.json must re-parse");
    for key in ["schema_version", "bench", "smoke", "results"] {
        assert!(back.get(key).is_some(), "BENCH_pipeline.json missing key '{key}'");
    }
    let entries = back.get("results").and_then(|r| r.as_arr()).expect("results must be an array");
    assert!(!entries.is_empty(), "results must be non-empty");
    for e in entries {
        let t = e.get("mean_s").and_then(Json::as_f64).expect("mean_s must be a number");
        assert!(t.is_finite() && t > 0.0, "mean_s must be positive, got {t}");
        let p = e.get("ppl").and_then(Json::as_f64).expect("ppl must be a number");
        assert!(p.is_finite() && p > 0.0, "ppl must be positive, got {p}");
    }
    println!("\nwrote BENCH_pipeline.json ({} bytes, schema ok)", text.len());
    qep::util::pool::shutdown();
}
