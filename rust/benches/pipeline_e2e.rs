//! End-to-end pipeline bench: full quantize-and-evaluate cycles per
//! (method, ±QEP) on tiny-s — the number a user experiences, and the
//! denominator for the §Perf optimization log.
//!
//! Run: `cargo bench --bench pipeline_e2e`

use qep::coordinator::{Pipeline, PipelineConfig};
use qep::eval::perplexity;
use qep::exp::ExpEnv;
use qep::model::Size;
use qep::quant::{Method, QuantConfig};
use qep::text::Flavor;
use qep::util::bench::smoke;
use qep::util::{fmt_duration, Stopwatch};

fn main() {
    let mut env = ExpEnv::new("artifacts");
    let model = env.model(Size::TinyS);
    let calib = env.calib_tokens(Flavor::C4, model.cfg.seq_len, 0);
    let eval = env.eval_tokens(Flavor::Wiki);

    println!("# end-to-end pipeline (tiny-s, INT3, 24 calib segments, 16k eval tokens)\n");
    println!("{:<22} {:>12} {:>12} {:>12} {:>10}", "config", "quantize", "eval ppl", "total", "ppl");
    // Smoke mode (CI's `cargo test --benches`): one method proves the
    // harness runs end to end; the full matrix is for real bench sessions.
    let all_methods = Method::all();
    let methods: &[Method] = if smoke() { &all_methods[..1] } else { &all_methods };
    for method in methods.iter().copied() {
        for qep in [None, Some(0.5)] {
            let t_total = Stopwatch::start();
            let out = Pipeline::new(PipelineConfig {
                quant: QuantConfig::int(3),
                method,
                qep_alpha: qep,
                ..Default::default()
            })
            .run(&model, &calib)
            .unwrap();
            let t_q = t_total.seconds();
            let t_eval = Stopwatch::start();
            let ppl = perplexity(&out.model, &eval);
            let label = format!(
                "{} {}",
                method.name(),
                if qep.is_some() { "+QEP" } else { "base" }
            );
            println!(
                "{:<22} {:>12} {:>12} {:>12} {:>10.3}",
                label,
                fmt_duration(t_q),
                fmt_duration(t_eval.seconds()),
                fmt_duration(t_total.seconds()),
                ppl
            );
        }
    }
}
