//! Table 3 bench: wall-clock cost of the quantization process for
//! GPTQ vs AWQ vs QEP+RTN across model sizes. The paper reports
//! 14.9m / 13.6m / 10.9m on Llama-2-7B — the *ordering* and the
//! "QEP correction is much cheaper than the quantizers" claim are what
//! this harness verifies at our scale.
//!
//! Run: `cargo bench --bench table3_runtime`

use qep::coordinator::{Pipeline, PipelineConfig};
use qep::exp::ExpEnv;
use qep::model::Size;
use qep::quant::{Method, QuantConfig};
use qep::text::Flavor;
use qep::util::bench::smoke;
use qep::util::fmt_duration;

fn main() {
    let mut env = ExpEnv::new("artifacts");
    println!("# Table 3 runtime bench (INT3, 24 calibration segments)\n");
    println!(
        "{:<10} {:>14} {:>14} {:>14} {:>14}",
        "size", "GPTQ", "AWQ", "QEP+RTN", "QEP corr. only"
    );
    // Smoke mode (CI's `cargo test --benches`): one size is enough to
    // prove the harness runs; full sweeps are for real bench sessions.
    let all_sizes = Size::all();
    let sizes: &[Size] = if smoke() { &all_sizes[..1] } else { &all_sizes };
    for size in sizes.iter().copied() {
        let model = env.model(size);
        let calib = env.calib_tokens(Flavor::C4, model.cfg.seq_len, 0);
        let mut cells = Vec::new();
        let mut corr = 0.0;
        for (method, qep) in [
            (Method::Gptq, None),
            (Method::Awq, None),
            (Method::Rtn, Some(0.5)),
        ] {
            // Best-of-2 to damp scheduler noise on the single core.
            let mut best = f64::INFINITY;
            for _ in 0..2 {
                let out = Pipeline::new(PipelineConfig {
                    quant: QuantConfig::int(3),
                    method,
                    qep_alpha: qep,
                    ..Default::default()
                })
                .run(&model, &calib)
                .unwrap();
                let t = out.report.hessian_s() + out.report.quant_s() + out.report.correction_s();
                if t < best {
                    best = t;
                    if qep.is_some() {
                        corr = out.report.correction_s();
                    }
                }
            }
            cells.push(best);
        }
        println!(
            "{:<10} {:>14} {:>14} {:>14} {:>14}",
            size.name(),
            fmt_duration(cells[0]),
            fmt_duration(cells[1]),
            fmt_duration(cells[2]),
            fmt_duration(corr),
        );
        // Robust ordering at this scale: QEP+RTN < AWQ (our cache-friendly
        // GPTQ column loop undercuts the paper's GPU GPTQ at d ≤ 512 —
        // see EXPERIMENTS.md Table 3 notes). Timing assertions are
        // meaningless on a noisy smoke run, so CI skips them.
        assert!(
            smoke() || cells[2] < cells[1],
            "{}: QEP+RTN should be cheaper than AWQ",
            size.name()
        );
    }
    println!("\nexpected shape (paper Table 3): QEP+RTN cheapest; costs grow with size");
}
