//! Round-to-nearest (RTN) baseline: snap every weight to its group grid,
//! ignoring activations entirely (Dettmers & Zettlemoyer 2023). This is the
//! cheapest method in Table 3 and — combined with QEP — the paper's
//! "QEP+RTN" row that stays competitive at a fraction of GPTQ's cost.

use super::{LayerCtx, QuantConfig, Quantizer, QuantizedTensor};
use crate::linalg::Mat;
use anyhow::Result;

#[derive(Default)]
pub struct Rtn;

impl Quantizer for Rtn {
    fn name(&self) -> &'static str {
        "RTN"
    }

    fn quantize(&self, w: &Mat, cfg: &QuantConfig, _ctx: &LayerCtx) -> Result<Mat> {
        Ok(QuantizedTensor::from_mat(w, cfg).dequantize())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn ctx(d: usize) -> LayerCtx {
        let mut rng = Rng::new(0);
        let x = Mat::randn(32, d, 1.0, &mut rng);
        LayerCtx::from_activations(&x, 0, "t")
    }

    #[test]
    fn rtn_8bit_is_near_lossless() {
        let mut rng = Rng::new(1);
        let w = Mat::randn(8, 32, 1.0, &mut rng);
        let q = Rtn.quantize(&w, &QuantConfig::int(8), &ctx(32)).unwrap();
        let rel = q.sub(&w).frob() / w.frob();
        assert!(rel < 0.01, "rel err {rel}");
    }

    #[test]
    fn rtn_output_is_on_grid() {
        let mut rng = Rng::new(2);
        let w = Mat::randn(4, 16, 1.0, &mut rng);
        let cfg = QuantConfig::int(3);
        let q = Rtn.quantize(&w, &cfg, &ctx(16)).unwrap();
        // Re-quantizing the output must be a fixed point.
        let q2 = Rtn.quantize(&q, &cfg, &ctx(16)).unwrap();
        for (a, b) in q.data.iter().zip(q2.data.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn rtn_error_grows_as_bits_shrink() {
        let mut rng = Rng::new(3);
        let w = Mat::randn(8, 64, 1.0, &mut rng);
        let c = ctx(64);
        let e4 = Rtn.quantize(&w, &QuantConfig::int(4), &c).unwrap().sub(&w).frob_sq();
        let e2 = Rtn.quantize(&w, &QuantConfig::int(2), &c).unwrap().sub(&w).frob_sq();
        assert!(e2 > e4 * 4.0);
    }
}
