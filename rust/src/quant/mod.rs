//! Layer-wise PTQ methods, from scratch: the quantization grid shared by
//! everyone, plus the four methods the paper benchmarks — RTN, GPTQ, AWQ,
//! QuIP — behind a common `Quantizer` trait. QEP (see `crate::qep`) is an
//! *orthogonal pre-correction*: it rewrites the weight matrix before any of
//! these methods run, exactly as in the paper.

pub mod awq;
pub mod budget;
pub mod gptq;
pub mod grid;
pub mod quip;
pub mod rtn;

pub use budget::{Alloc, Allocation, BitBudget, BudgetSpec};
pub use grid::{GroupGrid, QuantConfig, QuantizedTensor};

use crate::linalg::{Mat, Mat64};
use anyhow::Result;

/// Which layer-wise PTQ method to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    Rtn,
    Gptq,
    Awq,
    Quip,
}

impl Method {
    pub fn name(self) -> &'static str {
        match self {
            Method::Rtn => "RTN",
            Method::Gptq => "GPTQ",
            Method::Awq => "AWQ",
            Method::Quip => "QuIP",
        }
    }

    pub fn from_name(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "rtn" => Some(Method::Rtn),
            "gptq" => Some(Method::Gptq),
            "awq" => Some(Method::Awq),
            "quip" => Some(Method::Quip),
            _ => None,
        }
    }

    pub fn all() -> [Method; 4] {
        [Method::Rtn, Method::Gptq, Method::Awq, Method::Quip]
    }

    /// Activation stream each method calibrates on when QEP is *off*
    /// (§3: "no consensus" — GPTQ uses quantized activations, AWQ uses
    /// full-precision ones; we follow each original).
    pub fn base_uses_quantized_acts(self) -> bool {
        match self {
            Method::Rtn => true,   // RTN needs no activations; irrelevant.
            Method::Gptq => true,  // Frantar et al. 2022
            Method::Awq => false,  // Lin et al. 2024
            Method::Quip => true,  // Chee et al. 2023
        }
    }
}

/// Per-layer calibration context handed to a quantizer.
///
/// `hessian` is the *undamped* empirical Hessian `XᵀX` over calibration
/// tokens in the activation basis the method should quantize against
/// (quantized-stream X̂ for GPTQ/QuIP and for every QEP-corrected run;
/// full-precision X for base AWQ). `act_mean_abs[j] = mean_t |X[t,j]|` for
/// AWQ's saliency scales. `seed` derives the randomized rotations in QuIP.
pub struct LayerCtx {
    pub hessian: Mat64,
    pub act_mean_abs: Vec<f32>,
    pub seed: u64,
    pub layer_name: String,
}

impl LayerCtx {
    /// Build a context from tokens-major activations X [m, d].
    pub fn from_activations(x: &Mat, seed: u64, layer_name: &str) -> LayerCtx {
        let h32 = crate::linalg::matmul_tn(x, x);
        let mut hessian = Mat64::zeros(h32.rows, h32.cols);
        for (d, s) in hessian.data.iter_mut().zip(h32.data.iter()) {
            *d = *s as f64;
        }
        let m = x.rows.max(1) as f32;
        let mut act_mean_abs = vec![0.0f32; x.cols];
        for t in 0..x.rows {
            let row = x.row(t);
            for (a, v) in act_mean_abs.iter_mut().zip(row.iter()) {
                *a += v.abs();
            }
        }
        for a in act_mean_abs.iter_mut() {
            *a /= m;
        }
        LayerCtx { hessian, act_mean_abs, seed, layer_name: layer_name.to_string() }
    }

    /// Reconstruction error `tr(E H Eᵀ) = ‖E X‖²` for E = W − Ŵ — the exact
    /// layer-wise objective value, computed without touching X again.
    /// Evaluated through the blocked GEMM (E·H, then an elementwise trace)
    /// so it stays cheap even for the 512-wide MLP layers.
    pub fn recon_error(&self, w: &Mat, w_hat: &Mat) -> f64 {
        let e = w.sub(w_hat);
        let h32 = self.hessian.to_f32();
        let eh = crate::linalg::matmul(&e, &h32);
        e.data
            .iter()
            .zip(eh.data.iter())
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum()
    }
}

/// A layer-wise PTQ method: maps a (possibly QEP-corrected) weight matrix
/// `w` [out, in] to its dequantized quantized approximation.
pub trait Quantizer {
    fn name(&self) -> &'static str;

    /// Quantize and return the *dequantized* weights (weight-only PTQ: the
    /// compute path stays f32, as in all the paper's baselines).
    fn quantize(&self, w: &Mat, cfg: &QuantConfig, ctx: &LayerCtx) -> Result<Mat>;
}

pub fn quantizer_for(method: Method) -> Box<dyn Quantizer + Send + Sync> {
    match method {
        Method::Rtn => Box::new(rtn::Rtn),
        Method::Gptq => Box::new(gptq::Gptq::default()),
        Method::Awq => Box::new(awq::Awq::default()),
        Method::Quip => Box::new(quip::Quip::default()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn method_name_roundtrip() {
        for m in Method::all() {
            assert_eq!(Method::from_name(m.name()), Some(m));
        }
        assert_eq!(Method::from_name("gptq"), Some(Method::Gptq));
        assert_eq!(Method::from_name("nope"), None);
    }

    #[test]
    fn ctx_hessian_and_scales() {
        let mut rng = Rng::new(1);
        let x = Mat::randn(500, 8, 1.0, &mut rng);
        let ctx = LayerCtx::from_activations(&x, 0, "test");
        // Hessian diag ≈ m·E[x²] = 500.
        for i in 0..8 {
            let d = ctx.hessian.at(i, i);
            assert!((d - 500.0).abs() < 100.0, "diag {d}");
        }
        // mean |x| of N(0,1) ≈ 0.7979.
        for &a in &ctx.act_mean_abs {
            assert!((a - 0.7979).abs() < 0.1, "mean abs {a}");
        }
    }

    #[test]
    fn recon_error_matches_direct() {
        let mut rng = Rng::new(2);
        let x = Mat::randn(64, 6, 1.0, &mut rng);
        let ctx = LayerCtx::from_activations(&x, 0, "test");
        let w = Mat::randn(4, 6, 1.0, &mut rng);
        let mut w_hat = w.clone();
        for v in w_hat.data.iter_mut() {
            *v += 0.01 * rng.normal_f32();
        }
        // Direct: ‖(W−Ŵ)Xᵀ‖² with X tokens-major ⇒ ‖X (W−Ŵ)ᵀ‖².
        let e = w.sub(&w_hat);
        let ex = crate::linalg::matmul_nt(&x, &e);
        let want = ex.frob_sq();
        let got = ctx.recon_error(&w, &w_hat);
        assert!((got - want).abs() < 1e-3 * (1.0 + want), "{got} vs {want}");
    }
}
