//! GPTQ (Frantar et al., 2022): compensation-based layer-wise PTQ.
//!
//! Columns are quantized sequentially; the rounding error of column `j` is
//! redistributed onto the not-yet-quantized columns through the upper
//! Cholesky factor `U` of the damped inverse Hessian (`H⁻¹ = UᵀU`). We
//! implement the blocked "lazy batch" variant from the original paper:
//! within a block of `block_size` columns errors propagate immediately;
//! the tail update for the remaining columns is a single GEMM per block.
//!
//! Parallelism: the column order is a strict data dependence, but *rows*
//! are independent throughout — row `r`'s grid refits, rounding decisions,
//! and in-block compensation touch only row `r` of W and of the error
//! buffer (the Cholesky factor is shared read-only). Each lazy block
//! therefore sweeps its rows across the persistent worker pool
//! (`util::pool`), and the tail update runs through the parallel GEMM.
//! Per-row operation order is untouched — the in-block compensation axpy
//! runs through the element-wise register tile
//! (`linalg::micro::axpy_sub_f32`) — so results stay bit-identical to the
//! serial sweep.

use super::{grid::GroupGrid, LayerCtx, QuantConfig, Quantizer};
use crate::linalg::{matmul, upper_cholesky_of_inverse, Mat};
use crate::util::pool::{self, SendPtr};
use anyhow::{Context, Result};

pub struct Gptq {
    /// Damping as a fraction of mean(diag(H)) — GPTQ's `percdamp`.
    pub percdamp: f64,
    /// Lazy-update block width.
    pub block_size: usize,
    /// Quantize columns in order of decreasing Hessian diagonal
    /// (GPTQ's `--act-order`; groups are then formed in permuted order).
    pub act_order: bool,
}

impl Default for Gptq {
    fn default() -> Self {
        Gptq { percdamp: 0.01, block_size: 128, act_order: false }
    }
}

impl Quantizer for Gptq {
    fn name(&self) -> &'static str {
        "GPTQ"
    }

    fn quantize(&self, w: &Mat, cfg: &QuantConfig, ctx: &LayerCtx) -> Result<Mat> {
        let d = w.cols;
        let mut h = ctx.hessian.clone();
        assert_eq!(h.rows, d, "Hessian/weight shape mismatch");

        let mut wq = w.clone();

        // Dead input channels: zero Hessian diagonal ⇒ the column never
        // fires on calibration data; pin it to 0 and make H invertible.
        let mut dead = Vec::new();
        for i in 0..d {
            if h.at(i, i) <= 0.0 {
                *h.at_mut(i, i) = 1.0;
                dead.push(i);
                for r in 0..wq.rows {
                    wq.data[r * d + i] = 0.0;
                }
            }
        }

        // Damping (App. B.1).
        let damp = self.percdamp * h.mean_diag();
        h.add_diag(damp.max(1e-10));

        // Optional activation ordering.
        let perm: Vec<usize> = if self.act_order {
            let mut idx: Vec<usize> = (0..d).collect();
            idx.sort_by(|&a, &b| h.at(b, b).partial_cmp(&h.at(a, a)).unwrap());
            idx
        } else {
            (0..d).collect()
        };
        if self.act_order {
            wq = permute_cols(&wq, &perm);
            h = permute_sym(&h, &perm);
        }

        let u = upper_cholesky_of_inverse(&h)
            .context("GPTQ: Cholesky of inverse Hessian failed")?
            .to_f32();

        let glen = cfg.group_len(d);
        let n = wq.rows;
        let bs = self.block_size.min(d);
        // Active per-row grids, re-fit at each group boundary from the
        // *current* (error-compensated) weights — as in the reference code.
        let mut grids: Vec<GroupGrid> = vec![GroupGrid { scale: 1.0, zero: 0.0, qmax: 1 }; n];

        let pool = pool::global();
        let grain = pool::chunk(n, pool.threads());
        let mut err = Mat::zeros(n, bs);
        for b0 in (0..d).step_by(bs) {
            let b1 = (b0 + bs).min(d);
            let bw = b1 - b0;
            err.data[..n * bs].fill(0.0);

            // Row-parallel block sweep. Each worker owns a disjoint row
            // range of W, the error buffer, and the grid table; the column
            // loop runs serially *within* each row, preserving the exact
            // serial compensation order per row.
            {
                let wq_base = SendPtr::new(wq.data.as_mut_ptr());
                let err_base = SendPtr::new(err.data.as_mut_ptr());
                let grids_base = SendPtr::new(grids.as_mut_ptr());
                let u_ref = &u;
                pool.run(n, grain, |r0, r1| {
                    for r in r0..r1 {
                        // Sound: rows are disjoint across pool chunks.
                        let wr = unsafe { std::slice::from_raw_parts_mut(wq_base.0.add(r * d), d) };
                        let er = unsafe { std::slice::from_raw_parts_mut(err_base.0.add(r * bs), bs) };
                        let grid = unsafe { &mut *grids_base.0.add(r) };
                        for j in b0..b1 {
                            if j % glen == 0 {
                                // New group: fit the row's grid on current
                                // (error-compensated) values.
                                let g1 = (j + glen).min(d);
                                *grid = GroupGrid::fit(&wr[j..g1], cfg.bits);
                            }
                            let ujj = u_ref.at(j, j);
                            let urow = u_ref.row(j);
                            let v = wr[j];
                            let q = grid.snap(v);
                            wr[j] = q;
                            let e = (v - q) / ujj;
                            er[j - b0] = e;
                            // Immediate in-block compensation through the
                            // shared 8-wide register tile (element-wise,
                            // bit-identical to the plain loop).
                            crate::linalg::micro::axpy_sub_f32(
                                e,
                                &urow[j + 1..b1],
                                &mut wr[j + 1..b1],
                            );
                        }
                    }
                });
            }

            // Lazy tail update: W[:, b1..] -= Err · U[b0..b1, b1..]. The
            // GEMM goes through the parallel kernel; the subtraction is
            // row-partitioned over the pool.
            if b1 < d {
                let err_blk = if bw == bs {
                    err.clone()
                } else {
                    err.cols_slice(0, bw)
                };
                let mut u_tail = Mat::zeros(bw, d - b1);
                for (bi, j) in (b0..b1).enumerate() {
                    u_tail.row_mut(bi).copy_from_slice(&u.row(j)[b1..]);
                }
                let upd = matmul(&err_blk, &u_tail);
                let tail = d - b1;
                let wq_base = SendPtr::new(wq.data.as_mut_ptr());
                let upd_ref = &upd;
                pool.run(n, grain, |r0, r1| {
                    for r in r0..r1 {
                        // Sound: rows are disjoint across pool chunks.
                        let wr = unsafe {
                            std::slice::from_raw_parts_mut(wq_base.0.add(r * d + b1), tail)
                        };
                        let ur = upd_ref.row(r);
                        for (val, &u_val) in wr.iter_mut().zip(ur.iter()) {
                            *val -= u_val;
                        }
                    }
                });
            }
        }

        if self.act_order {
            wq = unpermute_cols(&wq, &perm);
        }
        // Re-pin dead columns (they were never updated but be explicit).
        for &i in &dead {
            for r in 0..wq.rows {
                wq.data[r * d + i] = 0.0;
            }
        }
        Ok(wq)
    }
}

fn permute_cols(m: &Mat, perm: &[usize]) -> Mat {
    let mut out = Mat::zeros(m.rows, m.cols);
    for r in 0..m.rows {
        let src = m.row(r);
        let dst = out.row_mut(r);
        for (c_new, &c_old) in perm.iter().enumerate() {
            dst[c_new] = src[c_old];
        }
    }
    out
}

fn unpermute_cols(m: &Mat, perm: &[usize]) -> Mat {
    let mut out = Mat::zeros(m.rows, m.cols);
    for r in 0..m.rows {
        let src = m.row(r);
        let dst = out.row_mut(r);
        for (c_new, &c_old) in perm.iter().enumerate() {
            dst[c_old] = src[c_new];
        }
    }
    out
}

fn permute_sym(h: &crate::linalg::Mat64, perm: &[usize]) -> crate::linalg::Mat64 {
    let n = h.rows;
    let mut out = crate::linalg::Mat64::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            *out.at_mut(i, j) = h.at(perm[i], perm[j]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::Rtn;
    use crate::util::rng::Rng;

    fn make_ctx(m: usize, d: usize, seed: u64) -> (Mat, LayerCtx) {
        let mut rng = Rng::new(seed);
        // Correlated activations (what makes GPTQ beat RTN).
        let base = Mat::randn(m, d, 1.0, &mut rng);
        let mix = Mat::randn(d, d, 0.4, &mut rng);
        let mut x = crate::linalg::matmul(&base, &mix);
        for (v, b) in x.data.iter_mut().zip(base.data.iter()) {
            *v += b;
        }
        let ctx = LayerCtx::from_activations(&x, seed, "test");
        (x, ctx)
    }

    #[test]
    fn gptq_beats_rtn_on_correlated_data() {
        let mut rng = Rng::new(1);
        let (_, ctx) = make_ctx(512, 48, 2);
        let w = Mat::randn(16, 48, 1.0, &mut rng);
        let cfg = QuantConfig::int(3);
        let gq = Gptq::default().quantize(&w, &cfg, &ctx).unwrap();
        let rq = Rtn.quantize(&w, &cfg, &ctx).unwrap();
        let e_g = ctx.recon_error(&w, &gq);
        let e_r = ctx.recon_error(&w, &rq);
        assert!(e_g < e_r, "GPTQ {e_g} !< RTN {e_r}");
    }

    #[test]
    fn blocked_matches_unblocked() {
        let mut rng = Rng::new(3);
        let (_, ctx) = make_ctx(256, 40, 4);
        let w = Mat::randn(8, 40, 1.0, &mut rng);
        let cfg = QuantConfig::int(4);
        let a = Gptq { block_size: 8, ..Default::default() }.quantize(&w, &cfg, &ctx).unwrap();
        let b = Gptq { block_size: 4096, ..Default::default() }.quantize(&w, &cfg, &ctx).unwrap();
        for (x, y) in a.data.iter().zip(b.data.iter()) {
            assert!((x - y).abs() < 2e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn dead_columns_are_zeroed_and_do_not_crash() {
        let mut rng = Rng::new(5);
        let mut x = Mat::randn(128, 16, 1.0, &mut rng);
        for t in 0..x.rows {
            *x.at_mut(t, 7) = 0.0; // channel 7 never fires
        }
        let ctx = LayerCtx::from_activations(&x, 0, "t");
        let w = Mat::randn(4, 16, 1.0, &mut rng);
        let q = Gptq::default().quantize(&w, &QuantConfig::int(3), &ctx).unwrap();
        for r in 0..4 {
            assert_eq!(q.at(r, 7), 0.0);
        }
    }

    #[test]
    fn act_order_roundtrips_and_helps_or_ties() {
        let mut rng = Rng::new(6);
        let (_, ctx) = make_ctx(512, 32, 7);
        let w = Mat::randn(8, 32, 1.0, &mut rng);
        let cfg = QuantConfig::int(2);
        let plain = Gptq::default().quantize(&w, &cfg, &ctx).unwrap();
        let ordered =
            Gptq { act_order: true, ..Default::default() }.quantize(&w, &cfg, &ctx).unwrap();
        let e_p = ctx.recon_error(&w, &plain);
        let e_o = ctx.recon_error(&w, &ordered);
        // act-order should not be catastrophically worse; typically better.
        assert!(e_o < e_p * 1.5, "act_order {e_o} vs plain {e_p}");
    }

    #[test]
    fn group_wise_gptq_improves_on_per_channel_at_int2() {
        let mut rng = Rng::new(8);
        let (_, ctx) = make_ctx(512, 64, 9);
        let w = Mat::randn(8, 64, 1.0, &mut rng);
        let pc = Gptq::default().quantize(&w, &QuantConfig::int(2), &ctx).unwrap();
        let gw = Gptq::default()
            .quantize(&w, &QuantConfig::int_group(2, 16), &ctx)
            .unwrap();
        assert!(ctx.recon_error(&w, &gw) < ctx.recon_error(&w, &pc));
    }

    #[test]
    fn high_bits_recover_weights_closely() {
        let mut rng = Rng::new(10);
        let (_, ctx) = make_ctx(256, 24, 11);
        let w = Mat::randn(6, 24, 1.0, &mut rng);
        let q = Gptq::default().quantize(&w, &QuantConfig::int(8), &ctx).unwrap();
        let rel = q.sub(&w).frob() / w.frob();
        assert!(rel < 0.02, "rel {rel}");
    }
}
