//! QuIP (Chee et al., 2023): quantization with incoherence processing.
//!
//! The layer problem `min ‖(W−Ŵ)X‖²` is conjugated with randomized signed
//! Hadamard rotations: `W̃ = U W Vᵀ`, `H̃ = V H Vᵀ` (activations rotate as
//! `X̃ = V X`). In the rotated basis weight magnitudes are *incoherent*
//! (no outliers), which is what makes 2-bit grids viable — the paper's
//! Table 1 shows QuIP(+QEP) as the only method standing at INT2. The
//! rounding core is LDLQ, which is equivalent to the GPTQ compensation
//! loop; we reuse our GPTQ implementation on the rotated problem and
//! rotate back afterwards.
//!
//! Both dimensions must be powers of two for the fast Hadamard transform;
//! when the output dimension is not (e.g. a vocab-sized head), we fall back
//! to input-side-only rotation, which preserves the objective exactly.

use super::{gptq::Gptq, LayerCtx, QuantConfig, Quantizer};
use crate::linalg::{Mat, Mat64, SignedHadamard};
use crate::util::rng::Rng;
use anyhow::Result;

pub struct Quip {
    pub core: Gptq,
}

impl Default for Quip {
    fn default() -> Self {
        Quip { core: Gptq::default() }
    }
}

impl Quantizer for Quip {
    fn name(&self) -> &'static str {
        "QuIP"
    }

    fn quantize(&self, w: &Mat, cfg: &QuantConfig, ctx: &LayerCtx) -> Result<Mat> {
        let (n, d) = (w.rows, w.cols);
        assert!(d.is_power_of_two(), "QuIP needs power-of-two in-features, got {d}");
        let mut rng = Rng::new(ctx.seed ^ 0x5157_4950); // "QuIP"
        let v = SignedHadamard::new(d, &mut rng);
        let u = if n.is_power_of_two() {
            Some(SignedHadamard::new(n, &mut rng))
        } else {
            None
        };

        // W̃ = U W Vᵀ.
        let mut wt = w.clone();
        v.right_mul_t(&mut wt); // W·Vᵀ
        if let Some(u) = &u {
            u.left_mul(&mut wt); // U·(W·Vᵀ)
        }

        // H̃ = V H Vᵀ in f64 (conjugate via f32 path then refine).
        let h32 = ctx.hessian.to_f32();
        let ht32 = conjugate_vhv(&h32, &v);
        let mut ht = Mat64::zeros(d, d);
        for (dst, src) in ht.data.iter_mut().zip(ht32.data.iter()) {
            *dst = *src as f64;
        }
        // Symmetrize (the FWHT in f32 introduces tiny asymmetry that can
        // trip the Cholesky).
        for i in 0..d {
            for j in 0..i {
                let m = 0.5 * (ht.at(i, j) + ht.at(j, i));
                *ht.at_mut(i, j) = m;
                *ht.at_mut(j, i) = m;
            }
        }

        let rot_ctx = LayerCtx {
            hessian: ht,
            act_mean_abs: vec![1.0; d],
            seed: ctx.seed,
            layer_name: format!("{}@rot", ctx.layer_name),
        };
        let mut wq = self.core.quantize(&wt, cfg, &rot_ctx)?;

        // Rotate back: Ŵ = Uᵀ W̃q V.
        if let Some(u) = &u {
            u.left_mul_t(&mut wq);
        }
        v.right_mul(&mut wq);
        Ok(wq)
    }
}

/// Compute V·H·Vᵀ for symmetric H.
fn conjugate_vhv(h: &Mat, v: &SignedHadamard) -> Mat {
    let mut m = h.clone();
    v.left_mul(&mut m); // V·H
    v.right_mul_t(&mut m); // (V·H)·Vᵀ
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::Rtn;
    use crate::util::rng::Rng;

    /// Weights in the regime where incoherence provably helps a min-max
    /// grid: a unit-variance body plus a *single* large outlier per row.
    /// The outlier inflates the per-row range (so RTN's 2-bit step dwarfs
    /// the body, flattening it onto the zero level ⇒ ~σ²·d error), while
    /// after rotation the same energy only raises the row variance by
    /// k²/d, giving ~0.3·(σ²+k²/d)·d error — smaller when n_out·k² ≲ 2.4·d.
    fn outlier_weights(n: usize, d: usize, rng: &mut Rng) -> Mat {
        let mut w = Mat::randn(n, d, 1.0, rng);
        for r in 0..n {
            let c = rng.below(d);
            *w.at_mut(r, c) = 12.0 * rng.sign();
        }
        w
    }

    fn gaussian_ctx(m: usize, d: usize, seed: u64) -> LayerCtx {
        let mut rng = Rng::new(seed);
        let x = Mat::randn(m, d, 1.0, &mut rng);
        LayerCtx::from_activations(&x, seed, "t")
    }

    #[test]
    fn quip_beats_rtn_at_2bit_with_outliers() {
        let mut rng = Rng::new(1);
        let ctx = gaussian_ctx(512, 128, 2);
        let w = outlier_weights(16, 128, &mut rng);
        let cfg = QuantConfig::int(2);
        let qq = Quip::default().quantize(&w, &cfg, &ctx).unwrap();
        let rq = Rtn.quantize(&w, &cfg, &ctx).unwrap();
        let (eq, er) = (ctx.recon_error(&w, &qq), ctx.recon_error(&w, &rq));
        assert!(eq < er, "QuIP {eq} !< RTN {er}");
    }

    #[test]
    fn conjugation_preserves_objective_value() {
        // ‖(W−Ŵ)X‖² is invariant under the (U,V) conjugation; check that
        // recon error evaluated in rotated coordinates matches direct.
        let mut rng = Rng::new(3);
        let d = 32;
        let x = Mat::randn(256, d, 1.0, &mut rng);
        let ctx = LayerCtx::from_activations(&x, 0, "t");
        let w = Mat::randn(8, d, 1.0, &mut rng);
        let mut w_hat = w.clone();
        for v in w_hat.data.iter_mut() {
            *v += 0.05 * rng.normal_f32();
        }
        let mut r2 = Rng::new(9);
        let v = SignedHadamard::new(d, &mut r2);
        let h32 = ctx.hessian.to_f32();
        let ht = conjugate_vhv(&h32, &v);
        let mut wt = w.clone();
        v.right_mul_t(&mut wt);
        let mut wht = w_hat.clone();
        v.right_mul_t(&mut wht);
        let mut ht64 = Mat64::zeros(d, d);
        for (dst, src) in ht64.data.iter_mut().zip(ht.data.iter()) {
            *dst = *src as f64;
        }
        let rot_ctx = LayerCtx { hessian: ht64, act_mean_abs: vec![1.0; d], seed: 0, layer_name: "r".into() };
        let e_direct = ctx.recon_error(&w, &w_hat);
        let e_rot = rot_ctx.recon_error(&wt, &wht);
        assert!((e_direct - e_rot).abs() < 1e-2 * (1.0 + e_direct), "{e_direct} vs {e_rot}");
    }

    #[test]
    fn non_pow2_out_dim_falls_back_to_one_sided() {
        let mut rng = Rng::new(5);
        let ctx = gaussian_ctx(256, 32, 6);
        let w = outlier_weights(7, 32, &mut rng); // 7 rows: not a power of 2
        let q = Quip::default().quantize(&w, &QuantConfig::int(3), &ctx).unwrap();
        assert_eq!((q.rows, q.cols), (7, 32));
        assert!(q.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(7);
        let ctx = gaussian_ctx(128, 16, 8);
        let w = Mat::randn(8, 16, 1.0, &mut rng);
        let a = Quip::default().quantize(&w, &QuantConfig::int(3), &ctx).unwrap();
        let b = Quip::default().quantize(&w, &QuantConfig::int(3), &ctx).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn seed_changes_result() {
        let mut rng = Rng::new(9);
        let x = Mat::randn(128, 16, 1.0, &mut rng);
        let ctx_a = LayerCtx::from_activations(&x, 1, "t");
        let ctx_b = LayerCtx::from_activations(&x, 2, "t");
        let w = Mat::randn(8, 16, 1.0, &mut rng);
        let a = Quip::default().quantize(&w, &QuantConfig::int(2), &ctx_a).unwrap();
        let b = Quip::default().quantize(&w, &QuantConfig::int(2), &ctx_b).unwrap();
        assert_ne!(a, b);
    }
}
