//! AWQ (Lin et al., 2024): activation-aware weight quantization.
//!
//! Salient weights — those multiplying high-magnitude activation channels —
//! are protected by scaling them *up* before RTN and scaling the activation
//! path *down* by the same factor (folded into the weight here, since we
//! evaluate weight-only dequantized models). The per-channel scale is
//! `s_j = (mean_t |x_{t,j}|)^β`, with β grid-searched to minimize the true
//! layer reconstruction error `tr(E H Eᵀ)`.

use super::{LayerCtx, QuantConfig, Quantizer, QuantizedTensor};
use crate::linalg::Mat;
use anyhow::Result;

pub struct Awq {
    /// β grid resolution: β ∈ {0, 1/n, …, 1}.
    pub grid_points: usize,
    /// Rows used during the β search (the final quantization always uses
    /// all rows). The per-channel scale is shared across rows, so a
    /// strided subsample ranks βs almost identically at a fraction of the
    /// cost — this keeps AWQ's 21-point search from dominating Table 3.
    pub search_rows: usize,
}

impl Default for Awq {
    fn default() -> Self {
        // Full-row search matches the reference implementation; set
        // `search_rows` lower to trade a little β fidelity for speed.
        Awq { grid_points: 20, search_rows: usize::MAX }
    }
}

impl Awq {
    /// Quantize with a fixed β and return (dequantized weights, error).
    fn try_beta(&self, w: &Mat, cfg: &QuantConfig, ctx: &LayerCtx, beta: f32) -> (Mat, f64) {
        let d = w.cols;
        // s_j = max(|x|_mean, eps)^β, normalized so the geometric mean is 1
        // (keeps grids in a sane range; pure rescaling otherwise).
        let mut s = vec![0.0f32; d];
        let mut log_sum = 0.0f64;
        for j in 0..d {
            let a = ctx.act_mean_abs[j].max(1e-8);
            let v = a.powf(beta);
            s[j] = v;
            log_sum += (v as f64).ln();
        }
        let gm = (log_sum / d as f64).exp() as f32;
        for v in s.iter_mut() {
            *v /= gm;
        }
        // W' = W·diag(s); RTN on W'; Ŵ = RTN(W')·diag(1/s).
        let mut ws = w.clone();
        for r in 0..ws.rows {
            let row = ws.row_mut(r);
            for j in 0..d {
                row[j] *= s[j];
            }
        }
        let mut dq = QuantizedTensor::from_mat(&ws, cfg).dequantize();
        for r in 0..dq.rows {
            let row = dq.row_mut(r);
            for j in 0..d {
                row[j] /= s[j];
            }
        }
        let err = ctx.recon_error(w, &dq);
        (dq, err)
    }
}

impl Quantizer for Awq {
    fn name(&self) -> &'static str {
        "AWQ"
    }

    fn quantize(&self, w: &Mat, cfg: &QuantConfig, ctx: &LayerCtx) -> Result<Mat> {
        // β search on a strided row subsample.
        let w_search = if w.rows > self.search_rows {
            let stride = w.rows / self.search_rows;
            let mut sub = Mat::zeros(self.search_rows, w.cols);
            for r in 0..self.search_rows {
                sub.row_mut(r).copy_from_slice(w.row(r * stride));
            }
            sub
        } else {
            w.clone()
        };
        let mut best_beta = 0.0f32;
        let mut best_err = f64::INFINITY;
        for i in 0..=self.grid_points {
            let beta = i as f32 / self.grid_points as f32;
            let (_, err) = self.try_beta(&w_search, cfg, ctx, beta);
            if err < best_err {
                best_err = err;
                best_beta = beta;
            }
        }
        // Final quantization of the full matrix at the winning β.
        Ok(self.try_beta(w, cfg, ctx, best_beta).0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::Rtn;
    use crate::util::rng::Rng;

    /// Activations with a few dominant channels — AWQ's motivating regime.
    fn outlier_ctx(m: usize, d: usize, seed: u64) -> LayerCtx {
        let mut rng = Rng::new(seed);
        let mut x = Mat::randn(m, d, 1.0, &mut rng);
        for t in 0..m {
            for j in 0..d / 8 {
                *x.at_mut(t, j * 8) *= 12.0;
            }
        }
        LayerCtx::from_activations(&x, seed, "t")
    }

    #[test]
    fn awq_beats_rtn_under_activation_outliers() {
        let mut rng = Rng::new(1);
        let ctx = outlier_ctx(512, 64, 2);
        let w = Mat::randn(16, 64, 1.0, &mut rng);
        let cfg = QuantConfig::int(3);
        let aq = Awq::default().quantize(&w, &cfg, &ctx).unwrap();
        let rq = Rtn.quantize(&w, &cfg, &ctx).unwrap();
        let (ea, er) = (ctx.recon_error(&w, &aq), ctx.recon_error(&w, &rq));
        assert!(ea < er, "AWQ {ea} !< RTN {er}");
    }

    #[test]
    fn beta_zero_equals_rtn() {
        let mut rng = Rng::new(3);
        let ctx = outlier_ctx(256, 32, 4);
        let w = Mat::randn(8, 32, 1.0, &mut rng);
        let cfg = QuantConfig::int(4);
        let (dq, _) = Awq::default().try_beta(&w, &cfg, &ctx, 0.0);
        let rq = Rtn.quantize(&w, &cfg, &ctx).unwrap();
        for (a, b) in dq.data.iter().zip(rq.data.iter()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn search_never_loses_to_beta_zero() {
        let mut rng = Rng::new(5);
        let ctx = outlier_ctx(256, 32, 6);
        let w = Mat::randn(8, 32, 1.0, &mut rng);
        let cfg = QuantConfig::int(2);
        let aq = Awq::default().quantize(&w, &cfg, &ctx).unwrap();
        let (_, e0) = Awq::default().try_beta(&w, &cfg, &ctx, 0.0);
        assert!(ctx.recon_error(&w, &aq) <= e0 + 1e-9);
    }

    #[test]
    fn uniform_activations_make_awq_harmless() {
        // With flat activation magnitudes the best β should do no worse
        // than RTN (s ≈ const ⇒ identical grids).
        let mut rng = Rng::new(7);
        let x = Mat::randn(256, 24, 1.0, &mut rng);
        let ctx = LayerCtx::from_activations(&x, 0, "t");
        let w = Mat::randn(8, 24, 1.0, &mut rng);
        let cfg = QuantConfig::int(3);
        let aq = Awq::default().quantize(&w, &cfg, &ctx).unwrap();
        let rq = Rtn.quantize(&w, &cfg, &ctx).unwrap();
        assert!(ctx.recon_error(&w, &aq) <= ctx.recon_error(&w, &rq) * 1.05);
    }
}
