//! Hessian-guided mixed-precision bit allocation (ROADMAP item 3).
//!
//! All layers getting the same width wastes budget: QEP's own analysis
//! shows layers differ sharply in how much quantization error they inject
//! downstream. This module scores each quantizable linear with a
//! trace-weighted proxy — the diagonal of its calibration Hessian
//! `diag(XᵀX)` times the squared RTN snap error at each candidate width —
//! and then assigns per-layer bit widths under a global
//! average-bits-per-weight budget.
//!
//! Determinism contract: scoring iterates rows/groups/columns in fixed
//! order with serial f64 accumulation, and both allocators are pure
//! serial functions of the cost table with documented tie-breaks (ties go
//! to the lowest layer index), so a given model + calibration stream maps
//! to exactly one allocation regardless of thread count, shard split, or
//! allocator invocation site.
//!
//! Budget semantics: the budget is a *ceiling* on average bits per
//! weight. Every layer is guaranteed at least `⌊B⌋` bits (the uniform
//! floor), and the fractional surplus `(B − ⌊B⌋)·Σ nₗ` is distributed as
//! whole-bit upgrades. An integral budget (e.g. 3.0) therefore reduces to
//! exactly the uniform grid, and any fractional budget elementwise
//! dominates the uniform-floor baseline.

use crate::linalg::Mat;
use crate::quant::grid::{GroupGrid, QuantConfig};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Narrowest / widest grid the allocator will assign (INT2..INT8 — the
/// same range the paper's grids span).
pub const MIN_BITS: u32 = 2;
pub const MAX_BITS: u32 = 8;

/// `.qtz` meta key holding the budget as its canonical string ("2.5").
pub const BUDGET_META_KEY: &str = "bit_budget";
/// `.qtz` meta key holding the allocator name ("dp" / "greedy").
pub const BUDGET_ALLOC_META_KEY: &str = "bit_alloc";
/// `.qtz` meta key holding the achieved average bits per weight.
pub const BUDGET_AVG_META_KEY: &str = "bit_alloc_avg_bits";
/// `.qtz` meta key holding the per-layer bit map (object: name → bits).
pub const LAYER_BITS_META_KEY: &str = "layer_bits";

/// A global average-bits-per-weight budget, stored in tenths of a bit
/// ("deci-bits") so capacity arithmetic and cell IDs stay exactly
/// integral: `BitBudget(25)` is 2.5 average bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BitBudget(u32);

impl BitBudget {
    pub fn from_decibits(d: u32) -> BitBudget {
        BitBudget(d)
    }

    pub fn decibits(self) -> u32 {
        self.0
    }

    pub fn avg_bits(self) -> f64 {
        self.0 as f64 / 10.0
    }

    /// The uniform floor: every layer gets at least this many bits.
    pub fn floor_bits(self) -> u32 {
        self.0 / 10
    }

    /// Deci-bits of surplus above the uniform floor (0..=9).
    pub fn frac_decibits(self) -> u32 {
        self.0 % 10
    }

    /// Canonical rendering with exactly one decimal: "2.5", "3.0".
    pub fn render(self) -> String {
        format!("{}.{}", self.0 / 10, self.0 % 10)
    }

    /// Parse "3" or "3.5" (one fractional digit, no leading zeros). The
    /// integer shorthand canonicalizes: `parse("3").render() == "3.0"`.
    pub fn parse(s: &str) -> Option<BitBudget> {
        let (int, frac) = match s.split_once('.') {
            Some((i, f)) => (i, f),
            None => (s, "0"),
        };
        let digits = |t: &str| !t.is_empty() && t.bytes().all(|b| b.is_ascii_digit());
        if !digits(int) || !digits(frac) || int.len() > 2 || frac.len() != 1 {
            return None;
        }
        if int.len() > 1 && int.starts_with('0') {
            return None;
        }
        Some(BitBudget(int.parse::<u32>().ok()? * 10 + frac.parse::<u32>().ok()?))
    }

    /// Strict variant for plan-cell IDs: only the canonical "d.d" form
    /// parses, so parse∘render is the identity.
    pub fn parse_strict(s: &str) -> Option<BitBudget> {
        BitBudget::parse(s).filter(|b| b.render() == s)
    }
}

/// Which allocator assigns the surplus bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum Alloc {
    /// Repeatedly upgrade the layer with the best marginal error
    /// reduction per upgraded weight. Optimal when all layers hold the
    /// same number of weights; a cheap approximation otherwise.
    Greedy,
    /// Exact knapsack over upgrade units (weight counts divided by their
    /// gcd), minimizing total proxy error under the budget.
    #[default]
    Dp,
}

impl Alloc {
    pub fn name(self) -> &'static str {
        match self {
            Alloc::Greedy => "greedy",
            Alloc::Dp => "dp",
        }
    }

    pub fn from_name(s: &str) -> Option<Alloc> {
        match s.to_ascii_lowercase().as_str() {
            "greedy" => Some(Alloc::Greedy),
            "dp" => Some(Alloc::Dp),
            _ => None,
        }
    }
}

/// Budget + allocator choice, as carried by `PipelineConfig.bit_budget`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BudgetSpec {
    pub budget: BitBudget,
    pub alloc: Alloc,
}

/// One layer's scoring table: `err[k]` is the proxy error when the layer
/// is quantized at `floor + k` bits (k = 0 is the uniform floor). The
/// curve is convex in practice — marginal gains shrink with each bit.
#[derive(Clone, Debug)]
pub struct LayerCost {
    pub name: String,
    /// Number of weights nₗ (rows × cols) — the cost of a one-bit upgrade.
    pub weights: usize,
    pub err: Vec<f64>,
}

/// Score one linear: Hessian-diagonal-weighted squared RTN snap error at
/// each candidate width `floor..=max_bits`. `diag[j]` is the j-th
/// diagonal of the layer's calibration Hessian `XᵀX` (column sums of
/// squared activations); the proxy is `Σⱼ diag[j] · Σᵢ (W[i,j] −
/// snap_b(W)[i,j])²` — the layer-wise objective `‖(W−Ŵ)X‖²` with the
/// off-diagonal Hessian terms dropped. RTN snapping makes the score
/// method-independent: it ranks layers, not quantizers.
pub fn layer_cost(
    name: &str,
    w: &Mat,
    diag: &[f64],
    base: &QuantConfig,
    floor_bits: u32,
    max_bits: u32,
) -> LayerCost {
    assert_eq!(diag.len(), w.cols, "diag(XᵀX) length must match layer columns");
    let mut err = Vec::with_capacity((max_bits - floor_bits + 1) as usize);
    for bits in floor_bits..=max_bits {
        let cfg = QuantConfig { bits, group: base.group };
        let glen = cfg.group_len(w.cols);
        let ngroups = w.cols.div_ceil(glen);
        let mut e = 0.0f64;
        for r in 0..w.rows {
            let row = w.row(r);
            for gi in 0..ngroups {
                let c0 = gi * glen;
                let c1 = (c0 + glen).min(w.cols);
                let grid = GroupGrid::fit(&row[c0..c1], bits);
                for c in c0..c1 {
                    let d = (grid.snap(row[c]) - row[c]) as f64;
                    e += diag[c] * d * d;
                }
            }
        }
        err.push(e);
    }
    LayerCost { name: name.to_string(), weights: w.rows * w.cols, err }
}

/// The result of an allocation: per-layer bit widths plus bookkeeping.
#[derive(Clone, Debug, PartialEq)]
pub struct Allocation {
    pub budget: BitBudget,
    pub alloc: Alloc,
    /// Canonical layer name (`blocks.{i}.{short}`) → assigned bits.
    pub bits: BTreeMap<String, u32>,
    /// Achieved average bits per weight (≤ the budget by construction).
    pub avg_bits: f64,
}

impl Allocation {
    pub fn bits_for(&self, name: &str) -> Option<u32> {
        self.bits.get(name).copied()
    }

    /// Human summary, e.g. "budget 2.5 (dp), avg 2.50: 7×INT2 + 7×INT3".
    pub fn summary(&self) -> String {
        let mut counts: BTreeMap<u32, usize> = BTreeMap::new();
        for &b in self.bits.values() {
            *counts.entry(b).or_insert(0) += 1;
        }
        let mix = counts
            .iter()
            .map(|(b, n)| format!("{n}×INT{b}"))
            .collect::<Vec<_>>()
            .join(" + ");
        format!(
            "budget {} ({}), avg {:.2}: {}",
            self.budget.render(),
            self.alloc.name(),
            self.avg_bits,
            mix
        )
    }
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Error for a budget outside the representable grid range.
fn infeasible(budget: BitBudget) -> anyhow::Error {
    anyhow!(
        "bit budget {} is infeasible: the feasible range is [{}.0, {}.0] average bits per weight \
         (grids span INT{MIN_BITS}..INT{MAX_BITS})",
        budget.render(),
        MIN_BITS,
        MAX_BITS
    )
}

/// Cheap feasibility gate (run it before any expensive scoring pre-pass):
/// the budget must lie in `[MIN_BITS, MAX_BITS]` average bits per weight.
pub fn check_feasible(budget: BitBudget) -> Result<()> {
    let d = budget.decibits();
    if d < MIN_BITS * 10 || d > MAX_BITS * 10 {
        return Err(infeasible(budget));
    }
    Ok(())
}

/// Assign per-layer bit widths under `budget` average bits per weight.
///
/// Every layer receives at least `⌊budget⌋` bits; the fractional surplus
/// is spent as whole-bit upgrades (layer ℓ may climb as far as
/// `⌊budget⌋ + len(errₗ) − 1` bits). Ties break toward the lowest layer
/// index — the computation is serial and bit-identical everywhere.
pub fn allocate(costs: &[LayerCost], budget: BitBudget, alloc: Alloc) -> Result<Allocation> {
    check_feasible(budget)?;
    if costs.is_empty() {
        return Err(anyhow!("bit budget allocation needs at least one layer"));
    }
    for c in costs {
        if c.weights == 0 || c.err.is_empty() {
            return Err(anyhow!("layer '{}' has no weights or no cost curve", c.name));
        }
    }
    let floor = budget.floor_bits();
    let n = costs.len();
    // Capacity in units of gcd(nₗ)/10 bit-weights: one-bit upgrades cost
    // 10·nₗ/g units, the surplus is frac·Σnₗ/g units — all exactly integral.
    let g = costs.iter().fold(0usize, |acc, c| gcd(acc, c.weights));
    let total: usize = costs.iter().map(|c| c.weights).sum();
    let capacity = budget.frac_decibits() as usize * (total / g);
    let step: Vec<usize> = costs.iter().map(|c| 10 * (c.weights / g)).collect();
    let max_ups: Vec<usize> = costs
        .iter()
        .map(|c| (c.err.len() - 1).min((MAX_BITS - floor) as usize))
        .collect();

    let ups = match alloc {
        Alloc::Greedy => greedy(costs, &step, &max_ups, capacity),
        Alloc::Dp => dp(costs, &step, &max_ups, capacity),
    };

    let mut bits = BTreeMap::new();
    let mut spent_bits = 0usize;
    for (i, c) in costs.iter().enumerate() {
        let b = floor + ups[i] as u32;
        spent_bits += b as usize * c.weights;
        bits.insert(c.name.clone(), b);
    }
    Ok(Allocation {
        budget,
        alloc,
        bits,
        avg_bits: spent_bits as f64 / total as f64,
    })
}

/// Greedy marginal-gain allocator: repeatedly upgrade the layer whose
/// next bit buys the largest proxy-error reduction per upgraded weight.
/// Zero-gain upgrades are skipped (bits stay minimal); ties on the rate
/// keep the lowest layer index.
fn greedy(costs: &[LayerCost], step: &[usize], max_ups: &[usize], capacity: usize) -> Vec<usize> {
    let mut ups = vec![0usize; costs.len()];
    let mut cap = capacity;
    loop {
        let mut best: Option<(f64, usize)> = None;
        for (i, c) in costs.iter().enumerate() {
            let k = ups[i];
            if k >= max_ups[i] || step[i] > cap {
                continue;
            }
            let gain = c.err[k] - c.err[k + 1];
            if gain <= 0.0 {
                continue;
            }
            let rate = gain / step[i] as f64;
            if best.is_none_or(|(r, _)| rate > r) {
                best = Some((rate, i));
            }
        }
        match best {
            Some((_, i)) => {
                ups[i] += 1;
                cap -= step[i];
            }
            None => break,
        }
    }
    ups
}

/// Exact allocator: minimize total proxy error subject to the upgrade
/// capacity — a bounded knapsack solved by dynamic programming over
/// layers × remaining capacity. The table is built backward and
/// reconstructed forward preferring the *largest* upgrade count on exact
/// value ties, which routes tied upgrades to the lowest layer index
/// (matching the greedy tie-break).
fn dp(costs: &[LayerCost], step: &[usize], max_ups: &[usize], capacity: usize) -> Vec<usize> {
    let n = costs.len();
    let w = capacity + 1;
    // dp[i][c] = min Σ err over layers i..n with c capacity units left.
    let mut table = vec![0.0f64; (n + 1) * w];
    for i in (0..n).rev() {
        for c in 0..w {
            let mut best = f64::INFINITY;
            for k in 0..=max_ups[i] {
                let kc = k * step[i];
                if kc > c {
                    break;
                }
                let v = costs[i].err[k] + table[(i + 1) * w + (c - kc)];
                if v < best {
                    best = v;
                }
            }
            table[i * w + c] = best;
        }
    }
    let mut ups = vec![0usize; n];
    let mut cap = capacity;
    for i in 0..n {
        let target = table[i * w + cap];
        let mut chosen = 0usize;
        for k in 0..=max_ups[i] {
            let kc = k * step[i];
            if kc > cap {
                break;
            }
            if costs[i].err[k] + table[(i + 1) * w + (cap - kc)] == target {
                chosen = k;
            }
        }
        ups[i] = chosen;
        cap -= chosen * step[i];
    }
    ups
}

/// Record an allocation in `.qtz` meta. Old readers ignore the extra
/// keys; `read_allocation_meta` restores it byte-identically (BTreeMap
/// ordering makes the serialized header deterministic).
pub fn write_allocation_meta(meta: &mut Json, alloc: &Allocation) {
    meta.set(BUDGET_META_KEY, Json::Str(alloc.budget.render()))
        .set(BUDGET_ALLOC_META_KEY, Json::Str(alloc.alloc.name().to_string()))
        .set(BUDGET_AVG_META_KEY, Json::Num(alloc.avg_bits));
    let mut layers = Json::obj();
    for (name, &bits) in &alloc.bits {
        layers.set(name, Json::Num(bits as f64));
    }
    meta.set(LAYER_BITS_META_KEY, layers);
}

/// Read an allocation back from `.qtz` meta. `Ok(None)` when the
/// artifact was produced without a bit budget (no budget key at all);
/// a loud error when the budget keys are present but malformed. The
/// per-layer widths in particular are validated as integers in
/// `MIN_BITS..=MAX_BITS` — an `as u32` cast here would silently
/// truncate a hand-edited fractional width and wrap a negative or huge
/// one into a grid the pipeline never quantized on.
pub fn read_allocation_meta(meta: &Json) -> Result<Option<Allocation>> {
    let budget_raw = match meta.get(BUDGET_META_KEY) {
        None => return Ok(None),
        Some(v) => v,
    };
    let budget = budget_raw
        .as_str()
        .and_then(BitBudget::parse_strict)
        .ok_or_else(|| anyhow!("invalid '{BUDGET_META_KEY}' in .qtz meta (want e.g. \"2.5\")"))?;
    let alloc = meta
        .get(BUDGET_ALLOC_META_KEY)
        .and_then(|v| v.as_str())
        .and_then(Alloc::from_name)
        .ok_or_else(|| anyhow!("invalid or missing '{BUDGET_ALLOC_META_KEY}' in .qtz meta"))?;
    let avg_bits = meta
        .get(BUDGET_AVG_META_KEY)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| anyhow!("invalid or missing '{BUDGET_AVG_META_KEY}' in .qtz meta"))?;
    let mut bits = BTreeMap::new();
    match meta.get(LAYER_BITS_META_KEY) {
        Some(Json::Obj(m)) => {
            for (name, v) in m {
                let raw = v.as_f64().ok_or_else(|| {
                    anyhow!("layer '{name}' has a non-numeric bit width in .qtz meta")
                })?;
                if raw.fract() != 0.0 || raw < MIN_BITS as f64 || raw > MAX_BITS as f64 {
                    bail!(
                        "layer '{name}' has invalid bit width {raw} in .qtz meta \
                         (supported: integers {MIN_BITS}..={MAX_BITS})"
                    );
                }
                bits.insert(name.clone(), raw as u32);
            }
        }
        _ => bail!("'{LAYER_BITS_META_KEY}' missing or not an object in .qtz meta"),
    }
    Ok(Some(Allocation { budget, alloc, bits, avg_bits }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cost(name: &str, weights: usize, err: &[f64]) -> LayerCost {
        LayerCost { name: name.to_string(), weights, err: err.to_vec() }
    }

    #[test]
    fn budget_parse_render_identity() {
        for (s, d) in [("2.5", 25), ("3.0", 30), ("3.5", 35), ("8.0", 80)] {
            let b = BitBudget::parse_strict(s).unwrap();
            assert_eq!(b.decibits(), d);
            assert_eq!(b.render(), s);
        }
        // Integer shorthand canonicalizes (CLI convenience) …
        assert_eq!(BitBudget::parse("3").unwrap().render(), "3.0");
        // … but the strict form used by plan IDs rejects it.
        for bad in ["3", "03.0", "3.", ".5", "3.50", "2,5", "", "x.y", "3.0x"] {
            assert_eq!(BitBudget::parse_strict(bad), None, "{bad:?}");
        }
    }

    #[test]
    fn integral_budget_is_uniform_floor() {
        let costs = [cost("a", 64, &[4.0, 1.0, 0.5]), cost("b", 64, &[9.0, 2.0, 1.0])];
        for alloc in [Alloc::Greedy, Alloc::Dp] {
            let a = allocate(&costs, BitBudget::from_decibits(30), alloc).unwrap();
            assert!(a.bits.values().all(|&b| b == 3), "{a:?}");
            assert_eq!(a.avg_bits, 3.0);
        }
    }

    #[test]
    fn surplus_goes_to_the_most_sensitive_layer() {
        // Layer b's first upgrade gains 7, layer a's gains 3: with surplus
        // for exactly one upgrade, b gets it.
        let costs = [cost("a", 64, &[4.0, 1.0]), cost("b", 64, &[9.0, 2.0])];
        for alloc in [Alloc::Greedy, Alloc::Dp] {
            let a = allocate(&costs, BitBudget::from_decibits(35), alloc).unwrap();
            assert_eq!(a.bits["a"], 3, "{alloc:?}");
            assert_eq!(a.bits["b"], 4, "{alloc:?}");
            assert_eq!(a.avg_bits, 3.5);
        }
    }

    #[test]
    fn ties_break_to_the_lowest_layer_index() {
        let costs = [cost("a", 64, &[4.0, 1.0]), cost("b", 64, &[4.0, 1.0])];
        for alloc in [Alloc::Greedy, Alloc::Dp] {
            let a = allocate(&costs, BitBudget::from_decibits(25), alloc).unwrap();
            assert_eq!(a.bits["a"], 3, "{alloc:?}");
            assert_eq!(a.bits["b"], 2, "{alloc:?}");
        }
    }

    #[test]
    fn unequal_layer_sizes_stay_exactly_on_budget() {
        // 256 + 512 weights, budget 2.5 ⇒ surplus 384 bit-weights: only
        // the 256-weight layer fits (upgrading the 512 one would cost 512).
        let costs = [cost("small", 256, &[1.0, 0.9]), cost("big", 512, &[100.0, 1.0])];
        let a = allocate(&costs, BitBudget::from_decibits(25), Alloc::Dp).unwrap();
        assert_eq!(a.bits["small"], 3);
        assert_eq!(a.bits["big"], 2);
        assert!(a.avg_bits <= 2.5);
    }

    #[test]
    fn infeasible_budgets_name_the_range() {
        let costs = [cost("a", 64, &[4.0, 1.0])];
        for d in [15, 19, 81, 90] {
            let e = allocate(&costs, BitBudget::from_decibits(d), Alloc::Dp).unwrap_err();
            let msg = format!("{e}");
            assert!(msg.contains("feasible range"), "{msg}");
            assert!(msg.contains("[2.0, 8.0]"), "{msg}");
        }
        assert!(allocate(&[], BitBudget::from_decibits(30), Alloc::Dp).is_err());
    }

    #[test]
    fn single_layer_cannot_split_a_fraction() {
        // One layer can't average 2.5 bits with integral widths: it stays
        // at the floor and the surplus goes unspent (budget is a ceiling).
        let costs = [cost("only", 128, &[4.0, 1.0])];
        for alloc in [Alloc::Greedy, Alloc::Dp] {
            let a = allocate(&costs, BitBudget::from_decibits(25), alloc).unwrap();
            assert_eq!(a.bits["only"], 2, "{alloc:?}");
            assert_eq!(a.avg_bits, 2.0);
        }
    }

    #[test]
    fn greedy_matches_dp_on_convex_equal_size_curves() {
        // Convex (decreasing marginal gains), equal layer sizes — the
        // regime where greedy is provably optimal.
        let mut rng = Rng::new(7);
        for trial in 0..20 {
            let costs: Vec<LayerCost> = (0..6)
                .map(|i| {
                    let mut e = 16.0 * (1.0 + rng.normal_f32().abs()) as f64;
                    let err: Vec<f64> = (0..5)
                        .map(|_| {
                            let cur = e;
                            e *= 0.2 + 0.3 * rng.normal_f32().abs().min(1.0) as f64;
                            cur
                        })
                        .collect();
                    cost(&format!("l{i}"), 64, &err)
                })
                .collect();
            for d in [25, 33, 38] {
                let ga = allocate(&costs, BitBudget::from_decibits(d), Alloc::Greedy).unwrap();
                let da = allocate(&costs, BitBudget::from_decibits(d), Alloc::Dp).unwrap();
                assert_eq!(ga.bits, da.bits, "trial {trial} budget {d}");
            }
        }
    }

    #[test]
    fn upgrades_cap_at_max_bits() {
        let costs = [cost("a", 64, &[4.0, 2.0, 1.0]), cost("b", 64, &[4.0, 2.0, 1.0])];
        let a = allocate(&costs, BitBudget::from_decibits(80), Alloc::Dp).unwrap();
        assert!(a.bits.values().all(|&b| b == 8), "{a:?}");
    }

    #[test]
    fn layer_cost_is_monotone_and_hessian_weighted() {
        let mut rng = Rng::new(11);
        let w = Mat::randn(8, 16, 1.0, &mut rng);
        let diag = vec![1.0f64; 16];
        let c = layer_cost("t", &w, &diag, &QuantConfig::int(2), 2, 5);
        assert_eq!(c.err.len(), 4);
        assert_eq!(c.weights, 8 * 16);
        for k in 1..c.err.len() {
            assert!(c.err[k] <= c.err[k - 1], "{:?}", c.err);
        }
        // Doubling every Hessian diagonal doubles the proxy exactly.
        let diag2 = vec![2.0f64; 16];
        let c2 = layer_cost("t", &w, &diag2, &QuantConfig::int(2), 2, 5);
        for (a, b) in c.err.iter().zip(c2.err.iter()) {
            assert!((b - 2.0 * a).abs() < 1e-9 * (1.0 + a.abs()));
        }
    }

    #[test]
    fn allocation_meta_roundtrip() {
        let costs = [cost("blocks.0.attn.wq", 256, &[4.0, 1.0]), cost("blocks.0.mlp.up", 512, &[9.0, 2.0])];
        let a = allocate(&costs, BitBudget::from_decibits(25), Alloc::Dp).unwrap();
        let mut meta = Json::obj();
        write_allocation_meta(&mut meta, &a);
        let text = meta.dump();
        let back = read_allocation_meta(&Json::parse(&text).unwrap()).unwrap().unwrap();
        assert_eq!(back, a);
        // Writing the read-back allocation again is byte-identical.
        let mut meta2 = Json::obj();
        write_allocation_meta(&mut meta2, &back);
        assert_eq!(meta2.dump(), text);
        // Plain meta without budget keys reads as None (not an error).
        assert!(read_allocation_meta(&Json::obj()).unwrap().is_none());
    }

    #[test]
    fn corrupt_layer_bits_error_loudly_naming_the_layer() {
        let costs = [cost("blocks.0.attn.wq", 256, &[4.0, 1.0])];
        let a = allocate(&costs, BitBudget::from_decibits(30), Alloc::Dp).unwrap();
        let mut meta = Json::obj();
        write_allocation_meta(&mut meta, &a);
        // Hand-edit the layer's width to values no grid represents:
        // fractional (an `as u32` would truncate), negative or huge
        // (would wrap), and integers outside INT2..INT8.
        for bad in [2.5, -3.0, 1.0, 9.0, 1e12, f64::NAN] {
            let mut m = meta.clone();
            let mut layers = Json::obj();
            layers.set("blocks.0.attn.wq", Json::Num(bad));
            m.set(LAYER_BITS_META_KEY, layers);
            let msg = format!("{}", read_allocation_meta(&m).unwrap_err());
            assert!(msg.contains("blocks.0.attn.wq"), "{bad}: {msg}");
            assert!(msg.contains("2..=8"), "{bad}: {msg}");
        }
    }
}
