//! The uniform asymmetric quantization grid shared by every method
//! (§6 “Quantization”: weight-only, per-channel or group-wise, INT4/3/2,
//! groups g32/g64/g128).
//!
//! A weight matrix W [out, in] is quantized per *output channel* (one
//! scale/zero per row) or *group-wise* (one scale/zero per `group`
//! consecutive input columns within a row). Codes are unsigned b-bit
//! integers; dequantization is `(q - zero) * scale`.

use crate::linalg::Mat;

/// Grid configuration. `group = None` means per-channel (one group spanning
/// the whole row — the paper's “per-channel” setting).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct QuantConfig {
    pub bits: u32,
    pub group: Option<usize>,
}

impl QuantConfig {
    /// Per-channel b-bit config (paper main text: INT4/INT3/INT2).
    pub fn int(bits: u32) -> QuantConfig {
        QuantConfig { bits, group: None }
    }

    /// Group-wise config (paper appendix: INT2g32 etc).
    pub fn int_group(bits: u32, group: usize) -> QuantConfig {
        QuantConfig { bits, group: Some(group) }
    }

    pub fn qmax(&self) -> i32 {
        (1i32 << self.bits) - 1
    }

    /// Effective group length for a row of `cols` input features: group
    /// sizes larger than the row clamp to per-channel.
    pub fn group_len(&self, cols: usize) -> usize {
        match self.group {
            Some(g) if g < cols => g,
            _ => cols,
        }
    }

    pub fn label(&self) -> String {
        match self.group {
            Some(g) => format!("INT{}g{}", self.bits, g),
            None => format!("INT{}", self.bits),
        }
    }

    pub fn from_label(s: &str) -> Option<QuantConfig> {
        let rest = s.strip_prefix("INT").or_else(|| s.strip_prefix("int"))?;
        if let Some((b, g)) = rest.split_once('g') {
            Some(QuantConfig::int_group(b.parse().ok()?, g.parse().ok()?))
        } else {
            Some(QuantConfig::int(rest.parse().ok()?))
        }
    }

    /// The eight settings of the appendix tables, in paper order.
    pub fn appendix_settings() -> Vec<QuantConfig> {
        vec![
            QuantConfig::int_group(4, 128),
            QuantConfig::int(4),
            QuantConfig::int_group(3, 128),
            QuantConfig::int(3),
            QuantConfig::int_group(2, 32),
            QuantConfig::int_group(2, 64),
            QuantConfig::int_group(2, 128),
            QuantConfig::int(2),
        ]
    }
}

/// Min–max asymmetric scale/zero for one group of values.
#[derive(Clone, Copy, Debug)]
pub struct GroupGrid {
    pub scale: f32,
    pub zero: f32,
    pub qmax: i32,
}

impl GroupGrid {
    /// Fit the grid to a slice of values (standard min-max with zero-point
    /// clamping so 0.0 is representable when the range straddles it).
    pub fn fit(values: &[f32], bits: u32) -> GroupGrid {
        let qmax = (1i32 << bits) - 1;
        let mut lo = 0.0f32;
        let mut hi = 0.0f32;
        for &v in values {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if hi - lo < 1e-12 {
            // Degenerate (all-equal, possibly all-zero) group.
            return GroupGrid { scale: 1.0, zero: -lo, qmax };
        }
        let scale = (hi - lo) / qmax as f32;
        let zero = (-lo / scale).round().clamp(0.0, qmax as f32);
        GroupGrid { scale, zero, qmax }
    }

    #[inline]
    pub fn quantize(&self, v: f32) -> i32 {
        ((v / self.scale + self.zero).round() as i32).clamp(0, self.qmax)
    }

    #[inline]
    pub fn dequantize(&self, q: i32) -> f32 {
        (q as f32 - self.zero) * self.scale
    }

    /// Round-trip a value through the grid.
    #[inline]
    pub fn snap(&self, v: f32) -> f32 {
        self.dequantize(self.quantize(v))
    }
}

/// A fully quantized tensor: codes + per-group grids. This is what the
/// serving path stores on disk / feeds the Pallas `quant_matmul` kernel;
/// the PTQ pipeline itself mostly passes dequantized f32 around.
#[derive(Clone, Debug)]
pub struct QuantizedTensor {
    pub rows: usize,
    pub cols: usize,
    pub bits: u32,
    pub group_len: usize,
    pub codes: Vec<u8>,
    /// One (scale, zero) per row per group, row-major: `rows * n_groups`.
    pub scales: Vec<f32>,
    pub zeros: Vec<f32>,
}

impl QuantizedTensor {
    pub fn n_groups(&self) -> usize {
        self.cols.div_ceil(self.group_len)
    }

    /// RTN-quantize a weight matrix onto the grid.
    pub fn from_mat(w: &Mat, cfg: &QuantConfig) -> QuantizedTensor {
        let glen = cfg.group_len(w.cols);
        let ngroups = w.cols.div_ceil(glen);
        let mut codes = vec![0u8; w.rows * w.cols];
        let mut scales = vec![0.0f32; w.rows * ngroups];
        let mut zeros = vec![0.0f32; w.rows * ngroups];
        for r in 0..w.rows {
            let row = w.row(r);
            for g in 0..ngroups {
                let c0 = g * glen;
                let c1 = (c0 + glen).min(w.cols);
                let grid = GroupGrid::fit(&row[c0..c1], cfg.bits);
                scales[r * ngroups + g] = grid.scale;
                zeros[r * ngroups + g] = grid.zero;
                for c in c0..c1 {
                    codes[r * w.cols + c] = grid.quantize(row[c]) as u8;
                }
            }
        }
        QuantizedTensor {
            rows: w.rows,
            cols: w.cols,
            bits: cfg.bits,
            group_len: glen,
            codes,
            scales,
            zeros,
        }
    }

    /// Borrowed packed view for the fused dequantize×GEMM kernels
    /// (`linalg::qgemm`), which consume codes + per-group grids directly —
    /// `qgemm_nt(x, &t.view())` is bitwise-identical to
    /// `matmul_nt(x, &t.dequantize())` without materializing the f32
    /// matrix.
    pub fn view(&self) -> crate::linalg::QWeightView<'_> {
        crate::linalg::QWeightView {
            rows: self.rows,
            cols: self.cols,
            group_len: self.group_len,
            codes: &self.codes,
            scales: &self.scales,
            zeros: &self.zeros,
        }
    }

    pub fn dequantize(&self) -> Mat {
        let ngroups = self.n_groups();
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                let g = c / self.group_len;
                let s = self.scales[r * ngroups + g];
                let z = self.zeros[r * ngroups + g];
                m.data[r * self.cols + c] = (self.codes[r * self.cols + c] as f32 - z) * s;
            }
        }
        m
    }

    /// Storage cost in bits per weight (codes + grids), the paper's
    /// compression metric for group-wise settings.
    pub fn bits_per_weight(&self) -> f64 {
        let code_bits = self.bits as f64;
        let grid_bits = 2.0 * 32.0 * self.n_groups() as f64 * self.rows as f64;
        code_bits + grid_bits / (self.rows * self.cols) as f64
    }
}

/// Fit per-group grids for a weight matrix and return them without
/// quantizing (GPTQ fits grids up front, then rounds columns sequentially).
pub fn fit_grids(w: &Mat, cfg: &QuantConfig) -> Vec<Vec<GroupGrid>> {
    let glen = cfg.group_len(w.cols);
    let ngroups = w.cols.div_ceil(glen);
    (0..w.rows)
        .map(|r| {
            let row = w.row(r);
            (0..ngroups)
                .map(|g| {
                    let c0 = g * glen;
                    let c1 = (c0 + glen).min(w.cols);
                    GroupGrid::fit(&row[c0..c1], cfg.bits)
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn labels_roundtrip() {
        for cfg in QuantConfig::appendix_settings() {
            assert_eq!(QuantConfig::from_label(&cfg.label()), Some(cfg));
        }
        assert_eq!(QuantConfig::from_label("INT4").unwrap(), QuantConfig::int(4));
        assert_eq!(QuantConfig::from_label("bad"), None);
    }

    #[test]
    fn grid_snap_error_bounded_by_half_step() {
        let mut rng = Rng::new(1);
        for bits in [2u32, 3, 4, 8] {
            let vals = rng.normal_vec(256, 1.0);
            let grid = GroupGrid::fit(&vals, bits);
            for &v in &vals {
                let err = (grid.snap(v) - v).abs();
                assert!(err <= grid.scale * 0.5 + 1e-6, "bits={bits} err={err} scale={}", grid.scale);
            }
        }
    }

    #[test]
    fn grid_represents_extremes() {
        let vals = [-1.0f32, 0.3, 2.0];
        let grid = GroupGrid::fit(&vals, 4);
        assert!((grid.snap(-1.0) + 1.0).abs() < grid.scale);
        assert!((grid.snap(2.0) - 2.0).abs() < grid.scale);
    }

    #[test]
    fn degenerate_group_is_exact() {
        let vals = [0.0f32; 16];
        let grid = GroupGrid::fit(&vals, 2);
        assert_eq!(grid.snap(0.0), 0.0);
        let vals2 = [3.5f32; 16];
        let grid2 = GroupGrid::fit(&vals2, 2);
        assert!((grid2.snap(3.5) - 3.5).abs() < 1e-5);
    }

    #[test]
    fn tensor_roundtrip_error_shrinks_with_bits() {
        let mut rng = Rng::new(2);
        let w = Mat::randn(16, 64, 1.0, &mut rng);
        let mut last = f64::INFINITY;
        for bits in [2u32, 3, 4, 8] {
            let qt = QuantizedTensor::from_mat(&w, &QuantConfig::int(bits));
            let err = qt.dequantize().sub(&w).frob_sq();
            assert!(err < last, "bits={bits}: {err} !< {last}");
            last = err;
        }
    }

    #[test]
    fn group_wise_beats_per_channel() {
        // Rows of unit-scale weights with a trailing block of exactly-
        // representable ±100 outliers: per-channel grids blow the step size
        // up to ~200/q (flattening the unit-scale weights onto the zero
        // level), while a group grid isolates the outlier block and keeps
        // the unit-scale groups at fine resolution.
        let mut rng = Rng::new(3);
        let mut w = Mat::randn(4, 64, 1.0, &mut rng);
        for r in 0..4 {
            for c in 56..64 {
                *w.at_mut(r, c) = 100.0; // constant outlier group: exactly
                                         // representable by its own grid
            }
        }
        let per_ch = QuantizedTensor::from_mat(&w, &QuantConfig::int(3));
        let grouped = QuantizedTensor::from_mat(&w, &QuantConfig::int_group(3, 8));
        let e_pc = per_ch.dequantize().sub(&w).frob_sq();
        let e_g = grouped.dequantize().sub(&w).frob_sq();
        assert!(e_g < e_pc * 0.5, "group {e_g} vs per-channel {e_pc}");
    }

    #[test]
    fn group_clamps_to_row_length() {
        let cfg = QuantConfig::int_group(4, 128);
        assert_eq!(cfg.group_len(64), 64);
        assert_eq!(cfg.group_len(256), 128);
    }

    #[test]
    fn bits_per_weight_accounting() {
        let mut rng = Rng::new(4);
        let w = Mat::randn(8, 128, 1.0, &mut rng);
        let qt = QuantizedTensor::from_mat(&w, &QuantConfig::int_group(2, 32));
        // 2 bits + 2 f32 per 32 weights = 2 + 64/32*... = 2 + 2 = 4.
        assert!((qt.bits_per_weight() - 4.0).abs() < 1e-9);
    }
}
