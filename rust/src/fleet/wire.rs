//! Length-prefixed, versioned wire protocol for fleet sweeps.
//!
//! Every frame on the coordinator⇄worker TCP connection is:
//!
//! ```text
//! +------+---------+---------+----------------+
//! | QFLT | version | length  | JSON payload   |
//! | 4 B  | u16 BE  | u32 BE  | `length` bytes |
//! +------+---------+---------+----------------+
//! ```
//!
//! The fixed magic makes a connection from anything that is not a fleet
//! peer (a port scanner, an HTTP client, a different tool) fail
//! immediately with [`WireError::BadMagic`] instead of stalling on a
//! bogus length. The version field rides on **every frame**, not just a
//! handshake, so a mid-stream mix-up (or a proxy splicing connections)
//! still surfaces as [`WireError::VersionMismatch`]. The length prefix is
//! capped at [`MAX_FRAME_LEN`]; anything larger is rejected before a
//! single payload byte is read ([`WireError::Oversized`]) — a garbage
//! length can therefore never trigger a giant allocation. A peer dying
//! mid-frame yields [`WireError::Truncated`]; a clean close between
//! frames yields [`WireError::Closed`], which connection loops treat as
//! normal termination rather than an error.
//!
//! Payloads are single JSON objects (via [`crate::util::json`]) with a
//! `"t"` type tag — see [`Msg`]. JSON keeps the protocol debuggable
//! (`CellRecord` already serializes as JSON for the durable record files,
//! so a `complete` frame embeds the exact line the coordinator will
//! append) and costs nothing measurable next to running a plan cell.

use crate::util::json::Json;
use std::fmt;
use std::io::{Read, Write};

/// Frame magic: first four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"QFLT";

/// Protocol version spoken by this build. Bump on any wire-visible
/// change; peers with a different version refuse each other loudly.
pub const VERSION: u16 = 1;

/// Hard cap on the payload length prefix. Real frames are tiny (a cell
/// id, a heartbeat, one JSONL record line); 4 MiB leaves room for any
/// conceivable record while making a garbage length unmistakable.
pub const MAX_FRAME_LEN: u32 = 4 << 20;

/// Everything that can go wrong reading or decoding a frame. Each
/// variant is a *named*, matchable failure mode — the protocol tests
/// assert on variants, not message strings.
#[derive(Debug)]
pub enum WireError {
    /// The first four bytes were not [`MAGIC`]: the peer is not speaking
    /// the fleet protocol (or the stream lost sync).
    BadMagic([u8; 4]),
    /// The frame's version field differs from ours.
    VersionMismatch { ours: u16, theirs: u16 },
    /// The length prefix exceeds [`MAX_FRAME_LEN`].
    Oversized(u32),
    /// The stream ended mid-frame (peer died while writing).
    Truncated { wanted: usize, got: usize },
    /// The stream closed cleanly between frames (normal peer exit).
    Closed,
    /// The payload was not valid JSON or not a known message shape.
    BadPayload(String),
    /// An underlying socket error (reset, timeout, ...).
    Io(std::io::Error),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::BadMagic(b) => {
                write!(f, "bad frame magic {b:02x?} (peer is not speaking the fleet protocol)")
            }
            WireError::VersionMismatch { ours, theirs } => write!(
                f,
                "protocol version mismatch: we speak v{ours}, peer sent v{theirs}"
            ),
            WireError::Oversized(n) => {
                write!(f, "frame length {n} exceeds the {MAX_FRAME_LEN}-byte cap")
            }
            WireError::Truncated { wanted, got } => {
                write!(f, "stream ended mid-frame ({got}/{wanted} bytes)")
            }
            WireError::Closed => write!(f, "connection closed"),
            WireError::BadPayload(e) => write!(f, "bad frame payload: {e}"),
            WireError::Io(e) => write!(f, "socket error: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> WireError {
        WireError::Io(e)
    }
}

/// One protocol message. The worker speaks first (`Hello`) and then
/// drives a strict request→reply loop; the only unsolicited frames are
/// worker→coordinator `Heartbeat`s, which are one-way (no ack) so they
/// can be fired from a side thread without desynchronizing the reply
/// stream.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Worker → coordinator: first frame on every connection.
    Hello,
    /// Coordinator → worker: handshake reply. `heartbeat_ms` is the
    /// cadence the worker must beat at to keep leases alive.
    Welcome { worker: u64, heartbeat_ms: u64 },
    /// Worker → coordinator: give me a cell.
    Request { worker: u64 },
    /// Coordinator → worker: run this cell under this lease.
    Assign { lease: u64, cell: String },
    /// Coordinator → worker: nothing to hand out. `done: true` means the
    /// sweep is complete (worker exits); `false` means every remaining
    /// cell is leased elsewhere (worker waits and re-requests).
    NoWork { done: bool },
    /// Worker → coordinator (one-way): still working under this lease.
    Heartbeat { lease: u64 },
    /// Worker → coordinator: the cell ran; `record` is the exact
    /// [`crate::io::results::CellRecord`] JSON the coordinator should
    /// persist.
    Complete { lease: u64, record: String },
    /// Coordinator → worker: completion verdict. `accepted: false` with a
    /// reason means the record was dropped (e.g. the cell was reassigned
    /// after a lease expiry and already finished elsewhere — first
    /// durable write wins).
    CompleteAck { accepted: bool, reason: String },
    /// Worker → coordinator: the cell errored; release it for retry.
    Failed { lease: u64, error: String },
    /// Status client → coordinator: report progress.
    StatusReq,
    /// Coordinator → status client: live counters.
    Status { total: u64, done: u64, leased: u64, pending: u64, workers: u64 },
    /// Coordinator → peer: the peer broke protocol; connection will
    /// close. Best-effort (the peer may not even parse it).
    ProtocolError { detail: String },
}

impl Msg {
    fn tag(&self) -> &'static str {
        match self {
            Msg::Hello => "hello",
            Msg::Welcome { .. } => "welcome",
            Msg::Request { .. } => "request",
            Msg::Assign { .. } => "assign",
            Msg::NoWork { .. } => "no_work",
            Msg::Heartbeat { .. } => "heartbeat",
            Msg::Complete { .. } => "complete",
            Msg::CompleteAck { .. } => "complete_ack",
            Msg::Failed { .. } => "failed",
            Msg::StatusReq => "status_req",
            Msg::Status { .. } => "status",
            Msg::ProtocolError { .. } => "protocol_error",
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("t", Json::Str(self.tag().to_string()));
        match self {
            Msg::Hello | Msg::StatusReq => {}
            Msg::Welcome { worker, heartbeat_ms } => {
                o.set("worker", num(*worker)).set("heartbeat_ms", num(*heartbeat_ms));
            }
            Msg::Request { worker } => {
                o.set("worker", num(*worker));
            }
            Msg::Assign { lease, cell } => {
                o.set("lease", num(*lease)).set("cell", Json::Str(cell.clone()));
            }
            Msg::NoWork { done } => {
                o.set("done", Json::Bool(*done));
            }
            Msg::Heartbeat { lease } => {
                o.set("lease", num(*lease));
            }
            Msg::Complete { lease, record } => {
                o.set("lease", num(*lease)).set("record", Json::Str(record.clone()));
            }
            Msg::CompleteAck { accepted, reason } => {
                o.set("accepted", Json::Bool(*accepted))
                    .set("reason", Json::Str(reason.clone()));
            }
            Msg::Failed { lease, error } => {
                o.set("lease", num(*lease)).set("error", Json::Str(error.clone()));
            }
            Msg::Status { total, done, leased, pending, workers } => {
                o.set("total", num(*total))
                    .set("done", num(*done))
                    .set("leased", num(*leased))
                    .set("pending", num(*pending))
                    .set("workers", num(*workers));
            }
            Msg::ProtocolError { detail } => {
                o.set("detail", Json::Str(detail.clone()));
            }
        }
        o
    }

    pub fn from_json(j: &Json) -> Result<Msg, WireError> {
        let tag = j
            .get("t")
            .and_then(Json::as_str)
            .ok_or_else(|| WireError::BadPayload("missing 't' type tag".to_string()))?;
        let u = |key: &str| -> Result<u64, WireError> {
            j.get(key)
                .and_then(Json::as_f64)
                .map(|n| n as u64)
                .ok_or_else(|| WireError::BadPayload(format!("'{tag}' missing '{key}'")))
        };
        let s = |key: &str| -> Result<String, WireError> {
            j.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| WireError::BadPayload(format!("'{tag}' missing '{key}'")))
        };
        let b = |key: &str| -> Result<bool, WireError> {
            match j.get(key) {
                Some(Json::Bool(v)) => Ok(*v),
                _ => Err(WireError::BadPayload(format!("'{tag}' missing '{key}'"))),
            }
        };
        Ok(match tag {
            "hello" => Msg::Hello,
            "welcome" => Msg::Welcome { worker: u("worker")?, heartbeat_ms: u("heartbeat_ms")? },
            "request" => Msg::Request { worker: u("worker")? },
            "assign" => Msg::Assign { lease: u("lease")?, cell: s("cell")? },
            "no_work" => Msg::NoWork { done: b("done")? },
            "heartbeat" => Msg::Heartbeat { lease: u("lease")? },
            "complete" => Msg::Complete { lease: u("lease")?, record: s("record")? },
            "complete_ack" => Msg::CompleteAck { accepted: b("accepted")?, reason: s("reason")? },
            "failed" => Msg::Failed { lease: u("lease")?, error: s("error")? },
            "status_req" => Msg::StatusReq,
            "status" => Msg::Status {
                total: u("total")?,
                done: u("done")?,
                leased: u("leased")?,
                pending: u("pending")?,
                workers: u("workers")?,
            },
            "protocol_error" => Msg::ProtocolError { detail: s("detail")? },
            other => {
                return Err(WireError::BadPayload(format!("unknown message type '{other}'")))
            }
        })
    }
}

fn num(v: u64) -> Json {
    Json::Num(v as f64)
}

/// Encode one frame (header + payload) into a byte vector. Split out
/// from [`write_msg`] so tests can inspect and corrupt exact bytes.
pub fn encode_frame(msg: &Msg) -> Vec<u8> {
    encode_frame_versioned(VERSION, msg.to_json().dump().as_bytes())
}

/// Encode a frame with an explicit version and raw payload — the
/// building block for version-mismatch and garbage-payload tests.
pub fn encode_frame_versioned(version: u16, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(10 + payload.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&version.to_be_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    out.extend_from_slice(payload);
    out
}

/// Write one message as a single frame (one `write_all` of the complete
/// frame, so a concurrently-heartbeating writer thread never interleaves
/// bytes mid-frame as long as writes are mutex-serialized).
pub fn write_msg<W: Write>(w: &mut W, msg: &Msg) -> Result<(), WireError> {
    w.write_all(&encode_frame(msg))?;
    w.flush()?;
    Ok(())
}

/// Read exactly `buf.len()` bytes. Distinguishes the three stream-end
/// shapes: clean close at a frame boundary ([`WireError::Closed`], only
/// when `at_boundary` and zero bytes arrived), death mid-frame
/// ([`WireError::Truncated`]), and socket errors ([`WireError::Io`]).
fn read_exact_frame<R: Read>(
    r: &mut R,
    buf: &mut [u8],
    at_boundary: bool,
) -> Result<(), WireError> {
    let mut got = 0usize;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                return Err(if at_boundary && got == 0 {
                    WireError::Closed
                } else {
                    WireError::Truncated { wanted: buf.len(), got }
                });
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    Ok(())
}

/// Read and decode one frame. Every failure mode is a named
/// [`WireError`]; this function never blocks forever on a malformed
/// header (the length cap bounds the largest read) and never panics on
/// garbage input.
pub fn read_msg<R: Read>(r: &mut R) -> Result<Msg, WireError> {
    let mut magic = [0u8; 4];
    read_exact_frame(r, &mut magic, true)?;
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let mut ver = [0u8; 2];
    read_exact_frame(r, &mut ver, false)?;
    let theirs = u16::from_be_bytes(ver);
    if theirs != VERSION {
        return Err(WireError::VersionMismatch { ours: VERSION, theirs });
    }
    let mut len = [0u8; 4];
    read_exact_frame(r, &mut len, false)?;
    let len = u32::from_be_bytes(len);
    if len > MAX_FRAME_LEN {
        return Err(WireError::Oversized(len));
    }
    let mut payload = vec![0u8; len as usize];
    read_exact_frame(r, &mut payload, false)?;
    let text = std::str::from_utf8(&payload)
        .map_err(|e| WireError::BadPayload(format!("payload not UTF-8: {e}")))?;
    let j = Json::parse(text).map_err(WireError::BadPayload)?;
    Msg::from_json(&j)
}
