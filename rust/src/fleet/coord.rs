//! The fleet coordinator: live lease-based dispatch of plan cells.
//!
//! [`CoordState`] is the deterministic heart — a pure state machine over
//! an explicit millisecond clock (every mutating call takes `now_ms`),
//! so the fault-injection tests drive lease expiry, reassignment, and
//! duplicate rejection with a fake clock instead of real sleeps. The TCP
//! server ([`serve`]) is a thin shell: thread-per-connection handlers
//! translate wire frames into state-machine calls under one mutex.
//!
//! ## Why the record file stays byte-identical to a local run
//!
//! The coordinator owns the single durable record file (the same
//! `<sweep>.shard-1-of-1.jsonl` an unsharded `repro exp <id> --out DIR`
//! run writes) and is the only writer. Three properties make its bytes
//! independent of worker count, assignment interleaving, and kill
//! schedule:
//!
//! 1. **Records are scheduling-free.** A cell's metrics derive from its
//!    identity (name-derived seeds), never from which worker ran it or
//!    when; `--stable-timings` zeroes the one wall-clock field at write
//!    time. Two honest executions of the same cell produce identical
//!    record lines.
//! 2. **First accepted completion wins.** A cell becomes `done` the
//!    moment its first completion is accepted — even one arriving from a
//!    lease that already expired (the work is real; rejecting it to
//!    favor an in-flight reassignment would only discard progress).
//!    Every later completion for that cell is rejected as a duplicate,
//!    so exactly one record per cell ever reaches the file.
//! 3. **Appends are manifest-ordered.** Accepted records stage into an
//!    in-order flush buffer and reach the fsynced [`RecordAppender`]
//!    only when every earlier to-do cell has flushed — the file is at
//!    all times a manifest-order prefix, exactly like the local durable
//!    path. A killed coordinator therefore leaves a file `--resume` can
//!    validate and extend without reordering anything.

use crate::exp::plan::{self, PlanCell};
use crate::fleet::wire::{self, Msg, WireError};
use crate::io::results::{CellRecord, RecordAppender};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Coordinator tuning knobs.
pub struct FleetOpts {
    /// A lease not renewed (heartbeat/completion) within this window is
    /// expired and its cell requeued.
    pub lease_ms: u64,
    /// Zero shard-local wall-clock fields at write time
    /// (`--stable-timings`), for byte-comparable record files.
    pub stable_timings: bool,
    /// Abort the sweep after one cell reports this many worker-side
    /// failures — a deterministic cell error would otherwise requeue
    /// forever.
    pub max_cell_failures: u32,
}

impl Default for FleetOpts {
    fn default() -> FleetOpts {
        FleetOpts { lease_ms: 30_000, stable_timings: false, max_cell_failures: 3 }
    }
}

/// One outstanding assignment.
struct Lease {
    cell: usize,
    worker: u64,
    expires_ms: u64,
}

/// Reply to a work request.
#[derive(Debug, PartialEq, Eq)]
pub enum Assignment {
    /// Run this cell under this lease.
    Cell { lease: u64, id: String },
    /// Every remaining cell is leased elsewhere — ask again shortly.
    Wait,
    /// The sweep is complete.
    Finished,
}

/// Verdict on a completion.
#[derive(Debug, PartialEq, Eq)]
pub enum Verdict {
    /// First completion for the cell: staged for durable append.
    Accepted,
    /// The cell already completed (typically: its lease expired, it was
    /// reassigned, and the other execution finished first). The record
    /// is dropped — first accepted completion wins.
    Duplicate,
    /// The completion is malformed (unknown cell, or a cell that does
    /// not match the named lease) and was dropped.
    Rejected(String),
}

/// Live progress counters (what `exp status --connect` renders).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FleetStatus {
    pub total: usize,
    pub done: usize,
    /// Cells currently out on an unexpired lease.
    pub leased: usize,
    /// Cells neither done nor leased.
    pub pending: usize,
    /// Workers registered and not yet disconnected.
    pub workers: usize,
}

impl FleetStatus {
    pub fn render(&self) -> String {
        format!(
            "[fleet] {}/{} cell(s) done, {} leased, {} unassigned, {} worker(s) connected",
            self.done, self.total, self.leased, self.pending, self.workers
        )
    }
}

/// Manifest-order flush buffer over the durable appender: an accepted
/// record is staged at its rank among the to-do cells and written only
/// once every lower rank has been written — the private `Flush` analog
/// of `exp::common::run_cells_durable`, rebuilt here because the fleet
/// accepts records from the network rather than a local pool.
struct InOrderSink {
    app: RecordAppender,
    stable: bool,
    /// Manifest index → flush rank (position among this run's to-do
    /// cells; resumed-over cells have no rank — they are already on
    /// disk, before every rank-0.. byte this run appends).
    rank: HashMap<usize, usize>,
    next: usize,
    staged: BTreeMap<usize, CellRecord>,
}

impl InOrderSink {
    fn stage(&mut self, idx: usize, mut rec: CellRecord) -> Result<()> {
        if self.stable {
            rec.stabilize();
        }
        let rank = *self.rank.get(&idx).expect("staged cell is in the to-do rank map");
        self.staged.insert(rank, rec);
        while let Some(rec) = self.staged.remove(&self.next) {
            self.app.append(&rec)?;
            self.next += 1;
        }
        Ok(())
    }
}

/// The coordinator state machine. All methods are synchronous and take
/// the current time explicitly; the TCP server calls them under a mutex
/// with a monotonic clock, tests with any clock they like.
pub struct CoordState {
    /// Full manifest, in order (`ids[i]` is cell index `i`).
    ids: Vec<String>,
    index: HashMap<String, usize>,
    /// Cells available for assignment, lowest manifest index first.
    pending: BTreeSet<usize>,
    /// Cells completed (this run or resumed-over from a prior run).
    done: HashSet<usize>,
    leases: HashMap<u64, Lease>,
    /// The currently-active lease per leased cell.
    lease_of_cell: HashMap<usize, u64>,
    workers: HashSet<u64>,
    next_lease: u64,
    next_worker: u64,
    failures: HashMap<usize, u32>,
    opts: FleetOpts,
    sink: InOrderSink,
}

impl CoordState {
    /// Build over a manifest, a resume skip set (cell IDs already durable
    /// in the record file — validated by the caller via the standard
    /// `--resume` path), and the open appender for the record file.
    pub fn new(
        cells: &[PlanCell],
        skip: &HashSet<String>,
        sink: RecordAppender,
        opts: FleetOpts,
    ) -> Result<CoordState> {
        let index = plan::index_manifest(cells)?;
        let ids: Vec<String> = cells.iter().map(|c| c.id()).collect();
        let mut done = HashSet::new();
        let mut pending = BTreeSet::new();
        let mut rank = HashMap::new();
        for (i, id) in ids.iter().enumerate() {
            if skip.contains(id) {
                done.insert(i);
            } else {
                rank.insert(i, rank.len());
                pending.insert(i);
            }
        }
        let stable = opts.stable_timings;
        Ok(CoordState {
            ids,
            index,
            pending,
            done,
            leases: HashMap::new(),
            lease_of_cell: HashMap::new(),
            workers: HashSet::new(),
            next_lease: 0,
            next_worker: 0,
            failures: HashMap::new(),
            opts,
            sink: InOrderSink { app: sink, stable, rank, next: 0, staged: BTreeMap::new() },
        })
    }

    pub fn finished(&self) -> bool {
        self.done.len() == self.ids.len()
    }

    /// Register a connection as a worker; IDs are never reused.
    pub fn register(&mut self) -> u64 {
        self.next_worker += 1;
        self.workers.insert(self.next_worker);
        self.next_worker
    }

    /// Expire every lease whose deadline has passed, requeueing cells
    /// that are not already done. Returns the requeued cell IDs (lowest
    /// manifest index first) for logging.
    pub fn expire(&mut self, now_ms: u64) -> Vec<String> {
        let mut dead: Vec<u64> =
            self.leases.iter().filter(|(_, l)| l.expires_ms <= now_ms).map(|(&n, _)| n).collect();
        dead.sort_unstable();
        let mut requeued = Vec::new();
        for lease in dead {
            if let Some(cell) = self.release(lease) {
                if !self.done.contains(&cell) {
                    self.pending.insert(cell);
                    requeued.push(self.ids[cell].clone());
                }
            }
        }
        requeued.sort();
        requeued
    }

    /// Hand out the lowest-index pending cell under a fresh lease.
    pub fn request(&mut self, worker: u64, now_ms: u64) -> Assignment {
        self.expire(now_ms);
        match self.pending.iter().next().copied() {
            Some(cell) => {
                self.pending.remove(&cell);
                self.next_lease += 1;
                let lease = self.next_lease;
                self.leases
                    .insert(lease, Lease { cell, worker, expires_ms: now_ms + self.opts.lease_ms });
                self.lease_of_cell.insert(cell, lease);
                Assignment::Cell { lease, id: self.ids[cell].clone() }
            }
            None if self.finished() => Assignment::Finished,
            None => Assignment::Wait,
        }
    }

    /// Renew a lease. Returns `false` when the lease is unknown or
    /// already expired — the worker learns its work was reassigned when
    /// its eventual completion comes back `Duplicate` (or `Accepted`, if
    /// it still wins the race).
    pub fn heartbeat(&mut self, lease: u64, now_ms: u64) -> bool {
        self.expire(now_ms);
        match self.leases.get_mut(&lease) {
            Some(l) => {
                l.expires_ms = now_ms + self.opts.lease_ms;
                true
            }
            None => false,
        }
    }

    /// Accept or reject a completed cell. The only `Err` is a durable-
    /// append failure — fatal to the whole sweep (the record file can no
    /// longer make progress). Malformed completions are `Verdict::
    /// Rejected`; repeats of a done cell are `Verdict::Duplicate`.
    pub fn complete(&mut self, lease: u64, rec: CellRecord, _now_ms: u64) -> Result<Verdict> {
        let Some(&idx) = self.index.get(&rec.id) else {
            return Ok(Verdict::Rejected(format!(
                "completion names cell '{}', which is not in this manifest",
                rec.id
            )));
        };
        if let Some(l) = self.leases.get(&lease) {
            if l.cell != idx {
                return Ok(Verdict::Rejected(format!(
                    "lease {lease} is for cell '{}' but the completion names '{}'",
                    self.ids[l.cell], rec.id
                )));
            }
        }
        // A completion under an expired (now unknown) lease is still
        // honored below: the computation is identity-derived, so the
        // record is exactly what the reassigned execution would produce.
        self.release(lease);
        if self.done.contains(&idx) {
            return Ok(Verdict::Duplicate);
        }
        self.pending.remove(&idx);
        self.done.insert(idx);
        self.sink
            .stage(idx, rec)
            .with_context(|| format!("durably appending record for '{}'", self.ids[idx]))?;
        Ok(Verdict::Accepted)
    }

    /// A worker reported a cell error: requeue it, or abort the sweep
    /// once the same cell has failed `max_cell_failures` times (a
    /// deterministic error would requeue forever).
    pub fn fail(&mut self, lease: u64, error: &str, _now_ms: u64) -> Result<()> {
        let Some(cell) = self.release(lease) else {
            return Ok(()); // expired lease; the cell is already requeued
        };
        if self.done.contains(&cell) {
            return Ok(());
        }
        let n = self.failures.entry(cell).or_insert(0);
        *n += 1;
        if *n >= self.opts.max_cell_failures {
            bail!(
                "cell '{}' failed {} time(s), last error: {error} — aborting the sweep \
                 (a deterministic cell error cannot be retried away)",
                self.ids[cell],
                n
            );
        }
        eprintln!(
            "[serve] cell '{}' failed (attempt {}): {error} — requeued",
            self.ids[cell], n
        );
        self.pending.insert(cell);
        Ok(())
    }

    /// A worker's connection ended: drop its registration and requeue
    /// every cell it still holds a live lease on.
    pub fn worker_gone(&mut self, worker: u64) -> Vec<String> {
        self.workers.remove(&worker);
        let mut held: Vec<u64> = self
            .leases
            .iter()
            .filter(|(_, l)| l.worker == worker)
            .map(|(&n, _)| n)
            .collect();
        held.sort_unstable();
        let mut requeued = Vec::new();
        for lease in held {
            if let Some(cell) = self.release(lease) {
                if !self.done.contains(&cell) {
                    self.pending.insert(cell);
                    requeued.push(self.ids[cell].clone());
                }
            }
        }
        requeued.sort();
        requeued
    }

    pub fn status(&self) -> FleetStatus {
        let leased =
            self.leases.values().filter(|l| !self.done.contains(&l.cell)).count();
        FleetStatus {
            total: self.ids.len(),
            done: self.done.len(),
            leased,
            pending: self.pending.len(),
            workers: self.workers.len(),
        }
    }

    /// Drop a lease (if known), returning its cell. Clears the
    /// cell→lease mapping only when this lease is still the active one.
    fn release(&mut self, lease: u64) -> Option<usize> {
        let l = self.leases.remove(&lease)?;
        if self.lease_of_cell.get(&l.cell) == Some(&lease) {
            self.lease_of_cell.remove(&l.cell);
        }
        Some(l.cell)
    }
}

// ---------------------------------------------------------------------
// TCP shell
// ---------------------------------------------------------------------

struct Shared {
    state: Mutex<CoordState>,
    /// First unrecoverable error (append failure, cell out of retries):
    /// the accept loop aborts the sweep with it.
    fatal: Mutex<Option<String>>,
    conns: AtomicUsize,
    lease_ms: u64,
    start: Instant,
}

impl Shared {
    fn now_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }

    fn state(&self) -> MutexGuard<'_, CoordState> {
        // A poisoning panic cannot corrupt CoordState invariants (no
        // method leaves it half-updated across an unwind point we
        // create), so keep serving rather than deadlocking the sweep.
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn set_fatal(&self, msg: String) {
        let mut f = self.fatal.lock().unwrap_or_else(|e| e.into_inner());
        f.get_or_insert(msg);
    }

    fn take_fatal(&self) -> Option<String> {
        self.fatal.lock().unwrap_or_else(|e| e.into_inner()).take()
    }
}

/// Heartbeat cadence handed to workers: several beats per lease window,
/// so one delayed packet never expires a healthy worker.
pub fn heartbeat_interval_ms(lease_ms: u64) -> u64 {
    (lease_ms / 4).max(10)
}

/// Socket read timeout: a fraction of the lease, strictly above the
/// heartbeat interval, so a healthy worker's beats (due every
/// quarter-lease) always land with margin — instead of the read
/// blocking for the full lease window and racing heartbeat delivery
/// against lease expiry. Floored at 100 ms so tiny test leases don't
/// turn every frame gap into a spurious disconnect.
pub fn read_timeout_ms(lease_ms: u64) -> u64 {
    (lease_ms / 3).max(100)
}

/// Run the coordinator over an already-bound listener until every cell
/// is durably recorded (returns `Ok`) or the sweep hits an
/// unrecoverable error. Workers that die mid-cell — missed heartbeats
/// or dropped connections — have their cells requeued automatically.
pub fn serve(listener: TcpListener, state: CoordState, lease_ms: u64) -> Result<()> {
    listener
        .set_nonblocking(true)
        .context("setting the fleet listener non-blocking")?;
    let shared = Arc::new(Shared {
        state: Mutex::new(state),
        fatal: Mutex::new(None),
        conns: AtomicUsize::new(0),
        lease_ms,
        start: Instant::now(),
    });
    loop {
        if let Some(msg) = shared.take_fatal() {
            bail!("{msg}");
        }
        {
            let mut st = shared.state();
            for id in st.expire(shared.now_ms()) {
                eprintln!("[serve] lease expired on '{id}' — requeued");
            }
            if st.finished() {
                break;
            }
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                shared.conns.fetch_add(1, Ordering::SeqCst);
                let sh = Arc::clone(&shared);
                std::thread::spawn(move || {
                    handle_conn(stream, &sh);
                    sh.conns.fetch_sub(1, Ordering::SeqCst);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(e).context("accepting a fleet connection"),
        }
    }
    // Linger briefly so connected workers can pick up NoWork{done} and
    // exit cleanly; stragglers only ever see a closed socket.
    let deadline = Instant::now() + Duration::from_secs(5);
    while shared.conns.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    Ok(())
}

/// Why a connection handler stopped reading.
enum ConnEnd {
    /// Peer closed cleanly between frames.
    Closed,
    /// Peer silent longer than the lease window, or died mid-frame.
    Dead(String),
    /// Peer broke the protocol (bad magic/version/payload...).
    Protocol(String),
}

fn handle_conn(stream: TcpStream, sh: &Shared) {
    stream.set_nodelay(true).ok();
    // A healthy peer is never silent for more than a heartbeat interval
    // (waiting workers re-request, busy workers heartbeat every
    // quarter-lease), so a read timeout just above that cadence doubles
    // as liveness detection for half-dead connections without ever
    // holding the socket for a full lease window.
    stream
        .set_read_timeout(Some(Duration::from_millis(read_timeout_ms(sh.lease_ms))))
        .ok();
    let mut worker: Option<u64> = None;
    let end = conn_loop(&stream, sh, &mut worker);
    if let Some(w) = worker {
        let requeued = sh.state().worker_gone(w);
        for id in &requeued {
            eprintln!("[serve] worker {w} gone — requeued '{id}'");
        }
    }
    match end {
        ConnEnd::Closed => {}
        ConnEnd::Dead(why) => eprintln!("[serve] connection lost: {why}"),
        ConnEnd::Protocol(why) => {
            eprintln!("[serve] protocol error from peer: {why}");
            let mut s = &stream;
            wire::write_msg(&mut s, &Msg::ProtocolError { detail: why }).ok();
        }
    }
}

fn conn_loop(mut stream: &TcpStream, sh: &Shared, worker: &mut Option<u64>) -> ConnEnd {
    loop {
        let msg = match wire::read_msg(&mut stream) {
            Ok(m) => m,
            Err(WireError::Closed) => return ConnEnd::Closed,
            Err(WireError::Io(e))
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                return ConnEnd::Dead(format!(
                    "peer silent past the read timeout ({} ms)",
                    read_timeout_ms(sh.lease_ms)
                ));
            }
            Err(e @ (WireError::Io(_) | WireError::Truncated { .. })) => {
                return ConnEnd::Dead(e.to_string())
            }
            Err(e) => return ConnEnd::Protocol(e.to_string()),
        };
        let reply = match msg {
            Msg::Hello => {
                if worker.is_some() {
                    return ConnEnd::Protocol("second Hello on one connection".to_string());
                }
                let w = sh.state().register();
                *worker = Some(w);
                Some(Msg::Welcome { worker: w, heartbeat_ms: heartbeat_interval_ms(sh.lease_ms) })
            }
            Msg::Request { worker: w } => {
                if *worker != Some(w) {
                    return ConnEnd::Protocol(format!(
                        "request names worker {w} but this connection registered as {:?}",
                        worker
                    ));
                }
                match sh.state().request(w, sh.now_ms()) {
                    Assignment::Cell { lease, id } => Some(Msg::Assign { lease, cell: id }),
                    Assignment::Wait => Some(Msg::NoWork { done: false }),
                    Assignment::Finished => Some(Msg::NoWork { done: true }),
                }
            }
            Msg::Heartbeat { lease } => {
                // One-way: renew (or silently ignore an expired lease —
                // the worker finds out at completion time).
                sh.state().heartbeat(lease, sh.now_ms());
                None
            }
            Msg::Complete { lease, record } => Some(handle_complete(sh, lease, &record)),
            Msg::Failed { lease, error } => {
                match sh.state().fail(lease, &error, sh.now_ms()) {
                    Ok(()) => Some(Msg::CompleteAck {
                        accepted: false,
                        reason: "cell requeued for retry".to_string(),
                    }),
                    Err(e) => {
                        sh.set_fatal(format!("{e:#}"));
                        return ConnEnd::Protocol(format!("{e:#}"));
                    }
                }
            }
            Msg::StatusReq => {
                let s = sh.state().status();
                Some(Msg::Status {
                    total: s.total as u64,
                    done: s.done as u64,
                    leased: s.leased as u64,
                    pending: s.pending as u64,
                    workers: s.workers as u64,
                })
            }
            other => {
                return ConnEnd::Protocol(format!(
                    "unexpected {other:?} frame from a fleet peer"
                ))
            }
        };
        if let Some(reply) = reply {
            if let Err(e) = wire::write_msg(&mut stream, &reply) {
                return ConnEnd::Dead(format!("reply failed: {e}"));
            }
        }
    }
}

fn handle_complete(sh: &Shared, lease: u64, record: &str) -> Msg {
    let rec = crate::util::json::Json::parse(record)
        .map_err(|e| anyhow!("completion payload is not JSON: {e}"))
        .and_then(|j| CellRecord::from_json(&j));
    let rec = match rec {
        Ok(r) => r,
        Err(e) => {
            return Msg::CompleteAck { accepted: false, reason: format!("bad record: {e:#}") }
        }
    };
    let id = rec.id.clone();
    match sh.state().complete(lease, rec, sh.now_ms()) {
        Ok(Verdict::Accepted) => {
            eprintln!("[serve] cell done: {id}");
            Msg::CompleteAck { accepted: true, reason: String::new() }
        }
        Ok(Verdict::Duplicate) => Msg::CompleteAck {
            accepted: false,
            reason: format!(
                "duplicate completion for '{id}' — the cell was reassigned and already \
                 recorded (first accepted completion wins)"
            ),
        },
        Ok(Verdict::Rejected(why)) => Msg::CompleteAck { accepted: false, reason: why },
        Err(e) => {
            // Durable-append failure: the sweep cannot make progress.
            sh.set_fatal(format!("{e:#}"));
            Msg::CompleteAck { accepted: false, reason: format!("fatal: {e:#}") }
        }
    }
}
