//! The fleet worker: a `run_plan_cell` loop driven by coordinator
//! assignments instead of a pre-computed shard slice.
//!
//! The worker is stateless on disk — it never writes records. It
//! connects, says `Hello`, then loops request→run→complete until the
//! coordinator answers `NoWork{done: true}`. While a cell runs, a side
//! thread fires one-way `Heartbeat` frames at the coordinator-announced
//! cadence so a slow-but-alive worker keeps its lease; frame writes go
//! through one mutex so heartbeat and completion frames never interleave
//! bytes. Records are produced with `(shard, n_shards) = (0, 1)` — the
//! same bookkeeping an unsharded local run writes — which is half of the
//! fleet's byte-identity contract (the coordinator's manifest-order
//! append is the other half).

use crate::exp::common::{run_plan_cell, ExpData, ExpEnv};
use crate::exp::plan::PlanCell;
use crate::fleet::wire::{self, Msg, WireError};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

pub struct WorkerCfg {
    /// Coordinator address (`host:port`).
    pub connect: String,
    /// Model artifact directory (random-weights fallback as usual).
    pub artifacts: String,
    /// Keep retrying the initial connect for this long — lets workers
    /// launch before (or while) the coordinator binds its socket.
    pub connect_timeout: Duration,
}

/// Serialize whole frames onto the shared socket: the heartbeat thread
/// and the main loop both write through this.
struct Tx {
    stream: Mutex<TcpStream>,
}

impl Tx {
    fn send(&self, msg: &Msg) -> Result<(), WireError> {
        let guard = self.stream.lock().unwrap_or_else(|e| e.into_inner());
        let mut s = &*guard;
        wire::write_msg(&mut s, msg)
    }
}

fn connect_retry(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + timeout;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(e)
                        .with_context(|| format!("connecting to fleet coordinator at {addr}"));
                }
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

/// Run one worker to sweep completion. Returns the number of cells this
/// worker completed and had accepted.
pub fn run_worker(cfg: &WorkerCfg) -> Result<usize> {
    let stream = connect_retry(&cfg.connect, cfg.connect_timeout)?;
    stream.set_nodelay(true).ok();
    let mut reader = stream.try_clone().context("cloning the fleet socket")?;
    let tx = Arc::new(Tx { stream: Mutex::new(stream) });

    tx.send(&Msg::Hello).map_err(wire_err)?;
    let (worker, heartbeat_ms) = match wire::read_msg(&mut reader).map_err(wire_err)? {
        Msg::Welcome { worker, heartbeat_ms } => (worker, heartbeat_ms.max(1)),
        Msg::ProtocolError { detail } => bail!("coordinator rejected the handshake: {detail}"),
        other => bail!("expected Welcome from the coordinator, got {other:?}"),
    };
    eprintln!("[work] registered as worker {worker} with {}", cfg.connect);

    let mut env = ExpEnv::new(&cfg.artifacts);
    let mut snapshots: HashMap<String, ExpData> = HashMap::new();
    let mut completed = 0usize;
    loop {
        tx.send(&Msg::Request { worker }).map_err(wire_err)?;
        match wire::read_msg(&mut reader).map_err(wire_err)? {
            Msg::Assign { lease, cell } => {
                let pc = PlanCell::parse(&cell).ok_or_else(|| {
                    anyhow!("coordinator assigned unparseable cell id '{cell}'")
                })?;
                let size = pc.size();
                let data = snapshots
                    .entry(size.name().to_string())
                    .or_insert_with(|| env.snapshot(&[size]));
                let outcome = run_leased_cell(&tx, lease, heartbeat_ms, data, &pc);
                let reply = match outcome {
                    Ok(rec) => {
                        Msg::Complete { lease, record: rec.to_json().dump() }
                    }
                    Err(e) => Msg::Failed { lease, error: format!("{e:#}") },
                };
                let ran_ok = matches!(reply, Msg::Complete { .. });
                tx.send(&reply).map_err(wire_err)?;
                match wire::read_msg(&mut reader).map_err(wire_err)? {
                    Msg::CompleteAck { accepted: true, .. } => {
                        completed += 1;
                        eprintln!("[work] cell done: {cell}");
                    }
                    Msg::CompleteAck { accepted: false, reason } => {
                        if ran_ok {
                            eprintln!("[work] completion for '{cell}' not recorded: {reason}");
                        } else {
                            eprintln!("[work] cell '{cell}' failed here: {reason}");
                        }
                    }
                    Msg::ProtocolError { detail } => {
                        bail!("coordinator aborted the connection: {detail}")
                    }
                    other => bail!("expected CompleteAck, got {other:?}"),
                }
            }
            Msg::NoWork { done: true } => break,
            Msg::NoWork { done: false } => {
                // Everything left is leased elsewhere; poll again soon
                // (also keeps the connection visibly alive).
                std::thread::sleep(Duration::from_millis(heartbeat_ms));
            }
            Msg::ProtocolError { detail } => bail!("coordinator aborted: {detail}"),
            other => bail!("unexpected {other:?} from the coordinator"),
        }
    }
    if env.used_fallback {
        eprintln!(
            "[work] NOTE: ran with RANDOM weights (artifacts missing). Results are \
             structural only."
        );
    }
    Ok(completed)
}

/// Run one cell with a heartbeat side-thread keeping its lease alive.
fn run_leased_cell(
    tx: &Arc<Tx>,
    lease: u64,
    heartbeat_ms: u64,
    data: &ExpData,
    pc: &PlanCell,
) -> Result<crate::io::results::CellRecord> {
    let stop = Arc::new(AtomicBool::new(false));
    let beat = {
        let tx = Arc::clone(tx);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(heartbeat_ms));
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                // Send errors are left to the main loop's next read.
                if tx.send(&Msg::Heartbeat { lease }).is_err() {
                    break;
                }
            }
        })
    };
    let result = run_plan_cell(data, pc, 0, 1);
    stop.store(true, Ordering::Relaxed);
    beat.join().ok();
    result
}

fn wire_err(e: WireError) -> anyhow::Error {
    match e {
        WireError::Closed => anyhow!(
            "coordinator closed the connection (killed mid-sweep? restart it over the same \
             --out dir with --resume, then relaunch workers)"
        ),
        other => anyhow!("fleet protocol failure: {other}"),
    }
}
