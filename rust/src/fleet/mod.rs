//! Fleet sweeps: a live TCP coordinator/worker pair for distributed
//! experiment runs (`repro exp serve <id>` / `repro exp work`).
//!
//! The shared-filesystem shard runner (`repro exp --shard i/N`) splits a
//! sweep *statically*: each process owns a fixed manifest slice, and a
//! dead shard stays dead until a human resumes it. The fleet promotes
//! that workflow into a self-supervising service over `std::net`:
//!
//! * [`wire`] — a length-prefixed, versioned frame protocol whose
//!   failure modes (garbage, truncation, version skew, oversized
//!   frames) are all named errors, never hangs or panics;
//! * [`coord`] — the coordinator: a fake-clock-testable lease/heartbeat
//!   state machine dispatching [`crate::exp::plan::PlanCell`] IDs,
//!   requeueing cells from dead workers, rejecting late duplicate
//!   completions (first accepted completion wins), and appending
//!   records in manifest order through the fsynced
//!   [`crate::io::results::RecordAppender`] durability path;
//! * [`worker`] — the worker: a `run_plan_cell` loop with a heartbeat
//!   side-thread, producing records bit-identical to a local run's.
//!
//! The determinism contract extends the sharded one: **any worker
//! count, assignment interleaving, or kill schedule merges to
//! byte-identical record files and renders versus an unsharded local
//! run** (with `--stable-timings`; `tests/cli_fleet.rs` and the CI
//! `fleet-kill-resume` job enforce it cross-process, SIGKILLs included).

pub mod coord;
pub mod wire;
pub mod worker;
