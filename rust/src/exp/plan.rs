//! The experiment **plan layer**: every sweep (`table1/2/3/4`,
//! `ablation-alpha`, `fig2`, `fig3`, `appendix`, `all`) enumerates to a
//! stable, ordered **manifest** of [`PlanCell`]s before anything runs.
//! This is what turns the monolithic sweep drivers into three composable
//! stages — *enumerate → run → render* — and what a distributed runner
//! needs: cell identities are strings ([`PlanCell::id`]) that round-trip
//! through [`PlanCell::parse`], so a cell can be named, shipped to
//! another process/machine, executed there, and collected back purely by
//! ID.
//!
//! Sharding model: shard `i` of `N` (1-based) owns exactly the manifest
//! entries whose 0-based index `j` satisfies `j % N == i - 1`
//! ([`shard_of`]). Assignment depends only on the manifest order — which
//! is fixed per (sweep, [`PlanParams`]) — so any split of the same plan
//! covers every cell exactly once ([`verify_coverage`] enforces this at
//! merge time). Because every cell's seed derives from its own identity
//! (see `common::Cell::derived_seed`), *results* are independent of the
//! split: the merged render is byte-identical to the single-process
//! sweep for every `N`.

use super::common::Cell;
use crate::eval::TaskFamily;
use crate::io::results::CellRecord;
use crate::model::Size;
use crate::quant::{Alloc, BitBudget, BudgetSpec, Method, QuantConfig};
use crate::text::Flavor;
use crate::util::cli::Args;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

/// Which experiment sweep a cell (or a CLI invocation) belongs to.
/// `Table12` covers the shared-cell drivers fig1/table1/table2;
/// `Appendix` covers tables 5–10 (one cell matrix feeds all six).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SweepId {
    Table12,
    Table3,
    Table4,
    AblationAlpha,
    Fig2,
    Fig3,
    Appendix,
    Lowrank,
    Budget,
    Cbq,
    All,
}

impl SweepId {
    /// Canonical name — also the prefix of this sweep's cell IDs and the
    /// stem of its shard record files.
    pub fn name(self) -> &'static str {
        match self {
            SweepId::Table12 => "table12",
            SweepId::Table3 => "table3",
            SweepId::Table4 => "table4",
            SweepId::AblationAlpha => "ablation-alpha",
            SweepId::Fig2 => "fig2",
            SweepId::Fig3 => "fig3",
            SweepId::Appendix => "appendix",
            SweepId::Lowrank => "lowrank",
            SweepId::Budget => "budget",
            SweepId::Cbq => "cbq",
            SweepId::All => "all",
        }
    }

    /// Accepts every CLI alias (`fig1`/`table1`/`table2` share cells, as
    /// do `table5`..`table10`/`appendix`).
    pub fn from_name(s: &str) -> Option<SweepId> {
        match s {
            "fig1" | "table1" | "table2" | "table12" => Some(SweepId::Table12),
            "table3" => Some(SweepId::Table3),
            "table4" => Some(SweepId::Table4),
            "ablation-alpha" => Some(SweepId::AblationAlpha),
            "fig2" => Some(SweepId::Fig2),
            "fig3" => Some(SweepId::Fig3),
            "appendix" | "table5" | "table6" | "table7" | "table8" | "table9" | "table10" => {
                Some(SweepId::Appendix)
            }
            "lowrank" | "lqer" | "qera" => Some(SweepId::Lowrank),
            "budget" | "mixed" | "mixed-precision" => Some(SweepId::Budget),
            "cbq" | "cross-block" => Some(SweepId::Cbq),
            "all" => Some(SweepId::All),
            _ => None,
        }
    }

    /// The concrete sweeps `all` expands to, in execution order.
    pub fn all_parts() -> [SweepId; 9] {
        [
            SweepId::Table12,
            SweepId::Table3,
            SweepId::Table4,
            SweepId::Fig2,
            SweepId::Fig3,
            SweepId::Appendix,
            SweepId::Lowrank,
            SweepId::Budget,
            SweepId::Cbq,
        ]
    }

    /// Timed sweeps run their cells serially (Table 3 measures per-cell
    /// wall-clock; concurrent cells would contend for cores).
    pub fn timed(self) -> bool {
        self == SweepId::Table3
    }
}

/// Metrics a sweep computes per quantized cell: perplexity eval flavors
/// and zero-shot task families. Derived from the sweep (not stored per
/// cell) so a cell ID alone fully determines the work.
pub fn wants(sweep: SweepId) -> (Vec<Flavor>, Vec<TaskFamily>) {
    match sweep {
        SweepId::Table12 => (vec![Flavor::Wiki], TaskFamily::all().to_vec()),
        SweepId::Appendix => (Flavor::all().to_vec(), TaskFamily::all().to_vec()),
        SweepId::Table4
        | SweepId::AblationAlpha
        | SweepId::Lowrank
        | SweepId::Budget
        | SweepId::Cbq => (vec![Flavor::Wiki], vec![]),
        SweepId::Fig3 => (vec![Flavor::Wiki], TaskFamily::all().to_vec()),
        SweepId::Table3 | SweepId::Fig2 | SweepId::All => (vec![], vec![]),
    }
}

/// The main-text settings of tables 1/2 (INT4/3/2 per-channel).
pub fn table12_settings() -> Vec<QuantConfig> {
    vec![QuantConfig::int(4), QuantConfig::int(3), QuantConfig::int(2)]
}

/// The methods of the appendix tables (5–10).
pub fn appendix_methods() -> [Method; 3] {
    [Method::Rtn, Method::Gptq, Method::Awq]
}

/// The α grid of the propagation-strength ablation.
pub fn ablation_alphas() -> [f32; 5] {
    [0.0, 0.25, 0.5, 0.75, 1.0]
}

/// The methods of the low-rank reconstruction sweep (LQER/QERA family).
pub fn lowrank_methods() -> [Method; 2] {
    [Method::Rtn, Method::Gptq]
}

/// The methods of the mixed-precision budget sweep.
pub fn budget_methods() -> [Method; 2] {
    [Method::Rtn, Method::Gptq]
}

/// The methods of the cross-block (CBQ) sweep: one whose base objective
/// is provably invariant under window refinement (GPTQ calibrates on the
/// quantized stream, so its `base` rows are flat across windows — an
/// in-table correctness anchor) and one that genuinely recalibrates on
/// the window's local full-precision reference (AWQ).
pub fn cbq_methods() -> [Method; 2] {
    [Method::Gptq, Method::Awq]
}

/// The window segment of a cbq cell ID: `w{W}`. Window 1 — the
/// layer-wise baseline row — is enumerated and rendered like any other.
pub fn window_name(window: usize) -> String {
    format!("w{window}")
}

/// Inverse of [`window_name`]. Strict — rejects `w0`, empty digits, and
/// leading zeros so `parse ∘ id` stays the identity.
fn parse_window(s: &str) -> Option<usize> {
    let digits = s.strip_prefix('w')?;
    let window: usize = digits.parse().ok()?;
    if window == 0 || digits != window.to_string() {
        return None;
    }
    Some(window)
}

/// The variant segment of an allocated budget cell ID: the allocator
/// name, `+qep`-suffixed when QEP is on (`dp`, `dp+qep`, `greedy`, …).
/// Uniform-floor baseline rows use the separate `budget/uni/...` ID form
/// (see [`PlanCell::id`]), never a variant.
pub fn budget_variant_name(alloc: Alloc, qep: bool) -> String {
    if qep {
        format!("{}+qep", alloc.name())
    } else {
        alloc.name().to_string()
    }
}

/// Inverse of [`budget_variant_name`]: `(alloc, qep)`.
fn parse_budget_variant(s: &str) -> Option<(Alloc, bool)> {
    let (name, qep) = match s.strip_suffix("+qep") {
        Some(n) => (n, true),
        None => (s, false),
    };
    Alloc::from_name(name).map(|a| (a, qep))
}

/// The variant segment of a lowrank cell ID: `base`, `+qep`, `+lr{r}`,
/// or `+qep+lr{r}`. Rank 0 (no adjunct) renders as the plain ±QEP
/// variant — `+lr0` is never emitted and never parses.
pub fn variant_name(qep: bool, rank: usize) -> String {
    match (qep, rank) {
        (false, 0) => "base".to_string(),
        (true, 0) => "+qep".to_string(),
        (false, r) => format!("+lr{r}"),
        (true, r) => format!("+qep+lr{r}"),
    }
}

/// Inverse of [`variant_name`]: `(qep, rank)`. Strict — rejects `+lr0`,
/// empty ranks, and leading zeros so `parse ∘ id` stays the identity.
fn parse_variant(s: &str) -> Option<(bool, usize)> {
    if let Some(qep) = parse_qep(s) {
        return Some((qep, 0));
    }
    let (qep, digits) = if let Some(d) = s.strip_prefix("+qep+lr") {
        (true, d)
    } else if let Some(d) = s.strip_prefix("+lr") {
        (false, d)
    } else {
        return None;
    };
    let rank: usize = digits.parse().ok()?;
    if rank == 0 || digits != rank.to_string() {
        return None;
    }
    Some((qep, rank))
}

/// Everything that parameterizes a plan besides the sweep ID. Two
/// processes that build a `PlanParams` from the same CLI flags (see
/// [`PlanParams::from_args`]) enumerate the identical manifest — the
/// contract the shard executor and the merge collector rely on.
#[derive(Clone, Debug)]
pub struct PlanParams {
    pub sizes: Vec<Size>,
    /// Table 4's single model size (first of `sizes`).
    pub table4_size: Size,
    /// Fig. 2's model size (standalone: first of `sizes`; under `all`:
    /// the second, to match the historical driver).
    pub fig2_size: Size,
    pub fig2_bits: u32,
    /// Resolved number of leading blocks Fig. 2 quantizes.
    pub fig2_blocks: usize,
    pub fig3_bits: Vec<u32>,
    pub fig3_seeds: u64,
    pub appendix_settings: Vec<QuantConfig>,
    /// Non-zero adjunct ranks of the lowrank sweep (rank 0 — no adjunct
    /// — is always enumerated in addition, as the `base`/`+qep` rows).
    pub lowrank_ranks: Vec<usize>,
    pub lowrank_settings: Vec<QuantConfig>,
    /// Average-bits budgets of the mixed-precision sweep. Uniform
    /// `INT⌊B⌋` baselines are enumerated alongside (deduped across
    /// budgets sharing a floor) so every budget row reads against a
    /// same-calibration uniform reference.
    pub budgets: Vec<BitBudget>,
    /// Cross-block window sizes of the cbq sweep. Window 1 is the
    /// layer-wise baseline row every wider window is read against, so
    /// the defaults always include it.
    pub cbq_windows: Vec<usize>,
}

impl PlanParams {
    /// Defaults for a size list (full-scale knobs everywhere). Callers
    /// tweak fields before planning; `from_args` mirrors the CLI.
    pub fn for_sizes(sizes: &[Size]) -> PlanParams {
        let first = sizes.first().copied().unwrap_or(Size::TinyS);
        let fig2_size = sizes.first().copied().unwrap_or(Size::TinyM);
        PlanParams {
            sizes: sizes.to_vec(),
            table4_size: first,
            fig2_size,
            fig2_bits: 3,
            fig2_blocks: resolve_fig2_blocks(fig2_size, None),
            fig3_bits: vec![4, 3, 2],
            fig3_seeds: 5,
            appendix_settings: QuantConfig::appendix_settings(),
            lowrank_ranks: vec![4, 16],
            lowrank_settings: vec![QuantConfig::int(3), QuantConfig::int(2)],
            budgets: vec![
                BitBudget::from_decibits(25),
                BitBudget::from_decibits(30),
                BitBudget::from_decibits(35),
            ],
            cbq_windows: vec![1, 2, 3],
        }
    }

    /// Build the plan parameters exactly the way the CLI drivers always
    /// have: `--sizes`/`--fast` pick the model list; Fig. 2 reads
    /// `--bits`/`--blocks` when run standalone but is pinned to
    /// (second size, INT3, half the blocks) under `all`; Fig. 3 reads
    /// `--seeds` standalone and uses the fast/full default under `all`;
    /// the appendix grid shrinks to two settings under `--fast`.
    pub fn from_args(sweep: SweepId, args: &Args) -> Result<PlanParams> {
        let fast = args.has("fast");
        let sizes: Vec<Size> = match args.get("sizes") {
            Some(spec) => {
                // Every name must resolve: a typo'd size silently shrinking
                // a sharded manifest is exactly the class of bug strict
                // flag handling exists to prevent.
                spec.split(',')
                    .map(|tok| {
                        Size::from_name(tok).ok_or_else(|| {
                            anyhow!(
                                "--sizes: unknown size '{tok}' (want s,m,l / tiny-s,tiny-m,tiny-l)"
                            )
                        })
                    })
                    .collect::<Result<Vec<Size>>>()?
            }
            None => {
                if fast {
                    vec![Size::TinyS]
                } else {
                    Size::all().to_vec()
                }
            }
        };
        let mut p = PlanParams::for_sizes(&sizes);
        if sweep == SweepId::All {
            // Historical `all` driver: fig2 runs on the second size at
            // INT3/default blocks; fig3 ignores --seeds.
            p.fig2_size = sizes.get(1).copied().unwrap_or(sizes[0]);
            p.fig2_bits = 3;
            p.fig2_blocks = resolve_fig2_blocks(p.fig2_size, None);
            p.fig3_seeds = if fast { 2 } else { 5 };
        } else {
            // Strict numeric flags: a typo'd value must error, never
            // silently fall back to a default manifest.
            p.fig2_bits = parse_flag(args, "bits", 3u32)?;
            let blocks: Option<usize> = args
                .get("blocks")
                .map(|b| b.parse())
                .transpose()
                .map_err(|_| anyhow!("--blocks expects an integer"))?;
            p.fig2_blocks = resolve_fig2_blocks(p.fig2_size, blocks);
            p.fig3_seeds = parse_flag(args, "seeds", if fast { 2u64 } else { 5 })?;
        }
        p.fig3_bits = if fast { vec![3] } else { vec![4, 3, 2] };
        p.appendix_settings = if fast {
            vec![QuantConfig::int(3), QuantConfig::int_group(2, 32)]
        } else {
            QuantConfig::appendix_settings()
        };
        if fast {
            p.lowrank_ranks = vec![2];
            p.lowrank_settings = vec![QuantConfig::int(3)];
            p.budgets = vec![BitBudget::from_decibits(25)];
            p.cbq_windows = vec![1, 2];
        }
        if let Some(spec) = args.get("budgets") {
            // Strict like --sizes/--ranks: every token must be a valid
            // in-range budget, and duplicates are rejected (they would
            // enumerate duplicate cell IDs).
            let mut budgets = Vec::new();
            for tok in spec.split(',') {
                let b = BitBudget::parse(tok).ok_or_else(|| {
                    anyhow!("--budgets expects averages like 2.5,3.0 (one decimal), got '{tok}'")
                })?;
                crate::quant::budget::check_feasible(b)?;
                if budgets.contains(&b) {
                    bail!("--budgets lists {} twice", b.render());
                }
                budgets.push(b);
            }
            p.budgets = budgets;
        }
        if let Some(spec) = args.get("windows") {
            // Strict like --budgets: every token must be a positive
            // integer, and duplicates are rejected (they would enumerate
            // duplicate cell IDs).
            let mut windows = Vec::new();
            for tok in spec.split(',') {
                let w: usize = match tok.parse() {
                    Ok(w) if w > 0 => w,
                    _ => bail!("--windows expects positive integers like 1,2,4, got '{tok}'"),
                };
                if windows.contains(&w) {
                    bail!("--windows lists {w} twice");
                }
                windows.push(w);
            }
            p.cbq_windows = windows;
        }
        if let Some(spec) = args.get("ranks") {
            // Same strictness as --sizes: every token must be a positive
            // integer (rank 0 is always implied as the base/+qep rows).
            p.lowrank_ranks = spec
                .split(',')
                .map(|tok| match tok.parse::<usize>() {
                    Ok(r) if r > 0 => Ok(r),
                    _ => Err(anyhow!("--ranks expects positive integers, got '{tok}'")),
                })
                .collect::<Result<Vec<usize>>>()?;
        }
        Ok(p)
    }
}

/// Parse an integer flag strictly: absent → default, present-but-bad →
/// error (never a silent default — it would change the planned manifest).
fn parse_flag<T: std::str::FromStr>(args: &Args, key: &str, default: T) -> Result<T> {
    match args.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got '{v}'")),
    }
}

/// Fig. 2 quantizes the first `n` blocks (default: half the model).
pub fn resolve_fig2_blocks(size: Size, requested: Option<usize>) -> usize {
    let n_layers = size.config().n_layers;
    requested.unwrap_or(n_layers / 2).min(n_layers)
}

/// The work a single manifest entry stands for. `Quant` covers every
/// sweep whose unit is "quantize a [`Cell`], then measure"; the α
/// ablation and Fig. 2 need pipeline overrides a plain `Cell` cannot
/// express, so they carry their own variants.
#[derive(Clone, Debug, PartialEq)]
pub enum CellTask {
    Quant(Cell),
    /// RTN INT3 with an explicit uniform propagation strength α.
    Alpha { size: Size, alpha: f32 },
    /// Quantize the first `n_blocks` blocks with RTN INT`bits`, ±QEP,
    /// and record the per-block error deltas Δ_m.
    Fig2 { size: Size, bits: u32, n_blocks: usize, qep: bool },
}

/// One enumerated unit of sweep work: a sweep tag plus its task. The
/// string form ([`PlanCell::id`]) is the cell's identity everywhere —
/// in shard record files, in merge coverage checks, on the CLI.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanCell {
    pub sweep: SweepId,
    pub task: CellTask,
}

fn qep_str(qep: bool) -> &'static str {
    if qep {
        "+qep"
    } else {
        "base"
    }
}

fn parse_qep(s: &str) -> Option<bool> {
    match s {
        "+qep" => Some(true),
        "base" => Some(false),
        _ => None,
    }
}

impl PlanCell {
    /// Stable, human-readable cell identity. Round-trips through
    /// [`PlanCell::parse`]: `parse(c.id()) == Some(c)` for every
    /// manifest cell (gated by `rust/tests/exp_plan.rs`).
    pub fn id(&self) -> String {
        match (&self.sweep, &self.task) {
            (SweepId::Table12, CellTask::Quant(c)) | (SweepId::Appendix, CellTask::Quant(c)) => {
                format!(
                    "{}/{}/{}/{}/{}",
                    self.sweep.name(),
                    c.quant.label(),
                    c.method.name(),
                    qep_str(c.qep),
                    c.size.name()
                )
            }
            (SweepId::Table3, CellTask::Quant(c)) => {
                format!("table3/{}/{}/{}", c.method.name(), qep_str(c.qep), c.size.name())
            }
            (SweepId::Table4, CellTask::Quant(c)) => format!(
                "table4/{}/{}/{}/{}",
                c.method.name(),
                qep_str(c.qep),
                c.calib_flavor.name(),
                c.size.name()
            ),
            (SweepId::Fig3, CellTask::Quant(c)) => format!(
                "fig3/{}/{}/{}/s{}",
                c.quant.label(),
                c.size.name(),
                qep_str(c.qep),
                c.seed
            ),
            (SweepId::AblationAlpha, CellTask::Alpha { size, alpha }) => {
                format!("ablation-alpha/a{alpha:.2}/{}", size.name())
            }
            (SweepId::Fig2, CellTask::Fig2 { size, bits, n_blocks, qep }) => {
                format!("fig2/{}/INT{bits}/b{n_blocks}/{}", size.name(), qep_str(*qep))
            }
            (SweepId::Lowrank, CellTask::Quant(c)) => format!(
                "lowrank/{}/{}/{}/{}",
                c.quant.label(),
                c.method.name(),
                variant_name(c.qep, c.lowrank_rank),
                c.size.name()
            ),
            // Allocated budget cells carry the budget in the ID (the cell
            // stores it); uniform floor baselines are budget-free cells
            // shared across every budget with the same ⌊B⌋, so their ID
            // names the grid, not a budget.
            (SweepId::Budget, CellTask::Quant(c)) => match c.budget {
                Some(spec) => format!(
                    "budget/{}/{}/{}/{}",
                    spec.budget.render(),
                    c.method.name(),
                    budget_variant_name(spec.alloc, c.qep),
                    c.size.name()
                ),
                None => format!(
                    "budget/uni/{}/{}/{}/{}",
                    c.quant.label(),
                    c.method.name(),
                    qep_str(c.qep),
                    c.size.name()
                ),
            },
            (SweepId::Cbq, CellTask::Quant(c)) => format!(
                "cbq/{}/{}/{}/{}/{}",
                c.quant.label(),
                c.method.name(),
                window_name(c.cbq_window),
                qep_str(c.qep),
                c.size.name()
            ),
            (sweep, task) => unreachable!("no ID form for {sweep:?} / {task:?}"),
        }
    }

    /// Inverse of [`PlanCell::id`]. Returns `None` for anything that is
    /// not a well-formed cell ID (the ID alone fully determines the
    /// work; no plan parameters needed).
    pub fn parse(id: &str) -> Option<PlanCell> {
        let p: Vec<&str> = id.split('/').collect();
        match p.as_slice() {
            ["table12", q, m, e, s] | ["appendix", q, m, e, s] => {
                let sweep =
                    if p[0] == "table12" { SweepId::Table12 } else { SweepId::Appendix };
                let cell = Cell::new(
                    Size::from_name(s)?,
                    Method::from_name(m)?,
                    QuantConfig::from_label(q)?,
                    parse_qep(e)?,
                );
                Some(PlanCell { sweep, task: CellTask::Quant(cell) })
            }
            ["table3", m, e, s] => {
                let cell = Cell::new(
                    Size::from_name(s)?,
                    Method::from_name(m)?,
                    QuantConfig::int(3),
                    parse_qep(e)?,
                );
                Some(PlanCell { sweep: SweepId::Table3, task: CellTask::Quant(cell) })
            }
            ["table4", m, e, f, s] => {
                let mut cell = Cell::new(
                    Size::from_name(s)?,
                    Method::from_name(m)?,
                    QuantConfig::int(3),
                    parse_qep(e)?,
                );
                cell.calib_flavor = Flavor::from_name(f)?;
                Some(PlanCell { sweep: SweepId::Table4, task: CellTask::Quant(cell) })
            }
            ["fig3", q, s, e, seed] => {
                let mut cell = Cell::new(
                    Size::from_name(s)?,
                    Method::Quip,
                    QuantConfig::from_label(q)?,
                    parse_qep(e)?,
                );
                cell.seed = seed.strip_prefix('s')?.parse().ok()?;
                Some(PlanCell { sweep: SweepId::Fig3, task: CellTask::Quant(cell) })
            }
            ["ablation-alpha", a, s] => Some(PlanCell {
                sweep: SweepId::AblationAlpha,
                task: CellTask::Alpha {
                    size: Size::from_name(s)?,
                    alpha: a.strip_prefix('a')?.parse().ok()?,
                },
            }),
            ["fig2", s, q, b, e] => Some(PlanCell {
                sweep: SweepId::Fig2,
                task: CellTask::Fig2 {
                    size: Size::from_name(s)?,
                    bits: q.strip_prefix("INT")?.parse().ok()?,
                    n_blocks: b.strip_prefix('b')?.parse().ok()?,
                    qep: parse_qep(e)?,
                },
            }),
            ["lowrank", q, m, v, s] => {
                let (qep, rank) = parse_variant(v)?;
                let mut cell = Cell::new(
                    Size::from_name(s)?,
                    Method::from_name(m)?,
                    QuantConfig::from_label(q)?,
                    qep,
                );
                cell.lowrank_rank = rank;
                Some(PlanCell { sweep: SweepId::Lowrank, task: CellTask::Quant(cell) })
            }
            ["cbq", q, m, w, e, s] => {
                let mut cell = Cell::new(
                    Size::from_name(s)?,
                    Method::from_name(m)?,
                    QuantConfig::from_label(q)?,
                    parse_qep(e)?,
                );
                cell.cbq_window = parse_window(w)?;
                Some(PlanCell { sweep: SweepId::Cbq, task: CellTask::Quant(cell) })
            }
            ["budget", "uni", q, m, e, s] => {
                let cell = Cell::new(
                    Size::from_name(s)?,
                    Method::from_name(m)?,
                    QuantConfig::from_label(q)?,
                    parse_qep(e)?,
                );
                Some(PlanCell { sweep: SweepId::Budget, task: CellTask::Quant(cell) })
            }
            ["budget", b, m, v, s] => {
                // Strict budget syntax (`parse_strict`): "2.5" round-trips,
                // "2.50"/"3" do not — `parse ∘ id` must stay the identity.
                // Out-of-range budgets can never be manifest cells (the
                // planner feasibility-checks them), so they don't parse.
                let budget = BitBudget::parse_strict(b)?;
                crate::quant::budget::check_feasible(budget).ok()?;
                let (alloc, qep) = parse_budget_variant(v)?;
                let mut cell = Cell::new(
                    Size::from_name(s)?,
                    Method::from_name(m)?,
                    QuantConfig::int(budget.floor_bits()),
                    qep,
                );
                cell.budget = Some(BudgetSpec { budget, alloc });
                Some(PlanCell { sweep: SweepId::Budget, task: CellTask::Quant(cell) })
            }
            _ => None,
        }
    }

    /// The model size this cell quantizes.
    pub fn size(&self) -> Size {
        match &self.task {
            CellTask::Quant(c) => c.size,
            CellTask::Alpha { size, .. } => *size,
            CellTask::Fig2 { size, .. } => *size,
        }
    }
}

/// Enumerate the stable, ordered manifest for a sweep. The order is the
/// historical driver order (settings-major matrices; `all` concatenates
/// its parts in run order) and is part of the sharding contract: shard
/// assignment is by manifest index.
pub fn manifest(sweep: SweepId, params: &PlanParams) -> Result<Vec<PlanCell>> {
    if params.sizes.is_empty() {
        bail!("experiment plan needs at least one model size");
    }
    let mut cells = Vec::new();
    match sweep {
        SweepId::Table12 => {
            quant_matrix(
                &mut cells,
                SweepId::Table12,
                &params.sizes,
                &table12_settings(),
                &Method::all(),
            );
        }
        SweepId::Table3 => {
            for (method, qep) in [(Method::Gptq, false), (Method::Awq, false), (Method::Rtn, true)]
            {
                for &s in &params.sizes {
                    cells.push(PlanCell {
                        sweep: SweepId::Table3,
                        task: CellTask::Quant(Cell::new(s, method, QuantConfig::int(3), qep)),
                    });
                }
            }
        }
        SweepId::Table4 => {
            let size = params.table4_size;
            let q = QuantConfig::int(3);
            // The calibration-free RTN reference first, then method ×
            // calibration flavor (the table's six delta cells).
            cells.push(PlanCell {
                sweep: SweepId::Table4,
                task: CellTask::Quant(Cell::new(size, Method::Rtn, q, false)),
            });
            for (method, qep) in [(Method::Gptq, false), (Method::Rtn, true)] {
                for fl in [Flavor::C4, Flavor::Ptb, Flavor::Wiki] {
                    let mut cell = Cell::new(size, method, q, qep);
                    cell.calib_flavor = fl;
                    cells.push(PlanCell { sweep: SweepId::Table4, task: CellTask::Quant(cell) });
                }
            }
        }
        SweepId::AblationAlpha => {
            for &a in &ablation_alphas() {
                for &s in &params.sizes {
                    cells.push(PlanCell {
                        sweep: SweepId::AblationAlpha,
                        task: CellTask::Alpha { size: s, alpha: a },
                    });
                }
            }
        }
        SweepId::Fig2 => {
            for qep in [false, true] {
                cells.push(PlanCell {
                    sweep: SweepId::Fig2,
                    task: CellTask::Fig2 {
                        size: params.fig2_size,
                        bits: params.fig2_bits,
                        n_blocks: params.fig2_blocks,
                        qep,
                    },
                });
            }
        }
        SweepId::Fig3 => {
            for &bits in &params.fig3_bits {
                for &size in &params.sizes {
                    for qep in [false, true] {
                        for seed in 0..params.fig3_seeds {
                            let mut cell =
                                Cell::new(size, Method::Quip, QuantConfig::int(bits), qep);
                            cell.seed = seed;
                            cells.push(PlanCell {
                                sweep: SweepId::Fig3,
                                task: CellTask::Quant(cell),
                            });
                        }
                    }
                }
            }
        }
        SweepId::Appendix => {
            quant_matrix(
                &mut cells,
                SweepId::Appendix,
                &params.sizes,
                &params.appendix_settings,
                &appendix_methods(),
            );
        }
        SweepId::Lowrank => {
            // settings × methods × ±QEP × (rank 0 then --ranks) × sizes;
            // rank 0 gives the base/+qep reference rows the table deltas
            // are read against.
            for &q in &params.lowrank_settings {
                for m in lowrank_methods() {
                    for qep in [false, true] {
                        for rank in std::iter::once(0).chain(params.lowrank_ranks.iter().copied())
                        {
                            for &s in &params.sizes {
                                let mut cell = Cell::new(s, m, q, qep);
                                cell.lowrank_rank = rank;
                                cells.push(PlanCell {
                                    sweep: SweepId::Lowrank,
                                    task: CellTask::Quant(cell),
                                });
                            }
                        }
                    }
                }
            }
        }
        SweepId::Budget => {
            // Uniform ⌊B⌋ baselines first (deduped across budgets that
            // share a floor — 3.0 and 3.5 both read against INT3), then
            // the allocated cells, budget-major. The render pairs each
            // budget with its floor baseline at lookup time.
            let mut floors: Vec<u32> = Vec::new();
            for b in &params.budgets {
                let f = b.floor_bits();
                if !floors.contains(&f) {
                    floors.push(f);
                }
            }
            for &f in &floors {
                for m in budget_methods() {
                    for qep in [false, true] {
                        for &s in &params.sizes {
                            cells.push(PlanCell {
                                sweep: SweepId::Budget,
                                task: CellTask::Quant(Cell::new(s, m, QuantConfig::int(f), qep)),
                            });
                        }
                    }
                }
            }
            for &b in &params.budgets {
                for m in budget_methods() {
                    for qep in [false, true] {
                        for &s in &params.sizes {
                            let mut cell = Cell::new(s, m, QuantConfig::int(b.floor_bits()), qep);
                            cell.budget = Some(BudgetSpec { budget: b, alloc: Alloc::Dp });
                            cells.push(PlanCell {
                                sweep: SweepId::Budget,
                                task: CellTask::Quant(cell),
                            });
                        }
                    }
                }
            }
        }
        SweepId::Cbq => {
            // method × ±QEP × window × sizes, window-minor so every
            // method's window column reads off adjacent manifest rows.
            // One quant setting (INT3, carried in the ID for forward
            // compatibility); window 1 is the layer-wise baseline row.
            let q = QuantConfig::int(3);
            for m in cbq_methods() {
                for qep in [false, true] {
                    for &w in &params.cbq_windows {
                        for &s in &params.sizes {
                            let mut cell = Cell::new(s, m, q, qep);
                            cell.cbq_window = w;
                            cells.push(PlanCell {
                                sweep: SweepId::Cbq,
                                task: CellTask::Quant(cell),
                            });
                        }
                    }
                }
            }
        }
        SweepId::All => {
            for part in SweepId::all_parts() {
                cells.extend(manifest(part, params)?);
            }
        }
    }
    Ok(cells)
}

/// The standard `settings × methods × ±QEP × sizes` matrix order shared
/// by the table 1/2 and appendix drivers.
fn quant_matrix(
    out: &mut Vec<PlanCell>,
    sweep: SweepId,
    sizes: &[Size],
    settings: &[QuantConfig],
    methods: &[Method],
) {
    for &q in settings {
        for &m in methods {
            for qep in [false, true] {
                for &s in sizes {
                    out.push(PlanCell { sweep, task: CellTask::Quant(Cell::new(s, m, q, qep)) });
                }
            }
        }
    }
}

/// Distinct model sizes a cell list touches, in first-seen order (the
/// snapshot the shard executor must load).
pub fn sizes_of(cells: &[PlanCell]) -> Vec<Size> {
    let mut sizes = Vec::new();
    for c in cells {
        if !sizes.contains(&c.size()) {
            sizes.push(c.size());
        }
    }
    sizes
}

/// A parsed `--shard i/N` spec (1-based shard index).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    pub index: usize,
    pub count: usize,
}

impl ShardSpec {
    pub fn parse(s: &str) -> Result<ShardSpec> {
        let err = || anyhow!("--shard expects i/N with 1 <= i <= N (e.g. --shard 2/3), got '{s}'");
        let (i, n) = s.split_once('/').ok_or_else(err)?;
        let index: usize = i.parse().map_err(|_| err())?;
        let count: usize = n.parse().map_err(|_| err())?;
        if count == 0 || index == 0 || index > count {
            return Err(err());
        }
        Ok(ShardSpec { index, count })
    }

    /// The manifest entries this shard owns.
    pub fn filter(&self, cells: &[PlanCell]) -> Vec<PlanCell> {
        cells
            .iter()
            .enumerate()
            .filter(|(j, _)| shard_of(*j, self.count) == self.index)
            .map(|(_, c)| c.clone())
            .collect()
    }
}

/// Deterministic shard assignment: manifest index `j` (0-based) belongs
/// to shard `(j % count) + 1`. Round-robin keeps mixed-cost sweeps
/// balanced (adjacent manifest entries tend to cost the same).
pub fn shard_of(index: usize, count: usize) -> usize {
    (index % count.max(1)) + 1
}

/// Result records keyed by cell ID, verified to cover a manifest exactly
/// once. Renders look cells up by identity, never by position, so shard
/// files can arrive in any order.
pub struct RecordMap {
    by_id: HashMap<String, CellRecord>,
}

impl RecordMap {
    pub fn get(&self, cell: &PlanCell) -> Result<&CellRecord> {
        let id = cell.id();
        self.by_id.get(&id).ok_or_else(|| anyhow!("no result record for cell '{id}'"))
    }

    pub fn any_fallback(&self) -> bool {
        self.by_id.values().any(|r| r.fallback)
    }

    /// Records in manifest order (the canonical order for record files
    /// written by an unsharded run).
    pub fn in_order(&self, cells: &[PlanCell]) -> Result<Vec<CellRecord>> {
        cells.iter().map(|c| self.get(c).cloned()).collect()
    }
}

/// First `show` IDs joined, with a `(+N more)` suffix — shared by the
/// coverage-error messages here and `exp status` rendering.
pub(crate) fn preview(ids: &[String], show: usize) -> String {
    let shown: Vec<&str> = ids.iter().take(show).map(|s| s.as_str()).collect();
    if ids.len() > show {
        format!("{} (+{} more)", shown.join(", "), ids.len() - show)
    } else {
        shown.join(", ")
    }
}

/// Manifest cells indexed by ID (value = position in manifest order),
/// verifying ID uniqueness. Shared by the merge-time coverage check and
/// the resume executor's record-directory validation so "is this record
/// part of this plan?" means the same thing everywhere.
pub fn index_manifest(cells: &[PlanCell]) -> Result<HashMap<String, usize>> {
    let mut expected: HashMap<String, usize> = HashMap::new();
    for (j, c) in cells.iter().enumerate() {
        if expected.insert(c.id(), j).is_some() {
            bail!("manifest bug: duplicate cell id '{}'", c.id());
        }
    }
    Ok(expected)
}

/// Merge-time coverage check: every manifest cell has exactly one record
/// and every record names a manifest cell. Gaps, duplicates, and unknown
/// IDs are hard errors — a partial or mixed-up merge must never render
/// (`repro exp status` shows the same counts without erroring).
pub fn verify_coverage(cells: &[PlanCell], records: Vec<CellRecord>) -> Result<RecordMap> {
    let expected = index_manifest(cells)?;
    let mut by_id: HashMap<String, CellRecord> = HashMap::new();
    let mut unknown = Vec::new();
    let mut duplicate = Vec::new();
    for r in records {
        if !expected.contains_key(&r.id) {
            unknown.push(r.id.clone());
        } else if by_id.contains_key(&r.id) {
            duplicate.push(r.id.clone());
        } else {
            by_id.insert(r.id.clone(), r);
        }
    }
    if !unknown.is_empty() {
        unknown.sort();
        bail!(
            "{} record(s) are not in the manifest (wrong sweep, flags, or corrupted id?): {}",
            unknown.len(),
            preview(&unknown, 5)
        );
    }
    if !duplicate.is_empty() {
        duplicate.sort();
        duplicate.dedup();
        bail!(
            "duplicate record(s) for {} cell(s) (overlapping shard files?): {}",
            duplicate.len(),
            preview(&duplicate, 5)
        );
    }
    let missing: Vec<String> =
        cells.iter().map(|c| c.id()).filter(|id| !by_id.contains_key(id)).collect();
    if !missing.is_empty() {
        bail!(
            "{} of {} manifest cell(s) have no record (incomplete shard set?): {}",
            missing.len(),
            cells.len(),
            preview(&missing, 5)
        );
    }
    Ok(RecordMap { by_id })
}
