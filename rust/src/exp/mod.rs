//! Experiment drivers — one per paper table/figure (see DESIGN.md §6) —
//! structured as three composable stages:
//!
//! 1. **enumerate** ([`plan`]): every sweep expands to a stable, ordered
//!    manifest of [`PlanCell`]s whose string IDs round-trip through
//!    [`PlanCell::parse`];
//! 2. **run** ([`common::run_cells`]): cells execute against an
//!    immutable [`ExpData`] snapshot with per-cell name-derived seeds,
//!    fanned across the work-stealing pool (Table 3's timed cells run
//!    serially because they measure wall-clock), each producing a
//!    machine-readable [`crate::io::results::CellRecord`];
//! 3. **render** ([`common::render_sweep`]): tables/figures are formatted
//!    from records by cell identity.
//!
//! Because stage 2 is a pure function of (cell ID, artifacts), the
//! stages can run in different processes: `repro exp <id> --shard i/N
//! --out DIR` runs one deterministic slice of the manifest and persists
//! records, and `repro exp merge <id> --out DIR` verifies exact manifest
//! coverage and renders output **byte-identical** to the single-process
//! sweep — for every shard count and every `--threads` value.
//!
//! The run stage is also **crash-safe**: `--out` runs append each record
//! durably in manifest order ([`common::run_cells_durable`]), a SIGKILL
//! leaves at most a torn final line the readers drop, `repro exp <id>
//! ... --resume` validates the directory ([`common::validate_resume`])
//! and runs only the missing cells, and `repro exp status` reports
//! done/missing/torn per sweep ([`common::status_report`]) — with
//! resumed runs byte-identical to uninterrupted ones.

pub mod common;
pub mod fig2;
pub mod fig3;
pub mod plan;
pub mod tables;

pub use common::{Cell, ExpData, ExpEnv, RenderCfg};
pub use plan::{CellTask, PlanCell, PlanParams, ShardSpec, SweepId};
