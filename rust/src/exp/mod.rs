//! Experiment drivers — one per paper table/figure (see DESIGN.md §6).

pub mod common;
pub mod fig2;
pub mod fig3;
pub mod tables;

pub use common::{ExpEnv, Cell};
