//! Experiment drivers — one per paper table/figure (see DESIGN.md §6).
//!
//! Drivers shard their independent (model × method × grid × ±QEP) cells
//! across the work-stealing pool: [`ExpEnv`] snapshots its caches into an
//! immutable [`ExpData`], cells run via [`Cell::run_on`] with per-cell
//! name-derived seeds, and results are collected in cell order — so
//! `repro exp all` saturates the machine while every table stays
//! byte-identical for every `--threads` value. The one exception is
//! Table 3, which measures per-cell runtime and therefore runs its cells
//! serially (see `tables::table3`).

pub mod common;
pub mod fig2;
pub mod fig3;
pub mod tables;

pub use common::{Cell, ExpData, ExpEnv};
