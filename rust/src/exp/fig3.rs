//! Fig. 3: seed stability of QuIP ± QEP. Five seeds per configuration;
//! report mean ± SEM for PPL (wiki) and mean task accuracy.

use super::common::{persist, Cell, ExpEnv, TASKS_PER_FAMILY};
use crate::eval::{perplexity, TaskFamily, TaskSet};
use crate::model::Size;
use crate::quant::{Method, QuantConfig};
use crate::text::Flavor;
use crate::util::stats::{mean, sem};
use crate::util::table::Table;
use anyhow::Result;

pub fn run(env: &mut ExpEnv, sizes: &[Size], bits_list: &[u32], n_seeds: u64) -> Result<()> {
    let mut t = Table::new(
        "Figure 3 data: QuIP ± QEP over seeds (mean ± SEM)",
        &["bits", "size", "QEP", "ppl mean", "ppl sem", "acc mean", "acc sem"],
    );
    let eval = env.eval_tokens(Flavor::Wiki);
    let task_corpus = env.corpus(Flavor::Wiki);
    for &bits in bits_list {
        for &size in sizes {
            for qep in [false, true] {
                let mut ppls = Vec::new();
                let mut accs = Vec::new();
                for seed in 0..n_seeds {
                    let mut cell = Cell::new(size, Method::Quip, QuantConfig::int(bits), qep);
                    cell.seed = seed;
                    let out = cell.run(env)?;
                    ppls.push(perplexity(&out.model, &eval));
                    let fam_accs: Vec<f64> = TaskFamily::all()
                        .iter()
                        .map(|&f| {
                            TaskSet::generate(f, &task_corpus, TASKS_PER_FAMILY, 1234)
                                .accuracy(&out.model)
                        })
                        .collect();
                    accs.push(mean(&fam_accs));
                    eprintln!(
                        "[fig3] {} INT{bits} qep={qep} seed={seed}: ppl={:.3} acc={:.4}",
                        size.name(),
                        ppls.last().unwrap(),
                        accs.last().unwrap()
                    );
                }
                t.row(vec![
                    format!("INT{bits}"),
                    size.name().to_string(),
                    if qep { "yes" } else { "no" }.to_string(),
                    format!("{:.3}", mean(&ppls)),
                    format!("{:.3}", sem(&ppls)),
                    format!("{:.4}", mean(&accs)),
                    format!("{:.4}", sem(&accs)),
                ]);
            }
        }
    }
    println!("{}", t.render());
    persist("fig3", &t)
}
