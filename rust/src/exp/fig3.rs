//! Fig. 3: seed stability of QuIP ± QEP. Five seeds per configuration;
//! report mean ± SEM for PPL (wiki) and mean task accuracy. Every
//! (bits × size × ±QEP × seed) replicate is an independent plan cell
//! (`fig3/INT<b>/<size>/<±qep>/s<seed>`), so the whole grid shards
//! across the pool — or across machines; aggregation happens at render
//! time in a fixed order from the per-replicate records, keeping the
//! table bytes invariant to thread counts and shard splits alike.

use super::common::{self, persist_to, ExpEnv, RenderCfg};
use super::plan::{CellTask, PlanCell, PlanParams, RecordMap, SweepId};
use crate::eval::TaskFamily;
use crate::model::Size;
use crate::quant::{Method, QuantConfig};
use crate::util::stats::{mean, sem};
use crate::util::table::Table;
use anyhow::Result;

/// Render the Fig. 3 table from per-replicate records: per-seed accuracy
/// is the mean over task families (in `TaskFamily::all()` order, exactly
/// as the historical driver computed it), then mean ± SEM over seeds.
pub fn render(params: &PlanParams, recs: &RecordMap, rcfg: &RenderCfg) -> Result<()> {
    let mut t = Table::new(
        "Figure 3 data: QuIP ± QEP over seeds (mean ± SEM)",
        &["bits", "size", "QEP", "ppl mean", "ppl sem", "acc mean", "acc sem"],
    );
    for &bits in &params.fig3_bits {
        for &size in &params.sizes {
            for qep in [false, true] {
                let mut ppls = Vec::new();
                let mut accs = Vec::new();
                for seed in 0..params.fig3_seeds {
                    let mut cell = super::Cell::new(size, Method::Quip, QuantConfig::int(bits), qep);
                    cell.seed = seed;
                    let pc = PlanCell { sweep: SweepId::Fig3, task: CellTask::Quant(cell) };
                    let rec = recs.get(&pc)?;
                    ppls.push(rec.ppl_for("wiki"));
                    let fam_accs: Vec<f64> =
                        TaskFamily::all().iter().map(|f| rec.acc_for(f.name())).collect();
                    accs.push(mean(&fam_accs));
                }
                t.row(vec![
                    format!("INT{bits}"),
                    size.name().to_string(),
                    if qep { "yes" } else { "no" }.to_string(),
                    format!("{:.3}", mean(&ppls)),
                    format!("{:.3}", sem(&ppls)),
                    format!("{:.4}", mean(&accs)),
                    format!("{:.4}", sem(&accs)),
                ]);
            }
        }
    }
    println!("{}", t.render());
    persist_to(&rcfg.results_dir, "fig3", &t)
}

/// Single-process driver (enumerate → run → render in one call).
pub fn run(env: &mut ExpEnv, sizes: &[Size], bits_list: &[u32], n_seeds: u64) -> Result<()> {
    let mut params = PlanParams::for_sizes(sizes);
    params.fig3_bits = bits_list.to_vec();
    params.fig3_seeds = n_seeds;
    common::run_sweep(env, SweepId::Fig3, &params, &RenderCfg::default()).map(|_| ())
}
