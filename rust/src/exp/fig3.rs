//! Fig. 3: seed stability of QuIP ± QEP. Five seeds per configuration;
//! report mean ± SEM for PPL (wiki) and mean task accuracy. Every
//! (bits × size × ±QEP × seed) replicate is an independent cell, so the
//! whole grid shards across the pool; aggregation runs in a fixed order
//! afterwards, keeping the table bytes thread-count-invariant.

use super::common::{persist, run_jobs, Cell, ExpEnv, TASKS_PER_FAMILY};
use crate::eval::{perplexity, TaskFamily, TaskSet};
use crate::model::Size;
use crate::quant::{Method, QuantConfig};
use crate::text::Flavor;
use crate::util::pool;
use crate::util::stats::{mean, sem};
use crate::util::table::Table;
use anyhow::Result;

pub fn run(env: &mut ExpEnv, sizes: &[Size], bits_list: &[u32], n_seeds: u64) -> Result<()> {
    let data = env.snapshot(sizes);
    let eval = data.eval_tokens(Flavor::Wiki);

    // Flat job list in table order; chunks of `n_seeds` aggregate below.
    let mut jobs: Vec<Cell> = Vec::new();
    for &bits in bits_list {
        for &size in sizes {
            for qep in [false, true] {
                for seed in 0..n_seeds {
                    let mut cell = Cell::new(size, Method::Quip, QuantConfig::int(bits), qep);
                    cell.seed = seed;
                    jobs.push(cell);
                }
            }
        }
    }

    // Task sets are replicate-independent: build once, score per cell.
    let task_corpus = data.corpus(Flavor::Wiki);
    let task_sets: Vec<TaskSet> = TaskFamily::all()
        .iter()
        .map(|&f| TaskSet::generate(f, task_corpus, TASKS_PER_FAMILY, 1234))
        .collect();
    let per_seed: Vec<(f64, f64)> =
        run_jobs(&pool::global(), jobs.len(), |i| -> Result<(f64, f64)> {
            let cell = &jobs[i];
            let out = cell.run_on(&data)?;
            let ppl = perplexity(&out.model, &eval);
            let fam_accs: Vec<f64> =
                task_sets.iter().map(|ts| ts.accuracy(&out.model)).collect();
            let acc = mean(&fam_accs);
            eprintln!(
                "[fig3] {} seed={}: ppl={ppl:.3} acc={acc:.4}",
                cell.label(),
                cell.seed
            );
            Ok((ppl, acc))
        })
        .into_iter()
        .collect::<Result<_>>()?;

    let mut t = Table::new(
        "Figure 3 data: QuIP ± QEP over seeds (mean ± SEM)",
        &["bits", "size", "QEP", "ppl mean", "ppl sem", "acc mean", "acc sem"],
    );
    let mut idx = 0;
    for &bits in bits_list {
        for &size in sizes {
            for qep in [false, true] {
                let chunk = &per_seed[idx..idx + n_seeds as usize];
                idx += n_seeds as usize;
                let ppls: Vec<f64> = chunk.iter().map(|&(p, _)| p).collect();
                let accs: Vec<f64> = chunk.iter().map(|&(_, a)| a).collect();
                t.row(vec![
                    format!("INT{bits}"),
                    size.name().to_string(),
                    if qep { "yes" } else { "no" }.to_string(),
                    format!("{:.3}", mean(&ppls)),
                    format!("{:.3}", sem(&ppls)),
                    format!("{:.4}", mean(&accs)),
                    format!("{:.4}", sem(&accs)),
                ]);
            }
        }
    }
    println!("{}", t.render());
    persist("fig3", &t)
}
