//! Table drivers and renderers. Each paper table is now three stages:
//! the cell matrix is enumerated by `plan::manifest`, executed by
//! `common::run_cells` (cells quantize once; every requested metric is
//! computed from the same quantized model, so combined drivers — tables
//! 1/2 share cells, 5–10 share cells — cost no more than one table),
//! and formatted here from the result records by cell identity.
//!
//! Sharding: untimed cells fan out across the work-stealing pool against
//! an immutable [`ExpData`] snapshot with per-cell name-derived seeds,
//! so every table renders byte-identically for every `--threads` value
//! *and* for every `--shard i/N` split (renders look records up by cell
//! ID, never by position). Table 3 is the deliberate exception: it
//! *measures* per-cell runtime, so its cells run serially in whichever
//! process owns them and its timing cells are shard-local wall-clock —
//! the one non-deterministic column (render with `--stable-timings` to
//! make even those bytes machine-independent).

use super::common::{self, persist_to, run_jobs, Cell, ExpData, ExpEnv, RenderCfg};
use super::plan::{self, CellTask, PlanCell, PlanParams, RecordMap, SweepId};
use crate::eval::{perplexity, TaskFamily};
use crate::model::Size;
use crate::quant::{Alloc, BudgetSpec, Method, QuantConfig};
use crate::text::Flavor;
use crate::util::pool::Pool;
use crate::util::stats;
use crate::util::table::{fmt_acc, fmt_ppl, fmt_runtime, Table};
use anyhow::Result;
use std::collections::HashMap;

/// What to measure for a cell matrix.
pub struct Wants {
    pub ppl: Vec<Flavor>,
    pub tasks: Vec<TaskFamily>,
}

/// Everything measured for one cell.
pub struct CellResult {
    pub cell: Cell,
    pub ppl: HashMap<Flavor, f64>,
    pub acc: HashMap<TaskFamily, f64>,
    /// Wall-clock of this cell's own pipeline. Meaningful in isolation
    /// (Table 3 runs cells serially); under a sharded sweep cells contend
    /// for cores and this becomes an upper bound.
    pub runtime_s: f64,
    pub correction_s: f64,
}

/// Run a matrix of cells against a snapshot on an explicit pool: one
/// pool task per cell, results collected in cell order. Cells derive
/// their seeds from their own identity, so the output is bit-identical
/// for every thread count and every stealing schedule. (Kept as the
/// parallel-equivalence suite's direct harness; the CLI drivers go
/// through the plan/record pipeline instead.)
pub fn run_matrix_on(
    data: &ExpData,
    cells: &[Cell],
    wants: &Wants,
    pool: &Pool,
) -> Result<Vec<CellResult>> {
    eprintln!("[exp] running {} cells on {} worker(s)", cells.len(), pool.threads());
    let results = run_jobs(pool, cells.len(), |i| -> Result<CellResult> {
        let cell = &cells[i];
        let out = cell.run_on(data)?;
        let mut ppl = HashMap::new();
        for &fl in &wants.ppl {
            let eval = data.eval_tokens(fl);
            ppl.insert(fl, perplexity(&out.model, &eval));
        }
        let mut acc = HashMap::new();
        for &fam in &wants.tasks {
            // Task sets are cell-independent: the snapshot builds each
            // family's set once and every cell scores against it.
            acc.insert(fam, data.task_set(fam).accuracy(&out.model));
        }
        eprintln!("[exp] cell {}/{} done: {}", i + 1, cells.len(), cell.label());
        Ok(CellResult {
            cell: cell.clone(),
            ppl,
            acc,
            runtime_s: out.report.total_s,
            correction_s: out.report.correction_s(),
        })
    });
    results.into_iter().collect()
}

/// Standard cell matrix: `settings × methods × ±QEP` for each size.
pub fn matrix(sizes: &[Size], settings: &[QuantConfig], methods: &[Method]) -> Vec<Cell> {
    let mut cells = Vec::new();
    for &q in settings {
        for &m in methods {
            for qep in [false, true] {
                for &s in sizes {
                    cells.push(Cell::new(s, m, q, qep));
                }
            }
        }
    }
    cells
}

fn header(sizes: &[Size]) -> Vec<String> {
    let mut h = vec!["Bits".to_string(), "Method".to_string(), "QEP".to_string()];
    h.extend(sizes.iter().map(|s| format!("{} ({})", s.name(), s.paper_analog())));
    h
}

/// Format a PPL table in the paper's layout (Tables 1, 5, 6, 7). Public
/// so the parallel-equivalence suite can assert byte-identical renders
/// across thread counts.
pub fn format_ppl_table(
    title: &str,
    results: &[CellResult],
    sizes: &[Size],
    settings: &[QuantConfig],
    methods: &[Method],
    flavor: Flavor,
) -> Table {
    let hdr = header(sizes);
    let mut t = Table::new(title, &hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for &q in settings {
        for &m in methods {
            for qep in [false, true] {
                let mut row = vec![
                    q.label(),
                    m.name().to_string(),
                    if qep { "yes" } else { "no" }.to_string(),
                ];
                for &s in sizes {
                    let v = results
                        .iter()
                        .find(|r| {
                            r.cell.size == s
                                && r.cell.method == m
                                && r.cell.quant == q
                                && r.cell.qep == qep
                        })
                        .and_then(|r| r.ppl.get(&flavor))
                        .copied()
                        .unwrap_or(f64::NAN);
                    row.push(fmt_ppl(v));
                }
                t.row(row);
            }
        }
        t.rule();
    }
    t
}

/// Format an accuracy table (Tables 2, 8, 9, 10). `family = None` means
/// the mean over all requested families (Table 2). Public for the same
/// reason as [`format_ppl_table`].
pub fn format_acc_table(
    title: &str,
    results: &[CellResult],
    sizes: &[Size],
    settings: &[QuantConfig],
    methods: &[Method],
    family: Option<TaskFamily>,
) -> Table {
    let hdr = header(sizes);
    let mut t = Table::new(title, &hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for &q in settings {
        for &m in methods {
            for qep in [false, true] {
                let mut row = vec![
                    q.label(),
                    m.name().to_string(),
                    if qep { "yes" } else { "no" }.to_string(),
                ];
                for &s in sizes {
                    let v = results
                        .iter()
                        .find(|r| {
                            r.cell.size == s
                                && r.cell.method == m
                                && r.cell.quant == q
                                && r.cell.qep == qep
                        })
                        .map(|r| match family {
                            Some(f) => *r.acc.get(&f).unwrap_or(&f64::NAN),
                            None => stats::mean(&r.acc.values().copied().collect::<Vec<_>>()),
                        })
                        .unwrap_or(f64::NAN);
                    row.push(fmt_acc(v));
                }
                t.row(row);
            }
        }
        t.rule();
    }
    t
}

fn family_from_name(name: &str) -> Option<TaskFamily> {
    TaskFamily::all().into_iter().find(|f| f.name() == name)
}

/// Reassemble [`CellResult`]s (the formatters' input) from the result
/// records of a sweep's `Quant` cells, looked up by cell identity.
fn quant_results(
    sweep: SweepId,
    params: &PlanParams,
    recs: &RecordMap,
) -> Result<Vec<CellResult>> {
    let cells = plan::manifest(sweep, params)?;
    let mut out = Vec::new();
    for pc in &cells {
        if let CellTask::Quant(cell) = &pc.task {
            let rec = recs.get(pc)?;
            let mut ppl = HashMap::new();
            for (k, v) in &rec.ppl {
                if let Some(fl) = Flavor::from_name(k) {
                    ppl.insert(fl, *v);
                }
            }
            let mut acc = HashMap::new();
            for (k, v) in &rec.acc {
                if let Some(fam) = family_from_name(k) {
                    acc.insert(fam, *v);
                }
            }
            out.push(CellResult {
                cell: cell.clone(),
                ppl,
                acc,
                runtime_s: rec.timings.total_s,
                correction_s: rec.timings.correction_s,
            });
        }
    }
    Ok(out)
}

/// Render Table 1 (+ Fig. 1 data) and Table 2 from records.
pub fn render_table12(params: &PlanParams, recs: &RecordMap, rcfg: &RenderCfg) -> Result<()> {
    let settings = plan::table12_settings();
    let methods = Method::all();
    let sizes = &params.sizes;
    let results = quant_results(SweepId::Table12, params, recs)?;

    let t1 = format_ppl_table(
        "Table 1: perplexity (wiki analog) — lower is better",
        &results,
        sizes,
        &settings,
        &methods,
        Flavor::Wiki,
    );
    println!("{}", t1.render());
    persist_to(&rcfg.results_dir, "table1", &t1)?;

    let t2 = format_acc_table(
        "Table 2: zero-shot average accuracy (cloze/completion/pattern) — higher is better",
        &results,
        sizes,
        &settings,
        &methods,
        None,
    );
    println!("{}", t2.render());
    persist_to(&rcfg.results_dir, "table2", &t2)?;

    // Fig. 1 is the bar-chart view of Table 1; emit its CSV series.
    let mut fig1 = Table::new(
        "Figure 1 data: PPL bars (method, bits, size, base, qep)",
        &["method", "bits", "size", "ppl_base", "ppl_qep"],
    );
    for &q in &settings {
        for &m in &methods {
            for &s in sizes.iter() {
                let find = |qep: bool| {
                    results
                        .iter()
                        .find(|r| {
                            r.cell.size == s
                                && r.cell.method == m
                                && r.cell.quant == q
                                && r.cell.qep == qep
                        })
                        .and_then(|r| r.ppl.get(&Flavor::Wiki))
                        .copied()
                        .unwrap_or(f64::NAN)
                };
                fig1.row(vec![
                    m.name().into(),
                    q.label(),
                    s.name().into(),
                    fmt_ppl(find(false)),
                    fmt_ppl(find(true)),
                ]);
            }
        }
    }
    println!("{}", fig1.render());
    persist_to(&rcfg.results_dir, "fig1", &fig1)?;
    Ok(())
}

/// Render Table 3 from records: quantization runtime comparison (GPTQ vs
/// AWQ vs QEP+RTN). Timing cells are the wall-clock of whichever process
/// ran the cell serially (shard-local); `--stable-timings` renders them
/// as a placeholder so the bytes are machine-independent.
pub fn render_table3(params: &PlanParams, recs: &RecordMap, rcfg: &RenderCfg) -> Result<()> {
    let mut hdr = vec!["Runtime".to_string()];
    hdr.extend(params.sizes.iter().map(|s| s.name().to_string()));
    let mut t = Table::new(
        "Table 3: quantization-process runtime (shard-local wall-clock)",
        &hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let rows: Vec<(&str, Method, bool)> = vec![
        ("GPTQ", Method::Gptq, false),
        ("AWQ", Method::Awq, false),
        ("QEP + RTN", Method::Rtn, true),
    ];
    for (label, method, qep) in rows {
        let mut row = vec![label.to_string()];
        for &s in &params.sizes {
            let pc = PlanCell {
                sweep: SweepId::Table3,
                task: CellTask::Quant(Cell::new(s, method, QuantConfig::int(3), qep)),
            };
            let rec = recs.get(&pc)?;
            row.push(fmt_runtime(rec.timings.total_s, rcfg.stable_timings));
        }
        t.row(row);
    }
    println!("{}", t.render());
    persist_to(&rcfg.results_dir, "table3", &t)
}

/// Render Table 4 from records: PPL (wiki eval) deltas vs base RTN for
/// GPTQ and QEP+RTN calibrated on c4/ptb/wiki.
pub fn render_table4(params: &PlanParams, recs: &RecordMap, rcfg: &RenderCfg) -> Result<()> {
    let size = params.table4_size;
    let q = QuantConfig::int(3);
    let ref_pc = PlanCell {
        sweep: SweepId::Table4,
        task: CellTask::Quant(Cell::new(size, Method::Rtn, q, false)),
    };
    let rtn = recs.get(&ref_pc)?.ppl_for("wiki");
    let flavors = [Flavor::C4, Flavor::Ptb, Flavor::Wiki];
    let variants = [("GPTQ", Method::Gptq, false), ("QEP + RTN", Method::Rtn, true)];
    let mut t = Table::new(
        &format!("Table 4: PPL relative to RTN ({}; eval=wiki; RTN={:.3})", size.name(), rtn),
        &["Method", "calib=C4", "calib=PTB", "calib=WikiText2"],
    );
    for &(label, method, qep) in &variants {
        let mut row = vec![label.to_string()];
        for &fl in &flavors {
            let mut cell = Cell::new(size, method, q, qep);
            cell.calib_flavor = fl;
            let pc = PlanCell { sweep: SweepId::Table4, task: CellTask::Quant(cell) };
            let ppl = recs.get(&pc)?.ppl_for("wiki");
            row.push(format!("{:+.3}", ppl - rtn));
        }
        t.row(row);
    }
    println!("{}", t.render());
    persist_to(&rcfg.results_dir, "table4", &t)
}

/// Render the α ablation from records (DESIGN.md §6, Prop. 5.4
/// empirically): PPL as a function of the propagation strength α for
/// RTN INT3 — the knob §5.3 introduces.
pub fn render_ablation_alpha(
    params: &PlanParams,
    recs: &RecordMap,
    rcfg: &RenderCfg,
) -> Result<()> {
    let mut hdr = vec!["alpha".to_string()];
    hdr.extend(params.sizes.iter().map(|s| s.name().to_string()));
    let mut t = Table::new(
        "Ablation: wiki PPL vs propagation strength α (RTN INT3)",
        &hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for &a in &plan::ablation_alphas() {
        let mut row = vec![format!("{a:.2}")];
        for &s in &params.sizes {
            let pc = PlanCell {
                sweep: SweepId::AblationAlpha,
                task: CellTask::Alpha { size: s, alpha: a },
            };
            row.push(fmt_ppl(recs.get(&pc)?.ppl_for("wiki")));
        }
        t.row(row);
    }
    println!("{}", t.render());
    persist_to(&rcfg.results_dir, "ablation_alpha", &t)
}

/// Render tables 5–7 (PPL under the appendix grid settings on
/// wiki/ptb/c4 evals) and 8–10 (per-task accuracy for the same cells)
/// from records. One cell matrix covers all six tables.
pub fn render_appendix(params: &PlanParams, recs: &RecordMap, rcfg: &RenderCfg) -> Result<()> {
    let settings = &params.appendix_settings;
    let methods = plan::appendix_methods();
    let sizes = &params.sizes;
    let results = quant_results(SweepId::Appendix, params, recs)?;

    for (idx, flavor, label) in [
        (5, Flavor::Wiki, "WikiText-2 analog"),
        (6, Flavor::Ptb, "PTB analog"),
        (7, Flavor::C4, "C4 analog"),
    ] {
        let t = format_ppl_table(
            &format!("Table {idx}: perplexity on {label}, eight grid settings"),
            &results,
            sizes,
            settings,
            &methods,
            flavor,
        );
        println!("{}", t.render());
        persist_to(&rcfg.results_dir, &format!("table{idx}"), &t)?;
    }
    for (idx, family) in [
        (8, TaskFamily::Cloze),
        (9, TaskFamily::Completion),
        (10, TaskFamily::Pattern),
    ] {
        let t = format_acc_table(
            &format!(
                "Table {idx}: accuracy on {} ({} analog), eight grid settings",
                family.name(),
                family.paper_analog()
            ),
            &results,
            sizes,
            settings,
            &methods,
            Some(family),
        );
        println!("{}", t.render());
        persist_to(&rcfg.results_dir, &format!("table{idx}"), &t)?;
    }
    Ok(())
}

/// Render the low-rank reconstruction sweep from records: wiki PPL for
/// `settings × methods × {base, +qep, +lr{r}, +qep+lr{r}}` — the LQER
/// (plain ±lowrank) and QERA (Hessian-weighted adjunct) family next to
/// their rank-0 references, orthogonal to QEP's α correction.
pub fn render_lowrank(params: &PlanParams, recs: &RecordMap, rcfg: &RenderCfg) -> Result<()> {
    let mut hdr = vec!["Bits".to_string(), "Method".to_string(), "Variant".to_string()];
    hdr.extend(params.sizes.iter().map(|s| s.name().to_string()));
    let mut t = Table::new(
        "Low-rank reconstruction (LQER/QERA): wiki PPL by adjunct rank",
        &hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for (qi, &q) in params.lowrank_settings.iter().enumerate() {
        if qi > 0 {
            t.rule();
        }
        for m in plan::lowrank_methods() {
            for qep in [false, true] {
                for rank in std::iter::once(0).chain(params.lowrank_ranks.iter().copied()) {
                    let mut row = vec![
                        q.label(),
                        m.name().to_string(),
                        plan::variant_name(qep, rank),
                    ];
                    for &s in &params.sizes {
                        let mut cell = Cell::new(s, m, q, qep);
                        cell.lowrank_rank = rank;
                        let pc =
                            PlanCell { sweep: SweepId::Lowrank, task: CellTask::Quant(cell) };
                        row.push(fmt_ppl(recs.get(&pc)?.ppl_for("wiki")));
                    }
                    t.row(row);
                }
            }
        }
    }
    println!("{}", t.render());
    persist_to(&rcfg.results_dir, "lowrank", &t)
}

/// Render the mixed-precision budget sweep from records: wiki PPL for
/// `budgets × methods × ±QEP`, each allocated (DP) row next to the
/// uniform `INT⌊B⌋` baseline at the same calibration stream. The
/// allocated config elementwise-dominates its uniform floor (every
/// layer gets ≥ ⌊B⌋ bits), so its PPL column should read ≤ the `uni`
/// row above it — the table makes that check visual.
pub fn render_budget(params: &PlanParams, recs: &RecordMap, rcfg: &RenderCfg) -> Result<()> {
    let mut hdr = vec!["Budget".to_string(), "Method".to_string(), "Variant".to_string()];
    hdr.extend(params.sizes.iter().map(|s| s.name().to_string()));
    let mut t = Table::new(
        "Mixed-precision budgets: wiki PPL, uniform ⌊B⌋ baseline vs DP allocation",
        &hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for (bi, &b) in params.budgets.iter().enumerate() {
        if bi > 0 {
            t.rule();
        }
        let floor = QuantConfig::int(b.floor_bits());
        for m in plan::budget_methods() {
            for qep in [false, true] {
                let qep_suffix = if qep { " +qep" } else { "" };
                // Uniform floor baseline (shared across budgets with the
                // same ⌊B⌋ — same record, re-read per budget group).
                let mut row =
                    vec![b.render(), m.name().to_string(), format!("uni {}{qep_suffix}", floor.label())];
                for &s in &params.sizes {
                    let pc = PlanCell {
                        sweep: SweepId::Budget,
                        task: CellTask::Quant(Cell::new(s, m, floor, qep)),
                    };
                    row.push(fmt_ppl(recs.get(&pc)?.ppl_for("wiki")));
                }
                t.row(row);
                // The allocated cell at the full budget.
                let mut row = vec![
                    b.render(),
                    m.name().to_string(),
                    plan::budget_variant_name(Alloc::Dp, qep),
                ];
                for &s in &params.sizes {
                    let mut cell = Cell::new(s, m, floor, qep);
                    cell.budget = Some(BudgetSpec { budget: b, alloc: Alloc::Dp });
                    let pc = PlanCell { sweep: SweepId::Budget, task: CellTask::Quant(cell) };
                    row.push(fmt_ppl(recs.get(&pc)?.ppl_for("wiki")));
                }
                t.row(row);
            }
        }
    }
    println!("{}", t.render());
    persist_to(&rcfg.results_dir, "budget", &t)
}

/// Render the CBQ cross-block sweep from records: wiki PPL for
/// `methods × ±QEP × windows` at INT3. Window `w1` is the layer-wise
/// baseline row. Base GPTQ never reads the full-precision reference
/// stream, so windowed refinement is a bitwise no-op for it and its
/// rows must match the `w1` row exactly — an in-table correctness
/// anchor — while AWQ and every +qep variant genuinely recalibrate
/// against the window's re-propagated reference.
pub fn render_cbq(params: &PlanParams, recs: &RecordMap, rcfg: &RenderCfg) -> Result<()> {
    let q = QuantConfig::int(3);
    let mut hdr = vec!["Method".to_string(), "QEP".to_string(), "Window".to_string()];
    hdr.extend(params.sizes.iter().map(|s| s.name().to_string()));
    let mut t = Table::new(
        "CBQ cross-block reconstruction: wiki PPL by window size (INT3)",
        &hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for (mi, m) in plan::cbq_methods().into_iter().enumerate() {
        if mi > 0 {
            t.rule();
        }
        for qep in [false, true] {
            for &w in &params.cbq_windows {
                let mut row = vec![
                    m.name().to_string(),
                    if qep { "yes" } else { "no" }.to_string(),
                    plan::window_name(w),
                ];
                for &s in &params.sizes {
                    let mut cell = Cell::new(s, m, q, qep);
                    cell.cbq_window = w;
                    let pc = PlanCell { sweep: SweepId::Cbq, task: CellTask::Quant(cell) };
                    row.push(fmt_ppl(recs.get(&pc)?.ppl_for("wiki")));
                }
                t.row(row);
            }
        }
    }
    println!("{}", t.render());
    persist_to(&rcfg.results_dir, "cbq", &t)
}

/// Table 1 (+ Fig. 1 data) and Table 2: single-process convenience
/// driver (enumerate → run → render in one call).
pub fn table1_and_2(env: &mut ExpEnv, sizes: &[Size]) -> Result<()> {
    let params = PlanParams::for_sizes(sizes);
    common::run_sweep(env, SweepId::Table12, &params, &RenderCfg::default()).map(|_| ())
}

/// Table 3: single-process driver. Cells run *serially* on purpose —
/// the metric is per-cell wall-clock (each cell still uses the full
/// pool internally).
pub fn table3(env: &mut ExpEnv, sizes: &[Size]) -> Result<()> {
    let params = PlanParams::for_sizes(sizes);
    common::run_sweep(env, SweepId::Table3, &params, &RenderCfg::default()).map(|_| ())
}

/// Table 4: single-process driver (robustness to the calibration set).
pub fn table4(env: &mut ExpEnv, size: Size) -> Result<()> {
    let params = PlanParams::for_sizes(&[size]);
    common::run_sweep(env, SweepId::Table4, &params, &RenderCfg::default()).map(|_| ())
}

/// α ablation: single-process driver. Every cell draws the same seed-0
/// calibration slice so α is the only moving part.
pub fn ablation_alpha(env: &mut ExpEnv, sizes: &[Size]) -> Result<()> {
    let params = PlanParams::for_sizes(sizes);
    common::run_sweep(env, SweepId::AblationAlpha, &params, &RenderCfg::default()).map(|_| ())
}

/// Tables 5–10: single-process driver over explicit grid settings.
pub fn appendix_tables(env: &mut ExpEnv, sizes: &[Size], settings: &[QuantConfig]) -> Result<()> {
    let mut params = PlanParams::for_sizes(sizes);
    params.appendix_settings = settings.to_vec();
    common::run_sweep(env, SweepId::Appendix, &params, &RenderCfg::default()).map(|_| ())
}
