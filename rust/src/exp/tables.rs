//! Table drivers. Each driver quantizes a matrix of (size × grid × method
//! × ±QEP) cells and formats the paper's corresponding table. Cells are
//! quantized once and every requested metric is computed from the same
//! quantized model, so combined drivers (tables 5–7 share cells; 8–10
//! share cells) cost no more than a single table.
//!
//! Sharding: independent cells fan out across the work-stealing pool
//! ([`run_matrix_on`]) against an immutable [`ExpData`] snapshot, with
//! per-cell name-derived seeds and results collected in cell order — so
//! every table renders byte-identically for every `--threads` value.
//! Table 3 is the deliberate exception: it *measures* per-cell runtime,
//! and concurrent cells would contend for cores and corrupt the timings,
//! so its cells run serially (each cell still uses the pool internally).

use super::common::{cell_ppl_on, persist, run_jobs, Cell, ExpData, ExpEnv, TASKS_PER_FAMILY};
use crate::eval::{perplexity, TaskFamily, TaskSet};
use crate::model::Size;
use crate::quant::{Method, QuantConfig};
use crate::text::Flavor;
use crate::util::pool::{self, Pool};
use crate::util::stats;
use crate::util::table::{fmt_acc, fmt_ppl, Table};
use anyhow::Result;
use std::collections::HashMap;

/// What to measure for a cell matrix.
pub struct Wants {
    pub ppl: Vec<Flavor>,
    pub tasks: Vec<TaskFamily>,
}

/// Everything measured for one cell.
pub struct CellResult {
    pub cell: Cell,
    pub ppl: HashMap<Flavor, f64>,
    pub acc: HashMap<TaskFamily, f64>,
    /// Wall-clock of this cell's own pipeline. Meaningful in isolation
    /// (Table 3 runs cells serially); under a sharded sweep cells contend
    /// for cores and this becomes an upper bound.
    pub runtime_s: f64,
    pub correction_s: f64,
}

/// Run a matrix of cells on the process-global pool, computing all
/// requested metrics per quantized model (quantize once, evaluate many).
pub fn run_matrix(env: &mut ExpEnv, cells: &[Cell], wants: &Wants) -> Result<Vec<CellResult>> {
    let mut sizes: Vec<Size> = Vec::new();
    for c in cells {
        if !sizes.contains(&c.size) {
            sizes.push(c.size);
        }
    }
    let data = env.snapshot(&sizes);
    run_matrix_on(&data, cells, wants, &pool::global())
}

/// [`run_matrix`] against a snapshot on an explicit pool: one pool task
/// per cell, results collected in cell order. Cells derive their seeds
/// from their own identity, so the output is bit-identical for every
/// thread count and every stealing schedule.
pub fn run_matrix_on(
    data: &ExpData,
    cells: &[Cell],
    wants: &Wants,
    pool: &Pool,
) -> Result<Vec<CellResult>> {
    eprintln!("[exp] running {} cells on {} worker(s)", cells.len(), pool.threads());
    // Task sets are cell-independent: build them once, score per cell.
    let task_corpus = data.corpus(Flavor::Wiki);
    let task_sets: Vec<(TaskFamily, TaskSet)> = wants
        .tasks
        .iter()
        .map(|&fam| (fam, TaskSet::generate(fam, task_corpus, TASKS_PER_FAMILY, 1234)))
        .collect();
    let results = run_jobs(pool, cells.len(), |i| -> Result<CellResult> {
        let cell = &cells[i];
        let out = cell.run_on(data)?;
        let mut ppl = HashMap::new();
        for &fl in &wants.ppl {
            let eval = data.eval_tokens(fl);
            ppl.insert(fl, perplexity(&out.model, &eval));
        }
        let mut acc = HashMap::new();
        for (fam, ts) in &task_sets {
            acc.insert(*fam, ts.accuracy(&out.model));
        }
        eprintln!("[exp] cell {}/{} done: {}", i + 1, cells.len(), cell.label());
        Ok(CellResult {
            cell: cell.clone(),
            ppl,
            acc,
            runtime_s: out.report.total_s,
            correction_s: out.report.correction_s(),
        })
    });
    results.into_iter().collect()
}

/// Standard cell matrix: `settings × methods × ±QEP` for each size.
pub fn matrix(sizes: &[Size], settings: &[QuantConfig], methods: &[Method]) -> Vec<Cell> {
    let mut cells = Vec::new();
    for &q in settings {
        for &m in methods {
            for qep in [false, true] {
                for &s in sizes {
                    cells.push(Cell::new(s, m, q, qep));
                }
            }
        }
    }
    cells
}

fn header(sizes: &[Size]) -> Vec<String> {
    let mut h = vec!["Bits".to_string(), "Method".to_string(), "QEP".to_string()];
    h.extend(sizes.iter().map(|s| format!("{} ({})", s.name(), s.paper_analog())));
    h
}

/// Format a PPL table in the paper's layout (Tables 1, 5, 6, 7). Public
/// so the parallel-equivalence suite can assert byte-identical renders
/// across thread counts.
pub fn format_ppl_table(
    title: &str,
    results: &[CellResult],
    sizes: &[Size],
    settings: &[QuantConfig],
    methods: &[Method],
    flavor: Flavor,
) -> Table {
    let hdr = header(sizes);
    let mut t = Table::new(title, &hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for &q in settings {
        for &m in methods {
            for qep in [false, true] {
                let mut row = vec![
                    q.label(),
                    m.name().to_string(),
                    if qep { "yes" } else { "no" }.to_string(),
                ];
                for &s in sizes {
                    let v = results
                        .iter()
                        .find(|r| {
                            r.cell.size == s
                                && r.cell.method == m
                                && r.cell.quant == q
                                && r.cell.qep == qep
                        })
                        .and_then(|r| r.ppl.get(&flavor))
                        .copied()
                        .unwrap_or(f64::NAN);
                    row.push(fmt_ppl(v));
                }
                t.row(row);
            }
        }
        t.rule();
    }
    t
}

/// Format an accuracy table (Tables 2, 8, 9, 10). `family = None` means
/// the mean over all requested families (Table 2). Public for the same
/// reason as [`format_ppl_table`].
pub fn format_acc_table(
    title: &str,
    results: &[CellResult],
    sizes: &[Size],
    settings: &[QuantConfig],
    methods: &[Method],
    family: Option<TaskFamily>,
) -> Table {
    let hdr = header(sizes);
    let mut t = Table::new(title, &hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for &q in settings {
        for &m in methods {
            for qep in [false, true] {
                let mut row = vec![
                    q.label(),
                    m.name().to_string(),
                    if qep { "yes" } else { "no" }.to_string(),
                ];
                for &s in sizes {
                    let v = results
                        .iter()
                        .find(|r| {
                            r.cell.size == s
                                && r.cell.method == m
                                && r.cell.quant == q
                                && r.cell.qep == qep
                        })
                        .map(|r| match family {
                            Some(f) => *r.acc.get(&f).unwrap_or(&f64::NAN),
                            None => stats::mean(&r.acc.values().copied().collect::<Vec<_>>()),
                        })
                        .unwrap_or(f64::NAN);
                    row.push(fmt_acc(v));
                }
                t.row(row);
            }
        }
        t.rule();
    }
    t
}

/// Table 1 (+ Fig. 1 data): WikiText-analog PPL, per-channel INT4/3/2.
/// Table 2: zero-shot average accuracy for the same cells.
pub fn table1_and_2(env: &mut ExpEnv, sizes: &[Size]) -> Result<()> {
    let settings = [QuantConfig::int(4), QuantConfig::int(3), QuantConfig::int(2)];
    let methods = Method::all();
    let cells = matrix(sizes, &settings, &methods);
    let wants = Wants { ppl: vec![Flavor::Wiki], tasks: TaskFamily::all().to_vec() };
    let results = run_matrix(env, &cells, &wants)?;

    let t1 = format_ppl_table(
        "Table 1: perplexity (wiki analog) — lower is better",
        &results,
        sizes,
        &settings,
        &methods,
        Flavor::Wiki,
    );
    println!("{}", t1.render());
    persist("table1", &t1)?;

    let t2 = format_acc_table(
        "Table 2: zero-shot average accuracy (cloze/completion/pattern) — higher is better",
        &results,
        sizes,
        &settings,
        &methods,
        None,
    );
    println!("{}", t2.render());
    persist("table2", &t2)?;

    // Fig. 1 is the bar-chart view of Table 1; emit its CSV series.
    let mut fig1 = Table::new(
        "Figure 1 data: PPL bars (method, bits, size, base, qep)",
        &["method", "bits", "size", "ppl_base", "ppl_qep"],
    );
    for &q in &settings {
        for &m in &methods {
            for &s in sizes {
                let find = |qep: bool| {
                    results
                        .iter()
                        .find(|r| {
                            r.cell.size == s && r.cell.method == m && r.cell.quant == q && r.cell.qep == qep
                        })
                        .and_then(|r| r.ppl.get(&Flavor::Wiki))
                        .copied()
                        .unwrap_or(f64::NAN)
                };
                fig1.row(vec![
                    m.name().into(),
                    q.label(),
                    s.name().into(),
                    fmt_ppl(find(false)),
                    fmt_ppl(find(true)),
                ]);
            }
        }
    }
    println!("{}", fig1.render());
    persist("fig1", &fig1)?;
    Ok(())
}

/// Table 3: quantization runtime comparison (GPTQ vs AWQ vs QEP+RTN).
///
/// Cells run *serially* on purpose: this table's metric is the wall-clock
/// of each quantization, and fanning cells out would make them contend
/// for the same cores. The pipeline inside each cell still uses the full
/// pool, so the reported times reflect the parallel engine.
pub fn table3(env: &mut ExpEnv, sizes: &[Size]) -> Result<()> {
    let mut hdr = vec!["Runtime".to_string()];
    hdr.extend(sizes.iter().map(|s| s.name().to_string()));
    let mut t = Table::new(
        "Table 3: quantization-process runtime",
        &hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let rows: Vec<(&str, Method, bool)> = vec![
        ("GPTQ", Method::Gptq, false),
        ("AWQ", Method::Awq, false),
        ("QEP + RTN", Method::Rtn, true),
    ];
    let q = QuantConfig::int(3);
    for (label, method, qep) in rows {
        let mut row = vec![label.to_string()];
        for &s in sizes {
            let cell = Cell::new(s, method, q, qep);
            let out = cell.run(env)?;
            row.push(crate::util::fmt_duration(out.report.total_s));
            eprintln!(
                "[table3] {} {}: {} (correction {})",
                s.name(),
                label,
                crate::util::fmt_duration(out.report.total_s),
                crate::util::fmt_duration(out.report.correction_s())
            );
        }
        t.row(row);
    }
    println!("{}", t.render());
    persist("table3", &t)
}

/// Table 4: robustness to the calibration dataset. PPL (wiki eval) deltas
/// vs base RTN for GPTQ and QEP+RTN calibrated on c4/ptb/wiki. All seven
/// cells (the RTN reference plus method × calibration flavor) shard
/// across the pool.
pub fn table4(env: &mut ExpEnv, size: Size) -> Result<()> {
    let q = QuantConfig::int(3);
    let data = env.snapshot(&[size]);
    let flavors = [Flavor::C4, Flavor::Ptb, Flavor::Wiki];
    let variants = [("GPTQ", Method::Gptq, false), ("QEP + RTN", Method::Rtn, true)];
    // cells[0] = the calibration-free RTN reference, then method × flavor.
    let mut cells = vec![Cell::new(size, Method::Rtn, q, false)];
    for &(_, method, qep) in &variants {
        for &fl in &flavors {
            let mut cell = Cell::new(size, method, q, qep);
            cell.calib_flavor = fl;
            cells.push(cell);
        }
    }
    let pool = pool::global();
    let ppls: Vec<f64> =
        run_jobs(&pool, cells.len(), |i| cell_ppl_on(&data, &cells[i], Flavor::Wiki))
            .into_iter()
            .collect::<Result<_>>()?;
    let rtn = ppls[0];
    let mut t = Table::new(
        &format!("Table 4: PPL relative to RTN ({}; eval=wiki; RTN={:.3})", size.name(), rtn),
        &["Method", "calib=C4", "calib=PTB", "calib=WikiText2"],
    );
    for (vi, &(label, _, _)) in variants.iter().enumerate() {
        let mut row = vec![label.to_string()];
        for fi in 0..flavors.len() {
            let ppl = ppls[1 + vi * flavors.len() + fi];
            row.push(format!("{:+.3}", ppl - rtn));
        }
        t.row(row);
    }
    println!("{}", t.render());
    persist("table4", &t)
}

/// Ablation (DESIGN.md §6, Prop. 5.4 empirically): PPL as a function of
/// the propagation strength α for RTN INT3 — the knob §5.3 introduces.
/// The α × size grid shards across the pool; every cell draws the same
/// seed-0 calibration slice so α is the only moving part.
pub fn ablation_alpha(env: &mut ExpEnv, sizes: &[Size]) -> Result<()> {
    let alphas = [0.0f32, 0.25, 0.5, 0.75, 1.0];
    let data = env.snapshot(sizes);
    let mut jobs = Vec::new();
    for &a in &alphas {
        for &s in sizes {
            jobs.push((a, s));
        }
    }
    let pool = pool::global();
    let vals: Vec<f64> = run_jobs(&pool, jobs.len(), |i| -> Result<f64> {
            let (a, s) = jobs[i];
            let model = data.model(s);
            let calib = data.calib_tokens(Flavor::C4, model.cfg.seq_len, 0);
            let mut cfg = Cell::new(s, Method::Rtn, QuantConfig::int(3), a > 0.0).pipeline_config();
            cfg.qep_alpha = Some(a); // α=0 ⇒ effectively BASE via short-circuit
            cfg.alpha_policy = None; // uniform α even for tiny-l here
            let out = crate::coordinator::Pipeline::new(cfg).run(model, &calib)?;
            let eval = data.eval_tokens(Flavor::Wiki);
            Ok(perplexity(&out.model, &eval))
        })
        .into_iter()
        .collect::<Result<_>>()?;

    let mut hdr = vec!["alpha".to_string()];
    hdr.extend(sizes.iter().map(|s| s.name().to_string()));
    let mut t = Table::new(
        "Ablation: wiki PPL vs propagation strength α (RTN INT3)",
        &hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for (ai, &a) in alphas.iter().enumerate() {
        let mut row = vec![format!("{a:.2}")];
        for si in 0..sizes.len() {
            row.push(fmt_ppl(vals[ai * sizes.len() + si]));
        }
        t.row(row);
    }
    println!("{}", t.render());
    persist("ablation_alpha", &t)
}

/// Tables 5–7: PPL under the eight grid settings on wiki/ptb/c4 evals.
/// Tables 8–10: per-task accuracy for the same cells.
/// One pass covers all six tables (methods: RTN/GPTQ/AWQ as in appendix).
pub fn appendix_tables(env: &mut ExpEnv, sizes: &[Size], settings: &[QuantConfig]) -> Result<()> {
    let methods = [Method::Rtn, Method::Gptq, Method::Awq];
    let cells = matrix(sizes, settings, &methods);
    let wants = Wants { ppl: Flavor::all().to_vec(), tasks: TaskFamily::all().to_vec() };
    let results = run_matrix(env, &cells, &wants)?;

    for (idx, flavor, label) in [
        (5, Flavor::Wiki, "WikiText-2 analog"),
        (6, Flavor::Ptb, "PTB analog"),
        (7, Flavor::C4, "C4 analog"),
    ] {
        let t = format_ppl_table(
            &format!("Table {idx}: perplexity on {label}, eight grid settings"),
            &results,
            sizes,
            settings,
            &methods,
            flavor,
        );
        println!("{}", t.render());
        persist(&format!("table{idx}"), &t)?;
    }
    for (idx, family) in [
        (8, TaskFamily::Cloze),
        (9, TaskFamily::Completion),
        (10, TaskFamily::Pattern),
    ] {
        let t = format_acc_table(
            &format!(
                "Table {idx}: accuracy on {} ({} analog), eight grid settings",
                family.name(),
                family.paper_analog()
            ),
            &results,
            sizes,
            settings,
            &methods,
            Some(family),
        );
        println!("{}", t.render());
        persist(&format!("table{idx}"), &t)?;
    }
    Ok(())
}
