//! Fig. 2: accumulation and growth of quantization error across blocks.
//! Quantize the first `n` blocks (paper: 10 of 32; we default to half the
//! model) with RTN, base vs +QEP, and report Δ_m (Eq. 2) per block. Each
//! run saturates the pool internally (GEMMs, SPD solves, per-layer
//! fan-out); see the comment at the call sites for why the two variants
//! are not themselves fanned out.

use super::common::{persist, ExpEnv};
use crate::coordinator::{Pipeline, PipelineConfig};
use crate::eval::delta_per_block;
use crate::model::Size;
use crate::quant::{Method, QuantConfig};
use crate::text::Flavor;
use crate::util::table::Table;
use anyhow::Result;

pub struct Fig2Result {
    pub deltas_base: Vec<f64>,
    pub deltas_qep: Vec<f64>,
    pub n_quantized: usize,
}

pub fn run(env: &mut ExpEnv, size: Size, bits: u32, n_blocks: Option<usize>) -> Result<Fig2Result> {
    let model = env.model(size);
    let n = n_blocks.unwrap_or(model.cfg.n_layers / 2).min(model.cfg.n_layers);
    let calib = env.calib_tokens(Flavor::C4, model.cfg.seq_len, 0);
    let probe = env.eval_tokens(Flavor::Wiki);
    let probe = &probe[..(8 * model.cfg.seq_len).min(probe.len())];

    let run_one = |qep: Option<f32>| -> Result<Vec<f64>> {
        let out = Pipeline::new(PipelineConfig {
            quant: QuantConfig::int(bits),
            method: Method::Rtn,
            qep_alpha: qep,
            max_blocks: Some(n),
            ..Default::default()
        })
        .run(&model, &calib)?;
        Ok(delta_per_block(&model, &out.model, probe))
    };

    // The two variants run sequentially on purpose: fanning just 2 jobs
    // across the pool would mark both workers as in-pool and serialize
    // every GEMM/SPD solve *inside* each pipeline — at ≥4 threads the
    // inner row-level parallelism is the much wider axis, so each run
    // gets the whole pool instead.
    let deltas_base = run_one(None)?;
    let deltas_qep = run_one(Some(0.5))?;

    let mut t = Table::new(
        &format!(
            "Figure 2: Δ_m per block ({}, INT{bits}, first {n} of {} blocks quantized, RTN)",
            size.name(),
            model.cfg.n_layers
        ),
        &["block m", "quantized?", "Δ_m BASE", "Δ_m +QEP", "ratio"],
    );
    for (i, (b, q)) in deltas_base.iter().zip(deltas_qep.iter()).enumerate() {
        t.row(vec![
            format!("{}", i + 1),
            if i < n { "yes" } else { "no" }.to_string(),
            format!("{b:.4e}"),
            format!("{q:.4e}"),
            format!("{:.2}x", b / q.max(1e-30)),
        ]);
    }
    println!("{}", t.render());
    persist("fig2", &t)?;
    Ok(Fig2Result { deltas_base, deltas_qep, n_quantized: n })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_shows_growth_and_qep_reduction() {
        let mut env = ExpEnv::new("/nonexistent-artifacts");
        let r = run(&mut env, Size::TinyS, 2, Some(2)).unwrap();
        assert_eq!(r.deltas_base.len(), 4);
        // Error persists into the unquantized blocks.
        assert!(r.deltas_base[2] > 0.0 && r.deltas_base[3] > 0.0);
        // QEP reduces the final-block error (the paper's headline of Fig 2).
        let last = r.deltas_base.len() - 1;
        assert!(
            r.deltas_qep[last] < r.deltas_base[last],
            "QEP {} !< BASE {}",
            r.deltas_qep[last],
            r.deltas_base[last]
        );
    }
}
