//! Fig. 2: accumulation and growth of quantization error across blocks.
//! Quantize the first `n` blocks (paper: 10 of 32; we default to half the
//! model) with RTN, base vs +QEP, and report Δ_m (Eq. 2) per block. The
//! two variants are two plan cells (`fig2/<size>/INT<b>/b<n>/{base,+qep}`)
//! whose records carry the per-block deltas; the render stage pairs them
//! back up by identity, so the figure merges byte-identically from any
//! shard split. Each variant's pipeline saturates the pool internally
//! (GEMMs, SPD solves, per-layer fan-out).

use super::common::{self, persist_to, ExpEnv, RenderCfg};
use super::plan::{self, CellTask, PlanCell, PlanParams, RecordMap, SweepId};
use crate::model::Size;
use crate::util::table::Table;
use anyhow::Result;

pub struct Fig2Result {
    pub deltas_base: Vec<f64>,
    pub deltas_qep: Vec<f64>,
    pub n_quantized: usize,
}

/// Render the Fig. 2 table from the two variant records.
pub fn render(params: &PlanParams, recs: &RecordMap, rcfg: &RenderCfg) -> Result<Fig2Result> {
    let pc = |qep: bool| PlanCell {
        sweep: SweepId::Fig2,
        task: CellTask::Fig2 {
            size: params.fig2_size,
            bits: params.fig2_bits,
            n_blocks: params.fig2_blocks,
            qep,
        },
    };
    let deltas_base = recs.get(&pc(false))?.deltas.clone();
    let deltas_qep = recs.get(&pc(true))?.deltas.clone();
    let n = params.fig2_blocks;
    let total = deltas_base.len();

    let mut t = Table::new(
        &format!(
            "Figure 2: Δ_m per block ({}, INT{}, first {n} of {total} blocks quantized, RTN)",
            params.fig2_size.name(),
            params.fig2_bits,
        ),
        &["block m", "quantized?", "Δ_m BASE", "Δ_m +QEP", "ratio"],
    );
    for (i, (b, q)) in deltas_base.iter().zip(deltas_qep.iter()).enumerate() {
        t.row(vec![
            format!("{}", i + 1),
            if i < n { "yes" } else { "no" }.to_string(),
            format!("{b:.4e}"),
            format!("{q:.4e}"),
            format!("{:.2}x", b / q.max(1e-30)),
        ]);
    }
    println!("{}", t.render());
    persist_to(&rcfg.results_dir, "fig2", &t)?;
    Ok(Fig2Result { deltas_base, deltas_qep, n_quantized: n })
}

/// Single-process driver (enumerate → run → render in one call).
pub fn run(env: &mut ExpEnv, size: Size, bits: u32, n_blocks: Option<usize>) -> Result<Fig2Result> {
    let mut params = PlanParams::for_sizes(&[size]);
    params.fig2_size = size;
    params.fig2_bits = bits;
    params.fig2_blocks = plan::resolve_fig2_blocks(size, n_blocks);
    // run_sweep renders (and returns records in manifest order: base
    // first, then +qep); rebuild the typed result from the records.
    let records = common::run_sweep(env, SweepId::Fig2, &params, &RenderCfg::default())?;
    Ok(Fig2Result {
        deltas_base: records[0].deltas.clone(),
        deltas_qep: records[1].deltas.clone(),
        n_quantized: params.fig2_blocks,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_shows_growth_and_qep_reduction() {
        let mut env = ExpEnv::new("/nonexistent-artifacts");
        let r = run(&mut env, Size::TinyS, 2, Some(2)).unwrap();
        assert_eq!(r.deltas_base.len(), 4);
        // Error persists into the unquantized blocks.
        assert!(r.deltas_base[2] > 0.0 && r.deltas_base[3] > 0.0);
        // QEP reduces the final-block error (the paper's headline of Fig 2).
        let last = r.deltas_base.len() - 1;
        assert!(
            r.deltas_qep[last] < r.deltas_base[last],
            "QEP {} !< BASE {}",
            r.deltas_qep[last],
            r.deltas_base[last]
        );
    }
}
