//! Shared experiment plumbing: model/corpus loading with fallbacks, the
//! quantize→evaluate cell runner, and result persistence.

use crate::coordinator::{Pipeline, PipelineConfig, PipelineOutput};
use crate::eval::{perplexity, TaskFamily, TaskSet};
use crate::model::{Model, Size};
use crate::qep::AlphaPolicy;
use crate::quant::{Method, QuantConfig};
use crate::runtime::ArtifactRegistry;
use crate::text::{Corpus, Flavor};
use anyhow::Result;
use std::collections::HashMap;

/// Calibration/eval token budgets (scaled-down analogs of the paper's
/// 128×2048-token calibration set).
pub const CALIB_SEGMENTS: usize = 16;
pub const EVAL_TOKENS: usize = 8 * 1024;
pub const TASKS_PER_FAMILY: usize = 32;

/// Experiment environment: loads trained models from artifacts when
/// available, otherwise falls back to deterministic random-weight models
/// (clearly labelled) so the drivers always run.
pub struct ExpEnv {
    pub reg: ArtifactRegistry,
    models: HashMap<String, Model>,
    corpora: HashMap<Flavor, Corpus>,
    pub used_fallback: bool,
}

impl ExpEnv {
    pub fn new(root: &str) -> ExpEnv {
        ExpEnv {
            reg: ArtifactRegistry::new(root),
            models: HashMap::new(),
            corpora: HashMap::new(),
            used_fallback: false,
        }
    }

    pub fn model(&mut self, size: Size) -> Model {
        let name = size.name().to_string();
        if let Some(m) = self.models.get(&name) {
            return m.clone();
        }
        let m = match self.reg.load_model(&name) {
            Ok(m) => m,
            Err(_) => {
                self.used_fallback = true;
                eprintln!("[exp] WARNING: {name}.qtz missing — using random weights (run `make artifacts`)");
                Model::random(&size.config(), 0xBEEF)
            }
        };
        self.models.insert(name, m.clone());
        m
    }

    pub fn corpus(&mut self, flavor: Flavor) -> Corpus {
        if let Some(c) = self.corpora.get(&flavor) {
            return Corpus { flavor: c.flavor, text: c.text.clone(), tokens: c.tokens.clone() };
        }
        let c = match self.reg.load_corpus(flavor) {
            Ok(c) => c,
            Err(_) => Corpus::generate(flavor, 256 * 1024, 0),
        };
        self.corpora.insert(flavor, Corpus { flavor: c.flavor, text: c.text.clone(), tokens: c.tokens.clone() });
        c
    }

    /// Calibration tokens for a flavor + seed (disjoint from eval split:
    /// calibration reads from the front, eval from the back).
    pub fn calib_tokens(&mut self, flavor: Flavor, seq_len: usize, seed: u64) -> Vec<u32> {
        let c = self.corpus(flavor);
        let need = CALIB_SEGMENTS * seq_len;
        let offset = (seed as usize * 7919 * seq_len) % c.tokens.len().saturating_sub(2 * need).max(1);
        c.tokens[offset..offset + need].to_vec()
    }

    /// Evaluation tokens (tail of the corpus — disjoint from calibration
    /// for reasonable seeds).
    pub fn eval_tokens(&mut self, flavor: Flavor) -> Vec<u32> {
        let c = self.corpus(flavor);
        let n = EVAL_TOKENS.min(c.tokens.len() / 2);
        c.tokens[c.tokens.len() - n..].to_vec()
    }
}

/// One experiment cell: a (model, method, grid, ±QEP) configuration.
#[derive(Clone, Debug)]
pub struct Cell {
    pub size: Size,
    pub method: Method,
    pub quant: QuantConfig,
    pub qep: bool,
    pub seed: u64,
    pub calib_flavor: Flavor,
}

impl Cell {
    pub fn new(size: Size, method: Method, quant: QuantConfig, qep: bool) -> Cell {
        Cell { size, method, quant, qep, seed: 0, calib_flavor: default_calib(method) }
    }

    /// Build the pipeline config for this cell, mirroring the paper's
    /// defaults: α = 1/2 everywhere, α = 0 on the MLPs of the largest model.
    pub fn pipeline_config(&self) -> PipelineConfig {
        let alpha_policy = if self.qep && self.size == Size::TinyL {
            Some(AlphaPolicy::paper_large_model())
        } else {
            None
        };
        PipelineConfig {
            quant: self.quant,
            method: self.method,
            qep_alpha: if self.qep { Some(0.5) } else { None },
            alpha_policy,
            damp_rel: 1.0,
            max_blocks: None,
            seed: self.seed,
            verbose: false,
            threads: 0,
        }
    }

    /// Quantize the model for this cell.
    pub fn run(&self, env: &mut ExpEnv) -> Result<PipelineOutput> {
        let model = env.model(self.size);
        let calib = env.calib_tokens(self.calib_flavor, model.cfg.seq_len, self.seed);
        Pipeline::new(self.pipeline_config()).run(&model, &calib)
    }

    pub fn label(&self) -> String {
        format!(
            "{} {} {} {}",
            self.size.name(),
            self.quant.label(),
            self.method.name(),
            if self.qep { "+QEP" } else { "base" }
        )
    }
}

/// The calibration dataset each method used in the paper (§6 Datasets):
/// GPTQ/QuIP → C4, AWQ → Pile (we map Pile→C4 flavor too; RTN needs none
/// but QEP+RTN evaluates the correction on C4).
pub fn default_calib(_method: Method) -> Flavor {
    Flavor::C4
}

/// Quantize + evaluate perplexity on a flavor.
pub fn cell_ppl(env: &mut ExpEnv, cell: &Cell, eval_flavor: Flavor) -> Result<f64> {
    let out = cell.run(env)?;
    let eval = env.eval_tokens(eval_flavor);
    Ok(perplexity(&out.model, &eval))
}

/// Quantize + evaluate zero-shot accuracy averaged over families.
pub fn cell_task_acc(env: &mut ExpEnv, cell: &Cell, families: &[TaskFamily]) -> Result<Vec<f64>> {
    let out = cell.run(env)?;
    let corpus = env.corpus(Flavor::Wiki);
    families
        .iter()
        .map(|&fam| {
            let ts = TaskSet::generate(fam, &corpus, TASKS_PER_FAMILY, 1234);
            Ok(ts.accuracy(&out.model))
        })
        .collect()
}

/// Write table text + csv under `results/`.
pub fn persist(name: &str, table: &crate::util::table::Table) -> Result<()> {
    std::fs::create_dir_all("results")?;
    std::fs::write(format!("results/{name}.txt"), table.render())?;
    std::fs::write(format!("results/{name}.csv"), table.to_csv())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_falls_back_to_random_models() {
        let mut env = ExpEnv::new("/nonexistent-artifacts");
        let m = env.model(Size::TinyS);
        assert!(env.used_fallback);
        m.validate().unwrap();
        // Cached on second access.
        let m2 = env.model(Size::TinyS);
        assert_eq!(m.blocks[0].wq, m2.blocks[0].wq);
    }

    #[test]
    fn calib_and_eval_splits_are_disjoint() {
        let mut env = ExpEnv::new("/nonexistent-artifacts");
        let calib = env.calib_tokens(Flavor::Wiki, 128, 0);
        let eval = env.eval_tokens(Flavor::Wiki);
        assert_eq!(calib.len(), CALIB_SEGMENTS * 128);
        assert!(eval.len() >= 1024);
        // Disjoint by construction: calib from the front region, eval tail.
        let c = env.corpus(Flavor::Wiki);
        assert!(c.tokens.len() > calib.len() + eval.len());
    }

    #[test]
    fn cell_labels_are_informative() {
        let cell = Cell::new(Size::TinyS, Method::Gptq, QuantConfig::int(3), true);
        assert_eq!(cell.label(), "tiny-s INT3 GPTQ +QEP");
    }

    #[test]
    fn tiny_l_gets_mlp_alpha_zero() {
        let cell = Cell::new(Size::TinyL, Method::Rtn, QuantConfig::int(4), true);
        let cfg = cell.pipeline_config();
        let p = cfg.alpha_policy.unwrap();
        assert_eq!(p.alpha_for("blocks.0.mlp.down"), 0.0);
        let cell_s = Cell::new(Size::TinyS, Method::Rtn, QuantConfig::int(4), true);
        assert!(cell_s.pipeline_config().alpha_policy.is_none());
    }
}
