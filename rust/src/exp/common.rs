//! Shared experiment plumbing: model/corpus loading with fallbacks, the
//! quantize→evaluate cell runner, and result persistence.
//!
//! Sharding model: [`ExpEnv`] owns the mutable caches (artifact loading,
//! fallback bookkeeping) and is *not* shared across workers. A sweep first
//! takes an immutable [`ExpData`] snapshot (models + corpora), then fans
//! independent cells out over the pool via [`Cell::run_on`]. Every cell
//! derives its calibration/pipeline seed from its own identity
//! ([`Cell::derived_seed`]), so results do not depend on which worker runs
//! which cell or in what order — sweeps are bit-identical for every
//! thread count.

use super::plan::{self, CellTask, PlanCell, PlanParams, RecordMap, SweepId};
use crate::coordinator::{Pipeline, PipelineConfig, PipelineOutput};
use crate::eval::{delta_per_block, perplexity, TaskFamily, TaskSet};
use crate::io::results::{read_records_tolerant, CellRecord, RecordAppender, TornTail};
use crate::model::{Model, Size};
use crate::qep::AlphaPolicy;
use crate::quant::{BudgetSpec, Method, QuantConfig};
use crate::runtime::ArtifactRegistry;
use crate::text::{Corpus, Flavor};
use crate::util::pool::{self, Pool};
use crate::util::Stopwatch;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::{Mutex, OnceLock};

/// Calibration/eval token budgets (scaled-down analogs of the paper's
/// 128×2048-token calibration set).
pub const CALIB_SEGMENTS: usize = 16;
pub const EVAL_TOKENS: usize = 8 * 1024;
pub const TASKS_PER_FAMILY: usize = 32;

/// Start offset of the calibration window in a corpus of `len` tokens:
/// spread over `[0, len − need − EVAL_TOKENS)` so the whole window stays
/// out of the [`EVAL_TOKENS`]-sized tail that [`eval_slice`] reads — for
/// *every* seed, because name-derived seeds are uniform full-width hashes
/// ("small seeds stay near the front" no longer holds). Shared by
/// [`calib_slice`] and the guard test so the two cannot drift apart.
pub fn calib_offset(len: usize, seq_len: usize, seed: u64) -> usize {
    let need = CALIB_SEGMENTS * seq_len;
    let span = len.saturating_sub(need + EVAL_TOKENS).max(1);
    // Full-width hashed seeds: wrap instead of overflowing.
    (seed as usize).wrapping_mul(7919).wrapping_mul(seq_len) % span
}

/// Calibration tokens from a corpus for a seed. Pure function of
/// (corpus, seq_len, seed) so sharded cells can draw their streams
/// without touching shared mutable state.
///
/// Disjointness contract: whenever the corpus holds a calibration window
/// plus the eval tail (`len ≥ CALIB_SEGMENTS·seq_len + EVAL_TOKENS`), the
/// window never overlaps [`eval_slice`]'s tail, for every seed (see
/// [`calib_offset`]). Shorter corpora fall back to the front and *may*
/// overlap the (also shrunken) eval split; a corpus smaller than one
/// calibration window is a hard error.
pub fn calib_slice(c: &Corpus, seq_len: usize, seed: u64) -> Vec<u32> {
    let need = CALIB_SEGMENTS * seq_len;
    let offset = calib_offset(c.tokens.len(), seq_len, seed);
    assert!(
        offset + need <= c.tokens.len(),
        "corpus too small for calibration: {} tokens < {need} needed",
        c.tokens.len()
    );
    c.tokens[offset..offset + need].to_vec()
}

/// Evaluation tokens: the [`EVAL_TOKENS`]-sized tail of the corpus.
/// Disjoint from [`calib_slice`]'s window for *every* seed whenever the
/// corpus holds both (see [`calib_offset`]).
pub fn eval_slice(c: &Corpus) -> Vec<u32> {
    let n = EVAL_TOKENS.min(c.tokens.len() / 2);
    c.tokens[c.tokens.len() - n..].to_vec()
}

/// Run `n` independent experiment jobs, either sharded across `pool`
/// (when there are at least as many jobs as workers) or serially with
/// each job keeping the *whole* pool for its inner kernels (when jobs are
/// scarcer than workers — outer fan-out would mark every worker as
/// in-pool, serialize the nested GEMM/SPD engines, and idle the remaining
/// cores). Results come back in job order and are bit-identical either
/// way; only wall-clock differs.
pub fn run_jobs<T, F>(pool: &Pool, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n >= pool.threads() {
        pool.par_map(n, f)
    } else {
        (0..n).map(f).collect()
    }
}

/// Experiment environment: loads trained models from artifacts when
/// available, otherwise falls back to deterministic random-weight models
/// (clearly labelled) so the drivers always run.
pub struct ExpEnv {
    pub reg: ArtifactRegistry,
    models: HashMap<String, Model>,
    corpora: HashMap<Flavor, Corpus>,
    pub used_fallback: bool,
    fallback_models: BTreeSet<String>,
}

impl ExpEnv {
    pub fn new(root: &str) -> ExpEnv {
        ExpEnv {
            reg: ArtifactRegistry::new(root),
            models: HashMap::new(),
            corpora: HashMap::new(),
            used_fallback: false,
            fallback_models: BTreeSet::new(),
        }
    }

    pub fn model(&mut self, size: Size) -> Model {
        let name = size.name().to_string();
        if let Some(m) = self.models.get(&name) {
            return m.clone();
        }
        let m = match self.reg.load_model(&name) {
            Ok(m) => m,
            Err(_) => {
                self.used_fallback = true;
                self.fallback_models.insert(name.clone());
                eprintln!("[exp] WARNING: {name}.qtz missing — using random weights (run `make artifacts`)");
                Model::random(&size.config(), 0xBEEF)
            }
        };
        self.models.insert(name, m.clone());
        m
    }

    pub fn corpus(&mut self, flavor: Flavor) -> Corpus {
        if let Some(c) = self.corpora.get(&flavor) {
            return c.clone();
        }
        let c = match self.reg.load_corpus(flavor) {
            Ok(c) => c,
            Err(_) => Corpus::generate(flavor, 256 * 1024, 0),
        };
        self.corpora.insert(flavor, c.clone());
        c
    }

    /// Calibration tokens for a flavor + seed (see [`calib_slice`]).
    pub fn calib_tokens(&mut self, flavor: Flavor, seq_len: usize, seed: u64) -> Vec<u32> {
        let c = self.corpus(flavor);
        calib_slice(&c, seq_len, seed)
    }

    /// Evaluation tokens (see [`eval_slice`]).
    pub fn eval_tokens(&mut self, flavor: Flavor) -> Vec<u32> {
        let c = self.corpus(flavor);
        eval_slice(&c)
    }

    /// Immutable snapshot of everything a sharded sweep needs: the models
    /// for `sizes` (loading/falling back now, so warnings print once,
    /// before the fan-out) and all corpus flavors. Workers read the
    /// snapshot concurrently; the env's caches stay warm for later calls.
    /// All flavors are included deliberately (a few MB of clones) so
    /// [`ExpData::corpus`] can never hit its missing-flavor panic no
    /// matter which eval/calib flavors a driver's cells request.
    pub fn snapshot(&mut self, sizes: &[Size]) -> ExpData {
        let mut models = HashMap::new();
        for &s in sizes {
            models.insert(s.name().to_string(), self.model(s));
        }
        let mut corpora = HashMap::new();
        for f in Flavor::all() {
            corpora.insert(f, self.corpus(f));
        }
        ExpData {
            models,
            corpora,
            fallback: self.fallback_models.clone(),
            task_sets: Default::default(),
        }
    }
}

/// Read-only inputs for a sharded sweep; see [`ExpEnv::snapshot`].
pub struct ExpData {
    models: HashMap<String, Model>,
    corpora: HashMap<Flavor, Corpus>,
    /// Model names that fell back to deterministic random weights
    /// because the trained artifact was missing (tagged per result
    /// record so merged sweeps can surface the warning).
    fallback: BTreeSet<String>,
    /// Lazily-built shared task sets, one per family (in
    /// `TaskFamily::all()` order). Task sets are cell-independent pure
    /// functions of the wiki corpus, so every cell scores against the
    /// same instance instead of regenerating it.
    task_sets: [OnceLock<TaskSet>; 3],
}

impl ExpData {
    /// Assemble a snapshot directly (tests inject custom tiny models under
    /// a size's name to keep sharded-sweep tests fast).
    pub fn from_parts(models: HashMap<String, Model>, corpora: HashMap<Flavor, Corpus>) -> ExpData {
        ExpData { models, corpora, fallback: BTreeSet::new(), task_sets: Default::default() }
    }

    /// The snapshot's shared task set for `family` (built on first use;
    /// deterministic, so when a task ran it never matters).
    pub fn task_set(&self, family: TaskFamily) -> &TaskSet {
        let idx = TaskFamily::all()
            .iter()
            .position(|&f| f == family)
            .expect("every family is in TaskFamily::all()");
        self.task_sets[idx].get_or_init(|| {
            TaskSet::generate(family, self.corpus(Flavor::Wiki), TASKS_PER_FAMILY, 1234)
        })
    }

    /// Whether `size`'s model in this snapshot is a random-weight
    /// fallback (results are structural only).
    pub fn is_fallback(&self, size: Size) -> bool {
        self.fallback.contains(size.name())
    }

    /// The snapshot's model for `size`. Panics if the snapshot was taken
    /// without it — a driver bug, not a runtime condition.
    pub fn model(&self, size: Size) -> &Model {
        self.models
            .get(size.name())
            .unwrap_or_else(|| panic!("model '{}' missing from snapshot", size.name()))
    }

    /// The snapshot's corpus for `flavor`.
    pub fn corpus(&self, flavor: Flavor) -> &Corpus {
        self.corpora
            .get(&flavor)
            .unwrap_or_else(|| panic!("corpus '{}' missing from snapshot", flavor.name()))
    }

    pub fn calib_tokens(&self, flavor: Flavor, seq_len: usize, seed: u64) -> Vec<u32> {
        calib_slice(self.corpus(flavor), seq_len, seed)
    }

    pub fn eval_tokens(&self, flavor: Flavor) -> Vec<u32> {
        eval_slice(self.corpus(flavor))
    }
}

/// One experiment cell: a (model, method, grid, ±QEP) configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct Cell {
    pub size: Size,
    pub method: Method,
    pub quant: QuantConfig,
    pub qep: bool,
    /// Replicate index (Fig. 3's seed axis); folded with the cell identity
    /// into [`Cell::derived_seed`] for the actual streams.
    pub seed: u64,
    pub calib_flavor: Flavor,
    /// Rank of the low-rank error-reconstruction adjunct (LQER/QERA);
    /// 0 = none. A compared axis like method/bits/±QEP: deliberately NOT
    /// part of [`Cell::derived_seed`], so `±lowrank` twins share their
    /// calibration stream.
    pub lowrank_rank: usize,
    /// Mixed-precision bit budget (`quant::budget`); `None` = uniform
    /// `quant.bits`. Also a compared axis — deliberately NOT part of
    /// [`Cell::derived_seed`], so allocated cells share their calibration
    /// stream with their uniform-bits twins.
    pub budget: Option<BudgetSpec>,
    /// CBQ cross-block window (`1` = layer-wise). A compared axis like
    /// method/bits/±QEP: deliberately NOT part of [`Cell::derived_seed`],
    /// so every window size shares its calibration stream with the
    /// layer-wise baseline.
    pub cbq_window: usize,
}

impl Cell {
    pub fn new(size: Size, method: Method, quant: QuantConfig, qep: bool) -> Cell {
        Cell {
            size,
            method,
            quant,
            qep,
            seed: 0,
            calib_flavor: default_calib(method),
            lowrank_rank: 0,
            budget: None,
            cbq_window: 1,
        }
    }

    /// Scheduling-independent seed for this cell's calibration draw and
    /// pipeline randomness: an FNV-1a hash of the cell's *data identity*
    /// (model size + calibration flavor) folded with the explicit
    /// replicate `seed`. Deliberately NOT a function of method/bits/±QEP:
    /// cells that differ only along a compared axis share the identical
    /// calibration window and per-layer randomness (the paper calibrates
    /// all methods on the same set, and Fig. 3's QuIP±QEP pairs must share
    /// rotations), while sharded sweeps stay bit-identical no matter which
    /// worker runs which cell.
    pub fn derived_seed(&self) -> u64 {
        crate::util::fnv1a(&format!("{}|{}", self.size.name(), self.calib_flavor.name()))
            ^ self.seed
    }

    /// Build the pipeline config for this cell, mirroring the paper's
    /// defaults: α = 1/2 everywhere, α = 0 on the MLPs of the largest model.
    pub fn pipeline_config(&self) -> PipelineConfig {
        let alpha_policy = if self.qep && self.size == Size::TinyL {
            Some(AlphaPolicy::paper_large_model())
        } else {
            None
        };
        PipelineConfig {
            quant: self.quant,
            method: self.method,
            qep_alpha: if self.qep { Some(0.5) } else { None },
            alpha_policy,
            damp_rel: 1.0,
            max_blocks: None,
            lowrank_rank: self.lowrank_rank,
            bit_budget: self.budget,
            cbq_window: self.cbq_window,
            seed: self.derived_seed(),
            verbose: false,
            threads: 0,
        }
    }

    /// Quantize the model for this cell straight off the env's caches (no
    /// snapshot clone — the single-cell path; sweeps use [`Cell::run_on`]
    /// against a shared snapshot instead).
    pub fn run(&self, env: &mut ExpEnv) -> Result<PipelineOutput> {
        let model = env.model(self.size);
        let calib = env.calib_tokens(self.calib_flavor, model.cfg.seq_len, self.derived_seed());
        Pipeline::new(self.pipeline_config()).run(&model, &calib)
    }

    /// Quantize the model for this cell against an immutable snapshot —
    /// the unit of work a sharded sweep hands to pool workers.
    pub fn run_on(&self, data: &ExpData) -> Result<PipelineOutput> {
        let model = data.model(self.size);
        let calib = data.calib_tokens(self.calib_flavor, model.cfg.seq_len, self.derived_seed());
        Pipeline::new(self.pipeline_config()).run(model, &calib)
    }

    pub fn label(&self) -> String {
        let mut label = format!(
            "{} {} {} {}",
            self.size.name(),
            self.quant.label(),
            self.method.name(),
            if self.qep { "+QEP" } else { "base" }
        );
        if self.lowrank_rank > 0 {
            label.push_str(&format!(" +LR{}", self.lowrank_rank));
        }
        if let Some(spec) = &self.budget {
            label.push_str(&format!(" B{}/{}", spec.budget.render(), spec.alloc.name()));
        }
        if self.cbq_window > 1 {
            label.push_str(&format!(" W{}", self.cbq_window));
        }
        label
    }
}

/// The calibration dataset each method used in the paper (§6 Datasets):
/// GPTQ/QuIP → C4, AWQ → Pile (we map Pile→C4 flavor too; RTN needs none
/// but QEP+RTN evaluates the correction on C4).
pub fn default_calib(_method: Method) -> Flavor {
    Flavor::C4
}

/// Write table text + csv under `results/`.
pub fn persist(name: &str, table: &crate::util::table::Table) -> Result<()> {
    persist_to("results", name, table)
}

/// Write table text + csv under an explicit results directory (the
/// merge collector and tests render away from the default `results/`).
pub fn persist_to(dir: &str, name: &str, table: &crate::util::table::Table) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(format!("{dir}/{name}.txt"), table.render())?;
    std::fs::write(format!("{dir}/{name}.csv"), table.to_csv())?;
    Ok(())
}

/// Where and how to render sweep outputs.
#[derive(Clone, Debug)]
pub struct RenderCfg {
    /// Directory for the persisted `.txt`/`.csv` artifacts.
    pub results_dir: String,
    /// Render wall-clock cells (Table 3) as a stable placeholder so the
    /// output bytes are machine-independent — the CI determinism gate
    /// and the local shard/merge tests compare renders byte-for-byte,
    /// and timings are the one non-deterministic metric.
    pub stable_timings: bool,
}

impl Default for RenderCfg {
    fn default() -> Self {
        RenderCfg { results_dir: "results".to_string(), stable_timings: false }
    }
}

/// Execute one plan cell against a snapshot — the unit of work of the
/// distributed runner. Pure up to wall-clock: the metrics in the
/// returned record depend only on (cell identity, snapshot), never on
/// which process, shard, worker, or schedule ran it.
pub fn run_plan_cell(
    data: &ExpData,
    pc: &PlanCell,
    shard: usize,
    n_shards: usize,
) -> Result<CellRecord> {
    let sw = Stopwatch::start();
    let mut rec = CellRecord::new(pc.id(), shard, n_shards);
    rec.fallback = data.is_fallback(pc.size());
    match &pc.task {
        CellTask::Quant(cell) => {
            let out = cell.run_on(data)?;
            let (ppl_flavors, families) = plan::wants(pc.sweep);
            for fl in ppl_flavors {
                let eval = data.eval_tokens(fl);
                rec.ppl.push((fl.name().to_string(), perplexity(&out.model, &eval)));
            }
            for fam in families {
                let ts = data.task_set(fam);
                rec.acc.push((fam.name().to_string(), ts.accuracy(&out.model)));
            }
            rec.timings = out.report.timings();
        }
        CellTask::Alpha { size, alpha } => {
            // Mirrors the historical α ablation exactly: RTN INT3, a
            // uniform α override (α=0 ⇒ effectively BASE via the
            // pipeline's short-circuit), and the same seed-0 calibration
            // slice for every α so α is the only moving part.
            let model = data.model(*size);
            let calib = data.calib_tokens(Flavor::C4, model.cfg.seq_len, 0);
            let mut cfg =
                Cell::new(*size, Method::Rtn, QuantConfig::int(3), *alpha > 0.0).pipeline_config();
            cfg.qep_alpha = Some(*alpha);
            cfg.alpha_policy = None;
            let out = Pipeline::new(cfg).run(model, &calib)?;
            let eval = data.eval_tokens(Flavor::Wiki);
            rec.ppl.push(("wiki".to_string(), perplexity(&out.model, &eval)));
            rec.timings = out.report.timings();
        }
        CellTask::Fig2 { size, bits, n_blocks, qep } => {
            let model = data.model(*size);
            let calib = data.calib_tokens(Flavor::C4, model.cfg.seq_len, 0);
            let probe = data.eval_tokens(Flavor::Wiki);
            let probe = &probe[..(8 * model.cfg.seq_len).min(probe.len())];
            let out = Pipeline::new(PipelineConfig {
                quant: QuantConfig::int(*bits),
                method: Method::Rtn,
                qep_alpha: if *qep { Some(0.5) } else { None },
                max_blocks: Some(*n_blocks),
                ..Default::default()
            })
            .run(model, &calib)?;
            rec.deltas = delta_per_block(model, &out.model, probe);
            rec.timings = out.report.timings();
        }
    }
    rec.wall_s = sw.seconds();
    rec.normalize();
    Ok(rec)
}

/// In-manifest-order durable flush state shared by the workers of one
/// [`run_cells_durable`] call. Records are appended (and fsynced) only
/// once every earlier cell's record has been appended, so the file on
/// disk is at all times an intact prefix of the uninterrupted run's file
/// — which is what makes a killed-and-resumed file byte-identical to an
/// uninterrupted one.
struct Flush {
    /// Next cell index (into the run's cell slice) to append.
    next: usize,
    /// Completed records waiting for their predecessors.
    ready: BTreeMap<usize, CellRecord>,
    sink: RecordAppender,
    /// First append failure; later offers become no-ops.
    err: Option<anyhow::Error>,
}

/// Offer cell `idx`'s record to the flush: stash it, then drain every
/// consecutively-ready record to disk.
fn offer(flush: &Mutex<Flush>, stable_timings: bool, idx: usize, rec: &CellRecord) {
    let mut rec = rec.clone();
    if stable_timings {
        rec.stabilize();
    }
    let mut fl = flush.lock().unwrap();
    if fl.err.is_some() {
        return;
    }
    fl.ready.insert(idx, rec);
    loop {
        let next = fl.next;
        let Some(r) = fl.ready.remove(&next) else { break };
        if let Err(e) = fl.sink.append(&r) {
            fl.err = Some(e);
            return;
        }
        fl.next += 1;
    }
}

/// Run a list of plan cells, fanning untimed cells across the pool
/// ([`run_jobs`] semantics) and running timed cells (Table 3 —
/// it *measures* per-cell runtime) serially afterwards, each with the
/// whole machine. Records come back in cell order regardless.
pub fn run_cells(
    data: &ExpData,
    cells: &[PlanCell],
    pool: &Pool,
    shard: usize,
    n_shards: usize,
) -> Result<Vec<CellRecord>> {
    run_cells_inner(data, cells, pool, shard, n_shards, None)
}

/// How a [`run_cells_durable`] call persists its progress.
pub struct DurableRun<'a> {
    /// Cell IDs already recorded by an interrupted run — skipped.
    pub skip: &'a HashSet<String>,
    /// Open appender on this run's record file (torn tail already
    /// truncated by the caller).
    pub sink: RecordAppender,
    /// Zero the shard-local wall-clock fields at write time
    /// (`--stable-timings`), making record files byte-comparable.
    pub stable_timings: bool,
}

/// Like [`run_cells`], but crash-safe: each record is durably appended to
/// `opts.sink` in manifest order as soon as its predecessors have flushed
/// (via the internal in-order flush buffer), and cells whose IDs are in `opts.skip` — already
/// recorded by an interrupted run — are not re-run. Timed (Table 3)
/// cells still run serially after the pooled ones, so pooled records
/// *later in the manifest than an unfinished timed cell* flush only once
/// the timed cells complete — a durability-granularity cost, never a
/// correctness one. Returns only the newly-run records, in cell order.
pub fn run_cells_durable(
    data: &ExpData,
    cells: &[PlanCell],
    pool: &Pool,
    shard: usize,
    n_shards: usize,
    opts: DurableRun,
) -> Result<Vec<CellRecord>> {
    let DurableRun { skip, sink, stable_timings } = opts;
    let todo: Vec<PlanCell> =
        cells.iter().filter(|c| !skip.contains(&c.id())).cloned().collect();
    if todo.len() < cells.len() {
        eprintln!(
            "[exp] resume: {} of {} cell(s) already recorded — running the remaining {}",
            cells.len() - todo.len(),
            cells.len(),
            todo.len()
        );
    }
    let n_todo = todo.len();
    let flush =
        Mutex::new(Flush { next: 0, ready: BTreeMap::new(), sink, err: None });
    let records =
        run_cells_inner(data, &todo, pool, shard, n_shards, Some((&flush, stable_timings)))?;
    let mut fl = flush.into_inner().expect("flush lock never poisoned: offer() cannot panic");
    if let Some(e) = fl.err.take() {
        return Err(e);
    }
    assert_eq!(fl.next, n_todo, "every record flushed in manifest order");
    Ok(records)
}

fn run_cells_inner(
    data: &ExpData,
    cells: &[PlanCell],
    pool: &Pool,
    shard: usize,
    n_shards: usize,
    sink: Option<(&Mutex<Flush>, bool)>,
) -> Result<Vec<CellRecord>> {
    let (timed, pooled): (Vec<usize>, Vec<usize>) =
        (0..cells.len()).partition(|&j| cells[j].sweep.timed());
    eprintln!(
        "[exp] running {} cell(s) on {} worker(s){}",
        cells.len(),
        pool.threads(),
        if timed.is_empty() {
            String::new()
        } else {
            format!(" ({} timed cell(s) serially)", timed.len())
        }
    );
    let mut slots: Vec<Option<Result<CellRecord>>> = (0..cells.len()).map(|_| None).collect();
    let pooled_records = run_jobs(pool, pooled.len(), |i| {
        let pc = &cells[pooled[i]];
        let r = run_plan_cell(data, pc, shard, n_shards);
        if let (Some((flush, stable)), Ok(rec)) = (sink, &r) {
            offer(flush, stable, pooled[i], rec);
        }
        eprintln!("[exp] cell done: {}", pc.id());
        r
    });
    for (&j, r) in pooled.iter().zip(pooled_records) {
        slots[j] = Some(r);
    }
    for &j in &timed {
        let pc = &cells[j];
        let r = run_plan_cell(data, pc, shard, n_shards);
        if let Ok(rec) = &r {
            if let Some((flush, stable)) = sink {
                offer(flush, stable, j, rec);
            }
            eprintln!(
                "[table3] {}: {} (correction {})",
                pc.id(),
                crate::util::fmt_duration(rec.timings.total_s),
                crate::util::fmt_duration(rec.timings.correction_s)
            );
        }
        slots[j] = Some(r);
    }
    slots.into_iter().map(|s| s.expect("every cell slot filled")).collect()
}

/// The single-process sweep driver: enumerate → run → render, returning
/// the records (in manifest order) so callers can also persist them.
/// This is the exact pipeline a sharded run splits across processes —
/// `repro exp <id> --shard i/N` stops after the run stage, and
/// `repro exp merge` picks up at the render stage.
pub fn run_sweep(
    env: &mut ExpEnv,
    sweep: SweepId,
    params: &PlanParams,
    rcfg: &RenderCfg,
) -> Result<Vec<CellRecord>> {
    let cells = plan::manifest(sweep, params)?;
    let data = env.snapshot(&plan::sizes_of(&cells));
    let records = run_cells(&data, &cells, &pool::global(), 0, 1)?;
    let map = plan::verify_coverage(&cells, records)?;
    render_sweep(sweep, params, &map, rcfg)?;
    map.in_order(&cells)
}

/// Render a sweep's tables/figures from verified records. `all` renders
/// each part in the historical driver order.
pub fn render_sweep(
    sweep: SweepId,
    params: &PlanParams,
    recs: &RecordMap,
    rcfg: &RenderCfg,
) -> Result<()> {
    match sweep {
        SweepId::Table12 => super::tables::render_table12(params, recs, rcfg),
        SweepId::Table3 => super::tables::render_table3(params, recs, rcfg),
        SweepId::Table4 => super::tables::render_table4(params, recs, rcfg),
        SweepId::AblationAlpha => super::tables::render_ablation_alpha(params, recs, rcfg),
        SweepId::Fig2 => super::fig2::render(params, recs, rcfg).map(|_| ()),
        SweepId::Fig3 => super::fig3::render(params, recs, rcfg),
        SweepId::Appendix => super::tables::render_appendix(params, recs, rcfg),
        SweepId::Lowrank => super::tables::render_lowrank(params, recs, rcfg),
        SweepId::Budget => super::tables::render_budget(params, recs, rcfg),
        SweepId::Cbq => super::tables::render_cbq(params, recs, rcfg),
        SweepId::All => {
            for part in SweepId::all_parts() {
                render_sweep(part, params, recs, rcfg)?;
            }
            Ok(())
        }
    }
}

/// One record directory scanned tolerantly — the raw material of both
/// `--resume` and `repro exp status`. Every complete record in every
/// `*.jsonl` file, plus any torn tails (crash-mid-append fragments, which
/// the readers drop). A missing or record-free directory scans to an
/// empty result: nothing recorded yet.
pub struct DirScan {
    /// Every `*.jsonl` file found, in sorted order (an existing-but-empty
    /// record file appears here and nowhere else).
    pub files: Vec<PathBuf>,
    pub records: Vec<(PathBuf, CellRecord)>,
    pub torn: Vec<(PathBuf, TornTail)>,
}

impl DirScan {
    /// IDs of every scanned record (duplicates included).
    pub fn ids(&self) -> impl Iterator<Item = &str> {
        self.records.iter().map(|(_, r)| r.id.as_str())
    }
}

/// Scan `dir` for record files, in sorted file order, tolerating torn
/// tails. Unlike `io::results::read_record_dir` this treats a *missing*
/// directory as "no progress yet" rather than an error — resume and
/// status must work before the first record lands. Any other read
/// failure (permissions, I/O) is a hard error: treating it as empty
/// would hand `--resume` an empty skip set and make it re-run — and
/// duplicate — every already-recorded cell.
pub fn scan_record_dir(dir: &Path) -> Result<DirScan> {
    let mut scan = DirScan { files: Vec::new(), records: Vec::new(), torn: Vec::new() };
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(scan),
        Err(e) => {
            return Err(e).with_context(|| format!("scanning record dir {}", dir.display()))
        }
    };
    scan.files = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().map(|x| x == "jsonl").unwrap_or(false))
        .collect();
    scan.files.sort();
    for path in &scan.files {
        let out = read_records_tolerant(path)?;
        if let Some(t) = out.torn {
            scan.torn.push((path.clone(), t));
        }
        for r in out.records {
            scan.records.push((path.clone(), r));
        }
    }
    Ok(scan)
}

/// `--resume` validation: every record already on disk must name a cell
/// of THIS manifest, exactly once. Hard errors: an ID that is not a
/// well-formed cell ID (corruption), a well-formed ID that is not in the
/// manifest (a **parameter mismatch** — the records were written under
/// different plan flags, and resuming over them would weld two different
/// sweeps together), and duplicate IDs across files. Torn tails are fine
/// (their cells simply count as missing). Returns the completed-cell ID
/// set — the skip set for [`run_cells_durable`].
pub fn validate_resume(cells: &[PlanCell], scan: &DirScan) -> Result<HashSet<String>> {
    let index = plan::index_manifest(cells)?;
    let mut done: HashMap<&str, &Path> = HashMap::new();
    for (path, rec) in &scan.records {
        if !index.contains_key(&rec.id) {
            if PlanCell::parse(&rec.id).is_some() {
                bail!(
                    "{}: record '{}' is a valid cell id but not in this manifest — parameter \
                     mismatch: were the existing records written with different flags \
                     (--fast/--sizes/--seeds/--bits/--blocks)? Re-run `repro exp status` with \
                     the original flags, or point --out at a fresh directory",
                    path.display(),
                    rec.id
                );
            }
            bail!(
                "{}: record id '{}' is not a well-formed cell id (corrupted or foreign file \
                 in the output directory)",
                path.display(),
                rec.id
            );
        }
        if let Some(prev) = done.get(rec.id.as_str()) {
            bail!(
                "duplicate records for cell '{}' (in {} and {}) — cannot resume over an \
                 ambiguous directory; delete one copy or start a fresh --out",
                rec.id,
                prev.display(),
                path.display()
            );
        }
        done.insert(rec.id.as_str(), path.as_path());
    }
    Ok(done.into_keys().map(|id| id.to_string()).collect())
}

/// Completion picture of a record directory against a manifest slice —
/// what `repro exp status` prints. Built tolerantly: torn tails and
/// unknown/duplicate IDs are *reported*, never errors, so status works
/// on exactly the directories that need triage. [`StatusReport::clean`]
/// implies `verify_coverage` would accept the same records.
pub struct StatusReport {
    pub total: usize,
    pub done: usize,
    /// Missing cell IDs, in manifest order.
    pub missing: Vec<String>,
    /// (sweep name, done, total) per constituent sweep, in manifest order.
    pub per_sweep: Vec<(String, usize, usize)>,
    pub torn: Vec<(PathBuf, TornTail)>,
    /// Record IDs not in the manifest (sorted, deduped).
    pub unknown: Vec<String>,
    /// Manifest IDs recorded more than once (sorted, deduped).
    pub duplicates: Vec<String>,
}

/// Build a [`StatusReport`] for `cells` (the full manifest or one shard's
/// slice) from a tolerant directory scan.
pub fn status_report(cells: &[PlanCell], scan: &DirScan) -> StatusReport {
    let ids: Vec<String> = cells.iter().map(|c| c.id()).collect();
    let in_manifest: HashSet<&str> = ids.iter().map(|s| s.as_str()).collect();
    let mut seen: HashMap<&str, usize> = HashMap::new();
    let mut unknown: Vec<String> = Vec::new();
    for id in scan.ids() {
        if in_manifest.contains(id) {
            *seen.entry(id).or_insert(0) += 1;
        } else {
            unknown.push(id.to_string());
        }
    }
    unknown.sort();
    unknown.dedup();
    let mut duplicates: Vec<String> =
        seen.iter().filter(|&(_, &n)| n > 1).map(|(id, _)| id.to_string()).collect();
    duplicates.sort();
    let missing: Vec<String> =
        ids.iter().filter(|id| !seen.contains_key(id.as_str())).cloned().collect();
    let mut per_sweep: Vec<(String, usize, usize)> = Vec::new();
    for (c, id) in cells.iter().zip(ids.iter()) {
        let name = c.sweep.name().to_string();
        if per_sweep.last().map(|(n, _, _)| n != &name).unwrap_or(true) {
            per_sweep.push((name, 0, 0));
        }
        let last = per_sweep.last_mut().expect("entry just ensured");
        last.2 += 1;
        if seen.contains_key(id.as_str()) {
            last.1 += 1;
        }
    }
    StatusReport {
        total: cells.len(),
        done: seen.len(),
        missing,
        per_sweep,
        torn: scan.torn.clone(),
        unknown,
        duplicates,
    }
}

/// Status lines preview at most 3 IDs (coverage errors show 5).
fn preview_ids(ids: &[String]) -> String {
    plan::preview(ids, 3)
}

impl StatusReport {
    /// True when the directory is fully healthy: every cell recorded
    /// exactly once, nothing foreign, nothing torn. `clean()` implies
    /// `verify_coverage` over the same slice succeeds (status is the
    /// stricter check: a torn tail fails `clean()` even when the torn
    /// cell's record exists intact elsewhere).
    pub fn clean(&self) -> bool {
        self.done == self.total
            && self.unknown.is_empty()
            && self.duplicates.is_empty()
            && self.torn.is_empty()
    }

    /// Human-readable report. `label` names the slice (e.g. `'all'` or
    /// `'all' shard 2/3`). Deterministic given the same directory state.
    pub fn render(&self, label: &str) -> String {
        let mut out = format!(
            "[status] {label}: {}/{} cell(s) done, {} missing\n",
            self.done,
            self.total,
            self.missing.len()
        );
        // Per-sweep breakdown only when there is more than one part
        // (i.e. the `all` sweep) — for a single sweep the header says it.
        if self.per_sweep.len() > 1 {
            for (name, done, total) in &self.per_sweep {
                out.push_str(&format!("  {name:<15} {done:>3}/{total:<3} done\n"));
            }
        }
        if !self.missing.is_empty() {
            out.push_str(&format!(
                "  next missing: {}\n",
                preview_ids(&self.missing)
            ));
        }
        for (path, t) in &self.torn {
            out.push_str(&format!(
                "  torn tail: {} ({} byte(s) after the last complete record — dropped; \
                 --resume re-runs that cell)\n",
                path.display(),
                t.fragment_bytes
            ));
        }
        if !self.unknown.is_empty() {
            out.push_str(&format!(
                "  PROBLEM: {} record(s) not in this manifest (different flags, or a foreign \
                 file?): {}\n",
                self.unknown.len(),
                preview_ids(&self.unknown)
            ));
        }
        if !self.duplicates.is_empty() {
            out.push_str(&format!(
                "  PROBLEM: duplicate records for {} cell(s): {}\n",
                self.duplicates.len(),
                preview_ids(&self.duplicates)
            ));
        }
        out.push_str(if self.clean() {
            "  complete — ready to `repro exp merge`\n"
        } else if !self.unknown.is_empty() || !self.duplicates.is_empty() {
            // --resume would hard-error on these; point at the real fix.
            "  broken — remove the foreign/duplicate record(s) above (or start a fresh \
             --out), then merge\n"
        } else {
            "  incomplete — finish or `--resume` the missing shard run(s), then merge\n"
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_falls_back_to_random_models() {
        let mut env = ExpEnv::new("/nonexistent-artifacts");
        let m = env.model(Size::TinyS);
        assert!(env.used_fallback);
        m.validate().unwrap();
        // Cached on second access.
        let m2 = env.model(Size::TinyS);
        assert_eq!(m.blocks[0].wq, m2.blocks[0].wq);
    }

    #[test]
    fn calib_and_eval_splits_are_disjoint() {
        let mut env = ExpEnv::new("/nonexistent-artifacts");
        let calib = env.calib_tokens(Flavor::Wiki, 128, 0);
        let eval = env.eval_tokens(Flavor::Wiki);
        assert_eq!(calib.len(), CALIB_SEGMENTS * 128);
        assert!(eval.len() >= 1024);
        // Disjoint by construction: calib from the front region, eval tail.
        let c = env.corpus(Flavor::Wiki);
        assert!(c.tokens.len() > calib.len() + eval.len());
    }

    #[test]
    fn snapshot_matches_env_streams() {
        let mut env = ExpEnv::new("/nonexistent-artifacts");
        let data = env.snapshot(&[Size::TinyS]);
        assert_eq!(
            data.calib_tokens(Flavor::Ptb, 64, 7),
            env.calib_tokens(Flavor::Ptb, 64, 7)
        );
        assert_eq!(data.eval_tokens(Flavor::C4), env.eval_tokens(Flavor::C4));
        assert_eq!(data.model(Size::TinyS).blocks[0].wq, env.model(Size::TinyS).blocks[0].wq);
    }

    #[test]
    fn derived_seeds_control_comparisons_and_split_replicates() {
        let a = Cell::new(Size::TinyS, Method::Gptq, QuantConfig::int(3), true);
        assert_eq!(a.derived_seed(), a.clone().derived_seed());
        // Cells that differ only along a compared axis (method/bits/±QEP)
        // must SHARE the stream — the comparison holds calibration fixed.
        let base = Cell::new(Size::TinyS, Method::Gptq, QuantConfig::int(3), false);
        assert_eq!(a.derived_seed(), base.derived_seed(), "±QEP must share calibration");
        let rtn = Cell::new(Size::TinyS, Method::Rtn, QuantConfig::int(2), false);
        assert_eq!(a.derived_seed(), rtn.derived_seed(), "methods must share calibration");
        let mut lr = a.clone();
        lr.lowrank_rank = 8;
        assert_eq!(a.derived_seed(), lr.derived_seed(), "±lowrank must share calibration");
        let mut bg = a.clone();
        bg.budget = Some(BudgetSpec {
            budget: crate::quant::BitBudget::parse("2.5").unwrap(),
            alloc: crate::quant::Alloc::Dp,
        });
        assert_eq!(a.derived_seed(), bg.derived_seed(), "±budget must share calibration");
        let mut cw = a.clone();
        cw.cbq_window = 3;
        assert_eq!(a.derived_seed(), cw.derived_seed(), "cbq windows must share calibration");
        // Data identity and replicates must split streams.
        let mut c = a.clone();
        c.calib_flavor = Flavor::Wiki;
        assert_ne!(a.derived_seed(), c.derived_seed(), "calib flavor must split streams");
        let mut d = a.clone();
        d.seed = 1;
        assert_ne!(a.derived_seed(), d.derived_seed(), "replicates must split streams");
        let l = Cell::new(Size::TinyL, Method::Gptq, QuantConfig::int(3), true);
        assert_ne!(a.derived_seed(), l.derived_seed(), "sizes must split streams");
        assert_eq!(a.pipeline_config().seed, a.derived_seed());
    }

    #[test]
    fn huge_derived_seeds_do_not_overflow_calib_offsets() {
        let c = Corpus::generate(Flavor::C4, 64 * 1024, 0);
        let cell = Cell::new(Size::TinyS, Method::Quip, QuantConfig::int(2), true);
        let toks = calib_slice(&c, 128, cell.derived_seed());
        assert_eq!(toks.len(), CALIB_SEGMENTS * 128);
    }

    #[test]
    fn hashed_seed_calib_never_lands_in_eval_tail() {
        // Name-derived seeds are uniform over u64, so the offset window
        // itself must exclude the eval tail — for every possible seed, not
        // just "reasonable" small ones. Uses the production calib_offset,
        // so the guard cannot drift from the implementation.
        let c = Corpus::generate(Flavor::C4, 64 * 1024, 0);
        let seq_len = 128usize;
        let need = CALIB_SEGMENTS * seq_len;
        let eval_start = c.tokens.len() - EVAL_TOKENS.min(c.tokens.len() / 2);
        for s in 0..256u64 {
            let seed = crate::util::fnv1a(&format!("probe-{s}"));
            let offset = calib_offset(c.tokens.len(), seq_len, seed);
            assert!(
                offset + need <= eval_start,
                "seed {s}: calib [{offset}..{}) reaches into eval tail [{eval_start}..)",
                offset + need
            );
        }
    }

    #[test]
    fn cell_labels_are_informative() {
        let cell = Cell::new(Size::TinyS, Method::Gptq, QuantConfig::int(3), true);
        assert_eq!(cell.label(), "tiny-s INT3 GPTQ +QEP");
        let mut lr = cell.clone();
        lr.lowrank_rank = 4;
        assert_eq!(lr.label(), "tiny-s INT3 GPTQ +QEP +LR4");
        let mut bg = cell.clone();
        bg.budget = Some(BudgetSpec {
            budget: crate::quant::BitBudget::parse("2.5").unwrap(),
            alloc: crate::quant::Alloc::Dp,
        });
        assert_eq!(bg.label(), "tiny-s INT3 GPTQ +QEP B2.5/dp");
        let mut cw = cell;
        cw.cbq_window = 2;
        assert_eq!(cw.label(), "tiny-s INT3 GPTQ +QEP W2");
    }

    #[test]
    fn tiny_l_gets_mlp_alpha_zero() {
        let cell = Cell::new(Size::TinyL, Method::Rtn, QuantConfig::int(4), true);
        let cfg = cell.pipeline_config();
        let p = cfg.alpha_policy.unwrap();
        assert_eq!(p.alpha_for("blocks.0.mlp.down"), 0.0);
        let cell_s = Cell::new(Size::TinyS, Method::Rtn, QuantConfig::int(4), true);
        assert!(cell_s.pipeline_config().alpha_policy.is_none());
    }
}
