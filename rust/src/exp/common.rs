//! Shared experiment plumbing: model/corpus loading with fallbacks, the
//! quantize→evaluate cell runner, and result persistence.
//!
//! Sharding model: [`ExpEnv`] owns the mutable caches (artifact loading,
//! fallback bookkeeping) and is *not* shared across workers. A sweep first
//! takes an immutable [`ExpData`] snapshot (models + corpora), then fans
//! independent cells out over the pool via [`Cell::run_on`]. Every cell
//! derives its calibration/pipeline seed from its own identity
//! ([`Cell::derived_seed`]), so results do not depend on which worker runs
//! which cell or in what order — sweeps are bit-identical for every
//! thread count.

use crate::coordinator::{Pipeline, PipelineConfig, PipelineOutput};
use crate::eval::{perplexity, TaskFamily, TaskSet};
use crate::model::{Model, Size};
use crate::qep::AlphaPolicy;
use crate::quant::{Method, QuantConfig};
use crate::runtime::ArtifactRegistry;
use crate::text::{Corpus, Flavor};
use crate::util::pool::Pool;
use anyhow::Result;
use std::collections::HashMap;

/// Calibration/eval token budgets (scaled-down analogs of the paper's
/// 128×2048-token calibration set).
pub const CALIB_SEGMENTS: usize = 16;
pub const EVAL_TOKENS: usize = 8 * 1024;
pub const TASKS_PER_FAMILY: usize = 32;

/// Start offset of the calibration window in a corpus of `len` tokens:
/// spread over `[0, len − need − EVAL_TOKENS)` so the whole window stays
/// out of the [`EVAL_TOKENS`]-sized tail that [`eval_slice`] reads — for
/// *every* seed, because name-derived seeds are uniform full-width hashes
/// ("small seeds stay near the front" no longer holds). Shared by
/// [`calib_slice`] and the guard test so the two cannot drift apart.
pub fn calib_offset(len: usize, seq_len: usize, seed: u64) -> usize {
    let need = CALIB_SEGMENTS * seq_len;
    let span = len.saturating_sub(need + EVAL_TOKENS).max(1);
    // Full-width hashed seeds: wrap instead of overflowing.
    (seed as usize).wrapping_mul(7919).wrapping_mul(seq_len) % span
}

/// Calibration tokens from a corpus for a seed. Pure function of
/// (corpus, seq_len, seed) so sharded cells can draw their streams
/// without touching shared mutable state.
///
/// Disjointness contract: whenever the corpus holds a calibration window
/// plus the eval tail (`len ≥ CALIB_SEGMENTS·seq_len + EVAL_TOKENS`), the
/// window never overlaps [`eval_slice`]'s tail, for every seed (see
/// [`calib_offset`]). Shorter corpora fall back to the front and *may*
/// overlap the (also shrunken) eval split; a corpus smaller than one
/// calibration window is a hard error.
pub fn calib_slice(c: &Corpus, seq_len: usize, seed: u64) -> Vec<u32> {
    let need = CALIB_SEGMENTS * seq_len;
    let offset = calib_offset(c.tokens.len(), seq_len, seed);
    assert!(
        offset + need <= c.tokens.len(),
        "corpus too small for calibration: {} tokens < {need} needed",
        c.tokens.len()
    );
    c.tokens[offset..offset + need].to_vec()
}

/// Evaluation tokens: the [`EVAL_TOKENS`]-sized tail of the corpus.
/// Disjoint from [`calib_slice`]'s window for *every* seed whenever the
/// corpus holds both (see [`calib_offset`]).
pub fn eval_slice(c: &Corpus) -> Vec<u32> {
    let n = EVAL_TOKENS.min(c.tokens.len() / 2);
    c.tokens[c.tokens.len() - n..].to_vec()
}

/// Run `n` independent experiment jobs, either sharded across `pool`
/// (when there are at least as many jobs as workers) or serially with
/// each job keeping the *whole* pool for its inner kernels (when jobs are
/// scarcer than workers — outer fan-out would mark every worker as
/// in-pool, serialize the nested GEMM/SPD engines, and idle the remaining
/// cores). Results come back in job order and are bit-identical either
/// way; only wall-clock differs.
pub fn run_jobs<T, F>(pool: &Pool, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n >= pool.threads() {
        pool.par_map(n, f)
    } else {
        (0..n).map(f).collect()
    }
}

/// Experiment environment: loads trained models from artifacts when
/// available, otherwise falls back to deterministic random-weight models
/// (clearly labelled) so the drivers always run.
pub struct ExpEnv {
    pub reg: ArtifactRegistry,
    models: HashMap<String, Model>,
    corpora: HashMap<Flavor, Corpus>,
    pub used_fallback: bool,
}

impl ExpEnv {
    pub fn new(root: &str) -> ExpEnv {
        ExpEnv {
            reg: ArtifactRegistry::new(root),
            models: HashMap::new(),
            corpora: HashMap::new(),
            used_fallback: false,
        }
    }

    pub fn model(&mut self, size: Size) -> Model {
        let name = size.name().to_string();
        if let Some(m) = self.models.get(&name) {
            return m.clone();
        }
        let m = match self.reg.load_model(&name) {
            Ok(m) => m,
            Err(_) => {
                self.used_fallback = true;
                eprintln!("[exp] WARNING: {name}.qtz missing — using random weights (run `make artifacts`)");
                Model::random(&size.config(), 0xBEEF)
            }
        };
        self.models.insert(name, m.clone());
        m
    }

    pub fn corpus(&mut self, flavor: Flavor) -> Corpus {
        if let Some(c) = self.corpora.get(&flavor) {
            return c.clone();
        }
        let c = match self.reg.load_corpus(flavor) {
            Ok(c) => c,
            Err(_) => Corpus::generate(flavor, 256 * 1024, 0),
        };
        self.corpora.insert(flavor, c.clone());
        c
    }

    /// Calibration tokens for a flavor + seed (see [`calib_slice`]).
    pub fn calib_tokens(&mut self, flavor: Flavor, seq_len: usize, seed: u64) -> Vec<u32> {
        let c = self.corpus(flavor);
        calib_slice(&c, seq_len, seed)
    }

    /// Evaluation tokens (see [`eval_slice`]).
    pub fn eval_tokens(&mut self, flavor: Flavor) -> Vec<u32> {
        let c = self.corpus(flavor);
        eval_slice(&c)
    }

    /// Immutable snapshot of everything a sharded sweep needs: the models
    /// for `sizes` (loading/falling back now, so warnings print once,
    /// before the fan-out) and all corpus flavors. Workers read the
    /// snapshot concurrently; the env's caches stay warm for later calls.
    /// All flavors are included deliberately (a few MB of clones) so
    /// [`ExpData::corpus`] can never hit its missing-flavor panic no
    /// matter which eval/calib flavors a driver's cells request.
    pub fn snapshot(&mut self, sizes: &[Size]) -> ExpData {
        let mut models = HashMap::new();
        for &s in sizes {
            models.insert(s.name().to_string(), self.model(s));
        }
        let mut corpora = HashMap::new();
        for f in Flavor::all() {
            corpora.insert(f, self.corpus(f));
        }
        ExpData { models, corpora }
    }
}

/// Read-only inputs for a sharded sweep; see [`ExpEnv::snapshot`].
pub struct ExpData {
    models: HashMap<String, Model>,
    corpora: HashMap<Flavor, Corpus>,
}

impl ExpData {
    /// Assemble a snapshot directly (tests inject custom tiny models under
    /// a size's name to keep sharded-sweep tests fast).
    pub fn from_parts(models: HashMap<String, Model>, corpora: HashMap<Flavor, Corpus>) -> ExpData {
        ExpData { models, corpora }
    }

    /// The snapshot's model for `size`. Panics if the snapshot was taken
    /// without it — a driver bug, not a runtime condition.
    pub fn model(&self, size: Size) -> &Model {
        self.models
            .get(size.name())
            .unwrap_or_else(|| panic!("model '{}' missing from snapshot", size.name()))
    }

    /// The snapshot's corpus for `flavor`.
    pub fn corpus(&self, flavor: Flavor) -> &Corpus {
        self.corpora
            .get(&flavor)
            .unwrap_or_else(|| panic!("corpus '{}' missing from snapshot", flavor.name()))
    }

    pub fn calib_tokens(&self, flavor: Flavor, seq_len: usize, seed: u64) -> Vec<u32> {
        calib_slice(self.corpus(flavor), seq_len, seed)
    }

    pub fn eval_tokens(&self, flavor: Flavor) -> Vec<u32> {
        eval_slice(self.corpus(flavor))
    }
}

/// One experiment cell: a (model, method, grid, ±QEP) configuration.
#[derive(Clone, Debug)]
pub struct Cell {
    pub size: Size,
    pub method: Method,
    pub quant: QuantConfig,
    pub qep: bool,
    /// Replicate index (Fig. 3's seed axis); folded with the cell identity
    /// into [`Cell::derived_seed`] for the actual streams.
    pub seed: u64,
    pub calib_flavor: Flavor,
}

impl Cell {
    pub fn new(size: Size, method: Method, quant: QuantConfig, qep: bool) -> Cell {
        Cell { size, method, quant, qep, seed: 0, calib_flavor: default_calib(method) }
    }

    /// Scheduling-independent seed for this cell's calibration draw and
    /// pipeline randomness: an FNV-1a hash of the cell's *data identity*
    /// (model size + calibration flavor) folded with the explicit
    /// replicate `seed`. Deliberately NOT a function of method/bits/±QEP:
    /// cells that differ only along a compared axis share the identical
    /// calibration window and per-layer randomness (the paper calibrates
    /// all methods on the same set, and Fig. 3's QuIP±QEP pairs must share
    /// rotations), while sharded sweeps stay bit-identical no matter which
    /// worker runs which cell.
    pub fn derived_seed(&self) -> u64 {
        crate::util::fnv1a(&format!("{}|{}", self.size.name(), self.calib_flavor.name()))
            ^ self.seed
    }

    /// Build the pipeline config for this cell, mirroring the paper's
    /// defaults: α = 1/2 everywhere, α = 0 on the MLPs of the largest model.
    pub fn pipeline_config(&self) -> PipelineConfig {
        let alpha_policy = if self.qep && self.size == Size::TinyL {
            Some(AlphaPolicy::paper_large_model())
        } else {
            None
        };
        PipelineConfig {
            quant: self.quant,
            method: self.method,
            qep_alpha: if self.qep { Some(0.5) } else { None },
            alpha_policy,
            damp_rel: 1.0,
            max_blocks: None,
            seed: self.derived_seed(),
            verbose: false,
            threads: 0,
        }
    }

    /// Quantize the model for this cell straight off the env's caches (no
    /// snapshot clone — the single-cell path; sweeps use [`Cell::run_on`]
    /// against a shared snapshot instead).
    pub fn run(&self, env: &mut ExpEnv) -> Result<PipelineOutput> {
        let model = env.model(self.size);
        let calib = env.calib_tokens(self.calib_flavor, model.cfg.seq_len, self.derived_seed());
        Pipeline::new(self.pipeline_config()).run(&model, &calib)
    }

    /// Quantize the model for this cell against an immutable snapshot —
    /// the unit of work a sharded sweep hands to pool workers.
    pub fn run_on(&self, data: &ExpData) -> Result<PipelineOutput> {
        let model = data.model(self.size);
        let calib = data.calib_tokens(self.calib_flavor, model.cfg.seq_len, self.derived_seed());
        Pipeline::new(self.pipeline_config()).run(model, &calib)
    }

    pub fn label(&self) -> String {
        format!(
            "{} {} {} {}",
            self.size.name(),
            self.quant.label(),
            self.method.name(),
            if self.qep { "+QEP" } else { "base" }
        )
    }
}

/// The calibration dataset each method used in the paper (§6 Datasets):
/// GPTQ/QuIP → C4, AWQ → Pile (we map Pile→C4 flavor too; RTN needs none
/// but QEP+RTN evaluates the correction on C4).
pub fn default_calib(_method: Method) -> Flavor {
    Flavor::C4
}

/// Quantize + evaluate perplexity on a flavor.
pub fn cell_ppl(env: &mut ExpEnv, cell: &Cell, eval_flavor: Flavor) -> Result<f64> {
    let out = cell.run(env)?;
    let eval = env.eval_tokens(eval_flavor);
    Ok(perplexity(&out.model, &eval))
}

/// [`cell_ppl`] against a snapshot (the sharded-sweep path).
pub fn cell_ppl_on(data: &ExpData, cell: &Cell, eval_flavor: Flavor) -> Result<f64> {
    let out = cell.run_on(data)?;
    let eval = data.eval_tokens(eval_flavor);
    Ok(perplexity(&out.model, &eval))
}

/// Quantize + evaluate zero-shot accuracy averaged over families.
pub fn cell_task_acc(env: &mut ExpEnv, cell: &Cell, families: &[TaskFamily]) -> Result<Vec<f64>> {
    let out = cell.run(env)?;
    let corpus = env.corpus(Flavor::Wiki);
    families
        .iter()
        .map(|&fam| {
            let ts = TaskSet::generate(fam, &corpus, TASKS_PER_FAMILY, 1234);
            Ok(ts.accuracy(&out.model))
        })
        .collect()
}

/// Write table text + csv under `results/`.
pub fn persist(name: &str, table: &crate::util::table::Table) -> Result<()> {
    std::fs::create_dir_all("results")?;
    std::fs::write(format!("results/{name}.txt"), table.render())?;
    std::fs::write(format!("results/{name}.csv"), table.to_csv())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_falls_back_to_random_models() {
        let mut env = ExpEnv::new("/nonexistent-artifacts");
        let m = env.model(Size::TinyS);
        assert!(env.used_fallback);
        m.validate().unwrap();
        // Cached on second access.
        let m2 = env.model(Size::TinyS);
        assert_eq!(m.blocks[0].wq, m2.blocks[0].wq);
    }

    #[test]
    fn calib_and_eval_splits_are_disjoint() {
        let mut env = ExpEnv::new("/nonexistent-artifacts");
        let calib = env.calib_tokens(Flavor::Wiki, 128, 0);
        let eval = env.eval_tokens(Flavor::Wiki);
        assert_eq!(calib.len(), CALIB_SEGMENTS * 128);
        assert!(eval.len() >= 1024);
        // Disjoint by construction: calib from the front region, eval tail.
        let c = env.corpus(Flavor::Wiki);
        assert!(c.tokens.len() > calib.len() + eval.len());
    }

    #[test]
    fn snapshot_matches_env_streams() {
        let mut env = ExpEnv::new("/nonexistent-artifacts");
        let data = env.snapshot(&[Size::TinyS]);
        assert_eq!(
            data.calib_tokens(Flavor::Ptb, 64, 7),
            env.calib_tokens(Flavor::Ptb, 64, 7)
        );
        assert_eq!(data.eval_tokens(Flavor::C4), env.eval_tokens(Flavor::C4));
        assert_eq!(data.model(Size::TinyS).blocks[0].wq, env.model(Size::TinyS).blocks[0].wq);
    }

    #[test]
    fn derived_seeds_control_comparisons_and_split_replicates() {
        let a = Cell::new(Size::TinyS, Method::Gptq, QuantConfig::int(3), true);
        assert_eq!(a.derived_seed(), a.clone().derived_seed());
        // Cells that differ only along a compared axis (method/bits/±QEP)
        // must SHARE the stream — the comparison holds calibration fixed.
        let base = Cell::new(Size::TinyS, Method::Gptq, QuantConfig::int(3), false);
        assert_eq!(a.derived_seed(), base.derived_seed(), "±QEP must share calibration");
        let rtn = Cell::new(Size::TinyS, Method::Rtn, QuantConfig::int(2), false);
        assert_eq!(a.derived_seed(), rtn.derived_seed(), "methods must share calibration");
        // Data identity and replicates must split streams.
        let mut c = a.clone();
        c.calib_flavor = Flavor::Wiki;
        assert_ne!(a.derived_seed(), c.derived_seed(), "calib flavor must split streams");
        let mut d = a.clone();
        d.seed = 1;
        assert_ne!(a.derived_seed(), d.derived_seed(), "replicates must split streams");
        let l = Cell::new(Size::TinyL, Method::Gptq, QuantConfig::int(3), true);
        assert_ne!(a.derived_seed(), l.derived_seed(), "sizes must split streams");
        assert_eq!(a.pipeline_config().seed, a.derived_seed());
    }

    #[test]
    fn huge_derived_seeds_do_not_overflow_calib_offsets() {
        let c = Corpus::generate(Flavor::C4, 64 * 1024, 0);
        let cell = Cell::new(Size::TinyS, Method::Quip, QuantConfig::int(2), true);
        let toks = calib_slice(&c, 128, cell.derived_seed());
        assert_eq!(toks.len(), CALIB_SEGMENTS * 128);
    }

    #[test]
    fn hashed_seed_calib_never_lands_in_eval_tail() {
        // Name-derived seeds are uniform over u64, so the offset window
        // itself must exclude the eval tail — for every possible seed, not
        // just "reasonable" small ones. Uses the production calib_offset,
        // so the guard cannot drift from the implementation.
        let c = Corpus::generate(Flavor::C4, 64 * 1024, 0);
        let seq_len = 128usize;
        let need = CALIB_SEGMENTS * seq_len;
        let eval_start = c.tokens.len() - EVAL_TOKENS.min(c.tokens.len() / 2);
        for s in 0..256u64 {
            let seed = crate::util::fnv1a(&format!("probe-{s}"));
            let offset = calib_offset(c.tokens.len(), seq_len, seed);
            assert!(
                offset + need <= eval_start,
                "seed {s}: calib [{offset}..{}) reaches into eval tail [{eval_start}..)",
                offset + need
            );
        }
    }

    #[test]
    fn cell_labels_are_informative() {
        let cell = Cell::new(Size::TinyS, Method::Gptq, QuantConfig::int(3), true);
        assert_eq!(cell.label(), "tiny-s INT3 GPTQ +QEP");
    }

    #[test]
    fn tiny_l_gets_mlp_alpha_zero() {
        let cell = Cell::new(Size::TinyL, Method::Rtn, QuantConfig::int(4), true);
        let cfg = cell.pipeline_config();
        let p = cfg.alpha_policy.unwrap();
        assert_eq!(p.alpha_for("blocks.0.mlp.down"), 0.0);
        let cell_s = Cell::new(Size::TinyS, Method::Rtn, QuantConfig::int(4), true);
        assert!(cell_s.pipeline_config().alpha_policy.is_none());
    }
}
