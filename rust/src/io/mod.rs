//! On-disk interchange formats shared between the build-time Python side
//! and the Rust runtime.

pub mod qtz;

pub use qtz::{Dtype, TensorFile, TensorView};
