//! On-disk interchange formats shared between the build-time Python side
//! and the Rust runtime.
//!
//! `.qtz` ([`qtz`]) is a minimal little-endian tensor container (named
//! f32/u8 tensors + JSON-ish metadata) written by
//! `python/compile/qtz.py` after JAX training and read back here for
//! quantization, evaluation, and serving. Quantized pipeline outputs
//! round-trip through the same format, which is what lets
//! `tests/parallel_equivalence.rs` assert *byte*-identical artifacts
//! across thread counts.
//!
//! [`results`] is the distributed-sweep interchange: JSON-lines files of
//! per-cell experiment records written by `repro exp --shard i/N` and
//! collected by `repro exp merge`. Metrics round-trip bit-exactly, so
//! merged renders match single-process renders byte for byte.

pub mod qtz;
pub mod results;

pub use qtz::{Dtype, TensorFile, TensorView};
pub use results::CellRecord;
