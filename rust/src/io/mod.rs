//! On-disk interchange formats shared between the build-time Python side
//! and the Rust runtime.
//!
//! The one format is `.qtz` ([`qtz`]): a minimal little-endian tensor
//! container (named f32/u8 tensors + JSON-ish metadata) written by
//! `python/compile/qtz.py` after JAX training and read back here for
//! quantization, evaluation, and serving. Quantized pipeline outputs
//! round-trip through the same format, which is what lets
//! `tests/parallel_equivalence.rs` assert *byte*-identical artifacts
//! across thread counts.

pub mod qtz;

pub use qtz::{Dtype, TensorFile, TensorView};
