//! QTZ — the tensor container used to pass model weights between the
//! Python build path and the Rust runtime (the environment has no
//! safetensors crate; this is a deliberately minimal equivalent).
//!
//! Layout:
//! ```text
//! b"QTZ1"                      4-byte magic
//! u64 LE header_len
//! header: JSON                 {"meta": {...}, "tensors": {name: {dtype, shape, offset, nbytes}}}
//! data blob                    little-endian raw values, 64-byte aligned per tensor
//! ```
//!
//! Supported dtypes: `f32` (weights, scales) and `i8` (quantized codes).
//! Both `python/compile/qtz.py` and this module implement the format; the
//! cross-language round-trip is covered by `rust/tests/qtz_interop.rs`.

use crate::linalg::Mat;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"QTZ1";
const ALIGN: usize = 64;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I8,
}

impl Dtype {
    pub fn size(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::I8 => 1,
        }
    }
    fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::I8 => "i8",
        }
    }
    fn from_name(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i8" => Ok(Dtype::I8),
            other => bail!("unsupported dtype '{other}'"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TensorView {
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

/// An in-memory QTZ file: named tensors + a free-form JSON metadata object.
pub struct TensorFile {
    pub meta: Json,
    entries: BTreeMap<String, TensorView>,
    blob: Vec<u8>,
}

impl Default for TensorFile {
    fn default() -> Self {
        Self::new()
    }
}

impl TensorFile {
    pub fn new() -> TensorFile {
        TensorFile { meta: Json::obj(), entries: BTreeMap::new(), blob: Vec::new() }
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(|s| s.as_str()).collect()
    }

    pub fn contains(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    pub fn view(&self, name: &str) -> Result<&TensorView> {
        self.entries.get(name).ok_or_else(|| anyhow!("tensor '{name}' not found"))
    }

    fn align_blob(&mut self) {
        while self.blob.len() % ALIGN != 0 {
            self.blob.push(0);
        }
    }

    pub fn put_f32(&mut self, name: &str, shape: &[usize], data: &[f32]) {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "{name}: shape/data mismatch");
        self.align_blob();
        let offset = self.blob.len();
        for v in data {
            self.blob.extend_from_slice(&v.to_le_bytes());
        }
        self.entries.insert(
            name.to_string(),
            TensorView { dtype: Dtype::F32, shape: shape.to_vec(), offset, nbytes: data.len() * 4 },
        );
    }

    pub fn put_mat(&mut self, name: &str, m: &Mat) {
        self.put_f32(name, &[m.rows, m.cols], &m.data);
    }

    pub fn put_i8(&mut self, name: &str, shape: &[usize], data: &[i8]) {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "{name}: shape/data mismatch");
        self.align_blob();
        let offset = self.blob.len();
        self.blob.extend(data.iter().map(|&v| v as u8));
        self.entries.insert(
            name.to_string(),
            TensorView { dtype: Dtype::I8, shape: shape.to_vec(), offset, nbytes: data.len() },
        );
    }

    pub fn get_f32(&self, name: &str) -> Result<(Vec<usize>, Vec<f32>)> {
        let v = self.view(name)?;
        if v.dtype != Dtype::F32 {
            bail!("tensor '{name}' is {:?}, wanted f32", v.dtype);
        }
        let bytes = &self.blob[v.offset..v.offset + v.nbytes];
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok((v.shape.clone(), data))
    }

    /// Fetch a rank-2 f32 tensor as a `Mat`.
    pub fn get_mat(&self, name: &str) -> Result<Mat> {
        let (shape, data) = self.get_f32(name)?;
        if shape.len() != 2 {
            bail!("tensor '{name}' has rank {} (wanted 2)", shape.len());
        }
        Ok(Mat::from_vec(shape[0], shape[1], data))
    }

    /// Fetch a rank-1 f32 tensor.
    pub fn get_vec(&self, name: &str) -> Result<Vec<f32>> {
        let (shape, data) = self.get_f32(name)?;
        if shape.len() != 1 {
            bail!("tensor '{name}' has rank {} (wanted 1)", shape.len());
        }
        Ok(data)
    }

    pub fn get_i8(&self, name: &str) -> Result<(Vec<usize>, Vec<i8>)> {
        let v = self.view(name)?;
        if v.dtype != Dtype::I8 {
            bail!("tensor '{name}' is {:?}, wanted i8", v.dtype);
        }
        let bytes = &self.blob[v.offset..v.offset + v.nbytes];
        Ok((v.shape.clone(), bytes.iter().map(|&b| b as i8).collect()))
    }

    pub fn serialize(&self) -> Vec<u8> {
        let mut tensors = Json::obj();
        for (name, v) in &self.entries {
            let mut t = Json::obj();
            t.set("dtype", Json::Str(v.dtype.name().into()))
                .set("shape", Json::from_usize_slice(&v.shape))
                .set("offset", Json::Num(v.offset as f64))
                .set("nbytes", Json::Num(v.nbytes as f64));
            tensors.set(name, t);
        }
        let mut header = Json::obj();
        header.set("meta", self.meta.clone()).set("tensors", tensors);
        let header_bytes = header.dump().into_bytes();

        let mut out = Vec::with_capacity(16 + header_bytes.len() + self.blob.len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(header_bytes.len() as u64).to_le_bytes());
        out.extend_from_slice(&header_bytes);
        // Pad so the blob start is ALIGN-aligned relative to file start.
        while (out.len()) % ALIGN != 0 {
            out.push(b' ');
        }
        out.extend_from_slice(&self.blob);
        out
    }

    pub fn deserialize(bytes: &[u8]) -> Result<TensorFile> {
        if bytes.len() < 12 || &bytes[..4] != MAGIC {
            bail!("not a QTZ1 file");
        }
        let header_len = u64::from_le_bytes(bytes[4..12].try_into().unwrap()) as usize;
        let header_end = 12 + header_len;
        if bytes.len() < header_end {
            bail!("truncated QTZ header");
        }
        let header_text = std::str::from_utf8(&bytes[12..header_end])
            .context("QTZ header not utf8")?;
        let header = Json::parse(header_text).map_err(|e| anyhow!("QTZ header: {e}"))?;
        let blob_start = header_end.div_ceil(ALIGN) * ALIGN;
        let blob = bytes[blob_start.min(bytes.len())..].to_vec();

        let mut entries = BTreeMap::new();
        if let Some(Json::Obj(tensors)) = header.get("tensors") {
            for (name, t) in tensors {
                let dtype = Dtype::from_name(
                    t.get("dtype").and_then(|d| d.as_str()).unwrap_or(""),
                )?;
                let shape: Vec<usize> = t
                    .get("shape")
                    .and_then(|s| s.as_arr())
                    .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                    .unwrap_or_default();
                let offset = t.get("offset").and_then(|o| o.as_usize()).unwrap_or(0);
                let nbytes = t.get("nbytes").and_then(|o| o.as_usize()).unwrap_or(0);
                if offset + nbytes > blob.len() {
                    bail!("tensor '{name}' out of bounds ({offset}+{nbytes} > {})", blob.len());
                }
                entries.insert(name.clone(), TensorView { dtype, shape, offset, nbytes });
            }
        }
        let meta = header.get("meta").cloned().unwrap_or_else(Json::obj);
        Ok(TensorFile { meta, entries, blob })
    }

    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let bytes = self.serialize();
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("create {}", path.as_ref().display()))?;
        f.write_all(&bytes)?;
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<TensorFile> {
        let mut f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("open {}", path.as_ref().display()))?;
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes)?;
        TensorFile::deserialize(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_in_memory() {
        let mut rng = Rng::new(1);
        let mut tf = TensorFile::new();
        tf.meta.set("model", Json::Str("tiny-s".into()));
        let w = Mat::randn(7, 5, 1.0, &mut rng);
        tf.put_mat("blocks.0.attn.wq", &w);
        tf.put_f32("scales", &[3], &[0.5, 1.5, -2.0]);
        tf.put_i8("codes", &[2, 2], &[-8, 7, 0, 1]);

        let back = TensorFile::deserialize(&tf.serialize()).unwrap();
        assert_eq!(back.meta.get("model").unwrap().as_str(), Some("tiny-s"));
        let w2 = back.get_mat("blocks.0.attn.wq").unwrap();
        assert_eq!(w, w2);
        assert_eq!(back.get_vec("scales").unwrap(), vec![0.5, 1.5, -2.0]);
        let (shape, codes) = back.get_i8("codes").unwrap();
        assert_eq!(shape, vec![2, 2]);
        assert_eq!(codes, vec![-8, 7, 0, 1]);
    }

    #[test]
    fn roundtrip_on_disk() {
        let mut tf = TensorFile::new();
        tf.put_f32("x", &[4], &[1.0, 2.0, 3.0, 4.0]);
        let path = std::env::temp_dir().join("qep_qtz_test.qtz");
        tf.save(&path).unwrap();
        let back = TensorFile::load(&path).unwrap();
        assert_eq!(back.get_vec("x").unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(TensorFile::deserialize(b"nope").is_err());
        let tf = TensorFile::new();
        let mut bytes = tf.serialize();
        bytes[0] = b'X';
        assert!(TensorFile::deserialize(&bytes).is_err());
    }

    #[test]
    fn missing_tensor_is_error() {
        let tf = TensorFile::new();
        assert!(tf.get_vec("absent").is_err());
    }

    #[test]
    fn dtype_mismatch_is_error() {
        let mut tf = TensorFile::new();
        tf.put_i8("c", &[1], &[3]);
        assert!(tf.get_f32("c").is_err());
    }

    #[test]
    fn alignment_is_respected() {
        let mut tf = TensorFile::new();
        tf.put_i8("a", &[3], &[1, 2, 3]);
        tf.put_f32("b", &[1], &[9.0]);
        let v = tf.view("b").unwrap();
        assert_eq!(v.offset % ALIGN, 0);
    }
}
