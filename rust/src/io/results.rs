//! Machine-readable experiment result records (JSON lines).
//!
//! A sharded sweep persists one [`CellRecord`] per executed cell to
//! `DIR/<sweep>.shard-<i>-of-<N>.jsonl`; `repro exp merge` reads every
//! `*.jsonl` in the directory back, verifies manifest coverage
//! (`exp::plan::verify_coverage`), and renders the tables. The format is
//! therefore a determinism boundary: every metric must survive the
//! write→read round trip **bit-exactly**, or merged tables would drift
//! from single-process renders. Finite floats ride on Rust's shortest
//! round-trip `f64` formatting; non-finite values (a collapsed cell's
//! infinite perplexity) are encoded as the strings `"inf"`/`"-inf"`/
//! `"nan"` because JSON has no literal for them.
//!
//! Timings (`timings`, `wall_s`) are wall-clock and *shard-local*: they
//! describe the process that measured them and are the one part of a
//! record that is not bit-deterministic across runs. `repro exp ...
//! --stable-timings --out DIR` zeroes them at write time
//! ([`CellRecord::stabilize`]) so determinism gates can compare record
//! files byte-for-byte.
//!
//! Crash safety: record files are written either atomically as a whole
//! ([`write_records`]: temp file + rename) or line-by-line through a
//! [`RecordAppender`] (one `write` per record, fsynced), so a SIGKILL can
//! only ever leave a *torn final line* — an unterminated trailing
//! fragment. [`read_records`] drops such a fragment with a warning
//! instead of erroring (the resume executor re-runs the cell), and
//! [`truncate_torn`] physically removes it before appending resumes.

use crate::coordinator::PhaseTimings;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

/// Everything measured for one executed plan cell.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CellRecord {
    /// The cell's identity (`exp::plan::PlanCell::id`).
    pub id: String,
    /// 1-based shard that produced this record; 0 for unsharded runs
    /// and single-cell (`repro exp cell`) runs.
    pub shard: usize,
    /// Total shard count of the producing run; 1 for unsharded runs.
    pub n_shards: usize,
    /// Perplexity per eval flavor name, sorted by flavor name.
    pub ppl: Vec<(String, f64)>,
    /// Zero-shot accuracy per task-family name, sorted by family name.
    pub acc: Vec<(String, f64)>,
    /// Fig. 2 only: per-block error deltas Δ_m.
    pub deltas: Vec<f64>,
    /// Pipeline phase timings (shard-local wall-clock).
    pub timings: PhaseTimings,
    /// End-to-end cell wall-clock including evaluation (shard-local).
    pub wall_s: f64,
    /// True when the cell ran on fallback random weights because the
    /// model artifact was missing — results are structural only.
    pub fallback: bool,
}

impl CellRecord {
    pub fn new(id: String, shard: usize, n_shards: usize) -> CellRecord {
        CellRecord { id, shard, n_shards, ..CellRecord::default() }
    }

    /// Canonicalize: metric lists sorted by key, matching what a JSON
    /// round trip produces (objects sort their keys), so `PartialEq`
    /// means the same thing before and after persistence.
    pub fn normalize(&mut self) {
        self.ppl.sort_by(|a, b| a.0.cmp(&b.0));
        self.acc.sort_by(|a, b| a.0.cmp(&b.0));
    }

    /// Metric lookup by eval flavor name; NaN when absent (renderers
    /// format NaN as "N/A", matching the historical drivers).
    pub fn ppl_for(&self, flavor: &str) -> f64 {
        lookup(&self.ppl, flavor)
    }

    /// Metric lookup by task-family name; NaN when absent.
    pub fn acc_for(&self, family: &str) -> f64 {
        lookup(&self.acc, family)
    }

    /// Zero the shard-local wall-clock fields (`timings`, `wall_s`) — the
    /// only non-deterministic bytes in a record. Applied at write time
    /// under `--stable-timings` so a killed-and-resumed run's record file
    /// can be compared byte-for-byte against an uninterrupted one.
    pub fn stabilize(&mut self) {
        self.timings = PhaseTimings::default();
        self.wall_s = 0.0;
    }

    /// The serialized JSONL form: one JSON object, newline-terminated.
    /// The trailing `\n` is the completeness marker — an appended record
    /// missing it is a torn tail from a crash mid-write.
    pub fn to_line(&self) -> String {
        let mut line = self.to_json().dump();
        line.push('\n');
        line
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("id", Json::Str(self.id.clone()))
            .set("shard", Json::Num(self.shard as f64))
            .set("n_shards", Json::Num(self.n_shards as f64))
            .set("ppl", metrics_json(&self.ppl))
            .set("acc", metrics_json(&self.acc))
            .set("deltas", Json::Arr(self.deltas.iter().map(|&v| f64_json(v)).collect()))
            .set("timings", timings_json(&self.timings))
            .set("wall_s", f64_json(self.wall_s))
            .set("fallback", Json::Bool(self.fallback));
        o
    }

    pub fn from_json(j: &Json) -> Result<CellRecord> {
        let id = j
            .get("id")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("record has no 'id'"))?
            .to_string();
        let mut rec = CellRecord::new(
            id,
            j.get("shard").and_then(Json::as_usize).unwrap_or(0),
            j.get("n_shards").and_then(Json::as_usize).unwrap_or(1),
        );
        rec.ppl = metrics_from_json(j.get("ppl"))?;
        rec.acc = metrics_from_json(j.get("acc"))?;
        if let Some(arr) = j.get("deltas").and_then(Json::as_arr) {
            rec.deltas = arr.iter().map(json_f64).collect::<Result<_>>()?;
        }
        if let Some(t) = j.get("timings") {
            rec.timings = timings_from_json(t)?;
        }
        rec.wall_s = j.get("wall_s").map(json_f64).transpose()?.unwrap_or(0.0);
        rec.fallback = matches!(j.get("fallback"), Some(Json::Bool(true)));
        Ok(rec)
    }
}

fn lookup(metrics: &[(String, f64)], key: &str) -> f64 {
    metrics.iter().find(|(k, _)| k == key).map(|&(_, v)| v).unwrap_or(f64::NAN)
}

/// Encode an `f64` exactly: finite values round-trip through Rust's
/// shortest-representation float formatting; non-finite become strings.
fn f64_json(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else if v.is_nan() {
        Json::Str("nan".to_string())
    } else if v > 0.0 {
        Json::Str("inf".to_string())
    } else {
        Json::Str("-inf".to_string())
    }
}

fn json_f64(j: &Json) -> Result<f64> {
    match j {
        Json::Num(n) => Ok(*n),
        Json::Str(s) => match s.as_str() {
            "nan" => Ok(f64::NAN),
            "inf" => Ok(f64::INFINITY),
            "-inf" => Ok(f64::NEG_INFINITY),
            other => bail!("bad float value '{other}'"),
        },
        other => bail!("expected a float, got {other:?}"),
    }
}

fn metrics_json(metrics: &[(String, f64)]) -> Json {
    let mut o = Json::obj();
    for (k, v) in metrics {
        o.set(k, f64_json(*v));
    }
    o
}

fn metrics_from_json(j: Option<&Json>) -> Result<Vec<(String, f64)>> {
    match j {
        None => Ok(Vec::new()),
        Some(Json::Obj(m)) => {
            // BTreeMap iteration is key-sorted — the normalized order.
            m.iter().map(|(k, v)| Ok((k.clone(), json_f64(v)?))).collect()
        }
        Some(other) => bail!("expected a metrics object, got {other:?}"),
    }
}

fn timings_json(t: &PhaseTimings) -> Json {
    let mut o = Json::obj();
    o.set("total_s", f64_json(t.total_s))
        .set("propagation_s", f64_json(t.propagation_s))
        .set("hessian_s", f64_json(t.hessian_s))
        .set("correction_s", f64_json(t.correction_s))
        .set("quant_s", f64_json(t.quant_s));
    o
}

fn timings_from_json(j: &Json) -> Result<PhaseTimings> {
    let field = |k: &str| -> Result<f64> { j.get(k).map(json_f64).transpose().map(|v| v.unwrap_or(0.0)) };
    Ok(PhaseTimings {
        total_s: field("total_s")?,
        propagation_s: field("propagation_s")?,
        hessian_s: field("hessian_s")?,
        correction_s: field("correction_s")?,
        quant_s: field("quant_s")?,
    })
}

/// Canonical record-file name for one shard of a sweep.
pub fn shard_filename(sweep: &str, shard: usize, count: usize) -> String {
    format!("{sweep}.shard-{shard}-of-{count}.jsonl")
}

/// Record-file name for a single cell run (`repro exp cell <id>`).
pub fn cell_filename(cell_id: &str) -> String {
    let sweep = cell_id.split('/').next().unwrap_or("cell");
    let rest: String = cell_id
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '.' || c == '-' { c } else { '_' })
        .collect();
    format!("{sweep}.cell-{rest}.jsonl")
}

/// Write records as JSON lines (one record per line) **atomically**:
/// the file is assembled in a sibling `.tmp` (which the `*.jsonl` readers
/// never pick up), fsynced, and renamed into place — a crash mid-write
/// can never leave a half-written `.jsonl` behind. Parent directories are
/// created as needed.
pub fn write_records(path: &Path, records: &[CellRecord]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
    }
    let mut out = String::new();
    for r in records {
        out.push_str(&r.to_line());
    }
    let tmp = path.with_extension("jsonl.tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(out.as_bytes())
            .with_context(|| format!("writing {}", tmp.display()))?;
        f.sync_data().with_context(|| format!("syncing {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", path.display()))?;
    Ok(())
}

/// Incremental, crash-safe record writer: each [`append`](Self::append)
/// issues a single `write` of one newline-terminated line and fsyncs it,
/// so after a SIGKILL the file holds every appended record intact plus at
/// most one torn (unterminated) fragment — which the tolerant readers
/// drop and [`truncate_torn`] removes. This is the durability primitive
/// under `repro exp ... --out DIR`: progress survives cell by cell.
pub struct RecordAppender {
    file: std::fs::File,
    path: PathBuf,
}

impl RecordAppender {
    /// Open `path` for appending (creating it, and parent directories, if
    /// needed). The caller is responsible for having truncated any torn
    /// tail first — appending after a fragment would corrupt the next line.
    pub fn open(path: &Path) -> Result<RecordAppender> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening {} for append", path.display()))?;
        Ok(RecordAppender { file, path: path.to_path_buf() })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Durably append one record: single write, then fsync.
    pub fn append(&mut self, rec: &CellRecord) -> Result<()> {
        let line = rec.to_line();
        self.file
            .write_all(line.as_bytes())
            .with_context(|| format!("appending to {}", self.path.display()))?;
        self.file
            .sync_data()
            .with_context(|| format!("syncing {}", self.path.display()))?;
        Ok(())
    }
}

/// A torn trailing fragment: bytes after the last newline-terminated
/// line, left by a process killed mid-append. The complete prefix
/// (`valid_bytes` long) is intact by the single-write append contract.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TornTail {
    /// Length in bytes of the valid (newline-terminated) prefix.
    pub valid_bytes: u64,
    /// Length in bytes of the dropped fragment.
    pub fragment_bytes: usize,
}

/// Everything a tolerant read recovers from one record file: the complete
/// records, plus the torn tail (if any) that was dropped.
pub struct ReadOutcome {
    pub records: Vec<CellRecord>,
    pub torn: Option<TornTail>,
}

/// Read one JSONL record file, tolerating a torn final line (no trailing
/// newline — the signature of a crash mid-append): the fragment is
/// reported, not parsed. Corruption anywhere else — a *terminated* line
/// that fails to parse — stays a hard error, because the append path can
/// never produce it. Empty files (a shard that owned no cells) yield no
/// records.
pub fn read_records_tolerant(path: &Path) -> Result<ReadOutcome> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let valid_end = text.rfind('\n').map(|i| i + 1).unwrap_or(0);
    let torn = if valid_end < text.len() {
        Some(TornTail { valid_bytes: valid_end as u64, fragment_bytes: text.len() - valid_end })
    } else {
        None
    };
    let mut records = Vec::new();
    for (i, line) in text[..valid_end].lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .map_err(|e| anyhow!("{}:{}: bad record JSON: {e}", path.display(), i + 1))?;
        records.push(
            CellRecord::from_json(&j)
                .with_context(|| format!("{}:{}", path.display(), i + 1))?,
        );
    }
    Ok(ReadOutcome { records, torn })
}

/// Read one JSONL record file. A torn final line (crash mid-append) is
/// dropped with a warning — never an error, so one killed shard cannot
/// poison an output directory; `repro exp <id> --resume` re-runs the
/// dropped cell.
pub fn read_records(path: &Path) -> Result<Vec<CellRecord>> {
    let out = read_records_tolerant(path)?;
    if let Some(t) = &out.torn {
        eprintln!(
            "[records] WARNING: {}: dropping torn final line ({} byte(s) after the last \
             complete record — a crash mid-append); the cell will count as missing",
            path.display(),
            t.fragment_bytes
        );
    }
    Ok(out.records)
}

/// Physically truncate a torn trailing fragment, leaving only complete
/// records. Returns `true` when bytes were cut. Must run before a resumed
/// run re-opens the file for append — appending after a fragment would
/// weld two records into one corrupt line.
pub fn truncate_torn(path: &Path) -> Result<bool> {
    let outcome = read_records_tolerant(path)?;
    match outcome.torn {
        None => Ok(false),
        Some(t) => {
            let f = std::fs::OpenOptions::new()
                .write(true)
                .open(path)
                .with_context(|| format!("opening {} to truncate torn tail", path.display()))?;
            f.set_len(t.valid_bytes)
                .with_context(|| format!("truncating {}", path.display()))?;
            f.sync_data()?;
            Ok(true)
        }
    }
}

/// Load every `*.jsonl` record file in `dir` (sorted by file name for a
/// deterministic read order). Errors when the directory holds no record
/// files at all — merging nothing is always a mistake.
pub fn read_record_dir(dir: &Path) -> Result<Vec<(PathBuf, Vec<CellRecord>)>> {
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("reading record dir {}", dir.display()))?;
    let mut files: Vec<PathBuf> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().map(|x| x == "jsonl").unwrap_or(false))
        .collect();
    files.sort();
    if files.is_empty() {
        bail!("no .jsonl record files in {}", dir.display());
    }
    files
        .into_iter()
        .map(|p| {
            let recs = read_records(&p)?;
            Ok((p, recs))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CellRecord {
        let mut r = CellRecord::new("table12/INT3/GPTQ/+qep/tiny-s".into(), 2, 3);
        r.ppl = vec![("wiki".into(), 6.123456789012345), ("ptb".into(), f64::INFINITY)];
        r.acc = vec![("cloze".into(), 0.515625)];
        r.deltas = vec![1.5e-7, 2.0];
        r.timings = PhaseTimings {
            total_s: 1.25,
            propagation_s: 0.5,
            hessian_s: 0.125,
            correction_s: 0.0625,
            quant_s: 0.5,
        };
        r.wall_s = 2.0;
        r.fallback = true;
        r.normalize();
        r
    }

    #[test]
    fn json_round_trip_is_bit_exact() {
        let rec = sample();
        let back = CellRecord::from_json(&Json::parse(&rec.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back.id, rec.id);
        assert_eq!(back.shard, 2);
        assert_eq!(back.n_shards, 3);
        assert_eq!(back.ppl.len(), 2);
        for ((ka, va), (kb, vb)) in rec.ppl.iter().zip(back.ppl.iter()) {
            assert_eq!(ka, kb);
            assert_eq!(va.to_bits(), vb.to_bits(), "{ka}");
        }
        assert_eq!(back.deltas[0].to_bits(), rec.deltas[0].to_bits());
        assert_eq!(back.timings, rec.timings);
        assert!(back.fallback);

        // NaN is representable too (it just isn't PartialEq-comparable).
        let mut nanrec = CellRecord::new("x".into(), 0, 1);
        nanrec.deltas = vec![f64::NAN];
        let back =
            CellRecord::from_json(&Json::parse(&nanrec.to_json().dump()).unwrap()).unwrap();
        assert!(back.deltas[0].is_nan());
    }

    #[test]
    fn awkward_floats_survive_exactly() {
        // Shortest-round-trip formatting must reproduce the bits for
        // values with no short decimal form.
        // (-0.0 is excluded: the JSON writer's integer fast path prints
        // it as "0", and no experiment metric can be negative zero.)
        for v in [
            1.0 / 3.0,
            f64::MIN_POSITIVE,
            1e300,
            6.02214076e23,
            f64::NEG_INFINITY,
        ] {
            let mut r = CellRecord::new("x".into(), 0, 1);
            r.ppl = vec![("wiki".into(), v)];
            let b = CellRecord::from_json(&Json::parse(&r.to_json().dump()).unwrap()).unwrap();
            assert_eq!(b.ppl[0].1.to_bits(), v.to_bits(), "value {v}");
        }
    }

    #[test]
    fn jsonl_files_round_trip() {
        let dir = std::env::temp_dir().join("qep_results_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(shard_filename("fig3", 1, 2));
        let recs = vec![sample(), CellRecord::new("fig3/INT3/tiny-s/base/s0".into(), 1, 2)];
        write_records(&path, &recs).unwrap();
        let back = read_records(&path).unwrap();
        assert_eq!(back, recs);
        // An empty shard file is valid and yields no records.
        let empty = dir.join(shard_filename("fig3", 2, 2));
        write_records(&empty, &[]).unwrap();
        assert!(read_records(&empty).unwrap().is_empty());
        let all = read_record_dir(&dir).unwrap();
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].1.len() + all[1].1.len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn filenames_are_tidy() {
        assert_eq!(shard_filename("all", 2, 3), "all.shard-2-of-3.jsonl");
        assert_eq!(
            cell_filename("table12/INT3/GPTQ/+qep/tiny-s"),
            "table12.cell-table12_INT3_GPTQ__qep_tiny-s.jsonl"
        );
    }

    #[test]
    fn torn_tail_is_dropped_reported_and_truncatable() {
        let dir = std::env::temp_dir().join(format!("qep_results_torn_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("torn.jsonl");
        let complete = sample().to_line();
        let mut bytes = complete.clone().into_bytes();
        bytes.extend_from_slice(b"{\"id\":\"fig3/INT3/ti"); // killed mid-write
        std::fs::write(&path, &bytes).unwrap();

        let out = read_records_tolerant(&path).unwrap();
        assert_eq!(out.records.len(), 1);
        let torn = out.torn.expect("fragment detected");
        assert_eq!(torn.valid_bytes as usize, complete.len());
        assert_eq!(torn.fragment_bytes, bytes.len() - complete.len());
        // The lenient reader drops it too (warning only).
        assert_eq!(read_records(&path).unwrap().len(), 1);

        assert!(truncate_torn(&path).unwrap());
        assert_eq!(std::fs::read(&path).unwrap(), complete.as_bytes());
        let clean = read_records_tolerant(&path).unwrap();
        assert_eq!(clean.records.len(), 1);
        assert!(clean.torn.is_none());
        assert!(!truncate_torn(&path).unwrap(), "second truncate is a no-op");

        // Appending after truncation yields two clean records.
        let mut app = RecordAppender::open(&path).unwrap();
        app.append(&CellRecord::new("fig3/INT3/tiny-s/base/s0".into(), 1, 2)).unwrap();
        assert_eq!(read_records(&path).unwrap().len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn appender_matches_whole_file_writer_byte_for_byte() {
        let dir = std::env::temp_dir().join(format!("qep_results_app_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let recs = vec![sample(), CellRecord::new("fig3/INT3/tiny-s/base/s1".into(), 2, 3)];
        let whole = dir.join("whole.jsonl");
        write_records(&whole, &recs).unwrap();
        let appended = dir.join("appended.jsonl");
        let mut app = RecordAppender::open(&appended).unwrap();
        for r in &recs {
            app.append(r).unwrap();
        }
        assert_eq!(std::fs::read(&whole).unwrap(), std::fs::read(&appended).unwrap());
        // No stray .tmp left behind, and the dir reader sees both files.
        assert!(!whole.with_extension("jsonl.tmp").exists());
        assert_eq!(read_record_dir(&dir).unwrap().len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stabilize_zeroes_only_wall_clock_fields() {
        let mut r = sample();
        r.stabilize();
        assert_eq!(r.timings, PhaseTimings::default());
        assert_eq!(r.wall_s, 0.0);
        assert_eq!(r.ppl_for("wiki"), 6.123456789012345, "metrics untouched");
        assert!(r.fallback);
    }

    #[test]
    fn corrupt_lines_error_with_location() {
        let dir = std::env::temp_dir().join("qep_results_corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        std::fs::write(&path, "{\"id\":\"x\"}\nnot json\n").unwrap();
        let err = read_records(&path).unwrap_err().to_string();
        assert!(err.contains(":2"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
