//! Thin, typed wrapper over the `xla` crate's PJRT client.
//!
//! The `xla` crate binds a vendored `xla_extension` build that is not
//! present in every build environment, so the real client lives behind the
//! `pjrt` cargo feature (enabling it additionally requires adding the
//! `xla` dependency to `Cargo.toml`). Without the feature this module
//! compiles a stub with the same surface whose constructor reports the
//! runtime as unavailable; everything else in the repo — quantization,
//! QEP, eval, experiments — is pure Rust and never needs it.

#[cfg(feature = "pjrt")]
mod real {
    use crate::linalg::Mat;
    use anyhow::{anyhow, Context, Result};
    use std::path::Path;

    /// One PJRT client per process; executables borrow it.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
    }

    impl PjrtRuntime {
        pub fn cpu() -> Result<PjrtRuntime> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
            Ok(PjrtRuntime { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO-text artifact.
        pub fn load<P: AsRef<Path>>(&self, path: P) -> Result<HloExecutable> {
            let path = path.as_ref();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
            Ok(HloExecutable { exe, name: path.display().to_string() })
        }
    }

    /// A compiled artifact ready to execute. JAX lowers with
    /// `return_tuple=True`, so outputs are always a tuple literal.
    pub struct HloExecutable {
        exe: xla::PjRtLoadedExecutable,
        pub name: String,
    }

    impl HloExecutable {
        /// Execute with raw literals; returns the decomposed output tuple.
        pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let result = self
                .exe
                .execute::<xla::Literal>(inputs)
                .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
            let out = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch {}: {e:?}", self.name))?;
            out.to_tuple().map_err(|e| anyhow!("untuple {}: {e:?}", self.name))
        }
    }

    /// Convert a row-major matrix into an f32 literal of shape [rows, cols].
    pub fn mat_to_literal(m: &Mat) -> Result<xla::Literal> {
        xla::Literal::vec1(&m.data)
            .reshape(&[m.rows as i64, m.cols as i64])
            .map_err(|e| anyhow!("reshape literal: {e:?}"))
    }

    /// Convert a 1-D f32 slice into a literal.
    pub fn vec_to_literal(v: &[f32]) -> xla::Literal {
        xla::Literal::vec1(v)
    }

    /// Tokens as an i32 literal of shape [n].
    pub fn tokens_to_literal(tokens: &[u32]) -> xla::Literal {
        let t: Vec<i32> = tokens.iter().map(|&x| x as i32).collect();
        xla::Literal::vec1(&t)
    }

    /// Read an f32 literal of any shape back into (shape, data).
    pub fn literal_to_f32(lit: &xla::Literal) -> Result<(Vec<usize>, Vec<f32>)> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow!("literal shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>().map_err(|e| anyhow!("literal data: {e:?}"))?;
        Ok((dims, data))
    }

    /// Read a rank-2 f32 literal into a Mat.
    pub fn literal_to_mat(lit: &xla::Literal) -> Result<Mat> {
        let (dims, data) = literal_to_f32(lit)?;
        match dims.len() {
            2 => Ok(Mat::from_vec(dims[0], dims[1], data)),
            // Accept [1, r, c] / [r*c] shapes defensively.
            3 if dims[0] == 1 => Ok(Mat::from_vec(dims[1], dims[2], data)),
            _ => Err(anyhow!("expected rank-2 literal, got {dims:?}")),
        }
    }
}

#[cfg(feature = "pjrt")]
pub use real::*;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use anyhow::{anyhow, Result};
    use std::path::Path;

    const UNAVAILABLE: &str =
        "PJRT runtime unavailable: built without the `pjrt` cargo feature \
         (requires the vendored `xla` crate)";

    /// Stub PJRT client compiled when the `pjrt` feature is off. Mirrors
    /// the real surface so callers (`repro info`, experiment fallbacks)
    /// degrade gracefully instead of failing to build.
    pub struct PjrtRuntime {
        _private: (),
    }

    impl PjrtRuntime {
        pub fn cpu() -> Result<PjrtRuntime> {
            Err(anyhow!(UNAVAILABLE))
        }

        pub fn platform(&self) -> String {
            "unavailable (no `pjrt` feature)".to_string()
        }

        pub fn load<P: AsRef<Path>>(&self, _path: P) -> Result<HloExecutable> {
            Err(anyhow!(UNAVAILABLE))
        }
    }

    /// Stub executable; never constructible without the `pjrt` feature.
    pub struct HloExecutable {
        pub name: String,
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::*;
