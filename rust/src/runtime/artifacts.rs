//! Artifact registry + the PJRT-backed model executor.
//!
//! `make artifacts` (Python, build-time only) writes per-size HLO programs:
//!
//! * `<name>.fwd.hlo.txt`    — tokens[seq] + all weights → (logits,)
//! * `<name>.block.hlo.txt`  — x[seq,d] + block weights → (out, attn_in,
//!                             attn_ctx, mlp_in, mlp_act) — the capture op
//! * `<name>.qmm.hlo.txt`    — Pallas fused dequant×matmul (serving path)
//! * `<name>.hess.hlo.txt`   — Pallas Hessian accumulation X → XᵀX
//! * `<name>.qtz`            — trained weights
//! * `data/<flavor>.txt`     — corpora (written by `repro gen-data`)
//!
//! Weight parameter order is canonical (see `param_order`) and mirrored by
//! `python/compile/aot.py`; changing one side breaks the cross-check test.

#[cfg(feature = "pjrt")]
use super::executor::{
    literal_to_mat, mat_to_literal, tokens_to_literal, vec_to_literal, HloExecutable, PjrtRuntime,
};
#[cfg(feature = "pjrt")]
use crate::linalg::Mat;
#[cfg(feature = "pjrt")]
use crate::model::ops::next_token_nll;
use crate::model::{Model, ModelConfig};
#[cfg(feature = "pjrt")]
use anyhow::anyhow;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

pub struct ArtifactRegistry {
    pub root: PathBuf,
}

impl ArtifactRegistry {
    pub fn new<P: AsRef<Path>>(root: P) -> ArtifactRegistry {
        ArtifactRegistry { root: root.as_ref().to_path_buf() }
    }

    /// Default location relative to the repo root.
    pub fn default_root() -> ArtifactRegistry {
        ArtifactRegistry::new("artifacts")
    }

    pub fn model_weights(&self, name: &str) -> PathBuf {
        self.root.join(format!("{name}.qtz"))
    }

    pub fn fwd_hlo(&self, name: &str) -> PathBuf {
        self.root.join(format!("{name}.fwd.hlo.txt"))
    }

    pub fn block_hlo(&self, name: &str) -> PathBuf {
        self.root.join(format!("{name}.block.hlo.txt"))
    }

    pub fn qmm_hlo(&self, name: &str) -> PathBuf {
        self.root.join(format!("{name}.qmm.hlo.txt"))
    }

    pub fn hess_hlo(&self, name: &str) -> PathBuf {
        self.root.join(format!("{name}.hess.hlo.txt"))
    }

    pub fn corpus(&self, flavor: &str) -> PathBuf {
        self.root.join("data").join(format!("{flavor}.txt"))
    }

    pub fn has_model(&self, name: &str) -> bool {
        self.model_weights(name).exists() && self.fwd_hlo(name).exists()
    }

    pub fn load_model(&self, name: &str) -> Result<Model> {
        Model::load(self.model_weights(name))
            .with_context(|| format!("loading {name} (run `make artifacts` first)"))
    }

    pub fn load_corpus(&self, flavor: crate::text::Flavor) -> Result<crate::text::Corpus> {
        let path = self.corpus(flavor.name());
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `repro gen-data`)", path.display()))?;
        Ok(crate::text::Corpus::from_text(flavor, text))
    }
}

/// Canonical flat parameter order for the `fwd` artifact (after `tokens`).
pub fn param_order(cfg: &ModelConfig) -> Vec<String> {
    let mut names = vec!["embed".to_string(), "pos".to_string()];
    for i in 0..cfg.n_layers {
        let p = format!("blocks.{i}");
        names.push(format!("{p}.attn_norm"));
        names.push(format!("{p}.attn.wq"));
        names.push(format!("{p}.attn.wk"));
        names.push(format!("{p}.attn.wv"));
        names.push(format!("{p}.attn.wo"));
        names.push(format!("{p}.mlp_norm"));
        names.push(format!("{p}.mlp.gate"));
        names.push(format!("{p}.mlp.up"));
        names.push(format!("{p}.mlp.down"));
    }
    names.push("final_norm".to_string());
    names
}

/// Collect a model's weights as literals in canonical order.
#[cfg(feature = "pjrt")]
fn weight_literals(model: &Model) -> Result<Vec<xla::Literal>> {
    let mut lits = Vec::new();
    lits.push(mat_to_literal(&model.embed)?);
    lits.push(mat_to_literal(&model.pos)?);
    for b in &model.blocks {
        lits.push(vec_to_literal(&b.attn_norm));
        lits.push(mat_to_literal(&b.wq)?);
        lits.push(mat_to_literal(&b.wk)?);
        lits.push(mat_to_literal(&b.wv)?);
        lits.push(mat_to_literal(&b.wo)?);
        lits.push(vec_to_literal(&b.mlp_norm));
        lits.push(mat_to_literal(&b.gate)?);
        lits.push(mat_to_literal(&b.up)?);
        lits.push(mat_to_literal(&b.down)?);
    }
    lits.push(vec_to_literal(&model.final_norm));
    Ok(lits)
}

/// A model served through the compiled PJRT forward artifact. Weights are
/// converted to literals once; per request only the token literal changes.
#[cfg(feature = "pjrt")]
pub struct PjrtModel {
    exe: HloExecutable,
    weights: Vec<xla::Literal>,
    pub cfg: ModelConfig,
}

#[cfg(feature = "pjrt")]
impl PjrtModel {
    /// Compile the artifact and bind `model`'s weights (which may be a
    /// quantized variant — same shapes, different values).
    pub fn bind(rt: &PjrtRuntime, reg: &ArtifactRegistry, model: &Model) -> Result<PjrtModel> {
        let exe = rt.load(reg.fwd_hlo(&model.cfg.name))?;
        Ok(PjrtModel { exe, weights: weight_literals(model)?, cfg: model.cfg.clone() })
    }

    /// Logits for exactly one segment of `seq_len` tokens.
    pub fn logits(&self, tokens: &[u32]) -> Result<Mat> {
        if tokens.len() != self.cfg.seq_len {
            return Err(anyhow!(
                "fwd artifact is shape-specialized to seq_len={}, got {}",
                self.cfg.seq_len,
                tokens.len()
            ));
        }
        let mut inputs = Vec::with_capacity(1 + self.weights.len());
        inputs.push(tokens_to_literal(tokens));
        // Literal isn't Clone in the public API; re-create views each call
        // is wasteful, so we keep literals and pass by slice reference.
        for w in &self.weights {
            inputs.push(shallow_copy(w)?);
        }
        let out = self.exe.run(&inputs)?;
        literal_to_mat(&out[0])
    }

    /// Perplexity over a token stream (multiple of seq_len).
    pub fn perplexity(&self, tokens: &[u32]) -> Result<f64> {
        let seq = self.cfg.seq_len;
        let usable = tokens.len() / seq * seq;
        let mut sum = 0.0;
        let mut count = 0usize;
        for seg in tokens[..usable].chunks_exact(seq) {
            let logits = self.logits(seg)?;
            let (s, c) = next_token_nll(&logits, seg, seq);
            sum += s;
            count += c;
        }
        Ok((sum / count.max(1) as f64).exp())
    }
}

/// The xla crate's `Literal` is not `Clone`; round-trip through raw data.
#[cfg(feature = "pjrt")]
fn shallow_copy(lit: &xla::Literal) -> Result<xla::Literal> {
    let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e:?}"))?;
    let dims: Vec<i64> = shape.dims().to_vec();
    match shape.ty() {
        xla::ElementType::F32 => {
            let v = lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
            xla::Literal::vec1(&v).reshape(&dims).map_err(|e| anyhow!("{e:?}"))
        }
        xla::ElementType::S32 => {
            let v = lit.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?;
            xla::Literal::vec1(&v).reshape(&dims).map_err(|e| anyhow!("{e:?}"))
        }
        other => Err(anyhow!("unsupported literal type {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Size;

    #[test]
    fn registry_paths() {
        let reg = ArtifactRegistry::new("/tmp/a");
        assert_eq!(reg.fwd_hlo("tiny-s"), PathBuf::from("/tmp/a/tiny-s.fwd.hlo.txt"));
        assert_eq!(reg.model_weights("tiny-m"), PathBuf::from("/tmp/a/tiny-m.qtz"));
        assert_eq!(reg.corpus("wiki"), PathBuf::from("/tmp/a/data/wiki.txt"));
        assert!(!reg.has_model("missing"));
    }

    #[test]
    fn param_order_matches_model_layout() {
        let cfg = Size::TinyS.config();
        let names = param_order(&cfg);
        assert_eq!(names.len(), 3 + 9 * cfg.n_layers);
        assert_eq!(names[0], "embed");
        assert_eq!(names[2], "blocks.0.attn_norm");
        assert_eq!(names.last().unwrap(), "final_norm");
        // Count matches weight_literals emission (needs the xla crate).
        #[cfg(feature = "pjrt")]
        {
            let model = Model::random(&cfg, 0);
            let lits = weight_literals(&model).unwrap();
            assert_eq!(lits.len(), names.len());
        }
    }
}
