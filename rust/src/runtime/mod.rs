//! PJRT runtime: loads HLO-text artifacts produced by `python/compile/aot.py`
//! (JAX + Pallas, lowered once at build time) and executes them on the PJRT
//! CPU client. Python is never on this path — the artifacts are plain
//! files; after `make artifacts` the `repro` binary is self-contained.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

pub mod artifacts;
pub mod executor;

pub use artifacts::ArtifactRegistry;
pub use executor::{HloExecutable, PjrtRuntime};
