//! The layer-wise PTQ coordinator — the L3 system contribution.
//!
//! It owns the *dual calibration streams*: full-precision activations `X`
//! propagated through the original weights, and quantized-stream
//! activations `X̂` propagated through everything quantized so far
//! (including earlier linears of the *same* block, in execution order
//! q/k/v → o → gate/up → down). Per linear layer it:
//!
//! 1. captures `(X, X̂)` at the layer input,
//! 2. applies the QEP correction `W*(α)` (when enabled),
//! 3. builds the layer Hessian from the method's calibration stream,
//! 4. dispatches to the configured base quantizer (RTN/GPTQ/AWQ/QuIP),
//! 5. writes the quantized weights into the output model and advances `X̂`.
//!
//! Phase timings are recorded per layer — they regenerate Table 3.

pub mod pipeline;
pub mod report;

pub use pipeline::{CBQ_WINDOW_META_KEY, Pipeline, PipelineConfig, PipelineOutput};
pub use report::{LayerReport, PhaseTimings, PipelineReport};
