//! Pipeline diagnostics: per-layer reconstruction errors, correction
//! magnitudes, and phase timings (Table 3's "quantization process" cost).

use crate::qep::CorrectionStats;

#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    /// Bit width this layer was quantized at (equals the uniform
    /// `QuantConfig.bits` unless a mixed-precision budget allocated a
    /// per-layer width).
    pub bits: u32,
    /// Layer-wise objective value ‖(W_target − Ŵ)X̂‖² after quantization.
    pub recon_error: f64,
    /// QEP correction diagnostics (zeroed when QEP is off or α=0).
    pub correction: CorrectionStats,
    /// Seconds building the Hessian / activation statistics.
    pub hessian_s: f64,
    /// Seconds inside the base quantizer.
    pub quant_s: f64,
    /// α used for this layer (0 when QEP off).
    pub alpha: f32,
}

#[derive(Clone, Debug, Default)]
pub struct PipelineReport {
    pub layers: Vec<LayerReport>,
    /// Seconds propagating the two calibration streams (forward passes).
    pub propagation_s: f64,
    /// Seconds in the mixed-precision scoring pre-pass + allocator
    /// (0 when no bit budget was requested).
    pub allocation_s: f64,
    pub total_s: f64,
}

/// Flat phase-timing snapshot of a pipeline run — the machine-readable
/// form carried by experiment result records (`io::results`). Timings
/// are wall-clock and therefore local to the process that measured them
/// (a sharded sweep's timings are *shard-local*); everything else in a
/// record is bit-deterministic.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseTimings {
    pub total_s: f64,
    pub propagation_s: f64,
    pub hessian_s: f64,
    pub correction_s: f64,
    pub quant_s: f64,
}

impl PipelineReport {
    /// Snapshot the per-phase timing aggregates (see [`PhaseTimings`]).
    pub fn timings(&self) -> PhaseTimings {
        PhaseTimings {
            total_s: self.total_s,
            propagation_s: self.propagation_s,
            hessian_s: self.hessian_s(),
            correction_s: self.correction_s(),
            quant_s: self.quant_s(),
        }
    }

    pub fn correction_s(&self) -> f64 {
        self.layers.iter().map(|l| l.correction.seconds).sum()
    }

    pub fn hessian_s(&self) -> f64 {
        self.layers.iter().map(|l| l.hessian_s).sum()
    }

    pub fn quant_s(&self) -> f64 {
        self.layers.iter().map(|l| l.quant_s).sum()
    }

    pub fn total_recon_error(&self) -> f64 {
        self.layers.iter().map(|l| l.recon_error).sum()
    }

    pub fn summary(&self) -> String {
        format!(
            "layers={} total={} (propagate={}, hessian={}, correction={}, quantize={}) recon={:.4e}",
            self.layers.len(),
            crate::util::fmt_duration(self.total_s),
            crate::util::fmt_duration(self.propagation_s),
            crate::util::fmt_duration(self.hessian_s()),
            crate::util::fmt_duration(self.correction_s()),
            crate::util::fmt_duration(self.quant_s()),
            self.total_recon_error()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregation_sums_layers() {
        let mut r = PipelineReport::default();
        for i in 0..3 {
            r.layers.push(LayerReport {
                name: format!("l{i}"),
                bits: 3,
                recon_error: 1.0,
                correction: CorrectionStats { rel_correction: 0.1, rel_upstream_err: 0.0, seconds: 0.5 },
                hessian_s: 0.25,
                quant_s: 1.0,
                alpha: 0.5,
            });
        }
        assert!((r.correction_s() - 1.5).abs() < 1e-12);
        assert!((r.hessian_s() - 0.75).abs() < 1e-12);
        assert!((r.quant_s() - 3.0).abs() < 1e-12);
        assert!((r.total_recon_error() - 3.0).abs() < 1e-12);
        assert!(r.summary().contains("layers=3"));
    }
}
