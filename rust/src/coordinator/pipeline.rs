//! The quantization pipeline driver.
//!
//! Parallelism happens at three nested levels, all on the same persistent
//! worker pool and all bit-identical to serial execution: the per-layer
//! fan-out here (wq/wk/wv and gate/up share captured inputs), the
//! row-partitioned GEMM/Hessian kernels (`linalg::par`), and the blocked
//! SPD engine behind the QEP correction and GPTQ's Cholesky factor
//! (`linalg::chol`). Nested calls degrade gracefully: work issued from
//! inside a pool worker runs inline instead of oversubscribing.
//!
//! Calibration is **software-pipelined**: a producer stage walks the
//! full-precision model over the calibration stream (`Forward::block` +
//! `BlockCapture`) on its own thread while the consumer stage quantizes
//! the current block, so block b+1's forward pass overlaps block b's
//! Hessian/Cholesky work instead of sitting serially on the critical
//! path. The hand-off point is fixed — one bounded channel slot, received
//! at the top of each consumer iteration — and the producer runs the
//! exact `Forward::block` chain the serial schedule would, so the
//! captures (and therefore the outputs) are bit-identical for every
//! thread count (see [`CapSource`]; `tests/parallel_equivalence.rs` is
//! the gate). A `threads = 1` pipeline skips the producer thread and
//! computes captures inline.
//!
//! Cross-block (CBQ-style) reconstruction: [`PipelineConfig::cbq_window`]
//! groups blocks into tumbling windows of W blocks. After a window's
//! layer-wise pass, every window layer is jointly re-reconstructed from
//! the *original* weights against a local full-precision reference — the
//! original window weights applied to the window's actual (drifted)
//! quantized-stream entry — so compensation targets the error the window
//! itself introduces (see [`Pipeline::refine_window`] for the math and
//! the provable no-op cases that keep `cbq_window = 1` byte-identical to
//! the layer-wise schedule).
//!
//! Pool lifecycle: [`Pipeline::new`] pre-starts the process-wide workers
//! (`util::pool::prestart`) whenever it will actually dispatch in
//! parallel, so the first layer's many small per-panel jobs don't pay the
//! one-time spawn cost; a `threads = 1` pipeline stays fully inline and
//! never starts them. Workers park between dispatches and survive across
//! pipeline runs; `repro` joins them on exit (`util::pool::shutdown`).

use super::report::{LayerReport, PipelineReport};
use crate::linalg::Mat;
use crate::model::ops::{causal_attention, linear, rmsnorm, swiglu};
use crate::model::{BlockCapture, BlockWeights, Forward, Model};
use crate::qep::{adjunct_from_residual, AlphaPolicy, CorrectionStats, LowRankAdjunct};
use crate::quant::budget::{self, Allocation, BudgetSpec};
use crate::quant::{quantizer_for, LayerCtx, Method, QuantConfig, Quantizer};
use crate::util::pool::Pool;
use crate::util::Stopwatch;
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::mpsc;

/// Linears that share one captured input stream and therefore quantize
/// independently of each other: their Hessian builds, QEP corrections, and
/// quantizer runs fan out across the pool (execution-order application
/// keeps reports deterministic).
const ATTN_QKV: [&str; 3] = ["attn.wq", "attn.wk", "attn.wv"];
const MLP_GATE_UP: [&str; 2] = ["mlp.gate", "mlp.up"];

/// `.qtz` meta key recording the CBQ window a model was quantized with
/// (only written when the window is > 1 — layer-wise artifacts stay
/// byte-identical to pre-CBQ writers).
pub const CBQ_WINDOW_META_KEY: &str = "cbq_window";

#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub quant: QuantConfig,
    pub method: Method,
    /// `Some(α)` enables QEP with uniform α; `None` is the BASE method.
    pub qep_alpha: Option<f32>,
    /// Fine-grained α policy; overrides `qep_alpha`'s uniform value when
    /// set (both require `qep_alpha = Some(_)` to enable QEP at all).
    pub alpha_policy: Option<AlphaPolicy>,
    /// QEP correction damping relative to mean(diag Ĥ) (App. B.1 uses the
    /// full mean diagonal ⇒ 1.0).
    pub damp_rel: f64,
    /// Quantize only the first `n` blocks, leaving the rest full precision
    /// (the Fig. 2 error-accumulation setup).
    pub max_blocks: Option<usize>,
    /// Rank of the low-rank error-reconstruction adjunct (LQER/QERA):
    /// after the base method runs, the residual `W* − Q(W*)` is
    /// approximated by a rank-`r` term `U·V` in the calibration-Hessian
    /// metric and carried alongside the quantized weights. `0` disables
    /// the adjunct. Orthogonal to `qep_alpha` — every method × ±QEP cell
    /// gains a `±lowrank` twin.
    pub lowrank_rank: usize,
    /// Mixed-precision bit budget (`quant::budget`): when set, a
    /// full-precision scoring pre-pass allocates per-layer bit widths
    /// under this average-bits-per-weight ceiling and `quant.bits` is
    /// ignored (the group setting still applies to every layer). The
    /// allocation is recorded in [`PipelineOutput::allocation`].
    pub bit_budget: Option<BudgetSpec>,
    /// CBQ-style cross-block window: blocks are grouped into tumbling
    /// windows of this many blocks, and after each window's layer-wise
    /// pass its layers are jointly re-reconstructed against the window's
    /// local full-precision reference ([`Pipeline::refine_window`]).
    /// `1` (the default) is exactly the layer-wise schedule — no window
    /// ever refines — and values beyond the quantized block count clamp
    /// loudly to one whole-model window.
    pub cbq_window: usize,
    pub seed: u64,
    pub verbose: bool,
    /// Worker threads for this pipeline's per-layer fan-out (0 = the
    /// process-wide default, which itself defaults to all hardware
    /// threads). GEMM/Hessian kernels consult the process-wide setting
    /// (`util::pool::set_global_threads`; the `repro --threads` flag sets
    /// both). Results are bit-identical for every value — per-layer seeds
    /// derive from the layer name and every parallel kernel fixes its
    /// reduction order — so these knobs only trade wall-clock time.
    pub threads: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            quant: QuantConfig::int(4),
            method: Method::Rtn,
            qep_alpha: None,
            alpha_policy: None,
            damp_rel: 1.0,
            max_blocks: None,
            lowrank_rank: 0,
            bit_budget: None,
            cbq_window: 1,
            seed: 0,
            verbose: false,
            threads: 0,
        }
    }
}

impl PipelineConfig {
    pub fn label(&self) -> String {
        let mut label = format!(
            "{} {} {}",
            self.quant.label(),
            self.method.name(),
            if self.qep_alpha.is_some() { "+QEP" } else { "base" }
        );
        if self.lowrank_rank > 0 {
            label.push_str(&format!(" +LR{}", self.lowrank_rank));
        }
        if let Some(spec) = &self.bit_budget {
            label.push_str(&format!(" B{}/{}", spec.budget.render(), spec.alloc.name()));
        }
        if self.cbq_window > 1 {
            label.push_str(&format!(" W{}", self.cbq_window));
        }
        label
    }

    fn policy(&self) -> Option<AlphaPolicy> {
        match (self.qep_alpha, &self.alpha_policy) {
            (Some(_), Some(p)) => Some(p.clone()),
            (Some(a), None) => Some(AlphaPolicy::uniform(a)),
            (None, _) => None,
        }
    }
}

pub struct PipelineOutput {
    /// The effective quantized model. When low-rank adjuncts were
    /// requested they are already folded into these dense weights, so
    /// evaluation and the pipeline's own propagation stream both see the
    /// corrected network.
    pub model: Model,
    /// The on-grid model (adjunct layers hold `Q(W*)` without `U·V`);
    /// `None` when the run produced no adjuncts. The `.qtz` artifact
    /// stores this plus the factors so serving can keep the factored form.
    pub base_model: Option<Model>,
    /// Per-layer low-rank factors, keyed by canonical layer name
    /// (`blocks.{i}.{short}`). Empty unless `lowrank_rank > 0`.
    pub adjuncts: BTreeMap<String, LowRankAdjunct>,
    /// The mixed-precision bit allocation, present iff
    /// `PipelineConfig.bit_budget` was set. `main` records it in the
    /// `.qtz` meta so eval and serving materialize the same per-layer
    /// grids.
    pub allocation: Option<Allocation>,
    pub report: PipelineReport,
}

/// Where the consumer stage gets its per-block full-precision captures:
/// computed inline (the serial schedule) or received from the producer
/// thread (the pipelined schedule). Both deliver bit-identical captures —
/// the producer runs the exact `Forward::block` chain over the same
/// full-precision stream the inline path walks — so the choice only
/// affects wall-clock, never bytes. The `recv` at the top of each
/// consumer iteration is the fixed hand-off point of the determinism
/// contract.
enum CapSource<'a> {
    Inline { f: &'a Forward<'a>, model: &'a Model, x: Mat },
    Piped(mpsc::Receiver<(BlockCapture, f64)>),
}

impl CapSource<'_> {
    /// Block `bi`'s capture plus the seconds its forward pass took (the
    /// producer measures its own wall-clock; timings are informational
    /// and never part of the deterministic surface).
    fn next(&mut self, bi: usize) -> (BlockCapture, f64) {
        match self {
            CapSource::Inline { f, model, x } => {
                let sw = Stopwatch::start();
                let (nx, cap) = f.block(&model.blocks[bi], x);
                let secs = sw.seconds();
                *x = nx;
                (cap, secs)
            }
            CapSource::Piped(rx) => {
                rx.recv().expect("calibration producer delivers one capture per block")
            }
        }
    }
}

/// The mutable quantized-stream state a pipeline run threads through its
/// pass-1 block loop and CBQ window refinements.
struct RunState {
    qmodel: Model,
    adjuncts: BTreeMap<String, LowRankAdjunct>,
    base_weights: Vec<(usize, String, Mat)>,
    report: PipelineReport,
}

/// One CBQ window's saved state: the block index it starts at, the
/// quantized-stream activations entering it, and the frozen per-block
/// captures of the pass-1 quantized stream (exactly the activations the
/// layer-wise pass calibrated on — cloned, never recomputed).
struct CbqWindow {
    start: usize,
    entry: Mat,
    frozen: Vec<BlockCapture>,
}

pub struct Pipeline {
    cfg: PipelineConfig,
    quantizer: Box<dyn Quantizer + Send + Sync>,
    pool: Pool,
}

impl Pipeline {
    pub fn new(cfg: PipelineConfig) -> Pipeline {
        let quantizer = quantizer_for(cfg.method);
        let pool = Pool::new(cfg.threads);
        if pool.threads() > 1 {
            // Spawn the persistent workers up front so the first layer's
            // small per-panel dispatches don't pay the one-time cost.
            crate::util::pool::prestart();
        }
        Pipeline { cfg, quantizer, pool }
    }

    /// Run layer-wise PTQ over the model using `calib_tokens` (length must
    /// tile the model's seq_len).
    pub fn run(&self, model: &Model, calib_tokens: &[u32]) -> Result<PipelineOutput> {
        let total = Stopwatch::start();
        let f = Forward::new(&model.cfg);
        let policy = self.cfg.policy();
        let mut st = RunState {
            qmodel: model.clone(),
            adjuncts: BTreeMap::new(),
            base_weights: Vec::new(),
            report: PipelineReport::default(),
        };

        let n_blocks = self
            .cfg
            .max_blocks
            .unwrap_or(model.cfg.n_layers)
            .min(model.cfg.n_layers);
        let window = self.effective_window(n_blocks);

        // Mixed precision: a dedicated full-precision pre-pass scores every
        // quantizable linear *before* quantization starts (the allocation
        // is global, so no layer may be touched until all are scored). The
        // whole pre-pass is serial and name-keyed — bit-identical for every
        // thread count.
        let alloc_timer = Stopwatch::start();
        let allocation = match &self.cfg.bit_budget {
            Some(spec) => Some(self.allocate_bits(model, calib_tokens, &f, n_blocks, *spec)?),
            None => None,
        };
        if allocation.is_some() {
            st.report.allocation_s = alloc_timer.seconds();
            if self.cfg.verbose {
                eprintln!("[pipeline] {}", allocation.as_ref().unwrap().summary());
            }
        }

        let prop = Stopwatch::start();
        let x_full = f.embed(model, calib_tokens);
        let mut x_hat = x_full.clone();
        st.report.propagation_s += prop.seconds();

        // The producer thread (pipelined schedule) borrows `model`/`f`
        // for the scope's duration; the consumer below owns every mutable
        // stream, so the stages never share mutable state and the only
        // synchronization is the bounded capture channel.
        std::thread::scope(|scope| -> Result<()> {
            let mut caps = if self.pool.threads() > 1 && n_blocks > 0 {
                // Producer stage: walk the full-precision stream one block
                // ahead of the consumer. The bounded slot keeps it at most
                // one capture ahead; a dropped receiver (consumer error)
                // ends it early.
                let (tx, rx) = mpsc::sync_channel(1);
                let (fwd, blocks) = (&f, &model.blocks[..n_blocks]);
                scope.spawn(move || {
                    let mut x = x_full;
                    for b in blocks {
                        let sw = Stopwatch::start();
                        let (nx, cap) = fwd.block(b, &x);
                        let secs = sw.seconds();
                        x = nx;
                        if tx.send((cap, secs)).is_err() {
                            return;
                        }
                    }
                });
                CapSource::Piped(rx)
            } else {
                CapSource::Inline { f: &f, model, x: x_full }
            };

            // CBQ bookkeeping. Windows starting at block 0 are never
            // recorded: there the quantized and full-precision streams
            // share the model input, so the window's local reference
            // equals the pass-1 captures and re-reconstruction is a
            // provable bitwise no-op (this is also why `cbq_window`
            // clamped to the whole model reproduces the layer-wise
            // bytes exactly).
            let mut win: Option<CbqWindow> = None;
            for bi in 0..n_blocks {
                if window > 1 && bi > 0 && bi % window == 0 {
                    win = Some(CbqWindow {
                        start: bi,
                        entry: x_hat.clone(),
                        frozen: Vec::new(),
                    });
                }

                // Full-precision stream: the fixed per-block hand-off.
                let (cap, fwd_secs) = caps.next(bi);
                st.report.propagation_s += fwd_secs;

                // Quantized stream, incrementally quantizing in execution
                // order.
                // -- attention ------------------------------------------
                let prop = Stopwatch::start();
                let attn_in_hat = rmsnorm(&x_hat, &st.qmodel.blocks[bi].attn_norm);
                st.report.propagation_s += prop.seconds();
                // wq/wk/wv see the same captured inputs and never read
                // each other's quantized weights, so they fan out across
                // the pool; applying in canonical order keeps the run
                // deterministic.
                let outs = self.pool.par_map(ATTN_QKV.len(), |i| {
                    self.compute_layer(
                        &st.qmodel,
                        bi,
                        ATTN_QKV[i],
                        &cap.attn_in,
                        &attn_in_hat,
                        policy.as_ref(),
                        Self::layer_bits(allocation.as_ref(), bi, ATTN_QKV[i]),
                    )
                });
                for (short, out) in ATTN_QKV.iter().zip(outs) {
                    let (w_hat, adj, layer_report) = out?;
                    Self::install(&mut st, bi, short, w_hat, adj);
                    st.report.layers.push(layer_report);
                }
                let prop = Stopwatch::start();
                let b = &st.qmodel.blocks[bi];
                let (q, k, v) = (
                    linear(&attn_in_hat, &b.wq),
                    linear(&attn_in_hat, &b.wk),
                    linear(&attn_in_hat, &b.wv),
                );
                let ctx_hat = causal_attention(&q, &k, &v, model.cfg.n_heads, model.cfg.seq_len);
                st.report.propagation_s += prop.seconds();
                let (w_hat, adj, layer_report) = self.compute_layer(
                    &st.qmodel,
                    bi,
                    "attn.wo",
                    &cap.attn_ctx,
                    &ctx_hat,
                    policy.as_ref(),
                    Self::layer_bits(allocation.as_ref(), bi, "attn.wo"),
                )?;
                Self::install(&mut st, bi, "attn.wo", w_hat, adj);
                st.report.layers.push(layer_report);

                // -- MLP ------------------------------------------------
                let prop = Stopwatch::start();
                let b = &st.qmodel.blocks[bi];
                let x1_hat = x_hat.add(&linear(&ctx_hat, &b.wo));
                let mlp_in_hat = rmsnorm(&x1_hat, &b.mlp_norm);
                st.report.propagation_s += prop.seconds();
                // gate/up share captured inputs, exactly like wq/wk/wv.
                let outs = self.pool.par_map(MLP_GATE_UP.len(), |i| {
                    self.compute_layer(
                        &st.qmodel,
                        bi,
                        MLP_GATE_UP[i],
                        &cap.mlp_in,
                        &mlp_in_hat,
                        policy.as_ref(),
                        Self::layer_bits(allocation.as_ref(), bi, MLP_GATE_UP[i]),
                    )
                });
                for (short, out) in MLP_GATE_UP.iter().zip(outs) {
                    let (w_hat, adj, layer_report) = out?;
                    Self::install(&mut st, bi, short, w_hat, adj);
                    st.report.layers.push(layer_report);
                }
                let prop = Stopwatch::start();
                let b = &st.qmodel.blocks[bi];
                let act_hat = swiglu(&linear(&mlp_in_hat, &b.gate), &linear(&mlp_in_hat, &b.up));
                st.report.propagation_s += prop.seconds();
                let (w_hat, adj, layer_report) = self.compute_layer(
                    &st.qmodel,
                    bi,
                    "mlp.down",
                    &cap.mlp_act,
                    &act_hat,
                    policy.as_ref(),
                    Self::layer_bits(allocation.as_ref(), bi, "mlp.down"),
                )?;
                Self::install(&mut st, bi, "mlp.down", w_hat, adj);
                st.report.layers.push(layer_report);

                let prop = Stopwatch::start();
                let b = &st.qmodel.blocks[bi];
                x_hat = x1_hat.add(&linear(&act_hat, &b.down));
                st.report.propagation_s += prop.seconds();

                // Freeze this block's pass-1 quantized-stream captures for
                // the window's joint pass (moves — the locals are dead).
                if let Some(w) = win.as_mut() {
                    w.frozen.push(BlockCapture {
                        attn_in: attn_in_hat,
                        attn_ctx: ctx_hat,
                        mlp_in: mlp_in_hat,
                        mlp_act: act_hat,
                    });
                }

                if self.cfg.verbose {
                    eprintln!(
                        "[pipeline] block {}/{n_blocks} done ({})",
                        bi + 1,
                        self.cfg.label()
                    );
                }

                if (bi + 1) % window == 0 || bi + 1 == n_blocks {
                    if let Some(w) = win.take() {
                        // A one-block tail has nothing to reconstruct
                        // jointly; it keeps its layer-wise pass.
                        if w.frozen.len() >= 2 {
                            x_hat = self.refine_window(
                                model,
                                &f,
                                &mut st,
                                w,
                                allocation.as_ref(),
                                policy.as_ref(),
                            )?;
                        }
                    }
                }
            }
            Ok(())
        })?;

        let RunState { qmodel, adjuncts, base_weights, mut report } = st;
        report.total_s = total.seconds();
        let base_model = if base_weights.is_empty() {
            None
        } else {
            let mut base = qmodel.clone();
            for (bi, short, w) in base_weights {
                *base.blocks[bi].linear_mut(&short) = w;
            }
            Some(base)
        };
        Ok(PipelineOutput { model: qmodel, base_model, adjuncts, allocation, report })
    }

    /// The effective CBQ window for a run over `n_blocks` blocks: `0`/`1`
    /// mean layer-wise, and anything beyond the quantized block count
    /// clamps — loudly, it is almost certainly a flag mistake — to one
    /// whole-model window (which reproduces the layer-wise bytes; see
    /// [`Pipeline::refine_window`]).
    fn effective_window(&self, n_blocks: usize) -> usize {
        let w = self.cfg.cbq_window.max(1);
        if w > n_blocks && n_blocks > 0 {
            eprintln!(
                "[pipeline] cbq window {w} exceeds the {n_blocks} quantized block(s) — \
                 clamping to {n_blocks}"
            );
            return n_blocks;
        }
        w
    }

    /// CBQ cross-block refinement of one window `[start, start+W)`.
    ///
    /// The layer-wise pass compensates each layer against the *global*
    /// full-precision stream. The cross-block pass instead reconstructs
    /// the whole window against its **local full-precision reference**:
    /// the original (unquantized) window weights applied to the window's
    /// actual quantized-stream entry `x̂_start`. Concretely:
    ///
    /// 1. propagate `x̂_start` through the original window weights,
    ///    capturing per-linear reference activations `X_ref`;
    /// 2. re-reconstruct every window layer from its original weights
    ///    with `(X, X̂) = (X_ref, X̂_frozen)`, where `X̂_frozen` are the
    ///    pass-1 quantized-stream captures — the same name-derived seeds
    ///    and bit widths as pass 1, and every layer independent given
    ///    those frozen streams, so all `W × 7` layers fan out in one
    ///    pool dispatch (index-ordered: bit-identical for every thread
    ///    count);
    /// 3. re-propagate `x̂` through the refined window so the next window
    ///    calibrates against the refined weights.
    ///
    /// QEP cells therefore compensate exactly the error the window itself
    /// introduces (`δ = X_ref − X̂_frozen`; zero at the window's first
    /// linear, genuinely informative at every later one), and AWQ
    /// recalibrates its scales on the local reference. Base methods whose
    /// objective never consults the full-precision stream (RTN, GPTQ,
    /// QuIP — see `Method::base_uses_quantized_acts`) are *provably
    /// invariant* under this refinement: their pass-2 inputs are
    /// bit-identical to pass 1, which `tests/pipeline_integration.rs`
    /// pins as a correctness anchor. Windows starting at block 0 are
    /// skipped by the caller for the same reason — there `x̂_start`
    /// equals the full-precision entry, making `X_ref` equal to the
    /// pass-1 captures and the whole pass a bitwise no-op.
    fn refine_window(
        &self,
        model: &Model,
        f: &Forward,
        st: &mut RunState,
        win: CbqWindow,
        allocation: Option<&Allocation>,
        policy: Option<&AlphaPolicy>,
    ) -> Result<Mat> {
        let CbqWindow { start, entry, frozen } = win;
        let n = frozen.len();

        // 1. Local full-precision reference over the original weights.
        let prop = Stopwatch::start();
        let mut ref_caps = Vec::with_capacity(n);
        let mut xr = entry.clone();
        for b in &model.blocks[start..start + n] {
            let (nx, cap) = f.block(b, &xr);
            ref_caps.push(cap);
            xr = nx;
        }
        st.report.propagation_s += prop.seconds();

        // 2. Joint re-reconstruction, every window layer from the
        //    original weights against (reference, frozen) streams.
        let jobs: Vec<(usize, &str)> = (0..n)
            .flat_map(|k| BlockWeights::LINEAR_NAMES.iter().map(move |&short| (k, short)))
            .collect();
        let outs = self.pool.par_map(jobs.len(), |i| {
            let (k, short) = jobs[i];
            self.compute_layer(
                model,
                start + k,
                short,
                ref_caps[k].input_for(short),
                frozen[k].input_for(short),
                policy,
                Self::layer_bits(allocation, start + k, short),
            )
        });
        for (&(k, short), out) in jobs.iter().zip(outs) {
            let (w_hat, adj, layer_report) = out?;
            Self::install(st, start + k, short, w_hat, adj);
            let slot = st
                .report
                .layers
                .iter_mut()
                .find(|l| l.name == layer_report.name)
                .expect("pass 1 reported every window layer");
            *slot = layer_report;
        }

        // 3. Re-propagate the quantized stream through the refined window.
        let prop = Stopwatch::start();
        let mut xh = entry;
        for b in &st.qmodel.blocks[start..start + n] {
            xh = f.block(b, &xh).0;
        }
        st.report.propagation_s += prop.seconds();
        if self.cfg.verbose {
            eprintln!(
                "[pipeline] cbq window blocks {}..{} jointly re-reconstructed",
                start + 1,
                start + n
            );
        }
        Ok(xh)
    }

    /// The allocated width for one linear (`None` ⇒ uniform
    /// `cfg.quant.bits`). Every scored layer is present in the map, so a
    /// miss can only mean "no budget was requested".
    fn layer_bits(allocation: Option<&Allocation>, block: usize, short: &str) -> Option<u32> {
        allocation.and_then(|a| a.bits_for(&format!("blocks.{block}.{short}")))
    }

    /// The mixed-precision scoring pre-pass: one full-precision forward
    /// pass over the calibration stream, capturing each linear's input
    /// activations, reducing them to Hessian diagonals `diag(XᵀX)` (column
    /// sums of squares, serial accumulation), and scoring the RTN snap
    /// error at the candidate widths {⌊B⌋, ⌊B⌋+1}. The fractional surplus
    /// only ever buys one-bit upgrades, so the allocation elementwise
    /// dominates the uniform-⌊B⌋ baseline (see `quant::budget`).
    fn allocate_bits(
        &self,
        model: &Model,
        calib_tokens: &[u32],
        f: &Forward,
        n_blocks: usize,
        spec: BudgetSpec,
    ) -> Result<Allocation> {
        budget::check_feasible(spec.budget)?;
        let floor = spec.budget.floor_bits();
        let hi = (floor + 1).min(budget::MAX_BITS);
        let mut costs = Vec::new();
        let mut x = f.embed(model, calib_tokens);
        for bi in 0..n_blocks {
            let (x_next, cap) = f.block(&model.blocks[bi], &x);
            for short in BlockWeights::LINEAR_NAMES {
                let acts = cap.input_for(short);
                let mut diag = vec![0.0f64; acts.cols];
                for t in 0..acts.rows {
                    let row = acts.row(t);
                    for (d, v) in diag.iter_mut().zip(row.iter()) {
                        *d += *v as f64 * *v as f64;
                    }
                }
                let w = model.blocks[bi].linear(short);
                costs.push(budget::layer_cost(
                    &format!("blocks.{bi}.{short}"),
                    w,
                    &diag,
                    &self.cfg.quant,
                    floor,
                    hi,
                ));
            }
            x = x_next;
        }
        budget::allocate(&costs, spec.budget, spec.alloc)
    }

    /// Install one quantized linear into the streaming model. The adjunct
    /// (if any) is folded into the propagated weight so downstream layers
    /// calibrate against the corrected stream; the on-grid base weight and
    /// the factors themselves are kept aside for the artifact. An upsert:
    /// a CBQ refinement pass re-installs layers the layer-wise pass
    /// already produced, replacing their base weights in place.
    fn install(
        st: &mut RunState,
        block: usize,
        short: &str,
        w_hat: Mat,
        adj: Option<LowRankAdjunct>,
    ) {
        match adj {
            Some(adj) => {
                let name = format!("blocks.{block}.{short}");
                let w_eff = adj.add_to(&w_hat);
                match st.base_weights.iter_mut().find(|(b, s, _)| *b == block && s == short) {
                    Some(slot) => slot.2 = w_hat,
                    None => st.base_weights.push((block, short.to_string(), w_hat)),
                }
                st.adjuncts.insert(name, adj);
                *st.qmodel.blocks[block].linear_mut(short) = w_eff;
            }
            None => *st.qmodel.blocks[block].linear_mut(short) = w_hat,
        }
    }

    /// Quantize one linear, returning the dequantized weights plus the
    /// layer report instead of mutating the model — this is the unit of
    /// work the pool fans out, so it must not touch shared state. It reads
    /// only the layer's own weights and the captured activation streams;
    /// the per-layer seed derives from the layer *name*, keeping results
    /// independent of scheduling order. (The CBQ refinement pass calls
    /// this with the *original* model and its window-local streams — same
    /// unit of work, different calibration target.)
    fn compute_layer(
        &self,
        qmodel: &Model,
        block: usize,
        short: &str,
        x_full_cap: &Mat,
        x_hat_cap: &Mat,
        policy: Option<&AlphaPolicy>,
        bits_override: Option<u32>,
    ) -> Result<(Mat, Option<LowRankAdjunct>, LayerReport)> {
        let name = format!("blocks.{block}.{short}");
        let w = qmodel.blocks[block].linear(short).clone();
        // Mixed precision swaps in the allocated width; the group setting
        // is shared by every layer.
        let qcfg = match bits_override {
            Some(bits) => QuantConfig { bits, group: self.cfg.quant.group },
            None => self.cfg.quant,
        };

        // 1. Calibration statistics on the method's activation stream.
        //    QEP always calibrates on X̂ (Eq. 5); base methods follow their
        //    original papers.
        let acts = if policy.is_some() || self.cfg.method.base_uses_quantized_acts() {
            x_hat_cap
        } else {
            x_full_cap
        };
        let hes = Stopwatch::start();
        let layer_seed = self.cfg.seed ^ crate::util::fnv1a(&name);
        let ctx = LayerCtx::from_activations(acts, layer_seed, &name);
        let hessian_s = hes.seconds();

        // 2. QEP correction, reusing ctx's Ĥ (acts == X̂ whenever QEP is on,
        //    so the Hessian is the same matrix the correction needs).
        let (w_target, correction, alpha) = match policy {
            Some(p) => {
                let a = p.alpha_for(&name);
                let (w_star, stats) = crate::qep::corrected_weight_with_h(
                    &w,
                    x_full_cap,
                    x_hat_cap,
                    Some(&ctx.hessian),
                    a,
                    self.cfg.damp_rel,
                )?;
                (w_star, stats, a)
            }
            None => (w.clone(), CorrectionStats::default(), 0.0),
        };

        // 3. Base method.
        let qt = Stopwatch::start();
        let w_hat = self.quantizer.quantize(&w_target, &qcfg, &ctx)?;
        let quant_s = qt.seconds();

        // 4. Low-rank reconstruction of whatever residual the grid left
        //    (LQER/QERA — orthogonal to the α correction above). The seed
        //    is the same name-derived value as the quantizer's, so shards
        //    and thread counts sketch with identical Ω.
        let adjunct = if self.cfg.lowrank_rank > 0 {
            let residual = w_target.sub(&w_hat);
            Some(adjunct_from_residual(
                &residual,
                Some(&ctx.hessian),
                self.cfg.lowrank_rank,
                self.cfg.damp_rel,
                layer_seed,
                &self.pool,
            ))
        } else {
            None
        };

        let recon_error = ctx.recon_error(&w_target, &w_hat);
        Ok((
            w_hat,
            adjunct,
            LayerReport { name, bits: qcfg.bits, recon_error, correction, hessian_s, quant_s, alpha },
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::util::rng::Rng;

    fn setup() -> (Model, Vec<u32>) {
        let mut cfg = ModelConfig::new("unit", 16, 2, 2, 32);
        cfg.seq_len = 8;
        let model = Model::random(&cfg, 1);
        let mut rng = Rng::new(2);
        let tokens: Vec<u32> = (0..8 * 16).map(|_| rng.below(256) as u32).collect();
        (model, tokens)
    }

    fn run(model: &Model, tokens: &[u32], cfg: PipelineConfig) -> PipelineOutput {
        Pipeline::new(cfg).run(model, tokens).unwrap()
    }

    #[test]
    fn quantizes_all_layers_and_reports() {
        let (model, tokens) = setup();
        let out = run(
            &model,
            &tokens,
            PipelineConfig { quant: QuantConfig::int(4), method: Method::Rtn, ..Default::default() },
        );
        assert_eq!(out.report.layers.len(), 2 * 7);
        out.model.validate().unwrap();
        // Weights must actually change (they're quantized).
        assert!(out.model.blocks[0].wq.sub(&model.blocks[0].wq).frob() > 0.0);
    }

    #[test]
    fn max_blocks_limits_quantization() {
        let (model, tokens) = setup();
        let out = run(
            &model,
            &tokens,
            PipelineConfig { max_blocks: Some(1), ..Default::default() },
        );
        assert_eq!(out.report.layers.len(), 7);
        // Block 1 untouched.
        assert_eq!(out.model.blocks[1].wq, model.blocks[1].wq);
        assert_ne!(out.model.blocks[0].wq, model.blocks[0].wq);
    }

    #[test]
    fn qep_runs_and_records_alpha() {
        let (model, tokens) = setup();
        let out = run(
            &model,
            &tokens,
            PipelineConfig {
                quant: QuantConfig::int(3),
                qep_alpha: Some(0.5),
                ..Default::default()
            },
        );
        assert!(out.report.layers.iter().all(|l| l.alpha == 0.5));
        // First layer of the whole net sees identical streams ⇒ tiny
        // correction; later layers see real upstream error.
        let first = &out.report.layers[0];
        assert!(first.correction.rel_upstream_err < 1e-9);
    }

    #[test]
    fn alpha_policy_overrides_apply() {
        let (model, tokens) = setup();
        let out = run(
            &model,
            &tokens,
            PipelineConfig {
                qep_alpha: Some(0.5),
                alpha_policy: Some(AlphaPolicy::uniform(0.5).with_override("mlp.", 0.0)),
                ..Default::default()
            },
        );
        for l in &out.report.layers {
            if l.name.contains("mlp.") {
                assert_eq!(l.alpha, 0.0, "{}", l.name);
            } else {
                assert_eq!(l.alpha, 0.5, "{}", l.name);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (model, tokens) = setup();
        let cfg = PipelineConfig {
            method: Method::Quip,
            quant: QuantConfig::int(3),
            seed: 42,
            ..Default::default()
        };
        let a = run(&model, &tokens, cfg.clone());
        let b = run(&model, &tokens, cfg);
        assert_eq!(a.model.blocks[0].wq, b.model.blocks[0].wq);
        assert_eq!(a.model.blocks[1].down, b.model.blocks[1].down);
    }

    #[test]
    fn all_methods_run_end_to_end() {
        let (model, tokens) = setup();
        for method in Method::all() {
            for qep in [None, Some(0.5)] {
                let out = run(
                    &model,
                    &tokens,
                    PipelineConfig {
                        quant: QuantConfig::int(3),
                        method,
                        qep_alpha: qep,
                        ..Default::default()
                    },
                );
                out.model.validate().unwrap();
                assert!(
                    out.model.blocks[0].wq.data.iter().all(|v| v.is_finite()),
                    "{method:?} qep={qep:?} produced non-finite weights"
                );
            }
        }
    }

    #[test]
    fn lowrank_rank_produces_adjuncts_and_effective_weights() {
        let (model, tokens) = setup();
        let out = run(
            &model,
            &tokens,
            PipelineConfig { quant: QuantConfig::int(3), lowrank_rank: 2, ..Default::default() },
        );
        assert_eq!(out.adjuncts.len(), 2 * 7);
        let base = out.base_model.as_ref().unwrap();
        let adj = &out.adjuncts["blocks.0.attn.wq"];
        assert_eq!(adj.rank(), 2);
        // Effective weight = on-grid base + U·V, exactly.
        assert_eq!(out.model.blocks[0].wq, adj.add_to(&base.blocks[0].wq));
        // Rank 0 leaves no adjunct section at all.
        let plain = run(
            &model,
            &tokens,
            PipelineConfig { quant: QuantConfig::int(3), ..Default::default() },
        );
        assert!(plain.adjuncts.is_empty());
        assert!(plain.base_model.is_none());
    }

    #[test]
    fn bit_budget_allocates_within_one_bit_of_the_floor() {
        let (model, tokens) = setup();
        let spec = BudgetSpec {
            budget: budget::BitBudget::parse("2.5").unwrap(),
            alloc: budget::Alloc::Dp,
        };
        let out = run(
            &model,
            &tokens,
            PipelineConfig { bit_budget: Some(spec), ..Default::default() },
        );
        let alloc = out.allocation.as_ref().unwrap();
        assert_eq!(alloc.bits.len(), 2 * 7);
        assert!(alloc.bits.values().all(|&b| b == 2 || b == 3), "{alloc:?}");
        assert!(alloc.bits.values().any(|&b| b == 3), "surplus unspent: {alloc:?}");
        assert!(alloc.avg_bits <= 2.5, "{}", alloc.avg_bits);
        // The report records the allocated width per layer.
        for l in &out.report.layers {
            assert_eq!(alloc.bits[&l.name], l.bits, "{}", l.name);
        }
        assert!(out.report.allocation_s > 0.0);
    }

    #[test]
    fn integral_budget_reduces_to_the_uniform_run() {
        let (model, tokens) = setup();
        let spec = BudgetSpec {
            budget: budget::BitBudget::parse("3.0").unwrap(),
            alloc: budget::Alloc::Dp,
        };
        // quant.bits is deliberately wrong (7): the budget must override it.
        let budgeted = run(
            &model,
            &tokens,
            PipelineConfig {
                quant: QuantConfig::int(7),
                method: Method::Gptq,
                bit_budget: Some(spec),
                ..Default::default()
            },
        );
        let uniform = run(
            &model,
            &tokens,
            PipelineConfig { quant: QuantConfig::int(3), method: Method::Gptq, ..Default::default() },
        );
        for bi in 0..2 {
            assert_eq!(budgeted.model.blocks[bi].wq, uniform.model.blocks[bi].wq);
            assert_eq!(budgeted.model.blocks[bi].down, uniform.model.blocks[bi].down);
        }
        assert_eq!(budgeted.allocation.as_ref().unwrap().avg_bits, 3.0);
    }

    #[test]
    fn infeasible_budget_fails_loudly_before_quantizing() {
        let (model, tokens) = setup();
        let spec = BudgetSpec {
            budget: budget::BitBudget::parse("1.5").unwrap(),
            alloc: budget::Alloc::Greedy,
        };
        let err = Pipeline::new(PipelineConfig { bit_budget: Some(spec), ..Default::default() })
            .run(&model, &tokens)
            .unwrap_err();
        assert!(format!("{err}").contains("feasible range"), "{err}");
    }

    #[test]
    fn timing_phases_are_populated() {
        let (model, tokens) = setup();
        let out = run(
            &model,
            &tokens,
            PipelineConfig { method: Method::Gptq, qep_alpha: Some(0.5), ..Default::default() },
        );
        assert!(out.report.total_s > 0.0);
        assert!(out.report.hessian_s() > 0.0);
        assert!(out.report.quant_s() > 0.0);
        assert!(out.report.propagation_s > 0.0);
    }

    #[test]
    fn cbq_window_labels_and_default() {
        let cfg = PipelineConfig::default();
        assert_eq!(cfg.cbq_window, 1);
        assert!(!cfg.label().contains(" W"), "{}", cfg.label());
        let cfg = PipelineConfig { cbq_window: 3, ..Default::default() };
        assert!(cfg.label().ends_with(" W3"), "{}", cfg.label());
    }

    #[test]
    fn cbq_refines_qep_windows_past_the_first() {
        // 4 blocks, window 2: window [0,2) is a provable no-op (the
        // quantized and full-precision streams share the model input),
        // window [2,4) genuinely re-reconstructs against its local
        // full-precision reference.
        let mut cfg = ModelConfig::new("unit", 16, 4, 2, 32);
        cfg.seq_len = 8;
        let model = Model::random(&cfg, 1);
        let mut rng = Rng::new(2);
        let tokens: Vec<u32> = (0..8 * 16).map(|_| rng.below(256) as u32).collect();
        let go = |w: usize| {
            run(
                &model,
                &tokens,
                PipelineConfig {
                    quant: QuantConfig::int(3),
                    qep_alpha: Some(0.5),
                    cbq_window: w,
                    ..Default::default()
                },
            )
        };
        let lw = go(1);
        let cbq = go(2);
        // First window: byte-identical to the layer-wise schedule.
        assert_eq!(lw.model.blocks[0].wq, cbq.model.blocks[0].wq);
        assert_eq!(lw.model.blocks[1].down, cbq.model.blocks[1].down);
        // Second window: the joint pass moved the QEP cells.
        assert!(
            lw.model.blocks[2].wo.sub(&cbq.model.blocks[2].wo).frob() > 0.0
                || lw.model.blocks[2].down.sub(&cbq.model.blocks[2].down).frob() > 0.0,
            "cbq window [2,4) left every +QEP layer untouched"
        );
        // The report still holds exactly one entry per layer, in pass-1
        // order, with refined stats swapped in place.
        assert_eq!(cbq.report.layers.len(), 4 * 7);
        assert_eq!(cbq.report.layers[0].name, "blocks.0.attn.wq");
        assert_eq!(cbq.report.layers[2 * 7].name, "blocks.2.attn.wq");
    }
}
