//! Micro-benchmark harness (the environment has no `criterion`; `cargo
//! bench` runs `harness = false` binaries built on this module).
//!
//! Methodology: warm up until `warmup_time` elapses, then run timed
//! batches until `measure_time` elapses or `max_iters` is hit; report
//! mean / median / p10 / p90 per-iteration wall time.

use super::stats;
use std::time::Instant;

#[derive(Clone, Copy)]
pub struct BenchConfig {
    pub warmup_time: f64,
    pub measure_time: f64,
    pub max_iters: usize,
    pub min_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_time: 0.3,
            measure_time: 1.5,
            max_iters: 10_000,
            min_iters: 5,
        }
    }
}

impl BenchConfig {
    /// The default config, unless `BENCH_SMOKE` is set in the environment
    /// — then a minimal one-or-two-iteration config, so `cargo test
    /// --benches` (CI's bit-rot check for the `harness = false` bench
    /// binaries) proves every bench still *runs* without paying full
    /// measurement time. Numbers produced under smoke are meaningless.
    pub fn from_env() -> BenchConfig {
        if smoke() {
            BenchConfig {
                warmup_time: 0.0,
                measure_time: 0.0,
                max_iters: 2,
                min_iters: 1,
            }
        } else {
            BenchConfig::default()
        }
    }
}

/// True when `BENCH_SMOKE` is set: benches should shrink sweeps to a
/// just-prove-it-runs size (CI runs them this way via `cargo test
/// --benches`; see `.github/workflows/ci.yml`).
pub fn smoke() -> bool {
    std::env::var_os("BENCH_SMOKE").is_some()
}

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
}

impl BenchResult {
    pub fn throughput_line(&self, unit: &str, per_iter: f64) -> String {
        let rate = per_iter / self.mean_s;
        format!("{:<38} {:>12}/s  ({} iters)", self.name, fmt_si(rate, unit), self.iters)
    }
}

fn fmt_si(x: f64, unit: &str) -> String {
    if x >= 1e9 {
        format!("{:.2} G{unit}", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2} M{unit}", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2} k{unit}", x / 1e3)
    } else {
        format!("{x:.2} {unit}")
    }
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

/// Benchmark a closure. The closure should return something observable to
/// prevent the optimizer from deleting the work; we `black_box` it.
pub fn bench<F, R>(name: &str, cfg: BenchConfig, mut f: F) -> BenchResult
where
    F: FnMut() -> R,
{
    // Warmup.
    let start = Instant::now();
    while start.elapsed().as_secs_f64() < cfg.warmup_time {
        black_box(f());
    }
    // Measure.
    let mut samples = Vec::new();
    let measure_start = Instant::now();
    while (measure_start.elapsed().as_secs_f64() < cfg.measure_time
        && samples.len() < cfg.max_iters)
        || samples.len() < cfg.min_iters
    {
        let t = Instant::now();
        black_box(f());
        samples.push(t.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: stats::mean(&samples),
        median_s: stats::percentile(&samples, 50.0),
        p10_s: stats::percentile(&samples, 10.0),
        p90_s: stats::percentile(&samples, 90.0),
    }
}

/// Print a result in a stable single-line format the bench logs rely on.
pub fn report(r: &BenchResult) {
    println!(
        "{:<44} mean {:>10}  median {:>10}  p10 {:>10}  p90 {:>10}  ({} iters)",
        r.name,
        fmt_time(r.mean_s),
        fmt_time(r.median_s),
        fmt_time(r.p10_s),
        fmt_time(r.p90_s),
        r.iters
    );
}

/// Identity function opaque to the optimizer (std::hint::black_box exists on
/// this toolchain; thin wrapper kept for call-site clarity).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let cfg = BenchConfig {
            warmup_time: 0.01,
            measure_time: 0.05,
            max_iters: 100,
            min_iters: 3,
        };
        let r = bench("noop-sum", cfg, || (0..1000u64).sum::<u64>());
        assert!(r.iters >= 3);
        assert!(r.mean_s > 0.0);
        assert!(r.p10_s <= r.p90_s);
    }
}
