//! Deterministic pseudo-random number generation.
//!
//! We need reproducible streams shared conceptually with the Python side
//! (the corpora are generated once at build time by Python; Rust-side RNG is
//! used for synthetic workloads in tests/benches and for the randomized
//! Hadamard sign vectors in QuIP). Xoshiro256++ seeded via SplitMix64 — the
//! standard, well-tested construction.

/// SplitMix64: seeds Xoshiro and is useful as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Xoshiro256++ PRNG. Not cryptographic; fast and high quality for
/// simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        Rng { s }
    }

    /// Derive an independent stream for a named sub-component.
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let res = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        res
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift rejection-free mapping; bias is < 2^-64 * n,
        // negligible for our n.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller (cached second sample omitted to keep
    /// the generator state simple and fork-safe).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-300 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Random ±1 sign.
    #[inline]
    pub fn sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Vector of iid N(0, sigma^2) f32s.
    pub fn normal_vec(&mut self, n: usize, sigma: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32() * sigma).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_constructions() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let mut a = Rng::new(7);
        let mut x = a.fork(1);
        let mut y = a.fork(2);
        let same = (0..32).filter(|_| x.next_u64() == y.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(9);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
