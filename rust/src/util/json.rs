//! Minimal JSON writer + parser, sufficient for the QTZ tensor-container
//! header and experiment result files. Supports objects, arrays, strings,
//! numbers, booleans, and null — no exotic escapes beyond \" \\ \n \t \r
//! and \uXXXX on input.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn from_str_slice(items: &[&str]) -> Json {
        Json::Arr(items.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    pub fn from_usize_slice(items: &[usize]) -> Json {
        Json::Arr(items.iter().map(|&n| Json::Num(n as f64)).collect())
    }

    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let val = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing data at byte {pos}"));
        }
        Ok(val)
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end of input".to_string());
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => expect_lit(b, pos, "true", Json::Bool(true)),
        b'f' => expect_lit(b, pos, "false", Json::Bool(false)),
        b'n' => expect_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn expect_lit(b: &[u8], pos: &mut usize, lit: &str, val: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(val)
    } else {
        Err(format!("expected '{lit}' at byte {pos}", pos = *pos))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| format!("bad number at byte {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    return Err("truncated escape".to_string());
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            return Err("truncated \\u escape".to_string());
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| "bad \\u escape")?;
                        let code =
                            u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        *pos += 4;
                    }
                    c => return Err(format!("bad escape '\\{}'", c as char)),
                }
                *pos += 1;
            }
            _ => {
                // Copy one UTF-8 scalar.
                let s = std::str::from_utf8(&b[*pos..]).map_err(|_| "invalid utf8")?;
                let c = s.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
    Err("unterminated string".to_string())
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // [
    let mut items = Vec::new();
    loop {
        skip_ws(b, pos);
        if *pos < b.len() && b[*pos] == b']' {
            *pos += 1;
            return Ok(Json::Arr(items));
        }
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        if *pos < b.len() && b[*pos] == b',' {
            *pos += 1;
        } else if *pos < b.len() && b[*pos] == b']' {
            *pos += 1;
            return Ok(Json::Arr(items));
        } else {
            return Err(format!("expected ',' or ']' at byte {pos}", pos = *pos));
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // {
    let mut map = BTreeMap::new();
    loop {
        skip_ws(b, pos);
        if *pos < b.len() && b[*pos] == b'}' {
            *pos += 1;
            return Ok(Json::Obj(map));
        }
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b'"' {
            return Err(format!("expected key at byte {pos}", pos = *pos));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b':' {
            return Err(format!("expected ':' at byte {pos}", pos = *pos));
        }
        *pos += 1;
        let val = parse_value(b, pos)?;
        map.insert(key, val);
        skip_ws(b, pos);
        if *pos < b.len() && b[*pos] == b',' {
            *pos += 1;
        } else if *pos < b.len() && b[*pos] == b'}' {
            *pos += 1;
            return Ok(Json::Obj(map));
        } else {
            return Err(format!("expected ',' or '}}' at byte {pos}", pos = *pos));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", Json::Str("tiny-s".into()))
            .set("dims", Json::from_usize_slice(&[64, 4]))
            .set("lr", Json::Num(0.001))
            .set("trained", Json::Bool(true))
            .set("note", Json::Null);
        let text = j.dump();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parses_nested_and_whitespace() {
        let j = Json::parse(" { \"a\" : [ 1 , 2.5 , {\"b\": \"x\\ny\"} ] } ").unwrap();
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].as_f64(), Some(2.5));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn integer_formatting_is_clean() {
        assert_eq!(Json::Num(64.0).dump(), "64");
        assert_eq!(Json::Num(0.5).dump(), "0.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse("\"\\u00e9\"").unwrap();
        assert_eq!(j.as_str(), Some("é"));
    }
}
