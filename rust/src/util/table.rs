//! Plain-text table renderer for the experiment drivers. Each paper table
//! is regenerated as an aligned monospace table with the same row/column
//! structure as the original.

/// A simple column-aligned table builder.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
    }

    /// A separator row rendered as dashes.
    pub fn rule(&mut self) {
        self.rows.push(vec!["—".to_string(); self.header.len()]);
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("# {}\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                let pad = widths[i] - c.chars().count();
                line.push_str(c);
                line.push_str(&" ".repeat(pad));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            if row.iter().all(|c| c == "—") {
                out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
            } else {
                out.push_str(&fmt_row(row, &widths));
            }
            out.push('\n');
        }
        out
    }

    /// Render as CSV for downstream plotting.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for row in &self.rows {
            if row.iter().all(|c| c == "—") {
                continue;
            }
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format perplexity the way the paper does: 3 decimals for small values,
/// no decimals for collapsed (>1000) cells.
pub fn fmt_ppl(p: f64) -> String {
    if !p.is_finite() {
        "N/A".to_string()
    } else if p >= 1000.0 {
        format!("{p:.0}")
    } else {
        format!("{p:.3}")
    }
}

/// Format accuracy with 4 decimals (paper style).
pub fn fmt_acc(a: f64) -> String {
    format!("{a:.4}")
}

/// Format a runtime cell (Table 3). Wall-clock is the one metric that
/// is not bit-deterministic across runs/machines, so determinism gates
/// (CI's shard-matrix merge diff, the shard/merge integration tests)
/// render with `stable = true`, which replaces the measurement with a
/// fixed placeholder.
pub fn fmt_runtime(seconds: f64, stable: bool) -> String {
    if stable {
        "n/a".to_string()
    } else {
        crate::util::fmt_duration(seconds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbbb", "c"]);
        t.row(vec!["1".into(), "2".into(), "3".into()]);
        t.row(vec!["100".into(), "x".into(), "yy".into()]);
        let r = t.render();
        assert!(r.contains("# demo"));
        let lines: Vec<&str> = r.lines().collect();
        // header + rule + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn ppl_formatting() {
        assert_eq!(fmt_ppl(6.1234), "6.123");
        assert_eq!(fmt_ppl(17783.9), "17784");
        assert_eq!(fmt_ppl(f64::NAN), "N/A");
    }

    #[test]
    fn runtime_formatting_has_a_stable_mode() {
        assert_eq!(fmt_runtime(90.0, false), "90.0s");
        assert_eq!(fmt_runtime(90.0, true), "n/a");
        assert_eq!(fmt_runtime(0.5, true), "n/a");
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_skips_rules() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.rule();
        t.row(vec!["3".into(), "4".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n3,4\n");
    }
}
