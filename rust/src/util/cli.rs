//! Tiny CLI argument parser (the environment has no `clap`). Supports
//! `--flag`, `--key value`, `--key=value`, and positional arguments.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    let (k, v) = stripped.split_at(eq);
                    out.flags.insert(k.to_string(), v[1..].to_string());
                } else if it
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn mixed_forms() {
        let a = parse(&["exp", "table1", "--bits", "3", "--alpha=0.5", "--verbose"]);
        assert_eq!(a.positional, vec!["exp", "table1"]);
        assert_eq!(a.get("bits"), Some("3"));
        assert_eq!(a.get_f64("alpha", 0.0), 0.5);
        assert!(a.has("verbose"));
        assert_eq!(a.get_usize("missing", 7), 7);
    }

    #[test]
    fn flag_before_positional() {
        let a = parse(&["--seed", "3", "run"]);
        assert_eq!(a.get_usize("seed", 0), 3);
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn bare_flag_at_end() {
        let a = parse(&["--qep"]);
        assert_eq!(a.get("qep"), Some("true"));
    }
}
