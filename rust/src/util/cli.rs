//! Tiny CLI argument parser (the environment has no `clap`). Supports
//! `--flag`, `--key value`, `--key=value`, and positional arguments.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    let (k, v) = stripped.split_at(eq);
                    out.flags.insert(k.to_string(), v[1..].to_string());
                } else if it
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Fetch a required `--key value` flag, turning absence into a usage
    /// error that says *why* the flag is needed (the subcommands that
    /// persist records all require `--out DIR`, each for its own reason).
    pub fn require(&self, key: &str, why: &str) -> Result<&str, String> {
        self.get(key).ok_or_else(|| format!("--{key} required: {why}"))
    }

    /// Reject flags outside `known` with a usage error. Every subcommand
    /// calls this with its accepted flag set, so a typo (`--shards` for
    /// `--shard`) fails loudly instead of being silently ignored — which
    /// for a sharded sweep would mean quietly running *every* cell.
    pub fn reject_unknown(&self, known: &[&str]) -> Result<(), String> {
        for flag in self.flags.keys() {
            if !known.contains(&flag.as_str()) {
                let mut msg = format!("unknown flag '--{flag}'");
                if let Some(near) = close_match(flag, known) {
                    msg.push_str(&format!(" (did you mean '--{near}'?)"));
                }
                let mut sorted: Vec<&str> = known.to_vec();
                sorted.sort_unstable();
                msg.push_str(&format!("; accepted flags: {}", sorted.join(", ")));
                return Err(msg);
            }
        }
        Ok(())
    }
}

/// The closest known flag within edit distance 2 (plain
/// insert/delete/substitute), for typo hints.
fn close_match<'a>(flag: &str, known: &[&'a str]) -> Option<&'a str> {
    known
        .iter()
        .map(|k| (edit_distance(flag, k), *k))
        .filter(|&(d, _)| d <= 2)
        .min_by_key(|&(d, _)| d)
        .map(|(_, k)| k)
}

fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for i in 1..=a.len() {
        cur[0] = i;
        for j in 1..=b.len() {
            let sub = usize::from(a[i - 1] != b[j - 1]);
            cur[j] = (prev[j] + 1).min(cur[j - 1] + 1).min(prev[j - 1] + sub);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn mixed_forms() {
        let a = parse(&["exp", "table1", "--bits", "3", "--alpha=0.5", "--verbose"]);
        assert_eq!(a.positional, vec!["exp", "table1"]);
        assert_eq!(a.get("bits"), Some("3"));
        assert_eq!(a.get_f64("alpha", 0.0), 0.5);
        assert!(a.has("verbose"));
        assert_eq!(a.get_usize("missing", 7), 7);
    }

    #[test]
    fn flag_before_positional() {
        let a = parse(&["--seed", "3", "run"]);
        assert_eq!(a.get_usize("seed", 0), 3);
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn bare_flag_at_end() {
        let a = parse(&["--qep"]);
        assert_eq!(a.get("qep"), Some("true"));
    }

    #[test]
    fn unknown_flags_are_rejected_with_a_hint() {
        let a = parse(&["exp", "all", "--shards", "2/3"]);
        let err = a.reject_unknown(&["shard", "out", "fast"]).unwrap_err();
        assert!(err.contains("unknown flag '--shards'"), "{err}");
        assert!(err.contains("did you mean '--shard'?"), "{err}");
        assert!(err.contains("accepted flags"), "{err}");
        // Exact flags pass.
        let ok = parse(&["exp", "all", "--shard", "2/3", "--fast"]);
        assert!(ok.reject_unknown(&["shard", "out", "fast"]).is_ok());
        // No hint when nothing is close.
        let far = parse(&["--zzzzzz"]);
        let err = far.reject_unknown(&["shard"]).unwrap_err();
        assert!(!err.contains("did you mean"), "{err}");
    }

    #[test]
    fn require_reports_the_reason() {
        let a = parse(&["exp", "table4", "--out", "shards"]);
        assert_eq!(a.require("out", "records go here"), Ok("shards"));
        let err = a.require("results", "tables go here").unwrap_err();
        assert!(err.contains("--results required"), "{err}");
        assert!(err.contains("tables go here"), "{err}");
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("shard", "shards"), 1);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
        assert_eq!(edit_distance("same", "same"), 0);
    }
}
