//! Small self-contained utilities (the environment is offline, so we carry
//! our own RNG, CLI parsing, bench timer, and table/JSON formatting instead
//! of pulling crates).

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod table;

/// FNV-1a hash of a name — the repo's stable name→seed derivation.
/// Per-layer and per-experiment-cell seeds must be identical across runs,
/// platforms, and pool scheduling orders (std's SipHash is randomized per
/// process, so we carry FNV). Used by the pipeline's layer seeds and the
/// sharded experiment sweeps' cell seeds.
pub fn fnv1a(name: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Wall-clock stopwatch used for the runtime experiments (Table 3).
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }
    pub fn seconds(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn millis(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// Human-friendly duration, matching the paper's "14.9m / 2.9h" style.
pub fn fmt_duration(seconds: f64) -> String {
    if seconds < 1.0 {
        format!("{:.0}ms", seconds * 1e3)
    } else if seconds < 120.0 {
        format!("{seconds:.1}s")
    } else if seconds < 7200.0 {
        format!("{:.1}m", seconds / 60.0)
    } else {
        format!("{:.1}h", seconds / 3600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duration_formatting_matches_paper_style() {
        assert_eq!(fmt_duration(0.5), "500ms");
        assert_eq!(fmt_duration(90.0), "90.0s");
        assert_eq!(fmt_duration(894.0), "14.9m");
        assert_eq!(fmt_duration(10440.0), "2.9h");
    }
}
