//! Elementary statistics used by the experiment drivers (Fig. 3 reports
//! mean ± SEM over seeds) and by the bench harness.

/// Mean of a slice; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Standard error of the mean (what Fig. 3's error bars show).
pub fn sem(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    stddev(xs) / (xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on sorted copies.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Geometric mean; useful for aggregating PPL ratios across models.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - 1.2909944487358056).abs() < 1e-12);
        assert!((sem(&xs) - stddev(&xs) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert!((percentile(&xs, 0.0) - 10.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 40.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_constant() {
        assert!((geomean(&[3.0, 3.0, 3.0]) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(sem(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
