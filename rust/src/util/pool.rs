//! Persistent-worker parallel execution substrate for the PTQ hot path.
//!
//! Design constraints (in priority order):
//!
//! 1. **Bit-identical results to the serial path.** Reproducibility is the
//!    whole point of this repo, so the pool never changes *what* is
//!    computed — only *who* computes it. Callers partition work into
//!    disjoint output regions (rows of a GEMM, independent layers) and each
//!    region is processed with exactly the serial kernel's floating-point
//!    operation order. No atomic float reductions, ever.
//! 2. **No dependencies.** The environment is offline; everything is built
//!    on `std::thread` + `Mutex`/`Condvar` + atomics.
//! 3. **No oversubscription.** Work executed *inside* a pool worker that
//!    itself calls into the pool runs inline (a thread-local flag marks
//!    pool context), so nested parallelism — e.g. a GEMM inside a
//!    parallel per-layer quantization — degrades gracefully to the serial
//!    kernel instead of spawning threads quadratically.
//!
//! # Persistent workers (vs the old scoped-spawn scheduler)
//!
//! Through PR 2 every [`Pool::run`] spawned fresh scoped threads and joined
//! them before returning. That is simple and safe, but the blocked
//! Cholesky/SPD engine issues *many small per-panel* dispatches per layer,
//! and at tens of microseconds per spawn+join the scheduling overhead grew
//! to a measurable fraction of the hot path. The pool now keeps one
//! process-wide set of worker threads that **park between dispatches**:
//!
//! * Workers are spawned lazily on the first parallel dispatch (never for
//!   `--threads 1` / [`Pool::serial`] work, which runs inline and touches
//!   no global state) and sized to `available_parallelism() - 1` helpers —
//!   the submitting thread is always worker 0.
//! * Job injection is mutex-lite: the submitter publishes one type-erased
//!   job descriptor under a small `Mutex` + `Condvar` pair, workers wake,
//!   claim a participation ticket, and then self-schedule grain-sized
//!   chunks off a **lock-free atomic cursor** exactly as before. One lock
//!   acquisition per worker per dispatch; the per-chunk path is atomic-only.
//! * A dispatch that asks for fewer threads than exist hands out fewer
//!   tickets (the rest keep sleeping); asking for more than exist is fine
//!   too — stealing means fewer workers simply take more chunks. Results
//!   are bit-identical in every case, so the worker count is purely a
//!   wall-clock knob.
//! * A panic inside a job is caught on the worker, forwarded to the
//!   submitter (which re-raises it after the job fully drains), and leaves
//!   the workers parked and reusable — a panicking job never deadlocks nor
//!   poisons subsequent dispatches.
//! * [`shutdown`] retires the pool gracefully (workers observe the flag,
//!   exit, and are joined). The next dispatch after a shutdown simply
//!   starts a fresh pool, so shutdown is safe to call at any quiescent
//!   point; the `repro` binary calls it on exit.
//!
//! The old scoped-spawn scheduler is kept as [`Pool::run_scoped`]: it is
//! the baseline `benches/linalg_hotpath.rs` measures dispatch overhead
//! against, and `tests/parallel_equivalence.rs` proves both engines
//! execute identical work.
//!
//! Scheduling within a job is chunked self-stealing: work items `[0, n)`
//! are split into grain-sized chunks published through a shared atomic
//! cursor, and every participant (including the calling thread) steals the
//! next chunk when it finishes its current one. Fast workers therefore
//! take more chunks — the load balancing of a work-stealing deque without
//! the deque.
//!
//! ```
//! use qep::util::pool::Pool;
//!
//! // Same surface as the scoped engine: `run` over disjoint chunks …
//! let pool = Pool::new(2);
//! let mut hits = vec![0u8; 10];
//! {
//!     let base = qep::util::pool::SendPtr::new(hits.as_mut_ptr());
//!     pool.run(10, 3, |s, e| {
//!         for i in s..e {
//!             // Sound: chunks are disjoint index ranges.
//!             unsafe { *base.0.add(i) += 1 };
//!         }
//!     });
//! }
//! assert!(hits.iter().all(|&h| h == 1));
//!
//! // … and `par_map`, which returns results in index order regardless of
//! // which worker computed what.
//! assert_eq!(pool.par_map(4, |i| i * i), vec![0, 1, 4, 9]);
//! ```

use std::any::Any;
use std::cell::Cell;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Process-wide default worker count. 0 means "ask the OS"
/// (`available_parallelism`). Set from the `repro` CLI via `--threads`.
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// True while the current thread is executing inside a pool worker.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// Number of hardware threads the OS reports (>= 1).
pub fn available_parallelism() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Set the process-wide default worker count (0 = auto). This only affects
/// scheduling; results are bit-identical for every setting.
///
/// ```
/// qep::util::pool::set_global_threads(2);
/// assert_eq!(qep::util::pool::global_threads(), 2);
/// qep::util::pool::set_global_threads(0); // back to "all hardware threads"
/// ```
pub fn set_global_threads(n: usize) {
    GLOBAL_THREADS.store(n, Ordering::Relaxed);
}

/// The process-wide default worker count, resolving 0 to the hardware.
pub fn global_threads() -> usize {
    match GLOBAL_THREADS.load(Ordering::Relaxed) {
        0 => available_parallelism(),
        n => n,
    }
}

/// A pool handle using the process-wide default worker count.
pub fn global() -> Pool {
    Pool::new(0)
}

/// Default stealing grain for `n` items on `threads` workers: ~4 chunks
/// per worker so fast workers can steal from slow ones, never below 1.
pub fn chunk(n: usize, threads: usize) -> usize {
    n.div_ceil(threads.max(1) * 4).max(1)
}

/// Shared mutable base pointer handed to pool workers.
///
/// Safety contract: workers may only dereference *disjoint* regions derived
/// from this pointer (e.g. distinct row ranges of a matrix). The wrapper
/// exists purely to move the pointer across the `Send`/`Sync` boundary of
/// worker threads; every dereference site stays `unsafe` and local.
pub struct SendPtr<T>(pub *mut T);

unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> SendPtr<T> {
        SendPtr(p)
    }
}

// ---------------------------------------------------------------------------
// The persistent runtime: parked workers + mutex-lite job injection.
// ---------------------------------------------------------------------------

/// One type-erased job, owned by the submitting stack frame. Workers only
/// ever see a raw pointer to it, and the submitter does not return (or
/// unwind past it) until every participant has checked out, so the
/// pointer never dangles.
struct JobCtx {
    /// Lock-free chunk cursor: participants `fetch_add(grain)` until `n`.
    cursor: AtomicUsize,
    n: usize,
    grain: usize,
    /// `&F` erased to a thin pointer; paired with the monomorphized
    /// trampoline below.
    data: *const (),
    call: unsafe fn(*const (), usize, usize),
    /// Workers currently inside *this* job (modified under the injector
    /// lock). Per-job — so a retiring submitter drains exactly its own
    /// participants and is never held up by a successor's job.
    active: AtomicUsize,
    /// First panic payload raised by any participant, re-raised by the
    /// submitter once the job has drained.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

/// Monomorphized trampoline restoring the erased closure type.
///
/// Safety: `data` must be the `&F` the matching [`JobCtx`] was built from,
/// still alive (guaranteed by the submitter draining before return).
unsafe fn call_erased<F: Fn(usize, usize) + Sync>(data: *const (), start: usize, end: usize) {
    (*(data as *const F))(start, end)
}

/// The chunk-stealing loop both engines run: claim grain-sized chunks off
/// the shared cursor until `[0, n)` is exhausted. Keeping this in ONE
/// place is part of the persistent-vs-scoped equivalence story — the two
/// engines cannot drift apart in how they chunk.
fn steal_loop<F: Fn(usize, usize)>(cursor: &AtomicUsize, n: usize, grain: usize, f: &F) {
    loop {
        let start = cursor.fetch_add(grain, Ordering::Relaxed);
        if start >= n {
            break;
        }
        f(start, (start + grain).min(n));
    }
}

/// [`steal_loop`] over a published (type-erased) job. Shared by workers
/// and the submitting thread.
fn steal_chunks(job: &JobCtx) {
    let (call, data) = (job.call, job.data);
    // Safety: see `call_erased`; the submitter keeps `data` alive until
    // every participant (including us) has checked out.
    steal_loop(&job.cursor, job.n, job.grain, &|start, end| unsafe {
        call(data, start, end)
    });
}

/// Run one participant's share of `job`, catching panics so a failing job
/// can neither kill a persistent worker nor leave the submitter waiting.
fn participate(job: &JobCtx) {
    if let Err(payload) = panic::catch_unwind(AssertUnwindSafe(|| steal_chunks(job))) {
        let mut slot = job.panic.lock().unwrap();
        if slot.is_none() {
            *slot = Some(payload);
        }
    }
}

/// Everything workers and submitters coordinate through. All fields are
/// only touched under `state`'s lock except the job's own atomics.
struct Injector {
    state: Mutex<InjectorState>,
    /// Workers park here between dispatches.
    work_cv: Condvar,
    /// Submitters park here: queued ones until the current job retires,
    /// the active one until its last participant checks out.
    done_cv: Condvar,
}

struct InjectorState {
    /// Bumped once per published job so parked workers can tell "new job"
    /// from a spurious wakeup.
    epoch: u64,
    /// The live job, as a pointer-sized integer (`*const JobCtx as usize`;
    /// stored as `usize` so the state stays `Send`). `None` while idle.
    /// Participant counts live in each job's own [`JobCtx::active`].
    job: Option<usize>,
    /// Helper participation tickets remaining for the live job. A dispatch
    /// on `t` threads hands out `t - 1` tickets; excess workers go back to
    /// sleep without touching the job.
    tickets: usize,
    shutdown: bool,
}

impl Injector {
    fn new() -> Injector {
        Injector {
            state: Mutex::new(InjectorState {
                epoch: 0,
                job: None,
                tickets: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        }
    }
}

/// The persistent pool: parked helper threads plus their injector.
struct Runtime {
    inj: Arc<Injector>,
    handles: Vec<JoinHandle<()>>,
}

impl Runtime {
    fn start(helpers: usize) -> Runtime {
        let inj = Arc::new(Injector::new());
        let handles = (0..helpers)
            .map(|i| {
                let inj = Arc::clone(&inj);
                std::thread::Builder::new()
                    .name(format!("qep-pool-{i}"))
                    .spawn(move || worker_loop(&inj))
                    .expect("spawn pool worker")
            })
            .collect();
        Runtime { inj, handles }
    }
}

/// `None` until the first parallel dispatch; `Some` while workers exist.
/// Guarded by a plain mutex: dispatch touches it once (clone an `Arc`), so
/// contention is irrelevant next to the work being dispatched.
static RUNTIME: Mutex<Option<Runtime>> = Mutex::new(None);

/// A parked worker's life: wait for a new epoch, claim a ticket, steal
/// chunks, check out, repeat — until shutdown.
fn worker_loop(inj: &Injector) {
    IN_POOL.with(|c| c.set(true));
    let mut seen = 0u64;
    loop {
        let job_ptr = {
            let mut st = inj.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    if st.job.is_some() && st.tickets > 0 {
                        st.tickets -= 1;
                        let p = st.job.unwrap();
                        // Check in under the lock, while the job is still
                        // published (and therefore alive).
                        unsafe { &*(p as *const JobCtx) }
                            .active
                            .fetch_add(1, Ordering::Relaxed);
                        break p;
                    }
                    // Job already retired or fully ticketed: sleep until
                    // the next epoch.
                }
                st = inj.work_cv.wait(st).unwrap();
            }
        };
        // Safety: we checked in under the lock while the job was still
        // published, and the submitter drains this job's `active` to zero
        // before the JobCtx goes out of scope.
        let job = unsafe { &*(job_ptr as *const JobCtx) };
        participate(job);
        // Check out under the lock; the submitter re-reads the count under
        // the same lock, so the final decrement is never missed.
        let _st = inj.state.lock().unwrap();
        if job.active.fetch_sub(1, Ordering::Relaxed) == 1 {
            inj.done_cv.notify_all();
        }
    }
}

/// Handle on the running injector, starting workers on first use.
fn injector() -> Arc<Injector> {
    let mut guard = RUNTIME.lock().unwrap();
    let rt = guard.get_or_insert_with(|| Runtime::start(available_parallelism().saturating_sub(1)));
    Arc::clone(&rt.inj)
}

/// Spawn the persistent workers now (normally they start lazily on the
/// first parallel dispatch). The pipeline calls this so the first layer's
/// dispatches don't pay the one-time spawn cost. A no-op when called from
/// inside a pool worker (e.g. a pipeline constructed by a sharded
/// experiment cell): workers already exist, and a worker must never block
/// on the runtime registry.
pub fn prestart() {
    if IN_POOL.with(|c| c.get()) {
        return;
    }
    let _ = injector();
}

/// True once the persistent workers have been spawned. Serial work
/// (`--threads 1`, [`Pool::serial`], sub-threshold problems) never starts
/// them — `tests/pool_serial_bypass.rs` holds this as an invariant.
pub fn workers_started() -> bool {
    RUNTIME.lock().unwrap().is_some()
}

/// Gracefully retire the persistent pool: signal shutdown, wake everyone,
/// and join the worker threads. Safe to call at any quiescent point (the
/// `repro` binary calls it on exit); a dispatch issued afterwards simply
/// starts a fresh pool. Workers mid-job finish their job first, so no
/// in-flight dispatch is ever abandoned.
///
/// ```
/// use qep::util::pool::{self, Pool};
/// let doubled = Pool::new(2).par_map(3, |i| i * 2);
/// assert_eq!(doubled, vec![0, 2, 4]);
/// pool::shutdown(); // joins the workers…
/// assert!(!pool::workers_started());
/// // …and the pool restarts transparently on the next dispatch.
/// assert_eq!(Pool::new(2).par_map(3, |i| i + 1), vec![1, 2, 3]);
/// ```
pub fn shutdown() {
    let mut guard = RUNTIME.lock().unwrap();
    if let Some(rt) = guard.take() {
        {
            let mut st = rt.inj.state.lock().unwrap();
            st.shutdown = true;
            rt.inj.work_cv.notify_all();
        }
        for h in rt.handles {
            let _ = h.join();
        }
    }
}

/// Publish `job`, work it from the calling thread, retire it, and wait for
/// every participating worker to check out before returning (or before
/// propagating a panic). `helpers` is the maximum number of persistent
/// workers that may join in.
fn dispatch(inj: &Injector, helpers: usize, job: &JobCtx) {
    {
        let mut st = inj.state.lock().unwrap();
        // One *published* job at a time: queue behind the live one. (A
        // predecessor's workers may still be draining — that's fine, they
        // are counted on the predecessor's own JobCtx, not ours.)
        while st.job.is_some() {
            st = inj.done_cv.wait(st).unwrap();
        }
        st.job = Some(job as *const JobCtx as usize);
        st.tickets = helpers;
        st.epoch = st.epoch.wrapping_add(1);
        inj.work_cv.notify_all();
    }

    // The calling thread is worker 0. Mark it as pool context so nested
    // pool calls inside `f` run inline.
    IN_POOL.with(|c| c.set(true));
    participate(job);
    IN_POOL.with(|c| c.set(false));

    // Retire the job, then drain *our own* participants: after this block
    // no worker holds a reference into the submitter's stack frame, and a
    // successor's job can never extend our wait.
    {
        let mut st = inj.state.lock().unwrap();
        st.job = None;
        st.tickets = 0;
        // Wake submitters queued on the slot before we drain — they only
        // need `job` to be `None`, not our workers to be done.
        inj.done_cv.notify_all();
        while job.active.load(Ordering::Relaxed) > 0 {
            st = inj.done_cv.wait(st).unwrap();
        }
    }

    if let Some(payload) = job.panic.lock().unwrap().take() {
        panic::resume_unwind(payload);
    }
}

// ---------------------------------------------------------------------------
// The public handle.
// ---------------------------------------------------------------------------

/// A lightweight handle on the execution substrate. Cheap to copy; it only
/// records *how many* threads a dispatch may use — the worker threads
/// themselves are process-wide, spawned lazily, and parked between
/// dispatches (see the module docs).
///
/// ```
/// use qep::util::pool::Pool;
/// assert_eq!(Pool::new(3).threads(), 3);
/// assert_eq!(Pool::serial().threads(), 1);
/// assert!(Pool::new(0).threads() >= 1); // 0 = process-wide default
/// ```
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// `threads = 0` resolves to the process-wide default
    /// ([`global_threads`]), which itself defaults to the hardware count.
    pub fn new(threads: usize) -> Pool {
        let t = if threads == 0 { global_threads() } else { threads };
        Pool { threads: t.max(1) }
    }

    /// A pool that always runs inline on the calling thread.
    pub fn serial() -> Pool {
        Pool { threads: 1 }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute `f(start, end)` over every grain-sized chunk of `[0, n)`,
    /// stealing chunks dynamically across up to `self.threads()` workers
    /// of the persistent pool.
    ///
    /// `f` must only touch state owned by its `[start, end)` range; chunks
    /// are disjoint, so disjoint-range writers need no further
    /// synchronization. Runs inline — without waking (or even starting)
    /// any worker — when a single worker suffices or when already inside a
    /// pool worker. If `f` panics, the panic is re-raised here after the
    /// job has fully drained; the workers survive for the next dispatch.
    pub fn run<F>(&self, n: usize, grain: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        let grain = grain.max(1);
        let workers = self.plan(n, grain);
        if workers <= 1 {
            if n > 0 {
                f(0, n);
            }
            return;
        }
        let inj = injector();
        let job = JobCtx {
            cursor: AtomicUsize::new(0),
            n,
            grain,
            data: &f as *const F as *const (),
            call: call_erased::<F>,
            active: AtomicUsize::new(0),
            panic: Mutex::new(None),
        };
        dispatch(&inj, workers - 1, &job);
    }

    /// The scoped-spawn scheduler the pool used before persistent workers
    /// (PR 1/2 behavior): identical chunking, stealing, and inline-guard
    /// semantics, but every call spawns and joins fresh `std::thread::scope`
    /// threads. Kept as the overhead baseline for
    /// `benches/linalg_hotpath.rs` and as the reference engine
    /// `tests/parallel_equivalence.rs` pins [`Pool::run`] against.
    pub fn run_scoped<F>(&self, n: usize, grain: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        let grain = grain.max(1);
        let workers = self.plan(n, grain);
        if workers <= 1 {
            if n > 0 {
                f(0, n);
            }
            return;
        }
        let cursor = AtomicUsize::new(0);
        let cursor_ref = &cursor;
        let f_ref = &f;
        std::thread::scope(|s| {
            for _ in 1..workers {
                s.spawn(|| {
                    IN_POOL.with(|c| c.set(true));
                    steal_loop(cursor_ref, n, grain, f_ref);
                });
            }
            // The calling thread is worker 0.
            IN_POOL.with(|c| c.set(true));
            steal_loop(cursor_ref, n, grain, f_ref);
            IN_POOL.with(|c| c.set(false));
        });
    }

    /// Evaluate `f(0), …, f(n-1)` across the pool and return the results in
    /// index order. Each item runs exactly once; output order is
    /// deterministic regardless of which worker computed what.
    ///
    /// ```
    /// use qep::util::pool::Pool;
    /// let squares = Pool::new(4).par_map(5, |i| i * i);
    /// assert_eq!(squares, vec![0, 1, 4, 9, 16]);
    /// assert!(Pool::new(4).par_map(0, |i| i).is_empty());
    /// ```
    pub fn par_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        if self.threads <= 1 || IN_POOL.with(|c| c.get()) {
            return (0..n).map(f).collect();
        }
        let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let slots_ref = &slots;
        let f_ref = &f;
        self.run(n, 1, move |start, end| {
            for i in start..end {
                let v = f_ref(i);
                *slots_ref[i].lock().unwrap() = Some(v);
            }
        });
        collect_par_map_slots(slots, self.threads)
    }

    /// How many workers a dispatch of `n` items at `grain` would actually
    /// use (1 = run inline). Shared by [`run`](Pool::run) and
    /// [`run_scoped`](Pool::run_scoped) so both engines make identical
    /// inline-vs-parallel decisions.
    fn plan(&self, n: usize, grain: usize) -> usize {
        if n == 0 {
            return 1;
        }
        let workers = self.threads.min(n.div_ceil(grain));
        if workers <= 1 || IN_POOL.with(|c| c.get()) {
            1
        } else {
            workers
        }
    }
}

/// Collect `par_map`'s per-item result slots into the output vector,
/// panicking **with a diagnostic** — which job index, out of how many,
/// and the pool state — when a slot is unfilled or poisoned. An unfilled
/// slot can only mean the chunk cursor skipped an index (a scheduler
/// bug); a poisoned one that a job panicked while publishing its result
/// (job panics are normally caught on the worker *before* the slot lock
/// is taken). Both are unreachable in correct operation, which is
/// exactly why the failure must name the culprit instead of dying in a
/// bare `unwrap`.
#[doc(hidden)] // public only so tests/pool_edge_cases.rs can cover the diagnostics
pub fn collect_par_map_slots<T>(slots: Vec<Mutex<Option<T>>>, threads: usize) -> Vec<T> {
    let n = slots.len();
    slots
        .into_iter()
        .enumerate()
        .map(|(i, slot)| match slot.into_inner() {
            Ok(Some(v)) => v,
            Ok(None) => panic!(
                "par_map: job {i} of {n} never produced a result (pool threads={threads}, \
                 persistent workers started={}) — the chunk cursor skipped an index",
                workers_started()
            ),
            Err(_) => panic!(
                "par_map: result slot {i} of {n} is poisoned (pool threads={threads}, \
                 persistent workers started={}) — a job panicked while publishing its result",
                workers_started()
            ),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_covers_every_index_exactly_once() {
        let n = 1000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let pool = Pool::new(4);
        let href = &hits;
        pool.run(n, 7, |start, end| {
            for i in start..end {
                href[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn scoped_baseline_covers_every_index_exactly_once() {
        let n = 513;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let pool = Pool::new(4);
        let href = &hits;
        pool.run_scoped(n, 8, |start, end| {
            for i in start..end {
                href[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn run_handles_empty_and_tiny_ranges() {
        let pool = Pool::new(4);
        pool.run(0, 8, |_, _| panic!("must not be called"));
        pool.run_scoped(0, 8, |_, _| panic!("must not be called"));
        let hit = AtomicU64::new(0);
        pool.run(1, 128, |s, e| {
            assert_eq!((s, e), (0, 1));
            hit.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn par_map_preserves_index_order() {
        for threads in [1usize, 2, 4, 9] {
            let pool = Pool::new(threads);
            let out = pool.par_map(37, |i| i * i);
            let want: Vec<usize> = (0..37).map(|i| i * i).collect();
            assert_eq!(out, want, "threads={threads}");
        }
    }

    #[test]
    fn nested_calls_run_inline_without_deadlock() {
        let pool = Pool::new(4);
        let total = AtomicU64::new(0);
        let tref = &total;
        pool.run(8, 1, |s, e| {
            // Nested use of the pool from inside a worker must degrade to
            // inline execution (and must not touch the injector again).
            let inner = Pool::new(4);
            inner.run(4, 1, |is, ie| {
                tref.fetch_add((ie - is) as u64 * (e - s) as u64, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn panicking_job_reports_and_pool_survives() {
        let pool = Pool::new(4);
        let res = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(64, 1, |s, _| {
                if s == 13 {
                    panic!("boom at 13");
                }
            });
        }));
        assert!(res.is_err(), "panic must propagate to the submitter");
        // The workers must still be alive and serving jobs.
        let out = pool.par_map(16, |i| i + 1);
        assert_eq!(out, (1..=16).collect::<Vec<_>>());
    }

    #[test]
    fn concurrent_submitters_queue_cleanly() {
        // Several OS threads dispatching simultaneously must serialize
        // through the injector without deadlock or cross-talk.
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let pool = Pool::new(3);
                    let out = pool.par_map(33, move |i| i * (t + 1));
                    let want: Vec<usize> = (0..33).map(|i| i * (t + 1)).collect();
                    assert_eq!(out, want, "submitter {t}");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn persistent_and_scoped_engines_do_identical_work() {
        let n = 257;
        let run_with = |scoped: bool| -> Vec<u64> {
            let mut out = vec![0u64; n];
            let base = SendPtr::new(out.as_mut_ptr());
            let pool = Pool::new(4);
            let f = |s: usize, e: usize| {
                for i in s..e {
                    // Sound: chunks are disjoint index ranges.
                    unsafe { *base.0.add(i) = (i * i + 1) as u64 };
                }
            };
            if scoped {
                pool.run_scoped(n, 5, f);
            } else {
                pool.run(n, 5, f);
            }
            out
        };
        assert_eq!(run_with(false), run_with(true));
    }

    #[test]
    fn chunk_grain_is_sane() {
        assert_eq!(chunk(0, 4), 1);
        assert_eq!(chunk(16, 4), 1);
        assert!(chunk(1000, 4) >= 32);
        assert_eq!(chunk(5, 0), 2);
    }

    #[test]
    fn global_threads_resolves_zero_to_hardware() {
        assert!(available_parallelism() >= 1);
        assert!(Pool::new(0).threads() >= 1);
        assert_eq!(Pool::new(3).threads(), 3);
        assert_eq!(Pool::serial().threads(), 1);
    }
}
