//! Work-stealing parallel execution substrate for the PTQ hot path.
//!
//! Design constraints (in priority order):
//!
//! 1. **Bit-identical results to the serial path.** Reproducibility is the
//!    whole point of this repo, so the pool never changes *what* is
//!    computed — only *who* computes it. Callers partition work into
//!    disjoint output regions (rows of a GEMM, independent layers) and each
//!    region is processed with exactly the serial kernel's floating-point
//!    operation order. No atomic float reductions, ever.
//! 2. **No dependencies.** The environment is offline; everything is built
//!    on `std::thread::scope` + atomics.
//! 3. **No oversubscription.** Work executed *inside* a pool worker that
//!    itself calls into the pool runs inline (a thread-local flag marks
//!    pool context), so nested parallelism — e.g. a GEMM inside a
//!    parallel per-layer quantization — degrades gracefully to the serial
//!    kernel instead of spawning threads quadratically.
//!
//! Scheduling is chunked self-stealing: work items `[0, n)` are split into
//! grain-sized chunks published through a shared atomic cursor, and every
//! worker (including the calling thread) steals the next chunk when it
//! finishes its current one. Fast workers therefore take more chunks —
//! the load balancing of a work-stealing deque without the deque.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Process-wide default worker count. 0 means "ask the OS"
/// (`available_parallelism`). Set from the `repro` CLI via `--threads`.
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// True while the current thread is executing inside a pool worker.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// Number of hardware threads the OS reports (>= 1).
pub fn available_parallelism() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Set the process-wide default worker count (0 = auto). This only affects
/// scheduling; results are bit-identical for every setting.
pub fn set_global_threads(n: usize) {
    GLOBAL_THREADS.store(n, Ordering::Relaxed);
}

/// The process-wide default worker count, resolving 0 to the hardware.
pub fn global_threads() -> usize {
    match GLOBAL_THREADS.load(Ordering::Relaxed) {
        0 => available_parallelism(),
        n => n,
    }
}

/// A pool handle using the process-wide default worker count.
pub fn global() -> Pool {
    Pool::new(0)
}

/// Default stealing grain for `n` items on `threads` workers: ~4 chunks
/// per worker so fast workers can steal from slow ones, never below 1.
pub fn chunk(n: usize, threads: usize) -> usize {
    n.div_ceil(threads.max(1) * 4).max(1)
}

/// Shared mutable base pointer handed to pool workers.
///
/// Safety contract: workers may only dereference *disjoint* regions derived
/// from this pointer (e.g. distinct row ranges of a matrix). The wrapper
/// exists purely to move the pointer across the `Send`/`Sync` boundary of
/// scoped threads; every dereference site stays `unsafe` and local.
pub struct SendPtr<T>(pub *mut T);

unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> SendPtr<T> {
        SendPtr(p)
    }
}

/// A lightweight handle on the execution substrate. Cheap to copy; threads
/// are spawned scoped per call (no idle spinning between calls).
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// `threads = 0` resolves to the process-wide default
    /// ([`global_threads`]), which itself defaults to the hardware count.
    pub fn new(threads: usize) -> Pool {
        let t = if threads == 0 { global_threads() } else { threads };
        Pool { threads: t.max(1) }
    }

    /// A pool that always runs inline on the calling thread.
    pub fn serial() -> Pool {
        Pool { threads: 1 }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute `f(start, end)` over every grain-sized chunk of `[0, n)`,
    /// stealing chunks dynamically across `self.threads()` workers.
    ///
    /// `f` must only touch state owned by its `[start, end)` range; chunks
    /// are disjoint, so disjoint-range writers need no further
    /// synchronization. Runs inline when a single worker suffices or when
    /// already inside a pool worker.
    pub fn run<F>(&self, n: usize, grain: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        if n == 0 {
            return;
        }
        let grain = grain.max(1);
        let workers = self.threads.min(n.div_ceil(grain));
        if workers <= 1 || IN_POOL.with(|c| c.get()) {
            f(0, n);
            return;
        }
        let cursor = AtomicUsize::new(0);
        let cursor_ref = &cursor;
        let f_ref = &f;
        std::thread::scope(|s| {
            for _ in 1..workers {
                s.spawn(move || {
                    IN_POOL.with(|c| c.set(true));
                    steal_loop(cursor_ref, n, grain, f_ref);
                });
            }
            // The calling thread is worker 0.
            IN_POOL.with(|c| c.set(true));
            steal_loop(cursor_ref, n, grain, f_ref);
            IN_POOL.with(|c| c.set(false));
        });
    }

    /// Evaluate `f(0), …, f(n-1)` across the pool and return the results in
    /// index order. Each item runs exactly once; output order is
    /// deterministic regardless of which worker computed what.
    pub fn par_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        let workers = self.threads.min(n);
        if workers <= 1 || IN_POOL.with(|c| c.get()) {
            return (0..n).map(f).collect();
        }
        let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let cursor = AtomicUsize::new(0);
        let slots_ref = &slots;
        let cursor_ref = &cursor;
        let f_ref = &f;
        std::thread::scope(|s| {
            for _ in 1..workers {
                s.spawn(move || {
                    IN_POOL.with(|c| c.set(true));
                    map_loop(cursor_ref, n, f_ref, slots_ref);
                });
            }
            IN_POOL.with(|c| c.set(true));
            map_loop(cursor_ref, n, f_ref, slots_ref);
            IN_POOL.with(|c| c.set(false));
        });
        slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("par_map: unfilled slot"))
            .collect()
    }
}

fn steal_loop<F: Fn(usize, usize) + Sync>(cursor: &AtomicUsize, n: usize, grain: usize, f: &F) {
    loop {
        let start = cursor.fetch_add(grain, Ordering::Relaxed);
        if start >= n {
            break;
        }
        f(start, (start + grain).min(n));
    }
}

fn map_loop<T: Send, F: Fn(usize) -> T + Sync>(
    cursor: &AtomicUsize,
    n: usize,
    f: &F,
    slots: &[Mutex<Option<T>>],
) {
    loop {
        let i = cursor.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            break;
        }
        let v = f(i);
        *slots[i].lock().unwrap() = Some(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_covers_every_index_exactly_once() {
        let n = 1000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let pool = Pool::new(4);
        let href = &hits;
        pool.run(n, 7, |start, end| {
            for i in start..end {
                href[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn run_handles_empty_and_tiny_ranges() {
        let pool = Pool::new(4);
        pool.run(0, 8, |_, _| panic!("must not be called"));
        let hit = AtomicU64::new(0);
        pool.run(1, 128, |s, e| {
            assert_eq!((s, e), (0, 1));
            hit.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn par_map_preserves_index_order() {
        for threads in [1usize, 2, 4, 9] {
            let pool = Pool::new(threads);
            let out = pool.par_map(37, |i| i * i);
            let want: Vec<usize> = (0..37).map(|i| i * i).collect();
            assert_eq!(out, want, "threads={threads}");
        }
    }

    #[test]
    fn nested_calls_run_inline_without_deadlock() {
        let pool = Pool::new(4);
        let total = AtomicU64::new(0);
        let tref = &total;
        pool.run(8, 1, |s, e| {
            // Nested use of the pool from inside a worker must degrade to
            // inline execution (and must not spawn recursively).
            let inner = Pool::new(4);
            inner.run(4, 1, |is, ie| {
                tref.fetch_add((ie - is) as u64 * (e - s) as u64, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn chunk_grain_is_sane() {
        assert_eq!(chunk(0, 4), 1);
        assert_eq!(chunk(16, 4), 1);
        assert!(chunk(1000, 4) >= 32);
        assert_eq!(chunk(5, 0), 2);
    }

    #[test]
    fn global_threads_resolves_zero_to_hardware() {
        assert!(available_parallelism() >= 1);
        assert!(Pool::new(0).threads() >= 1);
        assert_eq!(Pool::new(3).threads(), 3);
        assert_eq!(Pool::serial().threads(), 1);
    }
}
