//! Pure-Rust forward pass with per-linear activation capture — the data
//! source for the coordinator's dual calibration streams and the fallback
//! evaluation path (the PJRT artifacts execute the same graph; see
//! `crate::runtime`).

use super::config::ModelConfig;
use super::ops::{attend_one, causal_attention, linear, next_token_nll, rmsnorm, swiglu};
use super::store::{BlockWeights, Model};
use crate::linalg::Mat;
use crate::serve::KvCache;

/// Reject an out-of-vocab token with a message naming the token, its
/// position, and the vocab size — serving validates requests against this
/// same bound up front (`serve::sched`), so a bad id is refused at submit
/// time instead of aborting mid-batch deep inside `Mat::row`.
#[inline]
pub(crate) fn check_token(tok: u32, pos: usize, vocab: usize) {
    assert!(
        (tok as usize) < vocab,
        "out-of-vocab token {tok} at position {pos} (vocab size {vocab})"
    );
}

/// Activations captured at the inputs of each quantizable linear in one
/// block. `attn_in` feeds wq/wk/wv, `attn_ctx` feeds wo, `mlp_in` feeds
/// gate/up, `mlp_act` feeds down.
#[derive(Clone, Debug)]
pub struct BlockCapture {
    pub attn_in: Mat,
    pub attn_ctx: Mat,
    pub mlp_in: Mat,
    pub mlp_act: Mat,
}

impl BlockCapture {
    /// Capture matching a linear's short name.
    pub fn input_for(&self, short: &str) -> &Mat {
        match short {
            "attn.wq" | "attn.wk" | "attn.wv" => &self.attn_in,
            "attn.wo" => &self.attn_ctx,
            "mlp.gate" | "mlp.up" => &self.mlp_in,
            "mlp.down" => &self.mlp_act,
            other => panic!("unknown linear '{other}'"),
        }
    }
}

/// Forward-pass engine bound to a config (holds no weights; weights are
/// passed per call so full-precision and quantized streams share code).
pub struct Forward<'a> {
    pub cfg: &'a ModelConfig,
}

impl<'a> Forward<'a> {
    pub fn new(cfg: &'a ModelConfig) -> Forward<'a> {
        Forward { cfg }
    }

    /// Token + position embedding: tokens.len() must be a multiple of
    /// seq_len.
    pub fn embed(&self, model: &Model, tokens: &[u32]) -> Mat {
        let c = self.cfg;
        assert_eq!(tokens.len() % c.seq_len, 0, "tokens must tile seq_len");
        let mut x = Mat::zeros(tokens.len(), c.dim);
        for (t, &tok) in tokens.iter().enumerate() {
            check_token(tok, t, c.vocab);
            let e = model.embed.row(tok as usize);
            let p = model.pos.row(t % c.seq_len);
            let row = x.row_mut(t);
            for i in 0..c.dim {
                row[i] = e[i] + p[i];
            }
        }
        x
    }

    /// One block, returning output and captured per-linear inputs. This
    /// is the unit of the pipeline's producer stage: the calibration
    /// producer walks `block` one block ahead of the quantizing consumer
    /// (`coordinator::pipeline`), so it must stay a pure function of
    /// `(b, x)` — no internal state, no scheduling-dependent reductions —
    /// for the pipelined run to be byte-identical to the serial one.
    pub fn block(&self, b: &BlockWeights, x: &Mat) -> (Mat, BlockCapture) {
        let c = self.cfg;
        let attn_in = rmsnorm(x, &b.attn_norm);
        let q = linear(&attn_in, &b.wq);
        let k = linear(&attn_in, &b.wk);
        let v = linear(&attn_in, &b.wv);
        let attn_ctx = causal_attention(&q, &k, &v, c.n_heads, c.seq_len);
        let attn_out = linear(&attn_ctx, &b.wo);
        let x1 = x.add(&attn_out);

        let mlp_in = rmsnorm(&x1, &b.mlp_norm);
        let g = linear(&mlp_in, &b.gate);
        let u = linear(&mlp_in, &b.up);
        let mlp_act = swiglu(&g, &u);
        let mlp_out = linear(&mlp_act, &b.down);
        let out = x1.add(&mlp_out);
        (
            out,
            BlockCapture { attn_in, attn_ctx, mlp_in, mlp_act },
        )
    }

    /// Hidden states after all blocks (no final norm).
    pub fn backbone(&self, model: &Model, tokens: &[u32]) -> Mat {
        let mut x = self.embed(model, tokens);
        for b in &model.blocks {
            let (nx, _) = self.block(b, &x);
            x = nx;
        }
        x
    }

    /// Hidden states after each block: `out[i]` = activations *entering*
    /// block i; `out[n_layers]` = final hidden states. Used by the Fig. 2
    /// Δ_m experiment.
    pub fn block_trace(&self, model: &Model, tokens: &[u32]) -> Vec<Mat> {
        let mut x = self.embed(model, tokens);
        let mut trace = Vec::with_capacity(model.blocks.len() + 1);
        for b in &model.blocks {
            trace.push(x.clone());
            let (nx, _) = self.block(b, &x);
            x = nx;
        }
        trace.push(x);
        trace
    }

    /// Final logits (tied head): rmsnorm then x·Embedᵀ.
    pub fn logits(&self, model: &Model, hidden: &Mat) -> Mat {
        let h = rmsnorm(hidden, &model.final_norm);
        linear(&h, &model.embed)
    }

    /// Full forward to logits.
    pub fn forward(&self, model: &Model, tokens: &[u32]) -> Mat {
        let h = self.backbone(model, tokens);
        self.logits(model, &h)
    }

    /// One incremental decode step: feed a single token at the cache's
    /// current position, appending its per-block K/V rows instead of
    /// recomputing the whole segment. Returns the `[1, vocab]` logits row.
    ///
    /// Bit-identical to the full-recompute [`Self::forward`]: every
    /// per-row op (`rmsnorm`, the linears via the canonical skinny GEMV
    /// path, `swiglu`, residual adds) is row-independent with a fixed
    /// per-element order, and [`attend_one`] replicates
    /// [`causal_attention`]'s position body over the cached K/V rows — so
    /// the logits equal row `t` of `forward` over any segment sharing the
    /// prefix (`tests/serve_engine.rs` gates this for every prefix
    /// length). Panics if the cache is full (`t == seq_len`); the
    /// scheduler retires such sessions instead.
    pub fn decode_step(&self, model: &Model, cache: &mut KvCache, tok: u32) -> Mat {
        let c = self.cfg;
        let t = cache.len();
        assert!(t < c.seq_len, "decode_step: context full ({t} == seq_len)");
        assert_eq!(cache.n_layers(), model.blocks.len(), "cache/model layer mismatch");
        check_token(tok, t, c.vocab);
        let mut x = Mat::zeros(1, c.dim);
        {
            let e = model.embed.row(tok as usize);
            let p = model.pos.row(t);
            let row = x.row_mut(0);
            for i in 0..c.dim {
                row[i] = e[i] + p[i];
            }
        }
        for (li, b) in model.blocks.iter().enumerate() {
            let attn_in = rmsnorm(&x, &b.attn_norm);
            let q = linear(&attn_in, &b.wq);
            let k = linear(&attn_in, &b.wk);
            let v = linear(&attn_in, &b.wv);
            cache.write_row(li, t, k.row(0), v.row(0));
            let mut ctx = Mat::zeros(1, c.dim);
            {
                let (kc, vc) = cache.layer(li);
                attend_one(q.row(0), kc, vc, c.n_heads, t, ctx.row_mut(0));
            }
            let attn_out = linear(&ctx, &b.wo);
            let x1 = x.add(&attn_out);

            let mlp_in = rmsnorm(&x1, &b.mlp_norm);
            let g = linear(&mlp_in, &b.gate);
            let u = linear(&mlp_in, &b.up);
            let mlp_act = swiglu(&g, &u);
            let mlp_out = linear(&mlp_act, &b.down);
            x = x1.add(&mlp_out);
        }
        cache.advance(1);
        let h = rmsnorm(&x, &model.final_norm);
        linear(&h, &model.embed)
    }

    /// Perplexity over tokens (exp of mean next-token NLL in nats).
    pub fn perplexity(&self, model: &Model, tokens: &[u32]) -> f64 {
        let logits = self.forward(model, tokens);
        let (sum, count) = next_token_nll(&logits, tokens, self.cfg.seq_len);
        (sum / count.max(1) as f64).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::text::VOCAB_SIZE;
    use crate::util::rng::Rng;

    fn small() -> (ModelConfig, Model) {
        let mut cfg = ModelConfig::new("unit", 16, 2, 2, 32);
        cfg.seq_len = 8;
        let m = Model::random(&cfg, 1);
        (cfg, m)
    }

    fn tokens(n: usize, seed: u64) -> Vec<u32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.below(200) as u32).collect()
    }

    #[test]
    fn shapes_flow() {
        let (cfg, m) = small();
        let f = Forward::new(&cfg);
        let toks = tokens(16, 2);
        let logits = f.forward(&m, &toks);
        assert_eq!((logits.rows, logits.cols), (16, VOCAB_SIZE));
    }

    #[test]
    fn random_model_ppl_near_uniform() {
        // An untrained model should sit near uniform perplexity over the
        // vocabulary (allowing slack for embedding geometry).
        let (cfg, m) = small();
        let f = Forward::new(&cfg);
        let ppl = f.perplexity(&m, &tokens(256, 3));
        let uniform = VOCAB_SIZE as f64;
        assert!(ppl > uniform * 0.5 && ppl < uniform * 2.0, "ppl {ppl}");
    }

    #[test]
    fn capture_matches_recompute() {
        let (cfg, m) = small();
        let f = Forward::new(&cfg);
        let toks = tokens(16, 4);
        let x = f.embed(&m, &toks);
        let (out, cap) = f.block(&m.blocks[0], &x);
        // attn_in must be the rmsnorm of x.
        let want = rmsnorm(&x, &m.blocks[0].attn_norm);
        assert_eq!(cap.attn_in, want);
        // Rebuilding the block output from captures must agree.
        let attn_out = linear(&cap.attn_ctx, &m.blocks[0].wo);
        let x1 = x.add(&attn_out);
        let mlp_out = linear(&cap.mlp_act, &m.blocks[0].down);
        let rebuilt = x1.add(&mlp_out);
        for (a, b) in out.data.iter().zip(rebuilt.data.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn block_trace_is_consistent() {
        let (cfg, m) = small();
        let f = Forward::new(&cfg);
        let toks = tokens(16, 5);
        let trace = f.block_trace(&m, &toks);
        assert_eq!(trace.len(), cfg.n_layers + 1);
        let direct = f.backbone(&m, &toks);
        assert_eq!(trace.last().unwrap(), &direct);
    }

    #[test]
    fn capture_input_for_names() {
        let (cfg, m) = small();
        let f = Forward::new(&cfg);
        let toks = tokens(8, 6);
        let x = f.embed(&m, &toks);
        let (_, cap) = f.block(&m.blocks[0], &x);
        assert_eq!(cap.input_for("attn.wq"), &cap.attn_in);
        assert_eq!(cap.input_for("attn.wo"), &cap.attn_ctx);
        assert_eq!(cap.input_for("mlp.up"), &cap.mlp_in);
        assert_eq!(cap.input_for("mlp.down"), &cap.mlp_act);
    }

    #[test]
    fn deterministic_forward() {
        let (cfg, m) = small();
        let f = Forward::new(&cfg);
        let toks = tokens(16, 7);
        let a = f.forward(&m, &toks);
        let b = f.forward(&m, &toks);
        assert_eq!(a, b);
    }

    #[test]
    fn decode_steps_match_full_forward_bitwise() {
        let (cfg, m) = small();
        let f = Forward::new(&cfg);
        let toks = tokens(cfg.seq_len, 9);
        let full = f.forward(&m, &toks);
        let mut cache = KvCache::new(cfg.n_layers, cfg.seq_len, cfg.dim);
        for (t, &tok) in toks.iter().enumerate() {
            let row = f.decode_step(&m, &mut cache, tok);
            assert_eq!((row.rows, row.cols), (1, cfg.vocab));
            assert_eq!(row.row(0), full.row(t), "position {t}");
            assert_eq!(cache.len(), t + 1);
        }
    }

    #[test]
    #[should_panic(expected = "out-of-vocab token 9999 at position 3")]
    fn embed_rejects_out_of_vocab_tokens_loudly() {
        let (cfg, m) = small();
        let f = Forward::new(&cfg);
        let mut toks = tokens(cfg.seq_len, 10);
        toks[3] = 9999;
        f.embed(&m, &toks);
    }

    #[test]
    fn perturbing_late_block_changes_output() {
        let (cfg, mut m) = small();
        let toks = tokens(16, 8);
        let f = Forward::new(&cfg);
        let base = f.forward(&m, &toks);
        m.blocks[1].down.scale(1.5);
        let changed = f.forward(&m, &toks);
        assert!(base.sub(&changed).frob() > 1e-6);
    }
}
