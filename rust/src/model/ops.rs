//! Primitive neural ops shared by the forward pass and the coordinator's
//! fine-grained capture path. All functions are pure and tokens-major:
//! activations are `Mat [m, d]` with `m = n_segments * seq_len`.

use crate::linalg::{matmul_nt, Mat};

pub const NORM_EPS: f32 = 1e-5;

/// RMSNorm with learned gain: y = x / rms(x) * g.
pub fn rmsnorm(x: &Mat, gain: &[f32]) -> Mat {
    assert_eq!(x.cols, gain.len());
    let mut out = Mat::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let row = x.row(r);
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / x.cols as f32;
        let inv = 1.0 / (ms + NORM_EPS).sqrt();
        let orow = out.row_mut(r);
        for c in 0..x.cols {
            orow[c] = row[c] * inv * gain[c];
        }
    }
    out
}

/// SiLU (swish) activation.
#[inline]
pub fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

/// Elementwise silu(gate) * up — the SwiGLU gate.
pub fn swiglu(gate: &Mat, up: &Mat) -> Mat {
    assert_eq!((gate.rows, gate.cols), (up.rows, up.cols));
    let data = gate
        .data
        .iter()
        .zip(up.data.iter())
        .map(|(&g, &u)| silu(g) * u)
        .collect();
    Mat { rows: gate.rows, cols: gate.cols, data }
}

/// Numerically stable in-place softmax over a slice.
pub fn softmax_inplace(xs: &mut [f32]) {
    let max = xs.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let mut sum = 0.0f32;
    for v in xs.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum.max(1e-30);
    for v in xs.iter_mut() {
        *v *= inv;
    }
}

/// Multi-head causal self-attention over per-segment Q/K/V.
///
/// `q,k,v` are `[m, d]` with `m = n_seg * seq_len`; each segment attends
/// only within itself (the paper's calibration segments are independent).
/// Returns the context `[m, d]` (pre-output-projection).
pub fn causal_attention(q: &Mat, k: &Mat, v: &Mat, n_heads: usize, seq_len: usize) -> Mat {
    let (m, d) = (q.rows, q.cols);
    assert_eq!(m % seq_len, 0, "tokens not a multiple of seq_len");
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let n_seg = m / seq_len;
    let mut ctx = Mat::zeros(m, d);
    let mut scores = vec![0.0f32; seq_len];
    for s in 0..n_seg {
        let base = s * seq_len;
        for h in 0..n_heads {
            let h0 = h * hd;
            for t in 0..seq_len {
                let qrow = &q.row(base + t)[h0..h0 + hd];
                // scores over keys 0..=t (causal).
                for (u, sc) in scores[..=t].iter_mut().enumerate() {
                    let krow = &k.row(base + u)[h0..h0 + hd];
                    *sc = crate::linalg::gemm::dot(qrow, krow) * scale;
                }
                softmax_inplace(&mut scores[..=t]);
                let orow = &mut ctx.row_mut(base + t)[h0..h0 + hd];
                for (u, &p) in scores[..=t].iter().enumerate() {
                    let vrow = &v.row(base + u)[h0..h0 + hd];
                    for (o, &vv) in orow.iter_mut().zip(vrow.iter()) {
                        *o += p * vv;
                    }
                }
            }
        }
    }
    ctx
}

/// One query position of multi-head causal attention against cached K/V —
/// the incremental-decode twin of [`causal_attention`].
///
/// `q` is the position-`t` query row `[d]`; `k`/`v` hold the segment's
/// key/value rows with rows `0..=t` valid (a KV-cache; later rows are
/// never read). Accumulates the context into `out` (which the caller
/// zero-initializes, exactly like the full pass's fresh `ctx`).
///
/// Operation order is kept term-for-term identical to the position-`t`
/// body of [`causal_attention`]: scores via [`crate::linalg::gemm::dot`]
/// times the same scale, [`softmax_inplace`] over `0..=t`, then
/// ascending-position `*o += p·v` accumulation — so one decode step is
/// bit-identical to recomputing the whole prefix (the gate in
/// `tests/serve_engine.rs`).
pub fn attend_one(q: &[f32], k: &Mat, v: &Mat, n_heads: usize, t: usize, out: &mut [f32]) {
    let d = q.len();
    assert_eq!(d, out.len());
    assert!(t < k.rows && t < v.rows, "attend_one: position {t} outside cache");
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut scores = vec![0.0f32; t + 1];
    for h in 0..n_heads {
        let h0 = h * hd;
        let qrow = &q[h0..h0 + hd];
        for (u, sc) in scores.iter_mut().enumerate() {
            let krow = &k.row(u)[h0..h0 + hd];
            *sc = crate::linalg::gemm::dot(qrow, krow) * scale;
        }
        softmax_inplace(&mut scores);
        let orow = &mut out[h0..h0 + hd];
        for (u, &p) in scores.iter().enumerate() {
            let vrow = &v.row(u)[h0..h0 + hd];
            for (o, &vv) in orow.iter_mut().zip(vrow.iter()) {
                *o += p * vv;
            }
        }
    }
}

/// Linear layer y = x·Wᵀ for weight W [out, in] and x [m, in].
#[inline]
pub fn linear(x: &Mat, w: &Mat) -> Mat {
    matmul_nt(x, w)
}

/// Per-position next-token cross-entropy (nats). `logits` is `[m, vocab]`,
/// targets are the next token within each segment (positions `seq_len-1`,
/// i.e. segment boundaries, are skipped). Returns (sum_nll, count).
pub fn next_token_nll(
    logits: &Mat,
    tokens: &[u32],
    seq_len: usize,
) -> (f64, usize) {
    let m = logits.rows;
    assert_eq!(m, tokens.len());
    let mut sum = 0.0f64;
    let mut count = 0usize;
    let mut probs = vec![0.0f32; logits.cols];
    for t in 0..m {
        if (t + 1) % seq_len == 0 {
            continue; // last position in segment has no target
        }
        let target = tokens[t + 1] as usize;
        probs.copy_from_slice(logits.row(t));
        // log-softmax at the target index.
        let max = probs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let lse: f32 = probs.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
        sum += (lse - logits.at(t, target)) as f64;
        count += 1;
    }
    (sum, count)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn rmsnorm_unit_rms() {
        let mut rng = Rng::new(1);
        let x = Mat::randn(10, 16, 3.0, &mut rng);
        let gain = vec![1.0f32; 16];
        let y = rmsnorm(&x, &gain);
        for r in 0..y.rows {
            let ms: f32 = y.row(r).iter().map(|v| v * v).sum::<f32>() / 16.0;
            assert!((ms - 1.0).abs() < 1e-3, "rms {ms}");
        }
    }

    #[test]
    fn rmsnorm_gain_scales() {
        let x = Mat::from_vec(1, 2, vec![3.0, 4.0]);
        let y1 = rmsnorm(&x, &[1.0, 1.0]);
        let y2 = rmsnorm(&x, &[2.0, 2.0]);
        for c in 0..2 {
            assert!((y2.at(0, c) - 2.0 * y1.at(0, c)).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let mut xs = vec![1.0f32, 3.0, 2.0];
        softmax_inplace(&mut xs);
        let s: f32 = xs.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(xs[1] > xs[2] && xs[2] > xs[0]);
    }

    #[test]
    fn silu_properties() {
        assert_eq!(silu(0.0), 0.0);
        assert!(silu(10.0) > 9.9);
        assert!(silu(-10.0).abs() < 1e-3);
    }

    #[test]
    fn attention_is_causal() {
        // Changing a *future* token must not change earlier outputs.
        let mut rng = Rng::new(2);
        let seq = 8;
        let (q, k, mut v) = (
            Mat::randn(seq, 8, 1.0, &mut rng),
            Mat::randn(seq, 8, 1.0, &mut rng),
            Mat::randn(seq, 8, 1.0, &mut rng),
        );
        let a = causal_attention(&q, &k, &v, 2, seq);
        for c in 0..8 {
            *v.at_mut(seq - 1, c) += 100.0;
        }
        let b = causal_attention(&q, &k, &v, 2, seq);
        for t in 0..seq - 1 {
            for c in 0..8 {
                assert!((a.at(t, c) - b.at(t, c)).abs() < 1e-6, "leak at t={t}");
            }
        }
        // ...but the last position must change.
        assert!((a.at(seq - 1, 0) - b.at(seq - 1, 0)).abs() > 1e-3);
    }

    #[test]
    fn attention_segments_are_independent() {
        let mut rng = Rng::new(3);
        let seq = 4;
        let q = Mat::randn(2 * seq, 8, 1.0, &mut rng);
        let k = Mat::randn(2 * seq, 8, 1.0, &mut rng);
        let v = Mat::randn(2 * seq, 8, 1.0, &mut rng);
        let both = causal_attention(&q, &k, &v, 2, seq);
        // Segment 0 alone must equal rows 0..seq of the combined run.
        let q0 = q.cols_slice(0, 8); // full cols; take first seq rows manually
        let mut q0r = Mat::zeros(seq, 8);
        let mut k0r = Mat::zeros(seq, 8);
        let mut v0r = Mat::zeros(seq, 8);
        for t in 0..seq {
            q0r.row_mut(t).copy_from_slice(q0.row(t));
            k0r.row_mut(t).copy_from_slice(k.row(t));
            v0r.row_mut(t).copy_from_slice(v.row(t));
        }
        let solo = causal_attention(&q0r, &k0r, &v0r, 2, seq);
        for t in 0..seq {
            for c in 0..8 {
                assert!((both.at(t, c) - solo.at(t, c)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn first_position_attends_only_itself() {
        let mut rng = Rng::new(4);
        let q = Mat::randn(4, 4, 1.0, &mut rng);
        let k = Mat::randn(4, 4, 1.0, &mut rng);
        let v = Mat::randn(4, 4, 1.0, &mut rng);
        let a = causal_attention(&q, &k, &v, 1, 4);
        for c in 0..4 {
            assert!((a.at(0, c) - v.at(0, c)).abs() < 1e-5);
        }
    }

    #[test]
    fn attend_one_matches_causal_attention_bitwise() {
        // The decode-path attention must reproduce the full pass to the
        // bit at every position, for both even and ragged head widths.
        let mut rng = Rng::new(5);
        let seq = 8;
        let d = 8;
        for n_heads in [1usize, 2, 4] {
            let q = Mat::randn(seq, d, 1.0, &mut rng);
            let k = Mat::randn(seq, d, 1.0, &mut rng);
            let v = Mat::randn(seq, d, 1.0, &mut rng);
            let full = causal_attention(&q, &k, &v, n_heads, seq);
            for t in 0..seq {
                let mut out = vec![0.0f32; d];
                attend_one(q.row(t), &k, &v, n_heads, t, &mut out);
                for c in 0..d {
                    assert_eq!(
                        out[c].to_bits(),
                        full.at(t, c).to_bits(),
                        "heads={n_heads} t={t} c={c}"
                    );
                }
            }
        }
    }

    #[test]
    fn nll_of_uniform_logits_is_log_vocab() {
        let vocab = 16;
        let seq = 4;
        let logits = Mat::zeros(seq, vocab);
        let tokens = vec![3u32; seq];
        let (sum, count) = next_token_nll(&logits, &tokens, seq);
        assert_eq!(count, seq - 1);
        let nll = sum / count as f64;
        assert!((nll - (vocab as f64).ln()).abs() < 1e-5);
    }

    #[test]
    fn nll_rewards_correct_prediction() {
        let vocab = 8;
        let mut logits = Mat::zeros(2, vocab);
        *logits.at_mut(0, 5) = 20.0; // confidently predicts token 5
        let tokens = vec![0u32, 5u32];
        let (sum, count) = next_token_nll(&logits, &tokens, 2);
        assert_eq!(count, 1);
        assert!(sum < 1e-3, "nll {sum}");
    }
}
