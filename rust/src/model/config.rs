//! Model hyper-parameters. Dimensions are powers of two so QuIP's fast
//! Hadamard rotations apply without padding.

use crate::text::VOCAB_SIZE;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Size {
    /// ≈0.3M block params — the "7B" of our scale ladder.
    TinyS,
    /// ≈1.5M — the "13B".
    TinyM,
    /// ≈7M — the "70B".
    TinyL,
}

impl Size {
    pub fn name(self) -> &'static str {
        match self {
            Size::TinyS => "tiny-s",
            Size::TinyM => "tiny-m",
            Size::TinyL => "tiny-l",
        }
    }

    pub fn from_name(s: &str) -> Option<Size> {
        match s {
            "tiny-s" | "s" => Some(Size::TinyS),
            "tiny-m" | "m" => Some(Size::TinyM),
            "tiny-l" | "l" => Some(Size::TinyL),
            _ => None,
        }
    }

    pub fn all() -> [Size; 3] {
        [Size::TinyS, Size::TinyM, Size::TinyL]
    }

    /// The paper-model each size stands in for (table row labels).
    pub fn paper_analog(self) -> &'static str {
        match self {
            Size::TinyS => "Llama-2-7B",
            Size::TinyM => "Llama-2-13B",
            Size::TinyL => "Llama-2-70B",
        }
    }

    pub fn config(self) -> ModelConfig {
        match self {
            Size::TinyS => ModelConfig::new("tiny-s", 64, 4, 4, 128),
            Size::TinyM => ModelConfig::new("tiny-m", 128, 6, 4, 256),
            Size::TinyL => ModelConfig::new("tiny-l", 256, 8, 8, 512),
        }
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    pub name: String,
    pub dim: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub ffn: usize,
    pub vocab: usize,
    pub seq_len: usize,
}

impl ModelConfig {
    pub fn new(name: &str, dim: usize, n_layers: usize, n_heads: usize, ffn: usize) -> ModelConfig {
        assert_eq!(dim % n_heads, 0, "dim must divide by heads");
        ModelConfig {
            name: name.to_string(),
            dim,
            n_layers,
            n_heads,
            ffn,
            vocab: VOCAB_SIZE,
            seq_len: 128,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.dim / self.n_heads
    }

    /// Parameter count (tied embeddings counted once).
    pub fn n_params(&self) -> usize {
        let block = 2 * self.dim                  // norms
            + 4 * self.dim * self.dim             // q,k,v,o
            + 2 * self.ffn * self.dim             // gate, up
            + self.dim * self.ffn; // down
        self.vocab * self.dim                      // embed (tied head)
            + self.seq_len * self.dim              // positions
            + self.n_layers * block
            + self.dim // final norm
    }

    /// Canonical quantizable layer names in execution order for one block.
    pub fn layer_names(block: usize) -> [String; 7] {
        [
            format!("blocks.{block}.attn.wq"),
            format!("blocks.{block}.attn.wk"),
            format!("blocks.{block}.attn.wv"),
            format!("blocks.{block}.attn.wo"),
            format!("blocks.{block}.mlp.gate"),
            format!("blocks.{block}.mlp.up"),
            format!("blocks.{block}.mlp.down"),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_roundtrip_names() {
        for s in Size::all() {
            assert_eq!(Size::from_name(s.name()), Some(s));
        }
    }

    #[test]
    fn dims_are_pow2_and_divisible() {
        for s in Size::all() {
            let c = s.config();
            assert!(c.dim.is_power_of_two());
            assert!(c.ffn.is_power_of_two());
            assert_eq!(c.dim % c.n_heads, 0);
        }
    }

    #[test]
    fn param_counts_are_ordered() {
        let ns: Vec<usize> = Size::all().iter().map(|s| s.config().n_params()).collect();
        assert!(ns[0] < ns[1] && ns[1] < ns[2], "{ns:?}");
        // tiny-l should be ≈7M.
        assert!(ns[2] > 4_000_000 && ns[2] < 12_000_000, "{}", ns[2]);
    }

    #[test]
    fn layer_names_shape() {
        let names = ModelConfig::layer_names(3);
        assert_eq!(names[0], "blocks.3.attn.wq");
        assert_eq!(names[6], "blocks.3.mlp.down");
    }
}
