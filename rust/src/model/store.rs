//! Model weights: in-memory layout, QTZ (de)serialization, random init.

use super::config::{ModelConfig, Size};
use crate::io::TensorFile;
use crate::linalg::Mat;
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// Weights of one transformer block.
#[derive(Clone, Debug)]
pub struct BlockWeights {
    pub attn_norm: Vec<f32>,
    pub wq: Mat,
    pub wk: Mat,
    pub wv: Mat,
    pub wo: Mat,
    pub mlp_norm: Vec<f32>,
    pub gate: Mat,
    pub up: Mat,
    pub down: Mat,
}

impl BlockWeights {
    /// Access a quantizable linear by short name.
    pub fn linear(&self, short: &str) -> &Mat {
        match short {
            "attn.wq" => &self.wq,
            "attn.wk" => &self.wk,
            "attn.wv" => &self.wv,
            "attn.wo" => &self.wo,
            "mlp.gate" => &self.gate,
            "mlp.up" => &self.up,
            "mlp.down" => &self.down,
            other => panic!("unknown linear '{other}'"),
        }
    }

    pub fn linear_mut(&mut self, short: &str) -> &mut Mat {
        match short {
            "attn.wq" => &mut self.wq,
            "attn.wk" => &mut self.wk,
            "attn.wv" => &mut self.wv,
            "attn.wo" => &mut self.wo,
            "mlp.gate" => &mut self.gate,
            "mlp.up" => &mut self.up,
            "mlp.down" => &mut self.down,
            other => panic!("unknown linear '{other}'"),
        }
    }

    pub const LINEAR_NAMES: [&'static str; 7] = [
        "attn.wq", "attn.wk", "attn.wv", "attn.wo", "mlp.gate", "mlp.up", "mlp.down",
    ];
}

/// A full model: config + weights. Embedding and LM head are tied.
#[derive(Clone, Debug)]
pub struct Model {
    pub cfg: ModelConfig,
    pub embed: Mat,
    pub pos: Mat,
    pub blocks: Vec<BlockWeights>,
    pub final_norm: Vec<f32>,
}

impl Model {
    /// Random init (trainer-compatible scale): weights N(0, 0.02·base) with
    /// residual projections down-scaled by depth, norms at 1.
    pub fn random(cfg: &ModelConfig, seed: u64) -> Model {
        Model::random_scaled(cfg, seed, 1.0)
    }

    /// Random init with all linear weights multiplied by `gain` — used by
    /// the error-growth experiments to push γ‖W‖₂ above 1 (Prop. A.3).
    pub fn random_scaled(cfg: &ModelConfig, seed: u64, gain: f32) -> Model {
        let mut rng = Rng::new(seed);
        let d = cfg.dim;
        let std = 0.02f32 * gain;
        let resid_std = std / (2.0 * cfg.n_layers as f32).sqrt();
        let blocks = (0..cfg.n_layers)
            .map(|_| BlockWeights {
                attn_norm: vec![1.0; d],
                wq: Mat::randn(d, d, std, &mut rng),
                wk: Mat::randn(d, d, std, &mut rng),
                wv: Mat::randn(d, d, std, &mut rng),
                wo: Mat::randn(d, d, resid_std, &mut rng),
                mlp_norm: vec![1.0; d],
                gate: Mat::randn(cfg.ffn, d, std, &mut rng),
                up: Mat::randn(cfg.ffn, d, std, &mut rng),
                down: Mat::randn(d, cfg.ffn, resid_std, &mut rng),
            })
            .collect();
        Model {
            cfg: cfg.clone(),
            embed: Mat::randn(cfg.vocab, d, std, &mut rng),
            pos: Mat::randn(cfg.seq_len, d, std, &mut rng),
            blocks,
            final_norm: vec![1.0; d],
        }
    }

    pub fn size(&self) -> Option<Size> {
        Size::from_name(&self.cfg.name)
    }

    /// Serialize to a QTZ tensor file.
    pub fn to_tensor_file(&self) -> TensorFile {
        let mut tf = TensorFile::new();
        let c = &self.cfg;
        tf.meta = Json::obj();
        tf.meta
            .set("name", Json::Str(c.name.clone()))
            .set("dim", Json::Num(c.dim as f64))
            .set("n_layers", Json::Num(c.n_layers as f64))
            .set("n_heads", Json::Num(c.n_heads as f64))
            .set("ffn", Json::Num(c.ffn as f64))
            .set("vocab", Json::Num(c.vocab as f64))
            .set("seq_len", Json::Num(c.seq_len as f64));
        tf.put_mat("embed", &self.embed);
        tf.put_mat("pos", &self.pos);
        tf.put_f32("final_norm", &[self.final_norm.len()], &self.final_norm);
        for (i, b) in self.blocks.iter().enumerate() {
            let p = format!("blocks.{i}");
            tf.put_f32(&format!("{p}.attn_norm"), &[b.attn_norm.len()], &b.attn_norm);
            tf.put_mat(&format!("{p}.attn.wq"), &b.wq);
            tf.put_mat(&format!("{p}.attn.wk"), &b.wk);
            tf.put_mat(&format!("{p}.attn.wv"), &b.wv);
            tf.put_mat(&format!("{p}.attn.wo"), &b.wo);
            tf.put_f32(&format!("{p}.mlp_norm"), &[b.mlp_norm.len()], &b.mlp_norm);
            tf.put_mat(&format!("{p}.mlp.gate"), &b.gate);
            tf.put_mat(&format!("{p}.mlp.up"), &b.up);
            tf.put_mat(&format!("{p}.mlp.down"), &b.down);
        }
        tf
    }

    pub fn from_tensor_file(tf: &TensorFile) -> Result<Model> {
        let meta = &tf.meta;
        let g = |k: &str| -> Result<usize> {
            meta.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("model meta missing '{k}'"))
        };
        let name = meta
            .get("name")
            .and_then(|v| v.as_str())
            .unwrap_or("custom")
            .to_string();
        let mut cfg = ModelConfig::new(&name, g("dim")?, g("n_layers")?, g("n_heads")?, g("ffn")?);
        cfg.vocab = g("vocab")?;
        cfg.seq_len = g("seq_len")?;
        let blocks = (0..cfg.n_layers)
            .map(|i| -> Result<BlockWeights> {
                let p = format!("blocks.{i}");
                Ok(BlockWeights {
                    attn_norm: tf.get_vec(&format!("{p}.attn_norm"))?,
                    wq: tf.get_mat(&format!("{p}.attn.wq"))?,
                    wk: tf.get_mat(&format!("{p}.attn.wk"))?,
                    wv: tf.get_mat(&format!("{p}.attn.wv"))?,
                    wo: tf.get_mat(&format!("{p}.attn.wo"))?,
                    mlp_norm: tf.get_vec(&format!("{p}.mlp_norm"))?,
                    gate: tf.get_mat(&format!("{p}.mlp.gate"))?,
                    up: tf.get_mat(&format!("{p}.mlp.up"))?,
                    down: tf.get_mat(&format!("{p}.mlp.down"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let model = Model {
            embed: tf.get_mat("embed")?,
            pos: tf.get_mat("pos")?,
            final_norm: tf.get_vec("final_norm")?,
            blocks,
            cfg,
        };
        model.validate()?;
        Ok(model)
    }

    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        self.to_tensor_file().save(path)
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Model> {
        let tf = TensorFile::load(path.as_ref())
            .with_context(|| format!("loading model {}", path.as_ref().display()))?;
        Model::from_tensor_file(&tf)
    }

    /// Shape sanity checks (runs on every load).
    pub fn validate(&self) -> Result<()> {
        let c = &self.cfg;
        let check = |name: &str, m: &Mat, rows: usize, cols: usize| -> Result<()> {
            if (m.rows, m.cols) != (rows, cols) {
                Err(anyhow!(
                    "{name}: expected {rows}x{cols}, got {}x{}",
                    m.rows,
                    m.cols
                ))
            } else {
                Ok(())
            }
        };
        check("embed", &self.embed, c.vocab, c.dim)?;
        check("pos", &self.pos, c.seq_len, c.dim)?;
        if self.blocks.len() != c.n_layers {
            return Err(anyhow!("expected {} blocks, got {}", c.n_layers, self.blocks.len()));
        }
        for (i, b) in self.blocks.iter().enumerate() {
            check(&format!("blocks.{i}.wq"), &b.wq, c.dim, c.dim)?;
            check(&format!("blocks.{i}.wk"), &b.wk, c.dim, c.dim)?;
            check(&format!("blocks.{i}.wv"), &b.wv, c.dim, c.dim)?;
            check(&format!("blocks.{i}.wo"), &b.wo, c.dim, c.dim)?;
            check(&format!("blocks.{i}.gate"), &b.gate, c.ffn, c.dim)?;
            check(&format!("blocks.{i}.up"), &b.up, c.ffn, c.dim)?;
            check(&format!("blocks.{i}.down"), &b.down, c.dim, c.ffn)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> ModelConfig {
        let mut c = ModelConfig::new("unit", 16, 2, 2, 32);
        c.seq_len = 8;
        c
    }

    #[test]
    fn random_model_validates() {
        let m = Model::random(&small_cfg(), 1);
        m.validate().unwrap();
    }

    #[test]
    fn qtz_roundtrip_preserves_everything() {
        let m = Model::random(&small_cfg(), 2);
        let tf = m.to_tensor_file();
        let back = Model::from_tensor_file(&tf).unwrap();
        assert_eq!(back.cfg, m.cfg);
        assert_eq!(back.embed, m.embed);
        assert_eq!(back.blocks[1].down, m.blocks[1].down);
        assert_eq!(back.final_norm, m.final_norm);
    }

    #[test]
    fn disk_roundtrip() {
        let m = Model::random(&small_cfg(), 3);
        let path = std::env::temp_dir().join("qep_model_test.qtz");
        m.save(&path).unwrap();
        let back = Model::load(&path).unwrap();
        assert_eq!(back.blocks[0].wq, m.blocks[0].wq);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn linear_accessors_cover_all_names() {
        let mut m = Model::random(&small_cfg(), 4);
        for name in BlockWeights::LINEAR_NAMES {
            let w = m.blocks[0].linear(name).clone();
            assert!(w.rows > 0);
            m.blocks[0].linear_mut(name).scale(2.0);
            let w2 = m.blocks[0].linear(name);
            assert!((w2.data[0] - 2.0 * w.data[0]).abs() < 1e-6);
        }
    }

    #[test]
    fn validate_catches_shape_errors() {
        let mut m = Model::random(&small_cfg(), 5);
        m.blocks[0].wq = Mat::zeros(3, 3);
        assert!(m.validate().is_err());
    }

    #[test]
    fn scaled_init_scales_spectra() {
        let mut rng = Rng::new(0);
        let a = Model::random_scaled(&small_cfg(), 7, 1.0);
        let b = Model::random_scaled(&small_cfg(), 7, 10.0);
        let na = a.blocks[0].wq.spectral_norm_est(20, &mut rng);
        let nb = b.blocks[0].wq.spectral_norm_est(20, &mut rng);
        assert!(nb > na * 5.0);
    }
}
