//! The subject models: small pre-LN transformers (RMSNorm, multi-head
//! causal attention with learned absolute position embeddings, SwiGLU MLP,
//! tied embedding/LM head). Three sizes stand in for the paper's
//! 7B/13B/70B axis (see DESIGN.md §2). The architecture is mirrored
//! *exactly* by `python/compile/model.py`, so weights trained in JAX load
//! here and the PJRT artifacts agree numerically with this pure-Rust
//! forward (cross-checked in `rust/tests/pjrt_crosscheck.rs`).

pub mod config;
pub mod forward;
pub mod ops;
pub mod store;

pub use config::{ModelConfig, Size};
pub use forward::{BlockCapture, Forward};
pub use store::{BlockWeights, Model};
