//! Continuous-batching request scheduler: admits up to `max_batch`
//! concurrent sessions, runs ONE batched engine step per tick (so the
//! per-block GEMMs amortize across every in-flight session on the
//! persistent pool), and retires finished sequences immediately —
//! freeing their batch slot for the next queued request without
//! stalling the survivors.
//!
//! Determinism: admission is FIFO, the active order is stable under
//! retirement, and — because the engine's rows are bitwise independent
//! of batch composition — a request's generated tokens depend only on
//! its own prompt, never on `max_batch`, queue pressure, retirement
//! timing, or thread count. `tests/serve_engine.rs` gates solo-vs-packed
//! equality directly.
//!
//! Special tokens are handled explicitly, never clamped: sampling EOS
//! finishes a session with [`FinishReason::Eos`]; sampling any other
//! non-text id (BOS/PAD) finishes it with [`FinishReason::Special`] —
//! the previous serving example's `next.min(255)` silently rewrote such
//! ids to byte 255 and corrupted the decoded text.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::text::{is_special, EOS};
use crate::util::pool::Pool;

use super::engine::ServeModel;
use super::kv::KvCache;

/// Scheduler knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Maximum concurrently decoding sessions (the batch width).
    pub max_batch: usize,
    /// Per-request cap on generated tokens.
    pub max_new_tokens: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig { max_batch: 8, max_new_tokens: 64 }
    }
}

/// Why a session stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Sampled the EOS token.
    Eos,
    /// Sampled a non-EOS special token (BOS/PAD) — reported, not clamped.
    Special(u32),
    /// Hit `max_new_tokens` or the model's context length.
    Length,
}

/// A finished request.
#[derive(Clone, Debug)]
pub struct Completion {
    /// Submission id (FIFO order).
    pub id: usize,
    pub prompt_len: usize,
    /// Generated token ids — prompt and terminating special excluded.
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
}

/// One in-flight session. `ids` holds prompt + generated tokens; the
/// invariant between steps is `cache.len() == ids.len() − 1` (the most
/// recently sampled token has not been fed through the model yet).
struct Session {
    id: usize,
    prompt_len: usize,
    ids: Vec<u32>,
    cache: KvCache,
    new_tokens: usize,
}

/// Greedy continuous-batching scheduler over one [`ServeModel`].
pub struct Scheduler {
    model: ServeModel,
    pool: Pool,
    cfg: ServeConfig,
    queue: VecDeque<(usize, Vec<u32>)>,
    active: Vec<Session>,
    finished: Vec<Completion>,
    next_id: usize,
    steps: usize,
    tokens_generated: usize,
}

impl Scheduler {
    pub fn new(model: ServeModel, cfg: ServeConfig, pool: Pool) -> Scheduler {
        assert!(cfg.max_batch >= 1, "max_batch must be at least 1");
        Scheduler {
            model,
            pool,
            cfg,
            queue: VecDeque::new(),
            active: Vec::new(),
            finished: Vec::new(),
            next_id: 0,
            steps: 0,
            tokens_generated: 0,
        }
    }

    /// Enqueue a prompt, validating it up front so a bad request is
    /// refused here — with the offending token named — instead of
    /// aborting the whole batch deep inside the engine. Returns the
    /// request id.
    pub fn submit(&mut self, prompt: &[u32]) -> Result<usize> {
        let c = &self.model.cfg;
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if prompt.len() > c.seq_len {
            bail!("prompt length {} exceeds context length {}", prompt.len(), c.seq_len);
        }
        for (pos, &tok) in prompt.iter().enumerate() {
            if tok as usize >= c.vocab {
                bail!("out-of-vocab token {tok} at position {pos} (vocab size {})", c.vocab);
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back((id, prompt.to_vec()));
        Ok(id)
    }

    /// Admit queued requests into free batch slots: prefill each prompt
    /// and sample its first token. A request that finishes on that very
    /// token (EOS, special, or a context-filling prompt) retires without
    /// ever occupying a decode slot.
    fn admit(&mut self) {
        while self.active.len() < self.cfg.max_batch {
            let Some((id, prompt)) = self.queue.pop_front() else { break };
            let mut cache = self.model.new_cache();
            let logits = self.model.prefill(&mut cache, &prompt, &self.pool);
            let next = super::argmax(logits.row(logits.rows - 1)) as u32;
            let prompt_len = prompt.len();
            let mut sess = Session { id, prompt_len, ids: prompt, cache, new_tokens: 0 };
            match absorb(&mut sess, next, &self.cfg, self.model.cfg.seq_len) {
                Some(fin) => self.retire(sess, fin),
                None => self.active.push(sess),
            }
        }
    }

    fn retire(&mut self, sess: Session, finish: FinishReason) {
        let tokens = sess.ids[sess.prompt_len..].to_vec();
        self.tokens_generated += tokens.len();
        self.finished.push(Completion {
            id: sess.id,
            prompt_len: sess.prompt_len,
            tokens,
            finish,
        });
    }

    /// One scheduler tick: admit into free slots, then one batched
    /// decode step across every active session, absorbing each row's
    /// sampled token and retiring finished sessions in place. Returns
    /// `false` when no work remains.
    pub fn step(&mut self) -> bool {
        self.admit();
        if self.active.is_empty() {
            return !self.queue.is_empty();
        }
        let toks: Vec<u32> = self.active.iter().map(|s| *s.ids.last().unwrap()).collect();
        let mut caches: Vec<&mut KvCache> =
            self.active.iter_mut().map(|s| &mut s.cache).collect();
        let logits = self.model.decode_step_batch(&mut caches, &toks, &self.pool);
        drop(caches);
        self.steps += 1;
        let fins: Vec<Option<FinishReason>> = self
            .active
            .iter_mut()
            .enumerate()
            .map(|(i, s)| {
                let next = super::argmax(logits.row(i)) as u32;
                absorb(s, next, &self.cfg, self.model.cfg.seq_len)
            })
            .collect();
        // Stable retirement: survivors keep their relative (FIFO) order.
        let retiring: Vec<(Session, FinishReason)> = {
            let mut survivors = Vec::with_capacity(self.active.len());
            let mut out = Vec::new();
            for (s, fin) in self.active.drain(..).zip(fins) {
                match fin {
                    Some(f) => out.push((s, f)),
                    None => survivors.push(s),
                }
            }
            self.active = survivors;
            out
        };
        for (s, f) in retiring {
            self.retire(s, f);
        }
        !self.active.is_empty() || !self.queue.is_empty()
    }

    /// Drive to completion and return all completions in submission
    /// order.
    pub fn run(&mut self) -> Vec<Completion> {
        while self.step() {}
        let mut out = std::mem::take(&mut self.finished);
        out.sort_by_key(|c| c.id);
        self.steps = 0;
        self.tokens_generated = 0;
        out
    }

    /// Requests waiting for a batch slot.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Sessions currently decoding.
    pub fn in_flight(&self) -> usize {
        self.active.len()
    }

    /// Batched decode steps taken since the last [`Self::run`].
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Tokens generated (across retired sessions) since the last
    /// [`Self::run`].
    pub fn tokens_generated(&self) -> usize {
        self.tokens_generated
    }
}

/// Fold one sampled token into a session; `Some(reason)` retires it.
/// Specials end the session explicitly (satellite of the `next.min(255)`
/// clamp bug); text tokens extend it until `max_new_tokens` or the
/// context fills.
fn absorb(
    s: &mut Session,
    next: u32,
    cfg: &ServeConfig,
    seq_len: usize,
) -> Option<FinishReason> {
    if next == EOS {
        return Some(FinishReason::Eos);
    }
    if is_special(next) {
        return Some(FinishReason::Special(next));
    }
    s.ids.push(next);
    s.new_tokens += 1;
    if s.new_tokens >= cfg.max_new_tokens {
        return Some(FinishReason::Length);
    }
    if s.cache.len() >= seq_len {
        // The new token has no context slot left to be fed into.
        return Some(FinishReason::Length);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Model, ModelConfig};

    fn sched(max_batch: usize, max_new: usize) -> Scheduler {
        let mut cfg = ModelConfig::new("unit", 16, 2, 2, 32);
        cfg.seq_len = 8;
        let m = Model::random(&cfg, 1);
        Scheduler::new(
            ServeModel::from_model(&m),
            ServeConfig { max_batch, max_new_tokens: max_new },
            Pool::serial(),
        )
    }

    #[test]
    fn submit_rejects_bad_requests_with_reasons() {
        let mut s = sched(2, 4);
        let err = s.submit(&[]).unwrap_err().to_string();
        assert!(err.contains("empty prompt"), "{err}");
        let err = s.submit(&[1; 9]).unwrap_err().to_string();
        assert!(err.contains("exceeds context length"), "{err}");
        let err = s.submit(&[5, 100_000, 7]).unwrap_err().to_string();
        assert!(err.contains("out-of-vocab token 100000 at position 1"), "{err}");
        // Valid prompts get FIFO ids.
        assert_eq!(s.submit(&[1, 2]).unwrap(), 0);
        assert_eq!(s.submit(&[3]).unwrap(), 1);
        assert_eq!(s.queued(), 2);
    }

    #[test]
    fn completions_respect_limits_and_order() {
        let mut s = sched(2, 3);
        for p in [&[10u32, 20][..], &[30u32][..], &[40u32, 50, 60][..]] {
            s.submit(p).unwrap();
        }
        let done = s.run();
        assert_eq!(done.len(), 3);
        for (i, c) in done.iter().enumerate() {
            assert_eq!(c.id, i, "submission order");
            assert!(c.tokens.len() <= 3);
            assert!(c.tokens.iter().all(|&t| t < 256), "specials never leak");
            match c.finish {
                FinishReason::Length => assert!(
                    c.tokens.len() == 3 || c.prompt_len + c.tokens.len() >= 8
                ),
                FinishReason::Eos | FinishReason::Special(_) => {}
            }
        }
        assert_eq!(s.in_flight(), 0);
        assert_eq!(s.queued(), 0);
    }

    #[test]
    fn batch_width_never_changes_outputs() {
        let prompts: Vec<Vec<u32>> =
            vec![vec![10, 20, 30], vec![40], vec![50, 60], vec![70, 80, 90, 100]];
        let mut reference: Option<Vec<(usize, Vec<u32>, FinishReason)>> = None;
        for max_batch in [1usize, 2, 4] {
            let mut s = sched(max_batch, 4);
            for p in &prompts {
                s.submit(p).unwrap();
            }
            let got: Vec<(usize, Vec<u32>, FinishReason)> =
                s.run().into_iter().map(|c| (c.id, c.tokens, c.finish)).collect();
            match &reference {
                None => reference = Some(got),
                Some(want) => assert_eq!(&got, want, "max_batch={max_batch}"),
            }
        }
    }
}
