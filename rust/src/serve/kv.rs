//! Per-session KV-cache: the K and V projections of every processed
//! position, per block, so a decode step touches one new row per layer
//! instead of recomputing the whole segment (O(t·d) attention work per
//! token instead of an O(t·d²) re-forward).
//!
//! Storage is preallocated at `seq_len` rows per layer — sessions are
//! bounded by the model's context length and retire when they reach it
//! (no sliding-window rebuilds), so the cache never reallocates and row
//! writes are cheap `copy_from_slice`s. Rows at positions `>= len()` are
//! uninitialized-by-convention (zeros); attention only ever reads
//! `0..=t`, mirroring the causal mask of the full pass.

use crate::linalg::Mat;

/// KV rows for one session across all blocks. `len()` positions are
/// valid in every layer; the engine writes each layer's new rows at the
/// *same* positions during a step (one row for decode, the whole prompt
/// for prefill) and then calls [`KvCache::advance`] once, so the
/// per-layer views stay mutually consistent mid-step.
#[derive(Clone, Debug)]
pub struct KvCache {
    k: Vec<Mat>,
    v: Vec<Mat>,
    len: usize,
}

impl KvCache {
    /// Empty cache for `n_layers` blocks with room for `seq_len`
    /// positions of `dim`-wide K/V rows.
    pub fn new(n_layers: usize, seq_len: usize, dim: usize) -> KvCache {
        KvCache {
            k: (0..n_layers).map(|_| Mat::zeros(seq_len, dim)).collect(),
            v: (0..n_layers).map(|_| Mat::zeros(seq_len, dim)).collect(),
            len: 0,
        }
    }

    /// Positions cached so far (uniform across layers).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum positions this cache can hold (the model's seq_len).
    pub fn capacity(&self) -> usize {
        self.k.first().map_or(0, |m| m.rows)
    }

    pub fn n_layers(&self) -> usize {
        self.k.len()
    }

    /// Write layer `layer`'s K/V rows for position `t`. Writes may land
    /// anywhere in `len()..capacity()` before being committed — prefill
    /// stages a whole prompt's rows per layer while `len()` is still 0 —
    /// and become visible to `len()` only via [`Self::advance`].
    pub fn write_row(&mut self, layer: usize, t: usize, krow: &[f32], vrow: &[f32]) {
        debug_assert!(
            t >= self.len && t < self.capacity(),
            "write_row at {t} outside staging range {}..{}",
            self.len,
            self.capacity()
        );
        self.k[layer].row_mut(t).copy_from_slice(krow);
        self.v[layer].row_mut(t).copy_from_slice(vrow);
    }

    /// The K and V matrices for one layer (rows `0..len()` valid, plus
    /// any row written this step).
    pub fn layer(&self, layer: usize) -> (&Mat, &Mat) {
        (&self.k[layer], &self.v[layer])
    }

    /// Commit `n` newly written positions.
    pub fn advance(&mut self, n: usize) {
        self.len += n;
        debug_assert!(self.len <= self.capacity());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_roundtrip_and_len_advances() {
        let mut c = KvCache::new(2, 4, 3);
        assert_eq!((c.n_layers(), c.capacity(), c.len()), (2, 4, 0));
        assert!(c.is_empty());
        c.write_row(0, 0, &[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]);
        c.write_row(1, 0, &[7.0, 8.0, 9.0], &[1.5, 2.5, 3.5]);
        c.advance(1);
        assert_eq!(c.len(), 1);
        let (k0, v0) = c.layer(0);
        assert_eq!(k0.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(v0.row(0), &[4.0, 5.0, 6.0]);
        let (k1, _) = c.layer(1);
        assert_eq!(k1.row(0), &[7.0, 8.0, 9.0]);
    }
}
