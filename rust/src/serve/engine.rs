//! The serving forward engine: prefill + batched incremental decode over
//! per-session KV-caches, with every linear held either dense (f32) or
//! packed low-bit (routed through the fused dequantize×GEMM kernels in
//! `crate::linalg::qgemm`).
//!
//! Batching model: one decode step gathers the current token of every
//! in-flight session into an `[n, dim]` activation matrix, so the seven
//! per-block linears each run as ONE pooled GEMM across the whole batch —
//! the continuous-batching scheduler (`super::sched`) keeps `n` full as
//! sessions retire. Attention stays per-session (each has its own cache
//! and position) and is cheap relative to the linears.
//!
//! Determinism: every row of the batch is computed with the canonical
//! per-element operation order (skinny and wide GEMM paths share it, all
//! other ops are row-independent), so a session's logits are **bitwise
//! independent of batch composition** — the same prompt yields the same
//! tokens whether it runs alone or packed with fifteen strangers, for any
//! thread count. `tests/serve_engine.rs` and
//! `tests/parallel_equivalence.rs` gate this.

use crate::linalg::{matmul_nt_with, qgemm_nt_with, Mat};
use crate::model::config::ModelConfig;
use crate::model::forward::check_token;
use crate::model::ops::{attend_one, rmsnorm, swiglu};
use crate::model::Model;
use crate::qep::LowRankAdjunct;
use crate::quant::{QuantConfig, QuantizedTensor};
use crate::util::pool::Pool;
use std::collections::BTreeMap;

use super::kv::KvCache;

/// The base storage of one serving weight matrix: dense f32, or packed
/// codes + per-group grids consumed in place by the fused kernel.
#[derive(Clone, Debug)]
pub enum WeightKind {
    Dense(Mat),
    Quant(QuantizedTensor),
}

/// One serving weight matrix plus its optional low-rank error adjunct
/// (`W_eff = W + U·V`, kept factored — see `crate::qep::lowrank`).
#[derive(Clone, Debug)]
pub struct LinearW {
    pub weight: WeightKind,
    pub adjunct: Option<LowRankAdjunct>,
}

impl LinearW {
    pub fn dense(w: Mat) -> LinearW {
        LinearW { weight: WeightKind::Dense(w), adjunct: None }
    }

    pub fn quant(q: QuantizedTensor) -> LinearW {
        LinearW { weight: WeightKind::Quant(q), adjunct: None }
    }

    /// Attach a low-rank adjunct (`None` and rank-0 both mean "none").
    pub fn with_adjunct(mut self, adjunct: Option<LowRankAdjunct>) -> LinearW {
        self.adjunct = adjunct.filter(|a| a.rank() > 0);
        self
    }

    /// `x·W_effᵀ` on `pool`: the base GEMM (dense or fused dequant×GEMM),
    /// then the factored adjunct `y += (x·Vᵀ)·Uᵀ`. Every piece is
    /// bitwise-identical for every thread count; the `Quant` arm is
    /// additionally bitwise-identical to densifying first (`qgemm`'s
    /// contract), and the adjunct path is shared verbatim with the dense
    /// twin — so packed + adjunct ≡ dense-corrected twin, bit for bit.
    fn apply(&self, x: &Mat, pool: &Pool) -> Mat {
        let mut y = match &self.weight {
            WeightKind::Dense(w) => matmul_nt_with(x, w, pool),
            WeightKind::Quant(q) => qgemm_nt_with(x, &q.view(), pool),
        };
        if let Some(adj) = &self.adjunct {
            adj.apply_with(x, &mut y, pool);
        }
        y
    }

    /// Dense twin: `Quant` weights are materialized via `dequantize()`;
    /// the adjunct (if any) is carried over *in factored form*, so the
    /// twin runs the identical adjunct code path. Serving the twin
    /// produces bit-identical logits (and therefore identical
    /// generations) to the packed path — the cross-check the serving
    /// example runs end-to-end.
    fn dequantized(&self) -> LinearW {
        let weight = match &self.weight {
            WeightKind::Dense(w) => WeightKind::Dense(w.clone()),
            WeightKind::Quant(q) => WeightKind::Dense(q.dequantize()),
        };
        LinearW { weight, adjunct: self.adjunct.clone() }
    }
}

/// One block's serving weights (norms always f32).
#[derive(Clone, Debug)]
pub struct ServeBlock {
    pub attn_norm: Vec<f32>,
    pub wq: LinearW,
    pub wk: LinearW,
    pub wv: LinearW,
    pub wo: LinearW,
    pub mlp_norm: Vec<f32>,
    pub gate: LinearW,
    pub up: LinearW,
    pub down: LinearW,
}

/// A model prepared for serving. Embedding / position / tied logits head
/// stay dense f32 (they are a sliver of the weight traffic at this vocab
/// size); the seven per-block linears carry the quantization.
#[derive(Clone, Debug)]
pub struct ServeModel {
    pub cfg: ModelConfig,
    pub embed: Mat,
    pub pos: Mat,
    pub blocks: Vec<ServeBlock>,
    pub final_norm: Vec<f32>,
}

impl ServeModel {
    /// Dense f32 serving weights (the baseline engine).
    pub fn from_model(m: &Model) -> ServeModel {
        Self::build(m, |_, _, w| LinearW::dense(w.clone()))
    }

    /// Pack every block linear onto `cfg`'s grid (RTN) for the fused
    /// low-bit path. Apply this to a pipeline-quantized model — its
    /// weights already sit on grid points, so packing is lossless in
    /// practice — or to a raw model for a pure-RTN serving baseline.
    pub fn quantized(m: &Model, cfg: &QuantConfig) -> ServeModel {
        Self::build(m, |_, _, w| LinearW::quant(QuantizedTensor::from_mat(w, cfg)))
    }

    /// Pack every block linear onto `cfg`'s grid and attach each layer's
    /// low-rank adjunct (keys are canonical `blocks.{i}.{short}` names,
    /// exactly as `qep::load_with_adjuncts` returns them). `m` must hold
    /// the *on-grid base* weights — the adjunct is applied at serve time,
    /// not folded in.
    pub fn quantized_with_adjuncts(
        m: &Model,
        cfg: &QuantConfig,
        adjuncts: &BTreeMap<String, LowRankAdjunct>,
    ) -> ServeModel {
        Self::build(m, |bi, short, w| {
            let adj = adjuncts.get(&format!("blocks.{bi}.{short}")).cloned();
            LinearW::quant(QuantizedTensor::from_mat(w, cfg)).with_adjunct(adj)
        })
    }

    /// Pack each block linear onto its *own* grid: `bits` maps canonical
    /// layer names (`blocks.{i}.{short}`, exactly the `layer_bits` table
    /// a mixed-precision `.qtz` carries in its meta) to that layer's bit
    /// width; layers absent from the map fall back to `cfg.bits`. The
    /// group length comes from `cfg` everywhere. Packing is per-tensor,
    /// so mixed widths across layers need no engine changes — each fused
    /// dequant×GEMM reads its own tensor's grid.
    pub fn quantized_per_layer(
        m: &Model,
        cfg: &QuantConfig,
        bits: &BTreeMap<String, u32>,
    ) -> ServeModel {
        Self::build(m, |bi, short, w| {
            let lcfg = match bits.get(&format!("blocks.{bi}.{short}")) {
                Some(&b) => QuantConfig { bits: b, group: cfg.group },
                None => *cfg,
            };
            LinearW::quant(QuantizedTensor::from_mat(w, &lcfg))
        })
    }

    fn build(m: &Model, mk: impl Fn(usize, &str, &Mat) -> LinearW) -> ServeModel {
        ServeModel {
            cfg: m.cfg.clone(),
            embed: m.embed.clone(),
            pos: m.pos.clone(),
            blocks: m
                .blocks
                .iter()
                .enumerate()
                .map(|(bi, b)| ServeBlock {
                    attn_norm: b.attn_norm.clone(),
                    wq: mk(bi, "attn.wq", &b.wq),
                    wk: mk(bi, "attn.wk", &b.wk),
                    wv: mk(bi, "attn.wv", &b.wv),
                    wo: mk(bi, "attn.wo", &b.wo),
                    mlp_norm: b.mlp_norm.clone(),
                    gate: mk(bi, "mlp.gate", &b.gate),
                    up: mk(bi, "mlp.up", &b.up),
                    down: mk(bi, "mlp.down", &b.down),
                })
                .collect(),
            final_norm: m.final_norm.clone(),
        }
    }

    /// Dense twin of this engine (packed linears densified). Bitwise the
    /// same logits as `self` — the serving cross-check.
    pub fn dequantized(&self) -> ServeModel {
        ServeModel {
            cfg: self.cfg.clone(),
            embed: self.embed.clone(),
            pos: self.pos.clone(),
            blocks: self
                .blocks
                .iter()
                .map(|b| ServeBlock {
                    attn_norm: b.attn_norm.clone(),
                    wq: b.wq.dequantized(),
                    wk: b.wk.dequantized(),
                    wv: b.wv.dequantized(),
                    wo: b.wo.dequantized(),
                    mlp_norm: b.mlp_norm.clone(),
                    gate: b.gate.dequantized(),
                    up: b.up.dequantized(),
                    down: b.down.dequantized(),
                })
                .collect(),
            final_norm: self.final_norm.clone(),
        }
    }

    /// Fresh KV-cache sized for this model.
    pub fn new_cache(&self) -> KvCache {
        KvCache::new(self.blocks.len(), self.cfg.seq_len, self.cfg.dim)
    }

    /// Process a whole prompt into an empty cache, returning the
    /// `[prompt.len(), vocab]` logits (row `i` = logits after prompt
    /// token `i`). Row-for-row bit-identical to feeding the prompt
    /// through [`Self::decode_step_batch`] one token at a time — prefill
    /// is just the wide-GEMM formulation of the same chains.
    pub fn prefill(&self, cache: &mut KvCache, prompt: &[u32], pool: &Pool) -> Mat {
        let c = &self.cfg;
        assert!(cache.is_empty(), "prefill into a non-empty cache");
        assert!(!prompt.is_empty(), "prefill: empty prompt");
        assert!(
            prompt.len() <= c.seq_len,
            "prefill: prompt length {} exceeds seq_len {}",
            prompt.len(),
            c.seq_len
        );
        let l = prompt.len();
        let mut x = Mat::zeros(l, c.dim);
        for (t, &tok) in prompt.iter().enumerate() {
            check_token(tok, t, c.vocab);
            embed_row(self, tok, t, x.row_mut(t));
        }
        for (li, b) in self.blocks.iter().enumerate() {
            let attn_in = rmsnorm(&x, &b.attn_norm);
            let q = b.wq.apply(&attn_in, pool);
            let k = b.wk.apply(&attn_in, pool);
            let v = b.wv.apply(&attn_in, pool);
            for t in 0..l {
                cache.write_row(li, t, k.row(t), v.row(t));
            }
            let mut ctx = Mat::zeros(l, c.dim);
            let (kc, vc) = cache.layer(li);
            for t in 0..l {
                attend_one(q.row(t), kc, vc, c.n_heads, t, ctx.row_mut(t));
            }
            x = self.finish_block(b, &x, &ctx, pool);
        }
        cache.advance(l);
        self.head(&x, pool)
    }

    /// One batched decode step: session `i` feeds `toks[i]` at its own
    /// cache frontier. Returns `[n, vocab]` logits, row per session.
    /// Each row is bitwise independent of the other rows (batch
    /// composition, ordering, and thread count never change a session's
    /// bits). Panics if any cache is full — callers retire full sessions
    /// first ([`super::sched`]).
    pub fn decode_step_batch(
        &self,
        caches: &mut [&mut KvCache],
        toks: &[u32],
        pool: &Pool,
    ) -> Mat {
        let c = &self.cfg;
        let n = toks.len();
        assert_eq!(caches.len(), n, "one cache per token");
        let mut x = Mat::zeros(n, c.dim);
        for (i, (&tok, cache)) in toks.iter().zip(caches.iter()).enumerate() {
            let t = cache.len();
            assert!(t < c.seq_len, "decode: session {i} context full ({t} == seq_len)");
            assert_eq!(cache.n_layers(), self.blocks.len(), "cache/model layer mismatch");
            check_token(tok, t, c.vocab);
            embed_row(self, tok, t, x.row_mut(i));
        }
        for (li, b) in self.blocks.iter().enumerate() {
            let attn_in = rmsnorm(&x, &b.attn_norm);
            let q = b.wq.apply(&attn_in, pool);
            let k = b.wk.apply(&attn_in, pool);
            let v = b.wv.apply(&attn_in, pool);
            let mut ctx = Mat::zeros(n, c.dim);
            for i in 0..n {
                let cache = &mut *caches[i];
                let t = cache.len();
                cache.write_row(li, t, k.row(i), v.row(i));
                let (kc, vc) = cache.layer(li);
                attend_one(q.row(i), kc, vc, c.n_heads, t, ctx.row_mut(i));
            }
            x = self.finish_block(b, &x, &ctx, pool);
        }
        for cache in caches.iter_mut() {
            cache.advance(1);
        }
        self.head(&x, pool)
    }

    /// Residual + MLP tail shared by prefill and decode (identical op
    /// order to `Forward::block`).
    fn finish_block(&self, b: &ServeBlock, x: &Mat, ctx: &Mat, pool: &Pool) -> Mat {
        let attn_out = b.wo.apply(ctx, pool);
        let x1 = x.add(&attn_out);
        let mlp_in = rmsnorm(&x1, &b.mlp_norm);
        let g = b.gate.apply(&mlp_in, pool);
        let u = b.up.apply(&mlp_in, pool);
        let mlp_act = swiglu(&g, &u);
        let mlp_out = b.down.apply(&mlp_act, pool);
        x1.add(&mlp_out)
    }

    /// Tied logits head: rmsnorm then `x·Embedᵀ` (always dense).
    fn head(&self, x: &Mat, pool: &Pool) -> Mat {
        let h = rmsnorm(x, &self.final_norm);
        matmul_nt_with(&h, &self.embed, pool)
    }
}

/// Token + position embedding for one row (the decode-path twin of
/// `Forward::embed`'s per-token body).
fn embed_row(m: &ServeModel, tok: u32, t: usize, out: &mut [f32]) {
    let e = m.embed.row(tok as usize);
    let p = m.pos.row(t);
    for (i, o) in out.iter_mut().enumerate() {
        *o = e[i] + p[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Forward, ModelConfig};
    use crate::util::rng::Rng;

    fn small() -> (ModelConfig, Model) {
        let mut cfg = ModelConfig::new("unit", 16, 2, 2, 32);
        cfg.seq_len = 8;
        let m = Model::random(&cfg, 1);
        (cfg, m)
    }

    fn tokens(n: usize, seed: u64) -> Vec<u32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.below(200) as u32).collect()
    }

    #[test]
    fn dense_engine_matches_forward_bitwise() {
        let (cfg, m) = small();
        let sm = ServeModel::from_model(&m);
        let f = Forward::new(&cfg);
        let pool = Pool::serial();
        let toks = tokens(cfg.seq_len, 2);
        let full = f.forward(&m, &toks);
        // Prefill path.
        let mut cache = sm.new_cache();
        let pre = sm.prefill(&mut cache, &toks, &pool);
        assert_eq!(pre, full);
        // Decode path, one token at a time.
        let mut cache = sm.new_cache();
        for (t, &tok) in toks.iter().enumerate() {
            let mut caches = [&mut cache];
            let row = sm.decode_step_batch(&mut caches, &[tok], &pool);
            assert_eq!(row.row(0), full.row(t), "position {t}");
        }
    }

    #[test]
    fn quantized_engine_matches_its_dense_twin_bitwise() {
        let (cfg, m) = small();
        let qm = ServeModel::quantized(&m, &QuantConfig::int_group(4, 8));
        let dm = qm.dequantized();
        let pool = Pool::new(3);
        let toks = tokens(6, 3);
        let mut qc = qm.new_cache();
        let mut dc = dm.new_cache();
        let ql = qm.prefill(&mut qc, &toks, &pool);
        let dl = dm.prefill(&mut dc, &toks, &pool);
        assert_eq!(ql, dl);
        let next = 42u32;
        let q2 = qm.decode_step_batch(&mut [&mut qc], &[next], &pool);
        let d2 = dm.decode_step_batch(&mut [&mut dc], &[next], &pool);
        assert_eq!(q2, d2);
        let _ = cfg;
    }

    #[test]
    fn adjunct_carrying_engine_matches_dense_corrected_twin() {
        let (_cfg, m) = small();
        let mut adjuncts = BTreeMap::new();
        adjuncts.insert(
            "blocks.0.attn.wq".to_string(),
            crate::qep::adjunct_from_residual(
                &Mat::randn(16, 16, 0.05, &mut Rng::new(4)),
                None,
                2,
                1.0,
                9,
                &Pool::serial(),
            ),
        );
        let qm = ServeModel::quantized_with_adjuncts(&m, &QuantConfig::int_group(4, 8), &adjuncts);
        assert!(qm.blocks[0].wq.adjunct.is_some());
        assert!(qm.blocks[0].wk.adjunct.is_none());
        let dm = qm.dequantized();
        let pool = Pool::new(3);
        let toks = tokens(6, 5);
        let mut qc = qm.new_cache();
        let mut dc = dm.new_cache();
        assert_eq!(qm.prefill(&mut qc, &toks, &pool), dm.prefill(&mut dc, &toks, &pool));
        let q2 = qm.decode_step_batch(&mut [&mut qc], &[7], &pool);
        let d2 = dm.decode_step_batch(&mut [&mut dc], &[7], &pool);
        assert_eq!(q2, d2);
    }

    #[test]
    #[should_panic(expected = "out-of-vocab token")]
    fn decode_rejects_out_of_vocab_tokens() {
        let (_cfg, m) = small();
        let sm = ServeModel::from_model(&m);
        let mut cache = sm.new_cache();
        let pool = Pool::serial();
        sm.decode_step_batch(&mut [&mut cache], &[100_000], &pool);
    }
}
