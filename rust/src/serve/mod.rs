//! Batched quantized serving: KV-cached incremental decode
//! ([`kv`] + [`engine`]) under a continuous-batching request scheduler
//! ([`sched`]), with block linears served either dense (f32) or packed
//! low-bit through the fused dequantize×GEMM kernels
//! (`crate::linalg::qgemm`).
//!
//! The whole stack upholds the repo's bit-identity contract end-to-end:
//! a decode step equals the full-recompute forward, the fused quantized
//! path equals dequantize-then-matmul, and a session's generated tokens
//! are independent of batch composition and thread count. See
//! `tests/serve_engine.rs`, `tests/parallel_equivalence.rs`, and
//! `benches/serve_throughput.rs` for the gates and the tokens/sec
//! numbers (docs/PERFORMANCE.md §6).

pub mod engine;
pub mod kv;
pub mod sched;

pub use engine::{LinearW, ServeBlock, ServeModel, WeightKind};
pub use kv::KvCache;
pub use sched::{Completion, FinishReason, Scheduler, ServeConfig};

/// Greedy argmax over a logits row with a NaN-losing total-order fold:
/// strictly-greater comparisons from `(index 0, −∞)`, so a NaN logit
/// never wins (every comparison against NaN is false), ties keep the
/// lowest index, and an all-NaN or empty row returns 0. This is the one
/// shared argmax for everything that samples from logits — the previous
/// serving example's `partial_cmp(..).unwrap()` panicked outright on a
/// NaN logit.
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::argmax;

    #[test]
    fn argmax_is_nan_safe_with_lowest_index_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[f32::NAN, 1.0, 1.0]), 1, "NaN loses, tie keeps lowest");
        assert_eq!(argmax(&[1.0, f32::NAN, 2.0]), 2);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0, "all-NaN falls back to 0");
        assert_eq!(argmax(&[]), 0, "empty falls back to 0");
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), 0);
        assert_eq!(argmax(&[-2.0, -1.0, -1.0]), 1);
    }
}
