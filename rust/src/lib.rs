//! # QEP — Quantization Error Propagation
//!
//! Production reproduction of *“Quantization Error Propagation: Revisiting
//! Layer-Wise Post-Training Quantization”* (Arai & Ichikawa, NeurIPS 2025)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the quantization *coordinator*: calibration
//!   stream management, Hessian accumulation, the QEP weight correction, and
//!   from-scratch implementations of RTN / GPTQ / AWQ / QuIP plus the
//!   LQER/QERA low-rank error adjuncts ([`qep::lowrank`], backed by the
//!   deterministic SVD kernel in [`linalg::svd`]), the full
//!   evaluation harness (perplexity, zero-shot tasks, error-accumulation
//!   diagnostics) and a PJRT runtime that executes AOT-lowered JAX/Pallas
//!   artifacts with Python never on the request path.
//! * **Layer 2 (python/compile/model.py)** — the JAX transformer used for
//!   build-time training and AOT export to HLO text.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels (fused
//!   dequantize×matmul, Hessian accumulation) lowered into the same
//!   artifacts (interpret mode on CPU).
//!
//! Quick tour:
//!
//! ```no_run
//! use qep::model::Model;
//! use qep::quant::{QuantConfig, Method};
//! use qep::coordinator::{Pipeline, PipelineConfig};
//!
//! let model = Model::load("artifacts/tiny-s.qtz").unwrap();
//! let cfg = PipelineConfig {
//!     quant: QuantConfig::int(3),
//!     method: Method::Gptq,
//!     qep_alpha: Some(0.5),
//!     ..Default::default()
//! };
//! let calib = qep::text::Corpus::generate(qep::text::Flavor::C4, 64 * 2048, 0);
//! let quantized = Pipeline::new(cfg).run(&model, &calib.tokens).unwrap();
//! ```
//!
//! # Parallelism contract
//!
//! Everything hot runs on a dependency-free **persistent worker pool**
//! ([`util::pool`] — workers spawn once, park between dispatches, and
//! self-schedule chunks off a lock-free cursor): GEMM/Hessian kernels
//! ([`linalg::par`]), the blocked Cholesky/SPD engine ([`linalg::chol`],
//! whose trailing SYRK update runs through the register-tile
//! micro-kernels in [`linalg::micro`]), per-layer pipeline fan-out
//! ([`coordinator`]), GPTQ row sweeps, batched perplexity/task evaluation
//! ([`eval`]), sharded experiment sweeps ([`exp`] — staged
//! enumerate→run→render, distributable across processes/machines via
//! `repro exp --shard i/N` + `repro exp merge`, or live-dispatched over
//! TCP by the fleet coordinator in [`fleet`]), and the batched serving
//! engine ([`serve`] — KV-cached continuous batching whose quantized
//! linears run the fused dequantize×GEMM kernels in [`linalg::qgemm`]).
//! The invariant every one of these upholds — and that new code MUST
//! uphold — is:
//!
//! > **Results are bit-identical for every thread count** (and, for the
//! > blocked SPD engine, every block size; for the micro-kernels, every
//! > tile width; for sharded sweeps, every shard split; for serving,
//! > every batch composition). Workers own
//! > disjoint output regions, every floating-point reduction has a fixed
//! > order, and all randomness derives from stable names
//! > ([`util::fnv1a`]), never from scheduling.
//!
//! `rust/tests/parallel_equivalence.rs` gates the contract (including
//! persistent-pool vs scoped-spawn-baseline equivalence); the
//! `--threads N` CLI knob (0 = all cores; 1 = fully inline, no workers
//! ever spawned) therefore only trades wall-clock time. See `README.md`,
//! `docs/ARCHITECTURE.md`, and `docs/PERFORMANCE.md` at the repo root
//! for the contributor-facing tour and the benchmarking guide.
//!
//! # Feature flags
//!
//! * `pjrt` (off by default) — the real PJRT executor in [`runtime`],
//!   wrapping the vendored `xla` crate. The offline build image does not
//!   ship that crate, so enabling the feature additionally requires adding
//!   the `xla` dependency to `rust/Cargo.toml`. Without the feature the
//!   module compiles a same-surface stub whose constructor reports the
//!   runtime as unavailable; every other subsystem — quantization, QEP,
//!   eval, experiments — is pure Rust and never needs it
//!   (`tests/pjrt_crosscheck.rs` re-arms with the feature).

pub mod coordinator;
pub mod eval;
pub mod exp;
pub mod fleet;
pub mod io;
pub mod linalg;
pub mod model;
pub mod qep;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod text;
pub mod util;
