//! # QEP — Quantization Error Propagation
//!
//! Production reproduction of *“Quantization Error Propagation: Revisiting
//! Layer-Wise Post-Training Quantization”* (Arai & Ichikawa, NeurIPS 2025)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the quantization *coordinator*: calibration
//!   stream management, Hessian accumulation, the QEP weight correction, and
//!   from-scratch implementations of RTN / GPTQ / AWQ / QuIP, plus the full
//!   evaluation harness (perplexity, zero-shot tasks, error-accumulation
//!   diagnostics) and a PJRT runtime that executes AOT-lowered JAX/Pallas
//!   artifacts with Python never on the request path.
//! * **Layer 2 (python/compile/model.py)** — the JAX transformer used for
//!   build-time training and AOT export to HLO text.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels (fused
//!   dequantize×matmul, Hessian accumulation) lowered into the same
//!   artifacts (interpret mode on CPU).
//!
//! Quick tour:
//!
//! ```no_run
//! use qep::model::Model;
//! use qep::quant::{QuantConfig, Method};
//! use qep::coordinator::{Pipeline, PipelineConfig};
//!
//! let model = Model::load("artifacts/tiny-s.qtz").unwrap();
//! let cfg = PipelineConfig {
//!     quant: QuantConfig::int(3),
//!     method: Method::Gptq,
//!     qep_alpha: Some(0.5),
//!     ..Default::default()
//! };
//! let calib = qep::text::Corpus::generate(qep::text::Flavor::C4, 64 * 2048, 0);
//! let quantized = Pipeline::new(cfg).run(&model, &calib.tokens).unwrap();
//! ```

pub mod coordinator;
pub mod eval;
pub mod exp;
pub mod io;
pub mod linalg;
pub mod model;
pub mod qep;
pub mod quant;
pub mod runtime;
pub mod text;
pub mod util;
