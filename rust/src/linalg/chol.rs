//! Cholesky-based SPD routines in f64. These back both the QEP correction
//! term `(Ĥ + ρI)⁻¹` (Prop. 5.1) and GPTQ's `chol(H⁻¹)ᵀ` factor.
//!
//! All factorizations run in f64 regardless of the f32 data path: the
//! Hessians of trained transformer layers are poorly conditioned, and the
//! paper's damping (App. B.1, λ = mean diag) is applied *before* calling
//! into these routines by the callers.

use super::mat::Mat64;
use anyhow::{bail, Result};

/// In-place lower-Cholesky: on success `a` holds L (strictly-upper garbage
/// zeroed) with `a = L·Lᵀ` for the original SPD input.
pub fn cholesky_in_place(a: &mut Mat64) -> Result<()> {
    let n = a.rows;
    assert_eq!(a.rows, a.cols, "cholesky needs square input");
    for j in 0..n {
        // d = a[j][j] - sum_k L[j][k]^2
        let mut d = a.at(j, j);
        for k in 0..j {
            let l = a.at(j, k);
            d -= l * l;
        }
        if d <= 0.0 || !d.is_finite() {
            bail!("matrix not positive definite at pivot {j} (d = {d}); increase damping");
        }
        let ljj = d.sqrt();
        *a.at_mut(j, j) = ljj;
        for i in j + 1..n {
            let mut s = a.at(i, j);
            // s -= dot(L[i][..j], L[j][..j])
            let (ri, rj) = (i * n, j * n);
            for k in 0..j {
                s -= a.data[ri + k] * a.data[rj + k];
            }
            *a.at_mut(i, j) = s / ljj;
        }
    }
    // Zero the strictly-upper triangle so the result is a clean L.
    for i in 0..n {
        for j in i + 1..n {
            *a.at_mut(i, j) = 0.0;
        }
    }
    Ok(())
}

/// Solve L·y = b in place (forward substitution), L lower-triangular.
pub fn solve_lower(l: &Mat64, b: &mut [f64]) {
    let n = l.rows;
    for i in 0..n {
        let mut s = b[i];
        let row = &l.data[i * n..i * n + i];
        for (k, &lik) in row.iter().enumerate() {
            s -= lik * b[k];
        }
        b[i] = s / l.at(i, i);
    }
}

/// Solve Lᵀ·x = y in place (backward substitution).
pub fn solve_lower_transpose(l: &Mat64, b: &mut [f64]) {
    let n = l.rows;
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in i + 1..n {
            s -= l.at(k, i) * b[k];
        }
        b[i] = s / l.at(i, i);
    }
}

/// Solve (A) X = B for SPD A; returns X.
///
/// §Perf: substitution runs at the *matrix* level — whole rows of the RHS
/// are updated with contiguous axpys instead of solving column vectors one
/// at a time (the per-column path strided through B and ran ~6× slower on
/// the 512-wide MLP Hessians).
pub fn spd_solve(a: &Mat64, b: &Mat64) -> Result<Mat64> {
    assert_eq!(a.rows, b.rows);
    let mut l = a.clone();
    cholesky_in_place(&mut l)?;
    let n = a.rows;
    let m = b.cols;
    let mut x = b.clone();
    // Forward: L·Y = B, row-major rows of Y updated in place.
    for i in 0..n {
        let (done, rest) = x.data.split_at_mut(i * m);
        let yi = &mut rest[..m];
        let lrow = &l.data[i * n..i * n + i];
        for (k, &lik) in lrow.iter().enumerate() {
            if lik != 0.0 {
                let yk = &done[k * m..(k + 1) * m];
                for (a, b) in yi.iter_mut().zip(yk.iter()) {
                    *a -= lik * b;
                }
            }
        }
        let inv = 1.0 / l.at(i, i);
        for v in yi.iter_mut() {
            *v *= inv;
        }
    }
    // Backward: Lᵀ·X = Y.
    for i in (0..n).rev() {
        let (head, tail) = x.data.split_at_mut((i + 1) * m);
        let xi = &mut head[i * m..];
        for k in i + 1..n {
            let lki = l.at(k, i);
            if lki != 0.0 {
                let xk = &tail[(k - i - 1) * m..(k - i) * m];
                for (a, b) in xi.iter_mut().zip(xk.iter()) {
                    *a -= lki * b;
                }
            }
        }
        let inv = 1.0 / l.at(i, i);
        for v in xi.iter_mut() {
            *v *= inv;
        }
    }
    Ok(x)
}

/// Explicit SPD inverse via Cholesky. Prefer `spd_solve` when you only need
/// A⁻¹·B; the explicit inverse is used by QEP's correction where the same
/// Ĥ⁻¹ is reused across all rows of a layer.
pub fn spd_inverse(a: &Mat64) -> Result<Mat64> {
    let n = a.rows;
    spd_solve(a, &Mat64::eye(n))
}

/// GPTQ's factor: the *upper* Cholesky factor U of A⁻¹ (A SPD), such that
/// A⁻¹ = Uᵀ·U — torch's `linalg.cholesky(Hinv, upper=True)` convention,
/// whose rows feed the column-wise quantization loop.
///
/// For real matrices `chol(B, upper=True) = chol(B, lower=True)ᵀ`, so we
/// factor H⁻¹ = L·Lᵀ and return U = Lᵀ (B = (Lᵀ)ᵀ(Lᵀ) = Uᵀ·U).
pub fn upper_cholesky_of_inverse(h: &Mat64) -> Result<Mat64> {
    let mut l = spd_inverse(h)?;
    cholesky_in_place(&mut l)?;
    let n = l.rows;
    let mut u = Mat64::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            *u.at_mut(j, i) = l.at(i, j);
        }
    }
    Ok(u)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Mat64 {
        // A = B·Bᵀ + n·I  — well conditioned SPD.
        let mut b = Mat64::zeros(n, n);
        for v in b.data.iter_mut() {
            *v = rng.normal();
        }
        let mut a = Mat64::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b.at(i, k) * b.at(j, k);
                }
                *a.at_mut(i, j) = s;
            }
        }
        a.add_diag(n as f64);
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(1);
        for n in [1, 2, 5, 16, 40] {
            let a = random_spd(n, &mut rng);
            let mut l = a.clone();
            cholesky_in_place(&mut l).unwrap();
            // Check L·Lᵀ == A.
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0;
                    for k in 0..=i.min(j) {
                        s += l.at(i, k) * l.at(j, k);
                    }
                    assert!((s - a.at(i, j)).abs() < 1e-8 * (1.0 + a.at(i, j).abs()));
                }
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Mat64::eye(3);
        *a.at_mut(2, 2) = -1.0;
        assert!(cholesky_in_place(&mut a).is_err());
    }

    #[test]
    fn solve_and_inverse_agree() {
        let mut rng = Rng::new(2);
        let n = 24;
        let a = random_spd(n, &mut rng);
        let inv = spd_inverse(&a).unwrap();
        let id = a.matmul(&inv);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((id.at(i, j) - want).abs() < 1e-8, "{} {}", i, j);
            }
        }
    }

    #[test]
    fn triangular_solves() {
        let mut rng = Rng::new(3);
        let n = 12;
        let a = random_spd(n, &mut rng);
        let mut l = a.clone();
        cholesky_in_place(&mut l).unwrap();
        let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        // b = L x
        let mut b = vec![0.0; n];
        for i in 0..n {
            for k in 0..=i {
                b[i] += l.at(i, k) * x_true[k];
            }
        }
        solve_lower(&l, &mut b);
        for i in 0..n {
            assert!((b[i] - x_true[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn upper_cholesky_of_inverse_identity() {
        let mut rng = Rng::new(4);
        let n = 20;
        let h = random_spd(n, &mut rng);
        let u = upper_cholesky_of_inverse(&h).unwrap();
        // U must be upper triangular...
        for i in 0..n {
            for j in 0..i {
                assert!(u.at(i, j).abs() < 1e-12, "not upper at ({i},{j})");
            }
        }
        // ...and satisfy Uᵀ·U = H⁻¹, i.e. H·(Uᵀ·U) = I.
        let mut utu = Mat64::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += u.at(k, i) * u.at(k, j);
                }
                *utu.at_mut(i, j) = s;
            }
        }
        let id = h.matmul(&utu);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((id.at(i, j) - want).abs() < 1e-7);
            }
        }
    }
}
