//! Cholesky-based SPD routines in f64 — the blocked, pool-parallel heart
//! of the compensation hot path. These back both the QEP correction term
//! `(Ĥ + ρI)⁻¹` (Prop. 5.1) and GPTQ's `chol(H⁻¹)ᵀ` factor, and they are
//! called once per quantized linear, so after PR 1 parallelized GEMM they
//! were the largest single-threaded residue of the pipeline.
//!
//! # Algorithm
//!
//! [`cholesky_in_place_with`] is a blocked right-looking factorization:
//! per panel of `block` columns it (1) factors the small diagonal tile
//! serially, (2) triangular-solves the panel below the tile with rows
//! fanned across the persistent worker pool, and (3) applies the trailing
//! SYRK-shaped update `A₂₂ -= L₂₁·L₂₁ᵀ`, also row-parallel and running
//! through the SYRK register-tile micro-kernel
//! ([`super::micro::dot4_sub_f64`]: four independent scalar-order
//! dot-chains per tile). Multi-RHS solves ([`spd_solve_with`]) batch the
//! right-hand-side *columns* across pool workers with the f64 axpy tile.
//!
//! # Bit-identical parallelism (the repo contract)
//!
//! Every element's floating-point operation sequence is exactly the one
//! the classic unblocked algorithm ([`cholesky_unblocked`]) performs:
//! subtractions are applied term-by-term in ascending `k`, each one
//! individually rounded, regardless of which panel or worker applies them.
//! Workers own disjoint rows (factorization) or disjoint RHS column
//! strips (solves) and there is no cross-thread reduction anywhere, so
//! results are **bit-identical for every thread count and every block
//! size** — `tests/parallel_equivalence.rs` gates this.
//!
//! All factorizations run in f64 regardless of the f32 data path: the
//! Hessians of trained transformer layers are poorly conditioned, and the
//! paper's damping (App. B.1, λ = mean diag) is applied *before* calling
//! into these routines by the callers.

use super::mat::Mat64;
use super::micro;
use super::par::big_enough;
use crate::util::pool::{self, Pool, SendPtr};
use anyhow::{bail, Result};

/// Default panel width for the blocked factorization. Chosen so the
/// serial diagonal-tile work (`block³/3` per panel) is negligible next to
/// the parallel panel solve + trailing update on the layer sizes the
/// pipeline sees (d = 64…512). Any value gives bit-identical results.
pub const CHOL_BLOCK: usize = 64;

/// In-place lower-Cholesky on the process-global pool: on success `a`
/// holds L (strictly-upper garbage zeroed) with `a = L·Lᵀ` for the
/// original SPD input. Equivalent to
/// `cholesky_in_place_with(a, CHOL_BLOCK, &pool::global())`.
pub fn cholesky_in_place(a: &mut Mat64) -> Result<()> {
    cholesky_in_place_with(a, CHOL_BLOCK, &pool::global())
}

/// Reference unblocked factorization (the pre-blocking serial kernel).
/// Kept public so property tests and benches can pin the blocked engine
/// against it; the blocked path reproduces its results bit-for-bit.
pub fn cholesky_unblocked(a: &mut Mat64) -> Result<()> {
    let n = a.rows;
    assert_eq!(a.rows, a.cols, "cholesky needs square input");
    for j in 0..n {
        // d = a[j][j] - sum_k L[j][k]^2
        let mut d = a.at(j, j);
        for k in 0..j {
            let l = a.at(j, k);
            d -= l * l;
        }
        if d <= 0.0 || !d.is_finite() {
            bail!("matrix not positive definite at pivot {j} (d = {d}); increase damping");
        }
        let ljj = d.sqrt();
        *a.at_mut(j, j) = ljj;
        for i in j + 1..n {
            let mut s = a.at(i, j);
            // s -= dot(L[i][..j], L[j][..j])
            let (ri, rj) = (i * n, j * n);
            for k in 0..j {
                s -= a.data[ri + k] * a.data[rj + k];
            }
            *a.at_mut(i, j) = s / ljj;
        }
    }
    zero_upper(a);
    Ok(())
}

/// Blocked right-looking in-place lower-Cholesky on `pool`.
///
/// Bit-identical to [`cholesky_unblocked`] for every `block ≥ 1` and every
/// thread count: the per-element subtraction order (ascending `k`, one
/// rounding per term) is preserved exactly; panels and workers only change
/// *who* applies each operation, never the sequence.
pub fn cholesky_in_place_with(a: &mut Mat64, block: usize, pool: &Pool) -> Result<()> {
    let n = a.rows;
    assert_eq!(a.rows, a.cols, "cholesky needs square input");
    let block = block.max(1);
    let mut p0 = 0;
    while p0 < n {
        let p1 = (p0 + block).min(n);

        // 1. Factor the diagonal tile [p0,p1)² serially (contributions from
        //    columns < p0 were already subtracted by earlier trailing
        //    updates, so this is the plain unblocked recurrence).
        for j in p0..p1 {
            let mut d = a.at(j, j);
            for k in p0..j {
                let l = a.at(j, k);
                d -= l * l;
            }
            if d <= 0.0 || !d.is_finite() {
                bail!("matrix not positive definite at pivot {j} (d = {d}); increase damping");
            }
            let ljj = d.sqrt();
            *a.at_mut(j, j) = ljj;
            for i in j + 1..p1 {
                let mut s = a.at(i, j);
                let (ri, rj) = (i * n, j * n);
                for k in p0..j {
                    s -= a.data[ri + k] * a.data[rj + k];
                }
                *a.at_mut(i, j) = s / ljj;
            }
        }

        // 2. Panel solve: rows below the tile compute their L entries for
        //    the panel columns. Rows are independent (each reads only its
        //    own row plus the finished tile rows), so they fan out.
        let rows = n - p1;
        if rows > 0 {
            let base = SendPtr::new(a.data.as_mut_ptr());
            let bw = p1 - p0;
            let run_rows = |r0: usize, r1: usize| {
                for r in r0..r1 {
                    let i = p1 + r;
                    // Sound: this worker owns row i's panel slice; the tile
                    // rows [p0,p1) it reads are finalized and read-only here.
                    unsafe {
                        let arow = base.0.add(i * n);
                        for j in p0..p1 {
                            let ljrow = base.0.add(j * n);
                            let mut s = *arow.add(j);
                            for k in p0..j {
                                s -= *arow.add(k) * *ljrow.add(k);
                            }
                            *arow.add(j) = s / *ljrow.add(j);
                        }
                    }
                }
            };
            if pool.threads() > 1 && rows >= 2 && big_enough(rows, bw, bw) {
                pool.run(rows, pool::chunk(rows, pool.threads()), &run_rows);
            } else {
                run_rows(0, rows);
            }

            // 3. Trailing update A₂₂ -= L₂₁·L₂₁ᵀ (lower triangle only).
            //    Row i writes a[i][p1..=i] and reads panel columns [p0,p1)
            //    of rows ≤ i — finalized in step 2, untouched here — so
            //    rows again fan out with no synchronization. The inner
            //    dot-chains run through the SYRK micro-kernel
            //    (`micro::dot4_sub_f64`): four output columns per register
            //    tile, each keeping the scalar ascending-k subtraction
            //    order, so the tiling never changes bits.
            let run_trail = |r0: usize, r1: usize| {
                for r in r0..r1 {
                    let i = p1 + r;
                    // Sound: disjoint row ranges; reads are of panel columns
                    // [p0,p1) no worker writes during this pass, and every
                    // write lands in columns [p1,i] of row i — disjoint from
                    // all read slices (micro::syrk_row_sub_f64's contract).
                    unsafe {
                        let arow = base.0.add(i * n);
                        let apan = std::slice::from_raw_parts(arow.add(p0), bw);
                        // b(j2) = row j2's panel slice = base + j2·n + p0.
                        micro::syrk_row_sub_f64(apan, base.0.add(p0), n, arow, p1, i + 1);
                    }
                }
            };
            if pool.threads() > 1 && rows >= 2 && big_enough(rows, bw, rows / 2 + 1) {
                pool.run(rows, pool::chunk(rows, pool.threads()), &run_trail);
            } else {
                run_trail(0, rows);
            }
        }
        p0 = p1;
    }
    zero_upper(a);
    Ok(())
}

/// Zero the strictly-upper triangle so the result is a clean L.
fn zero_upper(a: &mut Mat64) {
    let n = a.rows;
    for i in 0..n {
        for j in i + 1..n {
            *a.at_mut(i, j) = 0.0;
        }
    }
}

/// Solve L·y = b in place (forward substitution), L lower-triangular.
/// Single-RHS vector path; multi-RHS callers use
/// [`solve_lower_multi_with`] to batch columns across the pool.
pub fn solve_lower(l: &Mat64, b: &mut [f64]) {
    let n = l.rows;
    for i in 0..n {
        let mut s = b[i];
        let row = &l.data[i * n..i * n + i];
        for (k, &lik) in row.iter().enumerate() {
            s -= lik * b[k];
        }
        b[i] = s / l.at(i, i);
    }
}

/// Solve Lᵀ·x = y in place (backward substitution).
pub fn solve_lower_transpose(l: &Mat64, b: &mut [f64]) {
    let n = l.rows;
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in i + 1..n {
            s -= l.at(k, i) * b[k];
        }
        b[i] = s / l.at(i, i);
    }
}

/// Forward-substitute L·Y = B in place over the RHS matrix `x` [n,m],
/// batching contiguous column strips across `pool`. Per-element operation
/// order is independent of the strip partition (each element's updates run
/// over `k` ascending with one rounding per axpy term), so results are
/// bit-identical for every thread count.
pub fn solve_lower_multi_with(l: &Mat64, x: &mut Mat64, pool: &Pool) {
    let (n, m) = (l.rows, x.cols);
    assert_eq!(x.rows, n, "solve_lower_multi shape mismatch");
    if m == 0 || n == 0 {
        return;
    }
    if pool.threads() > 1 && m >= 2 && big_enough(n, n, m) {
        let base = SendPtr::new(x.data.as_mut_ptr());
        pool.run(m, pool::chunk(m, pool.threads()), |c0, c1| {
            // Sound: column strips are disjoint regions of x.
            unsafe { forward_cols(l, base.0, m, c0, c1) }
        });
    } else {
        unsafe { forward_cols(l, x.data.as_mut_ptr(), m, 0, m) }
    }
}

/// Backward-substitute Lᵀ·X = Y in place over `x` [n,m]; the column-strip
/// twin of [`solve_lower_multi_with`].
pub fn solve_lower_transpose_multi_with(l: &Mat64, x: &mut Mat64, pool: &Pool) {
    let (n, m) = (l.rows, x.cols);
    assert_eq!(x.rows, n, "solve_lower_transpose_multi shape mismatch");
    if m == 0 || n == 0 {
        return;
    }
    if pool.threads() > 1 && m >= 2 && big_enough(n, n, m) {
        let base = SendPtr::new(x.data.as_mut_ptr());
        pool.run(m, pool::chunk(m, pool.threads()), |c0, c1| {
            // Sound: column strips are disjoint regions of x.
            unsafe { backward_cols(l, base.0, m, c0, c1) }
        });
    } else {
        unsafe { backward_cols(l, x.data.as_mut_ptr(), m, 0, m) }
    }
}

/// Forward substitution restricted to columns [c0,c1) of the row-major RHS
/// at `x`. Caller guarantees strips are disjoint across concurrent calls.
/// The per-row axpys run through the 4-wide f64 register tile
/// (`micro::axpy_sub_f64`) — element-wise, so bit-identical to the plain
/// loop.
unsafe fn forward_cols(l: &Mat64, x: *mut f64, m: usize, c0: usize, c1: usize) {
    let n = l.rows;
    let w = c1 - c0;
    for i in 0..n {
        // Sound: rows i and k < i are disjoint regions of x.
        let xi = std::slice::from_raw_parts_mut(x.add(i * m + c0), w);
        let lrow = &l.data[i * n..i * n + i];
        for (k, &lik) in lrow.iter().enumerate() {
            if lik != 0.0 {
                let xk = std::slice::from_raw_parts(x.add(k * m + c0), w);
                micro::axpy_sub_f64(lik, xk, xi);
            }
        }
        let inv = 1.0 / l.at(i, i);
        for v in xi.iter_mut() {
            *v *= inv;
        }
    }
}

/// Backward substitution restricted to columns [c0,c1); see
/// [`forward_cols`] for the soundness contract.
unsafe fn backward_cols(l: &Mat64, x: *mut f64, m: usize, c0: usize, c1: usize) {
    let n = l.rows;
    let w = c1 - c0;
    for i in (0..n).rev() {
        // Sound: rows i and k > i are disjoint regions of x.
        let xi = std::slice::from_raw_parts_mut(x.add(i * m + c0), w);
        for k in i + 1..n {
            let lki = l.at(k, i);
            if lki != 0.0 {
                let xk = std::slice::from_raw_parts(x.add(k * m + c0), w);
                micro::axpy_sub_f64(lki, xk, xi);
            }
        }
        let inv = 1.0 / l.at(i, i);
        for v in xi.iter_mut() {
            *v *= inv;
        }
    }
}

/// Solve A·X = B for SPD A on the process-global pool; returns X.
///
/// §Perf: substitution runs at the *matrix* level — whole column strips of
/// the RHS are updated with contiguous axpys instead of solving column
/// vectors one at a time (the per-column path strided through B and ran
/// ~6× slower on the 512-wide MLP Hessians), and strips fan out across
/// pool workers.
///
/// ```
/// use qep::linalg::{spd_solve, Mat64};
/// let mut a = Mat64::eye(2);
/// a.add_diag(3.0); // A = 4·I
/// let mut b = Mat64::zeros(2, 1);
/// *b.at_mut(0, 0) = 4.0;
/// *b.at_mut(1, 0) = 6.0;
/// let x = spd_solve(&a, &b).unwrap();
/// assert_eq!(x.at(0, 0), 1.0);
/// assert_eq!(x.at(1, 0), 1.5);
/// ```
pub fn spd_solve(a: &Mat64, b: &Mat64) -> Result<Mat64> {
    spd_solve_with(a, b, &pool::global())
}

/// [`spd_solve`] on an explicit pool: blocked Cholesky, then pooled
/// forward/backward substitution over RHS column strips. Bit-identical for
/// every thread count.
pub fn spd_solve_with(a: &Mat64, b: &Mat64, pool: &Pool) -> Result<Mat64> {
    assert_eq!(a.rows, b.rows);
    let mut l = a.clone();
    cholesky_in_place_with(&mut l, CHOL_BLOCK, pool)?;
    let mut x = b.clone();
    solve_lower_multi_with(&l, &mut x, pool);
    solve_lower_transpose_multi_with(&l, &mut x, pool);
    Ok(x)
}

/// Explicit SPD inverse via Cholesky. Prefer `spd_solve` when you only need
/// A⁻¹·B; the explicit inverse is used by QEP's correction where the same
/// Ĥ⁻¹ is reused across all rows of a layer.
pub fn spd_inverse(a: &Mat64) -> Result<Mat64> {
    spd_inverse_with(a, &pool::global())
}

/// [`spd_inverse`] on an explicit pool.
pub fn spd_inverse_with(a: &Mat64, pool: &Pool) -> Result<Mat64> {
    let n = a.rows;
    spd_solve_with(a, &Mat64::eye(n), pool)
}

/// GPTQ's factor: the *upper* Cholesky factor U of A⁻¹ (A SPD), such that
/// A⁻¹ = Uᵀ·U — torch's `linalg.cholesky(Hinv, upper=True)` convention,
/// whose rows feed the column-wise quantization loop.
///
/// For real matrices `chol(B, upper=True) = chol(B, lower=True)ᵀ`, so we
/// factor H⁻¹ = L·Lᵀ and return U = Lᵀ (B = (Lᵀ)ᵀ(Lᵀ) = Uᵀ·U).
pub fn upper_cholesky_of_inverse(h: &Mat64) -> Result<Mat64> {
    upper_cholesky_of_inverse_with(h, &pool::global())
}

/// [`upper_cholesky_of_inverse`] on an explicit pool.
pub fn upper_cholesky_of_inverse_with(h: &Mat64, pool: &Pool) -> Result<Mat64> {
    let mut l = spd_inverse_with(h, pool)?;
    cholesky_in_place_with(&mut l, CHOL_BLOCK, pool)?;
    let n = l.rows;
    let mut u = Mat64::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            *u.at_mut(j, i) = l.at(i, j);
        }
    }
    Ok(u)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Mat64 {
        // A = B·Bᵀ + n·I  — well conditioned SPD.
        let mut b = Mat64::zeros(n, n);
        for v in b.data.iter_mut() {
            *v = rng.normal();
        }
        let mut a = Mat64::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b.at(i, k) * b.at(j, k);
                }
                *a.at_mut(i, j) = s;
            }
        }
        a.add_diag(n as f64);
        a
    }

    /// Near-singular SPD: rank-1 dominant structure plus a tiny ridge.
    fn ill_conditioned_spd(n: usize, ridge: f64, rng: &mut Rng) -> Mat64 {
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut a = Mat64::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                *a.at_mut(i, j) = v[i] * v[j];
            }
        }
        a.add_diag(ridge);
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(1);
        for n in [1, 2, 5, 16, 40] {
            let a = random_spd(n, &mut rng);
            let mut l = a.clone();
            cholesky_in_place(&mut l).unwrap();
            // Check L·Lᵀ == A.
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0;
                    for k in 0..=i.min(j) {
                        s += l.at(i, k) * l.at(j, k);
                    }
                    assert!((s - a.at(i, j)).abs() < 1e-8 * (1.0 + a.at(i, j).abs()));
                }
            }
        }
    }

    #[test]
    fn blocked_matches_unblocked_bit_for_bit() {
        // The contract: every block size and every thread count reproduces
        // the unblocked serial factorization exactly, including sizes that
        // are not a multiple of the block.
        let mut rng = Rng::new(10);
        for n in [1usize, 2, 7, 33, 64, 65, 129] {
            let a = random_spd(n, &mut rng);
            let mut want = a.clone();
            cholesky_unblocked(&mut want).unwrap();
            for block in [1usize, 3, 8, 64, 200] {
                for threads in [1usize, 2, 4, 7] {
                    let mut got = a.clone();
                    cholesky_in_place_with(&mut got, block, &Pool::new(threads)).unwrap();
                    assert_eq!(
                        got.data, want.data,
                        "n={n} block={block} threads={threads} differs from unblocked"
                    );
                }
            }
        }
    }

    #[test]
    fn ill_conditioned_agrees_or_fails_identically() {
        // Near-singular inputs must behave the same on every path: either
        // all succeed with identical bits or all bail (same pivot check).
        let mut rng = Rng::new(11);
        for ridge in [1e-6, 1e-10, 0.0] {
            let a = ill_conditioned_spd(24, ridge, &mut rng);
            let mut reference = a.clone();
            let want = cholesky_unblocked(&mut reference);
            for block in [4usize, 24, 64] {
                let mut got = a.clone();
                let res = cholesky_in_place_with(&mut got, block, &Pool::new(4));
                match (&want, &res) {
                    (Ok(()), Ok(())) => assert_eq!(got.data, reference.data, "ridge={ridge}"),
                    (Err(_), Err(_)) => {}
                    _ => panic!("ridge={ridge} block={block}: blocked/unblocked disagree on PD"),
                }
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Mat64::eye(3);
        *a.at_mut(2, 2) = -1.0;
        assert!(cholesky_in_place(&mut a).is_err());
        let mut b = Mat64::eye(3);
        *b.at_mut(2, 2) = -1.0;
        assert!(cholesky_unblocked(&mut b).is_err());
    }

    #[test]
    fn solve_and_inverse_agree() {
        let mut rng = Rng::new(2);
        let n = 24;
        let a = random_spd(n, &mut rng);
        let inv = spd_inverse(&a).unwrap();
        let id = a.matmul(&inv);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((id.at(i, j) - want).abs() < 1e-8, "{} {}", i, j);
            }
        }
    }

    #[test]
    fn multi_rhs_solve_is_thread_invariant() {
        let mut rng = Rng::new(12);
        let n = 48;
        let a = random_spd(n, &mut rng);
        let mut b = Mat64::zeros(n, 13);
        for v in b.data.iter_mut() {
            *v = rng.normal();
        }
        let want = spd_solve_with(&a, &b, &Pool::serial()).unwrap();
        for threads in [2usize, 3, 8] {
            let got = spd_solve_with(&a, &b, &Pool::new(threads)).unwrap();
            assert_eq!(got.data, want.data, "threads={threads}");
        }
        // And it actually solves: A·X ≈ B.
        let ax = a.matmul(&want);
        for (x, y) in ax.data.iter().zip(b.data.iter()) {
            assert!((x - y).abs() < 1e-7, "{x} vs {y}");
        }
    }

    #[test]
    fn triangular_solves() {
        let mut rng = Rng::new(3);
        let n = 12;
        let a = random_spd(n, &mut rng);
        let mut l = a.clone();
        cholesky_in_place(&mut l).unwrap();
        let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        // b = L x
        let mut b = vec![0.0; n];
        for i in 0..n {
            for k in 0..=i {
                b[i] += l.at(i, k) * x_true[k];
            }
        }
        solve_lower(&l, &mut b);
        for i in 0..n {
            assert!((b[i] - x_true[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn multi_rhs_matches_vector_solves() {
        // The batched column-strip substitution must agree with the
        // single-RHS vector path on each column (to solver tolerance).
        let mut rng = Rng::new(13);
        let n = 20;
        let a = random_spd(n, &mut rng);
        let mut l = a.clone();
        cholesky_in_place(&mut l).unwrap();
        let mut b = Mat64::zeros(n, 5);
        for v in b.data.iter_mut() {
            *v = rng.normal();
        }
        let mut x = b.clone();
        solve_lower_multi_with(&l, &mut x, &Pool::new(4));
        solve_lower_transpose_multi_with(&l, &mut x, &Pool::new(4));
        for c in 0..5 {
            let mut col: Vec<f64> = (0..n).map(|r| b.at(r, c)).collect();
            solve_lower(&l, &mut col);
            solve_lower_transpose(&l, &mut col);
            for r in 0..n {
                assert!((x.at(r, c) - col[r]).abs() < 1e-12, "col {c} row {r}");
            }
        }
    }

    #[test]
    fn upper_cholesky_of_inverse_identity() {
        let mut rng = Rng::new(4);
        let n = 20;
        let h = random_spd(n, &mut rng);
        let u = upper_cholesky_of_inverse(&h).unwrap();
        // U must be upper triangular...
        for i in 0..n {
            for j in 0..i {
                assert!(u.at(i, j).abs() < 1e-12, "not upper at ({i},{j})");
            }
        }
        // ...and satisfy Uᵀ·U = H⁻¹, i.e. H·(Uᵀ·U) = I.
        let mut utu = Mat64::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += u.at(k, i) * u.at(k, j);
                }
                *utu.at_mut(i, j) = s;
            }
        }
        let id = h.matmul(&utu);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((id.at(i, j) - want).abs() < 1e-7);
            }
        }
    }
}
