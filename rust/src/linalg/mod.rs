//! Dense linear algebra substrate, written from scratch for this repo
//! (the environment is offline — no ndarray/BLAS). Everything the PTQ
//! pipeline needs: a row-major `Mat` (f32) workhorse with blocked GEMM,
//! an f64 `Mat64` for the numerically sensitive Hessian factorizations
//! (blocked Cholesky, SPD inverse, pooled multi-RHS triangular solves),
//! and the fast Walsh–Hadamard transform used by QuIP's incoherence
//! preprocessing.
//!
//! Parallel variants live in two places: [`par`] holds the row-partitioned
//! GEMM kernels, [`chol`] the blocked SPD engine. Both run on the
//! persistent worker pool (`crate::util::pool`) and both uphold the repo
//! contract that results are **bit-identical for every thread count** —
//! plain names (`matmul`, `spd_solve`, …) dispatch on the process-global
//! pool, `*_with`/`*_serial` variants take it explicitly. The innermost
//! loops of both (and of GPTQ's compensation sweep) share the fixed-width
//! register-tile micro-kernels in [`micro`], which vectorize across
//! independent output elements while keeping each element's
//! floating-point operation order exactly scalar.

pub mod chol;
pub mod gemm;
pub mod hadamard;
pub mod mat;
pub mod micro;
pub mod par;
pub mod qgemm;
pub mod svd;

pub use chol::{
    cholesky_in_place, cholesky_in_place_with, cholesky_unblocked, solve_lower,
    solve_lower_multi_with, solve_lower_transpose, solve_lower_transpose_multi_with, spd_inverse,
    spd_inverse_with, spd_solve, spd_solve_with, upper_cholesky_of_inverse,
    upper_cholesky_of_inverse_with, CHOL_BLOCK,
};
pub use gemm::{matmul, matmul_nt, matmul_nt_serial, matmul_serial, matmul_tn, matmul_tn_serial};
pub use hadamard::{fwht_inplace, hadamard_conjugate, hadamard_rows, SignedHadamard};
pub use mat::{Mat, Mat64};
pub use par::{matmul_nt_with, matmul_tn_with, matmul_with};
pub use qgemm::{qgemm_nt, qgemm_nt_serial, qgemm_nt_with, QWeightView};
pub use svd::{svd, svd_rank, svd_rank_with, svd_with, svd_with_block, Svd};
