//! Dense linear algebra substrate, written from scratch for this repo
//! (the environment is offline — no ndarray/BLAS). Everything the PTQ
//! pipeline needs: a row-major `Mat` (f32) workhorse with blocked GEMM,
//! an f64 `Mat64` for the numerically sensitive Hessian factorizations
//! (Cholesky, SPD inverse, triangular solves), and the fast Walsh–Hadamard
//! transform used by QuIP's incoherence preprocessing.

pub mod chol;
pub mod gemm;
pub mod hadamard;
pub mod mat;
pub mod par;

pub use chol::{cholesky_in_place, spd_inverse, spd_solve, upper_cholesky_of_inverse};
pub use gemm::{matmul, matmul_nt, matmul_nt_serial, matmul_serial, matmul_tn, matmul_tn_serial};
pub use hadamard::{fwht_inplace, hadamard_conjugate, hadamard_rows, SignedHadamard};
pub use mat::{Mat, Mat64};
pub use par::{matmul_nt_with, matmul_tn_with, matmul_with};
