//! Fixed-width register-tile micro-kernels for the innermost f32/f64
//! loops of the hot path — shared by the GEMM chunk kernels
//! ([`super::gemm`], dispatched in parallel by [`super::par`]), the
//! blocked Cholesky's trailing SYRK update and triangular substitutions
//! ([`super::chol`]), and GPTQ's in-block error compensation
//! (`crate::quant::gptq`).
//!
//! # Why hand-written tiles
//!
//! The repo's bit-identical-parallelism contract pins every output
//! element's floating-point operation *order*, which rules out the classic
//! fast-GEMM tricks (multiple accumulators per element, FMA-tree
//! reductions, `fast-math`). What it does *not* rule out is reorganizing
//! work **across** elements: each kernel below processes a fixed-width
//! tile of independent output elements in straight-line code, so LLVM's
//! auto-vectorizer sees branch-free, bounds-check-free bodies with one
//! independent mul-add chain per lane — SIMD across lanes, scalar-exact
//! order within each lane.
//!
//! Three tile shapes cover everything the repo does:
//!
//! * **Axpy tiles** (`axpy_*`): `y[j] (+|-)= a·x[j]` over a contiguous
//!   slice. Purely element-wise, so tiling is *trivially* bit-identical —
//!   same single rounding per element regardless of tile width. Width 8
//!   for f32, 4 for f64 (one 256-bit vector register either way).
//! * **The SYRK dot tile** (`dot4_sub_f64`): four trailing-update
//!   accumulators `acc[t] -= Σ_k a[k]·b_t[k]` advanced in lock-step over
//!   `k`. Each accumulator's subtraction chain runs in ascending `k` with
//!   one rounding per term — exactly the scalar order the unblocked
//!   Cholesky performs — while the four chains are mutually independent,
//!   which is what lets the vectorizer keep four FMA lanes busy where the
//!   scalar loop had one serial dependency chain.
//! * **GEMV dot tiles** (`dot8_f32` / `qdot8_f32`): eight output-column
//!   accumulators of an `x·Wᵀ` row advanced in lock-step over `k`, each
//!   chain in the *exact* per-element order of [`super::gemm`]'s blocked
//!   kernel — ascending `k` with the `x[k] == 0.0` skip — so the skinny
//!   decode path (`m < 8`) produces the same bits as the wide training
//!   path for every row. The `qdot*` twins fuse dequantization of packed
//!   low-bit codes (`(code − zero)·scale`) into the same chain, making
//!   the fused quantized GEMM ([`super::qgemm`]) bit-identical to
//!   dequantize-then-matmul by construction.
//!
//! `benches/linalg_hotpath.rs` reports the micro-kernel-vs-scalar speedup
//! on the SYRK shapes the compensation hot path actually sees (n = 512 and
//! 1024); `tests/parallel_equivalence.rs` and the Cholesky property tests
//! gate bit-identity against the scalar references.

/// f32 axpy tile width: 8 lanes = one 256-bit register of f32.
pub const F32_TILE: usize = 8;
/// f64 tile width: 4 lanes = one 256-bit register of f64.
pub const F64_TILE: usize = 4;

/// `y[j] += a · x[j]` over the whole slice, in fixed 8-wide register
/// tiles. Element-wise (one rounding per element), so this is
/// bit-identical to the plain loop for every input.
#[inline]
pub fn axpy_f32(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = y.len();
    let tiles = n / F32_TILE;
    for t in 0..tiles {
        let i = t * F32_TILE;
        // Fixed-size views: no bounds checks inside the straight-line tile.
        let xv: &[f32; F32_TILE] = x[i..i + F32_TILE].try_into().unwrap();
        let yv: &mut [f32; F32_TILE] = (&mut y[i..i + F32_TILE]).try_into().unwrap();
        for l in 0..F32_TILE {
            yv[l] += a * xv[l];
        }
    }
    for i in tiles * F32_TILE..n {
        y[i] += a * x[i];
    }
}

/// `y[j] -= a · x[j]` in 8-wide tiles; the compensation twin of
/// [`axpy_f32`] (GPTQ's in-block error propagation is a subtraction).
#[inline]
pub fn axpy_sub_f32(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = y.len();
    let tiles = n / F32_TILE;
    for t in 0..tiles {
        let i = t * F32_TILE;
        let xv: &[f32; F32_TILE] = x[i..i + F32_TILE].try_into().unwrap();
        let yv: &mut [f32; F32_TILE] = (&mut y[i..i + F32_TILE]).try_into().unwrap();
        for l in 0..F32_TILE {
            yv[l] -= a * xv[l];
        }
    }
    for i in tiles * F32_TILE..n {
        y[i] -= a * x[i];
    }
}

/// `y[j] -= a · x[j]` in 4-wide f64 tiles — the substitution kernel for
/// the multi-RHS triangular solves (each RHS column strip is one `y`).
#[inline]
pub fn axpy_sub_f64(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let n = y.len();
    let tiles = n / F64_TILE;
    for t in 0..tiles {
        let i = t * F64_TILE;
        let xv: &[f64; F64_TILE] = x[i..i + F64_TILE].try_into().unwrap();
        let yv: &mut [f64; F64_TILE] = (&mut y[i..i + F64_TILE]).try_into().unwrap();
        for l in 0..F64_TILE {
            yv[l] -= a * xv[l];
        }
    }
    for i in tiles * F64_TILE..n {
        y[i] -= a * x[i];
    }
}

/// The SYRK micro-kernel: four trailing-update dot-chains at once.
///
/// Computes `acc[t] -= Σ_k a[k]·b_t[k]` for `t = 0..4`, with every
/// accumulator's subtractions applied in ascending `k`, one rounding per
/// term — the exact operation order of the scalar loop
/// ([`dot1_sub_f64`]), so substituting this kernel for four consecutive
/// scalar columns is bit-identical. The four chains are independent,
/// giving the auto-vectorizer four parallel mul-sub lanes.
///
/// All of `b0..b3` must be at least `a.len()` long.
#[inline]
pub fn dot4_sub_f64(a: &[f64], b0: &[f64], b1: &[f64], b2: &[f64], b3: &[f64], acc: &mut [f64; 4]) {
    let n = a.len();
    // Equal-length views so the compiler can hoist all bounds checks.
    let (b0, b1, b2, b3) = (&b0[..n], &b1[..n], &b2[..n], &b3[..n]);
    let (mut v0, mut v1, mut v2, mut v3) = (acc[0], acc[1], acc[2], acc[3]);
    for k in 0..n {
        let ak = a[k];
        v0 -= ak * b0[k];
        v1 -= ak * b1[k];
        v2 -= ak * b2[k];
        v3 -= ak * b3[k];
    }
    *acc = [v0, v1, v2, v3];
}

/// Scalar reference chain `acc -= Σ_k a[k]·b[k]` (ascending `k`, one
/// rounding per term). Handles the ragged tail of a SYRK row and is the
/// baseline `benches/linalg_hotpath.rs` measures [`dot4_sub_f64`] against.
#[inline]
pub fn dot1_sub_f64(a: &[f64], b: &[f64], acc: f64) -> f64 {
    let n = a.len();
    let b = &b[..n];
    let mut v = acc;
    for k in 0..n {
        v -= a[k] * b[k];
    }
    v
}

/// Eight `x·Wᵀ` output elements at once: `acc[l] += Σ_k x[k]·b_l[k]`
/// with every chain in ascending `k`, one rounding per term, and terms
/// where `x[k] == 0.0` skipped — the exact per-element order of the
/// blocked GEMM kernel (`gemm::matmul_block` runs `if av == 0.0 {
/// continue; }` before its inner axpy). Substituting this tile for
/// eight consecutive output columns of a skinny `x·Wᵀ` row is therefore
/// bit-identical to the wide transpose path for every input, which is
/// what makes a 1-row decode step reproduce the training-path bits.
///
/// All of `b` must be at least `x.len()` long.
#[inline]
pub fn dot8_f32(x: &[f32], b: [&[f32]; 8], acc: &mut [f32; 8]) {
    let n = x.len();
    // Equal-length views so the compiler can hoist all bounds checks.
    let b = b.map(|bl| &bl[..n]);
    let mut v = *acc;
    for k in 0..n {
        let xk = x[k];
        if xk == 0.0 {
            continue;
        }
        for l in 0..8 {
            v[l] += xk * b[l][k];
        }
    }
    *acc = v;
}

/// Scalar twin of [`dot8_f32`] for the ragged column tail: one chain
/// `acc += Σ_k x[k]·b[k]`, ascending `k`, skipping `x[k] == 0.0`.
#[inline]
pub fn dot1_f32(x: &[f32], b: &[f32], acc: f32) -> f32 {
    let n = x.len();
    let b = &b[..n];
    let mut v = acc;
    for k in 0..n {
        let xk = x[k];
        if xk == 0.0 {
            continue;
        }
        v += xk * b[k];
    }
    v
}

/// The fused dequantize×GEMV tile: eight output elements of `x·dq(W)ᵀ`
/// where row `l` of the weight tile is stored as packed codes `c[l]`
/// with one `(scale, zero)` pair for the whole `k` range (one
/// quantization group — [`super::qgemm`] walks groups in ascending-`k`
/// order and calls this once per group).
///
/// Each lane's chain is `acc[l] += x[k] · ((c[l][k] as f32 − z[l]) ·
/// s[l])` in ascending `k`, skipping `x[k] == 0.0` — term-for-term the
/// bits of first materializing `dq = (code − zero)·scale` (exactly
/// `QuantizedTensor::dequantize`'s expression) and then running the
/// dense kernel's chain `acc += x[k]·dq`. Rust never contracts `a·b + c`
/// into an FMA on its own, so the rounding sequence is identical.
#[inline]
pub fn qdot8_f32(x: &[f32], c: [&[u8]; 8], s: &[f32; 8], z: &[f32; 8], acc: &mut [f32; 8]) {
    let n = x.len();
    let c = c.map(|cl| &cl[..n]);
    let mut v = *acc;
    for k in 0..n {
        let xk = x[k];
        if xk == 0.0 {
            continue;
        }
        for l in 0..8 {
            v[l] += xk * ((c[l][k] as f32 - z[l]) * s[l]);
        }
    }
    *acc = v;
}

/// Scalar twin of [`qdot8_f32`] for the ragged column tail.
#[inline]
pub fn qdot1_f32(x: &[f32], c: &[u8], s: f32, z: f32, acc: f32) -> f32 {
    let n = x.len();
    let c = &c[..n];
    let mut v = acc;
    for k in 0..n {
        let xk = x[k];
        if xk == 0.0 {
            continue;
        }
        v += xk * ((c[k] as f32 - z) * s);
    }
    v
}

/// One output row of a trailing SYRK update through the
/// [`dot4_sub_f64`] tile: for every `j` in `[j0, j1)`,
/// `*out.add(j) -= Σ_k apan[k] · *b(j).add(k)` where `b(j) = b_base +
/// j·b_stride` is row `j` of the panel. Whole tiles go through the
/// 4-wide kernel, the ragged tail through [`dot1_sub_f64`]; each
/// element keeps the scalar ascending-`k` order either way.
///
/// Raw-pointer form on purpose: in the blocked Cholesky the `b` rows,
/// `apan`, and the output row all live in the same matrix allocation
/// (and `b(j)` may even *be* `apan` when `j` is the output row), which
/// safe slices cannot express. The bench drives this exact function, so
/// it measures the production tiling, not a copy.
///
/// # Safety
///
/// For the whole call: every `b(j)` row (length `apan.len()`) must be
/// valid to read, `out.add(j0..j1)` valid to write, and the written
/// range must be disjoint from `apan` and from every `b(j)` row read
/// (the reads may alias each other and `apan` freely).
pub unsafe fn syrk_row_sub_f64(
    apan: &[f64],
    b_base: *const f64,
    b_stride: usize,
    out: *mut f64,
    j0: usize,
    j1: usize,
) {
    let k = apan.len();
    let mut j = j0;
    while j + 4 <= j1 {
        let b0 = std::slice::from_raw_parts(b_base.add(j * b_stride), k);
        let b1 = std::slice::from_raw_parts(b_base.add((j + 1) * b_stride), k);
        let b2 = std::slice::from_raw_parts(b_base.add((j + 2) * b_stride), k);
        let b3 = std::slice::from_raw_parts(b_base.add((j + 3) * b_stride), k);
        let mut acc = [*out.add(j), *out.add(j + 1), *out.add(j + 2), *out.add(j + 3)];
        dot4_sub_f64(apan, b0, b1, b2, b3, &mut acc);
        *out.add(j) = acc[0];
        *out.add(j + 1) = acc[1];
        *out.add(j + 2) = acc[2];
        *out.add(j + 3) = acc[3];
        j += 4;
    }
    while j < j1 {
        let bj = std::slice::from_raw_parts(b_base.add(j * b_stride), k);
        *out.add(j) = dot1_sub_f64(apan, bj, *out.add(j));
        j += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn vec_f32(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn vec_f64(n: usize, rng: &mut Rng) -> Vec<f64> {
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn axpy_tiles_match_plain_loops_bitwise() {
        let mut rng = Rng::new(1);
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 100] {
            let x = vec_f32(n, &mut rng);
            let y0 = vec_f32(n, &mut rng);
            let a = rng.normal() as f32;

            let mut tiled = y0.clone();
            axpy_f32(a, &x, &mut tiled);
            let mut plain = y0.clone();
            for j in 0..n {
                plain[j] += a * x[j];
            }
            assert_eq!(tiled, plain, "axpy_f32 n={n}");

            let mut tiled = y0.clone();
            axpy_sub_f32(a, &x, &mut tiled);
            let mut plain = y0;
            for j in 0..n {
                plain[j] -= a * x[j];
            }
            assert_eq!(tiled, plain, "axpy_sub_f32 n={n}");
        }
    }

    #[test]
    fn axpy_sub_f64_matches_plain_loop_bitwise() {
        let mut rng = Rng::new(2);
        for n in [0usize, 1, 3, 4, 5, 11, 64, 97] {
            let x = vec_f64(n, &mut rng);
            let y0 = vec_f64(n, &mut rng);
            let a = rng.normal();
            let mut tiled = y0.clone();
            axpy_sub_f64(a, &x, &mut tiled);
            let mut plain = y0;
            for j in 0..n {
                plain[j] -= a * x[j];
            }
            assert_eq!(tiled, plain, "n={n}");
        }
    }

    #[test]
    fn dot4_matches_four_scalar_chains_bitwise() {
        let mut rng = Rng::new(3);
        for k in [0usize, 1, 2, 7, 33, 64, 129] {
            let a = vec_f64(k, &mut rng);
            let bs: Vec<Vec<f64>> = (0..4).map(|_| vec_f64(k, &mut rng)).collect();
            let init: Vec<f64> = vec_f64(4, &mut rng);

            let mut acc = [init[0], init[1], init[2], init[3]];
            dot4_sub_f64(&a, &bs[0], &bs[1], &bs[2], &bs[3], &mut acc);

            for t in 0..4 {
                let mut want = init[t];
                for kk in 0..k {
                    want -= a[kk] * bs[t][kk];
                }
                assert_eq!(acc[t].to_bits(), want.to_bits(), "k={k} lane {t}");
                assert_eq!(
                    dot1_sub_f64(&a, &bs[t], init[t]).to_bits(),
                    want.to_bits(),
                    "dot1 k={k} lane {t}"
                );
            }
        }
    }

    #[test]
    fn syrk_row_matches_scalar_chains_bitwise() {
        // The full row helper (4-wide tiles + ragged tail) against plain
        // scalar chains, across tail lengths 0..=3 and j0 offsets.
        let mut rng = Rng::new(4);
        let bw = 5;
        for rows in [1usize, 2, 4, 5, 7, 8, 11] {
            for j0 in [0usize, 1, 3] {
                if j0 >= rows {
                    continue;
                }
                let panel = vec_f64(rows * bw, &mut rng);
                let apan = vec_f64(bw, &mut rng);
                let out0 = vec_f64(rows, &mut rng);

                let mut got = out0.clone();
                unsafe {
                    syrk_row_sub_f64(&apan, panel.as_ptr(), bw, got.as_mut_ptr(), j0, rows);
                }

                let mut want = out0;
                for j in j0..rows {
                    for k in 0..bw {
                        want[j] -= apan[k] * panel[j * bw + k];
                    }
                }
                for j in 0..rows {
                    assert_eq!(
                        got[j].to_bits(),
                        want[j].to_bits(),
                        "rows={rows} j0={j0} j={j}"
                    );
                }
            }
        }
    }

    #[test]
    fn dot8_matches_eight_scalar_chains_bitwise() {
        let mut rng = Rng::new(5);
        for k in [0usize, 1, 2, 7, 33, 64, 129] {
            let mut x = vec_f32(k, &mut rng);
            // Plant exact zeros so the skip branch is exercised.
            for (i, v) in x.iter_mut().enumerate() {
                if i % 5 == 2 {
                    *v = 0.0;
                }
            }
            let bs: Vec<Vec<f32>> = (0..8).map(|_| vec_f32(k, &mut rng)).collect();
            let init = vec_f32(8, &mut rng);

            let mut acc: [f32; 8] = init.clone().try_into().unwrap();
            let views: [&[f32]; 8] = std::array::from_fn(|l| bs[l].as_slice());
            dot8_f32(&x, views, &mut acc);

            for (l, b) in bs.iter().enumerate() {
                let mut want = init[l];
                for kk in 0..k {
                    if x[kk] == 0.0 {
                        continue;
                    }
                    want += x[kk] * b[kk];
                }
                assert_eq!(acc[l].to_bits(), want.to_bits(), "k={k} lane {l}");
                assert_eq!(
                    dot1_f32(&x, b, init[l]).to_bits(),
                    want.to_bits(),
                    "dot1 k={k} lane {l}"
                );
            }
        }
    }

    #[test]
    fn qdot8_matches_dequantize_then_scalar_chain_bitwise() {
        let mut rng = Rng::new(6);
        for k in [0usize, 1, 3, 8, 32, 65, 100] {
            let mut x = vec_f32(k, &mut rng);
            for (i, v) in x.iter_mut().enumerate() {
                if i % 7 == 3 {
                    *v = 0.0;
                }
            }
            let codes: Vec<Vec<u8>> =
                (0..8).map(|_| (0..k).map(|_| rng.below(16) as u8).collect()).collect();
            let s: [f32; 8] = std::array::from_fn(|_| rng.normal().abs() as f32 + 0.01);
            let z: [f32; 8] = std::array::from_fn(|_| rng.below(16) as f32);
            let init = vec_f32(8, &mut rng);

            let mut acc: [f32; 8] = init.clone().try_into().unwrap();
            let views: [&[u8]; 8] = std::array::from_fn(|l| codes[l].as_slice());
            qdot8_f32(&x, views, &s, &z, &mut acc);

            for (l, c) in codes.iter().enumerate() {
                // Reference: materialize the dequantized row, then run the
                // dense kernel's chain over it.
                let dq: Vec<f32> = c.iter().map(|&q| (q as f32 - z[l]) * s[l]).collect();
                let mut want = init[l];
                for kk in 0..k {
                    if x[kk] == 0.0 {
                        continue;
                    }
                    want += x[kk] * dq[kk];
                }
                assert_eq!(acc[l].to_bits(), want.to_bits(), "k={k} lane {l}");
                assert_eq!(
                    qdot1_f32(&x, c, s[l], z[l], init[l]).to_bits(),
                    want.to_bits(),
                    "qdot1 k={k} lane {l}"
                );
            }
        }
    }

    #[test]
    fn kernels_tolerate_longer_b_slices() {
        // chol's callers pass row slices that may extend past a.len().
        let a = [1.0f64, 2.0];
        let b = [1.0f64, 1.0, 99.0, 99.0];
        assert_eq!(dot1_sub_f64(&a, &b, 10.0), 10.0 - 1.0 - 2.0);
        let mut acc = [0.0f64; 4];
        dot4_sub_f64(&a, &b, &b, &b, &b, &mut acc);
        assert!(acc.iter().all(|&v| v == -3.0));
    }
}
