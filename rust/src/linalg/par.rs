//! Row-partitioned parallel GEMM kernels on the work-stealing pool.
//!
//! Parallelism model: the output matrix is split into contiguous row
//! chunks; each pool worker steals a chunk and runs the *same* chunk
//! kernel the serial path uses (`gemm::matmul_block` /
//! `gemm::matmul_tn_block`). Because every output element's accumulation
//! order is fixed by those kernels (ascending k), the result is
//! bit-identical to the serial computation for every thread count and
//! every stealing schedule — there is no cross-thread reduction anywhere.
//!
//! Small problems run inline: below ~`PAR_FLOP_THRESHOLD` floating-point
//! operations the scoped-spawn overhead outweighs the speedup.

use super::gemm;
use super::mat::Mat;
use crate::util::pool::{chunk, Pool, SendPtr};

/// Problems below this many FLOPs run serial even on a multi-thread pool
/// (~a 128×128×128 GEMM; spawn+steal overhead is tens of microseconds).
const PAR_FLOP_THRESHOLD: f64 = 4e6;

/// Shared dispatch heuristic for GEMM-shaped work (also used by the
/// blocked SPD engine in `chol.rs`): parallelize only when the ~`2·m·k·n`
/// FLOP count clears the spawn overhead. Purely a performance knob —
/// results are bit-identical either way.
pub(crate) fn big_enough(m: usize, k: usize, n: usize) -> bool {
    2.0 * m as f64 * k as f64 * n as f64 >= PAR_FLOP_THRESHOLD
}

/// C = A[m,k] · B[k,n] on `pool`. Bit-identical to
/// [`gemm::matmul_serial`] for every thread count.
pub fn matmul_with(a: &Mat, b: &Mat, pool: &Pool) -> Mat {
    assert_eq!(
        a.cols, b.rows,
        "matmul shape mismatch: {}x{} · {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::zeros(m, n);
    if pool.threads() > 1 && m >= 2 && big_enough(m, k, n) {
        let base = SendPtr::new(c.data.as_mut_ptr());
        pool.run(m, chunk(m, pool.threads()), |r0, r1| {
            // Sound: chunks are disjoint row ranges of c.
            let rows =
                unsafe { std::slice::from_raw_parts_mut(base.0.add(r0 * n), (r1 - r0) * n) };
            gemm::matmul_block(a, b, rows, r0, r1);
        });
    } else {
        gemm::matmul_block(a, b, &mut c.data, 0, m);
    }
    c
}

/// C = A[m,k] · B[n,k]ᵀ on `pool`. Bit-identical to
/// [`gemm::matmul_nt_serial`]: both transpose B once (m ≥ 8) and reuse the
/// row-chunk matmul kernel; the skinny GEMV path (m < 8) stays serial and
/// shares the same canonical per-element order, so results are identical
/// bits whichever path a shape takes.
pub fn matmul_nt_with(a: &Mat, b: &Mat, pool: &Pool) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_nt shape mismatch");
    if a.rows >= 8 {
        return matmul_with(a, &b.transpose(), pool);
    }
    gemm::matmul_nt_small(a, b)
}

/// C = A[k,m]ᵀ · B[k,n] on `pool` (the Hessian `XᵀX` build). Bit-identical
/// to [`gemm::matmul_tn_serial`].
pub fn matmul_tn_with(a: &Mat, b: &Mat, pool: &Pool) -> Mat {
    assert_eq!(a.rows, b.rows, "matmul_tn shape mismatch");
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::zeros(m, n);
    if pool.threads() > 1 && m >= 2 && big_enough(m, k, n) {
        let base = SendPtr::new(c.data.as_mut_ptr());
        pool.run(m, chunk(m, pool.threads()), |r0, r1| {
            // Sound: chunks are disjoint row ranges of c.
            let rows =
                unsafe { std::slice::from_raw_parts_mut(base.0.add(r0 * n), (r1 - r0) * n) };
            gemm::matmul_tn_block(a, b, rows, r0, r1);
        });
    } else {
        gemm::matmul_tn_block(a, b, &mut c.data, 0, m);
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul_nt_serial, matmul_serial, matmul_tn_serial};
    use crate::util::rng::Rng;

    #[test]
    fn pooled_matmul_is_bit_identical_to_serial() {
        let mut rng = Rng::new(1);
        // Shapes straddling the FLOP threshold and the chunk grain.
        for (m, k, n) in [(2, 1024, 1024), (64, 300, 129), (257, 128, 64), (512, 64, 64)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let want = matmul_serial(&a, &b);
            for threads in [1usize, 2, 3, 4, 8] {
                let got = matmul_with(&a, &b, &Pool::new(threads));
                assert_eq!(got, want, "matmul {m}x{k}x{n} threads={threads}");
            }
        }
    }

    #[test]
    fn pooled_nt_and_tn_are_bit_identical_to_serial() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(200, 256, 1.0, &mut rng);
        let b = Mat::randn(96, 256, 1.0, &mut rng);
        let want_nt = matmul_nt_serial(&a, &b);
        let x = Mat::randn(1024, 96, 1.0, &mut rng);
        let want_tn = matmul_tn_serial(&x, &x);
        for threads in [2usize, 4, 7] {
            let pool = Pool::new(threads);
            assert_eq!(matmul_nt_with(&a, &b, &pool), want_nt, "nt threads={threads}");
            assert_eq!(matmul_tn_with(&x, &x, &pool), want_tn, "tn threads={threads}");
        }
    }

    #[test]
    fn skinny_nt_uses_dot_path_on_any_pool() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(3, 64, 1.0, &mut rng);
        let b = Mat::randn(40, 64, 1.0, &mut rng);
        assert_eq!(matmul_nt_with(&a, &b, &Pool::new(4)), matmul_nt_serial(&a, &b));
    }

    #[test]
    fn degenerate_shapes_survive_the_pool() {
        let pool = Pool::new(4);
        let a = Mat::zeros(0, 5);
        let b = Mat::zeros(5, 3);
        let c = matmul_with(&a, &b, &pool);
        assert_eq!((c.rows, c.cols), (0, 3));
        let a2 = Mat::zeros(4, 0);
        let b2 = Mat::zeros(0, 3);
        let c2 = matmul_with(&a2, &b2, &pool);
        assert_eq!((c2.rows, c2.cols), (4, 3));
        assert!(c2.data.iter().all(|&v| v == 0.0));
    }
}
