//! Row-major dense matrices. `Mat` (f32) is the workhorse for weights and
//! activations; `Mat64` is used where factorization accuracy matters
//! (Hessian inverses in GPTQ/QEP).

use crate::util::rng::Rng;

/// Row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn randn(rows: usize, cols: usize, sigma: f32, rng: &mut Rng) -> Mat {
        Mat { rows, cols, data: rng.normal_vec(rows * cols, sigma) }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.cols;
        &mut self.data[r * c..(r + 1) * c]
    }

    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.at(r, c)).collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on larger matrices.
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        t.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        t
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += *b;
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| a - b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| a + b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn scale(&mut self, s: f32) {
        for v in self.data.iter_mut() {
            *v *= s;
        }
    }

    /// Scale column `c` by `s` (used by AWQ's per-input-channel scaling).
    pub fn scale_col(&mut self, c: usize, s: f32) {
        for r in 0..self.rows {
            self.data[r * self.cols + c] *= s;
        }
    }

    /// Scale row `r` by `s`.
    pub fn scale_row(&mut self, r: usize, s: f32) {
        for v in self.row_mut(r) {
            *v *= s;
        }
    }

    /// Squared Frobenius norm, accumulated in f64 (the paper's Δ metric is
    /// a squared Frobenius norm — Eq. 2).
    pub fn frob_sq(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    pub fn frob(&self) -> f64 {
        self.frob_sq().sqrt()
    }

    /// Max |entry|.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// Largest singular value estimate via a few power iterations on AᵀA.
    /// Used by the error-growth experiments (spectral norm ‖W‖₂).
    pub fn spectral_norm_est(&self, iters: usize, rng: &mut Rng) -> f64 {
        let n = self.cols;
        let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let norm = |x: &[f64]| x.iter().map(|a| a * a).sum::<f64>().sqrt();
        let nv = norm(&v).max(1e-30);
        v.iter_mut().for_each(|x| *x /= nv);
        let mut sigma = 0.0;
        for _ in 0..iters {
            // u = A v ; w = Aᵀ u
            let mut u = vec![0.0f64; self.rows];
            for r in 0..self.rows {
                let row = self.row(r);
                let mut acc = 0.0f64;
                for c in 0..n {
                    acc += row[c] as f64 * v[c];
                }
                u[r] = acc;
            }
            let mut w = vec![0.0f64; n];
            for r in 0..self.rows {
                let row = self.row(r);
                let ur = u[r];
                for c in 0..n {
                    w[c] += row[c] as f64 * ur;
                }
            }
            let nw = norm(&w).max(1e-30);
            sigma = norm(&u);
            v = w.iter().map(|x| x / nw).collect();
        }
        sigma
    }

    pub fn to_f64(&self) -> Mat64 {
        Mat64 {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| v as f64).collect(),
        }
    }

    /// Select a contiguous block of columns [c0, c1).
    pub fn cols_slice(&self, c0: usize, c1: usize) -> Mat {
        assert!(c0 <= c1 && c1 <= self.cols);
        let mut out = Mat::zeros(self.rows, c1 - c0);
        for r in 0..self.rows {
            out.row_mut(r).copy_from_slice(&self.row(r)[c0..c1]);
        }
        out
    }

    /// Vertically stack matrices with equal column counts.
    pub fn vstack(parts: &[&Mat]) -> Mat {
        assert!(!parts.is_empty());
        let cols = parts[0].cols;
        let rows: usize = parts.iter().map(|p| p.rows).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for p in parts {
            assert_eq!(p.cols, cols);
            data.extend_from_slice(&p.data);
        }
        Mat { rows, cols, data }
    }
}

/// Row-major f64 matrix for factorization-grade numerics.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat64 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat64 {
    pub fn zeros(rows: usize, cols: usize) -> Mat64 {
        Mat64 { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Mat64 {
        let mut m = Mat64::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn to_f32(&self) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| v as f32).collect(),
        }
    }

    /// Add `v` to every diagonal entry (Hessian damping, App. B.1).
    pub fn add_diag(&mut self, v: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self.data[i * self.cols + i] += v;
        }
    }

    /// Mean of the diagonal (GPTQ's damping scale).
    pub fn mean_diag(&self) -> f64 {
        let n = self.rows.min(self.cols);
        if n == 0 {
            return 0.0;
        }
        (0..n).map(|i| self.data[i * self.cols + i]).sum::<f64>() / n as f64
    }

    pub fn matmul(&self, other: &Mat64) -> Mat64 {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat64::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.at(i, k);
                if a == 0.0 {
                    continue;
                }
                let brow = other.row(k);
                let orow = &mut out.data[i * other.cols..(i + 1) * other.cols];
                for j in 0..other.cols {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    pub fn frob_sq(&self) -> f64 {
        self.data.iter().map(|&v| v * v).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_and_rows() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.at(0, 2), 3.0);
        assert_eq!(m.at(1, 0), 4.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
        assert_eq!(m.col(1), vec![2., 5.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(1);
        let m = Mat::randn(37, 53, 1.0, &mut rng);
        let tt = m.transpose().transpose();
        assert_eq!(m, tt);
    }

    #[test]
    fn frobenius_norm() {
        let m = Mat::from_vec(2, 2, vec![3., 0., 0., 4.]);
        assert!((m.frob() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn spectral_norm_of_diag() {
        let mut rng = Rng::new(2);
        let mut m = Mat::zeros(4, 4);
        for (i, s) in [1.0f32, 5.0, 2.0, 0.5].iter().enumerate() {
            *m.at_mut(i, i) = *s;
        }
        let est = m.spectral_norm_est(50, &mut rng);
        assert!((est - 5.0).abs() < 1e-3, "est {est}");
    }

    #[test]
    fn cols_slice_and_vstack() {
        let m = Mat::from_vec(2, 4, vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let s = m.cols_slice(1, 3);
        assert_eq!(s.data, vec![2., 3., 6., 7.]);
        let v = Mat::vstack(&[&m, &m]);
        assert_eq!(v.rows, 4);
        assert_eq!(v.row(2), m.row(0));
    }

    #[test]
    fn mat64_damping() {
        let mut h = Mat64::eye(3);
        *h.at_mut(1, 1) = 3.0;
        assert!((h.mean_diag() - (1.0 + 3.0 + 1.0) / 3.0).abs() < 1e-12);
        h.add_diag(0.5);
        assert_eq!(h.at(0, 0), 1.5);
    }

    #[test]
    fn scale_col_row() {
        let mut m = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        m.scale_col(1, 10.0);
        assert_eq!(m.data, vec![1., 20., 3., 40.]);
        m.scale_row(0, 2.0);
        assert_eq!(m.data, vec![2., 40., 3., 40.]);
    }
}
