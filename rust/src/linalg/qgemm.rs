//! Fused dequantize×GEMM: `y = x · dq(W)ᵀ` computed directly from packed
//! low-bit codes plus per-group scales/zeros, never materializing the f32
//! weight matrix. This is the serving-path speed unlock: at INT4g32 a
//! weight row streams ~4× fewer bytes than its f32 form, and single-token
//! decode is memory-bandwidth-bound, so tokens/sec follows the traffic.
//!
//! Bit-identity contract: every output element accumulates in ascending
//! `k` with the `x[k] == 0.0` skip — term-for-term the chain that
//! [`super::gemm`]'s canonical kernels run over the *dequantized* matrix,
//! with the dequantization expression `(code − zero)·scale` (exactly
//! `QuantizedTensor::dequantize`'s) fused into each term. Quantization
//! groups are walked in ascending-`k` order, so group boundaries never
//! reorder the chain; see [`super::micro::qdot8_f32`]. The result is
//! bitwise-identical to dequantize-then-`matmul_nt` for every shape,
//! thread count, and group length — gated here and in
//! `tests/parallel_equivalence.rs`.
//!
//! Parallelism: the serial kernel is column-major over output columns
//! (weight rows), so the pooled path partitions *columns* across workers —
//! decode batches are short (`m` = number of in-flight sessions) and wide
//! (`n` = dim or ffn), the opposite aspect ratio of the training GEMMs.
//! Each worker writes a disjoint set of `y[i·n + j]` elements through a
//! shared base pointer, exactly the [`crate::util::pool::SendPtr`] idiom
//! of the row-partitioned kernels.

use super::mat::Mat;
use super::micro;
use crate::util::pool::{chunk, Pool, SendPtr};

/// Borrowed view of a packed quantized weight matrix, row-major codes
/// (`rows × cols`) with `rows × n_groups` scale/zero pairs — the layout
/// of `crate::quant::QuantizedTensor`, decoupled so `linalg` does not
/// depend on `quant`. Obtain one via `QuantizedTensor::view()`.
#[derive(Clone, Copy, Debug)]
pub struct QWeightView<'a> {
    /// Output features (weight rows; `y` columns).
    pub rows: usize,
    /// Input features (weight columns; the contraction dimension).
    pub cols: usize,
    /// Quantization group length along `cols`.
    pub group_len: usize,
    /// Packed codes, one byte per weight, row-major `[rows × cols]`.
    pub codes: &'a [u8],
    /// Per-group scales, `[rows × n_groups]`.
    pub scales: &'a [f32],
    /// Per-group zero points, `[rows × n_groups]`.
    pub zeros: &'a [f32],
}

impl QWeightView<'_> {
    /// Number of quantization groups per row.
    pub fn n_groups(&self) -> usize {
        self.cols.div_ceil(self.group_len)
    }

    fn validate(&self) {
        assert!(self.group_len > 0, "qgemm: zero group length");
        assert_eq!(self.codes.len(), self.rows * self.cols, "qgemm: codes length");
        let ng = self.n_groups();
        assert_eq!(self.scales.len(), self.rows * ng, "qgemm: scales length");
        assert_eq!(self.zeros.len(), self.rows * ng, "qgemm: zeros length");
    }
}

/// `y = x[m,k] · dq(W)[n,k]ᵀ` on the global pool — the quantized twin of
/// [`super::gemm::matmul_nt`].
pub fn qgemm_nt(x: &Mat, w: &QWeightView) -> Mat {
    qgemm_nt_with(x, w, &crate::util::pool::global())
}

/// Single-threaded `y = x · dq(W)ᵀ` — the reference the pooled path must
/// match bit-for-bit (and the bench baseline).
pub fn qgemm_nt_serial(x: &Mat, w: &QWeightView) -> Mat {
    w.validate();
    assert_eq!(x.cols, w.cols, "qgemm shape mismatch: {}x{} · ({}x{})ᵀ", x.rows, x.cols, w.rows, w.cols);
    let mut y = Mat::zeros(x.rows, w.rows);
    // Sound: exclusive access to all of y.
    unsafe { qgemm_cols(x, w, y.data.as_mut_ptr(), 0, w.rows) };
    y
}

/// `y = x · dq(W)ᵀ` on `pool`. Bit-identical to [`qgemm_nt_serial`] for
/// every thread count: workers run the same column kernel over disjoint
/// column ranges, and each element's chain is fixed by construction.
pub fn qgemm_nt_with(x: &Mat, w: &QWeightView, pool: &Pool) -> Mat {
    w.validate();
    assert_eq!(x.cols, w.cols, "qgemm shape mismatch: {}x{} · ({}x{})ᵀ", x.rows, x.cols, w.rows, w.cols);
    let (m, k, n) = (x.rows, x.cols, w.rows);
    let mut y = Mat::zeros(m, n);
    if pool.threads() > 1 && m >= 1 && n >= 2 && super::par::big_enough(m, k, n) {
        let base = SendPtr::new(y.data.as_mut_ptr());
        pool.run(n, chunk(n, pool.threads()), |j0, j1| {
            // Sound: chunks are disjoint column ranges of y.
            unsafe { qgemm_cols(x, w, base.0, j0, j1) };
        });
    } else {
        unsafe { qgemm_cols(x, w, y.data.as_mut_ptr(), 0, n) };
    }
    y
}

/// Output columns `[j0, j1)` of `y = x · dq(W)ᵀ`, all rows, written to
/// `y_base[i·n + j]`. Whole 8-column tiles run through
/// [`micro::qdot8_f32`], the ragged tail through [`micro::qdot1_f32`];
/// groups advance in ascending `k`, so every element keeps the canonical
/// scalar chain either way.
///
/// Raw-pointer output on purpose: column partitions write interleaved
/// (non-contiguous) element sets of `y`, which disjoint `&mut` slices
/// cannot express.
///
/// # Safety
///
/// `y_base[i·n + j]` must be valid to write for all `i < x.rows`,
/// `j ∈ [j0, j1)`, and concurrent callers must use disjoint `j` ranges.
unsafe fn qgemm_cols(x: &Mat, w: &QWeightView, y_base: *mut f32, j0: usize, j1: usize) {
    let (m, k, n) = (x.rows, x.cols, w.rows);
    let glen = w.group_len;
    let ng = w.n_groups();
    for i in 0..m {
        let xrow = &x.data[i * k..(i + 1) * k];
        let yrow = y_base.add(i * n);
        let mut j = j0;
        while j + 8 <= j1 {
            let mut acc = [0.0f32; 8];
            for g in 0..ng {
                let c0 = g * glen;
                let c1 = (c0 + glen).min(k);
                let cv: [&[u8]; 8] =
                    std::array::from_fn(|l| &w.codes[(j + l) * k + c0..(j + l) * k + c1]);
                let s: [f32; 8] = std::array::from_fn(|l| w.scales[(j + l) * ng + g]);
                let z: [f32; 8] = std::array::from_fn(|l| w.zeros[(j + l) * ng + g]);
                micro::qdot8_f32(&xrow[c0..c1], cv, &s, &z, &mut acc);
            }
            for (l, &v) in acc.iter().enumerate() {
                *yrow.add(j + l) = v;
            }
            j += 8;
        }
        while j < j1 {
            let mut v = 0.0f32;
            for g in 0..ng {
                let c0 = g * glen;
                let c1 = (c0 + glen).min(k);
                v = micro::qdot1_f32(
                    &xrow[c0..c1],
                    &w.codes[j * k + c0..j * k + c1],
                    w.scales[j * ng + g],
                    w.zeros[j * ng + g],
                    v,
                );
            }
            *yrow.add(j) = v;
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul_nt_serial;
    use crate::quant::{QuantConfig, QuantizedTensor};
    use crate::util::rng::Rng;

    fn planted(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        let mut x = Mat::randn(rows, cols, 1.0, rng);
        // Exact zeros exercise the canonical skip branch.
        for (i, v) in x.data.iter_mut().enumerate() {
            if i % 5 == 2 {
                *v = 0.0;
            }
        }
        x
    }

    #[test]
    fn fused_matches_dequantize_then_matmul_bitwise() {
        let mut rng = Rng::new(11);
        for &(m, k, n) in &[(1usize, 32usize, 24usize), (3, 48, 20), (8, 40, 3), (17, 64, 33)] {
            for cfg in [QuantConfig::int_group(4, 16), QuantConfig::int(3)] {
                let x = planted(m, k, &mut rng);
                let w = Mat::randn(n, k, 1.0, &mut rng);
                let qt = QuantizedTensor::from_mat(&w, &cfg);
                let want = matmul_nt_serial(&x, &qt.dequantize());
                let got = qgemm_nt_serial(&x, &qt.view());
                assert_eq!(got, want, "m={m} k={k} n={n} cfg={}", cfg.label());
            }
        }
    }

    #[test]
    fn pooled_fused_is_bit_identical_to_serial() {
        let mut rng = Rng::new(12);
        // Big enough to clear the FLOP threshold so the pool really runs.
        let x = planted(4, 512, &mut rng);
        let w = Mat::randn(1024, 512, 1.0, &mut rng);
        let qt = QuantizedTensor::from_mat(&w, &QuantConfig::int_group(4, 32));
        let view = qt.view();
        let want = qgemm_nt_serial(&x, &view);
        for threads in [1usize, 2, 3, 4, 8] {
            let got = qgemm_nt_with(&x, &view, &Pool::new(threads));
            assert_eq!(got, want, "threads={threads}");
        }
        assert_eq!(want, matmul_nt_serial(&x, &qt.dequantize()));
    }

    #[test]
    fn degenerate_shapes_survive() {
        let x = Mat::zeros(0, 16);
        let w = Mat::zeros(4, 16);
        let qt = QuantizedTensor::from_mat(&w, &QuantConfig::int_group(4, 8));
        let y = qgemm_nt_serial(&x, &qt.view());
        assert_eq!((y.rows, y.cols), (0, 4));
    }
}
