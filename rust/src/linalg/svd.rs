//! Deterministic singular value decomposition.
//!
//! Two engines behind one surface:
//!
//! * **One-sided Jacobi** (Hestenes): rotations orthogonalize column pairs
//!   of a working copy of `A` in a *fixed cyclic order*, accumulating the
//!   right singular vectors. All reductions (column dots, norms) run
//!   serially in f64 in ascending index order; the only pooled work is the
//!   element-wise rotation update of two disjoint columns, which has no
//!   cross-element dependency — so the factorization is bit-identical for
//!   every thread count *and* every pool grain ("block size").
//! * **Seeded randomized range-finder** (Halko/Martinsson/Tropp) for
//!   truncated factorizations of large matrices: a name-seeded Gaussian
//!   sketch `Y = A·Ω`, deterministic modified Gram–Schmidt `Q`, and a small
//!   Jacobi SVD of `B = Qᵀ·A`. The two GEMMs route through the pooled
//!   row-partitioned kernels ([`crate::linalg::par`]), which uphold the
//!   repo-wide bit-identity contract and share the `micro.rs` register
//!   tiles with the rest of the hot path.
//!
//! The seed is the caller's responsibility and is expected to be
//! name-derived (`fnv1a(layer_name)`-style), exactly like the quantizer
//! seeds — so a sharded sweep and a local run sketch with identical Ω.
//!
//! ```
//! use qep::linalg::{svd, Mat};
//! use qep::util::rng::Rng;
//! let a = Mat::randn(12, 7, 1.0, &mut Rng::new(3));
//! let f = svd(&a);
//! assert_eq!(f.rank(), 7);
//! assert!((a.sub(&f.reconstruct())).frob() < 1e-3 * a.frob().max(1.0));
//! ```

use super::mat::Mat;
use super::par::{matmul_tn_with, matmul_with};
use crate::util::pool::{self, chunk, Pool, SendPtr};

/// Largest `min(m, n)` the truncated path hands to the full Jacobi engine
/// directly; above it (and when the target rank is small enough for a
/// sketch to pay off) the randomized range-finder runs first.
const JACOBI_DIRECT_MAX: usize = 96;

/// Range-finder oversampling columns beyond the requested rank.
const OVERSAMPLE: usize = 8;

/// Relative off-diagonal tolerance for Jacobi convergence.
const JACOBI_TOL: f64 = 1e-12;

/// Jacobi sweep cap (each sweep visits every column pair once).
const MAX_SWEEPS: usize = 64;

/// A (possibly truncated) factorization `A ≈ U · diag(s) · Vᵀ`.
///
/// `u` is `[m, r]` with orthonormal columns, `s` holds the `r` singular
/// values in non-increasing order, `vt` is `[r, n]` with orthonormal rows.
/// Columns of `u` / rows of `vt` paired with an exactly-zero singular
/// value are zero vectors (a rank-deficient input has fewer than `r`
/// meaningful directions).
#[derive(Clone, Debug, PartialEq)]
pub struct Svd {
    pub u: Mat,
    pub s: Vec<f32>,
    pub vt: Mat,
}

impl Svd {
    /// Number of retained singular triplets (including exact zeros).
    pub fn rank(&self) -> usize {
        self.s.len()
    }

    /// Keep only the leading `rank` triplets.
    pub fn truncate(mut self, rank: usize) -> Svd {
        let r = rank.min(self.s.len());
        self.s.truncate(r);
        self.vt = take_rows(&self.vt, r);
        self.u = take_cols(&self.u, r);
        self
    }

    /// `U · diag(s) · Vᵀ`, accumulated serially in f64 (test/diagnostic
    /// helper; the hot paths apply the factors without materializing).
    pub fn reconstruct(&self) -> Mat {
        let (m, n, r) = (self.u.rows, self.vt.cols, self.s.len());
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let urow = self.u.row(i);
            let orow = out.row_mut(i);
            for j in 0..n {
                let mut acc = 0.0f64;
                for t in 0..r {
                    acc += urow[t] as f64 * self.s[t] as f64 * self.vt.at(t, j) as f64;
                }
                orow[j] = acc as f32;
            }
        }
        out
    }
}

fn take_rows(a: &Mat, r: usize) -> Mat {
    let mut out = Mat::zeros(r, a.cols);
    for i in 0..r {
        out.row_mut(i).copy_from_slice(a.row(i));
    }
    out
}

fn take_cols(a: &Mat, r: usize) -> Mat {
    let mut out = Mat::zeros(a.rows, r);
    for i in 0..a.rows {
        out.row_mut(i).copy_from_slice(&a.row(i)[..r]);
    }
    out
}

/// Full SVD on the process-global pool with the default rotation grain.
pub fn svd(a: &Mat) -> Svd {
    svd_with(a, &pool::global())
}

/// Full SVD on an explicit pool. Bit-identical for every thread count.
pub fn svd_with(a: &Mat, pool: &Pool) -> Svd {
    svd_with_block(a, pool, 0)
}

/// Full SVD with an explicit pool *and* rotation-update grain (`block`;
/// 0 = auto). The grain only changes how the element-wise column rotation
/// is chunked across workers — never the arithmetic — so every
/// `(threads, block)` pair produces identical bits.
pub fn svd_with_block(a: &Mat, pool: &Pool, block: usize) -> Svd {
    let (m, n) = (a.rows, a.cols);
    if m == 0 || n == 0 {
        return Svd { u: Mat::zeros(m, 0), s: Vec::new(), vt: Mat::zeros(0, n) };
    }
    if m < n {
        // One-sided Jacobi wants tall matrices; factor Aᵀ = U'ΣV'ᵀ and
        // swap: A = V'ΣU'ᵀ.
        let f = svd_with_block(&a.transpose(), pool, block);
        let u = f.vt.transpose();
        let vt = f.u.transpose();
        return Svd { u, s: f.s, vt };
    }
    jacobi_tall(a, pool, block)
}

/// Truncated rank-`rank` SVD on the process-global pool.
pub fn svd_rank(a: &Mat, rank: usize, seed: u64) -> Svd {
    svd_rank_with(a, rank, seed, &pool::global())
}

/// Truncated rank-`rank` SVD: full Jacobi for small problems, seeded
/// randomized range-finder for large ones. The engine choice depends only
/// on the shape and rank (never on the pool), and both engines are
/// bit-identical across thread counts, so the result is a pure function
/// of `(a, rank, seed)`.
pub fn svd_rank_with(a: &Mat, rank: usize, seed: u64, pool: &Pool) -> Svd {
    let (m, n) = (a.rows, a.cols);
    let kmax = m.min(n);
    let r = rank.min(kmax);
    if r == 0 {
        return Svd { u: Mat::zeros(m, 0), s: Vec::new(), vt: Mat::zeros(0, n) };
    }
    let sketch = (r + OVERSAMPLE).min(kmax);
    if kmax <= JACOBI_DIRECT_MAX || sketch * 2 >= kmax {
        return svd_with(a, pool).truncate(r);
    }
    if m < n {
        let f = svd_rank_with(&a.transpose(), rank, seed, pool);
        let u = f.vt.transpose();
        let vt = f.u.transpose();
        return Svd { u, s: f.s, vt };
    }
    // Sketch: Y = A·Ω with a seeded Gaussian Ω — deterministic by seed,
    // pooled GEMM bit-identical by the par.rs contract.
    let mut rng = crate::util::rng::Rng::new(seed);
    let omega = Mat::randn(n, sketch, 1.0, &mut rng);
    let y = matmul_with(a, &omega, pool);
    let q = mgs_orthonormalize(&y);
    // Project: B = Qᵀ·A is [sketch, n]; its SVD lifts back through Q.
    let b = matmul_tn_with(&q, a, pool);
    let fb = svd_with(&b, pool).truncate(r);
    let u = matmul_with(&q, &fb.u, pool);
    Svd { u, s: fb.s, vt: fb.vt }
}

/// Modified Gram–Schmidt with re-orthogonalization, serial f64, fixed
/// column order. Columns that collapse below tolerance become exact zero
/// columns (deterministic handling of rank-deficient sketches).
fn mgs_orthonormalize(y: &Mat) -> Mat {
    let (m, l) = (y.rows, y.cols);
    // Column-major f64 working copy.
    let mut cols: Vec<f64> = vec![0.0; m * l];
    for i in 0..m {
        let row = y.row(i);
        for j in 0..l {
            cols[j * m + i] = row[j] as f64;
        }
    }
    let scale = cols.iter().fold(0.0f64, |acc, &v| acc.max(v.abs())).max(1.0);
    let tol = 1e-12 * scale;
    for j in 0..l {
        // Two MGS passes against the already-fixed columns.
        for _pass in 0..2 {
            for k in 0..j {
                let dot: f64 = (0..m).map(|i| cols[k * m + i] * cols[j * m + i]).sum();
                for i in 0..m {
                    cols[j * m + i] -= dot * cols[k * m + i];
                }
            }
        }
        let norm: f64 = (0..m).map(|i| cols[j * m + i] * cols[j * m + i]).sum::<f64>().sqrt();
        if norm > tol {
            for i in 0..m {
                cols[j * m + i] /= norm;
            }
        } else {
            for i in 0..m {
                cols[j * m + i] = 0.0;
            }
        }
    }
    let mut q = Mat::zeros(m, l);
    for i in 0..m {
        let row = q.row_mut(i);
        for j in 0..l {
            row[j] = cols[j * m + i] as f32;
        }
    }
    q
}

/// One-sided Jacobi on a tall (`m >= n`) matrix. Fixed cyclic pair order;
/// dots and norms are serial f64; the two-column rotation update is
/// element-wise and may be chunked across the pool without changing bits.
fn jacobi_tall(a: &Mat, pool: &Pool, block: usize) -> Svd {
    let (m, n) = (a.rows, a.cols);
    // G starts as A (column-major f64); V starts as I (column-major f64).
    let mut g: Vec<f64> = vec![0.0; m * n];
    for i in 0..m {
        let row = a.row(i);
        for j in 0..n {
            g[j * m + i] = row[j] as f64;
        }
    }
    let mut v: Vec<f64> = vec![0.0; n * n];
    for j in 0..n {
        v[j * n + j] = 1.0;
    }

    let grain = if block == 0 { chunk(m, pool.threads()) } else { block };
    for _sweep in 0..MAX_SWEEPS {
        let mut rotated = false;
        for p in 0..n.saturating_sub(1) {
            for q in p + 1..n {
                // Serial fixed-order reductions: αₚ, α_q, γ.
                let (mut alpha, mut beta, mut gamma) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let gp = g[p * m + i];
                    let gq = g[q * m + i];
                    alpha += gp * gp;
                    beta += gq * gq;
                    gamma += gp * gq;
                }
                if gamma == 0.0 || gamma.abs() <= JACOBI_TOL * (alpha * beta).sqrt() {
                    continue;
                }
                rotated = true;
                let tau = (beta - alpha) / (2.0 * gamma);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                rotate_pair(&mut g, m, (p, q), (c, s), pool, grain);
                // V is n×n — small next to G; rotate serially.
                rotate_serial(&mut v, n, p, q, c, s);
            }
        }
        if !rotated {
            break;
        }
    }

    // Singular values = column norms of G, sorted descending (stable on
    // the original index, so ties order deterministically).
    let norms: Vec<f64> = (0..n)
        .map(|j| (0..m).map(|i| g[j * m + i] * g[j * m + i]).sum::<f64>().sqrt())
        .collect();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&x, &y| norms[y].partial_cmp(&norms[x]).unwrap().then(x.cmp(&y)));

    let mut u = Mat::zeros(m, n);
    let mut s = vec![0.0f32; n];
    let mut vt = Mat::zeros(n, n);
    for (slot, &j) in order.iter().enumerate() {
        let norm = norms[j];
        s[slot] = norm as f32;
        if norm > 0.0 {
            for i in 0..m {
                *u.at_mut(i, slot) = (g[j * m + i] / norm) as f32;
            }
        }
        for i in 0..n {
            *vt.at_mut(slot, i) = v[j * n + i] as f32;
        }
    }
    Svd { u, s, vt }
}

/// Apply the rotation to columns `p`, `q` of the column-major `[m, _]`
/// buffer. Element `i`'s update touches only element `i` of each column,
/// so pool chunking cannot change any result bit.
fn rotate_pair(
    g: &mut [f64],
    m: usize,
    pq: (usize, usize),
    rot: (f64, f64),
    pool: &Pool,
    grain: usize,
) {
    let (p, q) = pq;
    let (c, s) = rot;
    debug_assert!(p < q);
    let (left, right) = g.split_at_mut(q * m);
    let gp = &mut left[p * m..(p + 1) * m];
    let gq = &mut right[..m];
    if pool.threads() > 1 && m >= 64 {
        let bp = SendPtr::new(gp.as_mut_ptr());
        let bq = SendPtr::new(gq.as_mut_ptr());
        pool.run(m, grain, |i0, i1| {
            for i in i0..i1 {
                // Sound: chunks are disjoint index ranges of both columns.
                unsafe {
                    let a = *bp.0.add(i);
                    let b = *bq.0.add(i);
                    *bp.0.add(i) = c * a - s * b;
                    *bq.0.add(i) = s * a + c * b;
                }
            }
        });
    } else {
        for i in 0..m {
            let a = gp[i];
            let b = gq[i];
            gp[i] = c * a - s * b;
            gq[i] = s * a + c * b;
        }
    }
}

fn rotate_serial(v: &mut [f64], m: usize, p: usize, q: usize, c: f64, s: f64) {
    let (left, right) = v.split_at_mut(q * m);
    let vp = &mut left[p * m..(p + 1) * m];
    let vq = &mut right[..m];
    for i in 0..m {
        let a = vp[i];
        let b = vq[i];
        vp[i] = c * a - s * b;
        vq[i] = s * a + c * b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn recon_err(a: &Mat, f: &Svd) -> f64 {
        a.sub(&f.reconstruct()).frob()
    }

    #[test]
    fn full_factorization_reconstructs() {
        let mut rng = Rng::new(7);
        for (m, n) in [(9usize, 9usize), (17, 5), (5, 17)] {
            let a = Mat::randn(m, n, 1.0, &mut rng);
            let f = svd_with(&a, &Pool::serial());
            assert_eq!(f.rank(), m.min(n));
            assert!(recon_err(&a, &f) < 1e-3, "{m}x{n}: err {}", recon_err(&a, &f));
            for w in f.s.windows(2) {
                assert!(w[0] >= w[1], "singular values must be sorted: {:?}", f.s);
            }
        }
    }

    #[test]
    fn truncated_matches_full_prefix() {
        let mut rng = Rng::new(11);
        let a = Mat::randn(20, 12, 1.0, &mut rng);
        let full = svd_with(&a, &Pool::serial());
        let trunc = svd_with(&a, &Pool::serial()).truncate(4);
        assert_eq!(trunc.s, full.s[..4].to_vec());
        assert_eq!(trunc.u.cols, 4);
        assert_eq!(trunc.vt.rows, 4);
    }

    #[test]
    fn randomized_path_captures_dominant_subspace() {
        // A rank-3 matrix plus tiny noise, big enough to take the
        // range-finder path: rank-8 recovery must be near-exact.
        let mut rng = Rng::new(13);
        let u = Mat::randn(200, 3, 1.0, &mut rng);
        let v = Mat::randn(3, 150, 1.0, &mut rng);
        let mut a = matmul_with(&u, &v, &Pool::serial());
        for x in a.data.iter_mut() {
            *x += 1e-5 * rng.normal_f32();
        }
        let f = svd_rank_with(&a, 8, 99, &Pool::serial());
        assert_eq!(f.rank(), 8);
        let rel = recon_err(&a, &f) / a.frob();
        assert!(rel < 1e-3, "relative error {rel}");
    }

    #[test]
    fn zero_matrix_and_rank_zero() {
        let z = Mat::zeros(6, 4);
        let f = svd_with(&z, &Pool::serial());
        assert!(f.s.iter().all(|&s| s == 0.0));
        assert!(f.u.data.iter().all(|&x| x == 0.0));
        let r0 = svd_rank_with(&z, 0, 1, &Pool::serial());
        assert_eq!(r0.rank(), 0);
        assert_eq!((r0.u.rows, r0.u.cols), (6, 0));
        assert_eq!((r0.vt.rows, r0.vt.cols), (0, 4));
    }
}
