//! Blocked matrix multiplication, optimized for cache locality and
//! auto-vectorization: i-k-j loop order with a contiguous j-inner loop,
//! plus k-blocking so the working set of B stays in L1/L2. This is the L3
//! hot path — QEP's correction term, Hessian builds, and every forward
//! pass run through it.
//!
//! The public `matmul` / `matmul_nt` / `matmul_tn` entry points dispatch
//! large problems to the row-partitioned parallel kernels in
//! [`super::par`] (persistent worker pool, see `crate::util::pool`).
//! Results are **bit-identical** to the `*_serial` variants for every
//! thread count: both paths run the same chunk kernels below, and each
//! output element's floating-point accumulation order is fixed by
//! construction (k ascending), independent of how rows are partitioned.
//! The contiguous inner axpy runs through the shared register-tile
//! micro-kernel ([`super::micro::axpy_f32`]) — element-wise, so tiling
//! never changes bits.

use super::mat::Mat;
use super::micro;

/// k-panel size: 256 k-steps × 4B × (inner j tile) fits comfortably in L2.
pub(crate) const KC: usize = 256;

/// C = A[m,k] · B[k,n], parallel over row blocks when the problem is large
/// enough (see [`super::par::matmul_with`]).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    super::par::matmul_with(a, b, &crate::util::pool::global())
}

/// C = A[m,k] · B[n,k]ᵀ  (i.e. rows of A dotted with rows of B).
/// This is the layout of every `x·Wᵀ` linear layer in the forward pass —
/// the single hottest operation in the repo.
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    super::par::matmul_nt_with(a, b, &crate::util::pool::global())
}

/// C = A[k,m]ᵀ · B[k,n]. Used for Hessian builds `Xᵀ X`-style products when
/// activations are stored tokens-major.
pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
    super::par::matmul_tn_with(a, b, &crate::util::pool::global())
}

/// Single-threaded C = A[m,k] · B[k,n] (the reference the parallel path
/// must match bit-for-bit; also what benches use as the speedup baseline).
pub fn matmul_serial(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(
        a.cols, b.rows,
        "matmul shape mismatch: {}x{} · {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_block(a, b, &mut c.data, 0, a.rows);
    c
}

/// Single-threaded C = A[m,k] · B[n,k]ᵀ.
///
/// §Perf: the dot-product formulation ran at ~3.3 GFLOP/s (strided
/// accumulator chains defeat the vectorizer); transposing B once and
/// dispatching to the axpy-style [`matmul_serial`] kernel runs at
/// ~7.5 GFLOP/s. The transpose is O(n·k) against O(m·n·k) multiply work,
/// negligible for every shape the model uses (m ≥ 128). For tiny m
/// (serving's single-token decode rows) we keep a GEMV-style path —
/// canonicalized onto the same per-element operation order as the wide
/// path, so both produce identical bits for every row (the KV-cache
/// decode ≡ full-recompute gate in `tests/serve_engine.rs` rests on
/// this).
pub fn matmul_nt_serial(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_nt shape mismatch");
    if a.rows >= 8 {
        return matmul_serial(a, &b.transpose());
    }
    matmul_nt_small(a, b)
}

/// Single-threaded C = A[k,m]ᵀ · B[k,n].
pub fn matmul_tn_serial(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows, "matmul_tn shape mismatch");
    let mut c = Mat::zeros(a.cols, b.cols);
    matmul_tn_block(a, b, &mut c.data, 0, a.cols);
    c
}

/// Compute rows `[r0, r1)` of C = A·B into `c` (the slice holding exactly
/// those rows). Every output element accumulates in ascending-k order —
/// k-panels ascending, k ascending within a panel — so any row
/// partitioning yields bit-identical results.
pub(crate) fn matmul_block(a: &Mat, b: &Mat, c: &mut [f32], r0: usize, r1: usize) {
    let (k, n) = (a.cols, b.cols);
    debug_assert_eq!(c.len(), (r1 - r0) * n);
    for kb in (0..k).step_by(KC) {
        let kend = (kb + KC).min(k);
        for i in r0..r1 {
            let arow = &a.data[i * k..(i + 1) * k];
            let crow = &mut c[(i - r0) * n..(i - r0 + 1) * n];
            for kk in kb..kend {
                let av = arow[kk];
                if av == 0.0 {
                    continue;
                }
                let brow = &b.data[kk * n..(kk + 1) * n];
                // Contiguous FMA-friendly inner axpy via the shared
                // 8-wide register tile (bit-identical to the plain loop).
                micro::axpy_f32(av, brow, crow);
            }
        }
    }
}

/// Compute rows `[r0, r1)` of C = Aᵀ·B (A stored [k, m]) into `c`. Same
/// ascending-k accumulation order as [`matmul_block`]; the k-panel keeps
/// the streamed B rows hot in L2 across the chunk's output rows.
pub(crate) fn matmul_tn_block(a: &Mat, b: &Mat, c: &mut [f32], r0: usize, r1: usize) {
    let (k, m, n) = (a.rows, a.cols, b.cols);
    debug_assert_eq!(c.len(), (r1 - r0) * n);
    for kb in (0..k).step_by(KC) {
        let kend = (kb + KC).min(k);
        for i in r0..r1 {
            let crow = &mut c[(i - r0) * n..(i - r0 + 1) * n];
            for kk in kb..kend {
                let av = a.data[kk * m + i];
                if av == 0.0 {
                    continue;
                }
                let brow = &b.data[kk * n..(kk + 1) * n];
                micro::axpy_f32(av, brow, crow);
            }
        }
    }
}

/// GEMV path for skinny `matmul_nt` (m < 8), where the transpose
/// overhead is not amortized. Runs through the 8-wide GEMV dot tile
/// ([`micro::dot8_f32`] + the [`micro::dot1_f32`] tail), whose
/// per-element chain — ascending `k`, skipping `a[i][k] == 0.0` — is
/// exactly [`matmul_block`]'s. `matmul_nt` therefore has ONE canonical
/// per-element order for every `m`: a 1-row decode step and a
/// seq_len-row training pass produce identical bits row-for-row.
pub(crate) fn matmul_nt_small(a: &Mat, b: &Mat) -> Mat {
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let crow = &mut c.data[i * n..(i + 1) * n];
        let mut j = 0;
        while j + 8 <= n {
            let bv: [&[f32]; 8] =
                std::array::from_fn(|l| &b.data[(j + l) * k..(j + l + 1) * k]);
            let mut acc = [0.0f32; 8];
            micro::dot8_f32(arow, bv, &mut acc);
            crow[j..j + 8].copy_from_slice(&acc);
            j += 8;
        }
        while j < n {
            crow[j] = micro::dot1_f32(arow, &b.data[j * k..(j + 1) * k], 0.0);
            j += 1;
        }
    }
    c
}

/// Unrolled dot product with 4 independent accumulators (breaks the FP add
/// dependency chain; ~3-4x over the naive loop on one core).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 8;
        s0 += a[i] * b[i] + a[i + 4] * b[i + 4];
        s1 += a[i + 1] * b[i + 1] + a[i + 5] * b[i + 5];
        s2 += a[i + 2] * b[i + 2] + a[i + 6] * b[i + 6];
        s3 += a[i + 3] * b[i + 3] + a[i + 7] * b[i + 7];
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..n {
        tail += a[i] * b[i];
    }
    s0 + s1 + s2 + s3 + tail
}

/// y += alpha * x  (axpy).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f64;
                for k in 0..a.cols {
                    s += a.at(i, k) as f64 * b.at(k, j) as f64;
                }
                *c.at_mut(i, j) = s as f32;
            }
        }
        c
    }

    fn assert_close(a: &Mat, b: &Mat, tol: f32) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(b.data.iter()) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (17, 33, 9), (64, 300, 48)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            assert_close(&matmul(&a, &b), &naive(&a, &b), 1e-4);
        }
    }

    #[test]
    fn nt_and_tn_match_transposed_naive() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(13, 29, 1.0, &mut rng);
        let b = Mat::randn(21, 29, 1.0, &mut rng);
        assert_close(&matmul_nt(&a, &b), &naive(&a, &b.transpose()), 1e-4);
        let a2 = Mat::randn(29, 13, 1.0, &mut rng);
        let b2 = Mat::randn(29, 21, 1.0, &mut rng);
        assert_close(&matmul_tn(&a2, &b2), &naive(&a2.transpose(), &b2), 1e-4);
    }

    #[test]
    fn dispatched_equals_serial_bitwise() {
        // The auto-dispatching entry points must agree with the serial
        // kernels to the bit, whatever the global pool looks like.
        let mut rng = Rng::new(7);
        let a = Mat::randn(96, 200, 1.0, &mut rng);
        let b = Mat::randn(200, 64, 1.0, &mut rng);
        assert_eq!(matmul(&a, &b), matmul_serial(&a, &b));
        let bt = Mat::randn(64, 200, 1.0, &mut rng);
        assert_eq!(matmul_nt(&a, &bt), matmul_nt_serial(&a, &bt));
        let x = Mat::randn(300, 72, 1.0, &mut rng);
        assert_eq!(matmul_tn(&x, &x), matmul_tn_serial(&x, &x));
    }

    #[test]
    fn nt_small_path_matches_wide_path_per_row_bitwise() {
        // The keystone of KV-cache decode ≡ full recompute: the skinny
        // GEMV path (m < 8) and the wide transpose path (m ≥ 8) must
        // produce identical bits row-for-row, so a 1-row decode linear
        // reproduces the corresponding row of the full-segment linear.
        let mut rng = Rng::new(9);
        let mut a = Mat::randn(8, 40, 1.0, &mut rng);
        // Plant exact zeros to exercise the shared skip branch.
        for (i, v) in a.data.iter_mut().enumerate() {
            if i % 6 == 1 {
                *v = 0.0;
            }
        }
        let b = Mat::randn(29, 40, 1.0, &mut rng);
        let wide = matmul_nt_serial(&a, &b); // m = 8 → transpose path
        for i in 0..a.rows {
            let ai = Mat::from_vec(1, a.cols, a.data[i * a.cols..(i + 1) * a.cols].to_vec());
            let got = matmul_nt_serial(&ai, &b); // m = 1 → GEMV path
            assert_eq!(&got.data[..], &wide.data[i * b.rows..(i + 1) * b.rows], "row {i}");
        }
        // And a mid-size skinny m, exercising both tile and tail columns.
        let a3 = Mat::from_vec(3, a.cols, a.data[..3 * a.cols].to_vec());
        let got3 = matmul_nt_serial(&a3, &b);
        assert_eq!(&got3.data[..], &wide.data[..3 * b.rows]);
    }

    #[test]
    fn dot_matches_naive_on_odd_lengths() {
        let mut rng = Rng::new(3);
        for n in [0, 1, 7, 8, 9, 31, 64, 100] {
            let x = rng.normal_vec(n, 1.0);
            let y = rng.normal_vec(n, 1.0);
            let want: f32 = x.iter().zip(y.iter()).map(|(a, b)| a * b).sum();
            assert!((dot(&x, &y) - want).abs() < 1e-3 * (1.0 + want.abs()));
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(8, 8, 1.0, &mut rng);
        let i = Mat::eye(8);
        assert_close(&matmul(&a, &i), &a, 1e-6);
        assert_close(&matmul(&i, &a), &a, 1e-6);
    }
}
