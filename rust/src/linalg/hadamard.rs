//! Fast Walsh–Hadamard transform and the randomized signed-Hadamard
//! rotation used by QuIP's incoherence preprocessing (Chee et al., 2023):
//! conjugate the layer problem with `U = H_n·diag(s)/√n`, quantize in the
//! rotated basis where weight magnitudes are spread out, then rotate back.

use super::mat::Mat;
use crate::util::rng::Rng;

/// In-place unnormalized fast Walsh–Hadamard transform; `x.len()` must be a
/// power of two. Applying twice multiplies by n.
pub fn fwht_inplace(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT length {n} not a power of two");
    let mut h = 1;
    while h < n {
        for block in (0..n).step_by(h * 2) {
            for i in block..block + h {
                let (a, b) = (x[i], x[i + h]);
                x[i] = a + b;
                x[i + h] = a - b;
            }
        }
        h *= 2;
    }
}

/// Apply the orthonormal Hadamard (H/√n) to every row of `m` in place.
pub fn hadamard_rows(m: &mut Mat) {
    let scale = 1.0 / (m.cols as f32).sqrt();
    for r in 0..m.rows {
        let row = m.row_mut(r);
        fwht_inplace(row);
        for v in row.iter_mut() {
            *v *= scale;
        }
    }
}

/// A randomized signed Hadamard rotation `Q = H·diag(s)/√n` with s ∈ {±1}ⁿ.
/// `Q` is orthogonal; `apply`/`apply_t` multiply vectors by Q / Qᵀ.
#[derive(Clone)]
pub struct SignedHadamard {
    pub n: usize,
    pub signs: Vec<f32>,
}

impl SignedHadamard {
    pub fn new(n: usize, rng: &mut Rng) -> SignedHadamard {
        assert!(n.is_power_of_two(), "SignedHadamard needs power-of-two dim, got {n}");
        SignedHadamard { n, signs: (0..n).map(|_| rng.sign()).collect() }
    }

    /// y = Q·x  (x modified in place): diag(s) then H/√n.
    pub fn apply(&self, x: &mut [f32]) {
        assert_eq!(x.len(), self.n);
        for (v, s) in x.iter_mut().zip(self.signs.iter()) {
            *v *= s;
        }
        fwht_inplace(x);
        let scale = 1.0 / (self.n as f32).sqrt();
        for v in x.iter_mut() {
            *v *= scale;
        }
    }

    /// y = Qᵀ·x: H/√n then diag(s) (H is symmetric).
    pub fn apply_t(&self, x: &mut [f32]) {
        assert_eq!(x.len(), self.n);
        fwht_inplace(x);
        let scale = 1.0 / (self.n as f32).sqrt();
        for (v, s) in x.iter_mut().zip(self.signs.iter()) {
            *v = *v * scale * s;
        }
    }

    /// Rows of `m` each multiplied by Qᵀ on the right: M ← M·Q ... operating
    /// row-wise this is `row ← Qᵀ·row`? No: (M·Q)[r,:] = Qᵀ applied to
    /// M[r,:] viewed as a column? For orthogonal Q, (M·Q)[r, c] = Σ_k M[r,k]
    /// Q[k,c] — i.e. each row transformed by Qᵀ acting on the left of the
    /// row-as-column, which equals `apply_t` when Q is symmetric-sign
    /// decomposed. We expose explicit helpers instead to avoid confusion.
    pub fn right_mul(&self, m: &mut Mat) {
        // M·Q where Q = H·D/√n: (M·H)·D/√n. Row r of M·H = FWHT(row r).
        assert_eq!(m.cols, self.n);
        let scale = 1.0 / (self.n as f32).sqrt();
        for r in 0..m.rows {
            let row = m.row_mut(r);
            fwht_inplace(row);
            for (v, s) in row.iter_mut().zip(self.signs.iter()) {
                *v *= s * scale;
            }
        }
    }

    /// M ← M·Qᵀ where Qᵀ = D·H/√n: scale columns by D then FWHT rows.
    pub fn right_mul_t(&self, m: &mut Mat) {
        assert_eq!(m.cols, self.n);
        let scale = 1.0 / (self.n as f32).sqrt();
        for r in 0..m.rows {
            let row = m.row_mut(r);
            for (v, s) in row.iter_mut().zip(self.signs.iter()) {
                *v *= s;
            }
            fwht_inplace(row);
            for v in row.iter_mut() {
                *v *= scale;
            }
        }
    }

    /// M ← Q·M (left multiplication) for row-major M with n rows.
    pub fn left_mul(&self, m: &mut Mat) {
        assert_eq!(m.rows, self.n);
        // Q·M = (Mᵀ·Qᵀ)ᵀ; do it column-blocked without materializing Mᵀ:
        // work on columns via a scratch buffer.
        let mut col = vec![0.0f32; self.n];
        for c in 0..m.cols {
            for r in 0..self.n {
                col[r] = m.at(r, c);
            }
            self.apply(&mut col);
            for r in 0..self.n {
                *m.at_mut(r, c) = col[r];
            }
        }
    }

    /// M ← Qᵀ·M.
    pub fn left_mul_t(&self, m: &mut Mat) {
        assert_eq!(m.rows, self.n);
        let mut col = vec![0.0f32; self.n];
        for c in 0..m.cols {
            for r in 0..self.n {
                col[r] = m.at(r, c);
            }
            self.apply_t(&mut col);
            for r in 0..self.n {
                *m.at_mut(r, c) = col[r];
            }
        }
    }
}

/// Conjugate an SPD matrix: Qᵀ·A·Q (QuIP transforms the Hessian into the
/// rotated basis: H' = Qᵀ H Q since X' = Qᵀ X).
pub fn hadamard_conjugate(a: &Mat, q: &SignedHadamard) -> Mat {
    assert_eq!(a.rows, a.cols);
    let mut m = a.clone();
    q.left_mul_t(&mut m); // Qᵀ·A
    q.right_mul(&mut m); // (Qᵀ·A)·Q
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul;

    #[test]
    fn fwht_self_inverse_up_to_n() {
        let mut x = vec![1.0f32, 2.0, -3.0, 0.5, 4.0, -1.0, 0.0, 2.5];
        let orig = x.clone();
        fwht_inplace(&mut x);
        fwht_inplace(&mut x);
        for (a, b) in x.iter().zip(orig.iter()) {
            assert!((a - b * 8.0).abs() < 1e-4);
        }
    }

    #[test]
    fn signed_hadamard_is_orthogonal() {
        let mut rng = Rng::new(5);
        let q = SignedHadamard::new(16, &mut rng);
        let mut x = rng.normal_vec(16, 1.0);
        let orig = x.clone();
        let norm0: f32 = orig.iter().map(|v| v * v).sum();
        q.apply(&mut x);
        let norm1: f32 = x.iter().map(|v| v * v).sum();
        assert!((norm0 - norm1).abs() < 1e-3 * norm0, "not norm preserving");
        q.apply_t(&mut x);
        for (a, b) in x.iter().zip(orig.iter()) {
            assert!((a - b).abs() < 1e-4, "QᵀQ ≠ I");
        }
    }

    #[test]
    fn right_and_left_muls_are_consistent_with_apply() {
        let mut rng = Rng::new(6);
        let q = SignedHadamard::new(8, &mut rng);
        // Build dense Q by applying to basis vectors.
        let mut qdense = Mat::zeros(8, 8);
        for j in 0..8 {
            let mut e = vec![0.0f32; 8];
            e[j] = 1.0;
            q.apply(&mut e);
            for i in 0..8 {
                *qdense.at_mut(i, j) = e[i];
            }
        }
        let m = Mat::randn(5, 8, 1.0, &mut rng);
        let mut got = m.clone();
        q.right_mul(&mut got);
        let want = matmul(&m, &qdense);
        for (a, b) in got.data.iter().zip(want.data.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
        let m2 = Mat::randn(8, 5, 1.0, &mut rng);
        let mut got2 = m2.clone();
        q.left_mul(&mut got2);
        let want2 = matmul(&qdense, &m2);
        for (a, b) in got2.data.iter().zip(want2.data.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn conjugation_preserves_trace() {
        let mut rng = Rng::new(7);
        let q = SignedHadamard::new(16, &mut rng);
        let b = Mat::randn(16, 16, 1.0, &mut rng);
        // SPD-ish: A = B·Bᵀ
        let a = crate::linalg::gemm::matmul_nt(&b, &b);
        let c = hadamard_conjugate(&a, &q);
        let tr_a: f32 = (0..16).map(|i| a.at(i, i)).sum();
        let tr_c: f32 = (0..16).map(|i| c.at(i, i)).sum();
        assert!((tr_a - tr_c).abs() < 1e-2 * tr_a.abs());
    }

    #[test]
    fn incoherence_spreads_outliers() {
        // A spiky weight row becomes flat after rotation — the property QuIP
        // relies on for low-bit grids.
        let mut rng = Rng::new(8);
        let q = SignedHadamard::new(64, &mut rng);
        let mut x = vec![0.0f32; 64];
        x[7] = 8.0;
        q.apply(&mut x);
        let max = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        assert!(max < 1.5, "rotation failed to spread the outlier: max={max}");
    }
}
