//! Low-rank quantization-error reconstruction (the LQER/QERA family).
//!
//! After a base quantizer produces `Q(W)`, the residual `R = W − Q(W)` is
//! approximated by a rank-`r` term `U·V` chosen to minimize the
//! *activation-weighted* error `‖(R − U·V)·X‖_F` — QERA's analytic
//! solution. With `H = XᵀX = L·Lᵀ` (damped Cholesky, same `ρ =
//! damp_rel·mean(diag H)` rule as the QEP correction), the optimum is the
//! truncated SVD of `B = R·L` mapped back through `L⁻¹`:
//!
//! ```text
//! B = R·L = U_r Σ_r V_rᵀ + …   ⇒   U = U_r,   V = Σ_r V_rᵀ L⁻¹
//! ```
//!
//! so the stored adjunct satisfies `U·V ≈ R` in the metric the layer
//! actually sees. Without calibration statistics the builder falls back
//! to the plain truncated SVD of `R` (LQER's data-free variant).
//!
//! The adjunct is orthogonal to both the base quantizer *and* QEP's α
//! correction: it is computed after quantization from whatever residual
//! is left, so every `Method × ±QEP` cell gains a `±lowrank` twin.
//!
//! Serving applies the factors without materializing: `y += (x·Vᵀ)·Uᵀ`
//! after the (quantized) GEMM, through the same pooled bit-identical
//! kernels — see `serve::engine::LinearW`.

use crate::io::TensorFile;
use crate::linalg::{cholesky_in_place, matmul_nt_with, solve_lower_transpose, svd_rank_with};
use crate::linalg::{Mat, Mat64};
use crate::model::Model;
use crate::util::json::Json;
use crate::util::pool::Pool;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// `.qtz` metadata key recording the adjunct rank (0 / absent = none).
pub const LOWRANK_META_KEY: &str = "lowrank_rank";

/// A rank-`r` reconstruction `U·V ≈ W − Q(W)` for one linear layer.
///
/// `u` is `[out, r]`, `v` is `[r, in]` — the same `[out, in]` orientation
/// as the layer weight, so `x·(U·V)ᵀ = (x·Vᵀ)·Uᵀ`.
#[derive(Clone, Debug, PartialEq)]
pub struct LowRankAdjunct {
    pub u: Mat,
    pub v: Mat,
}

impl LowRankAdjunct {
    pub fn rank(&self) -> usize {
        self.v.rows
    }

    pub fn out_dim(&self) -> usize {
        self.u.rows
    }

    pub fn in_dim(&self) -> usize {
        self.v.cols
    }

    /// Dense `U·V` as `[out, in]`, accumulated serially in f64 (fixed
    /// order — the materialized weight is part of the deterministic
    /// surface shared by eval and the pipeline's propagation stream).
    pub fn materialize(&self) -> Mat {
        let (m, n, r) = (self.u.rows, self.v.cols, self.rank());
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let urow = self.u.row(i);
            let orow = out.row_mut(i);
            for j in 0..n {
                let mut acc = 0.0f64;
                for t in 0..r {
                    acc += urow[t] as f64 * self.v.at(t, j) as f64;
                }
                orow[j] = acc as f32;
            }
        }
        out
    }

    /// `base + U·V` — the dense-corrected weight.
    pub fn add_to(&self, base: &Mat) -> Mat {
        assert_eq!((base.rows, base.cols), (self.u.rows, self.v.cols), "adjunct shape mismatch");
        base.add(&self.materialize())
    }

    /// Fused apply: `y += (x·Vᵀ)·Uᵀ` on `pool`. Both GEMMs are the pooled
    /// bit-identical kernels and the final add is element-wise in index
    /// order, so the result is invariant to thread count.
    pub fn apply_with(&self, x: &Mat, y: &mut Mat, pool: &Pool) {
        if self.rank() == 0 {
            return;
        }
        let t = matmul_nt_with(x, &self.v, pool);
        let add = matmul_nt_with(&t, &self.u, pool);
        y.add_assign(&add);
    }
}

/// Build the rank-`rank` adjunct for one layer from its residual
/// `R = W − Q(W)` and (optionally) the calibration Hessian `H = XᵀX`.
///
/// With a Hessian, the analytic QERA solution is used (damping follows
/// the QEP correction's `ρ = (damp_rel·mean(diag H)).max(1e-10)` rule);
/// without one — or if the damped factorization fails — the builder
/// falls back to the plain truncated SVD of `R`. `seed` drives the
/// randomized range-finder for large layers and is expected to be
/// name-derived so shards and thread counts agree on Ω.
pub fn adjunct_from_residual(
    residual: &Mat,
    hessian: Option<&Mat64>,
    rank: usize,
    damp_rel: f64,
    seed: u64,
    pool: &Pool,
) -> LowRankAdjunct {
    let (m, n) = (residual.rows, residual.cols);
    let r = rank.min(m.min(n));
    if r == 0 {
        return LowRankAdjunct { u: Mat::zeros(m, 0), v: Mat::zeros(0, n) };
    }
    if let Some(h) = hessian {
        assert_eq!((h.rows, h.cols), (n, n), "hessian must be [in, in]");
        let mut l = h.clone();
        let rho = (damp_rel * l.mean_diag()).max(1e-10);
        l.add_diag(rho);
        if cholesky_in_place(&mut l).is_ok() {
            return analytic_adjunct(residual, &l, r, seed, pool);
        }
    }
    plain_adjunct(residual, r, seed, pool)
}

/// QERA's analytic form: truncated SVD of `B = R·L`, mapped back through
/// `L⁻¹` via triangular solves.
fn analytic_adjunct(residual: &Mat, l: &Mat64, r: usize, seed: u64, pool: &Pool) -> LowRankAdjunct {
    let (m, n) = (residual.rows, residual.cols);
    // B = R·L in f64 (L is lower triangular: column j only sees k >= j).
    let mut b = Mat::zeros(m, n);
    for i in 0..m {
        let rrow = residual.row(i);
        let brow = b.row_mut(i);
        for j in 0..n {
            let mut acc = 0.0f64;
            for k in j..n {
                acc += rrow[k] as f64 * l.at(k, j);
            }
            brow[j] = acc as f32;
        }
    }
    let f = svd_rank_with(&b, r, seed, pool);
    // Row t of V is σ_t·v_tᵀ·L⁻¹, i.e. the solution z of Lᵀz = σ_t·v_t.
    let mut v = Mat::zeros(r, n);
    for t in 0..r {
        let mut z: Vec<f64> = (0..n).map(|j| f.s[t] as f64 * f.vt.at(t, j) as f64).collect();
        solve_lower_transpose(l, &mut z);
        for (dst, src) in v.row_mut(t).iter_mut().zip(z.iter()) {
            *dst = *src as f32;
        }
    }
    LowRankAdjunct { u: f.u, v }
}

/// Data-free fallback: plain truncated SVD of the residual, with Σ folded
/// into `V` so `U` keeps orthonormal columns.
fn plain_adjunct(residual: &Mat, r: usize, seed: u64, pool: &Pool) -> LowRankAdjunct {
    let f = svd_rank_with(residual, r, seed, pool);
    let mut v = f.vt;
    for t in 0..r {
        let s = f.s[t];
        for x in v.row_mut(t) {
            *x *= s;
        }
    }
    LowRankAdjunct { u: f.u, v }
}

// ---------------------------------------------------------------------------
// `.qtz` artifact section.
// ---------------------------------------------------------------------------

/// Tensor names for a layer's adjunct factors inside the `.qtz` file.
/// `layer` is the pipeline's canonical `blocks.{i}.{short}` name.
pub fn adjunct_tensor_names(layer: &str) -> (String, String) {
    (format!("lowrank.{layer}.u"), format!("lowrank.{layer}.v"))
}

/// Serialize `model` plus adjuncts into one tensor file: base tensors in
/// the model's canonical order first, then adjunct factors in sorted
/// layer order — a fixed insertion order, so the bytes are a pure
/// function of the contents (blob offsets depend on insertion order).
pub fn to_tensor_file_with_adjuncts(
    model: &Model,
    adjuncts: &BTreeMap<String, LowRankAdjunct>,
    rank: usize,
) -> TensorFile {
    let mut tf = model.to_tensor_file();
    tf.meta.set(LOWRANK_META_KEY, Json::Num(rank as f64));
    for (layer, adj) in adjuncts {
        let (un, vn) = adjunct_tensor_names(layer);
        tf.put_mat(&un, &adj.u);
        tf.put_mat(&vn, &adj.v);
    }
    tf
}

/// Save `model` (base/grid weights) plus its adjunct section.
pub fn save_with_adjuncts<P: AsRef<Path>>(
    path: P,
    model: &Model,
    adjuncts: &BTreeMap<String, LowRankAdjunct>,
    rank: usize,
) -> Result<()> {
    to_tensor_file_with_adjuncts(model, adjuncts, rank).save(path)
}

/// Extract the adjunct section of a tensor file (empty map when absent —
/// plain model files load unchanged).
pub fn adjuncts_from_tensor_file(tf: &TensorFile) -> Result<BTreeMap<String, LowRankAdjunct>> {
    let mut out = BTreeMap::new();
    let names: Vec<String> = tf.names().into_iter().map(|s| s.to_string()).collect();
    for name in &names {
        let Some(rest) = name.strip_prefix("lowrank.") else { continue };
        let Some(layer) = rest.strip_suffix(".u") else { continue };
        let (un, vn) = adjunct_tensor_names(layer);
        let u = tf.get_mat(&un)?;
        let v = tf
            .get_mat(&vn)
            .with_context(|| format!("adjunct '{layer}' has a U factor but no V"))?;
        if u.cols != v.rows {
            bail!(
                "adjunct '{layer}': U is [{},{}] but V is [{},{}]",
                u.rows,
                u.cols,
                v.rows,
                v.cols
            );
        }
        out.insert(layer.to_string(), LowRankAdjunct { u, v });
    }
    Ok(out)
}

/// Load a `.qtz` artifact together with its (possibly empty) adjunct map.
pub fn load_with_adjuncts<P: AsRef<Path>>(
    path: P,
) -> Result<(Model, BTreeMap<String, LowRankAdjunct>)> {
    let tf = TensorFile::load(path.as_ref())
        .with_context(|| format!("loading model {}", path.as_ref().display()))?;
    let model = Model::from_tensor_file(&tf)?;
    let adjuncts = adjuncts_from_tensor_file(&tf)?;
    Ok((model, adjuncts))
}

/// Fold every adjunct into its layer: `W ← W + U·V`. This is the dense
/// materialization evaluation uses; serving keeps the factored form.
pub fn materialize_into_model(
    model: &mut Model,
    adjuncts: &BTreeMap<String, LowRankAdjunct>,
) -> Result<()> {
    for (layer, adj) in adjuncts {
        let Some(rest) = layer.strip_prefix("blocks.") else {
            bail!("adjunct layer '{layer}' is not a block linear");
        };
        let Some((idx, short)) = rest.split_once('.') else {
            bail!("adjunct layer '{layer}' is not a block linear");
        };
        let bi: usize = idx.parse().with_context(|| format!("adjunct layer '{layer}'"))?;
        if bi >= model.blocks.len() {
            bail!("adjunct layer '{layer}' out of range ({} blocks)", model.blocks.len());
        }
        let w = model.blocks[bi].linear_mut(short);
        *w = adj.add_to(w);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul_nt;
    use crate::util::rng::Rng;

    fn residual(m: usize, n: usize, seed: u64) -> Mat {
        Mat::randn(m, n, 0.1, &mut Rng::new(seed))
    }

    fn hessian_of(x: &Mat) -> Mat64 {
        let h32 = crate::linalg::matmul_tn(x, x);
        let mut h = Mat64::zeros(x.cols, x.cols);
        for (dst, src) in h.data.iter_mut().zip(h32.data.iter()) {
            *dst = *src as f64;
        }
        h
    }

    #[test]
    fn full_rank_reconstructs_residual() {
        let r = residual(6, 9, 1);
        let adj = adjunct_from_residual(&r, None, 9, 1.0, 7, &Pool::serial());
        let err = r.sub(&adj.materialize()).frob() / r.frob();
        assert!(err < 1e-3, "full-rank reconstruction error {err}");
    }

    #[test]
    fn analytic_form_beats_plain_svd_in_weighted_norm() {
        // Activations concentrated on a few directions: the Hessian-aware
        // adjunct must win (or tie) in ‖(R − UV)·X‖.
        let mut rng = Rng::new(5);
        let (m, n, tokens, rank) = (12usize, 16usize, 200usize, 2usize);
        let r = residual(m, n, 2);
        let mut x = Mat::randn(tokens, n, 1.0, &mut rng);
        for t in 0..tokens {
            for (j, v) in x.row_mut(t).iter_mut().enumerate() {
                *v *= if j < 3 { 10.0 } else { 0.1 };
            }
        }
        let h = hessian_of(&x);
        let weighted = adjunct_from_residual(&r, Some(&h), rank, 1e-6, 3, &Pool::serial());
        let plain = adjunct_from_residual(&r, None, rank, 1e-6, 3, &Pool::serial());
        let err = |adj: &LowRankAdjunct| {
            let e = r.sub(&adj.materialize());
            matmul_nt(&x, &e).frob()
        };
        let (we, pe) = (err(&weighted), err(&plain));
        assert!(we <= pe * 1.0001, "weighted {we} !<= plain {pe}");
    }

    #[test]
    fn apply_matches_materialized_product() {
        let mut rng = Rng::new(9);
        let r = residual(10, 14, 4);
        let adj = adjunct_from_residual(&r, None, 3, 1.0, 11, &Pool::serial());
        let x = Mat::randn(5, 14, 1.0, &mut rng);
        let mut y = Mat::zeros(5, 10);
        adj.apply_with(&x, &mut y, &Pool::serial());
        let want = matmul_nt(&x, &adj.materialize());
        let err = y.sub(&want).frob() / want.frob().max(1e-12);
        assert!(err < 1e-4, "factored apply drifts from dense: {err}");
    }

    #[test]
    fn rank_zero_is_a_no_op() {
        let r = residual(4, 6, 8);
        let adj = adjunct_from_residual(&r, None, 0, 1.0, 1, &Pool::serial());
        assert_eq!(adj.rank(), 0);
        assert_eq!(adj.materialize(), Mat::zeros(4, 6));
        let x = Mat::randn(2, 6, 1.0, &mut Rng::new(1));
        let mut y = Mat::from_vec(2, 4, vec![1.0; 8]);
        let before = y.clone();
        adj.apply_with(&x, &mut y, &Pool::serial());
        assert_eq!(y, before);
    }

    #[test]
    fn artifact_roundtrip_is_byte_exact() {
        let mut cfg = crate::model::ModelConfig::new("unit", 16, 2, 2, 32);
        cfg.seq_len = 8;
        let model = Model::random(&cfg, 1);
        let mut adjuncts = BTreeMap::new();
        adjuncts.insert(
            "blocks.0.attn.wq".to_string(),
            adjunct_from_residual(&residual(16, 16, 3), None, 2, 1.0, 5, &Pool::serial()),
        );
        adjuncts.insert(
            "blocks.1.mlp.down".to_string(),
            adjunct_from_residual(&residual(16, 32, 4), None, 2, 1.0, 6, &Pool::serial()),
        );
        let bytes = to_tensor_file_with_adjuncts(&model, &adjuncts, 2).serialize();
        let tf = TensorFile::deserialize(&bytes).unwrap();
        let back_model = Model::from_tensor_file(&tf).unwrap();
        let back_adj = adjuncts_from_tensor_file(&tf).unwrap();
        assert_eq!(back_adj, adjuncts);
        let again = to_tensor_file_with_adjuncts(&back_model, &back_adj, 2).serialize();
        assert_eq!(bytes, again, "write→read→write must be byte-identical");
    }

    #[test]
    fn materialize_into_model_adds_uv() {
        let mut cfg = crate::model::ModelConfig::new("unit", 16, 2, 2, 32);
        cfg.seq_len = 8;
        let mut model = Model::random(&cfg, 2);
        let base = model.blocks[0].wk.clone();
        let adj = adjunct_from_residual(&residual(16, 16, 5), None, 2, 1.0, 7, &Pool::serial());
        let mut adjuncts = BTreeMap::new();
        adjuncts.insert("blocks.0.attn.wk".to_string(), adj.clone());
        materialize_into_model(&mut model, &adjuncts).unwrap();
        assert_eq!(model.blocks[0].wk, adj.add_to(&base));
        // Bad layer names are loud.
        let mut bad = BTreeMap::new();
        bad.insert("blocks.9.attn.wk".to_string(), adj);
        assert!(materialize_into_model(&mut model, &bad).is_err());
    }
}
