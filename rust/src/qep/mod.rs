//! QEP — the paper's contribution. Given the dual calibration streams
//! (full-precision activations `X` and quantized-stream activations `X̂`)
//! for a layer, compute the corrected weight
//!
//! ```text
//! W*(α) = W + α · W δ X̂ᵀ (Ĥ + ρI)⁻¹,   δ = X − X̂,  Ĥ = X̂ X̂ᵀ
//! ```
//!
//! (Prop. 5.1 + the tunable propagation of §5.3), then hand `W*` to any
//! base quantizer calibrated against `X̂`.
//!
//! The damped solve `(Ĥ + ρI)⁻¹·B` (ρ from App. B.1's mean-diagonal
//! rule) runs on the blocked, pool-parallel SPD engine in
//! `crate::linalg::chol`, so the correction scales with cores while
//! staying bit-identical for every thread count. See
//! `docs/ARCHITECTURE.md` §3 for the full equation-to-code map.

pub mod alpha;
pub mod correction;
pub mod lowrank;

pub use alpha::AlphaPolicy;
pub use correction::{
    corrected_weight, corrected_weight_with_h, correction_term, correction_term_with_h,
    CorrectionStats,
};
pub use lowrank::{
    adjunct_from_residual, adjuncts_from_tensor_file, load_with_adjuncts,
    materialize_into_model, save_with_adjuncts, to_tensor_file_with_adjuncts, LowRankAdjunct,
};
