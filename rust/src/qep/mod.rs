//! QEP — the paper's contribution. Given the dual calibration streams
//! (full-precision activations `X` and quantized-stream activations `X̂`)
//! for a layer, compute the corrected weight
//!
//! ```text
//! W*(α) = W + α · W δ X̂ᵀ (Ĥ + ρI)⁻¹,   δ = X − X̂,  Ĥ = X̂ X̂ᵀ
//! ```
//!
//! (Prop. 5.1 + the tunable propagation of §5.3), then hand `W*` to any
//! base quantizer calibrated against `X̂`.

pub mod alpha;
pub mod correction;

pub use alpha::AlphaPolicy;
pub use correction::{
    corrected_weight, corrected_weight_with_h, correction_term, correction_term_with_h,
    CorrectionStats,
};
