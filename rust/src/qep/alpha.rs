//! Propagation-strength policy (§5.3). The paper uses α=1/2 everywhere
//! except the MLP layers of the largest model, where α=0 both regularizes
//! and skips the correction cost entirely.

/// Decides α per layer. Patterns match on substrings of the canonical
/// layer name (`blocks.3.mlp.down` etc).
#[derive(Clone, Debug)]
pub struct AlphaPolicy {
    /// Default α for every layer.
    pub default: f32,
    /// `(substring, α)` overrides, first match wins.
    pub overrides: Vec<(String, f32)>,
}

impl AlphaPolicy {
    /// The paper's default: α = 1/2 for all layers.
    pub fn uniform(alpha: f32) -> AlphaPolicy {
        AlphaPolicy { default: alpha, overrides: Vec::new() }
    }

    /// The paper's Llama-2-70B setting: α = 1/2, but 0 for MLP layers
    /// (we mirror it for our largest model via the coordinator).
    pub fn paper_large_model() -> AlphaPolicy {
        AlphaPolicy {
            default: 0.5,
            overrides: vec![("mlp.".to_string(), 0.0)],
        }
    }

    pub fn with_override(mut self, pattern: &str, alpha: f32) -> AlphaPolicy {
        self.overrides.push((pattern.to_string(), alpha));
        self
    }

    pub fn alpha_for(&self, layer_name: &str) -> f32 {
        for (pat, a) in &self.overrides {
            if layer_name.contains(pat.as_str()) {
                return *a;
            }
        }
        self.default
    }
}

impl Default for AlphaPolicy {
    fn default() -> Self {
        AlphaPolicy::uniform(0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_policy() {
        let p = AlphaPolicy::uniform(0.5);
        assert_eq!(p.alpha_for("blocks.0.attn.wq"), 0.5);
        assert_eq!(p.alpha_for("blocks.7.mlp.down"), 0.5);
    }

    #[test]
    fn overrides_first_match_wins() {
        let p = AlphaPolicy::uniform(0.5)
            .with_override("mlp.", 0.0)
            .with_override("blocks.0.", 1.0);
        assert_eq!(p.alpha_for("blocks.0.mlp.down"), 0.0); // mlp matched first
        assert_eq!(p.alpha_for("blocks.0.attn.wq"), 1.0);
        assert_eq!(p.alpha_for("blocks.3.attn.wo"), 0.5);
    }

    #[test]
    fn paper_large_model_zeroes_mlp() {
        let p = AlphaPolicy::paper_large_model();
        assert_eq!(p.alpha_for("blocks.5.mlp.gate"), 0.0);
        assert_eq!(p.alpha_for("blocks.5.attn.wv"), 0.5);
    }
}
