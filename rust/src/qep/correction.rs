//! The QEP weight correction (Prop. 5.1 / Eq. 6).

use crate::linalg::{matmul, matmul_tn, spd_solve, Mat, Mat64};
use anyhow::{Context, Result};

/// Diagnostics from one correction, used by Table 3 (runtime) and the
/// overfitting analysis.
#[derive(Clone, Copy, Debug, Default)]
pub struct CorrectionStats {
    /// ‖αWδX̂ᵀĤ⁻¹‖_F / ‖W‖_F — relative size of the applied correction.
    pub rel_correction: f64,
    /// ‖δ‖²_F / ‖X‖²_F — upstream error energy this layer inherited.
    pub rel_upstream_err: f64,
    /// Seconds spent in the correction (the paper's "preprocessing" cost).
    pub seconds: f64,
}

/// Compute the correction matrix `C = δ X̂ᵀ (Ĥ + ρI)⁻¹` (shape d×d) from
/// tokens-major activations `x` (full-precision, [m,d]) and `x_hat`
/// (quantized stream, [m,d]).
///
/// `damp_rel` scales mean(diag Ĥ): the paper's App. B.1 sets the damping to
/// the mean diagonal (damp_rel = 1.0 would be that); our default in the
/// pipeline is 1.0 to match, configurable for ablations.
pub fn correction_term(x: &Mat, x_hat: &Mat, damp_rel: f64) -> Result<Mat> {
    correction_term_with_h(x, x_hat, None, damp_rel)
}

/// Like [`correction_term`] but reuses a precomputed (undamped) Ĥ = X̂ᵀX̂
/// when the caller already built one (the pipeline shares it with the
/// quantizer's `LayerCtx` — building Ĥ is half the correction cost).
pub fn correction_term_with_h(
    x: &Mat,
    x_hat: &Mat,
    h_pre: Option<&Mat64>,
    damp_rel: f64,
) -> Result<Mat> {
    assert_eq!((x.rows, x.cols), (x_hat.rows, x_hat.cols), "stream shape mismatch");
    let d = x.cols;
    let delta = x.sub(x_hat); // [m, d]

    // δ·X̂ᵀ in the paper's [d,m] convention = (deltaᵀ)·(x_hat) here: [d, d].
    let dxt = matmul_tn(&delta, x_hat);

    // Ĥ = X̂ᵀX̂ (tokens-major) in f64 + damping.
    let mut h = match h_pre {
        Some(h) => {
            assert_eq!((h.rows, h.cols), (d, d));
            h.clone()
        }
        None => {
            let h32 = matmul_tn(x_hat, x_hat);
            let mut h = Mat64::zeros(d, d);
            for (dst, src) in h.data.iter_mut().zip(h32.data.iter()) {
                *dst = *src as f64;
            }
            h
        }
    };
    let rho = (damp_rel * h.mean_diag()).max(1e-10);
    h.add_diag(rho);

    // C = DXT · Ĥ⁻¹. Solve Ĥ Yᵀ = DXTᵀ (Ĥ symmetric) ⇒ C = Y.
    let mut dxt_t = Mat64::zeros(d, d);
    for i in 0..d {
        for j in 0..d {
            *dxt_t.at_mut(i, j) = dxt.at(j, i) as f64;
        }
    }
    let y_t = spd_solve(&h, &dxt_t).context("QEP correction: Ĥ solve failed")?;
    let mut c = Mat::zeros(d, d);
    for i in 0..d {
        for j in 0..d {
            *c.at_mut(i, j) = y_t.at(j, i) as f32;
        }
    }
    Ok(c)
}

/// Full corrected weight `W*(α) = W + α·W·C` with diagnostics.
pub fn corrected_weight(
    w: &Mat,
    x: &Mat,
    x_hat: &Mat,
    alpha: f32,
    damp_rel: f64,
) -> Result<(Mat, CorrectionStats)> {
    corrected_weight_with_h(w, x, x_hat, None, alpha, damp_rel)
}

/// [`corrected_weight`] with an optional precomputed Ĥ (see
/// [`correction_term_with_h`]).
pub fn corrected_weight_with_h(
    w: &Mat,
    x: &Mat,
    x_hat: &Mat,
    h_pre: Option<&Mat64>,
    alpha: f32,
    damp_rel: f64,
) -> Result<(Mat, CorrectionStats)> {
    let t = crate::util::Stopwatch::start();
    if alpha == 0.0 {
        // α=0 short-circuit: the paper's cost-saving setting for huge MLPs.
        return Ok((
            w.clone(),
            CorrectionStats { rel_correction: 0.0, rel_upstream_err: upstream(x, x_hat), seconds: t.seconds() },
        ));
    }
    let c = correction_term_with_h(x, x_hat, h_pre, damp_rel)?;
    let mut wc = matmul(w, &c);
    wc.scale(alpha);
    let rel_correction = wc.frob() / w.frob().max(1e-30);
    let w_star = w.add(&wc);
    Ok((
        w_star,
        CorrectionStats {
            rel_correction,
            rel_upstream_err: upstream(x, x_hat),
            seconds: t.seconds(),
        },
    ))
}

fn upstream(x: &Mat, x_hat: &Mat) -> f64 {
    x.sub(x_hat).frob_sq() / x.frob_sq().max(1e-30)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul_nt;
    use crate::util::rng::Rng;

    /// Relaxed objective ‖W X − Ŵ X̂‖² in tokens-major layout:
    /// ‖X Wᵀ − X̂ Ŵᵀ‖².
    fn objective(w: &Mat, w_hat: &Mat, x: &Mat, x_hat: &Mat) -> f64 {
        let a = matmul_nt(x, w);
        let b = matmul_nt(x_hat, w_hat);
        a.sub(&b).frob_sq()
    }

    fn streams(m: usize, d: usize, noise: f32, seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        let x = Mat::randn(m, d, 1.0, &mut rng);
        let mut x_hat = x.clone();
        for v in x_hat.data.iter_mut() {
            *v += noise * rng.normal_f32();
        }
        (x, x_hat)
    }

    #[test]
    fn closed_form_minimizes_relaxed_objective() {
        // Prop. 5.1: with no damping, W* must beat W and nearby perturbations.
        let mut rng = Rng::new(1);
        let (x, x_hat) = streams(300, 12, 0.2, 2);
        let w = Mat::randn(6, 12, 1.0, &mut rng);
        let (w_star, _) = corrected_weight(&w, &x, &x_hat, 1.0, 1e-9).unwrap();
        let base = objective(&w, &w, &x, &x_hat);
        let star = objective(&w, &w_star, &x, &x_hat);
        assert!(star < base, "W* {star} !< W {base}");
        // Local optimality: random perturbations of W* don't improve.
        for i in 0..10 {
            let mut pert = w_star.clone();
            let mut prng = Rng::new(100 + i);
            for v in pert.data.iter_mut() {
                *v += 0.01 * prng.normal_f32();
            }
            assert!(objective(&w, &pert, &x, &x_hat) >= star * 0.9999);
        }
    }

    #[test]
    fn gradient_is_zero_at_closed_form() {
        // ∇ = 2(Ŵ Ĥ − W X X̂ᵀ) must vanish at W* (tokens-major algebra).
        let mut rng = Rng::new(3);
        let (x, x_hat) = streams(200, 8, 0.3, 4);
        let w = Mat::randn(4, 8, 1.0, &mut rng);
        let (w_star, _) = corrected_weight(&w, &x, &x_hat, 1.0, 1e-9).unwrap();
        let h_hat = matmul_tn(&x_hat, &x_hat);
        let xxh = matmul_tn(&x, &x_hat); // XᵀX̂ [d,d]... careful with sides
        // grad = W*·Ĥ − W·(X X̂ᵀ) in paper layout; here with row-weights:
        // d/dŴ ‖X Wᵀ − X̂ Ŵᵀ‖² = 2(Ŵ X̂ᵀX̂ − W XᵀX̂)ᵀ-ish; verify numerically.
        let g_analytic = matmul(&w_star, &h_hat).sub(&matmul(&w, &xxh));
        let scale = matmul(&w, &xxh).frob().max(1.0);
        assert!(
            g_analytic.frob() / scale < 1e-3,
            "gradient not zero: {}",
            g_analytic.frob() / scale
        );
    }

    #[test]
    fn alpha_zero_is_identity_and_fast() {
        let mut rng = Rng::new(5);
        let (x, x_hat) = streams(100, 8, 0.2, 6);
        let w = Mat::randn(4, 8, 1.0, &mut rng);
        let (w0, stats) = corrected_weight(&w, &x, &x_hat, 0.0, 1.0).unwrap();
        assert_eq!(w0, w);
        assert_eq!(stats.rel_correction, 0.0);
        assert!(stats.rel_upstream_err > 0.0);
    }

    #[test]
    fn alpha_interpolates_monotonically_in_objective() {
        // Prop. 5.4 (relaxed version): larger α ⇒ no worse objective.
        let mut rng = Rng::new(7);
        let (x, x_hat) = streams(400, 10, 0.25, 8);
        let w = Mat::randn(5, 10, 1.0, &mut rng);
        let mut last = f64::INFINITY;
        for a in [0.0f32, 0.25, 0.5, 0.75, 1.0] {
            let (ws, _) = corrected_weight(&w, &x, &x_hat, a, 1e-9).unwrap();
            let obj = objective(&w, &ws, &x, &x_hat);
            assert!(obj <= last * (1.0 + 1e-9), "α={a}: {obj} > {last}");
            last = obj;
        }
    }

    #[test]
    fn identical_streams_need_no_correction() {
        let mut rng = Rng::new(9);
        let (x, _) = streams(100, 8, 0.0, 10);
        let w = Mat::randn(4, 8, 1.0, &mut rng);
        let (ws, stats) = corrected_weight(&w, &x, &x.clone(), 1.0, 1e-9).unwrap();
        assert!(ws.sub(&w).frob() / w.frob() < 1e-4);
        assert!(stats.rel_upstream_err < 1e-12);
    }

    #[test]
    fn damping_shrinks_correction_toward_zero() {
        // Prop. 5.3: ridge λ ↑ (here damp ↑) ⇒ smaller correction.
        let mut rng = Rng::new(11);
        let (x, x_hat) = streams(300, 8, 0.3, 12);
        let w = Mat::randn(4, 8, 1.0, &mut rng);
        let (_, s_small) = corrected_weight(&w, &x, &x_hat, 1.0, 1e-6).unwrap();
        let (_, s_big) = corrected_weight(&w, &x, &x_hat, 1.0, 100.0).unwrap();
        assert!(s_big.rel_correction < s_small.rel_correction);
    }
}
