//! Deterministic synthetic corpus generator.
//!
//! Three flavors stand in for the paper's datasets:
//!
//! * `Wiki`  — small vocabulary, long structured sentences, low branching
//!   entropy, section headers (WikiText-2 analog; easiest to model).
//! * `Ptb`   — medium vocabulary, short newswire-style sentences, `<unk>`
//!   markers and digit normalization quirks (Penn Treebank analog).
//! * `C4`    — large vocabulary, high branching entropy, mixed casing and
//!   urls (web-crawl analog; hardest to model, used for calibration by
//!   GPTQ/QuIP in the paper).
//!
//! Each flavor is a first-order word-level Markov chain over a synthetic
//! lexicon: word `i` transitions to one of `branching` successors drawn
//! (deterministically per flavor+seed) with Zipf weights. The chain is
//! ergodic and learnable, so a byte-level transformer trained on one flavor
//! has meaningfully different PPL on the others — exactly the distribution
//! shift Table 4 needs.

use super::tokenizer::ByteTokenizer;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Flavor {
    Wiki,
    Ptb,
    C4,
}

impl Flavor {
    pub fn name(self) -> &'static str {
        match self {
            Flavor::Wiki => "wiki",
            Flavor::Ptb => "ptb",
            Flavor::C4 => "c4",
        }
    }

    pub fn from_name(s: &str) -> Option<Flavor> {
        match s {
            "wiki" | "wikitext2" | "wikitext-2" => Some(Flavor::Wiki),
            "ptb" => Some(Flavor::Ptb),
            "c4" => Some(Flavor::C4),
            _ => None,
        }
    }

    pub fn all() -> [Flavor; 3] {
        [Flavor::Wiki, Flavor::Ptb, Flavor::C4]
    }

    fn params(self) -> FlavorParams {
        match self {
            Flavor::Wiki => FlavorParams {
                vocab: 400,
                branching: 6,
                zipf: 1.3,
                sent_len: (6, 18),
                base_seed: 0x5EED_0001,
                headers: true,
                unk_rate: 0.0,
                url_rate: 0.0,
            },
            Flavor::Ptb => FlavorParams {
                vocab: 800,
                branching: 10,
                zipf: 1.1,
                sent_len: (4, 12),
                base_seed: 0x5EED_0002,
                headers: false,
                unk_rate: 0.03,
                url_rate: 0.0,
            },
            Flavor::C4 => FlavorParams {
                vocab: 1600,
                branching: 24,
                zipf: 0.9,
                sent_len: (3, 24),
                base_seed: 0x5EED_0003,
                headers: false,
                unk_rate: 0.0,
                url_rate: 0.02,
            },
        }
    }
}

struct FlavorParams {
    vocab: usize,
    branching: usize,
    zipf: f64,
    sent_len: (usize, usize),
    base_seed: u64,
    headers: bool,
    unk_rate: f64,
    url_rate: f64,
}

/// A generated corpus: raw text plus its byte-token encoding. `Clone` so
/// experiment sweeps can snapshot corpora into read-only shared state for
/// pool workers (see `exp::common::ExpData`).
#[derive(Clone)]
pub struct Corpus {
    pub flavor: Flavor,
    pub text: String,
    pub tokens: Vec<u32>,
}

const SYLLABLES: [&str; 24] = [
    "ba", "ke", "li", "mo", "nu", "ra", "se", "ti", "vo", "wa", "ze", "dro",
    "fen", "gal", "hir", "jul", "kap", "lor", "mer", "nis", "pod", "qua",
    "rus", "tam",
];

/// Build the flavor's lexicon: short pronounceable pseudo-words. Word ids
/// are frequency-ranked (id 0 = most frequent under the Zipf draw).
fn lexicon(p: &FlavorParams, rng: &mut Rng) -> Vec<String> {
    let mut words = Vec::with_capacity(p.vocab);
    let mut seen = std::collections::HashSet::new();
    while words.len() < p.vocab {
        let n_syll = 1 + rng.below(3);
        let mut w = String::new();
        for _ in 0..n_syll {
            w.push_str(SYLLABLES[rng.below(SYLLABLES.len())]);
        }
        if seen.insert(w.clone()) {
            words.push(w);
        }
    }
    words
}

/// Deterministic successor table: word i → `branching` candidate next-words
/// with Zipf-over-rank weights.
struct Chain {
    succ: Vec<Vec<usize>>,
    weights: Vec<f64>,
}

fn build_chain(p: &FlavorParams, rng: &mut Rng) -> Chain {
    let succ = (0..p.vocab)
        .map(|_| (0..p.branching).map(|_| zipf_draw(p.vocab, p.zipf, rng)).collect())
        .collect();
    let weights = (0..p.branching)
        .map(|r| 1.0 / ((r + 1) as f64).powf(p.zipf))
        .collect();
    Chain { succ, weights }
}

/// Draw a word id with Zipf(s) distribution over ranks 1..=n via inverse
/// CDF on a precomputed-free approximation (rejection-free, cheap).
fn zipf_draw(n: usize, s: f64, rng: &mut Rng) -> usize {
    // Inverse-transform on the continuous approximation of the Zipf CDF.
    let u = rng.f64().max(1e-12);
    if (s - 1.0).abs() < 1e-9 {
        let x = (n as f64).powf(u);
        (x as usize).clamp(1, n) - 1
    } else {
        let t = 1.0 - s;
        let x = ((n as f64).powf(t) * u + (1.0 - u)).powf(1.0 / t);
        (x as usize).clamp(1, n) - 1
    }
}

impl Corpus {
    /// Generate ≈`n_tokens` byte-tokens of flavor text, deterministic in
    /// `(flavor, seed)`.
    pub fn generate(flavor: Flavor, n_tokens: usize, seed: u64) -> Corpus {
        let p = flavor.params();
        // Lexicon + chain are functions of the flavor ONLY (base_seed), so
        // different seeds sample different walks of the *same* language —
        // that's what makes calibration/eval splits iid per flavor.
        let mut structure_rng = Rng::new(p.base_seed);
        let words = lexicon(&p, &mut structure_rng);
        let chain = build_chain(&p, &mut structure_rng);

        let mut rng = Rng::new(p.base_seed ^ (seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)).wrapping_add(1));
        let mut text = String::with_capacity(n_tokens + 64);
        let mut state = zipf_draw(p.vocab, p.zipf, &mut rng);
        let mut sent_words = 0usize;
        let mut sent_target = rng.range_f64(p.sent_len.0 as f64, p.sent_len.1 as f64) as usize;
        let mut sents_in_para = 0usize;
        let mut start_sentence = true;

        while text.len() < n_tokens {
            if p.headers && sents_in_para == 0 && rng.f64() < 0.15 {
                text.push_str(&format!("= {} =\n", words[rng.below(40)]));
            }
            let w = if rng.f64() < p.unk_rate {
                "<unk>".to_string()
            } else if rng.f64() < p.url_rate {
                format!("www.{}.com", words[rng.below(p.vocab)])
            } else {
                let mut w = words[state].clone();
                if start_sentence {
                    // Capitalize sentence starts (C4/wiki style; PTB is lowercased).
                    if flavor != Flavor::Ptb {
                        let mut cs = w.chars();
                        if let Some(c0) = cs.next() {
                            w = c0.to_ascii_uppercase().to_string() + cs.as_str();
                        }
                    }
                }
                w
            };
            text.push_str(&w);
            start_sentence = false;
            sent_words += 1;
            // Advance the chain.
            let next_rank = rng.categorical(&chain.weights);
            state = chain.succ[state][next_rank];

            if sent_words >= sent_target {
                text.push_str(". ");
                sent_words = 0;
                sent_target = rng.range_f64(p.sent_len.0 as f64, p.sent_len.1 as f64) as usize;
                sents_in_para += 1;
                start_sentence = true;
                if sents_in_para >= 4 + rng.below(4) {
                    text.pop();
                    text.push('\n');
                    sents_in_para = 0;
                }
            } else {
                text.push(' ');
            }
        }
        text.truncate(n_tokens);
        let tokens = ByteTokenizer.encode(&text);
        Corpus { flavor, text, tokens }
    }

    /// Load corpus text from a file (the artifact path written by
    /// `repro gen-data`, shared with the Python trainer).
    pub fn from_text(flavor: Flavor, text: String) -> Corpus {
        let tokens = ByteTokenizer.encode(&text);
        Corpus { flavor, text, tokens }
    }

    /// Split tokens into non-overlapping segments of `len` (the paper
    /// calibrates on 128 segments of 2048 tokens; we scale down).
    pub fn segments(&self, len: usize, count: usize) -> Vec<&[u32]> {
        self.tokens
            .chunks_exact(len)
            .take(count)
            .collect()
    }
}

/// Unigram byte entropy in bits — a quick flavor-separation diagnostic.
pub fn byte_entropy(tokens: &[u32]) -> f64 {
    let mut counts = [0usize; 259];
    for &t in tokens {
        counts[t as usize] += 1;
    }
    let total = tokens.len() as f64;
    counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / total;
            -p * p.log2()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = Corpus::generate(Flavor::Wiki, 2000, 7);
        let b = Corpus::generate(Flavor::Wiki, 2000, 7);
        assert_eq!(a.text, b.text);
    }

    #[test]
    fn seeds_sample_different_walks_of_same_language() {
        let a = Corpus::generate(Flavor::Ptb, 2000, 1);
        let b = Corpus::generate(Flavor::Ptb, 2000, 2);
        assert_ne!(a.text, b.text);
        // Same language ⇒ similar byte entropy.
        assert!((byte_entropy(&a.tokens) - byte_entropy(&b.tokens)).abs() < 0.3);
    }

    #[test]
    fn flavors_differ_statistically() {
        let wiki = Corpus::generate(Flavor::Wiki, 20_000, 0);
        let c4 = Corpus::generate(Flavor::C4, 20_000, 0);
        let ptb = Corpus::generate(Flavor::Ptb, 20_000, 0);
        assert_ne!(wiki.text[..200], c4.text[..200]);
        // C4 has the richest vocabulary ⇒ highest byte entropy.
        let (hw, hp, hc) =
            (byte_entropy(&wiki.tokens), byte_entropy(&ptb.tokens), byte_entropy(&c4.tokens));
        assert!(hc > hw, "c4 {hc} !> wiki {hw}");
        assert!(hp > 3.0 && hw > 3.0, "degenerate corpora");
    }

    #[test]
    fn ptb_has_unk_wiki_has_headers() {
        let ptb = Corpus::generate(Flavor::Ptb, 30_000, 0);
        assert!(ptb.text.contains("<unk>"));
        let wiki = Corpus::generate(Flavor::Wiki, 30_000, 0);
        assert!(wiki.text.contains("= "));
    }

    #[test]
    fn segments_are_exact_and_disjoint() {
        let c = Corpus::generate(Flavor::C4, 10_000, 3);
        let segs = c.segments(512, 8);
        assert_eq!(segs.len(), 8);
        assert!(segs.iter().all(|s| s.len() == 512));
        assert_eq!(segs[0], &c.tokens[..512]);
        assert_eq!(segs[1], &c.tokens[512..1024]);
    }

    #[test]
    fn ascii_only_output() {
        let c = Corpus::generate(Flavor::C4, 5_000, 0);
        assert!(c.text.is_ascii());
        assert!(c.tokens.iter().all(|&t| t < 256));
    }
}
