//! Byte-level tokenizer. Vocabulary = 256 byte values + BOS/EOS/PAD.
//! Chosen over BPE so the Python trainer and the Rust runtime share the
//! vocabulary with zero coordination (the corpus generator emits ASCII).

pub const BOS: u32 = 256;
pub const EOS: u32 = 257;
pub const PAD: u32 = 258;
pub const VOCAB_SIZE: usize = 259;

/// True for the non-text control ids (BOS/EOS/PAD occupy the tail of
/// the vocab, after the 256 byte values).
pub fn is_special(id: u32) -> bool {
    id >= BOS
}

#[derive(Clone, Copy, Debug, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    /// Encode text to token ids (no BOS/EOS added).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.as_bytes().iter().map(|&b| b as u32).collect()
    }

    /// Encode with BOS prefix and EOS suffix.
    pub fn encode_with_specials(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::with_capacity(text.len() + 2);
        out.push(BOS);
        out.extend(text.as_bytes().iter().map(|&b| b as u32));
        out.push(EOS);
        out
    }

    /// Decode ids back to text; specials are dropped, non-UTF8 replaced.
    pub fn decode(&self, ids: &[u32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter(|&&id| !is_special(id))
            .map(|&id| id as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer;
        let s = "the quick brown fox. 123!";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn specials_wrap_and_strip() {
        let t = ByteTokenizer;
        let ids = t.encode_with_specials("ab");
        assert_eq!(ids, vec![BOS, 97, 98, EOS]);
        assert_eq!(t.decode(&ids), "ab");
    }

    #[test]
    fn vocab_constants_are_distinct_and_sized() {
        assert!((BOS as usize) < VOCAB_SIZE);
        assert!((EOS as usize) < VOCAB_SIZE);
        assert!((PAD as usize) < VOCAB_SIZE);
        assert_ne!(BOS, EOS);
        assert_ne!(EOS, PAD);
    }

    #[test]
    fn is_special_splits_bytes_from_controls() {
        assert!(!is_special(0));
        assert!(!is_special(255));
        assert!(is_special(BOS));
        assert!(is_special(EOS));
        assert!(is_special(PAD));
    }
}
