//! Byte-level tokenizer. Vocabulary = 256 byte values + BOS/EOS/PAD.
//! Chosen over BPE so the Python trainer and the Rust runtime share the
//! vocabulary with zero coordination (the corpus generator emits ASCII).

pub const BOS: u32 = 256;
pub const EOS: u32 = 257;
pub const PAD: u32 = 258;
pub const VOCAB_SIZE: usize = 259;

#[derive(Clone, Copy, Debug, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    /// Encode text to token ids (no BOS/EOS added).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.as_bytes().iter().map(|&b| b as u32).collect()
    }

    /// Encode with BOS prefix and EOS suffix.
    pub fn encode_with_specials(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::with_capacity(text.len() + 2);
        out.push(BOS);
        out.extend(text.as_bytes().iter().map(|&b| b as u32));
        out.push(EOS);
        out
    }

    /// Decode ids back to text; specials are dropped, non-UTF8 replaced.
    pub fn decode(&self, ids: &[u32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter(|&&id| id < 256)
            .map(|&id| id as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer;
        let s = "the quick brown fox. 123!";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn specials_wrap_and_strip() {
        let t = ByteTokenizer;
        let ids = t.encode_with_specials("ab");
        assert_eq!(ids, vec![BOS, 97, 98, EOS]);
        assert_eq!(t.decode(&ids), "ab");
    }

    #[test]
    fn vocab_constants_are_distinct_and_sized() {
        assert!((BOS as usize) < VOCAB_SIZE);
        assert!((EOS as usize) < VOCAB_SIZE);
        assert!((PAD as usize) < VOCAB_SIZE);
        assert_ne!(BOS, EOS);
        assert_ne!(EOS, PAD);
    }
}
