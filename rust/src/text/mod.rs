//! Synthetic text substrate: the paper calibrates on C4/Pile and evaluates
//! on WikiText-2/PTB/C4. We have none of those (repro gate), so we build
//! three deterministic word-Markov corpora with *different statistics* —
//! what matters for the paper's experiments is (a) a learnable token
//! process so perplexity is meaningful and (b) genuine distribution shift
//! between the three flavors for the robustness study (Table 4).

pub mod gen;
pub mod tokenizer;

pub use gen::{Corpus, Flavor};
pub use tokenizer::{is_special, ByteTokenizer, BOS, EOS, PAD, VOCAB_SIZE};
