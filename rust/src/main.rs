//! `repro` — the QEP reproduction CLI (L3 leader entrypoint).
//!
//! ```text
//! repro gen-data [--out artifacts/data] [--tokens N]
//! repro quantize --model tiny-s --method gptq --bits 3 [--group 64] [--qep 0.5] [--out q.qtz]
//! repro eval --model-file q.qtz [--flavor wiki] [--tasks]
//! repro exp <fig1|fig2|fig3|table1|table2|table3|table4|appendix|all> [--sizes s,m,l] [--fast]
//! repro info
//! ```

use anyhow::{anyhow, bail, Result};
use qep::coordinator::{Pipeline, PipelineConfig};
use qep::eval::{perplexity, TaskFamily, TaskSet};
use qep::exp::{self, ExpEnv};
use qep::model::{Model, Size};
use qep::quant::{Method, QuantConfig};
use qep::text::{Corpus, Flavor};
use qep::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let result = dispatch(&args);
    // Gracefully join the persistent pool workers (no-op if no parallel
    // dispatch ever started them).
    qep::util::pool::shutdown();
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    if let Some(t) = args.get("threads") {
        let n: usize = t
            .parse()
            .map_err(|_| anyhow!("--threads expects a non-negative integer, got '{t}'"))?;
        qep::util::pool::set_global_threads(n);
    }
    match args.positional.first().map(|s| s.as_str()) {
        Some("gen-data") => gen_data(args),
        Some("quantize") => quantize(args),
        Some("eval") => eval(args),
        Some("exp") => experiment(args),
        Some("info") => info(),
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
repro — Quantization Error Propagation (QEP) reproduction

USAGE:
  repro gen-data [--out artifacts/data] [--tokens 262144]
  repro quantize --model <tiny-s|tiny-m|tiny-l|path.qtz> --method <rtn|gptq|awq|quip>
                 --bits <2|3|4|8> [--group N] [--qep <alpha>] [--calib <wiki|ptb|c4>]
                 [--seed N] [--threads N] [--out out.qtz]
  repro eval     --model-file <path.qtz> [--flavor wiki] [--tasks] [--chunk N]
  repro exp      <fig1|fig2|fig3|table1|table2|table3|table4|appendix|all>
                 [--sizes s,m,l] [--fast] [--artifacts DIR]
  repro info

THREADS:
  --threads N    Worker threads for the parallel execution engine (GEMMs,
                 Hessian builds, blocked Cholesky/SPD solves, per-layer
                 fan-out, GPTQ row sweeps, batched perplexity/task eval,
                 and sharded `exp` cell sweeps). Accepted by every
                 subcommand. 0 or omitted = use all hardware threads.
                 Output is bit-identical for every N — per-layer and
                 per-cell seeds derive from names and all parallel
                 reductions have a fixed order — so the knob only trades
                 wall-clock time. (Exception to *sharding*, not to
                 determinism: `exp table3` runs its cells serially because
                 it measures per-cell runtime.)

                 Pool lifecycle: worker threads are persistent. They spawn
                 once, on the first parallel dispatch (pre-started by the
                 quantize pipeline), park between jobs, and are joined
                 when repro exits. `--threads 1` bypasses them entirely —
                 every kernel runs inline on the calling thread and no
                 worker threads are ever created.

DOCS:
  README.md             quickstart + repo layout map
  docs/ARCHITECTURE.md  dataflow and paper-equation pointers
  docs/PERFORMANCE.md   parallelism contract, pool + micro-kernel design,
                        how to benchmark (cargo bench)
  cargo doc --no-deps   API reference (kept warning-free in CI)
";

fn gen_data(args: &Args) -> Result<()> {
    let out = args.get_or("out", "artifacts/data");
    let tokens = args.get_usize("tokens", 256 * 1024);
    std::fs::create_dir_all(out)?;
    for flavor in Flavor::all() {
        let c = Corpus::generate(flavor, tokens, 0);
        let path = format!("{out}/{}.txt", flavor.name());
        std::fs::write(&path, &c.text)?;
        println!("wrote {path} ({} bytes)", c.text.len());
    }
    Ok(())
}

fn load_model(args: &Args, key: &str) -> Result<Model> {
    let spec = args
        .get(key)
        .ok_or_else(|| anyhow!("--{key} required"))?;
    if let Some(size) = Size::from_name(spec) {
        let reg = qep::runtime::ArtifactRegistry::new(args.get_or("artifacts", "artifacts"));
        reg.load_model(size.name())
    } else {
        Model::load(spec)
    }
}

fn quantize(args: &Args) -> Result<()> {
    let model = load_model(args, "model")?;
    let method = Method::from_name(args.get_or("method", "rtn"))
        .ok_or_else(|| anyhow!("unknown method"))?;
    let bits = args.get_usize("bits", 4) as u32;
    let quant = match args.get("group") {
        Some(g) => QuantConfig::int_group(bits, g.parse()?),
        None => QuantConfig::int(bits),
    };
    let qep_alpha = args.get("qep").map(|a| a.parse::<f32>()).transpose()?;
    let flavor = Flavor::from_name(args.get_or("calib", "c4"))
        .ok_or_else(|| anyhow!("unknown calib flavor"))?;
    let seed = args.get_usize("seed", 0) as u64;

    let mut env = ExpEnv::new(args.get_or("artifacts", "artifacts"));
    let calib = env.calib_tokens(flavor, model.cfg.seq_len, seed);
    // `--threads` is handled once in dispatch() (set_global_threads);
    // threads: 0 in the default config resolves to that global setting.
    let cfg = PipelineConfig {
        quant,
        method,
        qep_alpha,
        seed,
        verbose: args.has("verbose"),
        ..Default::default()
    };
    println!("quantizing {} with {}", model.cfg.name, cfg.label());
    let out = Pipeline::new(cfg).run(&model, &calib)?;
    println!("{}", out.report.summary());
    if let Some(path) = args.get("out") {
        out.model.save(path)?;
        println!("saved {path}");
    }
    let eval_tokens = env.eval_tokens(Flavor::Wiki);
    println!("wiki ppl: {:.3}", perplexity(&out.model, &eval_tokens));
    Ok(())
}

fn eval(args: &Args) -> Result<()> {
    let model = Model::load(
        args.get("model-file").ok_or_else(|| anyhow!("--model-file required"))?,
    )?;
    let flavor = Flavor::from_name(args.get_or("flavor", "wiki"))
        .ok_or_else(|| anyhow!("unknown flavor"))?;
    let mut env = ExpEnv::new(args.get_or("artifacts", "artifacts"));
    let tokens = env.eval_tokens(flavor);
    let chunk = args.get_usize("chunk", qep::eval::DEFAULT_CHUNK_SEGMENTS);
    println!(
        "{} ppl: {:.3}",
        flavor.name(),
        qep::eval::perplexity_chunked(&model, &tokens, chunk)
    );
    if args.has("tasks") {
        let corpus = env.corpus(Flavor::Wiki);
        for fam in TaskFamily::all() {
            let ts = TaskSet::generate(fam, &corpus, 60, 1234);
            println!("{} ({}): {:.4}", fam.name(), fam.paper_analog(), ts.accuracy(&model));
        }
    }
    Ok(())
}

fn parse_sizes(args: &Args) -> Vec<Size> {
    match args.get("sizes") {
        Some(spec) => spec.split(',').filter_map(Size::from_name).collect(),
        None => {
            if args.has("fast") {
                vec![Size::TinyS]
            } else {
                Size::all().to_vec()
            }
        }
    }
}

fn experiment(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: repro exp <id>"))?
        .as_str();
    let mut env = ExpEnv::new(args.get_or("artifacts", "artifacts"));
    let sizes = parse_sizes(args);
    let fast = args.has("fast");
    match which {
        "fig1" | "table1" | "table2" => exp::tables::table1_and_2(&mut env, &sizes)?,
        "fig2" => {
            let size = sizes.first().copied().unwrap_or(Size::TinyM);
            let bits = args.get_usize("bits", 3) as u32;
            let n = args.get("blocks").map(|b| b.parse()).transpose()?;
            exp::fig2::run(&mut env, size, bits, n)?;
        }
        "fig3" => {
            let seeds = args.get_usize("seeds", if fast { 2 } else { 5 }) as u64;
            let bits: Vec<u32> = if fast { vec![3] } else { vec![4, 3, 2] };
            exp::fig3::run(&mut env, &sizes, &bits, seeds)?;
        }
        "table3" => exp::tables::table3(&mut env, &sizes)?,
        "ablation-alpha" => exp::tables::ablation_alpha(&mut env, &sizes)?,
        "table4" => {
            let size = sizes.first().copied().unwrap_or(Size::TinyS);
            exp::tables::table4(&mut env, size)?;
        }
        "appendix" | "table5" | "table6" | "table7" | "table8" | "table9" | "table10" => {
            let settings = if fast {
                vec![QuantConfig::int(3), QuantConfig::int_group(2, 32)]
            } else {
                QuantConfig::appendix_settings()
            };
            exp::tables::appendix_tables(&mut env, &sizes, &settings)?;
        }
        "all" => {
            exp::tables::table1_and_2(&mut env, &sizes)?;
            exp::tables::table3(&mut env, &sizes)?;
            exp::tables::table4(&mut env, sizes.first().copied().unwrap_or(Size::TinyS))?;
            let size = sizes.get(1).copied().unwrap_or(sizes[0]);
            exp::fig2::run(&mut env, size, 3, None)?;
            let seeds = if fast { 2u64 } else { 5u64 };
            let bits: &[u32] = if fast { &[3] } else { &[4, 3, 2] };
            exp::fig3::run(&mut env, &sizes, bits, seeds)?;
            let settings = if fast {
                vec![QuantConfig::int(3), QuantConfig::int_group(2, 32)]
            } else {
                QuantConfig::appendix_settings()
            };
            exp::tables::appendix_tables(&mut env, &sizes, &settings)?;
        }
        other => bail!("unknown experiment '{other}'"),
    }
    if env.used_fallback {
        eprintln!("[exp] NOTE: ran with RANDOM weights (artifacts missing). Results are structural only.");
    }
    Ok(())
}

fn info() -> Result<()> {
    println!("QEP reproduction — three-layer Rust + JAX + Pallas stack");
    for s in Size::all() {
        let c = s.config();
        println!(
            "  {:7} (stand-in for {:11}): dim={} layers={} heads={} ffn={} params={:.2}M",
            c.name,
            s.paper_analog(),
            c.dim,
            c.n_layers,
            c.n_heads,
            c.ffn,
            c.n_params() as f64 / 1e6
        );
    }
    match qep::runtime::PjrtRuntime::cpu() {
        Ok(rt) => println!("  PJRT: {}", rt.platform()),
        Err(e) => println!("  PJRT unavailable: {e}"),
    }
    Ok(())
}
