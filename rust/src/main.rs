//! `repro` — the QEP reproduction CLI (L3 leader entrypoint).
//!
//! ```text
//! repro gen-data [--out artifacts/data] [--tokens N]
//! repro quantize --model tiny-s --method gptq --bits 3 [--group 64] [--qep 0.5] [--out q.qtz]
//! repro eval --model-file q.qtz [--flavor wiki] [--tasks]
//! repro exp <fig1|fig2|fig3|table1|table2|table3|table4|ablation-alpha|appendix|all>
//!           [--sizes s,m,l] [--fast] [--shard i/N --out DIR [--resume]] [--results DIR]
//! repro exp plan <id>            # list the sweep's cell manifest
//! repro exp cell <cell-id> --out DIR
//! repro exp status <id> --out DIR [--shard i/N]
//! repro exp merge <id> --out DIR [--results DIR]
//! repro serve-bench [--model tiny-s] [--sessions 4] [--gen 32] [--bits 4] [--group 32]
//! repro info
//! ```

use anyhow::{anyhow, bail, Context, Result};
use qep::coordinator::{CBQ_WINDOW_META_KEY, Pipeline, PipelineConfig};
use qep::eval::{perplexity, TaskFamily, TaskSet};
use qep::exp::{self, plan, ExpEnv, PlanCell, PlanParams, RenderCfg, ShardSpec, SweepId};
use qep::io::results;
use qep::model::{Model, Size};
use qep::quant::{Method, QuantConfig};
use qep::text::{Corpus, Flavor};
use qep::util::cli::Args;
use qep::util::pool;
use std::collections::HashSet;
use std::path::{Path, PathBuf};

fn main() {
    let args = Args::from_env();
    let result = dispatch(&args);
    // Gracefully join the persistent pool workers (no-op if no parallel
    // dispatch ever started them).
    qep::util::pool::shutdown();
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Per-subcommand accepted flags. `reject_unknown` turns a typo'd flag
/// (e.g. `--shards`) into a usage error instead of silently ignoring it
/// — which for a sharded sweep would mean quietly running every cell.
const GEN_DATA_FLAGS: &[&str] = &["threads", "out", "tokens"];
const QUANTIZE_FLAGS: &[&str] = &[
    "threads", "model", "method", "bits", "group", "qep", "calib", "seed", "out", "artifacts",
    "verbose", "lowrank-rank", "bit-budget", "alloc", "cbq-window",
];
const EVAL_FLAGS: &[&str] = &["threads", "model-file", "flavor", "tasks", "chunk", "artifacts"];
/// `repro exp <id>` (run / shard-run). Plan flags + execution flags.
/// `--stable-timings` is accepted both when rendering (placeholder
/// wall-clock cells) and when persisting records with `--out` (records
/// written with zeroed timings, so determinism gates can byte-compare
/// record files); `--resume` continues an interrupted `--out` run.
const EXP_RUN_FLAGS: &[&str] = &[
    "threads",
    "sizes",
    "fast",
    "artifacts",
    "bits",
    "blocks",
    "seeds",
    "ranks",
    "budgets",
    "windows",
    "shard",
    "out",
    "results",
    "stable-timings",
    "resume",
];
/// `repro exp plan <id>`: plan flags only (nothing runs or renders).
const EXP_PLAN_FLAGS: &[&str] = &[
    "threads", "sizes", "fast", "bits", "blocks", "seeds", "ranks", "budgets", "windows", "shard",
];
/// `repro exp status <id>`: plan flags + the record directory (+ an
/// optional shard slice to report on). `--connect` instead asks a live
/// fleet coordinator; `--watch` re-polls either source until done.
const EXP_STATUS_FLAGS: &[&str] = &[
    "threads", "sizes", "fast", "bits", "blocks", "seeds", "ranks", "budgets", "windows", "shard",
    "out", "connect", "watch",
];
/// `repro exp serve <id>`: the fleet coordinator — run flags minus
/// `--shard` (the fleet assigns cells dynamically) plus the listen
/// socket and lease tuning. No `--artifacts`: the coordinator never
/// runs a cell, it only dispatches, persists, and renders.
const EXP_SERVE_FLAGS: &[&str] = &[
    "threads",
    "sizes",
    "fast",
    "bits",
    "blocks",
    "seeds",
    "ranks",
    "budgets",
    "windows",
    "out",
    "results",
    "stable-timings",
    "resume",
    "listen",
    "lease-ms",
];
/// `repro exp work`: the fleet worker — everything about the plan comes
/// over the wire, so only the coordinator address and local execution
/// knobs are accepted.
const EXP_WORK_FLAGS: &[&str] = &["threads", "connect", "artifacts"];
/// `repro exp cell <cell-id>`: the cell ID carries the whole plan.
const EXP_CELL_FLAGS: &[&str] = &["threads", "artifacts", "out"];
/// `repro exp merge <id>`: plan flags + collect/render flags (no --shard
/// — merge always collects the full manifest).
const EXP_MERGE_FLAGS: &[&str] = &[
    "threads",
    "sizes",
    "fast",
    "bits",
    "blocks",
    "seeds",
    "ranks",
    "budgets",
    "windows",
    "out",
    "results",
    "stable-timings",
];
const INFO_FLAGS: &[&str] = &["threads"];
/// `repro serve-bench`: batched KV-cache serving throughput, quantized
/// vs dense f32, on one process.
const SERVE_BENCH_FLAGS: &[&str] =
    &["threads", "model", "artifacts", "sessions", "gen", "prompt-len", "bits", "group", "seed"];

fn check_flags(args: &Args, known: &[&str]) -> Result<()> {
    args.reject_unknown(known).map_err(|e| anyhow!("{e}"))
}

fn dispatch(args: &Args) -> Result<()> {
    if let Some(t) = args.get("threads") {
        let n: usize = t
            .parse()
            .map_err(|_| anyhow!("--threads expects a non-negative integer, got '{t}'"))?;
        qep::util::pool::set_global_threads(n);
    }
    match args.positional.first().map(|s| s.as_str()) {
        Some("gen-data") => {
            check_flags(args, GEN_DATA_FLAGS)?;
            gen_data(args)
        }
        Some("quantize") => {
            check_flags(args, QUANTIZE_FLAGS)?;
            quantize(args)
        }
        Some("eval") => {
            check_flags(args, EVAL_FLAGS)?;
            eval(args)
        }
        Some("exp") => experiment(args),
        Some("serve-bench") => {
            check_flags(args, SERVE_BENCH_FLAGS)?;
            serve_bench(args)
        }
        Some("info") => {
            check_flags(args, INFO_FLAGS)?;
            info()
        }
        Some("help") | None => {
            println!("{}", HELP);
            Ok(())
        }
        Some(other) => bail!("unknown command '{other}' (run `repro help` for usage)"),
    }
}

const HELP: &str = "\
repro — Quantization Error Propagation (QEP) reproduction

USAGE:
  repro gen-data [--out artifacts/data] [--tokens 262144]
  repro quantize --model <tiny-s|tiny-m|tiny-l|path.qtz> --method <rtn|gptq|awq|quip>
                 [--bits <2|3|4|8> | --bit-budget B [--alloc dp|greedy]] [--group N]
                 [--qep <alpha>] [--lowrank-rank R] [--cbq-window W]
                 [--calib <wiki|ptb|c4>] [--seed N] [--threads N] [--out out.qtz]
  repro eval     --model-file <path.qtz> [--flavor wiki] [--tasks] [--chunk N]
  repro exp      <fig1..fig3|table1..table10|ablation-alpha|appendix|lowrank|budget|cbq|all>
                 [--sizes s,m,l] [--fast] [--ranks 4,16] [--budgets 2.5,3.0,3.5]
                 [--windows 1,2,3] [--artifacts DIR]
                 [--results DIR] [--shard i/N] [--out DIR] [--resume]
                 [--stable-timings]
  repro exp plan  <id> [--fast] [--sizes ...] [--shard i/N]
  repro exp cell  <cell-id> --out DIR
  repro exp status <id> --out DIR [--shard i/N] [--fast] [--sizes ...] [--watch]
  repro exp status --connect <addr|fleet.addr> [--watch]
  repro exp merge <id> --out DIR [--results DIR] [--stable-timings] [--fast] [--sizes ...]
  repro exp serve <id> --out DIR [--listen 127.0.0.1:0] [--lease-ms 30000]
                 [--resume] [--stable-timings] [--results DIR] [--fast] [--sizes ...]
  repro exp work  --connect <addr|fleet.addr> [--artifacts DIR] [--threads N]
  repro serve-bench [--model <tiny-s|tiny-m|tiny-l|path.qtz>] [--sessions 4] [--gen 32]
                 [--prompt-len 16] [--bits 4] [--group 32] [--seed 0] [--threads N]
  repro info

Unrecognized --flags are rejected with a usage error (a typo'd flag must
never silently change what a sweep runs).

LOW-RANK RECONSTRUCTION (LQER/QERA family):
  --lowrank-rank R  (quantize) After quantizing each layer, approximate
                  its quantization residual W − Q(W) with a rank-R
                  adjunct U·V computed from a deterministic SVD. When a
                  calibration Hessian is available the residual is
                  whitened by its Cholesky factor first (QERA's analytic
                  activation-weighted form); otherwise a plain SVD of
                  the residual (LQER). R=0 (default) disables it. The
                  adjunct is orthogonal to --qep: both can be on at
                  once. With --out, the .qtz stores the on-grid base
                  weights plus factored `lowrank.<layer>.{u,v}` tensor
                  sections; eval and serving fold or fuse them back in
                  (serving applies y += U·(V·x) after the quantized
                  GEMM, bit-identical to dense correction).
  --ranks a,b,... (exp lowrank) Non-zero adjunct ranks the sweep
                  enumerates next to its rank-0 base/+qep reference
                  rows (default 4,16; --fast: 2).

BUDGET (Hessian-guided mixed-precision bit allocation):
  --bit-budget B  (quantize) Instead of one uniform --bits width, give
                  the model a global *average* bits-per-weight budget
                  (e.g. 2.5) and let a sensitivity-guided allocator
                  assign each layer its own width. A calibration
                  pre-pass scores every layer's quantization error at
                  the candidate widths, weighted by its Hessian
                  diagonal diag(XᵀX); every layer gets at least ⌊B⌋
                  bits and the fractional surplus buys one-bit
                  upgrades for the most sensitive layers, so the
                  allocated model dominates the uniform ⌊B⌋ grid
                  layer-by-layer. Feasible range: 2.0–8.0 (the INT2..
                  INT8 grids). Mutually exclusive with --bits. The
                  allocation (budget, allocator, per-layer bit map) is
                  stored in the .qtz meta; `repro eval` and serving
                  materialize the same per-layer grids. Composes with
                  --qep and --lowrank-rank.
  --alloc dp|greedy  (quantize, with --bit-budget) Allocator choice:
                  'dp' (default) is an exact knapsack over upgrade
                  units; 'greedy' upgrades by best marginal gain per
                  weight. Both are deterministic (ties break to the
                  lowest layer index) and bit-identical across
                  --threads values; they agree whenever all layers
                  hold the same number of weights.
  --budgets a,b,... (exp budget) Budgets the mixed-precision sweep
                  enumerates (default 2.5,3.0,3.5; --fast: 2.5), each
                  as DP-allocated cells next to a uniform INT⌊B⌋
                  baseline sharing the same calibration stream — the
                  rendered table reads allocated vs uniform PPL at the
                  same budget.

CBQ (cross-block reconstruction):
  --cbq-window W  (quantize) Reconstruct jointly over tumbling windows
                  of W transformer blocks instead of strictly one layer
                  at a time: every window past the first gets its
                  layer-wise pass first, then all of its linears are
                  re-reconstructed together against the full-precision
                  reference re-propagated from the window's quantized
                  entry activations — CBQ's cross-block error
                  compensation on top of QEP's per-layer correction.
                  W=1 (default) is exactly the layer-wise schedule;
                  windows larger than the quantized block count clamp
                  loudly to one whole-model window (which provably
                  reproduces the layer-wise bytes). Composes with
                  --qep, --lowrank-rank and --bit-budget; written to
                  the .qtz meta (`cbq_window`) when W > 1. Output stays
                  bit-identical for every --threads value.
  --windows a,b,... (exp cbq) Window sizes the cross-block sweep
                  enumerates (default 1,2,3; --fast: 1,2); w1 renders
                  as the layer-wise baseline row next to each windowed
                  variant.

SHARDING (distributed experiment sweeps):
  Every `exp` sweep first enumerates a stable, ordered manifest of cell
  IDs (see `repro exp plan <id>`), so the grid can split across
  processes or machines and merge back without losing determinism:

    repro exp all --fast --shard 1/3 --out shards/     # machine 1
    repro exp all --fast --shard 2/3 --out shards/     # machine 2
    repro exp all --fast --shard 3/3 --out shards/     # machine 3
    # machine 2 died mid-sweep? nothing is lost:
    repro exp status all --fast --out shards/          # who owes what
    repro exp all --fast --shard 2/3 --out shards/ --resume
    repro exp merge all --fast --out shards/           # fan-in

  --shard i/N     Run only the manifest cells with index % N == i-1
                  (1-based i) and write one JSON-lines record per cell
                  to --out DIR instead of rendering tables. Pass the
                  same sweep flags (--fast/--sizes/...) to every shard
                  and to merge: the manifest is a pure function of them.
  --out DIR       Durable record mode (with or without --shard): every
                  cell's record is appended to DIR in manifest order and
                  fsynced the moment it completes, so a crash or SIGKILL
                  loses at most the cells in flight — never the file. A
                  fresh run refuses records that already exist for its
                  cells (and an unsharded run refuses any non-empty DIR):
                  that is interrupted progress; continue it with --resume
                  or use a fresh directory.
  --resume        Continue an interrupted --out run: existing records are
                  validated against the manifest (unknown, duplicate, or
                  parameter-mismatched records — written under different
                  flags — are hard errors), a torn final line from a
                  mid-write kill is truncated and re-run, and only the
                  missing cells execute. A resumed run's records and
                  merged tables are byte-identical to an uninterrupted
                  run's (with --stable-timings; CI enforces this with a
                  kill-and-resume gate).
  exp status      Report completion of a record directory without running
                  anything: done/missing/torn counts per sweep (optionally
                  for one --shard slice), the next missing cell IDs, and
                  any records that would fail a merge or resume.
                    repro exp status all --fast --out shards/
  exp merge       Load every *.jsonl record file in --out DIR, verify
                  the manifest is covered exactly once (gaps, duplicates
                  and unknown IDs are hard errors — `exp status` shows
                  which shards still owe cells), and render tables
                  into --results DIR (default results/). Merged output
                  is byte-identical to the unsharded run for every N —
                  cell seeds derive from cell identity, never from
                  scheduling (CI enforces this with a 3-shard matrix).
  exp cell        Run a single cell by ID (IDs round-trip: anything
                  `repro exp plan` prints is accepted), for external
                  schedulers and crash recovery.
  --stable-timings  Determinism-gate mode for the one non-deterministic
                  metric, shard-local wall-clock: rendering shows Table
                  3's timing cells as a fixed placeholder, and records
                  written with --out carry zeroed timing fields so two
                  runs of the same cells are byte-identical files.

FLEET (live TCP dispatch — sharding without pre-splitting):
  Where --shard fixes each process's slice up front, the fleet assigns
  cells dynamically: a coordinator owns the sweep's single record file
  and hands out one cell at a time to however many workers connect,
  from one terminal to a cluster:

    repro exp serve all --fast --out fleet/            # coordinator
    repro exp work --connect fleet/fleet.addr          # worker(s), any count
    repro exp status --connect fleet/fleet.addr --watch

  exp serve       Listen for workers (default --listen 127.0.0.1:0; the
                  bound address is printed and written to
                  --out/fleet.addr), dispatch cells, append each
                  accepted record durably (fsynced, manifest order) to
                  --out/<sweep>.shard-1-of-1.jsonl — the same file an
                  unsharded `--out` run writes — and render when every
                  cell is recorded. Workers that miss a heartbeat for a
                  full lease (--lease-ms, default 30000) or drop their
                  connection have their cells requeued automatically; a
                  cell that was requeued and finishes twice keeps only
                  the first accepted record (first durable write wins —
                  records derive from cell identity, so both copies are
                  bit-identical and the file stays deterministic).
                  --resume continues a killed coordinator (or local
                  unsharded run) over the same --out dir, dispatching
                  only the missing cells. Record files and renders are
                  byte-identical to a local run for every worker count
                  and kill schedule (with --stable-timings; CI's
                  fleet-kill-resume gate SIGKILLs a worker AND the
                  coordinator and diffs against a local run).
  exp work        Connect to a coordinator (host:port, or the path of
                  its fleet.addr file), run assigned cells, send each
                  record back over the socket. Heartbeats keep the lease
                  alive while a slow cell runs; a worker that dies is
                  simply reassigned. Workers never write records — the
                  coordinator is the only writer.
  exp status --connect
                  Ask a live coordinator for progress (done/leased/
                  unassigned cells, connected workers); --watch re-polls
                  every second until the sweep finishes. Without
                  --connect, `exp status <id> --out DIR [--watch]` reads
                  the record directory as before.

SERVING:
  serve-bench    Batched KV-cache serving throughput on this machine:
                 the same model is served dense f32 and packed
                 INT<bits>g<group> (fused dequantize×GEMM), greedy
                 decode under the continuous-batching scheduler, and the
                 single-stream + aggregate tokens/sec are reported with
                 the quantized-vs-f32 speedup. Sizes resolve through
                 artifacts/ with a random-weights fallback (timing is
                 weight-independent). `cargo bench --bench
                 serve_throughput` is the multi-point version (N ∈
                 {1,4,16}) that persists BENCH_serve.json.

THREADS:
  --threads N    Worker threads for the parallel execution engine (GEMMs,
                 Hessian builds, blocked Cholesky/SPD solves, per-layer
                 fan-out, GPTQ row sweeps, batched perplexity/task eval,
                 and sharded `exp` cell sweeps). Accepted by every
                 subcommand. 0 or omitted = use all hardware threads.
                 Output is bit-identical for every N — per-layer and
                 per-cell seeds derive from names and all parallel
                 reductions have a fixed order — so the knob only trades
                 wall-clock time. (Exception to *sharding*, not to
                 determinism: `exp table3` runs its cells serially because
                 it measures per-cell runtime.)

                 Pool lifecycle: worker threads are persistent. They spawn
                 once, on the first parallel dispatch (pre-started by the
                 quantize pipeline), park between jobs, and are joined
                 when repro exits. `--threads 1` bypasses them entirely —
                 every kernel runs inline on the calling thread and no
                 worker threads are ever created.

DOCS:
  README.md             quickstart + repo layout map + distributed sweeps
  docs/ARCHITECTURE.md  dataflow (enumerate→run→render) and paper-equation
                        pointers
  docs/PERFORMANCE.md   parallelism contract, pool + micro-kernel design,
                        how to benchmark (cargo bench)
  cargo doc --no-deps   API reference (kept warning-free in CI)
";

fn gen_data(args: &Args) -> Result<()> {
    let out = args.get_or("out", "artifacts/data");
    let tokens = args.get_usize("tokens", 256 * 1024);
    std::fs::create_dir_all(out)?;
    for flavor in Flavor::all() {
        let c = Corpus::generate(flavor, tokens, 0);
        let path = format!("{out}/{}.txt", flavor.name());
        std::fs::write(&path, &c.text)?;
        println!("wrote {path} ({} bytes)", c.text.len());
    }
    Ok(())
}

fn load_model(args: &Args, key: &str) -> Result<Model> {
    let spec = args
        .get(key)
        .ok_or_else(|| anyhow!("--{key} required"))?;
    if let Some(size) = Size::from_name(spec) {
        let reg = qep::runtime::ArtifactRegistry::new(args.get_or("artifacts", "artifacts"));
        reg.load_model(size.name())
    } else {
        Model::load(spec)
    }
}

fn quantize(args: &Args) -> Result<()> {
    let model = load_model(args, "model")?;
    let method = Method::from_name(args.get_or("method", "rtn"))
        .ok_or_else(|| anyhow!("unknown method"))?;
    // --bits and --bit-budget are mutually exclusive by design: a budget
    // allocates every layer's width itself, so an explicit uniform width
    // next to it can only be a contradiction — error loudly instead of
    // silently ignoring one of them.
    let bit_budget = match args.get("bit-budget") {
        None => None,
        Some(v) => {
            if args.get("bits").is_some() {
                bail!(
                    "--bits and --bit-budget are mutually exclusive: a bit budget assigns \
                     per-layer widths itself (drop --bits, or drop --bit-budget for a \
                     uniform grid)"
                );
            }
            let b = qep::quant::BitBudget::parse(v).ok_or_else(|| {
                anyhow!(
                    "--bit-budget expects an average bits-per-weight like 2.5 or 3 \
                     (at most one decimal), got '{v}'"
                )
            })?;
            qep::quant::budget::check_feasible(b)?;
            Some(b)
        }
    };
    let alloc = match args.get("alloc") {
        None => qep::quant::Alloc::default(),
        Some(v) => {
            if bit_budget.is_none() {
                bail!("--alloc only applies with --bit-budget");
            }
            qep::quant::Alloc::from_name(v)
                .ok_or_else(|| anyhow!("--alloc expects 'dp' or 'greedy', got '{v}'"))?
        }
    };
    let bits = args.get_usize("bits", 4) as u32;
    let quant = match args.get("group") {
        Some(g) => QuantConfig::int_group(bits, g.parse()?),
        None => QuantConfig::int(bits),
    };
    let qep_alpha = args.get("qep").map(|a| a.parse::<f32>()).transpose()?;
    let flavor = Flavor::from_name(args.get_or("calib", "c4"))
        .ok_or_else(|| anyhow!("unknown calib flavor"))?;
    let seed = args.get_usize("seed", 0) as u64;
    let lowrank_rank: usize = match args.get("lowrank-rank") {
        None => 0,
        Some(v) => v
            .parse()
            .map_err(|_| anyhow!("--lowrank-rank expects a non-negative integer, got '{v}'"))?,
    };
    let cbq_window: usize = match args.get("cbq-window") {
        None => 1,
        Some(v) => match v.parse() {
            Ok(w) if w >= 1 => w,
            _ => bail!("--cbq-window expects a positive integer (1 = layer-wise), got '{v}'"),
        },
    };

    let mut env = ExpEnv::new(args.get_or("artifacts", "artifacts"));
    let calib = env.calib_tokens(flavor, model.cfg.seq_len, seed);
    // `--threads` is handled once in dispatch() (set_global_threads);
    // threads: 0 in the default config resolves to that global setting.
    let cfg = PipelineConfig {
        quant,
        method,
        qep_alpha,
        lowrank_rank,
        cbq_window,
        seed,
        verbose: args.has("verbose"),
        bit_budget: bit_budget.map(|budget| qep::quant::BudgetSpec { budget, alloc }),
        ..Default::default()
    };
    println!("quantizing {} with {}", model.cfg.name, cfg.label());
    let out = Pipeline::new(cfg).run(&model, &calib)?;
    println!("{}", out.report.summary());
    if let Some(a) = &out.allocation {
        println!("{}", a.summary());
    }
    if let Some(path) = args.get("out") {
        // The allocation (budget, allocator, per-layer bit map) rides in
        // the .qtz meta so eval and serving materialize the same
        // per-layer grids this run quantized on.
        let mut tf = if out.adjuncts.is_empty() {
            out.model.to_tensor_file()
        } else {
            // Store the on-grid base weights plus the factored adjuncts
            // (not the effective sum): serving re-packs the base weights
            // losslessly and applies U·(V·x) after the quantized GEMM.
            let base = out.base_model.as_ref().expect("adjuncts imply a base model");
            qep::qep::to_tensor_file_with_adjuncts(base, &out.adjuncts, lowrank_rank)
        };
        if let Some(a) = &out.allocation {
            qep::quant::budget::write_allocation_meta(&mut tf.meta, a);
        }
        if cbq_window > 1 {
            tf.meta.set(CBQ_WINDOW_META_KEY, qep::util::json::Json::Num(cbq_window as f64));
        }
        tf.save(path)?;
        println!("saved {path}");
    }
    let eval_tokens = env.eval_tokens(Flavor::Wiki);
    println!("wiki ppl: {:.3}", perplexity(&out.model, &eval_tokens));
    Ok(())
}

fn eval(args: &Args) -> Result<()> {
    // Low-rank adjunct sections, if present, are folded into the dense
    // weights here: eval measures the effective model.
    let mf = args.get("model-file").ok_or_else(|| anyhow!("--model-file required"))?;
    let tf = qep::io::TensorFile::load(mf).with_context(|| format!("loading model {mf}"))?;
    let mut model = Model::from_tensor_file(&tf)?;
    let adjuncts = qep::qep::adjuncts_from_tensor_file(&tf)?;
    if !adjuncts.is_empty() {
        qep::qep::materialize_into_model(&mut model, &adjuncts)?;
        println!("applied {} low-rank adjunct(s)", adjuncts.len());
    }
    if let Some(a) = qep::quant::budget::read_allocation_meta(&tf.meta)? {
        println!("mixed-precision: {}", a.summary());
    }
    if let Some(w) = tf.meta.get(CBQ_WINDOW_META_KEY).and_then(|v| v.as_f64()) {
        println!("cbq window: {w}");
    }
    let flavor = Flavor::from_name(args.get_or("flavor", "wiki"))
        .ok_or_else(|| anyhow!("unknown flavor"))?;
    let mut env = ExpEnv::new(args.get_or("artifacts", "artifacts"));
    let tokens = env.eval_tokens(flavor);
    let chunk = args.get_usize("chunk", qep::eval::DEFAULT_CHUNK_SEGMENTS);
    println!(
        "{} ppl: {:.3}",
        flavor.name(),
        qep::eval::perplexity_chunked(&model, &tokens, chunk)
    );
    if args.has("tasks") {
        let corpus = env.corpus(Flavor::Wiki);
        for fam in TaskFamily::all() {
            let ts = TaskSet::generate(fam, &corpus, 60, 1234);
            println!("{} ({}): {:.4}", fam.name(), fam.paper_analog(), ts.accuracy(&model));
        }
    }
    Ok(())
}

/// `repro serve-bench`: throughput of the batched KV-cache serving
/// engine, dense f32 vs packed low-bit, on synthetic prompts. Greedy
/// decode through the continuous-batching scheduler; reports tokens/sec
/// for both engines and the speedup.
fn serve_bench(args: &Args) -> Result<()> {
    use qep::serve::{Scheduler, ServeConfig, ServeModel};
    use qep::util::rng::Rng;
    use qep::util::Stopwatch;

    let spec = args.get_or("model", "tiny-s");
    // A .qtz written by `quantize --bit-budget` carries its per-layer bit
    // allocation in the meta; serving honors it so the packed engine runs
    // the exact grids the pipeline allocated.
    let (model, allocation) = if let Some(size) = Size::from_name(spec) {
        let mut env = ExpEnv::new(args.get_or("artifacts", "artifacts"));
        (env.model(size), None)
    } else {
        let tf = qep::io::TensorFile::load(spec).with_context(|| format!("loading model {spec}"))?;
        let alloc = qep::quant::budget::read_allocation_meta(&tf.meta)?;
        (Model::from_tensor_file(&tf)?, alloc)
    };
    let sessions = args.get_usize("sessions", 4).max(1);
    let gen = args.get_usize("gen", 32).max(1);
    let prompt_len = args.get_usize("prompt-len", 16).clamp(1, model.cfg.seq_len);
    let bits = args.get_usize("bits", 4) as u32;
    let group = args.get_usize("group", 32);
    let seed = args.get_usize("seed", 0) as u64;
    let qcfg = QuantConfig::int_group(bits, group);

    // Synthetic byte prompts: serving throughput does not depend on the
    // weights being trained, only on shapes and batch composition.
    let mut rng = Rng::new(seed);
    let prompts: Vec<Vec<u32>> = (0..sessions)
        .map(|_| (0..prompt_len).map(|_| rng.below(256) as u32).collect())
        .collect();

    let mut run = |sm: ServeModel, label: &str| -> Result<f64> {
        let mut sched = Scheduler::new(
            sm,
            ServeConfig { max_batch: sessions, max_new_tokens: gen },
            pool::global(),
        );
        for p in &prompts {
            sched.submit(p)?;
        }
        let t = Stopwatch::start();
        let done = sched.run();
        let secs = t.seconds();
        let tokens: usize = done.iter().map(|c| c.tokens.len()).sum();
        let tok_s = tokens as f64 / secs.max(1e-9);
        println!(
            "{label:18} {tokens:6} tokens in {secs:7.3}s  = {tok_s:8.1} tok/s  \
             ({sessions} sessions × ≤{gen} new)",
        );
        Ok(tok_s)
    };

    println!(
        "serve-bench: {} (dim={} layers={} seq={}), prompts {}×{}",
        model.cfg.name, model.cfg.dim, model.cfg.n_layers, model.cfg.seq_len, sessions, prompt_len
    );
    let f32_tok_s = run(ServeModel::from_model(&model), "dense f32")?;
    let (qm, qlabel) = match &allocation {
        Some(a) => {
            println!("serving per-layer grids: {}", a.summary());
            (
                ServeModel::quantized_per_layer(&model, &qcfg, &a.bits),
                format!("mixed B{}g{group}", a.budget.render()),
            )
        }
        None => (ServeModel::quantized(&model, &qcfg), format!("int{bits}g{group}")),
    };
    let q_tok_s = run(qm, &qlabel)?;
    println!("speedup (quantized vs f32): {:.2}×", q_tok_s / f32_tok_s.max(1e-9));
    Ok(())
}

/// Resolve `<id>` at `positional[pos]` into a sweep + its plan params.
fn sweep_from(args: &Args, pos: usize) -> Result<(SweepId, PlanParams)> {
    let name = args.positional.get(pos).ok_or_else(|| {
        anyhow!(
            "missing experiment id (fig1..fig3, table1..table10, ablation-alpha, appendix, \
             lowrank, budget, cbq, all)"
        )
    })?;
    let sweep = SweepId::from_name(name)
        .ok_or_else(|| anyhow!("unknown experiment '{name}'"))?;
    let params = PlanParams::from_args(sweep, args)?;
    Ok((sweep, params))
}

fn render_cfg(args: &Args) -> RenderCfg {
    RenderCfg {
        results_dir: args.get_or("results", "results").to_string(),
        stable_timings: args.has("stable-timings"),
    }
}

const FALLBACK_NOTE: &str =
    "[exp] NOTE: ran with RANDOM weights (artifacts missing). Results are structural only.";

fn experiment(args: &Args) -> Result<()> {
    let sub = args
        .positional
        .get(1)
        .ok_or_else(|| {
            anyhow!("usage: repro exp <id|plan|cell|status|merge|serve|work> (see `repro help`)")
        })?
        .as_str();
    match sub {
        "plan" => {
            check_flags(args, EXP_PLAN_FLAGS)?;
            exp_plan(args)
        }
        "cell" => {
            check_flags(args, EXP_CELL_FLAGS)?;
            exp_cell(args)
        }
        "status" => {
            check_flags(args, EXP_STATUS_FLAGS)?;
            exp_status(args)
        }
        "merge" => {
            check_flags(args, EXP_MERGE_FLAGS)?;
            exp_merge(args)
        }
        "serve" => {
            check_flags(args, EXP_SERVE_FLAGS)?;
            exp_serve(args)
        }
        "work" => {
            check_flags(args, EXP_WORK_FLAGS)?;
            exp_work(args)
        }
        _ => {
            check_flags(args, EXP_RUN_FLAGS)?;
            exp_run(args)
        }
    }
}

/// `repro exp plan <id>`: print the manifest, one cell ID per line
/// (restricted to one shard's slice with `--shard i/N`).
fn exp_plan(args: &Args) -> Result<()> {
    let (sweep, params) = sweep_from(args, 2)?;
    let mut cells = plan::manifest(sweep, &params)?;
    let total = cells.len();
    if let Some(spec) = args.get("shard") {
        let spec = ShardSpec::parse(spec)?;
        cells = spec.filter(&cells);
        eprintln!(
            "[plan] '{}': {} of {} cell(s) on shard {}/{}",
            sweep.name(),
            cells.len(),
            total,
            spec.index,
            spec.count
        );
    } else {
        eprintln!("[plan] '{}': {} cell(s)", sweep.name(), total);
    }
    for c in &cells {
        println!("{}", c.id());
    }
    Ok(())
}

/// `repro exp cell <cell-id> --out DIR`: run one cell by identity and
/// persist its record — the primitive external schedulers build on.
fn exp_cell(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(2)
        .ok_or_else(|| anyhow!("usage: repro exp cell <cell-id> --out DIR"))?;
    let pc = PlanCell::parse(id).ok_or_else(|| {
        anyhow!("unparseable cell id '{id}' (run `repro exp plan <id>` to list valid cells)")
    })?;
    let out_dir = args
        .require("out", "where the cell's record file goes")
        .map_err(|e| anyhow!("{e}"))?;
    let mut env = ExpEnv::new(args.get_or("artifacts", "artifacts"));
    let data = env.snapshot(&[pc.size()]);
    let rec = exp::common::run_plan_cell(&data, &pc, 0, 1)?;
    let path = Path::new(out_dir).join(results::cell_filename(id));
    results::write_records(&path, &[rec])?;
    println!("wrote 1 cell record to {}", path.display());
    if env.used_fallback {
        eprintln!("{FALLBACK_NOTE}");
    }
    Ok(())
}

/// `repro exp status <id> --out DIR [--shard i/N]`: completion triage
/// for a record directory — done/missing/torn counts per sweep (and per
/// shard slice), next missing cell IDs, and any records that would make
/// a merge or resume fail. Purely informational: problems are printed,
/// never exit codes; `exp merge` stays the gate.
fn exp_status(args: &Args) -> Result<()> {
    let watch = args.has("watch");
    if let Some(target) = args.get("connect") {
        // Live mode: the coordinator defines the plan, so no sweep id or
        // record directory is needed here.
        return fleet_status(target, watch);
    }
    let (sweep, params) = sweep_from(args, 2)?;
    let dir = args
        .require("out", "the directory holding the record files to inspect")
        .map_err(|e| anyhow!("{e}"))?;
    let mut cells = plan::manifest(sweep, &params)?;
    let mut label = format!("'{}'", sweep.name());
    if let Some(spec) = args.get("shard") {
        let spec = ShardSpec::parse(spec)?;
        cells = spec.filter(&cells);
        label = format!("'{}' shard {}/{}", sweep.name(), spec.index, spec.count);
    }
    loop {
        let scan = exp::common::scan_record_dir(Path::new(dir))?;
        let report = exp::common::status_report(&cells, &scan);
        print!("{}", report.render(&label));
        if !watch || report.done == report.total {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_millis(WATCH_POLL_MS));
    }
}

/// Poll cadence for `exp status --watch` (both dir and fleet modes).
const WATCH_POLL_MS: u64 = 1000;

/// Resolve a `--connect` value: a literal `host:port`, or a path to the
/// `fleet.addr` file the coordinator writes next to its records (handy
/// for scripts that never have to parse the bound port themselves).
fn resolve_addr(target: &str) -> Result<String> {
    let p = Path::new(target);
    if p.is_file() {
        let text = std::fs::read_to_string(p)
            .with_context(|| format!("reading coordinator address file {target}"))?;
        return Ok(text.trim().to_string());
    }
    Ok(target.to_string())
}

/// `repro exp status --connect ADDR [--watch]`: live progress straight
/// from a running coordinator's state machine (includes leases and
/// connected workers, which no record directory can show).
fn fleet_status(target: &str, watch: bool) -> Result<()> {
    use qep::fleet::wire::{self, Msg};
    let addr = resolve_addr(target)?;
    let mut seen_one = false;
    loop {
        let stream = match std::net::TcpStream::connect(&addr) {
            Ok(s) => s,
            Err(_) if watch && seen_one => {
                // The coordinator renders and exits the moment the last
                // cell lands — a vanished socket after successful polls
                // is completion, not failure.
                println!("[fleet] coordinator at {addr} is gone (sweep finished or aborted)");
                return Ok(());
            }
            Err(e) => {
                return Err(e).with_context(|| format!("connecting to coordinator at {addr}"))
            }
        };
        let mut s = &stream;
        wire::write_msg(&mut s, &Msg::StatusReq).map_err(|e| anyhow!("{e}"))?;
        match wire::read_msg(&mut s).map_err(|e| anyhow!("{e}"))? {
            Msg::Status { total, done, leased, pending, workers } => {
                let st = qep::fleet::coord::FleetStatus {
                    total: total as usize,
                    done: done as usize,
                    leased: leased as usize,
                    pending: pending as usize,
                    workers: workers as usize,
                };
                println!("{}", st.render());
                if !watch || done == total {
                    return Ok(());
                }
                seen_one = true;
            }
            other => bail!("expected a Status reply, got {other:?}"),
        }
        std::thread::sleep(std::time::Duration::from_millis(WATCH_POLL_MS));
    }
}

/// `repro exp serve <id> --out DIR`: the fleet coordinator. Owns the
/// sweep's single record file (`<sweep>.shard-1-of-1.jsonl`, exactly
/// what an unsharded `--out` run writes), hands cells to `repro exp
/// work` workers over TCP, requeues cells from dead workers, and
/// renders once every cell is durably recorded. `--resume` continues an
/// interrupted coordinator (its own or a local unsharded run's) over
/// the same directory, dispatching only the missing cells.
fn exp_serve(args: &Args) -> Result<()> {
    let (sweep, params) = sweep_from(args, 2)?;
    let out_dir = args
        .require("out", "the directory the fleet's record file goes to")
        .map_err(|e| anyhow!("{e}"))?;
    let resume = args.has("resume");
    let stable = args.has("stable-timings");
    let lease_ms = args.get_usize("lease-ms", 30_000).max(20) as u64;
    let cells = plan::manifest(sweep, &params)?;
    let (skip, path) = prepare_records(
        Path::new(out_dir),
        &results::shard_filename(sweep.name(), 1, 1),
        &cells,
        &cells,
        resume,
        true,
    )?;
    let opts = qep::fleet::coord::FleetOpts {
        lease_ms,
        stable_timings: stable,
        ..Default::default()
    };
    let appender = results::RecordAppender::open(&path)?;
    let state = qep::fleet::coord::CoordState::new(&cells, &skip, appender, opts)?;
    let listener = std::net::TcpListener::bind(args.get_or("listen", "127.0.0.1:0"))
        .with_context(|| format!("binding {}", args.get_or("listen", "127.0.0.1:0")))?;
    let addr = listener.local_addr()?;
    // Advertise the bound address (ports from `:0` are OS-assigned) in a
    // non-.jsonl file the record scanners ignore; removed on exit.
    let addr_file = Path::new(out_dir).join("fleet.addr");
    std::fs::write(&addr_file, format!("{addr}\n"))
        .with_context(|| format!("writing {}", addr_file.display()))?;
    println!(
        "[serve] '{}': {} cell(s), {} already recorded; listening on {addr} \
         (workers: repro exp work --connect {addr})",
        sweep.name(),
        cells.len(),
        skip.len(),
    );
    let served = qep::fleet::coord::serve(listener, state, lease_ms);
    std::fs::remove_file(&addr_file).ok();
    served?;
    let rcfg = render_cfg(args);
    let fallback = render_from_dir(sweep, &params, Path::new(out_dir), &rcfg)?;
    println!(
        "[serve] sweep '{}' complete: {} record(s) in {}, rendered into {}/",
        sweep.name(),
        cells.len(),
        path.display(),
        rcfg.results_dir
    );
    if fallback {
        eprintln!("{FALLBACK_NOTE}");
    }
    Ok(())
}

/// `repro exp work --connect ADDR`: one fleet worker. Runs cells the
/// coordinator assigns until the sweep completes.
fn exp_work(args: &Args) -> Result<()> {
    let target = args
        .require("connect", "the coordinator's host:port (or its fleet.addr file)")
        .map_err(|e| anyhow!("{e}"))?;
    let cfg = qep::fleet::worker::WorkerCfg {
        connect: resolve_addr(target)?,
        artifacts: args.get_or("artifacts", "artifacts").to_string(),
        connect_timeout: std::time::Duration::from_secs(10),
    };
    let completed = qep::fleet::worker::run_worker(&cfg)?;
    println!("[work] sweep complete: this worker ran {completed} cell(s)");
    Ok(())
}

/// Load every record file in `dir`, verify exact manifest coverage, and
/// render. Shared by `exp merge` and the durable (`--out`) run path so a
/// resumed run renders through exactly the records it persisted.
fn render_from_dir(
    sweep: SweepId,
    params: &PlanParams,
    dir: &Path,
    rcfg: &RenderCfg,
) -> Result<bool> {
    let cells = plan::manifest(sweep, params)?;
    let mut records = Vec::new();
    for (path, recs) in results::read_record_dir(dir)? {
        eprintln!("[records] {}: {} record(s)", path.display(), recs.len());
        records.extend(recs);
    }
    let map = plan::verify_coverage(&cells, records).with_context(|| {
        format!(
            "records in {} do not cover the '{}' manifest (run `repro exp status {} --out {} \
             <same flags>` for per-shard completion and torn-tail triage)",
            dir.display(),
            sweep.name(),
            sweep.name(),
            dir.display()
        )
    })?;
    let fallback = map.any_fallback();
    exp::common::render_sweep(sweep, params, &map, rcfg)?;
    Ok(fallback)
}

/// `repro exp merge <id> --out DIR`: the collector. Loads every record
/// file a shard run wrote into DIR, verifies the manifest is covered
/// exactly once, and renders — byte-identical to the unsharded sweep.
fn exp_merge(args: &Args) -> Result<()> {
    let (sweep, params) = sweep_from(args, 2)?;
    let dir = args
        .require("out", "the directory the shard runs wrote records into")
        .map_err(|e| anyhow!("{e}"))?;
    let rcfg = render_cfg(args);
    let fallback = render_from_dir(sweep, &params, Path::new(dir), &rcfg)?;
    println!(
        "[merge] rendered '{}' from cell records in {} into {}/",
        sweep.name(),
        dir,
        rcfg.results_dir
    );
    if fallback {
        eprintln!("{FALLBACK_NOTE}");
    }
    Ok(())
}

/// Resolve the record file + skip set for a durable (`--out`) run.
///
/// Fresh runs refuse to touch records that already exist for this run —
/// the target file itself, or any record in the directory naming one of
/// this run's cells — because silently re-running them would either
/// clobber durable progress or hand `merge` duplicates. `--resume` is
/// the explicit opt-in: the directory is scanned and validated against
/// the manifest (unknown / parameter-mismatched / duplicate records are
/// hard errors), a torn tail on this run's own file is physically
/// truncated, and everything already recorded lands in the skip set.
fn prepare_records(
    dir: &Path,
    file_name: &str,
    all_cells: &[PlanCell],
    mine: &[PlanCell],
    resume: bool,
    require_empty: bool,
) -> Result<(HashSet<String>, PathBuf)> {
    let path = dir.join(file_name);
    let scan = exp::common::scan_record_dir(dir)?;
    if !resume {
        // Unsharded runs render from the whole directory afterwards, so
        // they need it genuinely fresh; sibling shards of the same run
        // legitimately share a directory, so a shard run only refuses
        // records that collide with *its* slice (or its own file).
        if require_empty && !scan.files.is_empty() {
            bail!(
                "--out {} already holds {} record file(s) — pass --resume to continue an \
                 interrupted run of this sweep, or point --out at a fresh directory; \
                 `repro exp status` shows its completion",
                dir.display(),
                scan.files.len()
            );
        }
        if path.exists() {
            bail!(
                "{} already exists — pass --resume to continue that run (finished cells are \
                 skipped), or point --out at a fresh directory; `repro exp status` shows \
                 its completion",
                path.display()
            );
        }
        let mine_ids: HashSet<String> = mine.iter().map(|c| c.id()).collect();
        if let Some((p, rec)) = scan.records.iter().find(|(_, r)| mine_ids.contains(&r.id)) {
            bail!(
                "--out already holds a record for this run's cell '{}' (in {}) — pass \
                 --resume to skip finished cells, or use a fresh directory",
                rec.id,
                p.display()
            );
        }
        return Ok((HashSet::new(), path));
    }
    let done = exp::common::validate_resume(all_cells, &scan)?;
    for (p, _) in &scan.torn {
        if *p == path {
            if results::truncate_torn(p)? {
                eprintln!(
                    "[exp] resume: truncated torn tail in {} (that cell re-runs)",
                    p.display()
                );
            }
        } else {
            eprintln!(
                "[exp] resume: ignoring torn tail in {} (another run's file — resume it \
                 separately)",
                p.display()
            );
        }
    }
    Ok((done, path))
}

/// One durable (`--out`) run, shared by the `--shard` and unsharded
/// branches of [`exp_run`]: guard/validate the directory
/// ([`prepare_records`]), snapshot, and execute with per-cell durable
/// appends. Returns (newly-run count, record file path).
struct DurableCli<'a> {
    env: &'a mut ExpEnv,
    /// Full manifest (resume validation context).
    cells: &'a [PlanCell],
    /// The slice this run executes.
    mine: &'a [PlanCell],
    dir: &'a Path,
    file_name: String,
    /// Record bookkeeping (shard, n_shards); (0, 1) for unsharded runs.
    shard: (usize, usize),
    resume: bool,
    require_empty: bool,
    stable: bool,
}

fn run_durable(cli: DurableCli) -> Result<(usize, PathBuf)> {
    let (skip, path) = prepare_records(
        cli.dir,
        &cli.file_name,
        cli.cells,
        cli.mine,
        cli.resume,
        cli.require_empty,
    )?;
    let data = cli.env.snapshot(&plan::sizes_of(cli.mine));
    let opts = exp::common::DurableRun {
        skip: &skip,
        sink: results::RecordAppender::open(&path)?,
        stable_timings: cli.stable,
    };
    let new = exp::common::run_cells_durable(
        &data,
        cli.mine,
        &pool::global(),
        cli.shard.0,
        cli.shard.1,
        opts,
    )?;
    Ok((new.len(), path))
}

/// `repro exp <id>`: the sweep driver. Unsharded it runs the whole
/// manifest and renders; with `--shard i/N` it runs one deterministic
/// slice and only persists records (rendering needs every cell — use
/// `merge`). Whenever `--out DIR` is given, records are appended durably
/// cell-by-cell (fsynced, manifest order) so a killed run loses at most
/// the cell in flight, and `--resume` picks up exactly the missing
/// cells — bit-identical to never having been interrupted.
fn exp_run(args: &Args) -> Result<()> {
    let (sweep, params) = sweep_from(args, 1)?;
    let resume = args.has("resume");
    let stable = args.has("stable-timings");
    let mut env = ExpEnv::new(args.get_or("artifacts", "artifacts"));
    match args.get("shard") {
        Some(spec) => {
            let spec = ShardSpec::parse(spec)?;
            let out_dir = args
                .require("out", "the directory this shard's record file goes to")
                .map_err(|e| anyhow!("{e}"))?;
            // A shard run persists records and never renders — reject
            // render-only flags instead of silently ignoring them.
            // (--stable-timings *is* meaningful here: it zeroes the
            // shard-local wall-clock fields in the persisted records.)
            if args.has("results") {
                bail!(
                    "--results has no effect with --shard (rendering happens at \
                     `repro exp merge`); pass it there instead"
                );
            }
            let cells = plan::manifest(sweep, &params)?;
            let mine = spec.filter(&cells);
            let (new_count, path) = run_durable(DurableCli {
                env: &mut env,
                cells: &cells,
                mine: &mine,
                dir: Path::new(out_dir),
                file_name: results::shard_filename(sweep.name(), spec.index, spec.count),
                shard: (spec.index, spec.count),
                resume,
                require_empty: false,
                stable,
            })?;
            println!(
                "[shard {}/{}] {} cell record(s) in {} ({} newly run; manifest has {} cells)",
                spec.index,
                spec.count,
                mine.len(),
                path.display(),
                new_count,
                cells.len()
            );
        }
        None => {
            let rcfg = render_cfg(args);
            match args.get("out") {
                None => {
                    if resume {
                        bail!(
                            "--resume requires --out DIR: records are what a resumed run \
                             continues from"
                        );
                    }
                    exp::common::run_sweep(&mut env, sweep, &params, &rcfg)?;
                }
                Some(out_dir) => {
                    let cells = plan::manifest(sweep, &params)?;
                    let (new_count, path) = run_durable(DurableCli {
                        env: &mut env,
                        cells: &cells,
                        mine: &cells,
                        dir: Path::new(out_dir),
                        file_name: results::shard_filename(sweep.name(), 1, 1),
                        shard: (0, 1),
                        resume,
                        require_empty: true,
                        stable,
                    })?;
                    println!(
                        "wrote {} cell record(s) to {} ({} newly run)",
                        cells.len(),
                        path.display(),
                        new_count
                    );
                    // Render through the persisted records — exactly what
                    // a merge of this directory would see.
                    render_from_dir(sweep, &params, Path::new(out_dir), &rcfg)?;
                }
            }
        }
    }
    if env.used_fallback {
        eprintln!("{FALLBACK_NOTE}");
    }
    Ok(())
}

fn info() -> Result<()> {
    println!("QEP reproduction — three-layer Rust + JAX + Pallas stack");
    for s in Size::all() {
        let c = s.config();
        println!(
            "  {:7} (stand-in for {:11}): dim={} layers={} heads={} ffn={} params={:.2}M",
            c.name,
            s.paper_analog(),
            c.dim,
            c.n_layers,
            c.n_heads,
            c.ffn,
            c.n_params() as f64 / 1e6
        );
    }
    match qep::runtime::PjrtRuntime::cpu() {
        Ok(rt) => println!("  PJRT: {}", rt.platform()),
        Err(e) => println!("  PJRT unavailable: {e}"),
    }
    Ok(())
}
