//! Synthetic zero-shot multiple-choice tasks, scored by length-normalized
//! option log-likelihood — the same scoring machinery as the paper's
//! ArcE/PiQA/StoryCloze harness (lm-eval style).
//!
//! Three families are generated from a corpus (see DESIGN.md §2):
//! * `Cloze`      (PiQA analog, 4-way): pick the corpus-consistent next
//!   word among distractors sampled from far-away positions.
//! * `Completion` (StoryCloze analog, 2-way): true continuation of a
//!   passage vs a continuation lifted from elsewhere.
//! * `Pattern`    (ArcE analog, 4-way): true continuation vs
//!   character-scrambled corruptions of it.

use crate::linalg::Mat;
use crate::model::{Forward, Model};
use crate::text::{ByteTokenizer, Corpus};
use crate::util::pool::{self, Pool};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskFamily {
    Cloze,
    Completion,
    Pattern,
}

impl TaskFamily {
    pub fn name(self) -> &'static str {
        match self {
            TaskFamily::Cloze => "cloze",
            TaskFamily::Completion => "completion",
            TaskFamily::Pattern => "pattern",
        }
    }

    /// The paper benchmark each family stands in for.
    pub fn paper_analog(self) -> &'static str {
        match self {
            TaskFamily::Cloze => "PIQA",
            TaskFamily::Completion => "StoryCloze",
            TaskFamily::Pattern => "ARC-Easy",
        }
    }

    pub fn all() -> [TaskFamily; 3] {
        [TaskFamily::Cloze, TaskFamily::Completion, TaskFamily::Pattern]
    }

    pub fn n_options(self) -> usize {
        match self {
            TaskFamily::Completion => 2,
            _ => 4,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Task {
    pub prompt: String,
    pub options: Vec<String>,
    pub correct: usize,
}

pub struct TaskSet {
    pub family: TaskFamily,
    pub tasks: Vec<Task>,
}

impl TaskSet {
    /// Build `n` tasks from a corpus, deterministic in `seed`.
    pub fn generate(family: TaskFamily, corpus: &Corpus, n: usize, seed: u64) -> TaskSet {
        let mut rng = Rng::new(seed ^ 0x7A5C_0000 ^ family.name().len() as u64);
        let text = &corpus.text;
        let words: Vec<&str> = text.split_whitespace().collect();
        let mut tasks = Vec::with_capacity(n);
        let mut guard = 0;
        while tasks.len() < n && guard < n * 50 {
            guard += 1;
            if let Some(t) = make_task(family, text, &words, &mut rng) {
                tasks.push(t);
            }
        }
        TaskSet { family, tasks }
    }

    /// Accuracy of `model` on this task set, scored on the process-global
    /// pool (tasks are independent forward passes).
    pub fn accuracy(&self, model: &Model) -> f64 {
        self.accuracy_with(model, &pool::global())
    }

    /// [`TaskSet::accuracy`] on an explicit pool. Each task's scoring is
    /// an independent forward pass, and the correct-count reduction is an
    /// integer sum, so the result is identical for every thread count.
    pub fn accuracy_with(&self, model: &Model, pool: &Pool) -> f64 {
        if self.tasks.is_empty() {
            return 0.0;
        }
        let hits = pool.par_map(self.tasks.len(), |i| {
            let t = &self.tasks[i];
            OptionScorer::new(model).pick(&t.prompt, &t.options) == t.correct
        });
        let correct = hits.into_iter().filter(|&h| h).count();
        correct as f64 / self.tasks.len() as f64
    }
}

fn make_task(family: TaskFamily, text: &str, words: &[&str], rng: &mut Rng) -> Option<Task> {
    match family {
        TaskFamily::Cloze => {
            // Prompt = span ending right before a word; options = that word
            // + 3 words sampled from far away (must differ).
            if words.len() < 64 {
                return None;
            }
            let wi = 24 + rng.below(words.len() - 48);
            let target = words.get(wi)?.trim_end_matches(['.', ',']);
            if target.len() < 3 {
                return None;
            }
            let prompt_words = &words[wi.saturating_sub(16)..wi];
            let prompt = prompt_words.join(" ") + " ";
            let mut options = vec![target.to_string()];
            let mut tries = 0;
            while options.len() < 4 && tries < 64 {
                tries += 1;
                let d = words[rng.below(words.len())].trim_end_matches(['.', ',']);
                if d.len() >= 3 && !options.iter().any(|o| o == d) {
                    options.push(d.to_string());
                }
            }
            if options.len() < 4 {
                return None;
            }
            shuffle_with_answer(prompt, options, rng)
        }
        TaskFamily::Completion => {
            let len = text.len();
            if len < 600 {
                return None;
            }
            let a = floor_char(text, rng.below(len - 400));
            let p_end = floor_char(text, a + 192);
            let t_end = floor_char(text, p_end + 96);
            let prompt = text[a..p_end].to_string();
            let truth = text[p_end..t_end].to_string();
            // Distractor: same length, far-away position.
            let b = floor_char(text, (a + len / 2) % (len - 200));
            let b_end = floor_char(text, b + (t_end - p_end));
            let distract = text[b..b_end].to_string();
            if truth == distract || truth.is_empty() || distract.is_empty() {
                return None;
            }
            shuffle_with_answer(prompt, vec![truth, distract], rng)
        }
        TaskFamily::Pattern => {
            let len = text.len();
            if len < 400 {
                return None;
            }
            let a = floor_char(text, rng.below(len - 300));
            let p_end = floor_char(text, a + 128);
            let t_end = floor_char(text, p_end + 64);
            let prompt = text[a..p_end].to_string();
            let truth = text[p_end..t_end].to_string();
            let mut options = vec![truth.clone()];
            for _ in 0..3 {
                options.push(scramble(&truth, rng));
            }
            if options[1..].iter().any(|o| *o == truth) {
                return None;
            }
            shuffle_with_answer(prompt, options, rng)
        }
    }
}

/// Scramble the characters of each word (keeps whitespace structure —
/// plausible-looking but ungrammatical, the "wrong answer" signature).
fn scramble(s: &str, rng: &mut Rng) -> String {
    s.split(' ')
        .map(|w| {
            let mut chars: Vec<char> = w.chars().collect();
            rng.shuffle(&mut chars);
            chars.into_iter().collect::<String>()
        })
        .collect::<Vec<_>>()
        .join(" ")
}

fn floor_char(s: &str, mut i: usize) -> usize {
    i = i.min(s.len());
    while i > 0 && !s.is_char_boundary(i) {
        i -= 1;
    }
    i
}

/// Shuffle options (truth is at index 0 on input), tracking the correct
/// index so answer position carries no signal.
fn shuffle_with_answer(prompt: String, options: Vec<String>, rng: &mut Rng) -> Option<Task> {
    let n = options.len();
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let correct = order.iter().position(|&i| i == 0)?;
    let options = order.into_iter().map(|i| options[i].clone()).collect();
    Some(Task { prompt, options, correct })
}

/// Length-normalized option log-likelihood scorer.
pub struct OptionScorer<'m> {
    model: &'m Model,
}

impl<'m> OptionScorer<'m> {
    pub fn new(model: &'m Model) -> OptionScorer<'m> {
        OptionScorer { model }
    }

    /// Mean per-token log-prob of `option` following `prompt`.
    pub fn score(&self, prompt: &str, option: &str) -> f64 {
        let tok = ByteTokenizer;
        let seq = self.model.cfg.seq_len;
        let mut ids = tok.encode(prompt);
        let opt_ids = tok.encode(option);
        if opt_ids.is_empty() {
            return f64::NEG_INFINITY;
        }
        ids.extend_from_slice(&opt_ids);
        // Keep the last `seq` tokens; the option must fit.
        if ids.len() > seq {
            ids.drain(..ids.len() - seq);
        }
        let opt_len = opt_ids.len().min(ids.len().saturating_sub(1));
        let opt_start = ids.len() - opt_len;
        // Pad to a full segment (causal: pads after the option are inert).
        let real_len = ids.len();
        ids.resize(seq, crate::text::PAD);
        let f = Forward::new(&self.model.cfg);
        let logits = f.forward(self.model, &ids);
        let mut lp = 0.0f64;
        for pos in opt_start..real_len {
            // logits at pos-1 predict token at pos.
            lp += log_prob(&logits, pos - 1, ids[pos] as usize);
        }
        lp / opt_len as f64
    }

    pub fn pick(&self, prompt: &str, options: &[String]) -> usize {
        let mut best = 0;
        let mut best_score = f64::NEG_INFINITY;
        for (i, o) in options.iter().enumerate() {
            let s = self.score(prompt, o);
            if s > best_score {
                best_score = s;
                best = i;
            }
        }
        best
    }
}

fn log_prob(logits: &Mat, row: usize, target: usize) -> f64 {
    let r = logits.row(row);
    let max = r.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let lse: f32 = r.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
    (r[target] - lse) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::text::Flavor;

    fn tiny_model() -> Model {
        let mut cfg = ModelConfig::new("unit", 16, 2, 2, 32);
        cfg.seq_len = 64;
        Model::random(&cfg, 1)
    }

    #[test]
    fn generation_is_deterministic_and_sized() {
        let corpus = Corpus::generate(Flavor::Wiki, 30_000, 0);
        for fam in TaskFamily::all() {
            let a = TaskSet::generate(fam, &corpus, 20, 7);
            let b = TaskSet::generate(fam, &corpus, 20, 7);
            assert_eq!(a.tasks.len(), 20, "{fam:?}");
            for (x, y) in a.tasks.iter().zip(b.tasks.iter()) {
                assert_eq!(x.prompt, y.prompt);
                assert_eq!(x.options, y.options);
                assert_eq!(x.correct, y.correct);
            }
        }
    }

    #[test]
    fn option_counts_match_family() {
        let corpus = Corpus::generate(Flavor::C4, 30_000, 1);
        for fam in TaskFamily::all() {
            let ts = TaskSet::generate(fam, &corpus, 10, 3);
            for t in &ts.tasks {
                assert_eq!(t.options.len(), fam.n_options());
                assert!(t.correct < t.options.len());
            }
        }
    }

    #[test]
    fn correct_answers_are_uniformly_placed() {
        let corpus = Corpus::generate(Flavor::Ptb, 40_000, 2);
        let ts = TaskSet::generate(TaskFamily::Cloze, &corpus, 60, 5);
        let mut counts = [0usize; 4];
        for t in &ts.tasks {
            counts[t.correct] += 1;
        }
        // No position should hoard the answers (guards against a scorer
        // that always picks index 0 looking accurate).
        assert!(counts.iter().all(|&c| c > 3), "{counts:?}");
    }

    #[test]
    fn random_model_scores_near_chance() {
        let corpus = Corpus::generate(Flavor::Wiki, 30_000, 3);
        let model = tiny_model();
        let ts = TaskSet::generate(TaskFamily::Completion, &corpus, 30, 9);
        let acc = ts.accuracy(&model);
        assert!(acc > 0.15 && acc < 0.85, "acc {acc}");
    }

    #[test]
    fn scorer_prefers_duplicated_prompt_text() {
        // A model with strong positional/token correlations isn't available
        // untrained; instead sanity-check the scorer machinery: identical
        // options must produce identical scores.
        let model = tiny_model();
        let scorer = OptionScorer::new(&model);
        let a = scorer.score("hello world ", "foo bar");
        let b = scorer.score("hello world ", "foo bar");
        assert_eq!(a, b);
        assert!(a.is_finite());
    }

    #[test]
    fn scramble_preserves_length_structure() {
        let mut rng = Rng::new(4);
        let s = "alpha beta gamma";
        let sc = scramble(s, &mut rng);
        assert_eq!(sc.split(' ').count(), 3);
        assert_eq!(sc.len(), s.len());
    }
}
