//! Evaluation harness: perplexity (Tables 1, 5–7), zero-shot multiple
//! choice tasks (Tables 2, 8–10), and the per-block error-accumulation
//! metric Δ_m (Fig. 2).

pub mod delta;
pub mod ppl;
pub mod tasks;

pub use delta::delta_per_block;
pub use ppl::perplexity;
pub use tasks::{Task, TaskFamily, TaskSet};
