//! Evaluation harness: perplexity (Tables 1, 5–7), zero-shot multiple
//! choice tasks (Tables 2, 8–10), and the per-block error-accumulation
//! metric Δ_m (Fig. 2).
//!
//! Both perplexity and task scoring batch their independent forward
//! passes across the work-stealing pool (`crate::util::pool`) with fixed
//! reduction orders, so every metric is bit-identical for every thread
//! count; `*_with` variants take the pool explicitly, plain names use the
//! process-global one (`repro --threads`).

pub mod delta;
pub mod ppl;
pub mod tasks;

pub use delta::delta_per_block;
pub use ppl::{perplexity, perplexity_chunked, perplexity_with, DEFAULT_CHUNK_SEGMENTS};
pub use tasks::{Task, TaskFamily, TaskSet};
