//! Perplexity evaluation, chunked so memory stays flat on long corpora
//! and batched across the work-stealing pool so long corpora evaluate at
//! hardware speed.
//!
//! Calibration segments are independent by construction (each segment
//! attends only within itself and segment-boundary positions carry no
//! next-token target), so chunks of segments fan out across pool workers.
//! The per-chunk forward passes are untouched and the final log-loss
//! reduction runs in fixed chunk order on the calling thread, so the
//! result is **bit-identical for every thread count** — same contract as
//! the rest of the parallel engine.

use crate::model::ops::next_token_nll;
use crate::model::{Forward, Model};
use crate::util::pool::{self, Pool};

/// Segments per forward pass used by [`perplexity`]. Large enough to
/// amortize per-chunk setup, small enough that logits for one chunk
/// ([`DEFAULT_CHUNK_SEGMENTS`] × seq_len × vocab floats) stay cache- and
/// memory-friendly, and small enough to leave several chunks per worker
/// for stealing on typical eval budgets.
pub const DEFAULT_CHUNK_SEGMENTS: usize = 8;

/// Next-token perplexity of `model` over `tokens` (trimmed to a multiple
/// of seq_len), on the process-global pool. Forwards to
/// [`perplexity_chunked`] with [`DEFAULT_CHUNK_SEGMENTS`].
///
/// Degenerate inputs are NaN-free by contract: with fewer tokens than one
/// full segment — or a seq_len of 1, which leaves no position with a
/// next-token target — there is nothing to score and the result is
/// `f64::INFINITY` (no evidence of fit), never NaN and never a panic.
///
/// ```
/// use qep::eval::perplexity;
/// use qep::model::{Model, ModelConfig};
/// let mut cfg = ModelConfig::new("doc", 16, 2, 2, 32);
/// cfg.seq_len = 8;
/// let model = Model::random(&cfg, 0);
/// let tokens: Vec<u32> = (0..32).map(|t| (t % 251) as u32).collect();
/// let ppl = perplexity(&model, &tokens);
/// assert!(ppl.is_finite() && ppl > 1.0);
/// // Fewer tokens than one segment: defined, not a panic.
/// assert_eq!(perplexity(&model, &tokens[..3]), f64::INFINITY);
/// ```
pub fn perplexity(model: &Model, tokens: &[u32]) -> f64 {
    perplexity_chunked(model, tokens, DEFAULT_CHUNK_SEGMENTS)
}

/// [`perplexity`] with an explicit chunk size (segments per forward pass)
/// on the process-global pool.
pub fn perplexity_chunked(model: &Model, tokens: &[u32], chunk_segments: usize) -> f64 {
    perplexity_with(model, tokens, chunk_segments, &pool::global())
}

/// [`perplexity_chunked`] on an explicit pool: chunks of `chunk_segments`
/// segments run their forward passes in parallel; per-chunk (nll, count)
/// pairs are reduced in chunk order, so at a fixed chunk size the value
/// is bit-identical to the serial evaluation for every thread count.
/// Different chunk sizes regroup the partial log-loss sums (different
/// floating-point association) and may differ in the last bits — the
/// thread-count knob is the bit-exact one, the chunk size is not.
pub fn perplexity_with(model: &Model, tokens: &[u32], chunk_segments: usize, pool: &Pool) -> f64 {
    let seq = model.cfg.seq_len;
    let usable = tokens.len() / seq * seq;
    if usable == 0 {
        return f64::INFINITY; // not enough tokens for one segment
    }
    let chunk = chunk_segments.max(1) * seq;
    let pieces: Vec<&[u32]> = tokens[..usable].chunks(chunk).collect();
    let partials = pool.par_map(pieces.len(), |i| {
        let f = Forward::new(&model.cfg);
        let logits = f.forward(model, pieces[i]);
        next_token_nll(&logits, pieces[i], seq)
    });
    let mut sum = 0.0f64;
    let mut count = 0usize;
    for (s, c) in partials {
        sum += s;
        count += c;
    }
    if count == 0 {
        return f64::INFINITY; // seq_len == 1: every position is a boundary
    }
    (sum / count as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::util::rng::Rng;

    fn setup() -> (Model, Vec<u32>) {
        let mut cfg = ModelConfig::new("unit", 16, 2, 2, 32);
        cfg.seq_len = 8;
        let model = Model::random(&cfg, 1);
        let mut rng = Rng::new(2);
        let tokens: Vec<u32> = (0..8 * 20).map(|_| rng.below(256) as u32).collect();
        (model, tokens)
    }

    #[test]
    fn chunking_does_not_change_ppl() {
        let (model, tokens) = setup();
        let a = perplexity_chunked(&model, &tokens, 1);
        let b = perplexity_chunked(&model, &tokens, 20);
        assert!((a - b).abs() < 1e-6 * a, "{a} vs {b}");
    }

    #[test]
    fn default_forwards_to_chunked() {
        let (model, tokens) = setup();
        let a = perplexity(&model, &tokens);
        let b = perplexity_chunked(&model, &tokens, DEFAULT_CHUNK_SEGMENTS);
        assert_eq!(a, b);
    }

    #[test]
    fn pooled_eval_is_bit_identical_to_serial() {
        let (model, tokens) = setup();
        let want = perplexity_with(&model, &tokens, 2, &Pool::serial());
        for threads in [2usize, 3, 8] {
            let got = perplexity_with(&model, &tokens, 2, &Pool::new(threads));
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn degenerate_inputs_are_nan_free() {
        let (model, tokens) = setup();
        // Empty and shorter-than-one-segment inputs: +∞, no panic, no NaN.
        assert_eq!(perplexity(&model, &[]), f64::INFINITY);
        assert_eq!(perplexity(&model, &tokens[..1]), f64::INFINITY);
        assert_eq!(perplexity(&model, &tokens[..7]), f64::INFINITY);
        // seq_len = 1 leaves no next-token targets: +∞ as documented.
        let mut cfg = model.cfg.clone();
        cfg.seq_len = 1;
        let m1 = Model::random(&cfg, 1);
        assert_eq!(perplexity(&m1, &tokens[..4]), f64::INFINITY);
    }

    #[test]
    fn trailing_partial_segment_is_ignored() {
        let (model, tokens) = setup();
        let a = perplexity(&model, &tokens);
        let mut extended = tokens.clone();
        extended.extend_from_slice(&[1, 2, 3]); // 3 extra tokens < seq_len
        let b = perplexity(&model, &extended);
        assert!((a - b).abs() < 1e-9);
    }

    #[test]
    fn damaged_model_has_higher_ppl_on_structured_text() {
        // On structured (corpus) text a trained-ish signal is absent here,
        // but catastrophically corrupting weights must not *reduce* PPL
        // relative to the same model evaluated consistently.
        let (model, _) = setup();
        let corpus = crate::text::Corpus::generate(crate::text::Flavor::Wiki, 2048, 0);
        let base = perplexity(&model, &corpus.tokens);
        let mut broken = model.clone();
        for b in broken.blocks.iter_mut() {
            b.wq.scale(30.0);
            b.down.scale(30.0);
        }
        let worse = perplexity(&broken, &corpus.tokens);
        assert!(worse.is_finite());
        assert!(worse >= base * 0.5, "corruption imploded ppl: {base} -> {worse}");
    }
}
