//! Perplexity evaluation, chunked so memory stays flat on long corpora.

use crate::model::ops::next_token_nll;
use crate::model::{Forward, Model};

/// Next-token perplexity of `model` over `tokens` (trimmed to a multiple of
/// seq_len). Processes `chunk_segments` segments per forward pass.
pub fn perplexity(model: &Model, tokens: &[u32]) -> f64 {
    perplexity_chunked(model, tokens, 8)
}

pub fn perplexity_chunked(model: &Model, tokens: &[u32], chunk_segments: usize) -> f64 {
    let seq = model.cfg.seq_len;
    let usable = tokens.len() / seq * seq;
    assert!(usable > 0, "not enough tokens for one segment");
    let f = Forward::new(&model.cfg);
    let chunk = (chunk_segments.max(1)) * seq;
    let mut sum = 0.0f64;
    let mut count = 0usize;
    for piece in tokens[..usable].chunks(chunk) {
        let logits = f.forward(model, piece);
        let (s, c) = next_token_nll(&logits, piece, seq);
        sum += s;
        count += c;
    }
    (sum / count.max(1) as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::util::rng::Rng;

    fn setup() -> (Model, Vec<u32>) {
        let mut cfg = ModelConfig::new("unit", 16, 2, 2, 32);
        cfg.seq_len = 8;
        let model = Model::random(&cfg, 1);
        let mut rng = Rng::new(2);
        let tokens: Vec<u32> = (0..8 * 20).map(|_| rng.below(256) as u32).collect();
        (model, tokens)
    }

    #[test]
    fn chunking_does_not_change_ppl() {
        let (model, tokens) = setup();
        let a = perplexity_chunked(&model, &tokens, 1);
        let b = perplexity_chunked(&model, &tokens, 20);
        assert!((a - b).abs() < 1e-6 * a, "{a} vs {b}");
    }

    #[test]
    fn trailing_partial_segment_is_ignored() {
        let (model, tokens) = setup();
        let a = perplexity(&model, &tokens);
        let mut extended = tokens.clone();
        extended.extend_from_slice(&[1, 2, 3]); // 3 extra tokens < seq_len
        let b = perplexity(&model, &extended);
        assert!((a - b).abs() < 1e-9);
    }

    #[test]
    fn damaged_model_has_higher_ppl_on_structured_text() {
        // On structured (corpus) text a trained-ish signal is absent here,
        // but catastrophically corrupting weights must not *reduce* PPL
        // relative to the same model evaluated consistently.
        let (model, _) = setup();
        let corpus = crate::text::Corpus::generate(crate::text::Flavor::Wiki, 2048, 0);
        let base = perplexity(&model, &corpus.tokens);
        let mut broken = model.clone();
        for b in broken.blocks.iter_mut() {
            b.wq.scale(30.0);
            b.down.scale(30.0);
        }
        let worse = perplexity(&broken, &corpus.tokens);
        assert!(worse.is_finite());
        assert!(worse >= base * 0.5, "corruption imploded ppl: {base} -> {worse}");
    }
}
