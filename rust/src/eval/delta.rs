//! The Fig. 2 diagnostic: Δ_m = ‖f_m(X) − f̂_m(X)‖²_F per transformer
//! block, where f̂ is the (partially) quantized model. The paper quantizes
//! the first 10 blocks and shows the error keeps *growing* through the
//! remaining full-precision blocks — the motivation for QEP.

use crate::model::{Forward, Model};

/// Δ_m for m = 1..=n_layers: squared Frobenius distance between the two
/// models' activations *after* block m (index 0 in the returned vec is
/// after block 1).
pub fn delta_per_block(full: &Model, quantized: &Model, tokens: &[u32]) -> Vec<f64> {
    assert_eq!(full.cfg, quantized.cfg, "model configs differ");
    let f = Forward::new(&full.cfg);
    let trace_full = f.block_trace(full, tokens);
    let trace_q = f.block_trace(quantized, tokens);
    // trace[i] = activations entering block i; trace[n] = final states.
    // Δ after block m = trace[m+1] difference, skipping the embedding (i=0,
    // identical by construction).
    (1..trace_full.len())
        .map(|i| trace_full[i].sub(&trace_q[i]).frob_sq())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Pipeline, PipelineConfig};
    use crate::model::ModelConfig;
    use crate::quant::{Method, QuantConfig};
    use crate::util::rng::Rng;

    fn setup() -> (Model, Vec<u32>) {
        let mut cfg = ModelConfig::new("unit", 16, 4, 2, 32);
        cfg.seq_len = 8;
        let model = Model::random(&cfg, 1);
        let mut rng = Rng::new(2);
        let tokens: Vec<u32> = (0..8 * 8).map(|_| rng.below(256) as u32).collect();
        (model, tokens)
    }

    #[test]
    fn identical_models_have_zero_delta() {
        let (model, tokens) = setup();
        let d = delta_per_block(&model, &model, &tokens);
        assert_eq!(d.len(), 4);
        assert!(d.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn partially_quantized_error_persists_after_quantized_prefix() {
        let (model, tokens) = setup();
        let out = Pipeline::new(PipelineConfig {
            quant: QuantConfig::int(2),
            method: Method::Rtn,
            max_blocks: Some(2),
            ..Default::default()
        })
        .run(&model, &tokens)
        .unwrap();
        let d = delta_per_block(&model, &out.model, &tokens);
        // Error is introduced in blocks 1-2 and must not vanish afterwards.
        assert!(d[0] > 0.0);
        assert!(d[1] > 0.0);
        assert!(d[2] > 0.0 && d[3] > 0.0, "error vanished in FP blocks: {d:?}");
    }

    #[test]
    fn error_grows_within_quantized_prefix() {
        let (model, tokens) = setup();
        let out = Pipeline::new(PipelineConfig {
            quant: QuantConfig::int(2),
            method: Method::Rtn,
            ..Default::default()
        })
        .run(&model, &tokens)
        .unwrap();
        let d = delta_per_block(&model, &out.model, &tokens);
        // Accumulation: last block's delta exceeds the first block's.
        assert!(d.last().unwrap() > &d[0], "{d:?}");
    }
}
