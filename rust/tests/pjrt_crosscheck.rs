//! Cross-checks between the PJRT-executed AOT artifacts (JAX/Pallas,
//! lowered at build time) and the pure-Rust forward path. These are the
//! tests that prove the three layers compose: same weights, same tokens,
//! same numbers.
//!
//! Gated on `artifacts/` being present (run `make artifacts`); without it
//! each test is a no-op pass with a loud eprintln, so `cargo test` stays
//! green on a fresh checkout. The whole file additionally requires the
//! `pjrt` cargo feature (the `xla` crate is not in the default build).

#![cfg(feature = "pjrt")]

use qep::linalg::matmul_tn;
use qep::model::{Forward, Model};
use qep::quant::{QuantConfig, QuantizedTensor};
use qep::runtime::executor::{literal_to_mat, mat_to_literal};
use qep::runtime::{ArtifactRegistry, PjrtRuntime};
use qep::text::Flavor;
use qep::util::rng::Rng;

fn registry() -> ArtifactRegistry {
    ArtifactRegistry::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

fn skip(name: &str) -> bool {
    let reg = registry();
    if !reg.has_model("tiny-s") {
        eprintln!("[{name}] SKIP: artifacts missing (run `make artifacts`)");
        return true;
    }
    false
}

#[test]
fn fwd_artifact_matches_rust_forward() {
    if skip("fwd_artifact_matches_rust_forward") {
        return;
    }
    let reg = registry();
    let model = reg.load_model("tiny-s").unwrap();
    let rt = PjrtRuntime::cpu().unwrap();
    let pjrt = qep::runtime::artifacts::PjrtModel::bind(&rt, &reg, &model).unwrap();

    let corpus = reg.load_corpus(Flavor::Wiki).unwrap();
    let tokens = &corpus.tokens[..model.cfg.seq_len];
    let jax_logits = pjrt.logits(tokens).unwrap();

    let f = Forward::new(&model.cfg);
    let rust_logits = f.forward(&model, tokens);

    assert_eq!((jax_logits.rows, jax_logits.cols), (rust_logits.rows, rust_logits.cols));
    let diff = jax_logits.sub(&rust_logits);
    let rel = diff.frob() / rust_logits.frob().max(1e-12);
    assert!(rel < 2e-4, "PJRT vs Rust logits diverge: rel={rel}");
}

#[test]
fn fwd_artifact_ppl_matches_rust_ppl() {
    if skip("fwd_artifact_ppl_matches_rust_ppl") {
        return;
    }
    let reg = registry();
    let model = reg.load_model("tiny-s").unwrap();
    let rt = PjrtRuntime::cpu().unwrap();
    let pjrt = qep::runtime::artifacts::PjrtModel::bind(&rt, &reg, &model).unwrap();
    let corpus = reg.load_corpus(Flavor::Wiki).unwrap();
    let tokens = &corpus.tokens[..model.cfg.seq_len * 4];
    let ppl_pjrt = pjrt.perplexity(tokens).unwrap();
    let ppl_rust = qep::eval::perplexity(&model, tokens);
    assert!(
        (ppl_pjrt - ppl_rust).abs() / ppl_rust < 1e-3,
        "ppl mismatch: pjrt={ppl_pjrt} rust={ppl_rust}"
    );
    // A trained model must be far below the uniform 259 baseline.
    assert!(ppl_rust < 100.0, "trained tiny-s ppl suspiciously high: {ppl_rust}");
}

#[test]
fn hessian_artifact_matches_rust_gemm() {
    if skip("hessian_artifact_matches_rust_gemm") {
        return;
    }
    let reg = registry();
    let rt = PjrtRuntime::cpu().unwrap();
    let exe = rt.load(reg.hess_hlo("tiny-s")).unwrap();
    let mut rng = Rng::new(9);
    let x = qep::linalg::Mat::randn(1024, 64, 1.0, &mut rng); // shape fixed by aot.py
    let out = exe.run(&[mat_to_literal(&x).unwrap()]).unwrap();
    let h_pjrt = literal_to_mat(&out[0]).unwrap();
    let h_rust = matmul_tn(&x, &x);
    let rel = h_pjrt.sub(&h_rust).frob() / h_rust.frob();
    assert!(rel < 1e-4, "Pallas hessian vs Rust: rel={rel}");
}

#[test]
fn qmm_artifact_matches_rust_dequant_matmul() {
    if skip("qmm_artifact_matches_rust_dequant_matmul") {
        return;
    }
    let reg = registry();
    let rt = PjrtRuntime::cpu().unwrap();
    let exe = rt.load(reg.qmm_hlo("tiny-s")).unwrap();

    // Build a quantized weight with the Rust grid, group=32 (aot contract).
    let mut rng = Rng::new(11);
    let w = qep::linalg::Mat::randn(64, 64, 1.0, &mut rng);
    let qt = QuantizedTensor::from_mat(&w, &QuantConfig::int_group(4, 32));
    let x = qep::linalg::Mat::randn(128, 64, 1.0, &mut rng);

    let codes_f32: Vec<f32> = qt.codes.iter().map(|&c| c as f32).collect();
    let codes = qep::linalg::Mat::from_vec(64, 64, codes_f32);
    let ngroups = qt.n_groups();
    let scales = qep::linalg::Mat::from_vec(64, ngroups, qt.scales.clone());
    let zeros = qep::linalg::Mat::from_vec(64, ngroups, qt.zeros.clone());

    let out = exe
        .run(&[
            mat_to_literal(&x).unwrap(),
            mat_to_literal(&codes).unwrap(),
            mat_to_literal(&scales).unwrap(),
            mat_to_literal(&zeros).unwrap(),
        ])
        .unwrap();
    let y_pjrt = literal_to_mat(&out[0]).unwrap();

    let y_rust = qep::linalg::matmul_nt(&x, &qt.dequantize());
    let rel = y_pjrt.sub(&y_rust).frob() / y_rust.frob();
    assert!(rel < 1e-4, "Pallas qmm vs Rust dequant·matmul: rel={rel}");
}

#[test]
fn block_artifact_matches_rust_block() {
    if skip("block_artifact_matches_rust_block") {
        return;
    }
    let reg = registry();
    let model = reg.load_model("tiny-s").unwrap();
    let rt = PjrtRuntime::cpu().unwrap();
    let exe = rt.load(reg.block_hlo("tiny-s")).unwrap();

    let mut rng = Rng::new(13);
    let x = qep::linalg::Mat::randn(model.cfg.seq_len, model.cfg.dim, 0.5, &mut rng);
    let b = &model.blocks[1];
    let inputs = vec![
        mat_to_literal(&x).unwrap(),
        qep::runtime::executor::vec_to_literal(&b.attn_norm),
        mat_to_literal(&b.wq).unwrap(),
        mat_to_literal(&b.wk).unwrap(),
        mat_to_literal(&b.wv).unwrap(),
        mat_to_literal(&b.wo).unwrap(),
        qep::runtime::executor::vec_to_literal(&b.mlp_norm),
        mat_to_literal(&b.gate).unwrap(),
        mat_to_literal(&b.up).unwrap(),
        mat_to_literal(&b.down).unwrap(),
    ];
    let out = exe.run(&inputs).unwrap();
    assert_eq!(out.len(), 5, "block artifact returns (out, 4 captures)");
    let out_pjrt = literal_to_mat(&out[0]).unwrap();

    let f = Forward::new(&model.cfg);
    let (out_rust, cap) = f.block(b, &x);
    let rel = out_pjrt.sub(&out_rust).frob() / out_rust.frob();
    assert!(rel < 2e-4, "block output mismatch: rel={rel}");

    // Capture points line up too (attn_in is the cheapest to check).
    let attn_in_pjrt = literal_to_mat(&out[1]).unwrap();
    let rel2 = attn_in_pjrt.sub(&cap.attn_in).frob() / cap.attn_in.frob();
    assert!(rel2 < 2e-4, "attn_in capture mismatch: rel={rel2}");
}

#[test]
fn trained_weights_load_and_validate() {
    if skip("trained_weights_load_and_validate") {
        return;
    }
    let reg = registry();
    for name in ["tiny-s", "tiny-m", "tiny-l"] {
        if !reg.has_model(name) {
            continue;
        }
        let m = reg.load_model(name).unwrap();
        m.validate().unwrap();
        assert!(m.embed.data.iter().all(|v| v.is_finite()));
    }
}
