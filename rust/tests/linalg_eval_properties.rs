//! Dedicated property suites for the last linalg/eval modules without
//! one: `linalg/hadamard.rs` (the fast Walsh–Hadamard transform under
//! QuIP's incoherence processing) and `eval/delta.rs` (the Fig. 2
//! per-block error diagnostic).

use qep::eval::delta_per_block;
use qep::linalg::{fwht_inplace, hadamard_conjugate, Mat, SignedHadamard};
use qep::model::{Model, ModelConfig};
use qep::util::rng::Rng;
use std::panic::{catch_unwind, AssertUnwindSafe};

// ---------------------------------------------------------------- hadamard

/// Dense unnormalized Hadamard matrix H_n from the transform itself
/// (columns = FWHT of basis vectors).
fn dense_h(n: usize) -> Vec<Vec<f32>> {
    let mut h = vec![vec![0.0f32; n]; n];
    for j in 0..n {
        let mut e = vec![0.0f32; n];
        e[j] = 1.0;
        fwht_inplace(&mut e);
        for (row, &v) in h.iter_mut().zip(e.iter()) {
            row[j] = v;
        }
    }
    h
}

#[test]
fn fwht_involution_applies_twice_to_n_times_identity() {
    for n in [1usize, 2, 4, 8, 64] {
        let mut rng = Rng::new(n as u64);
        let orig = rng.normal_vec(n, 1.0);
        let mut x = orig.clone();
        fwht_inplace(&mut x);
        fwht_inplace(&mut x);
        for (i, (a, b)) in x.iter().zip(orig.iter()).enumerate() {
            assert!(
                (a - b * n as f32).abs() < 1e-3 * (1.0 + b.abs() * n as f32),
                "n={n} index {i}: {a} vs {}",
                b * n as f32
            );
        }
    }
}

#[test]
fn dense_hadamard_satisfies_h_h_transpose_equals_n_identity() {
    // All entries of H are ±1 and every dot product is a sum of ±1
    // terms, so f32 arithmetic is exact here: assert exactly n·I.
    for n in [2usize, 4, 8, 16] {
        let h = dense_h(n);
        for i in 0..n {
            for j in 0..n {
                assert!(h[i][j] == 1.0 || h[i][j] == -1.0, "n={n}: H[{i}][{j}]={}", h[i][j]);
            }
        }
        for i in 0..n {
            for j in 0..n {
                let dot: f32 = (0..n).map(|k| h[i][k] * h[j][k]).sum();
                let want = if i == j { n as f32 } else { 0.0 };
                assert_eq!(dot, want, "n={n}: (H·Hᵀ)[{i}][{j}]");
            }
        }
    }
}

#[test]
fn non_power_of_two_lengths_are_rejected() {
    for n in [0usize, 3, 6, 12, 100] {
        let n_copy = n;
        let r = catch_unwind(AssertUnwindSafe(move || {
            let mut x = vec![1.0f32; n_copy];
            fwht_inplace(&mut x);
        }));
        assert!(r.is_err(), "fwht_inplace must reject length {n}");
        let r = catch_unwind(AssertUnwindSafe(move || {
            let mut rng = Rng::new(1);
            SignedHadamard::new(n_copy, &mut rng)
        }));
        assert!(r.is_err(), "SignedHadamard must reject dimension {n}");
    }
}

#[test]
fn signed_hadamard_is_orthogonal_for_every_size_and_seed() {
    for n in [2usize, 8, 64] {
        for seed in 0..3u64 {
            let mut rng = Rng::new(seed);
            let q = SignedHadamard::new(n, &mut rng);
            let orig = rng.normal_vec(n, 1.0);
            // Norm preservation (orthogonality on a random vector)…
            let mut x = orig.clone();
            q.apply(&mut x);
            let n0: f32 = orig.iter().map(|v| v * v).sum();
            let n1: f32 = x.iter().map(|v| v * v).sum();
            assert!((n0 - n1).abs() < 1e-3 * n0.max(1.0), "n={n} seed={seed}: norm drift");
            // …and exact inversion: Qᵀ(Q x) = x.
            q.apply_t(&mut x);
            for (a, b) in x.iter().zip(orig.iter()) {
                assert!((a - b).abs() < 1e-4, "n={n} seed={seed}: QᵀQ ≠ I ({a} vs {b})");
            }
        }
    }
}

#[test]
fn matrix_rotations_round_trip() {
    let mut rng = Rng::new(7);
    let q = SignedHadamard::new(16, &mut rng);
    let m = Mat::randn(5, 16, 1.0, &mut rng);
    let mut r = m.clone();
    q.right_mul(&mut r); // M·Q
    q.right_mul_t(&mut r); // (M·Q)·Qᵀ = M
    for (a, b) in r.data.iter().zip(m.data.iter()) {
        assert!((a - b).abs() < 1e-4, "right_mul/right_mul_t round trip: {a} vs {b}");
    }
    let m2 = Mat::randn(16, 5, 1.0, &mut rng);
    let mut r2 = m2.clone();
    q.left_mul(&mut r2); // Q·M
    q.left_mul_t(&mut r2); // Qᵀ·(Q·M) = M
    for (a, b) in r2.data.iter().zip(m2.data.iter()) {
        assert!((a - b).abs() < 1e-4, "left_mul/left_mul_t round trip: {a} vs {b}");
    }
}

#[test]
fn conjugation_preserves_frobenius_norm() {
    // Qᵀ·A·Q with orthogonal Q preserves ‖A‖_F (and, as the inline unit
    // tests already check, the trace).
    let mut rng = Rng::new(9);
    let q = SignedHadamard::new(32, &mut rng);
    let b = Mat::randn(32, 32, 1.0, &mut rng);
    let a = qep::linalg::matmul_nt(&b, &b); // SPD-ish, symmetric
    let c = hadamard_conjugate(&a, &q);
    let fa = a.frob();
    let fc = c.frob();
    assert!((fa - fc).abs() < 1e-2 * fa, "‖A‖_F {fa} vs ‖QᵀAQ‖_F {fc}");
}

// ------------------------------------------------------------------ delta

fn tiny_model(seed: u64) -> (Model, Vec<u32>) {
    let mut cfg = ModelConfig::new("unit", 16, 4, 2, 32);
    cfg.seq_len = 8;
    let model = Model::random(&cfg, seed);
    let mut rng = Rng::new(seed ^ 0xD137);
    let tokens: Vec<u32> = (0..8 * 8).map(|_| rng.below(256) as u32).collect();
    (model, tokens)
}

#[test]
fn delta_is_zero_iff_models_agree_and_is_symmetric() {
    let (model, tokens) = tiny_model(1);
    let d = delta_per_block(&model, &model, &tokens);
    assert_eq!(d.len(), 4, "one Δ per block");
    assert!(d.iter().all(|&v| v == 0.0));

    let mut other = model.clone();
    for v in other.blocks[1].wq.data.iter_mut() {
        *v += 0.01;
    }
    let ab = delta_per_block(&model, &other, &tokens);
    let ba = delta_per_block(&other, &model, &tokens);
    assert_eq!(ab.len(), ba.len());
    for (i, (x, y)) in ab.iter().zip(ba.iter()).enumerate() {
        assert_eq!(x, y, "Δ_{i} not symmetric");
    }
    // Non-negativity comes with the squared Frobenius norm.
    assert!(ab.iter().all(|&v| v >= 0.0));
}

#[test]
fn delta_localizes_to_the_perturbed_block_and_after() {
    let (model, tokens) = tiny_model(2);
    for k in 0..4usize {
        let mut pert = model.clone();
        let mut rng = Rng::new(100 + k as u64);
        for v in pert.blocks[k].wq.data.iter_mut() {
            *v += 0.05 * rng.normal_f32();
        }
        let d = delta_per_block(&model, &pert, &tokens);
        for (j, &v) in d.iter().enumerate() {
            if j < k {
                assert_eq!(v, 0.0, "perturbing block {k} leaked into earlier Δ_{j}");
            } else {
                assert!(v > 0.0, "perturbing block {k} left Δ_{j} at exactly zero");
            }
        }
    }
}

#[test]
fn delta_is_deterministic() {
    let (model, tokens) = tiny_model(3);
    let mut pert = model.clone();
    for v in pert.blocks[0].wv.data.iter_mut() {
        *v += 0.02;
    }
    let a = delta_per_block(&model, &pert, &tokens);
    let b = delta_per_block(&model, &pert, &tokens);
    assert_eq!(a, b);
}
