//! In-process fault-injection suite for the fleet coordinator state
//! machine (`qep::fleet::coord::CoordState`). The state machine takes
//! its clock as an explicit argument, so every fault here — a worker
//! dying mid-cell, a late duplicate completion racing a reassignment, a
//! coordinator killed and restarted over its record directory — is
//! driven deterministically, no sleeps, no sockets. The invariant under
//! every schedule: **exactly-once cell coverage** (`verify_coverage`
//! accepts the record file) and a record file byte-identical to an
//! uninterrupted local run's.

use qep::exp::common::{
    run_cells_durable, run_plan_cell, scan_record_dir, validate_resume, DurableRun,
};
use qep::exp::plan::{manifest, verify_coverage, PlanCell, PlanParams, SweepId};
use qep::exp::ExpData;
use qep::fleet::coord::{Assignment, CoordState, FleetOpts, Verdict};
use qep::io::results::{read_records, shard_filename, CellRecord, RecordAppender};
use qep::model::{Model, ModelConfig, Size};
use qep::text::{Corpus, Flavor};
use qep::util::pool::Pool;
use std::collections::{HashMap, HashSet};
use std::path::PathBuf;

fn fresh_data() -> ExpData {
    let mut cfg = ModelConfig::new("tiny-s", 16, 2, 2, 32);
    cfg.seq_len = 8;
    let model = Model::random(&cfg, 3);
    let mut models = HashMap::new();
    models.insert(Size::TinyS.name().to_string(), model);
    let mut corpora = HashMap::new();
    for f in Flavor::all() {
        corpora.insert(f, Corpus::generate(f, 24 * 1024, 0));
    }
    ExpData::from_parts(models, corpora)
}

fn tiny_params() -> PlanParams {
    let mut p = PlanParams::for_sizes(&[Size::TinyS]);
    p.fig3_bits = vec![3];
    p.fig3_seeds = 2;
    p.appendix_settings = vec![qep::quant::QuantConfig::int(3)];
    p
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("qep_fleet_coord_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

const SWEEP: SweepId = SweepId::AblationAlpha;

fn cells() -> Vec<PlanCell> {
    manifest(SWEEP, &tiny_params()).unwrap()
}

/// A synthetic record for state-machine-only tests (the coordinator
/// never inspects metrics, only identity).
fn rec(id: &str) -> CellRecord {
    CellRecord::new(id.to_string(), 0, 1)
}

fn opts(lease_ms: u64) -> FleetOpts {
    FleetOpts { lease_ms, stable_timings: true, ..Default::default() }
}

fn state_in(dir: &std::path::Path, lease_ms: u64, skip: &HashSet<String>) -> CoordState {
    let path = dir.join(shard_filename(SWEEP.name(), 1, 1));
    CoordState::new(&cells(), skip, RecordAppender::open(&path).unwrap(), opts(lease_ms)).unwrap()
}

fn assigned(a: Assignment) -> (u64, String) {
    match a {
        Assignment::Cell { lease, id } => (lease, id),
        other => panic!("expected an assignment, got {other:?}"),
    }
}

/// Worker dies mid-cell: its lease expires, the cell is reassigned to a
/// live worker, and the dead worker's eventual late completion is
/// rejected as a duplicate — the file keeps exactly one record.
#[test]
fn lease_expiry_reassigns_and_late_duplicate_is_rejected() {
    let dir = tmp_dir("expiry");
    let mut st = state_in(&dir, 100, &HashSet::new());
    let w1 = st.register();
    let w2 = st.register();

    let (lease1, id1) = assigned(st.request(w1, 0));
    // w1 goes silent (no heartbeat). Past the lease window the cell is
    // requeued...
    let requeued = st.expire(150);
    assert_eq!(requeued, vec![id1.clone()]);
    // ...and handed to w2 under a fresh lease.
    let (lease2, id2) = assigned(st.request(w2, 150));
    assert_eq!(id2, id1);
    assert_ne!(lease2, lease1);

    // w2 finishes first: accepted.
    assert!(matches!(st.complete(lease2, rec(&id1), 200).unwrap(), Verdict::Accepted));
    // w1 limps back with the same cell under the expired lease: rejected
    // deterministically (first accepted completion won).
    assert!(matches!(st.complete(lease1, rec(&id1), 210).unwrap(), Verdict::Duplicate));

    let path = dir.join(shard_filename(SWEEP.name(), 1, 1));
    assert_eq!(
        read_records(&path).unwrap().iter().filter(|r| r.id == id1).count(),
        1,
        "exactly one record for the contested cell"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The mirror race: the presumed-dead worker's completion arrives
/// *before* the reassigned execution finishes. First accepted completion
/// wins — the expired-lease completion is honored, the reassigned
/// worker's later one is the duplicate.
#[test]
fn expired_lease_completion_wins_when_it_arrives_first() {
    let dir = tmp_dir("race");
    let mut st = state_in(&dir, 100, &HashSet::new());
    let w1 = st.register();
    let w2 = st.register();

    let (lease1, id1) = assigned(st.request(w1, 0));
    let (lease2, id2) = assigned(st.request(w2, 150)); // implicit expiry inside request()
    assert_eq!(id2, id1, "expiry inside request() requeued the cell");

    assert!(matches!(st.complete(lease1, rec(&id1), 160).unwrap(), Verdict::Accepted));
    assert!(matches!(st.complete(lease2, rec(&id1), 170).unwrap(), Verdict::Duplicate));

    let path = dir.join(shard_filename(SWEEP.name(), 1, 1));
    assert_eq!(read_records(&path).unwrap().len(), 1);
    std::fs::remove_dir_all(&dir).ok();
}

/// A slow-but-alive worker keeps its lease by heartbeating: the cell is
/// never reassigned, other workers wait, and the eventual completion is
/// accepted.
#[test]
fn heartbeats_keep_a_slow_worker_leased() {
    let dir = tmp_dir("heartbeat");
    let mut st = state_in(&dir, 100, &HashSet::new());
    let w1 = st.register();
    let w2 = st.register();

    // w1 takes every cell (serially slow, but alive).
    let mut held = Vec::new();
    loop {
        match st.request(w1, 0) {
            Assignment::Cell { lease, id } => held.push((lease, id)),
            Assignment::Wait | Assignment::Finished => break,
        }
    }
    assert!(!held.is_empty());

    // Well past the original deadline, heartbeats keep renewing...
    for t in [80u64, 160, 240, 320] {
        for (lease, _) in &held {
            assert!(st.heartbeat(*lease, t), "lease {lease} lost at t={t}");
        }
        // ...so w2 finds nothing to steal.
        assert_eq!(st.request(w2, t), Assignment::Wait);
    }

    // The slow completions are all accepted, long after lease_ms.
    for (lease, id) in &held {
        assert!(matches!(st.complete(*lease, rec(id), 400).unwrap(), Verdict::Accepted));
    }
    assert!(st.finished());
    assert_eq!(st.request(w2, 410), Assignment::Finished);
    std::fs::remove_dir_all(&dir).ok();
}

/// A dropped connection releases the worker's leases immediately — no
/// waiting out the lease window.
#[test]
fn worker_disconnect_requeues_its_cells_immediately() {
    let dir = tmp_dir("disconnect");
    let mut st = state_in(&dir, 60_000, &HashSet::new()); // huge lease: expiry can't help
    let w1 = st.register();
    let w2 = st.register();

    let (_l1, id1) = assigned(st.request(w1, 0));
    let (_l2, id2) = assigned(st.request(w1, 0));
    assert_ne!(id1, id2);

    let mut requeued = st.worker_gone(w1);
    requeued.sort();
    let mut want = vec![id1.clone(), id2.clone()];
    want.sort();
    assert_eq!(requeued, want);

    // Both cells immediately available again, manifest order first.
    let (_, got1) = assigned(st.request(w2, 1));
    let (_, got2) = assigned(st.request(w2, 1));
    assert_eq!(got1, id1);
    assert_eq!(got2, id2);
    std::fs::remove_dir_all(&dir).ok();
}

/// A worker-side cell error requeues the cell for another attempt, but a
/// deterministically-failing cell aborts the sweep after max failures
/// instead of spinning forever.
#[test]
fn failing_cell_retries_then_aborts_the_sweep() {
    let dir = tmp_dir("failures");
    let mut st = state_in(&dir, 100, &HashSet::new());
    let w = st.register();

    let (lease, id) = assigned(st.request(w, 0));
    st.fail(lease, "boom", 1).unwrap();
    let (lease, id_again) = assigned(st.request(w, 2));
    assert_eq!(id_again, id, "failed cell requeued first (lowest manifest index)");
    st.fail(lease, "boom", 3).unwrap();
    let (lease, _) = assigned(st.request(w, 4));
    let err = st.fail(lease, "boom", 5).unwrap_err().to_string();
    assert!(err.contains(&id) && err.contains("aborting"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

/// The tentpole invariant, in-process: real cell executions dispatched
/// through an adversarial schedule — two workers, a mid-cell death with
/// reassignment, late duplicates, out-of-order completions — produce a
/// record file **byte-identical** to an uninterrupted local
/// `run_cells_durable` run, and `verify_coverage` accepts it.
#[test]
fn adversarial_schedule_is_byte_identical_to_local_run() {
    let params = tiny_params();
    let all = cells();
    assert!(all.len() >= 4, "need cells to shuffle");
    let pool = Pool::new(2);

    // Reference: uninterrupted local durable run, stable timings.
    let ref_dir = tmp_dir("adv_ref");
    let ref_path = ref_dir.join(shard_filename(SWEEP.name(), 1, 1));
    let empty = HashSet::new();
    run_cells_durable(
        &fresh_data(),
        &all,
        &pool,
        0,
        1,
        DurableRun {
            skip: &empty,
            sink: RecordAppender::open(&ref_path).unwrap(),
            stable_timings: true,
        },
    )
    .unwrap();
    let want_bytes = std::fs::read(&ref_path).unwrap();

    // Fleet leg: workers actually run their cells (fresh snapshot per
    // worker, like real processes), but the schedule is hostile.
    let data_w1 = fresh_data();
    let data_w2 = fresh_data();
    let run = |data: &ExpData, id: &str| {
        let pc = PlanCell::parse(id).unwrap();
        run_plan_cell(data, &pc, 0, 1).unwrap()
    };

    let fleet_dir = tmp_dir("adv_fleet");
    let mut st = state_in(&fleet_dir, 100, &HashSet::new());
    let w1 = st.register();
    let w2 = st.register();

    // w1 takes the first two cells, dies holding both (one via expiry,
    // one via disconnect); w2 takes over everything, completing in
    // arrival order, interleaved with w1's zombie duplicates.
    let (l1a, c1a) = assigned(st.request(w1, 0));
    let (l1b, c1b) = assigned(st.request(w1, 0));
    let mut want = vec![c1a.clone(), c1b.clone()];
    want.sort();
    assert_eq!(st.expire(150), want);
    st.worker_gone(w1);

    // w2 drains the queue; completions land out of manifest order
    // (stash then complete in reverse) to exercise the in-order sink.
    let mut stash: Vec<(u64, String, CellRecord)> = Vec::new();
    loop {
        match st.request(w2, 200) {
            Assignment::Cell { lease, id } => {
                let r = run(&data_w2, &id);
                stash.push((lease, id, r));
            }
            Assignment::Wait | Assignment::Finished => break,
        }
    }
    assert_eq!(stash.len(), all.len());
    // Heartbeats keep every stashed lease alive while w2 "works".
    for t in [260u64, 340] {
        for (lease, _, _) in &stash {
            assert!(st.heartbeat(*lease, t));
        }
    }
    for (lease, id, r) in stash.into_iter().rev() {
        assert!(matches!(st.complete(lease, r, 350).unwrap(), Verdict::Accepted), "{id}");
    }
    // Zombie w1 now reports its two original cells: both rejected.
    assert!(matches!(st.complete(l1a, run(&data_w1, &c1a), 400).unwrap(), Verdict::Duplicate));
    assert!(matches!(st.complete(l1b, run(&data_w1, &c1b), 401).unwrap(), Verdict::Duplicate));
    assert!(st.finished());

    // Byte identity + exactly-once coverage.
    let fleet_path = fleet_dir.join(shard_filename(SWEEP.name(), 1, 1));
    assert_eq!(
        std::fs::read(&fleet_path).unwrap(),
        want_bytes,
        "fleet record file differs from the uninterrupted local run"
    );
    verify_coverage(&all, read_records(&fleet_path).unwrap()).unwrap();
    for d in [ref_dir, fleet_dir] {
        std::fs::remove_dir_all(&d).ok();
    }
}

/// Coordinator killed mid-sweep: a restart over the same `--out` dir
/// (the standard scan → validate → skip pipeline) dispatches only the
/// missing cells, and the finished file is byte-identical to never
/// having died. Exactly-once coverage holds across the restart.
#[test]
fn coordinator_restart_resumes_only_missing_cells() {
    let all = cells();
    let pool = Pool::new(2);

    // Reference bytes from an uninterrupted local run.
    let ref_dir = tmp_dir("restart_ref");
    let ref_path = ref_dir.join(shard_filename(SWEEP.name(), 1, 1));
    let empty = HashSet::new();
    run_cells_durable(
        &fresh_data(),
        &all,
        &pool,
        0,
        1,
        DurableRun {
            skip: &empty,
            sink: RecordAppender::open(&ref_path).unwrap(),
            stable_timings: true,
        },
    )
    .unwrap();
    let want_bytes = std::fs::read(&ref_path).unwrap();

    // Incarnation 1: completes two cells, then the process "dies" (state
    // dropped; the record file stays).
    let dir = tmp_dir("restart");
    let data = fresh_data();
    let done_ids: Vec<String>;
    {
        let mut st = state_in(&dir, 100, &HashSet::new());
        let w = st.register();
        let (la, ca) = assigned(st.request(w, 0));
        let (lb, cb) = assigned(st.request(w, 0));
        let ra = run_plan_cell(&data, &PlanCell::parse(&ca).unwrap(), 0, 1).unwrap();
        let rb = run_plan_cell(&data, &PlanCell::parse(&cb).unwrap(), 0, 1).unwrap();
        assert!(matches!(st.complete(la, ra, 10).unwrap(), Verdict::Accepted));
        assert!(matches!(st.complete(lb, rb, 11).unwrap(), Verdict::Accepted));
        done_ids = vec![ca, cb];
        assert!(!st.finished());
    }

    // Restart: the standard resume pipeline recovers the skip set.
    let scan = scan_record_dir(&dir).unwrap();
    assert_eq!(scan.records.len(), 2);
    let skip = validate_resume(&all, &scan).unwrap();
    assert_eq!(skip.len(), 2);
    for id in &done_ids {
        assert!(skip.contains(id));
    }

    // Incarnation 2 dispatches ONLY the missing cells...
    let mut st = state_in(&dir, 100, &skip);
    let w = st.register();
    let mut dispatched = Vec::new();
    loop {
        match st.request(w, 0) {
            Assignment::Cell { lease, id } => dispatched.push((lease, id)),
            Assignment::Wait | Assignment::Finished => break,
        }
    }
    assert_eq!(dispatched.len(), all.len() - 2, "only missing cells dispatched");
    for (_, id) in &dispatched {
        assert!(!skip.contains(id), "resumed coordinator re-dispatched finished cell {id}");
    }
    // ...and completing them finishes the sweep with identical bytes.
    for (lease, id) in dispatched {
        let r = run_plan_cell(&data, &PlanCell::parse(&id).unwrap(), 0, 1).unwrap();
        assert!(matches!(st.complete(lease, r, 50).unwrap(), Verdict::Accepted));
    }
    assert!(st.finished());

    let path = dir.join(shard_filename(SWEEP.name(), 1, 1));
    assert_eq!(std::fs::read(&path).unwrap(), want_bytes);
    verify_coverage(&all, read_records(&path).unwrap()).unwrap();
    for d in [ref_dir, dir] {
        std::fs::remove_dir_all(&d).ok();
    }
}

/// Completions that name the wrong cell for their lease, or a cell not
/// in the manifest, are rejected (not crashes, not writes).
#[test]
fn malformed_completions_are_rejected_without_writing() {
    let dir = tmp_dir("malformed");
    let mut st = state_in(&dir, 100, &HashSet::new());
    let w = st.register();
    let (lease, id) = assigned(st.request(w, 0));

    match st.complete(lease, rec("not-a-cell/at-all"), 1).unwrap() {
        Verdict::Rejected(why) => assert!(why.contains("not in this manifest"), "{why}"),
        other => panic!("expected Rejected, got {other:?}"),
    }
    let other_id = cells()
        .iter()
        .map(|c| c.id())
        .find(|i| *i != id)
        .expect("sweep has >1 cell");
    match st.complete(lease, rec(&other_id), 2).unwrap() {
        Verdict::Rejected(why) => assert!(why.contains("lease"), "{why}"),
        other => panic!("expected Rejected, got {other:?}"),
    }
    // The honest completion still lands afterwards.
    assert!(matches!(st.complete(lease, rec(&id), 3).unwrap(), Verdict::Accepted));
    let path = dir.join(shard_filename(SWEEP.name(), 1, 1));
    assert_eq!(read_records(&path).unwrap().len(), 1);
    std::fs::remove_dir_all(&dir).ok();
}

/// Live status counters track the fault lifecycle.
#[test]
fn status_counters_track_the_lifecycle() {
    let dir = tmp_dir("status");
    let mut st = state_in(&dir, 100, &HashSet::new());
    let total = cells().len();
    let s = st.status();
    assert_eq!((s.total, s.done, s.leased, s.pending, s.workers), (total, 0, 0, total, 0));

    let w1 = st.register();
    let (lease, id) = assigned(st.request(w1, 0));
    let s = st.status();
    assert_eq!((s.leased, s.pending, s.workers), (1, total - 1, 1));

    assert!(matches!(st.complete(lease, rec(&id), 10).unwrap(), Verdict::Accepted));
    let s = st.status();
    assert_eq!((s.done, s.leased), (1, 0));

    st.worker_gone(w1);
    assert_eq!(st.status().workers, 0);
    std::fs::remove_dir_all(&dir).ok();
}

/// The socket read timeout is decoupled from the lease: a worker's
/// heartbeats (due every quarter-lease) must always land with margin
/// before the read times out, and the coordinator must never hold a
/// socket read for a full lease window — that race is exactly how a
/// slow cell used to expire a healthy worker.
#[test]
fn read_timeout_gives_heartbeats_margin_for_every_lease() {
    use qep::fleet::coord::{heartbeat_interval_ms, read_timeout_ms};
    for lease in [40u64, 100, 300, 1_000, 30_000, 600_000] {
        let hb = heartbeat_interval_ms(lease);
        let rt = read_timeout_ms(lease);
        assert!(rt > hb, "lease {lease}: read timeout {rt} ms ≤ heartbeat interval {hb} ms");
        assert!(rt >= 100, "lease {lease}: read timeout {rt} ms below the 100 ms floor");
        if lease >= 300 {
            assert!(rt < lease, "lease {lease}: read timeout {rt} ms blocks a full lease");
        }
    }
}
