//! Wire-format property tests for the fleet protocol
//! (`qep::fleet::wire`): every message type round-trips through a real
//! byte stream, and every malformed input — torn frames, garbage bytes,
//! oversized length prefixes, version skew, junk payloads — fails
//! loudly with the *named* error variant, never a hang or a panic.

use qep::fleet::wire::{
    encode_frame, encode_frame_versioned, read_msg, write_msg, Msg, WireError, MAGIC,
    MAX_FRAME_LEN, VERSION,
};
use std::io::Cursor;

/// One instance of every message variant, with awkward payload content
/// (quotes, newlines, unicode) to stress the JSON layer.
fn all_messages() -> Vec<Msg> {
    vec![
        Msg::Hello,
        Msg::Welcome { worker: 7, heartbeat_ms: 2500 },
        Msg::Request { worker: 7 },
        Msg::Assign { lease: 41, cell: "table12/INT3/GPTQ/+qep/tiny-s".to_string() },
        Msg::NoWork { done: false },
        Msg::NoWork { done: true },
        Msg::Heartbeat { lease: 41 },
        Msg::Complete {
            lease: 41,
            record: "{\"id\":\"table12/INT3/GPTQ/+qep/tiny-s\",\"ppl\":{\"wiki\":6.25}}"
                .to_string(),
        },
        Msg::CompleteAck { accepted: true, reason: String::new() },
        Msg::CompleteAck { accepted: false, reason: "late \"duplicate\"\nrejected".to_string() },
        Msg::Failed { lease: 9, error: "cell exploded: α≠0.5\ttab".to_string() },
        Msg::StatusReq,
        Msg::Status { total: 17, done: 5, leased: 3, pending: 9, workers: 4 },
        Msg::ProtocolError { detail: "bad frame".to_string() },
    ]
}

#[test]
fn every_message_round_trips() {
    for msg in all_messages() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &msg).unwrap();
        let mut cur = Cursor::new(buf);
        let back = read_msg(&mut cur).unwrap();
        assert_eq!(back, msg);
        // The stream is fully consumed: a second read sees a clean close.
        assert!(matches!(read_msg(&mut cur), Err(WireError::Closed)), "{msg:?}");
    }
}

#[test]
fn back_to_back_frames_read_in_order() {
    let msgs = all_messages();
    let mut buf = Vec::new();
    for m in &msgs {
        write_msg(&mut buf, m).unwrap();
    }
    let mut cur = Cursor::new(buf);
    for want in &msgs {
        assert_eq!(&read_msg(&mut cur).unwrap(), want);
    }
    assert!(matches!(read_msg(&mut cur), Err(WireError::Closed)));
}

/// Killing the peer at *any* byte boundary inside a frame must surface
/// as `Truncated` (mid-frame) — only the zero-byte case is a clean
/// `Closed`. This sweeps every prefix of a real frame.
#[test]
fn every_truncation_point_fails_loudly() {
    let frame = encode_frame(&Msg::Assign { lease: 3, cell: "fig3/INT3/tiny-s/base/s0".into() });
    for cut in 0..frame.len() {
        let mut cur = Cursor::new(frame[..cut].to_vec());
        match read_msg(&mut cur) {
            Err(WireError::Closed) => assert_eq!(cut, 0, "Closed only at a frame boundary"),
            Err(WireError::Truncated { wanted, got }) => {
                assert!(cut > 0);
                assert!(got < wanted, "cut at {cut}: got {got} wanted {wanted}");
            }
            other => panic!("cut at {cut}: expected Truncated/Closed, got {other:?}"),
        }
    }
    // The uncut frame still parses (the sweep above proves failures are
    // about truncation, not the frame itself).
    assert!(read_msg(&mut Cursor::new(frame)).is_ok());
}

#[test]
fn garbage_bytes_are_rejected_as_bad_magic() {
    for garbage in [
        b"GET / HTTP/1.1\r\nHost: x\r\n\r\n".to_vec(),
        b"\x00\x00\x00\x00\x00\x00\x00\x00\x00\x00".to_vec(),
        b"QFLX\x00\x01\x00\x00\x00\x02{}".to_vec(), // one magic byte off
        vec![0xff; 64],
    ] {
        match read_msg(&mut Cursor::new(garbage)) {
            Err(WireError::BadMagic(b)) => assert_ne!(b, MAGIC),
            other => panic!("expected BadMagic, got {other:?}"),
        }
    }
}

#[test]
fn version_mismatch_is_detected_before_the_payload() {
    // A *valid* frame from a future protocol version: payload is even
    // well-formed JSON, but the version gate must fire first.
    let frame = encode_frame_versioned(VERSION + 1, b"{\"t\":\"hello\"}");
    match read_msg(&mut Cursor::new(frame)) {
        Err(WireError::VersionMismatch { ours, theirs }) => {
            assert_eq!(ours, VERSION);
            assert_eq!(theirs, VERSION + 1);
        }
        other => panic!("expected VersionMismatch, got {other:?}"),
    }
    // Version 0 (e.g. zeroed bytes after the magic) as well.
    let frame = encode_frame_versioned(0, b"{}");
    assert!(matches!(
        read_msg(&mut Cursor::new(frame)),
        Err(WireError::VersionMismatch { theirs: 0, .. })
    ));
}

/// A hostile or corrupt length prefix may not trigger a giant
/// allocation or a blocking read — it must be rejected from the header
/// alone.
#[test]
fn oversized_length_prefix_is_rejected_without_reading_the_body() {
    let mut frame = Vec::new();
    frame.extend_from_slice(&MAGIC);
    frame.extend_from_slice(&VERSION.to_be_bytes());
    frame.extend_from_slice(&(MAX_FRAME_LEN + 1).to_be_bytes());
    // No body at all: if the implementation tried to read it, it would
    // report Truncated; the cap must fire first.
    match read_msg(&mut Cursor::new(frame)) {
        Err(WireError::Oversized(n)) => assert_eq!(n, MAX_FRAME_LEN + 1),
        other => panic!("expected Oversized, got {other:?}"),
    }
    // u32::MAX — the classic garbage value.
    let mut frame = Vec::new();
    frame.extend_from_slice(&MAGIC);
    frame.extend_from_slice(&VERSION.to_be_bytes());
    frame.extend_from_slice(&u32::MAX.to_be_bytes());
    assert!(matches!(read_msg(&mut Cursor::new(frame)), Err(WireError::Oversized(_))));
}

#[test]
fn junk_payloads_are_named_payload_errors() {
    for payload in [
        &b"not json at all"[..],
        &b"{\"t\":\"no_such_message\"}"[..],
        &b"{\"missing\":\"type tag\"}"[..],
        &b"{\"t\":\"assign\",\"lease\":1}"[..], // missing 'cell'
        &b"{\"t\":\"welcome\",\"worker\":true}"[..], // wrong field type
        &b"\xff\xfe\x00"[..],                   // not UTF-8
    ] {
        match read_msg(&mut Cursor::new(encode_frame_versioned(VERSION, payload))) {
            Err(WireError::BadPayload(_)) => {}
            other => panic!("payload {payload:?}: expected BadPayload, got {other:?}"),
        }
    }
}

/// Frame corruption *after* a valid frame doesn't poison the valid one —
/// readers consume exactly one frame's bytes per call.
#[test]
fn valid_frame_then_garbage_reads_the_valid_frame_first() {
    let mut buf = encode_frame(&Msg::NoWork { done: true });
    buf.extend_from_slice(b"trailing garbage");
    let mut cur = Cursor::new(buf);
    assert_eq!(read_msg(&mut cur).unwrap(), Msg::NoWork { done: true });
    assert!(matches!(read_msg(&mut cur), Err(WireError::BadMagic(_))));
}

#[test]
fn errors_render_useful_messages() {
    // The Display impls are what workers print on a dead coordinator —
    // keep the key facts (versions, sizes) in them.
    let e = WireError::VersionMismatch { ours: 1, theirs: 9 };
    let s = e.to_string();
    assert!(s.contains("v1") && s.contains("v9"), "{s}");
    let s = WireError::Oversized(MAX_FRAME_LEN + 7).to_string();
    assert!(s.contains(&(MAX_FRAME_LEN + 7).to_string()), "{s}");
    let s = WireError::Truncated { wanted: 10, got: 3 }.to_string();
    assert!(s.contains("3/10"), "{s}");
}
