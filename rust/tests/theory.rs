//! Property tests for the paper's theoretical claims (Appendix A), on
//! synthetic deep networks where the quantities are directly measurable:
//!
//! * Prop. A.1/A.3 — error accumulates and grows exponentially with depth
//!   when γ‖W‖₂ > 1 under layer-wise *independent* quantization.
//! * Thm. 5.2    — QEP's output error ≤ BASE's output error.
//! * Prop. 5.4   — output error is monotone non-increasing in α.
//! * Prop. 5.3/A.6 — the α ↔ ridge-λ correspondence: α(λ) is strictly
//!   decreasing with α(0)=1, α(∞)=0; ridge endpoints match W*(0)/W*(1).
//! * Lemma A.7   — ‖Z(I−αP)‖_F is non-increasing in α for projections P.

use qep::linalg::{matmul, matmul_nt, matmul_tn, spd_solve, Mat, Mat64};
use qep::qep::corrected_weight;
use qep::quant::{LayerCtx, QuantConfig, Quantizer};
use qep::util::rng::Rng;

/// A deep MLP: y = σ(W_L σ(W_{L-1} ... σ(W_1 x))), tokens-major.
struct DeepNet {
    weights: Vec<Mat>,
    relu: bool,
}

impl DeepNet {
    fn random(depth: usize, dim: usize, gain: f32, relu: bool, rng: &mut Rng) -> DeepNet {
        // N(0, gain/sqrt(d)) keeps ‖W‖₂ ≈ 2·gain.
        let sigma = gain / (dim as f32).sqrt();
        let weights = (0..depth).map(|_| Mat::randn(dim, dim, sigma, rng)).collect();
        DeepNet { weights, relu }
    }

    fn act(&self, mut x: Mat) -> Mat {
        if self.relu {
            for v in x.data.iter_mut() {
                *v = v.max(0.0);
            }
        }
        x
    }

    /// Forward through layers `0..upto` with the given weight set.
    fn forward(&self, weights: &[Mat], x: &Mat, upto: usize) -> Mat {
        let mut h = x.clone();
        for w in weights.iter().take(upto) {
            h = self.act(matmul_nt(&h, w));
        }
        h
    }

    /// Per-layer activation mismatch ‖X_l − X̂_l‖_F between weight sets.
    fn mismatch_profile(&self, quantized: &[Mat], x: &Mat) -> Vec<f64> {
        (1..=self.weights.len())
            .map(|l| {
                let a = self.forward(&self.weights, x, l);
                let b = self.forward(quantized, x, l);
                a.sub(&b).frob()
            })
            .collect()
    }

    /// BASE layer-wise PTQ: quantize each layer independently against the
    /// quantized stream, no correction (Eq. 1 with X = X̂).
    fn quantize_base(&self, x: &Mat, cfg: &QuantConfig, q: &dyn Quantizer) -> Vec<Mat> {
        let mut out = Vec::new();
        let mut x_hat = x.clone();
        for w in &self.weights {
            let ctx = LayerCtx::from_activations(&x_hat, 0, "t");
            let wq = q.quantize(w, cfg, &ctx).unwrap();
            x_hat = self.act(matmul_nt(&x_hat, &wq));
            out.push(wq);
        }
        out
    }

    /// QEP layer-wise PTQ (Eq. 3 via Prop. 5.1): correct, then quantize
    /// against X̂.
    fn quantize_qep(
        &self,
        x: &Mat,
        cfg: &QuantConfig,
        q: &dyn Quantizer,
        alpha: f32,
        damp: f64,
    ) -> Vec<Mat> {
        let mut out = Vec::new();
        let mut x_full = x.clone();
        let mut x_hat = x.clone();
        for w in &self.weights {
            let (w_star, _) = corrected_weight(w, &x_full, &x_hat, alpha, damp).unwrap();
            let ctx = LayerCtx::from_activations(&x_hat, 0, "t");
            let wq = q.quantize(&w_star, cfg, &ctx).unwrap();
            x_hat = self.act(matmul_nt(&x_hat, &wq));
            x_full = self.act(matmul_nt(&x_full, w));
            out.push(wq);
        }
        out
    }

    fn output_error(&self, quantized: &[Mat], x: &Mat) -> f64 {
        let l = self.weights.len();
        self.forward(&self.weights, x, l)
            .sub(&self.forward(quantized, x, l))
            .frob()
    }
}

fn rtn() -> Box<dyn Quantizer + Send + Sync> {
    qep::quant::quantizer_for(qep::quant::Method::Rtn)
}

// ---------------------------------------------------------------- A.3 ----

#[test]
fn error_grows_geometrically_in_expansive_nets() {
    let mut rng = Rng::new(1);
    let dim = 24;
    let depth = 10;
    // gain 1.5 ⇒ ‖W‖₂ ≈ 3 > 1: the expansive regime of Prop. A.3.
    let net = DeepNet::random(depth, dim, 1.5, false, &mut rng);
    let x = Mat::randn(64, dim, 1.0, &mut rng);
    let quantized = net.quantize_base(&x, &QuantConfig::int(8), rtn().as_ref());
    let profile = net.mismatch_profile(&quantized, &x);
    // Strictly increasing after the first couple of layers, and the
    // overall growth is at least geometric with a sizeable base.
    let growth = profile.last().unwrap() / profile[1].max(1e-30);
    let per_layer = growth.powf(1.0 / (depth as f64 - 2.0));
    assert!(per_layer > 1.25, "per-layer growth {per_layer} (profile {profile:?})");
    for w in profile[1..].windows(2) {
        assert!(w[1] > w[0] * 0.9, "profile not growing: {profile:?}");
    }
}

#[test]
fn error_stays_bounded_in_contractive_nets() {
    // Complement of A.3: with γ‖W‖ < 1 the recursion is a contraction and
    // the profile must not blow up.
    let mut rng = Rng::new(2);
    let net = DeepNet::random(10, 24, 0.3, false, &mut rng);
    let x = Mat::randn(64, 24, 1.0, &mut rng);
    let quantized = net.quantize_base(&x, &QuantConfig::int(8), rtn().as_ref());
    let profile = net.mismatch_profile(&quantized, &x);
    assert!(profile.last().unwrap() < &(profile.iter().cloned().fold(0.0, f64::max) + 1e-9));
    assert!(profile.last().unwrap() / profile[0].max(1e-30) < 10.0, "{profile:?}");
}

// ------------------------------------------------------------- Thm 5.2 ----

#[test]
fn qep_output_error_beats_base_linear() {
    let mut rng = Rng::new(3);
    let mut wins = 0;
    let n_trials = 8;
    for seed in 0..n_trials {
        let mut r = Rng::new(100 + seed);
        let net = DeepNet::random(6, 16, 1.0, false, &mut r);
        let x = Mat::randn(128, 16, 1.0, &mut rng);
        let base = net.quantize_base(&x, &QuantConfig::int(4), rtn().as_ref());
        let qep = net.quantize_qep(&x, &QuantConfig::int(4), rtn().as_ref(), 1.0, 1e-6);
        if net.output_error(&qep, &x) <= net.output_error(&base, &x) {
            wins += 1;
        }
    }
    // The theorem is first-order; rounding noise can flip rare cases.
    assert!(wins >= n_trials - 1, "QEP won only {wins}/{n_trials}");
}

#[test]
fn qep_output_error_beats_base_relu() {
    let mut rng = Rng::new(4);
    let mut err_base = 0.0;
    let mut err_qep = 0.0;
    for seed in 0..6 {
        let mut r = Rng::new(200 + seed);
        let net = DeepNet::random(5, 16, 0.9, true, &mut r);
        let x = Mat::randn(128, 16, 1.0, &mut rng);
        let base = net.quantize_base(&x, &QuantConfig::int(3), rtn().as_ref());
        // ReLU sparsifies X̂ ⇒ ill-conditioned Ĥ: use the paper's damping
        // regime (App. B.1) rather than the near-zero linear-case value.
        let qep = net.quantize_qep(&x, &QuantConfig::int(3), rtn().as_ref(), 1.0, 0.1);
        err_base += net.output_error(&base, &x);
        err_qep += net.output_error(&qep, &x);
    }
    assert!(err_qep < err_base, "QEP {err_qep} !< BASE {err_base}");
}

// ------------------------------------------------------------- Prop 5.4 ----

#[test]
fn output_error_is_monotone_in_alpha() {
    // Aggregate monotonicity across seeds (per-seed curves carry rounding
    // noise; the theorem is first-order).
    let alphas = [0.0f32, 0.25, 0.5, 0.75, 1.0];
    let mut totals = vec![0.0f64; alphas.len()];
    for seed in 0..6 {
        let mut r = Rng::new(300 + seed);
        let net = DeepNet::random(6, 16, 1.0, false, &mut r);
        let mut rx = Rng::new(400 + seed);
        let x = Mat::randn(128, 16, 1.0, &mut rx);
        for (i, &a) in alphas.iter().enumerate() {
            let q = net.quantize_qep(&x, &QuantConfig::int(4), rtn().as_ref(), a, 1e-6);
            totals[i] += net.output_error(&q, &x);
        }
    }
    for i in 1..alphas.len() {
        assert!(
            totals[i] <= totals[i - 1] * 1.02,
            "not monotone at α={}: {totals:?}",
            alphas[i]
        );
    }
    assert!(
        *totals.last().unwrap() < totals[0] * 0.95,
        "α=1 should clearly beat α=0: {totals:?}"
    );
}

// -------------------------------------------------------- Prop 5.3/A.6 ----

/// α(λ) = (1/d)·tr(Ĥ·(Ĥ+λI)⁻¹).
fn alpha_of_lambda(h: &Mat64, lambda: f64) -> f64 {
    let d = h.rows;
    let mut damped = h.clone();
    damped.add_diag(lambda);
    let sol = spd_solve(&damped, h).unwrap();
    (0..d).map(|i| sol.at(i, i)).sum::<f64>() / d as f64
}

#[test]
fn alpha_lambda_mapping_is_decreasing_bijection() {
    let mut rng = Rng::new(5);
    let x = Mat::randn(200, 12, 1.0, &mut rng);
    let h32 = matmul_tn(&x, &x);
    let mut h = Mat64::zeros(12, 12);
    for (d, s) in h.data.iter_mut().zip(h32.data.iter()) {
        *d = *s as f64;
    }
    let lambdas = [0.0, 1.0, 10.0, 100.0, 1e4, 1e8];
    let alphas: Vec<f64> = lambdas.iter().map(|&l| alpha_of_lambda(&h, l)).collect();
    assert!((alphas[0] - 1.0).abs() < 1e-9, "α(0) = {}", alphas[0]);
    for w in alphas.windows(2) {
        assert!(w[1] < w[0], "not strictly decreasing: {alphas:?}");
    }
    assert!(*alphas.last().unwrap() < 0.01, "α(∞) → 0: {alphas:?}");
}

#[test]
fn ridge_endpoints_match_alpha_endpoints() {
    // W*(λ→∞) → W (α=0) and W*(λ→0) → the α=1 closed form.
    let mut rng = Rng::new(6);
    let x = Mat::randn(200, 10, 1.0, &mut rng);
    let mut x_hat = x.clone();
    for v in x_hat.data.iter_mut() {
        *v += 0.2 * rng.normal_f32();
    }
    let w = Mat::randn(5, 10, 1.0, &mut rng);

    // Ridge solution: W(I + δX̂ᵀ(Ĥ+λI)⁻¹) computed directly.
    let ridge = |lambda: f64| -> Mat {
        let delta = x.sub(&x_hat);
        let dxt = matmul_tn(&delta, &x_hat);
        let h32 = matmul_tn(&x_hat, &x_hat);
        let d = h32.rows;
        let mut h = Mat64::zeros(d, d);
        for (dst, src) in h.data.iter_mut().zip(h32.data.iter()) {
            *dst = *src as f64;
        }
        h.add_diag(lambda);
        let mut dxt_t = Mat64::zeros(d, d);
        for i in 0..d {
            for j in 0..d {
                *dxt_t.at_mut(i, j) = dxt.at(j, i) as f64;
            }
        }
        let y_t = spd_solve(&h, &dxt_t).unwrap();
        let mut c = Mat::zeros(d, d);
        for i in 0..d {
            for j in 0..d {
                *c.at_mut(i, j) = y_t.at(j, i) as f32;
            }
        }
        w.add(&matmul(&w, &c))
    };

    let (w_alpha1, _) = corrected_weight(&w, &x, &x_hat, 1.0, 1e-12).unwrap();
    let near0 = ridge(1e-9);
    assert!(near0.sub(&w_alpha1).frob() / w_alpha1.frob() < 1e-3);

    let huge = ridge(1e12);
    assert!(huge.sub(&w).frob() / w.frob() < 1e-3);
}

// ----------------------------------------------------------- Lemma A.7 ----

#[test]
fn projection_shrinkage_lemma() {
    let mut rng = Rng::new(7);
    // P = X̂ᵀ(X̂X̂ᵀ)⁻¹X̂ in the paper's layout; build an orthogonal projector
    // onto a random k-dim subspace via Gram-Schmidt.
    let (n, k) = (16, 5);
    let mut basis: Vec<Vec<f32>> = Vec::new();
    while basis.len() < k {
        let mut v = rng.normal_vec(n, 1.0);
        for b in &basis {
            let dot: f32 = v.iter().zip(b.iter()).map(|(a, c)| a * c).sum();
            for (vi, bi) in v.iter_mut().zip(b.iter()) {
                *vi -= dot * bi;
            }
        }
        let norm: f32 = v.iter().map(|a| a * a).sum::<f32>().sqrt();
        if norm > 1e-3 {
            for vi in v.iter_mut() {
                *vi /= norm;
            }
            basis.push(v);
        }
    }
    let mut p = Mat::zeros(n, n);
    for b in &basis {
        for i in 0..n {
            for j in 0..n {
                *p.at_mut(i, j) += b[i] * b[j];
            }
        }
    }
    let z = Mat::randn(8, n, 1.0, &mut rng);
    let mut last = f64::INFINITY;
    for step in 0..=10 {
        let a = step as f32 / 10.0;
        // Z(I - αP)
        let zp = matmul(&z, &p);
        let mut za = z.clone();
        for (v, q) in za.data.iter_mut().zip(zp.data.iter()) {
            *v -= a * q;
        }
        let norm = za.frob();
        assert!(norm <= last + 1e-5, "α={a}: {norm} > {last}");
        last = norm;
    }
}
