//! Serving-path gates: KV-cache decode ≡ full-recompute forward (every
//! prefix length), prefill ≡ step-by-step decode, batch-composition
//! independence (ragged session lengths, single-session batches,
//! mid-batch retirement), quantized-engine ≡ dense-twin, and explicit
//! special-token handling.

use qep::linalg::Mat;
use qep::model::{Forward, Model, ModelConfig};
use qep::quant::QuantConfig;
use qep::serve::{FinishReason, Scheduler, ServeConfig, ServeModel};
use qep::text::{EOS, PAD, VOCAB_SIZE};
use qep::util::pool::Pool;
use qep::util::rng::Rng;

fn small() -> (ModelConfig, Model) {
    let mut cfg = ModelConfig::new("unit", 16, 2, 2, 32);
    cfg.seq_len = 8;
    let m = Model::random(&cfg, 1);
    (cfg, m)
}

fn tokens(n: usize, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.below(200) as u32).collect()
}

/// KV-cache decode must equal the full-recompute forward to the bit at
/// every prefix length — including prefixes shorter than seq_len, where
/// the reference segment is padded with PAD (trailing rows cannot touch
/// earlier positions: all ops are row-wise and attention is causal).
#[test]
fn decode_matches_padded_full_recompute_for_every_prefix_length() {
    let (cfg, m) = small();
    let f = Forward::new(&cfg);
    let toks = tokens(cfg.seq_len, 11);
    let sm = ServeModel::from_model(&m);
    let pool = Pool::serial();
    for prefix in 1..=cfg.seq_len {
        let mut padded = toks[..prefix].to_vec();
        padded.resize(cfg.seq_len, PAD);
        let full = f.forward(&m, &padded);
        // Forward::decode_step chain.
        let mut cache = qep::serve::KvCache::new(cfg.n_layers, cfg.seq_len, cfg.dim);
        let mut last = Mat::zeros(0, 0);
        for &tok in &toks[..prefix] {
            last = f.decode_step(&m, &mut cache, tok);
        }
        assert_eq!(last.row(0), full.row(prefix - 1), "decode_step prefix={prefix}");
        // Engine prefill: every row, not just the last.
        let mut ecache = sm.new_cache();
        let pre = sm.prefill(&mut ecache, &toks[..prefix], &pool);
        for t in 0..prefix {
            assert_eq!(pre.row(t), full.row(t), "prefill prefix={prefix} t={t}");
        }
    }
}

/// Ragged batch: sessions prefilled to different lengths, then decoded
/// together — each row must equal the same session decoded alone.
#[test]
fn ragged_batch_rows_match_solo_decode_bitwise() {
    let (cfg, m) = small();
    let sm = ServeModel::from_model(&m);
    let pool = Pool::serial();
    let prompts = [tokens(3, 21), tokens(1, 22), tokens(5, 23)];
    let feeds = [tokens(2, 31), tokens(2, 32), tokens(2, 33)];

    // Solo reference: each session alone (single-session batches).
    let mut solo_logits: Vec<Vec<Mat>> = Vec::new();
    for (p, f) in prompts.iter().zip(feeds.iter()) {
        let mut cache = sm.new_cache();
        sm.prefill(&mut cache, p, &pool);
        let mut rows = Vec::new();
        for &tok in f {
            rows.push(sm.decode_step_batch(&mut [&mut cache], &[tok], &pool));
        }
        solo_logits.push(rows);
    }

    // Batched: all three sessions step together at ragged positions.
    let mut caches: Vec<_> = prompts
        .iter()
        .map(|p| {
            let mut c = sm.new_cache();
            sm.prefill(&mut c, p, &pool);
            c
        })
        .collect();
    for step in 0..2 {
        let toks: Vec<u32> = feeds.iter().map(|f| f[step]).collect();
        let mut refs: Vec<&mut qep::serve::KvCache> = caches.iter_mut().collect();
        let batched = sm.decode_step_batch(&mut refs, &toks, &pool);
        for s in 0..3 {
            assert_eq!(
                batched.row(s),
                solo_logits[s][step].row(0),
                "step={step} session={s}"
            );
        }
    }
}

/// The quantized engine (fused qgemm path) must produce the same bits as
/// serving its dense dequantized twin — so quantized generations are
/// exactly the dense-model generations of the same grid weights.
#[test]
fn quantized_scheduler_matches_dense_twin_generations() {
    let (cfg, m) = small();
    let qm = ServeModel::quantized(&m, &QuantConfig::int_group(4, 8));
    let dm = qm.dequantized();
    let prompts = [tokens(2, 41), tokens(4, 42), tokens(1, 43)];
    let run = |model: ServeModel| {
        let mut s = Scheduler::new(
            model,
            ServeConfig { max_batch: 2, max_new_tokens: 5 },
            Pool::new(2),
        );
        for p in &prompts {
            s.submit(p).unwrap();
        }
        s.run()
            .into_iter()
            .map(|c| (c.id, c.tokens, c.finish))
            .collect::<Vec<_>>()
    };
    assert_eq!(run(qm), run(dm));
    let _ = cfg;
}

/// Mid-batch retirement: a session that hits the context limit retires
/// while the batch keeps decoding, and nobody's tokens change relative
/// to running alone.
#[test]
fn mid_batch_retirement_does_not_disturb_survivors() {
    let (cfg, m) = small();
    let sm = ServeModel::from_model(&m);
    // Prompt of length seq_len−1 retires after one generated token
    // (context full); the short prompt keeps going.
    let long = tokens(cfg.seq_len - 1, 51);
    let short = tokens(1, 52);
    let solo = |prompt: &[u32]| {
        let mut s = Scheduler::new(
            ServeModel::from_model(&m),
            ServeConfig { max_batch: 1, max_new_tokens: 10 },
            Pool::serial(),
        );
        s.submit(prompt).unwrap();
        s.run().remove(0)
    };
    let solo_long = solo(&long);
    let solo_short = solo(&short);
    assert_eq!(solo_long.finish, FinishReason::Length);
    assert!(solo_long.tokens.len() <= 1, "context-limited session");
    assert!(solo_short.tokens.len() > solo_long.tokens.len());

    let mut batch = Scheduler::new(
        sm,
        ServeConfig { max_batch: 2, max_new_tokens: 10 },
        Pool::serial(),
    );
    batch.submit(&long).unwrap();
    batch.submit(&short).unwrap();
    let done = batch.run();
    assert_eq!(done[0].tokens, solo_long.tokens);
    assert_eq!(done[0].finish, solo_long.finish);
    assert_eq!(done[1].tokens, solo_short.tokens);
    assert_eq!(done[1].finish, solo_short.finish);
}

/// The adjunct-carrying quantized engine (fused qgemm + factored
/// `y += (x·Vᵀ)·Uᵀ` apply) must match its dequantized dense-corrected
/// twin — same dense grid weights, same factored adjunct — to the bit,
/// at every prefix length and through full scheduler generations.
#[test]
fn adjunct_serving_matches_its_dense_corrected_twin_at_every_prefix_length() {
    let (cfg, m) = small();
    let pool = Pool::new(3);
    // Adjuncts on a subset of layers; layers without one must serve
    // exactly as before.
    let mk = |rows: usize, cols: usize, seed: u64, svd_seed: u64| {
        qep::qep::adjunct_from_residual(
            &Mat::randn(rows, cols, 0.05, &mut Rng::new(seed)),
            None,
            2,
            1.0,
            svd_seed,
            &Pool::serial(),
        )
    };
    let mut adjuncts = std::collections::BTreeMap::new();
    adjuncts.insert("blocks.0.attn.wq".to_string(), mk(16, 16, 71, 1));
    adjuncts.insert("blocks.1.mlp.down".to_string(), mk(16, 32, 72, 2));
    let qcfg = QuantConfig::int_group(4, 8);
    let qm = ServeModel::quantized_with_adjuncts(&m, &qcfg, &adjuncts);
    let dm = qm.dequantized();
    let toks = tokens(cfg.seq_len, 73);
    for prefix in 1..cfg.seq_len {
        let mut qc = qm.new_cache();
        let mut dc = dm.new_cache();
        let qpre = qm.prefill(&mut qc, &toks[..prefix], &pool);
        let dpre = dm.prefill(&mut dc, &toks[..prefix], &pool);
        for t in 0..prefix {
            assert_eq!(qpre.row(t), dpre.row(t), "prefill prefix={prefix} t={t}");
        }
        let qstep = qm.decode_step_batch(&mut [&mut qc], &[toks[prefix]], &pool);
        let dstep = dm.decode_step_batch(&mut [&mut dc], &[toks[prefix]], &pool);
        assert_eq!(qstep.row(0), dstep.row(0), "decode_step_batch prefix={prefix}");
    }
    // Full generations through the continuous-batching scheduler agree.
    let prompts = [tokens(2, 81), tokens(4, 82)];
    let run = |model: ServeModel| {
        let mut s = Scheduler::new(
            model,
            ServeConfig { max_batch: 2, max_new_tokens: 4 },
            Pool::serial(),
        );
        for p in &prompts {
            s.submit(p).unwrap();
        }
        s.run()
            .into_iter()
            .map(|c| (c.id, c.tokens, c.finish))
            .collect::<Vec<_>>()
    };
    let qm2 = ServeModel::quantized_with_adjuncts(&m, &qcfg, &adjuncts);
    let dm2 = qm2.dequantized();
    assert_eq!(run(qm2), run(dm2));
}

/// A model rigged so its first sampled token is a chosen special: zeroed
/// blocks pass the embedding straight through, and the tied head then
/// scores the boosted embedding row highest.
fn rigged_model(winner: u32) -> Model {
    let mut cfg = ModelConfig::new("rig", 16, 2, 2, 32);
    cfg.seq_len = 8;
    let mut m = Model::random(&cfg, 1);
    for b in &mut m.blocks {
        b.attn_norm = vec![1.0; cfg.dim];
        b.mlp_norm = vec![1.0; cfg.dim];
        b.wq = Mat::zeros(cfg.dim, cfg.dim);
        b.wk = Mat::zeros(cfg.dim, cfg.dim);
        b.wv = Mat::zeros(cfg.dim, cfg.dim);
        b.wo = Mat::zeros(cfg.dim, cfg.dim);
        b.gate = Mat::zeros(cfg.ffn, cfg.dim);
        b.up = Mat::zeros(cfg.ffn, cfg.dim);
        b.down = Mat::zeros(cfg.dim, cfg.ffn);
    }
    m.pos = Mat::zeros(cfg.seq_len, cfg.dim);
    m.final_norm = vec![1.0; cfg.dim];
    m.embed = Mat::zeros(VOCAB_SIZE, cfg.dim);
    m.embed.row_mut(10).fill(1.0);
    m.embed.row_mut(winner as usize).fill(2.0);
    m
}

/// Sampling EOS finishes with Eos; sampling any other special (PAD here)
/// finishes with Special — reported, never clamped into byte range.
#[test]
fn special_tokens_finish_sessions_explicitly() {
    for (winner, want) in [(EOS, FinishReason::Eos), (PAD, FinishReason::Special(PAD))] {
        let m = rigged_model(winner);
        let mut s = Scheduler::new(
            ServeModel::from_model(&m),
            ServeConfig { max_batch: 1, max_new_tokens: 4 },
            Pool::serial(),
        );
        s.submit(&[10]).unwrap();
        let done = s.run();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].finish, want, "winner={winner}");
        assert!(done[0].tokens.is_empty(), "special is excluded from output");
    }
}
