//! RTN/AWQ/QuIP counterparts to `gptq_edge_cases.rs`, plus the QEP
//! correction's own degenerate inputs: dead calibration columns, all-zero
//! weights, ragged group sizes, extreme weight scales, every production
//! bit width, and the ±QEP correction path — all must produce finite
//! outputs, and identical bytes for every global thread count (the
//! repo's core invariant; see docs/PERFORMANCE.md).

use qep::linalg::Mat;
use qep::qep::correction::corrected_weight;
use qep::quant::awq::Awq;
use qep::quant::quip::Quip;
use qep::quant::rtn::Rtn;
use qep::quant::{LayerCtx, QuantConfig, Quantizer};
use qep::util::pool;
use qep::util::rng::Rng;

fn gaussian_ctx(m: usize, d: usize, seed: u64) -> LayerCtx {
    let mut rng = Rng::new(seed);
    let x = Mat::randn(m, d, 1.0, &mut rng);
    LayerCtx::from_activations(&x, seed, "edge")
}

/// Activations with dead (always-zero) channels — the regime that breaks
/// naive per-channel scaling and Hessian inversion.
fn dead_column_ctx(m: usize, d: usize, dead: &[usize], seed: u64) -> LayerCtx {
    let mut rng = Rng::new(seed);
    let mut x = Mat::randn(m, d, 1.0, &mut rng);
    for t in 0..m {
        for &c in dead {
            *x.at_mut(t, c) = 0.0;
        }
    }
    LayerCtx::from_activations(&x, seed, "dead")
}

/// Rows at wildly different magnitudes (1e-6 … 1e6), plus one zero row —
/// per-row grids must absorb the scale spread without overflow.
fn extreme_scale_weights(d: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    let mut w = Mat::randn(6, d, 1.0, &mut rng);
    let scales = [1e-6f32, 1e-2, 1.0, 1e2, 1e6, 0.0];
    for (r, &s) in scales.iter().enumerate() {
        for v in w.row_mut(r) {
            *v *= s;
        }
    }
    w
}

fn assert_finite(m: &Mat, label: &str) {
    for (i, v) in m.data.iter().enumerate() {
        assert!(v.is_finite(), "{label}: non-finite value {v} at flat index {i}");
    }
}

fn quantizers() -> Vec<(&'static str, Box<dyn Quantizer>)> {
    vec![
        ("rtn", Box::new(Rtn) as Box<dyn Quantizer>),
        ("awq", Box::new(Awq::default())),
        ("quip", Box::new(Quip::default())),
    ]
}

#[test]
fn all_zero_weights_quantize_to_zero_for_every_method() {
    // d = 32: power of two so QuIP's rotation path runs too.
    let ctx = gaussian_ctx(128, 32, 1);
    let w = Mat::zeros(8, 32);
    for (name, q) in quantizers() {
        for bits in [2u32, 3, 4] {
            let out = q.quantize(&w, &QuantConfig::int(bits), &ctx).unwrap();
            assert_eq!((out.rows, out.cols), (8, 32), "{name} INT{bits}");
            assert!(
                out.data.iter().all(|&v| v == 0.0),
                "{name} INT{bits}: zero weights must stay exactly zero"
            );
        }
    }
}

#[test]
fn dead_calibration_columns_stay_finite_and_deterministic() {
    let ctx = dead_column_ctx(192, 32, &[0, 7, 31], 2);
    let mut rng = Rng::new(3);
    let w = Mat::randn(6, 32, 1.0, &mut rng);
    for (name, q) in quantizers() {
        for bits in [2u32, 3, 4] {
            let cfg = QuantConfig::int(bits);
            let a = q.quantize(&w, &cfg, &ctx).unwrap();
            assert_finite(&a, &format!("{name} INT{bits} dead-columns"));
            let b = q.quantize(&w, &cfg, &ctx).unwrap();
            assert_eq!(a, b, "{name} INT{bits}: repeat run must be bit-identical");
        }
    }
}

#[test]
fn fully_dead_activations_do_not_crash_any_method() {
    // Every calibration activation zero: the Hessian is all zeros and
    // AWQ's channel saliencies all hit their floor. RTN ignores the ctx
    // entirely; AWQ degenerates to (normalized) RTN; QuIP's rotated
    // Hessian is still all-zero, so its GPTQ core pins everything to 0.
    let x = Mat::zeros(96, 16);
    let ctx = LayerCtx::from_activations(&x, 0, "allzero");
    let mut rng = Rng::new(4);
    let w = Mat::randn(5, 16, 1.0, &mut rng);
    for (name, q) in quantizers() {
        let out = q.quantize(&w, &QuantConfig::int(3), &ctx).unwrap();
        assert_finite(&out, &format!("{name} fully-dead ctx"));
        if name == "quip" {
            assert!(
                out.data.iter().all(|&v| v == 0.0),
                "quip: all-dead rotated Hessian must pin every column to zero"
            );
        }
    }
}

#[test]
fn ragged_group_sizes_are_finite_and_idempotent_for_rtn() {
    // Group length 12 on d = 32: the last group holds only 8 columns.
    let ctx = gaussian_ctx(160, 32, 5);
    let mut rng = Rng::new(6);
    let w = Mat::randn(6, 32, 1.0, &mut rng);
    for bits in [2u32, 3, 4] {
        let cfg = QuantConfig::int_group(bits, 12);
        for (name, q) in quantizers() {
            let out = q.quantize(&w, &cfg, &ctx).unwrap();
            assert_finite(&out, &format!("{name} {} ragged groups", cfg.label()));
        }
        // RTN's output must already lie on the ragged grid: re-quantizing
        // is a fixed point (the per-group grids refit identically).
        let r1 = Rtn.quantize(&w, &cfg, &ctx).unwrap();
        let r2 = Rtn.quantize(&r1, &cfg, &ctx).unwrap();
        for (a, b) in r1.data.iter().zip(r2.data.iter()) {
            assert!((a - b).abs() < 1e-5, "RTN INT{bits}/g12 not a fixed point: {a} vs {b}");
        }
    }
}

#[test]
fn extreme_weight_scales_survive_every_method() {
    let ctx = gaussian_ctx(160, 32, 7);
    let w = extreme_scale_weights(32, 8);
    for (name, q) in quantizers() {
        for bits in [2u32, 3, 4] {
            let out = q.quantize(&w, &QuantConfig::int(bits), &ctx).unwrap();
            assert_finite(&out, &format!("{name} INT{bits} extreme scales"));
        }
    }
    // The zero row must quantize to exactly zero under RTN (its grid
    // degenerates to a single level).
    let r = Rtn.quantize(&w, &QuantConfig::int(3), &ctx).unwrap();
    assert!(r.row(5).iter().all(|&v| v == 0.0), "zero row must stay zero");
}

#[test]
fn correction_handles_degenerate_streams() {
    let mut rng = Rng::new(9);
    let w = Mat::randn(6, 16, 1.0, &mut rng);

    // Zero upstream error: the correction term is exactly zero.
    let x = Mat::randn(200, 16, 1.0, &mut rng);
    let (w_star, stats) = corrected_weight(&w, &x, &x, 0.5, 1.0).unwrap();
    assert_eq!(w_star, w, "δ = 0 must leave the weights untouched");
    assert_eq!(stats.rel_upstream_err, 0.0);

    // All-zero streams: Ĥ is pure damping, δ = 0, still exact identity.
    let z = Mat::zeros(200, 16);
    let (w_star, _) = corrected_weight(&w, &z, &z, 1.0, 1.0).unwrap();
    assert_eq!(w_star, w);

    // Dead columns in the quantized stream only: damping keeps the solve
    // alive and the output finite.
    let mut x_hat = x.clone();
    for t in 0..x_hat.rows {
        *x_hat.at_mut(t, 3) = 0.0;
        *x_hat.at_mut(t, 11) = 0.0;
    }
    let (w_star, stats) = corrected_weight(&w, &x, &x_hat, 0.5, 1.0).unwrap();
    assert_finite(&w_star, "correction with dead x̂ columns");
    assert!(stats.rel_correction.is_finite());

    // Extreme-magnitude streams stay inside f32/f64 range end to end.
    let mut x_big = x.clone();
    let mut xh_big = x_hat.clone();
    for v in x_big.data.iter_mut() {
        *v *= 1e4;
    }
    for v in xh_big.data.iter_mut() {
        *v *= 1e4;
    }
    let (w_star, _) = corrected_weight(&w, &x_big, &xh_big, 1.0, 1.0).unwrap();
    assert_finite(&w_star, "correction with 1e4-scaled streams");
}

/// The ONLY test in this binary that touches the process-wide thread
/// setting (the GEMMs under every method and under the correction's
/// Hessian/solve read the global pool). Keeping every
/// `set_global_threads` call inside one `#[test]` means the forced-serial
/// leg cannot be overwritten by a concurrently running test (cargo's
/// default harness runs tests in parallel threads of one process).
#[test]
fn methods_and_correction_are_bit_identical_across_thread_counts() {
    let ctx = dead_column_ctx(256, 32, &[5], 10);
    let mut rng = Rng::new(11);
    let w = Mat::randn(8, 32, 1.0, &mut rng);
    let x = Mat::randn(256, 32, 1.0, &mut rng);
    let mut x_hat = x.clone();
    for v in x_hat.data.iter_mut() {
        *v += 0.05 * rng.normal_f32();
    }

    let run_all = || {
        let mut outs: Vec<(String, Mat)> = Vec::new();
        for (name, q) in quantizers() {
            for bits in [2u32, 3] {
                let cfg = QuantConfig::int(bits);
                // Base path…
                outs.push((
                    format!("{name} INT{bits} base"),
                    q.quantize(&w, &cfg, &ctx).unwrap(),
                ));
                // …and the +QEP path: correct first, then quantize, as
                // the pipeline does.
                let (w_star, _) = corrected_weight(&w, &x, &x_hat, 0.5, 1.0).unwrap();
                outs.push((
                    format!("{name} INT{bits} +qep"),
                    q.quantize(&w_star, &cfg, &ctx).unwrap(),
                ));
            }
        }
        outs
    };

    pool::set_global_threads(1);
    let serial = run_all();
    pool::set_global_threads(4);
    let pooled = run_all();
    pool::set_global_threads(0);

    assert_eq!(serial.len(), pooled.len());
    for ((label, a), (_, b)) in serial.iter().zip(pooled.iter()) {
        assert_eq!(a, b, "{label}: output differs between --threads 1 and --threads 4");
    }
}
