//! Edge-case suite for the persistent worker pool (`util::pool`).
//!
//! The pool is the substrate under every parallel kernel in the repo, so
//! its failure modes must be boring: empty job lists are no-ops, a
//! panicking job surfaces the panic to the submitter without deadlocking
//! or poisoning later dispatches, nested `par_map` from a worker thread
//! runs inline, and shutdown/restart is transparent. (The `--threads 1`
//! never-spawn invariant lives in its own process-isolated test file,
//! `pool_serial_bypass.rs`, because these tests *do* start workers.)

use qep::util::pool::{self, Pool};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};

#[test]
fn zero_size_jobs_are_noops_on_every_engine() {
    let pool = Pool::new(4);
    pool.run(0, 16, |_, _| panic!("run must not invoke f for n=0"));
    pool.run_scoped(0, 16, |_, _| panic!("run_scoped must not invoke f for n=0"));
    let empty: Vec<usize> = pool.par_map(0, |_| panic!("par_map must not invoke f for n=0"));
    assert!(empty.is_empty());
}

#[test]
fn panicking_job_propagates_without_deadlock_and_pool_stays_usable() {
    let pool = Pool::new(4);

    // A worker-side panic must reach the submitter…
    let res = catch_unwind(AssertUnwindSafe(|| {
        pool.run(128, 1, |s, _| {
            if s == 77 {
                panic!("injected failure at chunk 77");
            }
        });
    }));
    assert!(res.is_err(), "panic must propagate out of Pool::run");

    // …and must not poison the persistent workers: follow-up dispatches
    // of both flavors still complete with full coverage.
    for round in 0..3 {
        let hits = AtomicUsize::new(0);
        pool.run(200, 7, |s, e| {
            hits.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 200, "round {round}");
        let out = pool.par_map(21, |i| i * 3);
        assert_eq!(out, (0..21).map(|i| i * 3).collect::<Vec<_>>(), "round {round}");
    }
}

#[test]
fn panicking_par_map_item_propagates_and_pool_survives() {
    let pool = Pool::new(3);
    let res = catch_unwind(AssertUnwindSafe(|| {
        pool.par_map(32, |i| {
            if i == 9 {
                panic!("item 9 failed");
            }
            i
        })
    }));
    assert!(res.is_err());
    assert_eq!(pool.par_map(4, |i| i + 10), vec![10, 11, 12, 13]);
}

#[test]
fn nested_par_map_from_worker_threads_runs_inline() {
    // Outer fan-out across workers; each item issues an inner par_map,
    // which must degrade to inline execution (no re-entrant dispatch, no
    // deadlock) and still return results in index order.
    let pool = Pool::new(4);
    let outer = pool.par_map(6, |i| {
        let inner = Pool::new(4).par_map(5, move |j| i * 10 + j);
        inner.iter().sum::<usize>()
    });
    let want: Vec<usize> = (0..6)
        .map(|i| (0..5).map(|j| i * 10 + j).sum())
        .collect();
    assert_eq!(outer, want);
}

#[test]
fn deeply_nested_run_inside_par_map_inside_run_stays_inline() {
    let total = AtomicUsize::new(0);
    let tref = &total;
    Pool::new(4).run(4, 1, |s, e| {
        for _ in s..e {
            let sums = Pool::new(4).par_map(3, |i| {
                let mut acc = 0usize;
                Pool::new(4).run(8, 2, |is, ie| {
                    // Innermost level: runs inline on this worker, so a
                    // plain non-atomic accumulator would also be fine;
                    // the atomic keeps the closure Fn.
                    tref.fetch_add(ie - is, Ordering::Relaxed);
                });
                acc += i;
                acc
            });
            assert_eq!(sums, vec![0, 1, 2]);
        }
    });
    assert_eq!(total.load(Ordering::Relaxed), 4 * 3 * 8);
}

#[test]
fn shutdown_and_restart_are_transparent() {
    let pool = Pool::new(2);
    assert_eq!(pool.par_map(3, |i| i), vec![0, 1, 2]);
    pool::shutdown();
    // A fresh dispatch restarts the workers transparently.
    assert_eq!(pool.par_map(3, |i| i + 1), vec![1, 2, 3]);
    // Repeated shutdown is a no-op.
    pool::shutdown();
    pool::shutdown();
    assert_eq!(pool.par_map(2, |i| i * 5), vec![0, 5]);
}

#[test]
fn oversubscribed_thread_counts_complete() {
    // Requesting far more threads than exist hands out more tickets than
    // there are workers; the job must still complete with full coverage.
    let pool = Pool::new(64);
    let hits = AtomicUsize::new(0);
    pool.run(1000, 3, |s, e| {
        hits.fetch_add(e - s, Ordering::Relaxed);
    });
    assert_eq!(hits.load(Ordering::Relaxed), 1000);
}

/// Best-effort extraction of a panic payload's message (panics raised via
/// `panic!("...")` carry a `String`; literal-only panics carry `&str`).
fn panic_message(err: &(dyn std::any::Any + Send)) -> String {
    err.downcast_ref::<String>()
        .cloned()
        .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default()
}

#[test]
fn par_map_slot_diagnostics_name_the_job_and_pool_state() {
    use std::sync::Mutex;

    // The happy path is a plain in-order collect.
    let slots: Vec<Mutex<Option<usize>>> = (0..4).map(|i| Mutex::new(Some(i * i))).collect();
    assert_eq!(pool::collect_par_map_slots(slots, 8), vec![0, 1, 4, 9]);

    // An unfilled slot must fail with the job index, the job count, and
    // the pool state — not a bare `unwrap`.
    let slots: Vec<Mutex<Option<usize>>> =
        vec![Mutex::new(Some(10)), Mutex::new(None), Mutex::new(Some(30))];
    let err = catch_unwind(AssertUnwindSafe(|| pool::collect_par_map_slots(slots, 4)))
        .expect_err("unfilled slot must panic");
    let msg = panic_message(err.as_ref());
    assert!(msg.contains("job 1 of 3"), "{msg}");
    assert!(msg.contains("threads=4"), "{msg}");
    assert!(msg.contains("workers started="), "{msg}");

    // A poisoned slot (a job panicked while publishing) gets its own
    // diagnostic.
    let slots: Vec<Mutex<Option<usize>>> = vec![Mutex::new(Some(1))];
    let _ = catch_unwind(AssertUnwindSafe(|| {
        let _guard = slots[0].lock().unwrap();
        panic!("poison the slot lock");
    }));
    assert!(slots[0].lock().is_err(), "lock must be poisoned for this test");
    let err = catch_unwind(AssertUnwindSafe(|| pool::collect_par_map_slots(slots, 2)))
        .expect_err("poisoned slot must panic");
    let msg = panic_message(err.as_ref());
    assert!(msg.contains("slot 0 of 1 is poisoned"), "{msg}");
    assert!(msg.contains("publishing its result"), "{msg}");
}
