//! Plan-layer invariants for the distributed experiment runner.
//!
//! The contracts under test:
//! * every sweep enumerates a stable manifest of unique cell IDs;
//! * `PlanCell::parse ∘ PlanCell::id` is the identity for every cell of
//!   every sweep, under fast and full plan parameters;
//! * for every shard count N ∈ {1, 2, 3, 7}, the union of the shard
//!   assignments equals the full manifest with no duplicates;
//! * merge coverage verification rejects gaps, duplicates, and IDs that
//!   are not in the manifest, each with a clear error;
//! * `PlanParams::from_args` mirrors the historical CLI defaults.

use qep::exp::plan::{
    self, manifest, shard_of, verify_coverage, PlanCell, PlanParams, ShardSpec, SweepId,
};
use qep::io::results::CellRecord;
use qep::model::Size;
use qep::util::cli::Args;

fn all_sweeps() -> [SweepId; 11] {
    [
        SweepId::Table12,
        SweepId::Table3,
        SweepId::Table4,
        SweepId::AblationAlpha,
        SweepId::Fig2,
        SweepId::Fig3,
        SweepId::Appendix,
        SweepId::Lowrank,
        SweepId::Budget,
        SweepId::Cbq,
        SweepId::All,
    ]
}

fn param_variants() -> Vec<PlanParams> {
    let mut fastish = PlanParams::for_sizes(&[Size::TinyS]);
    fastish.fig3_bits = vec![3];
    fastish.fig3_seeds = 2;
    let full = PlanParams::for_sizes(&Size::all());
    vec![fastish, full]
}

#[test]
fn manifests_are_nonempty_with_unique_ids() {
    for params in param_variants() {
        for sweep in all_sweeps() {
            let cells = manifest(sweep, &params).unwrap();
            assert!(!cells.is_empty(), "{sweep:?} enumerated nothing");
            let mut ids: Vec<String> = cells.iter().map(|c| c.id()).collect();
            let n = ids.len();
            ids.sort();
            ids.dedup();
            assert_eq!(ids.len(), n, "{sweep:?} has duplicate cell ids");
        }
    }
}

#[test]
fn cell_ids_round_trip_through_parse() {
    for params in param_variants() {
        for sweep in all_sweeps() {
            for cell in manifest(sweep, &params).unwrap() {
                let id = cell.id();
                let back = PlanCell::parse(&id)
                    .unwrap_or_else(|| panic!("'{id}' does not parse"));
                assert_eq!(back, cell, "parse∘id is not the identity for '{id}'");
                assert_eq!(back.id(), id, "id∘parse is not the identity for '{id}'");
            }
        }
    }
}

#[test]
fn garbage_ids_do_not_parse() {
    for bad in [
        "",
        "table12",
        "table12/INT3/GPTQ/+qep",               // missing size
        "table12/INT3/GPTQ/+qep/tiny-s/extra",  // trailing segment
        "table12/INT3/NOPE/+qep/tiny-s",        // unknown method
        "table12/INT3/GPTQ/maybe/tiny-s",       // bad qep marker
        "fig3/INT3/tiny-s/+qep/7",              // seed missing 's' prefix
        "ablation-alpha/0.25/tiny-s",           // alpha missing 'a' prefix
        "fig2/tiny-s/INT3/4/+qep",              // blocks missing 'b' prefix
        "nonsense/INT3/GPTQ/base/tiny-s",
        "lowrank/INT3/RTN/+lr0/tiny-s",         // rank 0 renders as base, never +lr0
        "lowrank/INT3/RTN/+lr/tiny-s",          // empty rank
        "lowrank/INT3/RTN/+qep+lr/tiny-s",      // empty rank, qep form
        "lowrank/INT3/RTN/+lr02/tiny-s",        // leading zero breaks id∘parse
        "lowrank/INT3/RTN/+lr-4/tiny-s",        // negative rank
        "table12/INT3/GPTQ/+lr2/tiny-s",        // rank variants are lowrank-only
        "budget/2.50/GPTQ/dp/tiny-s",           // non-canonical budget ("2.5")
        "budget/3/GPTQ/dp/tiny-s",              // missing decimal breaks id∘parse
        "budget/2.5/GPTQ/rtn/tiny-s",           // unknown allocator
        "budget/2.5/GPTQ/dp+lr2/tiny-s",        // rank variants are lowrank-only
        "budget/2.5/GPTQ/base/tiny-s",          // uniform rows use budget/uni/...
        "budget/uni/INT3/GPTQ/dp/tiny-s",       // uniform rows carry base/+qep
        "budget/1.5/GPTQ/dp/tiny-s",            // below the feasible range
        "budget/8.5/GPTQ/dp/tiny-s",            // above the feasible range
        "cbq/INT3/GPTQ/w0/+qep/tiny-s",         // window 0 is never planned
        "cbq/INT3/GPTQ/w02/+qep/tiny-s",        // leading zero breaks id∘parse
        "cbq/INT3/GPTQ/2/+qep/tiny-s",          // window missing 'w' prefix
        "cbq/INT3/GPTQ/w-2/+qep/tiny-s",        // negative window
        "table12/INT3/GPTQ/w2/+qep/tiny-s",     // window segments are cbq-only
    ] {
        assert!(PlanCell::parse(bad).is_none(), "'{bad}' should not parse");
    }
}

#[test]
fn every_shard_split_covers_the_manifest_exactly_once() {
    for params in param_variants() {
        for sweep in all_sweeps() {
            let cells = manifest(sweep, &params).unwrap();
            for n in [1usize, 2, 3, 7] {
                let mut seen: Vec<String> = Vec::new();
                for i in 1..=n {
                    let spec = ShardSpec { index: i, count: n };
                    for c in spec.filter(&cells) {
                        seen.push(c.id());
                    }
                }
                assert_eq!(seen.len(), cells.len(), "{sweep:?} N={n}: union size");
                let mut sorted = seen.clone();
                sorted.sort();
                sorted.dedup();
                assert_eq!(sorted.len(), cells.len(), "{sweep:?} N={n}: duplicates");
                let mut want: Vec<String> = cells.iter().map(|c| c.id()).collect();
                want.sort();
                assert_eq!(sorted, want, "{sweep:?} N={n}: union != manifest");
            }
        }
    }
}

#[test]
fn shard_assignment_is_round_robin_by_index() {
    assert_eq!(shard_of(0, 3), 1);
    assert_eq!(shard_of(1, 3), 2);
    assert_eq!(shard_of(2, 3), 3);
    assert_eq!(shard_of(3, 3), 1);
    // N=1 owns everything.
    for j in 0..10 {
        assert_eq!(shard_of(j, 1), 1);
    }
}

#[test]
fn shard_specs_parse_strictly() {
    assert_eq!(ShardSpec::parse("1/3").unwrap(), ShardSpec { index: 1, count: 3 });
    assert_eq!(ShardSpec::parse("3/3").unwrap(), ShardSpec { index: 3, count: 3 });
    for bad in ["0/3", "4/3", "x/3", "3/0", "3", "", "1/3/5", "-1/3"] {
        assert!(ShardSpec::parse(bad).is_err(), "'{bad}' should be rejected");
    }
}

fn records_for(cells: &[PlanCell]) -> Vec<CellRecord> {
    cells.iter().map(|c| CellRecord::new(c.id(), 1, 1)).collect()
}

#[test]
fn merge_accepts_exact_coverage_in_any_order() {
    let params = PlanParams::for_sizes(&[Size::TinyS]);
    let cells = manifest(SweepId::Table4, &params).unwrap();
    let mut records = records_for(&cells);
    records.reverse();
    let map = verify_coverage(&cells, records).unwrap();
    for c in &cells {
        assert_eq!(map.get(c).unwrap().id, c.id());
    }
}

#[test]
fn merge_rejects_gaps_duplicates_and_aliens() {
    let params = PlanParams::for_sizes(&[Size::TinyS]);
    let cells = manifest(SweepId::Table4, &params).unwrap();

    // Gap: drop one record.
    let mut missing = records_for(&cells);
    let dropped = missing.remove(3);
    let err = verify_coverage(&cells, missing).unwrap_err().to_string();
    assert!(err.contains("no record"), "{err}");
    assert!(err.contains(&dropped.id), "{err}");

    // Duplicate: one cell recorded twice.
    let mut doubled = records_for(&cells);
    doubled.push(CellRecord::new(cells[2].id(), 2, 2));
    let err = verify_coverage(&cells, doubled).unwrap_err().to_string();
    assert!(err.contains("duplicate"), "{err}");
    assert!(err.contains(&cells[2].id()), "{err}");

    // Alien: a record whose ID is not in this manifest (e.g. merged the
    // wrong sweep's directory).
    let mut alien = records_for(&cells);
    alien.push(CellRecord::new("fig3/INT3/tiny-s/base/s0".into(), 1, 1));
    let err = verify_coverage(&cells, alien).unwrap_err().to_string();
    assert!(err.contains("not in the manifest"), "{err}");
    assert!(err.contains("fig3/INT3/tiny-s/base/s0"), "{err}");
}

#[test]
fn all_manifest_is_the_ordered_concatenation_of_its_parts() {
    let params = PlanParams::for_sizes(&[Size::TinyS]);
    let all = manifest(SweepId::All, &params).unwrap();
    let mut concat = Vec::new();
    for part in SweepId::all_parts() {
        concat.extend(manifest(part, &params).unwrap());
    }
    assert_eq!(all, concat);
}

fn parse_args(argv: &[&str]) -> Args {
    Args::parse(argv.iter().map(|s| s.to_string()))
}

#[test]
fn from_args_mirrors_the_historical_cli_defaults() {
    // --fast: one size, 2 fig3 seeds, INT3-only fig3 bits, 2 appendix settings.
    let a = parse_args(&["exp", "all", "--fast"]);
    let p = PlanParams::from_args(SweepId::All, &a).unwrap();
    assert_eq!(p.sizes, vec![Size::TinyS]);
    assert_eq!(p.fig3_seeds, 2);
    assert_eq!(p.fig3_bits, vec![3]);
    assert_eq!(p.appendix_settings.len(), 2);
    // Under `all`, fig2 uses the second size when present.
    let a = parse_args(&["exp", "all", "--sizes", "s,m,l"]);
    let p = PlanParams::from_args(SweepId::All, &a).unwrap();
    assert_eq!(p.fig2_size, Size::TinyM);
    assert_eq!(p.table4_size, Size::TinyS);
    assert_eq!(p.fig3_seeds, 5);
    assert_eq!(p.appendix_settings.len(), 8);
    // Standalone fig2/fig3 read their own knobs.
    let a = parse_args(&["exp", "fig2", "--sizes", "m", "--bits", "2", "--blocks", "3"]);
    let p = PlanParams::from_args(SweepId::Fig2, &a).unwrap();
    assert_eq!(p.fig2_size, Size::TinyM);
    assert_eq!(p.fig2_bits, 2);
    assert_eq!(p.fig2_blocks, 3);
    let a = parse_args(&["exp", "fig3", "--fast", "--seeds", "4"]);
    let p = PlanParams::from_args(SweepId::Fig3, &a).unwrap();
    assert_eq!(p.fig3_seeds, 4);
    // Garbage --sizes is a hard error, not an empty sweep.
    let a = parse_args(&["exp", "all", "--sizes", "gigantic"]);
    assert!(PlanParams::from_args(SweepId::All, &a).is_err());
    // ... and so is a single typo'd size among valid ones (silently
    // dropping it would shrink a sharded manifest).
    let a = parse_args(&["exp", "all", "--sizes", "tiny-s,tiny-x"]);
    let err = PlanParams::from_args(SweepId::All, &a).unwrap_err().to_string();
    assert!(err.contains("tiny-x"), "{err}");
    // Unparseable numeric plan flags error instead of silently
    // planning the default manifest.
    let a = parse_args(&["exp", "fig3", "--seeds", "1O"]);
    assert!(PlanParams::from_args(SweepId::Fig3, &a).is_err());
    let a = parse_args(&["exp", "fig2", "--bits", "three"]);
    assert!(PlanParams::from_args(SweepId::Fig2, &a).is_err());
    let a = parse_args(&["exp", "fig2", "--blocks", "x"]);
    assert!(PlanParams::from_args(SweepId::Fig2, &a).is_err());
}

#[test]
fn lowrank_plan_flags_and_variants() {
    // Defaults: full ranks {4,16} over INT3+INT2; --fast shrinks both.
    let p = PlanParams::for_sizes(&[Size::TinyS]);
    assert_eq!(p.lowrank_ranks, vec![4, 16]);
    assert_eq!(p.lowrank_settings.len(), 2);
    let a = parse_args(&["exp", "lowrank", "--fast"]);
    let p = PlanParams::from_args(SweepId::Lowrank, &a).unwrap();
    assert_eq!(p.lowrank_ranks, vec![2]);
    assert_eq!(p.lowrank_settings.len(), 1);
    // --ranks overrides, strictly (0 and non-integers are hard errors).
    let a = parse_args(&["exp", "lowrank", "--fast", "--ranks", "1,8,32"]);
    let p = PlanParams::from_args(SweepId::Lowrank, &a).unwrap();
    assert_eq!(p.lowrank_ranks, vec![1, 8, 32]);
    for bad in ["0", "4,0", "x", "4,,8", "-2"] {
        let a = parse_args(&["exp", "lowrank", "--ranks", bad]);
        assert!(
            PlanParams::from_args(SweepId::Lowrank, &a).is_err(),
            "--ranks {bad} should be rejected"
        );
    }
    // The manifest enumerates rank 0 (base/+qep) next to every --ranks
    // value, and the variant segment round-trips through parse.
    let a = parse_args(&["exp", "lowrank", "--fast", "--ranks", "3"]);
    let p = PlanParams::from_args(SweepId::Lowrank, &a).unwrap();
    let cells = manifest(SweepId::Lowrank, &p).unwrap();
    // 1 setting × 2 methods × ±qep × {0, 3} × 1 size.
    assert_eq!(cells.len(), 8);
    let ids: Vec<String> = cells.iter().map(|c| c.id()).collect();
    assert!(ids.contains(&"lowrank/INT3/RTN/base/tiny-s".to_string()), "{ids:?}");
    assert!(ids.contains(&"lowrank/INT3/RTN/+lr3/tiny-s".to_string()), "{ids:?}");
    assert!(ids.contains(&"lowrank/INT3/GPTQ/+qep+lr3/tiny-s".to_string()), "{ids:?}");
    assert_eq!(plan::variant_name(false, 0), "base");
    assert_eq!(plan::variant_name(true, 0), "+qep");
    assert_eq!(plan::variant_name(false, 7), "+lr7");
    assert_eq!(plan::variant_name(true, 7), "+qep+lr7");
}

#[test]
fn budget_plan_flags_and_cells() {
    use qep::quant::BitBudget;
    // Defaults: budgets {2.5, 3.0, 3.5}; --fast shrinks to {2.5}.
    let p = PlanParams::for_sizes(&[Size::TinyS]);
    assert_eq!(
        p.budgets,
        vec![
            BitBudget::from_decibits(25),
            BitBudget::from_decibits(30),
            BitBudget::from_decibits(35)
        ]
    );
    let a = parse_args(&["exp", "budget", "--fast"]);
    let p = PlanParams::from_args(SweepId::Budget, &a).unwrap();
    assert_eq!(p.budgets, vec![BitBudget::from_decibits(25)]);
    // Fast manifest: uniform INT2 baselines (2 methods × ±qep) plus the
    // 2.5 DP cells (2 methods × ±qep) on one size.
    let cells = manifest(SweepId::Budget, &p).unwrap();
    assert_eq!(cells.len(), 8);
    let ids: Vec<String> = cells.iter().map(|c| c.id()).collect();
    assert!(ids.contains(&"budget/uni/INT2/RTN/base/tiny-s".to_string()), "{ids:?}");
    assert!(ids.contains(&"budget/uni/INT2/GPTQ/+qep/tiny-s".to_string()), "{ids:?}");
    assert!(ids.contains(&"budget/2.5/RTN/dp/tiny-s".to_string()), "{ids:?}");
    assert!(ids.contains(&"budget/2.5/GPTQ/dp+qep/tiny-s".to_string()), "{ids:?}");
    // Full defaults: floors {2, 3} dedupe the uniform baselines (3.0 and
    // 3.5 share INT3): 2×2×2 uniform + 3×2×2 allocated.
    let p = PlanParams::for_sizes(&[Size::TinyS]);
    let cells = manifest(SweepId::Budget, &p).unwrap();
    assert_eq!(cells.len(), 20);
    // --budgets overrides, strictly: out-of-range, malformed, and
    // duplicate values are hard errors (duplicates would enumerate
    // duplicate cell IDs).
    let a = parse_args(&["exp", "budget", "--budgets", "2.5,4.0"]);
    let p = PlanParams::from_args(SweepId::Budget, &a).unwrap();
    assert_eq!(
        p.budgets,
        vec![BitBudget::from_decibits(25), BitBudget::from_decibits(40)]
    );
    for bad in ["1.5", "8.5", "abc", "2.55", "2.5,2.5", "2.5,,3.0", ""] {
        let a = parse_args(&["exp", "budget", "--budgets", bad]);
        assert!(
            PlanParams::from_args(SweepId::Budget, &a).is_err(),
            "--budgets {bad} should be rejected"
        );
    }
    // Variant rendering.
    assert_eq!(plan::budget_variant_name(qep::quant::Alloc::Dp, false), "dp");
    assert_eq!(plan::budget_variant_name(qep::quant::Alloc::Dp, true), "dp+qep");
    assert_eq!(plan::budget_variant_name(qep::quant::Alloc::Greedy, true), "greedy+qep");
}

#[test]
fn cbq_plan_flags_and_cells() {
    // Defaults: windows {1, 2, 3}; --fast shrinks to {1, 2}.
    let p = PlanParams::for_sizes(&[Size::TinyS]);
    assert_eq!(p.cbq_windows, vec![1, 2, 3]);
    let a = parse_args(&["exp", "cbq", "--fast"]);
    let p = PlanParams::from_args(SweepId::Cbq, &a).unwrap();
    assert_eq!(p.cbq_windows, vec![1, 2]);
    // Fast manifest: 2 methods × ±qep × 2 windows × 1 size. Window 1 —
    // the layer-wise baseline row — is enumerated like any other.
    let cells = manifest(SweepId::Cbq, &p).unwrap();
    assert_eq!(cells.len(), 8);
    let ids: Vec<String> = cells.iter().map(|c| c.id()).collect();
    assert!(ids.contains(&"cbq/INT3/GPTQ/w1/base/tiny-s".to_string()), "{ids:?}");
    assert!(ids.contains(&"cbq/INT3/GPTQ/w2/+qep/tiny-s".to_string()), "{ids:?}");
    assert!(ids.contains(&"cbq/INT3/AWQ/w2/base/tiny-s".to_string()), "{ids:?}");
    // --windows overrides, strictly: zero, malformed, and duplicate
    // values are hard errors (duplicates would enumerate duplicate
    // cell IDs).
    let a = parse_args(&["exp", "cbq", "--windows", "1,4"]);
    let p = PlanParams::from_args(SweepId::Cbq, &a).unwrap();
    assert_eq!(p.cbq_windows, vec![1, 4]);
    for bad in ["0", "1,0", "x", "1,,2", "-2", "2,2", ""] {
        let a = parse_args(&["exp", "cbq", "--windows", bad]);
        assert!(
            PlanParams::from_args(SweepId::Cbq, &a).is_err(),
            "--windows {bad} should be rejected"
        );
    }
    // Window segment rendering.
    assert_eq!(plan::window_name(1), "w1");
    assert_eq!(plan::window_name(12), "w12");
}

#[test]
fn sweep_names_resolve_with_aliases() {
    for (alias, want) in [
        ("fig1", SweepId::Table12),
        ("table1", SweepId::Table12),
        ("table2", SweepId::Table12),
        ("table3", SweepId::Table3),
        ("table4", SweepId::Table4),
        ("ablation-alpha", SweepId::AblationAlpha),
        ("fig2", SweepId::Fig2),
        ("fig3", SweepId::Fig3),
        ("appendix", SweepId::Appendix),
        ("table7", SweepId::Appendix),
        ("lowrank", SweepId::Lowrank),
        ("lqer", SweepId::Lowrank),
        ("qera", SweepId::Lowrank),
        ("budget", SweepId::Budget),
        ("mixed-precision", SweepId::Budget),
        ("cbq", SweepId::Cbq),
        ("cross-block", SweepId::Cbq),
        ("all", SweepId::All),
    ] {
        assert_eq!(SweepId::from_name(alias), Some(want), "{alias}");
    }
    assert_eq!(SweepId::from_name("table11"), None);
    // Fig. 2's plan resolves block counts statically from the size.
    assert_eq!(plan::resolve_fig2_blocks(Size::TinyS, None), 2);
    assert_eq!(plan::resolve_fig2_blocks(Size::TinyS, Some(99)), 4);
}
